"""End-to-end system behaviour: training reduces loss; the launchers run;
the dry-run machinery works on a scaled mesh (subprocess: own XLA flags)."""

import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.compat import HAS_NATIVE_SHARD_MAP
from repro.data.pipeline import DataConfig, make_batch
from repro.launch.mesh import use_mesh
from repro.models import lm
from repro.optim import adamw
from repro.runtime import steps as steps_mod
from repro.runtime.fault_tolerance import elastic_mesh

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_lm_training_reduces_loss():
    cfg = registry.get("qwen2.5-3b").smoke
    mesh = elastic_mesh(1)
    opt_cfg = adamw.AdamWConfig(lr=3e-3, warmup_steps=2, total_steps=40,
                                schedule="constant")
    with use_mesh(mesh):
        bundle = steps_mod.make_train_step(cfg, mesh, opt_cfg, batch=4,
                                           seq=32, donate=False)
        params, _ = lm.init(cfg, jax.random.PRNGKey(0))
        state = {"params": params, "opt": adamw.init(params, opt_cfg)}
        data = DataConfig(vocab=cfg.vocab, seq_len=32, global_batch=4)
        losses = []
        for step in range(30):
            batch = make_batch(data, step % 2, mesh)  # 2 repeating batches
            state, m = bundle.fn(state, batch)
            losses.append(float(jax.device_get(m["loss"])))
    assert losses[-1] < losses[0] - 0.5, losses[::6]


def test_prefill_step_runs():
    cfg = registry.get("internvl2-1b").smoke
    mesh = elastic_mesh(1)
    with use_mesh(mesh):
        bundle = steps_mod.make_prefill_step(cfg, mesh, batch=2, seq=16)
        params, _ = lm.init(cfg, jax.random.PRNGKey(0))
        batch = {
            "tokens": jnp.zeros((2, 16), jnp.int32),
            "prefix_embeds": jnp.zeros((2, cfg.frontend_len, cfg.d_model),
                                       jnp.bfloat16),
        }
        out = bundle.fn(params, batch)
        assert out.shape[0] == 2 and not bool(jnp.isnan(out).any())


def test_decode_step_runs_and_advances_cache():
    cfg = registry.get("recurrentgemma-9b").smoke
    mesh = elastic_mesh(1)
    with use_mesh(mesh):
        bundle = steps_mod.make_decode_step(cfg, mesh, batch=2, seq=32)
        params, _ = lm.init(cfg, jax.random.PRNGKey(0))
        cache = lm.init_cache(cfg, 2, 32, length=8)
        logits, cache2 = bundle.fn(params, cache,
                                   jnp.zeros((2, 1), jnp.int32))
        assert int(cache2.length) == 9
        assert not bool(jnp.isnan(logits).any())


@pytest.mark.slow
def test_dryrun_cell_subprocess():
    """The real dry-run entry point on a scaled (4-device) mesh."""
    env = dict(os.environ, DRYRUN_DEVICES="4", DRYRUN_MESH="2x2",
               PYTHONPATH=os.path.join(REPO, "src"))
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", "qwen2.5-3b",
         "--shape", "decode_32k"],
        capture_output=True, text=True, env=env, timeout=560, cwd=REPO)
    assert "1/1 cells compiled" in out.stdout, out.stdout + out.stderr


@pytest.mark.slow
@pytest.mark.skipif(
    not HAS_NATIVE_SHARD_MAP,
    reason="partial-manual shard_map unsupported by jax 0.4.x SPMD")
def test_compressed_pod_trainstep_subprocess():
    """int8 cross-pod gradient compression: compile + run on a 2x2x2 mesh."""
    script = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
from repro.configs import registry
from repro.runtime import steps as steps_mod
from repro.models import lm
from repro.optim import adamw
from repro.launch.mesh import use_mesh
from repro.data.pipeline import DataConfig, make_batch
cfg = registry.get("qwen2.5-3b").smoke
mesh = jax.make_mesh((2, 2, 2), ("pod", "data", "model"))
with use_mesh(mesh):
    b = steps_mod.make_train_step_compressed(cfg, mesh, batch=4, seq=16)
    params, specs = lm.init(cfg, jax.random.PRNGKey(0))
    opt_cfg = adamw.AdamWConfig(state_dtype=cfg.opt_state_dtype)
    state = {"params": params, "opt": adamw.init(params, opt_cfg)}
    err = jax.tree.map(lambda p: jnp.zeros((2,) + p.shape, jnp.float32), params)
    batch = make_batch(DataConfig(vocab=cfg.vocab, seq_len=16, global_batch=4), 0, mesh)
    state, err, m = b.fn(state, err, batch)
    loss = float(jax.device_get(m["loss"]))
    assert loss == loss and loss < 20, loss
    print("COMPRESSED_OK", loss)
"""
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    out = subprocess.run([sys.executable, "-c", script], capture_output=True,
                         text=True, env=env, timeout=560, cwd=REPO)
    assert "COMPRESSED_OK" in out.stdout, out.stdout + out.stderr[-2000:]
