"""GeneratorRunner contract — the ISSUE-8 refactor surface.

All four generator families serve through one runner contract
(``models/runner.py``): policy-driven forwards that match the legacy
entry points, ``tconv_problems()`` that agree with what the forward
actually traces, input geometry helpers, plan resolution precedence, the
int8 policy's closeness to f32, and the generic step builder that
replaced the per-model sample-step plumbing in ``runtime/steps.py``.
"""

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core.maps import TConvProblem
from repro.kernels import ops
from repro.kernels.registry import Plan
from repro.models import gan
from repro.models.runner import (DEFAULT_METHOD, GeneratorRunner, make_runner,
                                 get_spec, runner_names)

MODELS = ("dcgan", "pix2pix", "fsrcnn", "styletransfer")

# CPU-sized geometry per family (same knobs the serve smoke CLI uses).
TINY = {
    "dcgan": dict(init_kw={"scale_down": 16}),
    "pix2pix": dict(init_kw={"depth": 4, "scale_down": 16}),
    "fsrcnn": dict(init_kw={"d": 8, "s": 4, "m": 1}, input_hw=8),
    "styletransfer": dict(init_kw={"base": 8, "n_res": 1}, input_hw=16),
}


@pytest.fixture(scope="module")
def runners():
    return {name: make_runner(name, key=jax.random.PRNGKey(i), **TINY[name])
            for i, name in enumerate(MODELS)}


def test_registry_covers_all_four_families():
    assert set(runner_names()) >= set(MODELS)
    with pytest.raises(ValueError, match="unknown runner"):
        get_spec("nope")


def test_unknown_option_rejected():
    with pytest.raises(TypeError, match="accepts options"):
        make_runner("fsrcnn", key=jax.random.PRNGKey(0),
                    init_kw=TINY["fsrcnn"]["init_kw"], not_an_option=1)
    with pytest.raises(TypeError, match="accepts options"):
        # dcgan declares no options at all
        make_runner("dcgan", key=jax.random.PRNGKey(0),
                    init_kw=TINY["dcgan"]["init_kw"], input_hw=8)


# ---------------------------------------------------------------------------
# Forward parity: the runner IS the legacy entry point.
# ---------------------------------------------------------------------------


def _legacy_forward(name, params, x):
    if name == "dcgan":
        return gan.dcgan_generator(params, x, method=DEFAULT_METHOD)
    if name == "pix2pix":
        return gan.pix2pix_generator(params, x,
                                     depth=gan.pix2pix_depth(params),
                                     method=DEFAULT_METHOD)
    if name == "fsrcnn":
        return gan.fsrcnn(params, x, method=DEFAULT_METHOD)
    return gan.styletransfer(params, x, method=DEFAULT_METHOD)


@pytest.mark.parametrize("name", MODELS)
def test_runner_matches_legacy_forward(runners, name):
    r = runners[name]
    x = r.example_inputs(batch=1, seed=3)
    got = np.asarray(r.apply(x))
    want = np.asarray(_legacy_forward(name, r.params, x))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)
    assert np.isfinite(got).all()


# ---------------------------------------------------------------------------
# tconv_problems() agrees with what the forward actually traces.
# ---------------------------------------------------------------------------


class _RecordingPolicy:
    """Logs every named TCONV the forward issues (shape ground truth)."""

    def __init__(self):
        self.layers = {}

    def tconv(self, x, w, bias=None, *, name, stride, padding="SAME",
              activation="none"):
        self.layers[name] = TConvProblem(x.shape[1], x.shape[2], x.shape[3],
                                         w.shape[0], w.shape[2], stride)
        return ops.tconv(x, w, bias, stride=stride, padding=padding,
                         method="lax", activation=activation)


@pytest.mark.parametrize("name", MODELS)
def test_tconv_problems_match_traced_layers(runners, name):
    r = runners[name]
    rec = _RecordingPolicy()
    r.spec.forward(r.params, r.example_inputs(batch=1), r.options, policy=rec)
    assert rec.layers, "forward issued no TCONVs through the policy"
    assert rec.layers == r.tconv_problems()


# ---------------------------------------------------------------------------
# Input geometry.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", MODELS)
def test_input_spec_and_example_inputs(runners, name):
    r = runners[name]
    spec = r.input_spec(batch=3)
    x = r.example_inputs(batch=3, seed=1)
    assert spec.shape == x.shape == (3,) + r.input_shape()
    assert spec.dtype == x.dtype == jnp.float32


# ---------------------------------------------------------------------------
# Plan resolution precedence.
# ---------------------------------------------------------------------------


def test_resolve_plans_explicit_beats_cache(monkeypatch, tmp_path, runners):
    from repro.core import autotune, plan_table

    monkeypatch.setenv(autotune.CACHE_ENV, str(tmp_path / "cache.json"))
    monkeypatch.setenv(plan_table.TABLE_DIR_ENV, str(tmp_path / "no_plans"))
    autotune.reset_shared_caches()
    plan_table.reset_shipped_tables()

    r = runners["dcgan"]
    problems = r.tconv_problems()
    name, prob = next(iter(problems.items()))
    cached = Plan(2, 4, "bcj")
    autotune.shared_cache().put(
        autotune.cache_key(prob, dtype=jnp.float32, batch=2), cached)

    assert r.resolve_plans(batch=2) == {name: cached}
    override = Plan(1, 4, "cbj")
    assert r.resolve_plans(batch=2, plans={name: override})[name] == override
    # plan-incapable method: only explicit entries pass through
    r_lax = GeneratorRunner(r.spec, r.params, method="lax")
    assert r_lax.resolve_plans(batch=2) == {}
    assert r_lax.resolve_plans(batch=2, plans={name: override}) == {
        name: override}
    autotune.reset_shared_caches()


# ---------------------------------------------------------------------------
# Int8 policy.
# ---------------------------------------------------------------------------


def test_int8_calibration_and_closeness(runners):
    r = runners["dcgan"]
    scales = r.quant_scales()
    assert set(scales) == set(r.tconv_problems())
    assert all(q.x_scale > 0 and q.w_scale > 0 and q.y_scale > 0
               for q in scales.values())
    assert r.quant_scales() is scales  # memoized

    x = r.example_inputs(batch=2, seed=5)
    f32 = np.asarray(r.apply(x))
    i8 = np.asarray(r.apply(x, precision="int8"))
    assert np.isfinite(i8).all()
    # tanh output in [-1, 1]; static PTQ on a 4-layer net stays close.
    assert np.max(np.abs(f32 - i8)) < 0.25
    with pytest.raises(ValueError, match="precision must be one of"):
        r.apply(x, precision="fp4")


def test_int8_policy_runs_requant_epilogue(runners):
    """The int8 policy quantizes operands and defers the activation to
    after dequant (requant runs BEFORE activation in the Epilogue)."""
    r = runners["dcgan"]
    pol = r.policy(precision="int8")
    name, prob = next(iter(r.tconv_problems().items()))
    rng = np.random.default_rng(0)
    x = rng.standard_normal((1, prob.ih, prob.iw, prob.ic)).astype(np.float32)
    w = (rng.standard_normal((prob.ks, prob.ks, prob.oc, prob.ic)) * 0.1
         ).astype(np.float32)
    y = np.asarray(pol.tconv(x, w, name=name, stride=prob.stride,
                             activation="relu"))
    assert (y >= 0).all()          # activation applied post-dequant
    q = pol.quant[name]
    # outputs live on the y_scale grid (int8 store, dequantized after)
    np.testing.assert_allclose(y / q.y_scale, np.round(y / q.y_scale),
                               atol=1e-4)


# ---------------------------------------------------------------------------
# jitted() memoization + warm tracking.
# ---------------------------------------------------------------------------


def test_jitted_memoized_and_warm_tracking(runners):
    r = runners["fsrcnn"]
    assert not r.has_compiled(batch=2, precision="f32")
    fn = r.jitted(batch=2)
    assert r.jitted(batch=2) is fn
    out = np.asarray(fn(r.example_inputs(batch=2)))
    assert r.has_compiled(batch=2, precision="f32")
    np.testing.assert_allclose(out, np.asarray(r.apply(
        r.example_inputs(batch=2))), rtol=1e-5, atol=1e-5)


# ---------------------------------------------------------------------------
# Step builders (runtime/steps.py rides the runner now).
# ---------------------------------------------------------------------------


def test_make_runner_sample_step_generic(runners):
    from repro.runtime import steps

    r = runners["styletransfer"]
    bundle = steps.make_runner_sample_step(r, batch=2)
    assert bundle.kind == "styletransfer_sample"
    assert bundle.meta["precision"] == "f32"
    assert bundle.meta["method"] == r.method
    out = np.asarray(bundle.fn(r.params, r.example_inputs(batch=2)))
    assert out.shape[0] == 2 and np.isfinite(out).all()


def test_make_gan_sample_step_compat(runners):
    from repro.runtime import steps

    r = runners["dcgan"]
    z_dim = r.input_shape()[0]
    bundle = steps.make_gan_sample_step(r.params, batch=2, z_dim=z_dim)
    assert bundle.kind == "gan_sample"
    with pytest.raises(ValueError, match="z_dim"):
        steps.make_gan_sample_step(r.params, batch=2, z_dim=z_dim + 1)
