"""Shipped plan tables (core/plan_table.py) + the four-tier precedence chain.

Covers the ISSUE-3 acceptance surface: schema validation of table files,
backend-keyed loading with the per-process memo, the committed
``src/repro/data/plans/`` tables being valid, the full consumption
precedence (explicit ``plan=`` > user cache > shipped table > heuristic)
with tier attribution in ``ops.consumed_plans()``, and the
``tools/tune_sweep.py`` CLI's resumability (zero re-measurements on
re-run) and export workflow.
"""

import json
import math
import os
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core import plan_table
from repro.core.autotune import (TIER_SHIPPED, TIER_USER_CACHE, PlanCache,
                                 cache_key, cached_plan, lookup_plan)
from repro.core.maps import TConvProblem
from repro.kernels import ref
from repro.kernels.registry import Plan

RNG = np.random.default_rng(11)

REPO = Path(__file__).resolve().parent.parent


def _table_dict(entries: dict, backend: str = "cpu") -> dict:
    return {
        "version": plan_table.TABLE_VERSION,
        "provenance": {"backend": backend, "jax": "0.4.37", "repeats": 2,
                       "created": 1754000000.0, "note": "test"},
        "entries": entries,
    }


def _entry(plan: Plan, **meta) -> dict:
    return {"plan": plan.to_json(), **meta}


def _write_table(d: Path, backend: str, table: dict) -> Path:
    d.mkdir(parents=True, exist_ok=True)
    path = d / f"{backend}.json"
    path.write_text(json.dumps(table))
    return path


# ---------------------------------------------------------------------------
# Schema validation
# ---------------------------------------------------------------------------


def test_validate_accepts_wellformed_table():
    t = _table_dict({
        "tconv:ih4:iw4:ic8:ks3:oc4:s2:SAME|float32|tpu-v5e|b1":
            _entry(Plan(2, 4, "cbj", "mm2im_db"), us=12.5, default_us=20.0),
    })
    assert plan_table.validate_table_json(t) == []


@pytest.mark.parametrize("mutate,expect", [
    (lambda t: t.update(version=99), "version"),
    (lambda t: t.pop("provenance"), "provenance"),
    (lambda t: t["provenance"].pop("backend"), "backend"),
    (lambda t: t["provenance"].pop("created"), "created"),
    (lambda t: t.pop("entries"), "entries"),
])
def test_validate_rejects_structural_defects(mutate, expect):
    t = _table_dict({})
    mutate(t)
    errs = plan_table.validate_table_json(t)
    assert errs and any(expect in e for e in errs), errs


def test_validate_rejects_bad_entries():
    key = "tconv:ih4:iw4:ic8:ks3:oc4:s2:SAME|float32|tpu-v5e|b1"
    bad = _table_dict({
        "not-a-key": _entry(Plan(2, 4)),                       # malformed key
        key: {"us": 1.0},                                      # no plan
        key + "x": _entry(Plan(2, 4), us="fast"),              # us not numeric
    })
    bad["entries"]["tconv:ih1:iw1:ic1:ks1:oc1:s1:SAME|int8|hw|b1"] = {
        "plan": {"block_oh": 0, "block_oc": 4}}                # illegal plan
    errs = plan_table.validate_table_json(bad)
    assert len(errs) >= 4, errs
    assert plan_table.validate_table_json([1, 2]), "non-dict must fail"


def test_v1_table_lenient_load(tmp_path):
    """Pre-fold v1 tables (the committed-cpu.json generation) keep
    loading: version 1 validates, plans without fold_batch read back as
    unfolded — and the v2 field is *gated* out of v1 files."""
    key = "tconv:ih4:iw4:ic8:ks3:oc4:s2:SAME|float32|tpu-v5e|b1"
    v1 = {
        "version": 1,
        "provenance": {"backend": "cpu", "jax": "0.4.37", "repeats": 2,
                       "created": 1754000000.0},
        "entries": {key: {"plan": {"block_oh": 2, "block_oc": 4,
                                   "grid_order": "cbj"}, "us": 9.0}},
    }
    assert plan_table.validate_table_json(v1) == []
    _write_table(tmp_path, "cpu", v1)
    t = plan_table.load_table("cpu", directory=tmp_path, strict=True)
    assert t.get(key) == Plan(2, 4, "cbj")
    assert t.get(key).fold_batch is False

    # The same table claiming to carry the v2 field is rejected: old
    # readers would silently drop the fold and run an untimed geometry.
    # The exporter writes the field into BOTH plan dicts, so the gate
    # covers both.
    for field in ("plan", "default_plan"):
        v1_bad = json.loads(json.dumps(v1))
        v1_bad["entries"][key][field] = {"block_oh": 2, "block_oc": 4,
                                         "fold_batch": True}
        errs = plan_table.validate_table_json(v1_bad)
        assert errs and any("fold_batch" in e and "version 2" in e
                            for e in errs), (field, errs)
        # Stamped as v2 the identical payload is fine.
        v1_bad["version"] = 2
        assert plan_table.validate_table_json(v1_bad) == []


def test_v2_table_roundtrips_folded_plan(tmp_path):
    key = "tconv:ih4:iw4:ic8:ks3:oc4:s2:SAME|float32|tpu-v5e|b8"
    folded = Plan(2, 4, "bcj", "mm2im_db", True)
    t = _table_dict({key: _entry(folded, us=3.0)})
    assert t["version"] == plan_table.TABLE_VERSION == 2
    assert plan_table.validate_table_json(t) == []
    _write_table(tmp_path, "cpu", t)
    loaded = plan_table.load_table("cpu", directory=tmp_path, strict=True)
    assert loaded.get(key) == folded


def test_load_table_lenient_vs_strict(tmp_path):
    # Absent file: lenient None, strict raises.
    assert plan_table.load_table("cpu", directory=tmp_path) is None
    with pytest.raises(ValueError, match="no shipped table"):
        plan_table.load_table("cpu", directory=tmp_path, strict=True)
    # Corrupt JSON: lenient None, strict raises.
    (tmp_path / "cpu.json").write_text("{nope")
    assert plan_table.load_table("cpu", directory=tmp_path) is None
    with pytest.raises(ValueError, match="not valid JSON"):
        plan_table.load_table("cpu", directory=tmp_path, strict=True)
    # Schema-invalid: lenient None, strict raises with the report.
    (tmp_path / "cpu.json").write_text(json.dumps({"version": 1}))
    assert plan_table.load_table("cpu", directory=tmp_path) is None
    with pytest.raises(ValueError, match="invalid shipped plan table"):
        plan_table.load_table("cpu", directory=tmp_path, strict=True)


def test_shipped_table_backend_keying_and_memo(monkeypatch, tmp_path):
    key = "tconv:ih4:iw4:ic8:ks3:oc4:s2:SAME|float32|tpu-v5e|b1"
    _write_table(tmp_path, "cpu", _table_dict({key: _entry(Plan(2, 4))},
                                              "cpu"))
    _write_table(tmp_path, "tpu", _table_dict({key: _entry(Plan(4, 4))},
                                              "tpu"))
    monkeypatch.setenv(plan_table.TABLE_DIR_ENV, str(tmp_path))
    plan_table.reset_shipped_tables()
    assert plan_table.available_backends() == ("cpu", "tpu")
    assert plan_table.shipped_table("cpu").get(key) == Plan(2, 4)
    assert plan_table.shipped_table("tpu").get(key) == Plan(4, 4)
    assert plan_table.shipped_table("rocm") is None
    # Memoized: deleting the file does not drop an already-loaded table...
    (tmp_path / "cpu.json").unlink()
    assert plan_table.shipped_table("cpu") is not None
    # ...until the memo is reset.
    plan_table.reset_shipped_tables()
    assert plan_table.shipped_table("cpu") is None


def test_committed_tables_are_valid(monkeypatch):
    """Every table committed under src/repro/data/plans/ passes strict
    validation, and the cpu one is present and non-trivial (that is what
    lets CI exercise the shipped tier end-to-end)."""
    monkeypatch.delenv(plan_table.TABLE_DIR_ENV, raising=False)
    plan_table.reset_shipped_tables()
    d = plan_table.table_dir()
    backends = plan_table.available_backends(d)
    assert "cpu" in backends, f"no committed cpu table under {d}"
    for backend in backends:
        t = plan_table.load_table(backend, directory=d, strict=True)
        assert t.provenance["backend"] == backend
        assert len(t) > 0
    cpu = plan_table.load_table("cpu", directory=d, strict=True)
    assert len(cpu) >= 10
    # int8 (the paper's precision) and batch>1 coverage shipped too.
    assert any("|int8|" in k for k in cpu.keys())
    assert any(k.endswith("|b8") for k in cpu.keys())


# ---------------------------------------------------------------------------
# Four-tier precedence: explicit > user cache > shipped table > heuristic
# ---------------------------------------------------------------------------


def _isolated_tiers(monkeypatch, tmp_path):
    """Empty user cache + empty shipped-table dir, memos reset."""
    from repro.core import autotune
    from repro.kernels import ops

    cache_path = tmp_path / "user_cache.json"
    table_dir = tmp_path / "plans"
    monkeypatch.setenv(autotune.CACHE_ENV, str(cache_path))
    monkeypatch.setenv(plan_table.TABLE_DIR_ENV, str(table_dir))
    monkeypatch.delenv(ops.AUTOLOAD_ENV, raising=False)
    autotune.reset_shared_caches()
    plan_table.reset_shipped_tables()
    ops.clear_consumed_plans()
    return PlanCache(cache_path), table_dir


def test_lookup_plan_tier_order(monkeypatch, tmp_path):
    """lookup_plan: user cache beats shipped table; either beats nothing."""
    cache, table_dir = _isolated_tiers(monkeypatch, tmp_path)
    p = TConvProblem(6, 6, 8, 3, 6, 2)
    key = cache_key(p)

    assert lookup_plan(p) is None
    assert cached_plan(p) is None

    shipped = Plan(2, 6, "cbj")
    _write_table(table_dir, "cpu",
                 _table_dict({key: _entry(shipped, us=9.0)}))
    plan_table.reset_shipped_tables()
    assert lookup_plan(p) == (shipped, TIER_SHIPPED)
    assert cached_plan(p) == shipped

    user = Plan(4, 6, "bcj")
    cache.put(key, user)
    assert lookup_plan(p) == (user, TIER_USER_CACHE)
    assert cached_plan(p) == user


def test_four_tier_precedence_through_tconv(monkeypatch, tmp_path):
    """The acceptance chain, end-to-end through ops.tconv dispatch with
    tier attribution in consumed_plans().  Distinct problem shapes per
    tier (ops.tconv's jit cache is keyed by shapes, so a shape traced
    under one tier would not re-trace under another)."""
    from repro.kernels import ops
    from repro.kernels.ops import tconv

    cache, table_dir = _isolated_tiers(monkeypatch, tmp_path)

    p_ship = TConvProblem(9, 7, 3, 3, 5, 2)    # only in the shipped table
    p_user = TConvProblem(7, 9, 3, 3, 5, 2)    # in both: user cache wins
    p_heur = TConvProblem(9, 9, 3, 3, 5, 2)    # in neither: heuristic
    ship_plan = Plan(2, 5, "cbj")
    user_plan = Plan(4, 5, "bcj")
    _write_table(table_dir, "cpu", _table_dict({
        cache_key(p_ship): _entry(ship_plan, us=5.0),
        cache_key(p_user): _entry(Plan(6, 5, "cbj"), us=7.0),
    }))
    plan_table.reset_shipped_tables()
    cache.put(cache_key(p_user), user_plan)

    def run(p):
        x = RNG.standard_normal((1, p.ih, p.iw, p.ic)).astype(np.float32)
        w = (RNG.standard_normal((p.ks, p.ks, p.oc, p.ic)) * 0.1
             ).astype(np.float32)
        got = np.asarray(tconv(x, w, stride=p.stride))
        np.testing.assert_allclose(
            got, np.asarray(ref.tconv_lax(x, w, stride=p.stride)),
            rtol=1e-4, atol=1e-4)

    # Tier 3 — shipped table serves the hit, attributed as such.
    run(p_ship)
    assert ops.consumed_plans()[-1] == (cache_key(p_ship), ship_plan,
                                        TIER_SHIPPED)
    # Tier 2 — user cache wins over the shipped entry for the same key.
    run(p_user)
    assert ops.consumed_plans()[-1] == (cache_key(p_user), user_plan,
                                        TIER_USER_CACHE)
    # Tier 4 — no entry anywhere: heuristic, nothing consumed.
    n = len(ops.consumed_plans())
    run(p_heur)
    assert len(ops.consumed_plans()) == n
    # Tier 1 — explicit plan= skips auto-consumption entirely (and wins
    # over both stored tiers for a problem present in each).
    x = RNG.standard_normal((1, p_user.ih, p_user.iw, p_user.ic)
                            ).astype(np.float32)
    w = (RNG.standard_normal((p_user.ks, p_user.ks, p_user.oc, p_user.ic))
         * 0.1).astype(np.float32)
    got = np.asarray(tconv(x, w, stride=p_user.stride, plan=Plan(2, 5)))
    np.testing.assert_allclose(
        got, np.asarray(ref.tconv_lax(x, w, stride=p_user.stride)),
        rtol=1e-4, atol=1e-4)
    assert len(ops.consumed_plans()) == n


def test_shipped_tier_survives_user_cache_deletion(monkeypatch, tmp_path):
    """The headline acceptance criterion: REPRO_AUTOTUNE_AUTOLOAD=1, user
    cache deleted -> a problem in the committed table still runs under its
    tuned plan, proven by consumed_plans() reporting a shipped-tier hit.

    Uses the *real* committed cpu table (no REPRO_PLAN_TABLE_DIR), with a
    problem drawn from it at a batch unlikely to be traced elsewhere."""
    from repro.core import autotune
    from repro.kernels import ops
    from repro.kernels.ops import tconv

    monkeypatch.delenv(plan_table.TABLE_DIR_ENV, raising=False)
    plan_table.reset_shipped_tables()
    table = plan_table.shipped_table("cpu")
    assert table is not None and len(table) > 0

    # Deleted (never-created) user cache.
    monkeypatch.setenv(autotune.CACHE_ENV, str(tmp_path / "gone.json"))
    monkeypatch.setenv(ops.AUTOLOAD_ENV, "1")
    autotune.reset_shared_caches()
    ops.clear_consumed_plans()

    # The FCN Table II row ships in the table (f32, b8): tiny and with a
    # batch no other test traces.
    from repro.configs.paper_models import TABLE_II

    p = next(r for r in TABLE_II if r.name == "FCN").problem
    batch = 8
    key = cache_key(p, dtype=jnp.float32, batch=batch)
    want_plan = table.get(key)
    assert want_plan is not None, f"{key} missing from committed cpu table"

    x = RNG.standard_normal((batch, p.ih, p.iw, p.ic)).astype(np.float32)
    w = (RNG.standard_normal((p.ks, p.ks, p.oc, p.ic)) * 0.1
         ).astype(np.float32)
    got = np.asarray(tconv(x, w, stride=p.stride))
    np.testing.assert_allclose(
        got, np.asarray(ref.tconv_lax(x, w, stride=p.stride)),
        rtol=1e-4, atol=1e-4)
    assert (key, want_plan, TIER_SHIPPED) in ops.consumed_plans()


# ---------------------------------------------------------------------------
# tune_sweep CLI: resumability + export (subprocess, real entry point)
# ---------------------------------------------------------------------------


def _run_cli(args, env_extra):
    env = dict(os.environ,
               PYTHONPATH=str(REPO / "src") + os.pathsep
               + os.environ.get("PYTHONPATH", ""),
               **env_extra)
    return subprocess.run(
        [sys.executable, str(REPO / "tools" / "tune_sweep.py"), *args],
        capture_output=True, text=True, env=env, timeout=600)


@pytest.mark.slow
def test_tune_sweep_cli_resumes_without_remeasuring(tmp_path):
    cache = tmp_path / "sweep.json"
    base = ["--filter", "ih1:iw1", "--dtypes", "f32", "--batches", "1",
            "--repeats", "1", "--max-measure", "2", "--cache", str(cache)]
    env = {plan_table.TABLE_DIR_ENV: str(tmp_path / "no_tables")}

    first = _run_cli([*base, "--expect-measured", "1"], env)
    assert first.returncode == 0, first.stdout + first.stderr
    assert "measured=1" in first.stdout

    # Interrupted-and-rerun: every completed key replays from the cache
    # with ZERO re-measurements (the acceptance criterion).
    second = _run_cli([*base, "--expect-measured", "0"], env)
    assert second.returncode == 0, second.stdout + second.stderr
    assert "measured=0 skipped=1" in second.stdout

    # And the CLI detects a resumability regression (expectation violated).
    third = _run_cli([*base, "--expect-measured", "5"], env)
    assert third.returncode == 2

    # Batch-8 work item: fold_batch candidates enumerate (plan v2), the
    # tuned entry persists the fold decision explicitly, and the resumed
    # rerun replays it with zero re-measurements.
    b8 = ["--filter", "ih1:iw1", "--dtypes", "f32", "--batches", "8",
          "--repeats", "1", "--max-measure", "2", "--cache", str(cache)]
    fold_first = _run_cli([*b8, "--expect-measured", "1"], env)
    assert fold_first.returncode == 0, fold_first.stdout + fold_first.stderr
    entries = json.loads(cache.read_text())["entries"]
    b1_key = next(k for k in entries if k.endswith("|b1"))
    b8_key = next(k for k in entries if k.endswith("|b8"))
    # The fold decision is serialized explicitly (schema v2)...
    assert "fold_batch" in entries[b8_key]["plan"]
    # ...and folded candidates were actually enumerated: the b8 field is
    # strictly larger than the b1 field for the same problem (the fold
    # knob is the only batch-dependent candidate axis).
    assert entries[b8_key]["n_candidates"] > entries[b1_key]["n_candidates"]
    fold_again = _run_cli([*b8, "--expect-measured", "0"], env)
    assert fold_again.returncode == 0, fold_again.stdout + fold_again.stderr

    # Export promotes the cache into a strict-valid table whose
    # provenance reflects the *entries'* recorded measurement conditions —
    # stamped at the current schema version so the fold_batch field it
    # carries is legal.
    out = tmp_path / "tables" / "cpu.json"
    exp = _run_cli(["--cache", str(cache), "--export", str(out),
                    "--backend", "cpu"], env)
    assert exp.returncode == 0, exp.stdout + exp.stderr
    t = plan_table.load_table("cpu", directory=out.parent, strict=True)
    assert len(t) == 2 and t.provenance["backend"] == "cpu"
    assert t.provenance["repeats"] == 1  # from the entry, not the CLI default
    assert math.isfinite(t.get_entry(t.keys()[0])["us"])
    raw = json.loads(out.read_text())
    assert raw["version"] == plan_table.TABLE_VERSION
    assert all("fold_batch" in e["plan"] for e in raw["entries"].values())

    # Exporting cpu-tuned entries into a table labeled for another
    # backend is refused (misprovenance guard).
    bad = _run_cli(["--cache", str(cache), "--export",
                    str(tmp_path / "tables" / "tpu.json"),
                    "--backend", "tpu"], env)
    assert bad.returncode == 2 and "refusing to export" in bad.stdout


def test_tune_sweep_work_items_and_problem_space():
    """The in-process surface: 261 synthetic + Table II rows, filter/limit
    behave, and the small slice is genuinely interpret-friendly."""
    sys.path.insert(0, str(REPO / "tools"))
    try:
        import tune_sweep
    finally:
        sys.path.pop(0)
    probs = tune_sweep.sweep_problems()
    assert len(probs) >= 261
    ns = argparse_ns(tune_sweep, dtypes="f32,int8", batches="1,8",
                     small=False, filter=None, limit=None)
    items = tune_sweep.work_items(ns)
    assert len(items) == len(probs) * 4
    ns = argparse_ns(tune_sweep, dtypes="f32", batches="1", small=True,
                     filter="|float32|", limit=5)
    small = tune_sweep.work_items(ns)
    assert len(small) == 5
    for p, dtype, batch, key in small:
        assert p.ih <= 7 and p.ic <= 64 and "|float32|" in key


def argparse_ns(tune_sweep, **overrides):
    ns = tune_sweep.build_parser().parse_args([])
    for k, v in overrides.items():
        setattr(ns, k, v)
    return ns
