"""Paper model-set tests: every TCONV method agrees end-to-end; DCGAN
training through the MM2IM kernel reduces the generator loss."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import gan
from repro.optim import adamw

METHODS = ("mm2im", "iom_unfused", "zero_insertion", "tdc", "lax")


def test_dcgan_generator_methods_agree():
    p, _ = gan.init_dcgan_g(jax.random.PRNGKey(0), scale_down=16)
    z = jax.random.normal(jax.random.PRNGKey(1), (2, 100))
    outs = {m: np.asarray(gan.dcgan_generator(p, z, method=m)) for m in METHODS}
    for m in METHODS[1:]:
        np.testing.assert_allclose(outs[m], outs["mm2im"], rtol=1e-4, atol=1e-4)
    assert outs["mm2im"].shape == (2, 64, 64, 3)


def test_pix2pix_unet_methods_agree():
    p, _ = gan.init_pix2pix_g(jax.random.PRNGKey(2), depth=5, scale_down=8)
    x = jax.random.normal(jax.random.PRNGKey(3), (1, 32, 32, 3))
    a = np.asarray(gan.pix2pix_generator(p, x, depth=5, method="mm2im"))
    b = np.asarray(gan.pix2pix_generator(p, x, depth=5, method="lax"))
    np.testing.assert_allclose(a, b, rtol=1e-4, atol=1e-4)
    assert a.shape == x.shape


def test_fsrcnn_upscales():
    p, _ = gan.init_fsrcnn(jax.random.PRNGKey(4), upscale=3)
    x = jax.random.normal(jax.random.PRNGKey(5), (1, 16, 16, 1))
    y = gan.fsrcnn(p, x, upscale=3)
    assert y.shape == (1, 48, 48, 1)
    y2 = gan.fsrcnn(p, x, upscale=3, method="lax")
    np.testing.assert_allclose(np.asarray(y), np.asarray(y2), rtol=1e-4,
                               atol=1e-4)


def test_styletransfer_shapes_and_agreement():
    p, _ = gan.init_styletransfer(jax.random.PRNGKey(6), base=8, n_res=2)
    x = jax.random.normal(jax.random.PRNGKey(7), (1, 32, 32, 3))
    y = gan.styletransfer(p, x)
    assert y.shape == (1, 32, 32, 3)
    y2 = gan.styletransfer(p, x, method="lax")
    np.testing.assert_allclose(np.asarray(y), np.asarray(y2), rtol=1e-4,
                               atol=1e-4)


def test_dcgan_trains_through_mm2im_kernel():
    """A few generator steps against a frozen discriminator must reduce
    the generator loss — gradients flow through the Pallas kernel."""
    kg, kd = jax.random.split(jax.random.PRNGKey(8))
    g_params, _ = gan.init_dcgan_g(kg, scale_down=32)
    d_params, _ = gan.init_dcgan_d(kd, base=4)
    z = jax.random.normal(jax.random.PRNGKey(9), (4, 100))
    opt_cfg = adamw.AdamWConfig(lr=3e-3, weight_decay=0.0, clip_norm=None,
                                warmup_steps=0, schedule="constant")
    opt = adamw.init(g_params, opt_cfg)

    def g_loss(gp):
        fake = gan.dcgan_generator(gp, z, method="mm2im")
        return jnp.mean(jax.nn.softplus(-gan.dcgan_discriminator(d_params, fake)))

    @jax.jit
    def step(gp, o):
        l, g = jax.value_and_grad(g_loss)(gp)
        gp, o, _ = adamw.apply(g, o, gp, opt_cfg)
        return gp, o, l

    losses = []
    for _ in range(5):
        g_params, opt, l = step(g_params, opt)
        losses.append(float(l))
    assert losses[-1] < losses[0]
