"""Golden worked example: stride-2 3x3 *output-gathered* TCONV, by hand.

Same worked numbers as ``test_mm2im_ks_paper_example.py`` (2x2 counting
input, 3x3 counting weights, SAME stride 2), but checked through the
output-gathered dataflow (DESIGN.md §2.7): each output pixel ``(oh, ow)``
*gathers* its strided input contributions

    kh ≡ oh + ct (mod S),   ih = (oh + ct - kh) / S   (0 <= ih < Ih)

instead of col2im scattering partial products.  Every gather index below
is a hand-derived literal, so a regression in the index math produces a
readable diff against the worked table rather than an opaque allclose
failure.
"""

import numpy as np

from repro.core.segregate import segregate
from repro.kernels.mm2im_og_pallas import _pack_og_weights, mm2im_og_tconv
from repro.kernels.ops import tconv
from repro.kernels.registry import Plan

KS, S = 3, 2

X = np.arange(1, 5, dtype=np.float32).reshape(1, 2, 2, 1)
W = np.arange(1, 10, dtype=np.float32).reshape(KS, KS, 1, 1)

# The same hand-computed 4x4 SAME output as the ks worked example.
GOLD = np.array([[1.,  2.,  5.,  4.],
                 [4.,  5., 14., 10.],
                 [10., 14., 36., 24.],
                 [12., 15., 34., 20.]], np.float32)


def _gather_taps(o: int, ih: int) -> list:
    """Hand formula: [(k, i)] with k ≡ o (mod S), i = (o - k) / S in range.

    ct = 0 for this geometry, so the residue is ``o`` itself; one axis of
    the 2D gather (rows and columns factor independently).
    """
    return [(k, (o - k) // S) for k in range(KS)
            if (o - k) % S == 0 and 0 <= (o - k) // S < ih]


def test_gather_index_table():
    """The full hand-derived gather table for the 4x4 output.

    Output row 2 (residue 0) gathers kernel rows {0, 2} from input rows
    {1, 0}; output row 1 (residue 1) gathers kernel row {1} from input
    row {0}; border rows lose the out-of-range tap.  Mirrors the tap
    derivation in the ks example's docstring, but resolved per *output*
    index, which is the og kernel's iteration order.
    """
    want = {
        0: [(0, 0)],            # oh 0: kh 0 @ ih 0 (kh 2 -> ih -1, dropped)
        1: [(1, 0)],            # oh 1: kh 1 @ ih 0
        2: [(0, 1), (2, 0)],    # oh 2: kh 0 @ ih 1, kh 2 @ ih 0
        3: [(1, 1)],            # oh 3: kh 1 @ ih 1 (kh 3 doesn't exist)
    }
    for o in range(4):
        assert _gather_taps(o, 2) == want[o], o


def test_gather_reconstructs_gold():
    """Explicit numpy gather-sum over the hand table reproduces GOLD —
    the dataflow the Pallas kernel implements, spelled out in loops."""
    out = np.zeros((4, 4), np.float32)
    for oh in range(4):
        for ow in range(4):
            for kh, ih in _gather_taps(oh, 2):
                for kw, iw in _gather_taps(ow, 2):
                    out[oh, ow] += X[0, ih, iw, 0] * W[kh, kw, 0, 0]
    np.testing.assert_array_equal(out, GOLD)
    # Single-pixel spot check straight off the table:
    # out[2,2] = x[1,1]·w[0,0] + x[1,0]·w[0,2] + x[0,1]·w[2,0]
    #          + x[0,0]·w[2,2] = 4·1 + 3·3 + 2·7 + 1·9 = 36.
    assert out[2, 2] == 36.0


def test_packed_weight_layout():
    """``_pack_og_weights`` is tap-major ``(Ks², Ic, Oc)``: the same
    sub-kernel grouping permutation as the ks packing ([0,2,6,8,1,7,3,5,4]
    for this geometry) on axis 0, so a kernel-side contiguous slice
    ``w[offset:offset+taps]`` is one residue's K-extent."""
    import jax.numpy as jnp

    from repro.kernels.mm2im_pallas import prepare_mm2im

    p = prepare_mm2im(jnp.asarray(X), jnp.asarray(np.transpose(W, (0, 1, 3, 2))),
                      None, stride=S, padding="SAME", block_oh=None,
                      block_oc=None, activation="none", out_scale=None,
                      out_dtype=None, grid_order="auto", interpret=True)
    seg = segregate(KS, S, "SAME")
    packed = np.asarray(_pack_og_weights(p, seg))
    assert packed.shape == (KS * KS, 1, packed.shape[2])  # (Ks², Ic, Oc_p)
    np.testing.assert_array_equal(packed[:, 0, 0],
                                  [1, 3, 7, 9, 2, 8, 4, 6, 5])
    # Sub-kernel (0,0) owns offset 0 with 4 taps: w[{0,2}x{0,2}] = 1,3,7,9.
    sk = seg.subkernels[0]
    assert (sk.offset, sk.taps) == (0, 4)
    np.testing.assert_array_equal(packed[sk.offset:sk.offset + sk.taps, 0, 0],
                                  [1, 3, 7, 9])


def test_kernel_matches_worked_example():
    """The og Pallas kernel and registry dispatch reproduce the table —
    including a multi-row-block plan, which exercises the slab windowing
    (``delta + row_shift - jh`` row indexing) across block boundaries."""
    got = np.asarray(mm2im_og_tconv(X, W, stride=S, padding="SAME",
                                    interpret=True))[0, :, :, 0]
    np.testing.assert_array_equal(got, GOLD)
    via_ops = np.asarray(tconv(X, W, stride=S, method="mm2im_og"))
    np.testing.assert_array_equal(via_ops[0, :, :, 0], GOLD)
    blocked = np.asarray(tconv(X, W, stride=S, method="mm2im_og",
                               plan=Plan(2, 4, "bcj")))
    np.testing.assert_array_equal(blocked[0, :, :, 0], GOLD)
