"""Kernel registry dispatch + block-size autotuner behaviour.

Covers the ISSUE-1 acceptance surface: registered-vs-default dispatch
equivalence against the ``kernels/ref.py`` oracles, cache write->read
round-trips across PlanCache instances (simulating separate processes),
cache-key stability, and a tuned plan executing correctly through
``ops.tconv`` and the layer/model plumbing.
"""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.core.autotune import (PlanCache, autotune_result, cache_key,
                                 default_plan, measure_plan)
from repro.core.maps import TConvProblem
from repro.kernels import ref, registry
from repro.kernels.ops import tconv
from repro.kernels.registry import Plan
from repro.layers import common as layers_common

RNG = np.random.default_rng(7)


def _xw(ih=5, iw=5, ic=4, ks=3, oc=4, b=1):
    x = RNG.standard_normal((b, ih, iw, ic)).astype(np.float32)
    w = (RNG.standard_normal((ks, ks, oc, ic)) * 0.1).astype(np.float32)
    return x, w


# ---------------------------------------------------------------------------
# Registry dispatch
# ---------------------------------------------------------------------------


def test_builtin_methods_registered():
    assert set(registry.names()) >= {"mm2im", "iom_unfused", "zero_insertion",
                                     "tdc", "lax"}


def test_unknown_method_raises():
    x, w = _xw()
    with pytest.raises(ValueError, match="method must be one of"):
        tconv(x, w, stride=2, method="nope")


@pytest.mark.parametrize("method", ["mm2im", "iom_unfused", "zero_insertion",
                                    "tdc", "lax"])
def test_registered_dispatch_matches_reference(method):
    """Every built-in method agrees with the lax gold oracle through the
    registry-dispatched ``ops.tconv`` — bias and activation included."""
    x, w = _xw()
    b = RNG.standard_normal(4).astype(np.float32)
    got = np.asarray(tconv(x, w, b, stride=2, method=method,
                           activation="relu"))
    want = np.maximum(np.asarray(ref.tconv_lax(x, w, stride=2)) + b, 0)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_register_custom_kernel_dispatch():
    """A plugged-in implementation dispatches by name, then unregisters."""

    @registry.register("direct_test",
                       description="ref.tconv_direct as a plugin")
    def _direct(x, w, *, stride, padding, epilogue, plan):
        return ref.tconv_direct(x, w, stride=stride, padding=padding)

    try:
        x, w = _xw()
        got = np.asarray(tconv(x, w, stride=2, method="direct_test"))
        want = np.asarray(tconv(x, w, stride=2, method="mm2im"))
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)
    finally:
        assert registry.unregister("direct_test") is not None
    with pytest.raises(ValueError):
        registry.get("direct_test")


def test_mixed_fuse_capabilities_get_full_epilogue():
    """A kernel fusing only one of bias/activation still gets the other
    applied by the dispatcher (regression: the unfused half was dropped).

    Under the Epilogue-typed contract the kernel receives only the fused
    *prefix* of present stages — 'fuse_act_only' with a bias present gets
    an empty kernel epilogue (activation cannot run before the unfused
    bias add) and the dispatcher applies both stages itself.
    """
    from repro.core.epilogue import apply_epilogue

    def _direct(x, w, *, stride, padding, epilogue, plan):
        out = ref.tconv_direct(x, w, stride=stride, padding=padding)
        return apply_epilogue(out, epilogue)

    registry.register("fuse_bias_only", fuses=("bias",))(_direct)
    registry.register("fuse_act_only", fuses=("activation",))(_direct)
    try:
        x, w = _xw()
        b = RNG.standard_normal(4).astype(np.float32)
        want = np.maximum(np.asarray(ref.tconv_lax(x, w, stride=2)) + b, 0)
        for method in ("fuse_bias_only", "fuse_act_only"):
            got = np.asarray(tconv(x, w, b, stride=2, method=method,
                                   activation="relu"))
            np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4,
                                       err_msg=method)
    finally:
        registry.unregister("fuse_bias_only")
        registry.unregister("fuse_act_only")


def test_plan_rejected_for_untiled_method():
    x, w = _xw()
    with pytest.raises(ValueError, match="does not accept an explicit"):
        tconv(x, w, stride=2, method="lax", plan=(2, 4))


def test_plan_tuple_normalization():
    assert registry.as_plan((4, 8)) == Plan(4, 8, "auto")
    assert registry.as_plan((4, 8, "cbj")) == Plan(4, 8, "cbj")
    assert registry.as_plan(None) is None
    with pytest.raises(ValueError):
        registry.as_plan("bogus")
    with pytest.raises(ValueError):
        Plan(0, 4)
    with pytest.raises(ValueError):
        Plan(2, 4, "zzz")


def test_plan_method_json_roundtrip():
    """Plan.method survives the cache JSON format; legacy entries (no
    'method' key) read back as method=None."""
    p = Plan(4, 8, "cbj", "mm2im_db")
    assert Plan.from_json(p.to_json()) == p
    assert "method" not in Plan(4, 8).to_json()  # legacy-shaped output
    assert Plan.from_json({"block_oh": 4, "block_oc": 8}) == Plan(4, 8)


def test_explicit_plan_through_tconv_matches_default():
    x, w = _xw(ih=6, iw=6, ic=8, ks=5, oc=6)
    want = np.asarray(tconv(x, w, stride=2))
    for plan in [(2, 4), (4, 2, "cbj"), Plan(2, 6, "bcj")]:
        got = np.asarray(tconv(x, w, stride=2, plan=plan))
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


# ---------------------------------------------------------------------------
# Plan cache
# ---------------------------------------------------------------------------


def test_cache_roundtrip_across_instances(tmp_path):
    path = tmp_path / "cache.json"
    c1 = PlanCache(path)
    plan = Plan(4, 16, "cbj")
    c1.put("some:key", plan, meta={"us": 12.5})
    # Fresh instance = fresh process: must read what the first wrote.
    c2 = PlanCache(path)
    assert c2.get("some:key") == plan
    assert c2.get_entry("some:key")["us"] == 12.5
    assert c2.get("missing") is None
    assert len(c2) == 1


def test_cache_sees_external_writes(tmp_path):
    """A long-lived PlanCache re-reads the file when another instance (or
    process) writes it — the same-process tune-then-consume path."""
    import os

    path = tmp_path / "cache.json"
    long_lived = PlanCache(path)
    assert long_lived.get("k") is None  # primes the (empty) memo
    writer = PlanCache(path)
    writer.put("k", Plan(4, 8))
    # Force a distinct mtime even on coarse-mtime filesystems.
    os.utime(path, ns=(1, 1))
    assert long_lived.get("k") == Plan(4, 8)


def test_cache_tolerates_corruption(tmp_path):
    path = tmp_path / "cache.json"
    path.write_text("{not json")
    c = PlanCache(path)
    with pytest.warns(UserWarning, match="corrupt"):
        assert c.get("k") is None
    c.put("k", Plan(2, 8))
    assert PlanCache(path).get("k") == Plan(2, 8)


def test_corrupt_cache_quarantined_with_oneshot_warning(tmp_path):
    """ISSUE 10: a cache that does not parse is moved to ``<path>.corrupt``
    and warned about once (naming the file), instead of being silently
    read as empty forever."""
    from repro.core import autotune

    autotune.reset_shared_caches()
    path = tmp_path / "cache.json"
    path.write_text('{"version": 1, "entries": {')   # truncated write
    with pytest.warns(UserWarning, match=str(path)) as rec:
        assert PlanCache(path)._read_disk() == {}
    assert len(rec) == 1
    assert (tmp_path / "cache.json.corrupt").read_text().startswith(
        '{"version"')                                # bytes kept for triage
    assert not path.exists()                         # path cleared for writes
    # one-shot per path per process: a second corrupt read warns nothing
    path.write_text("[1, 2, 3]")                     # non-object JSON
    import warnings as _w
    with _w.catch_warnings(record=True) as again:
        _w.simplefilter("always")
        assert PlanCache(path)._read_disk() == {}
    assert again == []
    autotune.reset_shared_caches()                   # clears the warn memo


def test_structural_garbage_quarantined_not_attribute_error(tmp_path):
    """Valid JSON that is not the cache schema (non-object top level,
    non-object entries) used to escape the old ``(OSError, ValueError)``
    net as an AttributeError; now it quarantines like any corruption."""
    from repro.core import autotune

    for payload in ('["not", "a", "dict"]',
                    '{"version": 1, "entries": [1, 2]}'):
        autotune.reset_shared_caches()
        path = tmp_path / "garbage.json"
        path.write_text(payload)
        with pytest.warns(UserWarning, match="corrupt"):
            assert PlanCache(path)._read_disk() == {}
        assert not path.exists()
        (tmp_path / "garbage.json.corrupt").unlink()
    autotune.reset_shared_caches()


def test_version_mismatch_still_silently_empty(tmp_path):
    """A *valid* cache from another schema generation is not corruption:
    read as empty with no warning and no quarantine (documented behavior
    — see the ``_CACHE_VERSION`` note in ``core/autotune.py``)."""
    import json as _json
    import warnings as _w

    from repro.core import autotune

    autotune.reset_shared_caches()
    path = tmp_path / "cache.json"
    path.write_text(_json.dumps({"version": 999, "entries": {"k": {}}}))
    with _w.catch_warnings(record=True) as rec:
        _w.simplefilter("always")
        assert PlanCache(path)._read_disk() == {}
    assert rec == [] and path.exists()


def test_cache_key_stability():
    p = TConvProblem(4, 4, 8, 3, 4, 2)
    key = cache_key(p, dtype=jnp.float32, batch=2)
    assert key == "tconv:ih4:iw4:ic8:ks3:oc4:s2:SAME|float32|tpu-v5e|b2"
    # Same inputs -> same key (no process-dependent state).
    assert key == cache_key(TConvProblem(4, 4, 8, 3, 4, 2),
                            dtype=jnp.float32, batch=2)
    assert cache_key(p, dtype=jnp.int8) != key


# ---------------------------------------------------------------------------
# Autotuner end-to-end
# ---------------------------------------------------------------------------


def test_autotune_and_execute_through_ops(tmp_path):
    p = TConvProblem(4, 4, 2, 3, 2, 2)
    cache = PlanCache(tmp_path / "tune.json")
    res = autotune_result(p, cache=cache, max_measure=2, repeats=1)
    assert not res.from_cache and res.n_measured >= 2
    assert res.plan.block_oh % p.stride == 0

    # The tuned plan computes the right answer through ops.tconv.
    x, w = _xw(ih=p.ih, iw=p.iw, ic=p.ic, ks=p.ks, oc=p.oc)
    got = np.asarray(tconv(x, w, stride=p.stride, plan=res.plan))
    want = np.asarray(ref.iom_reference(x, w, stride=p.stride))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    # Second call is a cache hit with the identical plan; a fresh PlanCache
    # object (separate process in spirit) sees it too.
    res2 = autotune_result(p, cache=cache, max_measure=2, repeats=1)
    assert res2.from_cache and res2.plan == res.plan
    assert PlanCache(tmp_path / "tune.json").get(res.key) == res.plan


def test_tconv_int8_explicit_bad_plan_raises():
    """tconv_int8 surfaces the same block_oh-vs-stride ValueError as tconv
    for an explicit plan, instead of deferring to a deeper kernel assert."""
    from repro.kernels.ops import tconv_int8

    x = RNG.integers(-128, 128, (1, 4, 4, 2)).astype(np.int8)
    w = RNG.integers(-128, 128, (3, 3, 2, 2)).astype(np.int8)
    b = np.zeros((2,), np.int32)
    with pytest.raises(ValueError, match="multiple of"):
        tconv_int8(x, w, b, 0.05, stride=2, plan=Plan(3, 2))


def test_bwd_zero_bias_keeps_weight_dtype():
    """Gradients through the bias-free MM2IM path must not silently
    promote bf16 to f32 (regression: bwd hardcoded an f32 zero-bias)."""
    import jax

    x = jnp.asarray(RNG.standard_normal((1, 4, 4, 2)), jnp.bfloat16)
    w = jnp.asarray(RNG.standard_normal((3, 3, 2, 2)) * 0.1, jnp.bfloat16)
    dx, dw = jax.grad(
        lambda xx, ww: tconv(xx, ww, stride=2).astype(jnp.float32).sum(),
        argnums=(0, 1))(x, w)
    assert dx.dtype == jnp.bfloat16 and dx.shape == x.shape
    assert dw.dtype == jnp.bfloat16 and dw.shape == w.shape


def test_default_plan_matches_heuristic():
    p = TConvProblem(8, 8, 16, 5, 12, 2)
    d = default_plan(p)
    from repro.kernels.mm2im_pallas import plan_blocks
    boh, boc = plan_blocks(p.ih, p.iw, p.ic, p.ks, p.oc, p.stride, p.padding,
                           vmem_budget=int(0.75 * 16 * 2**20), in_bytes=4)
    assert (d.block_oh, d.block_oc) == (boh, boc)


def test_measure_plan_returns_positive_time():
    p = TConvProblem(3, 3, 2, 3, 2, 1)
    us = measure_plan(p, Plan(1, 2), repeats=1, warmup=1)
    assert us > 0


def test_measure_plan_int8_times_requant_epilogue():
    """int8 candidates must be timed with a representative bias +
    per-tensor out_scale so the measured program is the int8-output
    requant kernel tconv_int8 will actually run — not a bare int32-output
    MatMul (regression: the epilogue was silently dropped)."""
    from repro.core.autotune import measure_epilogue

    p = TConvProblem(3, 3, 2, 3, 2, 1)
    bias, out_scale = measure_epilogue(p, jnp.int8)
    assert bias is not None and bias.shape == (p.oc,)
    assert bias.dtype == jnp.int32
    assert isinstance(out_scale, float) and out_scale > 0
    # Float dtypes keep the epilogue-free forward.
    assert measure_epilogue(p, jnp.float32) == (None, None)
    # And the int8 measurement path runs end-to-end through the kernel.
    us = measure_plan(p, Plan(1, 2), dtype=jnp.int8, repeats=1, warmup=1)
    assert us > 0


def test_cache_save_merges_concurrent_writers(tmp_path):
    """_save must merge only its dirty keys over current on-disk entries:
    a writer whose memo predates another process's writes neither drops
    that process's *new* keys nor reverts its *re-tuned* ones
    (last-writer-wins per *key*, not per file)."""
    path = tmp_path / "cache.json"
    c1 = PlanCache(path)
    c1.put("k1", Plan(2, 2))
    # Another process adds k2 AND re-tunes k1 behind c1's back.
    other = PlanCache(path)
    other.put("k2", Plan(4, 4))
    other.put("k1", Plan(16, 16))
    # Simulate the read-modify-write race: c1's memo is stale (old k1, no
    # k2) but its recorded mtime matches the file, so _load() trusts the
    # memo — exactly the state a slow writer is in between load and save.
    c1._loaded_mtime = c1._mtime()
    c1._entries = {"k1": {"plan": Plan(2, 2).to_json()}}
    c1.put("k3", Plan(8, 8))
    survivors = PlanCache(path)
    assert survivors.get("k2") == Plan(4, 4), "concurrent new key clobbered"
    assert survivors.get("k1") == Plan(16, 16), \
        "stale memo reverted a concurrent re-tune of an untouched key"
    assert survivors.get("k3") == Plan(8, 8)


def test_cache_concurrent_processes_lose_no_keys(tmp_path):
    """Two real processes writing disjoint keys into one cache file at
    the same time: the flock-serialized merge in _save keeps every key —
    the property tune_sweep's zero-re-measurement resumability relies on
    when shards share a cache."""
    import os
    import subprocess
    import sys

    from pathlib import Path

    path = tmp_path / "cache.json"
    script = (
        "import sys\n"
        "from repro.core.autotune import PlanCache\n"
        "from repro.kernels.registry import Plan\n"
        f"c = PlanCache({str(path)!r})\n"
        "tag = sys.argv[1]\n"
        "for i in range(15):\n"
        "    c.put(f'{tag}:{i}', Plan(2, 2))\n"
    )
    env = dict(os.environ)
    env["PYTHONPATH"] = (
        str(Path(__file__).resolve().parent.parent / "src")
        + os.pathsep + env.get("PYTHONPATH", ""))
    procs = [subprocess.Popen([sys.executable, "-c", script, tag],
                              env=env, stdout=subprocess.PIPE,
                              stderr=subprocess.PIPE)
             for tag in ("a", "b")]
    for pr in procs:
        out, err = pr.communicate(timeout=300)
        assert pr.returncode == 0, err.decode()
    final = PlanCache(path)
    missing = [f"{t}:{i}" for t in ("a", "b") for i in range(15)
               if final.get(f"{t}:{i}") is None]
    assert not missing, f"concurrent writers lost keys: {missing}"


def test_cache_hit_without_timings_reports_nan_speedup(tmp_path):
    """An entry lacking us/default_us (imported table, hand-written) must
    not report speedup 0.0 — that reads as a 0x slowdown; NaN means
    'unknown' (regression)."""
    import math

    from repro.core.autotune import autotune_result, cache_key

    p = TConvProblem(4, 4, 2, 3, 2, 2)
    cache = PlanCache(tmp_path / "tune.json")
    cache.put(cache_key(p), Plan(2, 2))  # no us / default_us metadata
    res = autotune_result(p, cache=cache, max_measure=2, repeats=1)
    assert res.from_cache
    assert math.isnan(res.us) and math.isnan(res.default_us)
    assert math.isnan(res.speedup_vs_default)
    # Timed entries still report a real ratio.
    from repro.core.autotune import TuningResult
    ok = TuningResult(key="k", plan=Plan(2, 2), us=50.0,
                      default_plan=Plan(2, 2), default_us=100.0,
                      n_candidates=1, n_measured=1, from_cache=False)
    assert ok.speedup_vs_default == pytest.approx(2.0)


# ---------------------------------------------------------------------------
# Automatic plan-cache consumption (no explicit plans= anywhere)
# ---------------------------------------------------------------------------


def _fresh_autoload(monkeypatch, tmp_path):
    """Point auto-consumption at an empty tmp cache and reset memos.

    Also isolates the shipped-table tier (an empty tmp dir) so the
    committed ``src/repro/data/plans/`` tables cannot serve these tests'
    problems behind the user cache's back.
    """
    from repro.core import autotune, plan_table
    from repro.kernels import ops

    path = tmp_path / "auto_cache.json"
    monkeypatch.setenv(autotune.CACHE_ENV, str(path))
    monkeypatch.setenv(plan_table.TABLE_DIR_ENV, str(tmp_path / "no_plans"))
    monkeypatch.delenv(ops.AUTOLOAD_ENV, raising=False)
    autotune.reset_shared_caches()
    plan_table.reset_shipped_tables()
    ops.clear_consumed_plans()
    return autotune.PlanCache(path)


def test_tconv_layer_consumes_cached_plan(monkeypatch, tmp_path):
    """Write a plan to a tmp cache -> tconv_layer picks it up with no
    plan= argument, including the tuned kernel-variant preference.

    Shapes here are unique within the test session: ops.tconv's jit cache
    is keyed by shapes + static args, so a shape compiled before the cache
    entry existed would (correctly) not retrace.
    """
    import jax

    from repro.core.autotune import cache_key
    from repro.core.maps import TConvProblem
    from repro.kernels import ops

    cache = _fresh_autoload(monkeypatch, tmp_path)
    ih, iw, ic, ks, oc, s = 7, 5, 2, 3, 3, 2
    p = TConvProblem(ih, iw, ic, ks, oc, s)
    plan = Plan(4, 3, "bcj", "mm2im_db")
    key = cache_key(p, dtype=jnp.float32, batch=1)
    cache.put(key, plan)

    params, _ = layers_common.init_tconv(jax.random.PRNGKey(0), ks, oc, ic)
    x = RNG.standard_normal((1, ih, iw, ic)).astype(np.float32)
    got = np.asarray(layers_common.tconv_layer(params, x, stride=s))
    want = np.asarray(
        ref.tconv_lax(x, np.asarray(params["w"]), stride=s)
        + np.asarray(params["b"]))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)
    consumed = ops.consumed_plans()
    from repro.core.autotune import TIER_USER_CACHE
    assert consumed and consumed[-1] == (key, plan, TIER_USER_CACHE), consumed


def test_autoload_disabled_by_env(monkeypatch, tmp_path):
    from repro.core.autotune import cache_key
    from repro.core.maps import TConvProblem
    from repro.kernels import ops
    from repro.kernels.ops import tconv

    cache = _fresh_autoload(monkeypatch, tmp_path)
    p = TConvProblem(7, 3, 2, 3, 3, 2)
    cache.put(cache_key(p, dtype=jnp.float32, batch=1), Plan(2, 3, "bcj"))
    monkeypatch.setenv(ops.AUTOLOAD_ENV, "0")

    x = RNG.standard_normal((1, 7, 3, 2)).astype(np.float32)
    w = (RNG.standard_normal((3, 3, 3, 2)) * 0.1).astype(np.float32)
    got = np.asarray(tconv(x, w, stride=2))
    np.testing.assert_allclose(
        got, np.asarray(ref.tconv_lax(x, w, stride=2)), rtol=1e-4, atol=1e-4)
    assert not ops.consumed_plans()


def test_gan_step_builders_consume_cache(monkeypatch, tmp_path):
    """runtime/steps resolves per-layer cached plans with no plans= arg;
    explicit entries take precedence over cache hits."""
    import jax

    from repro.core.autotune import cache_key
    from repro.models import gan
    from repro.runtime import steps

    cache = _fresh_autoload(monkeypatch, tmp_path)
    gp, _ = gan.init_dcgan_g(jax.random.PRNGKey(1), scale_down=64)
    probs = gan.dcgan_tconv_problems(gp)
    t1_plan = Plan(2, 4, "bcj", "mm2im_db")
    t2_plan = Plan(4, 2, "cbj")
    cache.put(cache_key(probs["t1"], dtype=jnp.float32, batch=3), t1_plan)
    cache.put(cache_key(probs["t2"], dtype=jnp.float32, batch=3), t2_plan)

    resolved = steps.resolve_gan_plans(gp, batch=3)
    assert resolved == {"t1": t1_plan, "t2": t2_plan}
    # Explicit plans= beats the cache (precedence contract).
    override = Plan(2, 2, "bcj")
    resolved = steps.resolve_gan_plans(gp, batch=3, plans={"t1": override})
    assert resolved["t1"] == override and resolved["t2"] == t2_plan
    # Builders accept the resolved mapping end-to-end (trace only).
    bundle = steps.make_gan_sample_step(gp, batch=3)
    assert bundle.kind == "gan_sample"


def test_plan_free_methods_ignore_cache(monkeypatch, tmp_path):
    """A populated cache must not break plan-incapable methods: the GAN
    builders skip resolution for them, and an unregistered plan.method in
    the cache degrades to the default kernel instead of raising."""
    import jax

    from repro.core.autotune import cache_key
    from repro.core.maps import TConvProblem
    from repro.models import gan
    from repro.runtime import steps

    cache = _fresh_autoload(monkeypatch, tmp_path)
    gp, _ = gan.init_dcgan_g(jax.random.PRNGKey(2), scale_down=64)
    for prob in gan.dcgan_tconv_problems(gp).values():
        cache.put(cache_key(prob, dtype=jnp.float32, batch=2), Plan(2, 2))
    # method='lax' cannot take plans; the builder must not hand it any.
    bundle = steps.make_gan_sample_step(gp, batch=2, method="lax")
    assert bundle.meta["plans"] == {}
    z = RNG.standard_normal((2, 100)).astype(np.float32)
    np.asarray(bundle.fn(gp, z))  # traces + runs without a dispatch error

    # Stale cache entry naming an unregistered variant: lookup_plan skips
    # the whole entry (with a warning) and dispatch runs the heuristic —
    # see test_stale_method_plan_skipped_with_warning for the tier walk.
    p = TConvProblem(3, 7, 2, 3, 3, 2)
    cache.put(cache_key(p, dtype=jnp.float32, batch=1),
              Plan(2, 3, "bcj", "not_a_registered_kernel"))
    x = RNG.standard_normal((1, 3, 7, 2)).astype(np.float32)
    w = (RNG.standard_normal((3, 3, 3, 2)) * 0.1).astype(np.float32)
    with pytest.warns(UserWarning, match="unregistered"):
        got = np.asarray(tconv(x, w, stride=2))
    np.testing.assert_allclose(
        got, np.asarray(ref.tconv_lax(x, w, stride=2)), rtol=1e-4, atol=1e-4)

    # Corrupt geometry (block_oh not a stride multiple): the auto-loaded
    # plan is discarded — heuristic dispatch instead of a ValueError.
    p_bad = TConvProblem(3, 5, 2, 3, 3, 2)
    cache.put(cache_key(p_bad, dtype=jnp.float32, batch=1), Plan(3, 3))
    from repro.kernels import ops
    ops.clear_consumed_plans()
    x = RNG.standard_normal((1, 3, 5, 2)).astype(np.float32)
    w = (RNG.standard_normal((3, 3, 3, 2)) * 0.1).astype(np.float32)
    got = np.asarray(tconv(x, w, stride=2))
    np.testing.assert_allclose(
        got, np.asarray(ref.tconv_lax(x, w, stride=2)), rtol=1e-4, atol=1e-4)
    assert not ops.consumed_plans()


def test_stale_method_plan_skipped_with_warning(monkeypatch, tmp_path):
    """An entry whose ``Plan.method`` is not in this checkout's registry —
    a cache or table written by a newer build with an extra kernel family
    — must be *skipped with a warning* at every read tier, falling through
    to the next one, and never fail dispatch (regression: lookup used to
    return the plan and dispatch raised on the unknown method)."""
    import json

    import jax

    from repro.core import autotune, plan_table

    cache = _fresh_autoload(monkeypatch, tmp_path)
    p = TConvProblem(5, 3, 2, 3, 3, 2)
    key = cache_key(p, dtype=jnp.float32, batch=1)

    # Shipped-table tier: a valid v2 table whose entry names a kernel
    # family this build does not have.
    tdir = tmp_path / "tables"
    tdir.mkdir()
    backend = jax.default_backend()
    table = {
        "version": 2,
        "provenance": {"backend": backend, "jax": jax.__version__,
                       "repeats": 1, "created": 0.0},
        "entries": {key: {"plan": Plan(2, 3, "bcj",
                                       "kernel_from_the_future").to_json()}},
    }
    (tdir / f"{backend}.json").write_text(json.dumps(table))
    monkeypatch.setenv(plan_table.TABLE_DIR_ENV, str(tdir))
    plan_table.reset_shipped_tables()

    with pytest.warns(UserWarning, match="unregistered"):
        assert autotune.lookup_plan(p, cache=cache) is None  # -> heuristic

    # User-cache tier: a stale entry there warns too and falls through to
    # the shipped tier (also stale here) -> still a clean miss.
    cache.put(key, Plan(4, 3, "bcj", "another_future_kernel"))
    with pytest.warns(UserWarning, match="unregistered"):
        assert autotune.lookup_plan(p, cache=cache) is None

    # A *valid* shipped entry underneath is reachable: the stale user
    # cache falls through TO it instead of masking the tier.
    good = Plan(2, 3, "bcj", "mm2im")
    table["entries"][key]["plan"] = good.to_json()
    (tdir / f"{backend}.json").write_text(json.dumps(table))
    plan_table.reset_shipped_tables()
    with pytest.warns(UserWarning, match="unregistered"):
        hit = autotune.lookup_plan(p, cache=cache)
    assert hit == (good, autotune.TIER_SHIPPED)

    # End-to-end: dispatch under the stale user cache computes correctly.
    x = RNG.standard_normal((1, p.ih, p.iw, p.ic)).astype(np.float32)
    w = (RNG.standard_normal((p.ks, p.ks, p.oc, p.ic)) * 0.1
         ).astype(np.float32)
    with pytest.warns(UserWarning, match="unregistered"):
        got = np.asarray(tconv(x, w, stride=p.stride))
    np.testing.assert_allclose(
        got, np.asarray(ref.tconv_lax(x, w, stride=p.stride)),
        rtol=1e-4, atol=1e-4)


def test_tuned_plan_through_layer_and_model(tmp_path):
    """Plans flow through layers.common.tconv_layer and models.gan."""
    import jax

    from repro.models import gan

    p = TConvProblem(4, 4, 4, 3, 4, 2)
    plan = Plan(2, 4, "bcj")
    params, _ = layers_common.init_tconv(jax.random.PRNGKey(0), 3, 4, 4)
    x = RNG.standard_normal((1, 4, 4, 4)).astype(np.float32)
    got = np.asarray(layers_common.tconv_layer(params, x, stride=2,
                                               plan=plan))
    want = np.asarray(tconv(x, params["w"], params["b"], stride=2))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    # DCGAN generator with per-layer plans == without (numerics unchanged).
    gp, _ = gan.init_dcgan_g(jax.random.PRNGKey(1), scale_down=64)
    probs = gan.dcgan_tconv_problems(gp)
    assert probs["t1"].ih == 4 and probs["t4"].oc == 3
    plans = {name: Plan(2 * pr.stride, min(pr.oc, 4))
             for name, pr in probs.items()}
    z = RNG.standard_normal((2, 100)).astype(np.float32)
    img_plain = np.asarray(gan.dcgan_generator(gp, z))
    img_planned = np.asarray(gan.dcgan_generator(gp, z, plans=plans))
    np.testing.assert_allclose(img_planned, img_plain, rtol=1e-4, atol=1e-4)
