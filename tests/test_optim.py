"""Optimizer: convergence, clipping, schedules, accumulation equivalence."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.optim import adamw


def test_adamw_converges_quadratic():
    cfg = adamw.AdamWConfig(lr=0.1, weight_decay=0.0, clip_norm=None,
                            warmup_steps=0, schedule="constant")
    params = {"x": jnp.array([5.0, -3.0])}
    opt = adamw.init(params, cfg)
    for _ in range(200):
        g = jax.grad(lambda p: jnp.sum(p["x"] ** 2))(params)
        params, opt, _ = adamw.apply(g, opt, params, cfg)
    assert float(jnp.abs(params["x"]).max()) < 1e-2


def test_clip_norm_bounds_update():
    cfg = adamw.AdamWConfig(lr=1.0, clip_norm=1.0, weight_decay=0.0,
                            warmup_steps=0, schedule="constant")
    params = {"x": jnp.zeros(4)}
    opt = adamw.init(params, cfg)
    g = {"x": jnp.full(4, 100.0)}
    _, _, m = adamw.apply(g, opt, params, cfg)
    assert float(m["grad_norm"]) == 200.0  # pre-clip global norm reported


def test_warmup_cosine_schedule():
    cfg = adamw.AdamWConfig(lr=1.0, warmup_steps=10, total_steps=110,
                            schedule="cosine")
    s = adamw.make_schedule(cfg)
    assert float(s(jnp.array(0))) == 0.0
    assert abs(float(s(jnp.array(10))) - 1.0) < 1e-6
    assert float(s(jnp.array(110))) < 1e-6
    assert 0.4 < float(s(jnp.array(60))) < 0.6


def test_bf16_state_dtype():
    cfg = adamw.AdamWConfig(state_dtype="bfloat16")
    opt = adamw.init({"x": jnp.zeros(3)}, cfg)
    assert opt.m["x"].dtype == jnp.bfloat16


def test_grad_accumulation_matches_full_batch():
    w = jnp.array([1.0, 2.0])
    xs = jnp.arange(8.0).reshape(8, 1) / 8.0
    ys = 3.0 * xs[:, 0]

    def lg(params, batch):
        def loss(p):
            pred = batch["x"][:, 0] * p[0] + p[1]
            return jnp.mean((pred - batch["y"]) ** 2)
        return jax.value_and_grad(loss)(params)

    full_l, full_g = lg(w, {"x": xs, "y": ys})
    acc = adamw.accumulate(lg, n_micro=4)
    acc_l, acc_g = acc(w, {"x": xs, "y": ys})
    np.testing.assert_allclose(float(full_l), float(acc_l), rtol=1e-6)
    np.testing.assert_allclose(np.asarray(full_g), np.asarray(acc_g), rtol=1e-6)
