"""Calibrated perf-model coefficients (core/model_fit) + the CI perf gate.

The regression anchor here is measured data: the shipped ``cpu.json``
sweep table plus the head-to-head records distilled into
``BENCH_mm2im.json`` at the time the large-image slice landed.  The
misranks that motivate the calibration (db predicted faster but measured
2.3x slower; the gather-family og predicted 1.7-3x *slower* than
``mm2im_ks`` by the roofline but measured 1.7-3.2x *faster*) are baked in
as constants — the live BENCH file gets regenerated with fresh timings, a
fixture must not drift with it.  (The original PR 6 fixture pinned a
fold-db misrank from an earlier machine era whose direction no longer
reproduces — re-derived here per the fixture's own instruction when the
large-image refit made the two eras mutually unsatisfiable.)
"""

import json
import subprocess
import sys
from pathlib import Path

import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import model_fit as mf
from repro.core.autotune import cache_key
from repro.core.maps import TConvProblem
from repro.kernels.registry import Plan

REPO = Path(__file__).resolve().parent.parent
CPU_TABLE = REPO / "src" / "repro" / "data" / "plans" / "cpu.json"

# The head-to-heads recorded in BENCH_mm2im.json when the large-image
# slice landed (interpret-mode CPU, f32, repeats 2-3).  The dbcmp rows
# compare single- vs double-buffered at the heuristic default geometry of
# each problem; the fold rows compare grid-batch vs folded at a fixed
# geometry on the batch-8 quarter-width DCGAN layer-1 shape; the ogcmp
# rows compare the output-gathered family against mm2im and mm2im_ks on
# large-image stride-4 shapes (each yields TWO pairs: og_vs_mm2im and
# og_vs_mm2im_ks).
RECORDED_ROWS = [
    {"name": "autotune_ih7_ic32_ks3_oc16_s1_dbcmp",
     "derived": "geom=oh4/oc16/cbj;sb_us=72.0;db_us=81.4"},
    {"name": "autotune_ih7_ic32_ks5_oc16_s2_dbcmp",
     "derived": "geom=oh8/oc16/cbj;sb_us=117.9;db_us=148.2"},
    {"name": "autotune_ih7_ic64_ks3_oc32_s1_dbcmp",
     "derived": "geom=oh4/oc32/cbj;sb_us=163.1;db_us=149.4"},
    {"name": "autotune_ih7_ic64_ks5_oc32_s2_dbcmp",
     "derived": "geom=oh8/oc32/cbj;sb_us=304.6;db_us=714.9"},
    {"name": "autotune_fold_dcgan1_mm2im",
     "derived": "batch=8;geom=oh8/oc128/bcj;"
                "grid_us=8384.8;fold_us=6690.9"},
    {"name": "autotune_fold_dcgan1_mm2im_db",
     "derived": "batch=8;geom=oh4/oc128/bcj;"
                "grid_us=9759.3;fold_us=8211.4"},
    {"name": "autotune_large_ih32_ic16_ks5_oc16_s4_ogcmp",
     "derived": "geom=oh128/oc16/bcj;"
                "og_us=576.8;mm2im_us=785.9;ks_us=1003.1"},
    {"name": "autotune_large_ih32_ic32_ks7_oc16_s4_ogcmp",
     "derived": "geom=oh128/oc16/bcj;"
                "og_us=1221.3;mm2im_us=1340.3;ks_us=2939.1"},
    {"name": "autotune_large_ih64_ic16_ks7_oc16_s4_ogcmp",
     "derived": "geom=oh64/oc16/bcj;"
                "og_us=3228.8;mm2im_us=4821.0;ks_us=10264.5"},
    {"name": "autotune_large_ih64_ic32_ks7_oc16_s4_ogcmp",
     "derived": "geom=oh64/oc16/bcj;"
                "og_us=4727.2;mm2im_us=5895.6;ks_us=12404.8"},
]
RECORDED_DOC = {"autotune": RECORDED_ROWS}
#: One RankPair per db/fold row, two per ogcmp row (the four ogcmp rows
#: also put mm2im_ks@large past MIN_REGIME_SAMPLES in the in-test refit).
N_RECORDED_PAIRS = 6 + 2 * 4
# The decisive rank_agree=0 records the fitted model must flip (ISSUE 6
# acceptance, re-derived with the ISSUE 9 large-image slice): db measured
# 2.3x *slower* than sb while the roofline predicts it faster, and og
# measured 2.4-3.2x *faster* than mm2im_ks on large-image shapes while
# the uncalibrated roofline (which cannot see the gather-read savings
# win) predicts it 1.7-3x slower.
MISRANKED = ("autotune_ih7_ic64_ks5_oc32_s2_dbcmp",
             "autotune_large_ih32_ic32_ks7_oc16_s4_ogcmp:og_vs_mm2im_ks",
             "autotune_large_ih64_ic16_ks7_oc16_s4_ogcmp:og_vs_mm2im_ks")


@pytest.fixture(scope="module")
def recorded_pairs():
    return mf.pairs_from_bench(RECORDED_DOC)


@pytest.fixture(scope="module")
def fitted(recorded_pairs):
    """The calibration refit from committed measurements (as CI's --fit)."""
    samples = mf.samples_from_store(CPU_TABLE, backend="cpu")
    samples += mf.samples_from_bench(RECORDED_DOC)
    return mf.fit_coefficients(samples, backend="cpu",
                               sources=["cpu.json", "recorded rows"])


def test_cache_key_round_trips():
    p = TConvProblem(7, 7, 64, 5, 32, 2, "VALID")
    key = cache_key(p, dtype=jnp.int8, batch=8)
    got_p, dt, hw, batch = mf.parse_cache_key(key)
    assert got_p == p and dt == "int8" and batch == 8
    with pytest.raises(ValueError):
        mf.parse_cache_key("not-a-key|f32|hw|b1")


def test_samples_from_shipped_table():
    samples = mf.samples_from_store(CPU_TABLE, backend="cpu")
    # Every committed entry carries both a winner and a default timing.
    n_entries = len(json.loads(CPU_TABLE.read_text())["entries"])
    assert len(samples) == 2 * n_entries
    assert all(s.us > 0 and s.bits in (8, 16, 32) for s in samples)
    # Backend filtering: a different backend keeps nothing.
    assert mf.samples_from_store(CPU_TABLE, backend="tpu") == []


def test_recorded_pairs_parse(recorded_pairs):
    assert len(recorded_pairs) == N_RECORDED_PAIRS
    by_name = {p.name: p for p in recorded_pairs}
    db = by_name["autotune_ih7_ic64_ks3_oc32_s1_dbcmp"]
    assert db.plan_a.method == "mm2im" and db.plan_b.method == "mm2im_db"
    assert db.plan_a.block_oh == 4 and db.plan_a.block_oc == 32
    assert db.measured_ratio == pytest.approx(163.1 / 149.4)
    fold = by_name["autotune_fold_dcgan1_mm2im_db"]
    assert fold.batch == 8 and fold.plan_b.fold_batch
    assert not fold.plan_a.fold_batch
    og = by_name["autotune_large_ih64_ic16_ks7_oc16_s4_ogcmp:og_vs_mm2im_ks"]
    assert og.plan_a.method == "mm2im_og" and og.plan_b.method == "mm2im_ks"
    assert og.problem == TConvProblem(64, 64, 16, 7, 16, 4)
    assert og.measured_ratio == pytest.approx(3228.8 / 10264.5)


def test_fitted_model_flips_recorded_misranks(fitted, recorded_pairs):
    """The acceptance criterion: both recorded rank_agree=0 head-to-heads
    rank correctly under the fitted coefficients, and the overall decisive
    score strictly improves on the raw roofline."""
    base = mf.rank_agreement(recorded_pairs, None)
    fit = mf.rank_agreement(recorded_pairs, fitted)
    base_by = {r["name"]: r for r in base["pairs"]}
    fit_by = {r["name"]: r for r in fit["pairs"]}
    for name in MISRANKED:
        assert not base_by[name]["agree"], (
            f"{name}: the roofline no longer misranks this pair — "
            f"the fixture lost its point, re-derive it")
        assert fit_by[name]["agree"], (
            f"{name}: fitted model failed to flip the recorded misrank")
    assert fit["n_misranks"] < base["n_misranks"]
    assert fit["mean_abs_log2_err"] < base["mean_abs_log2_err"]
    # Pin the replayed score so silent fit regressions surface: the
    # roofline decisively misranks the small db pair and all four
    # og-vs-mm2im_ks large-image pairs; the refit flips every one.
    assert base["n_misranks"] == 5
    assert fit["n_misranks"] <= 1


def test_fit_round_trip_and_provenance(fitted, tmp_path):
    path = mf.save_fit(fitted, tmp_path / "cpu.fit.json")
    loaded = mf.load_fit(path, strict=True)
    assert loaded.backend == "cpu"
    assert set(loaded.regimes) == set(fitted.regimes)
    for key, c in fitted.regimes.items():
        np.testing.assert_allclose(loaded.regimes[key].vector, c.vector)
        assert loaded.regimes[key].n_samples == c.n_samples
    for field in mf.REQUIRED_PROVENANCE:
        assert field in loaded.provenance
    assert loaded.provenance["sources"] == ["cpu.json", "recorded rows"]


def test_validate_fit_json_catches_breakage(fitted, tmp_path):
    doc = fitted.to_json()
    assert mf.validate_fit_json(doc) == []
    bad = json.loads(json.dumps(doc))
    del bad["provenance"]["backend"]
    bad["regimes"]["mm2im"]["us_per_tile"] = -1.0
    del bad["regimes"]["*"]
    errs = mf.validate_fit_json(bad)
    assert any("backend" in e for e in errs)
    assert any("us_per_tile" in e for e in errs)
    assert any("global" in e for e in errs)
    # save_fit refuses invalid docs; load_fit degrades to None (lenient).
    p = tmp_path / "bad.fit.json"
    p.write_text(json.dumps(bad))
    assert mf.load_fit(p) is None
    with pytest.raises(ValueError):
        mf.load_fit(p, strict=True)


def test_predict_us_regime_fallback(fitted):
    """Unknown methods score with the '*' global regime, same unit system."""
    p = TConvProblem(8, 8, 64, 5, 32, 2)
    got = fitted.predict_us(p, Plan(8, 32, "bcj", "exotic_variant"))
    want = fitted.predict_us(p, Plan(8, 32, "bcj", None))
    star = fitted.regimes["*"]
    assert got > 0
    assert fitted.coeffs_for("exotic_variant") is star
    # ...while known, well-sampled regimes use their own coefficients.
    assert fitted.coeffs_for("mm2im") is fitted.regimes["mm2im"]
    assert want > 0


def test_rank_agreement_scores_magnitude_not_just_sign():
    """The old per-row rank_agree flag checked the sign only — a 7.09x
    prediction of a measured 1.36x ratio scored as agreement.  The score
    now carries the magnitude error and flags non-decisive pairs."""
    p = TConvProblem(4, 4, 256, 5, 128, 2)
    a = Plan(8, 128, "bcj", "mm2im")
    b = Plan(8, 128, "bcj", "mm2im", fold_batch=True)
    pairs = [mf.RankPair("decisive", p, 8, 32, a, b, 1000.0, 100.0),
             mf.RankPair("noise", p, 8, 32, a, b, 110.0, 100.0)]
    score = mf.rank_agreement(pairs, None, decisive_band=1.5)
    rows = {r["name"]: r for r in score["pairs"]}
    assert rows["decisive"]["decisive"] and not rows["noise"]["decisive"]
    assert score["n_decisive"] == 1
    # Magnitude error is |log2(pred/meas)| — nonzero even when the sign
    # agrees, which is exactly what the old flag hid.
    for r in score["pairs"]:
        assert r["abs_log2_err"] >= 0.0
    assert score["mean_abs_log2_err"] is not None


def test_shipped_fit_env_override(fitted, tmp_path, monkeypatch):
    monkeypatch.setenv(mf.FIT_DIR_ENV, str(tmp_path))
    mf.reset_shipped_fits()
    try:
        assert mf.shipped_fit("cpu") is None  # nothing there yet
        mf.reset_shipped_fits()
        mf.save_fit(fitted, mf.fit_path("cpu"))
        got = mf.shipped_fit("cpu")
        assert got is not None and got.backend == "cpu"
        # Memoized: same object on the second lookup.
        assert mf.shipped_fit("cpu") is got
    finally:
        mf.reset_shipped_fits()


def test_shipped_cpu_fit_is_valid_and_current():
    """The committed cpu.fit.json must parse, validate, and still flip the
    recorded misranks — a stale calibration is a silent ranking bug."""
    fit = mf.load_fit(REPO / "src" / "repro" / "data" / "plans"
                      / "cpu.fit.json", strict=True)
    score = mf.rank_agreement(mf.pairs_from_bench(RECORDED_DOC), fit)
    by = {r["name"]: r for r in score["pairs"]}
    for name in MISRANKED:
        assert by[name]["agree"], (
            f"committed cpu.fit.json no longer flips {name} — refit with "
            f"tools/tune_sweep.py --fit")


def test_nnls_nonnegative_and_exact_on_interior():
    rng = np.random.default_rng(0)
    X = rng.uniform(1, 100, (50, 3))
    w_true = np.array([2.0, 0.5, 3.0])
    coef = mf._nnls(X, X @ w_true)
    np.testing.assert_allclose(coef, w_true, rtol=1e-8)
    # A column that only hurts is clipped to zero, not negative.
    y = X[:, 0] * 4.0 - X[:, 1] * 2.0
    coef = mf._nnls(X, y)
    assert (coef >= 0).all()


# ---------------------------------------------------------------------------
# tools/bench_gate.py — pass / rank hard-fail / latency noise band.
# ---------------------------------------------------------------------------

def _tuned_row(name: str, speedup: float) -> dict:
    return {"name": name, "us_per_call": 100.0,
            "derived": f"default_us=200.0;speedup={speedup:.2f}x;"
                       f"plan=oh8/oc32/bcj/mm2im"}


def _gate(tmp_path, cand: dict, base: dict, *extra) -> tuple:
    cp, bp = tmp_path / "cand.json", tmp_path / "base.json"
    cp.write_text(json.dumps(cand))
    bp.write_text(json.dumps(base))
    proc = subprocess.run(
        [sys.executable, str(REPO / "tools" / "bench_gate.py"),
         "--candidate", str(cp), "--baseline", str(bp), *extra],
        capture_output=True, text=True)
    return proc.returncode, proc.stdout + proc.stderr


def test_bench_gate_passes_identical_docs(tmp_path):
    doc = {"autotune": RECORDED_ROWS + [_tuned_row("autotune_a", 1.4)]}
    code, out = _gate(tmp_path, doc, doc)
    assert code == 0, out
    assert "PASS" in out


def test_bench_gate_fails_injected_rank_regression(tmp_path):
    """The acceptance criterion's synthetic regression: swapping the sb/db
    measurement of an agreeing decisive pair must hard-fail the gate."""
    cand = json.loads(json.dumps(RECORDED_DOC))
    for r in cand["autotune"]:
        if r["name"] == "autotune_ih7_ic64_ks5_oc32_s2_dbcmp":
            r["derived"] = r["derived"].replace(
                "sb_us=304.6", "sb_us=714.9").replace(
                "db_us=714.9", "db_us=304.6")
    code, out = _gate(tmp_path, cand, RECORDED_DOC)
    assert code == 1, out
    assert "FAIL: candidate misranks" in out


def test_bench_gate_latency_noise_band(tmp_path):
    base = {"autotune": [_tuned_row(f"autotune_p{i}", 2.0)
                         for i in range(3)]}
    soft = {"autotune": [_tuned_row(f"autotune_p{i}", 1.6)
                         for i in range(3)]}
    # A 0.8x geomean ratio is inside the default 0.5 band: reported, passes.
    code, out = _gate(tmp_path, soft, base)
    assert code == 0, out
    # ...but beyond a tight band it fails.
    code, out = _gate(tmp_path, soft, base, "--noise-band", "0.9")
    assert code == 1, out
    assert "below the noise band" in out


def test_bench_gate_rejects_unreadable_input(tmp_path):
    (tmp_path / "base.json").write_text("{}")
    proc = subprocess.run(
        [sys.executable, str(REPO / "tools" / "bench_gate.py"),
         "--candidate", str(tmp_path / "missing.json"),
         "--baseline", str(tmp_path / "base.json")],
        capture_output=True, text=True)
    assert proc.returncode != 0
    assert "cannot read" in proc.stderr


def test_bench_gate_ignores_serve_chaos_section(tmp_path):
    """ISSUE 10: degraded-mode chaos rows (fault-injected latencies) must
    not influence either gate leg — a candidate differing only in its
    ``serve_chaos`` section gates identically to the baseline."""
    base = {"autotune": RECORDED_ROWS + [_tuned_row("autotune_a", 1.4)],
            "serve_chaos": {"serve_chaos_ladder_dcgan_f32":
                            {"retries": "2", "degraded": "2"}}}
    cand = json.loads(json.dumps(base))
    cand["serve_chaos"] = {"serve_chaos_breaker_dcgan_f32":
                           {"shed_after_trip": "14",
                            "breaker_state": "open"}}
    code, out = _gate(tmp_path, cand, base)
    assert code == 0, out
    assert "serve_chaos" not in out              # stripped before both legs
