"""Chaos suite for the resilient serving layer (ISSUE 10).

The invariant under test, everywhere: **no submitted request is ever
left unfulfilled** — every request either completes (possibly on a lower
ladder rung), fails with a typed error (``DeadlineExceeded``, a
``ShedError`` subclass at admission, ``LadderExhausted``,
``DrainLoopCrash``, ``ServerClosed``), and the counters in
``server.stats()`` account for all of it
(``requests == completed + failed + pending``, sheds separate).

Most tests drive a jax-free ``FakeRunner`` through the real server and
ladder machinery with injected clocks/sleeps, so the state machines are
deterministic; one integration test pushes a real (tiny) model through
an injected fault and checks the rescued outputs.
"""

import threading
import time

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.serve import resilience
from repro.serve.bucketing import CircuitOpenError, QueueFullError, ShedError
from repro.serve.resilience import (BREAKER_CLOSED, BREAKER_HALF_OPEN,
                                    BREAKER_OPEN, CircuitBreaker,
                                    DeadlineExceeded, DegradationLadder,
                                    DispatchFault, DrainLoopCrash,
                                    FaultInjector, InjectedFault,
                                    LadderExhausted, PoisonedBucket,
                                    ResilienceConfig, RUNG_F32,
                                    RUNG_HEURISTIC, RUNG_LAX, RUNG_TUNED,
                                    TransientFault, is_transient,
                                    ladder_rungs, run_ladder)
from repro.serve.server import ServerClosed, TconvServer

NOSLEEP = lambda s: None  # noqa: E731 — injected backoff sleep


# ---------------------------------------------------------------------------
# A jax-free runner: every ladder rung produces a distinct marker value,
# and each rung's failure mode is switchable per test.
# ---------------------------------------------------------------------------

MARK_TUNED, MARK_HEURISTIC, MARK_LAX = 1.0, 2.0, 3.0
MARK_TUNED_INT8 = 1.5


class _FakeSpec:
    def forward(self, params, x, *, options=None, policy=None):
        if getattr(policy, "fail", False):
            raise RuntimeError("policy forward broken")
        return jnp.ones_like(x) * getattr(policy, "marker", MARK_LAX)


class _FakePolicy:
    def __init__(self, marker, fail=False):
        self.marker = marker
        self.fail = fail


class FakeRunner:
    """Duck-typed GeneratorRunner: shape (4,), no tuned plans anywhere."""

    name = "fake"
    spec = _FakeSpec()
    params = {}
    options = {}

    def __init__(self):
        self.fail_tuned = None      # exception *instance* to raise, or None
        self.fail_tuned_times = 0   # raise only the first N calls (0 = all)
        self.fail_heuristic = False
        self.tuned_calls = 0

    def input_shape(self):
        return (4,)

    def tconv_problems(self):
        return {}

    def example_inputs(self, batch, seed=0):
        return np.zeros((batch, 4), np.float32)

    def has_compiled(self, *, batch, precision="f32"):
        return False

    def policy(self, precision="f32", plans=None):
        return _FakePolicy(MARK_HEURISTIC, fail=self.fail_heuristic)

    def jitted(self, *, batch, precision="f32"):
        mark = MARK_TUNED_INT8 if precision == "int8" else MARK_TUNED

        def fn(x):
            self.tuned_calls += 1
            if self.fail_tuned is not None:
                if (self.fail_tuned_times == 0
                        or self.tuned_calls <= self.fail_tuned_times):
                    raise self.fail_tuned
            return jnp.ones((batch, 4)) * mark

        return fn


def _server(runner=None, **kw):
    runner = runner or FakeRunner()
    kw.setdefault("max_wait_s", 60.0)  # batches flush on force only
    kw.setdefault("candidate_batches", (2,))
    kw.setdefault("default_batch", 2)
    return runner, TconvServer({"fake": runner}, **kw)


def _x():
    return np.zeros(4, np.float32)


# ---------------------------------------------------------------------------
# Exceptions / ladder-rung structure.
# ---------------------------------------------------------------------------


def test_exception_taxonomy_and_transience():
    assert issubclass(DeadlineExceeded, TimeoutError)
    assert issubclass(QueueFullError, ShedError)
    assert issubclass(CircuitOpenError, ShedError)
    assert issubclass(InjectedFault, TransientFault)
    assert is_transient(InjectedFault("x"))
    assert is_transient(OSError("dma timeout"))
    assert not is_transient(DispatchFault("x"))
    assert not is_transient(ValueError("shape"))


def test_ladder_rung_order():
    assert ladder_rungs("f32") == (RUNG_TUNED, RUNG_HEURISTIC, RUNG_LAX)
    assert ladder_rungs("int8") == (RUNG_TUNED, RUNG_HEURISTIC, RUNG_F32,
                                    RUNG_LAX)


# ---------------------------------------------------------------------------
# run_ladder (injected sleep; no server).
# ---------------------------------------------------------------------------


def _run(runner, *, precision="f32", injector=None,
         config=None, batch_index=1):
    return run_ladder(DegradationLadder(runner), np.zeros((2, 4), np.float32),
                      bucket="fake:4:f32:b2", batch=2, precision=precision,
                      batch_index=batch_index,
                      config=config or ResilienceConfig(),
                      injector=injector, rng=np.random.default_rng(0),
                      sleep=NOSLEEP)


def test_ladder_healthy_serves_tuned():
    out, rung, retries = _run(FakeRunner())
    assert rung == RUNG_TUNED and retries == 0
    np.testing.assert_array_equal(out, np.full((2, 4), MARK_TUNED))


def test_ladder_transient_fault_retries_in_place():
    r = FakeRunner()
    r.fail_tuned, r.fail_tuned_times = TransientFault("blip"), 1
    out, rung, retries = _run(r)
    assert rung == RUNG_TUNED and retries == 1   # retry rescued the rung
    np.testing.assert_array_equal(out, np.full((2, 4), MARK_TUNED))


def test_ladder_nontransient_descends_without_retry():
    r = FakeRunner()
    r.fail_tuned = ValueError("deterministic")
    out, rung, retries = _run(r)
    assert rung == RUNG_HEURISTIC and retries == 0
    assert r.tuned_calls == 1                    # exactly one attempt
    np.testing.assert_array_equal(out, np.full((2, 4), MARK_HEURISTIC))


def test_ladder_persistent_transient_descends_after_one_retry():
    r = FakeRunner()
    r.fail_tuned = TransientFault("always")      # every attempt fails
    out, rung, retries = _run(r)
    assert rung == RUNG_HEURISTIC and retries == 1
    assert r.tuned_calls == 2                    # attempt + one retry only


def test_ladder_falls_to_lax_bottom():
    r = FakeRunner()
    r.fail_tuned = ValueError("broken")
    r.fail_heuristic = True
    out, rung, _ = _run(r)
    assert rung == RUNG_LAX
    np.testing.assert_array_equal(out, np.full((2, 4), MARK_LAX))


def test_ladder_int8_precision_rung():
    r = FakeRunner()
    orig = r.jitted

    def jitted(*, batch, precision="f32"):
        if precision == "int8":
            def broken(x):
                raise ValueError("int8 path broken")
            return broken
        return orig(batch=batch, precision=precision)

    r.jitted = jitted
    r.fail_heuristic = True
    out, rung, _ = _run(r, precision="int8")
    assert rung == RUNG_F32                      # rescued by the f32 forward
    np.testing.assert_array_equal(out, np.full((2, 4), MARK_TUNED))


def test_ladder_exhausted_raises_typed_with_cause():
    r = FakeRunner()
    r.fail_tuned = ValueError("broken")
    r.fail_heuristic = True
    broken_spec = _FakeSpec()
    r.spec = broken_spec
    # break the lax rung too: _ReferencePolicy has no marker, so make the
    # forward itself reject reference policies
    r.spec.forward = lambda params, x, options=None, policy=None: (
        (_ for _ in ()).throw(RuntimeError("lax broken")))
    with pytest.raises(LadderExhausted) as ei:
        _run(r)
    assert ei.value.__cause__ is not None


def test_ladder_memoizes_rung_fns():
    ladder = DegradationLadder(FakeRunner())
    f1 = ladder.fn(RUNG_TUNED, batch=2, precision="f32")
    f2 = ladder.fn(RUNG_TUNED, batch=2, precision="f32")
    assert f1 is f2
    assert ladder.fn(RUNG_TUNED, batch=4, precision="f32") is not f1


# ---------------------------------------------------------------------------
# Circuit breaker state machine (injected clock).
# ---------------------------------------------------------------------------


def test_breaker_trips_after_threshold_and_probes():
    b = CircuitBreaker(threshold=3, cooldown_s=10.0)
    assert b.state == BREAKER_CLOSED and b.allow(now=0.0)
    assert not b.record_failure(now=1.0)
    assert not b.record_failure(now=2.0)
    assert b.record_failure(now=3.0)             # third consecutive: trips
    assert b.state == BREAKER_OPEN and b.trips == 1
    assert not b.allow(now=3.1)                  # open: shed
    assert not b.allow(now=12.9)                 # cooldown not elapsed
    assert b.allow(now=13.0)                     # half-open probe admitted
    assert b.state == BREAKER_HALF_OPEN
    assert not b.allow(now=13.0)                 # only one probe at a time
    b.record_success()                           # probe ok: closed
    assert b.state == BREAKER_CLOSED and b.consecutive_failures == 0
    assert b.allow(now=13.1)


def test_breaker_failed_probe_reopens():
    b = CircuitBreaker(threshold=1, cooldown_s=5.0)
    assert b.record_failure(now=0.0)             # threshold 1: instant trip
    assert b.allow(now=5.0)                      # probe
    assert b.record_failure(now=5.1)             # probe failed: re-open
    assert b.state == BREAKER_OPEN and b.trips == 2
    assert not b.allow(now=10.0)                 # new cooldown from 5.1
    assert b.allow(now=10.2)


def test_breaker_success_resets_consecutive_count():
    b = CircuitBreaker(threshold=2, cooldown_s=1.0)
    b.record_failure(now=0.0)
    b.record_success()
    b.record_failure(now=1.0)                    # 1 again, not 2: no trip
    assert b.state == BREAKER_CLOSED and b.trips == 0


# ---------------------------------------------------------------------------
# FaultInjector determinism + trigger semantics.
# ---------------------------------------------------------------------------


def test_injector_fail_nth_targets_tuned_rung_only():
    inj = FaultInjector(fail_nth_batch=2)
    inj.before_batch("b", 1, rung=RUNG_TUNED, attempt=0)      # not nth
    with pytest.raises(InjectedFault):
        inj.before_batch("b", 2, rung=RUNG_TUNED, attempt=0)
    with pytest.raises(InjectedFault):
        inj.before_batch("b", 2, rung=RUNG_TUNED, attempt=1)  # retry too
    inj.before_batch("b", 2, rung=RUNG_HEURISTIC, attempt=0)  # lower rung ok
    assert inj.injected == {"fail": 2}


def test_injector_poison_hits_every_rung_of_matching_bucket():
    inj = FaultInjector(poison_bucket="fake:")
    for rung in ladder_rungs("int8"):
        with pytest.raises(PoisonedBucket):
            inj.before_batch("fake:4x4:int8:b2", 7, rung=rung, attempt=0)
    inj.before_batch("other:4:f32:b1", 7, rung=RUNG_TUNED, attempt=0)
    assert inj.injected["poison"] == 4


def test_injector_dispatch_raise_wraps_fn():
    inj = FaultInjector(raise_in_dispatch_nth=3)
    ok = inj.wrap(lambda x: x, "b", 2, rung=RUNG_TUNED, attempt=0)
    assert ok("payload") == "payload"
    bad = inj.wrap(lambda x: x, "b", 3, rung=RUNG_TUNED, attempt=0)
    with pytest.raises(DispatchFault):
        bad("payload")
    # lower rungs get the real fn even on the nth batch
    low = inj.wrap(lambda x: x, "b", 3, rung=RUNG_LAX, attempt=0)
    assert low("payload") == "payload"


def test_injector_crash_fires_once():
    inj = FaultInjector(crash_drain_at_batch=2)
    inj.maybe_crash(1)
    with pytest.raises(DrainLoopCrash):
        inj.maybe_crash(2)
    inj.maybe_crash(3)                           # once only
    assert inj.injected == {"drain_crash": 1}


def test_injector_is_deterministic_across_replays():
    def play():
        inj = FaultInjector(fail_nth_batch=2, seed=7)
        for n in range(1, 9):
            try:
                inj.before_batch("b", n, rung=RUNG_TUNED, attempt=0)
            except InjectedFault:
                pass
        return dict(inj.injected)

    assert play() == play() == {"fail": 4}


# ---------------------------------------------------------------------------
# Server: deadlines, shedding, breaker at admission.
# ---------------------------------------------------------------------------


def test_deadline_expired_request_fails_fast():
    _, srv = _server()
    req = srv.submit("fake", _x(), deadline_s=0.0)  # dead on arrival
    live = srv.submit("fake", _x())                 # no deadline
    assert srv.serve_once(force=True) == 2
    with pytest.raises(DeadlineExceeded):
        req.result(timeout=0)
    assert live.result(timeout=0) is not None       # live one still served
    b = srv.stats()["buckets"]["fake:4:f32:b2"]
    assert b["deadline_expired"] == 1 and b["failed"] == 1
    assert b["completed"] == 1
    assert b["requests"] == b["completed"] + b["failed"]


def test_default_deadline_from_config():
    _, srv = _server(resilience_config=ResilienceConfig(
        default_deadline_s=0.0))
    req = srv.submit("fake", _x())
    srv.serve_once(force=True)
    with pytest.raises(DeadlineExceeded):
        req.result(timeout=0)


def test_queue_full_sheds_without_enqueueing():
    _, srv = _server(resilience_config=ResilienceConfig(max_queue_depth=2))
    admitted = [srv.submit("fake", _x()) for _ in range(2)]
    for _ in range(3):
        with pytest.raises(QueueFullError):
            srv.submit("fake", _x())
    srv.serve_once(force=True)
    assert all(r.result(timeout=0) is not None for r in admitted)
    b = srv.stats()["buckets"]["fake:4:f32:b2"]
    assert b["shed"] == 3 and b["requests"] == 2 == b["completed"]


def test_breaker_trips_then_sheds_then_half_open_recovers():
    r, srv = _server(resilience_config=ResilienceConfig(
        breaker_threshold=2, breaker_cooldown_s=0.0))
    r.fail_tuned = ValueError("broken")
    r.fail_heuristic = True
    r.spec = _FakeSpec()                         # fresh: no class-level leak
    r.spec.forward = lambda params, x, options=None, policy=None: (
        (_ for _ in ()).throw(RuntimeError("lax broken")))
    failed = []
    for _ in range(2):                           # two fully-failed batches
        failed.append(srv.submit("fake", _x()))
        srv.serve_once(force=True)
    for q in failed:
        with pytest.raises(LadderExhausted):
            q.result(timeout=0)
    b = srv.stats()["buckets"]["fake:4:f32:b2"]
    assert b["breaker"]["state"] == BREAKER_OPEN
    assert b["breaker"]["trips"] == 1
    # cooldown 0: next submit is the half-open probe; heal the runner
    r.fail_tuned = None
    probe = srv.submit("fake", _x())
    srv.serve_once(force=True)
    assert probe.result(timeout=0) is not None
    assert srv.stats()["buckets"]["fake:4:f32:b2"]["breaker"]["state"] == \
        BREAKER_CLOSED


def test_breaker_open_sheds_with_typed_error():
    r, srv = _server(resilience_config=ResilienceConfig(
        breaker_threshold=1, breaker_cooldown_s=600.0))
    r.fail_tuned = ValueError("broken")
    r.fail_heuristic = True
    r.spec = _FakeSpec()
    r.spec.forward = lambda params, x, options=None, policy=None: (
        (_ for _ in ()).throw(RuntimeError("lax broken")))
    doomed = srv.submit("fake", _x())
    srv.serve_once(force=True)
    with pytest.raises(LadderExhausted):
        doomed.result(timeout=0)
    with pytest.raises(CircuitOpenError):        # open + long cooldown
        srv.submit("fake", _x())
    assert srv.stats()["buckets"]["fake:4:f32:b2"]["shed"] == 1


# ---------------------------------------------------------------------------
# Server: ladder accounting, injector composition.
# ---------------------------------------------------------------------------


def test_server_records_rungs_and_degraded():
    r, srv = _server(fault_injector=FaultInjector(fail_nth_batch=2))
    reqs = []
    for _ in range(4):                           # 4 serial partial batches
        reqs.append(srv.submit("fake", _x()))
        srv.serve_once(force=True)
    outs = [q.result(timeout=0) for q in reqs]
    # batches 2 and 4 were injected: retried (transient) then descended
    np.testing.assert_array_equal(outs[0], np.full(4, MARK_TUNED))
    np.testing.assert_array_equal(outs[1], np.full(4, MARK_HEURISTIC))
    b = srv.stats()["buckets"]["fake:4:f32:b2"]
    assert b["rungs"] == {RUNG_TUNED: 2, RUNG_HEURISTIC: 2}
    assert b["degraded"] == 2 and b["retries"] == 2
    assert b["completed"] == 4 and b["failed"] == 0
    assert srv.stats()["fault_injection"]["fail"] == 4  # 2 per bad batch


def test_server_straggler_composition_counts_stalls():
    from repro.runtime.fault_tolerance import StragglerSimulator

    straggler = StragglerSimulator(p=1.0, delay_s=0.0, seed=3)
    _, srv = _server(fault_injector=FaultInjector(straggler=straggler))
    q = srv.submit("fake", _x())
    srv.serve_once(force=True)
    assert q.result(timeout=0) is not None
    assert srv.stats()["fault_injection"]["straggler_stalls"] == 1


# ---------------------------------------------------------------------------
# Drain-loop supervision.
# ---------------------------------------------------------------------------


def test_supervisor_restarts_crashed_drain_and_fails_inflight():
    _, srv = _server(max_wait_s=0.01,
                     fault_injector=FaultInjector(crash_drain_at_batch=1))
    with srv:
        crashed = srv.submit("fake", _x())
        with pytest.raises(DrainLoopCrash):
            crashed.result(timeout=10.0)         # failed, not wedged
        # the supervisor restarted the drain thread: traffic flows again
        deadline = time.monotonic() + 10.0
        while srv.stats()["drain_restarts"] == 0:
            assert time.monotonic() < deadline, "supervisor never restarted"
            time.sleep(0.01)
        healthy = srv.submit("fake", _x())
        assert healthy.result(timeout=10.0) is not None
    s = srv.stats()
    assert s["drain_crashes"] == 1 and s["drain_restarts"] >= 1
    assert s["fault_injection"]["drain_crash"] == 1


def test_crash_in_serve_once_counts_request_as_failed():
    _, srv = _server(fault_injector=FaultInjector(crash_drain_at_batch=1))
    q = srv.submit("fake", _x())
    with pytest.raises(DrainLoopCrash):
        srv.serve_once(force=True)               # synchronous caller path
    # the popped request is in-flight; failing it is the guard's job —
    # simulate what _loop_guard does
    srv._fail_inflight(DrainLoopCrash("from guard"))
    with pytest.raises(DrainLoopCrash):
        q.result(timeout=0)
    b = srv.stats()["buckets"]["fake:4:f32:b2"]
    assert b["failed"] == 1 and srv.stats()["pending"] == 0


# ---------------------------------------------------------------------------
# Integration: a real model rescued by the ladder.
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def fsrcnn_runner():
    from repro.models.runner import make_runner

    return make_runner("fsrcnn", key=jax.random.PRNGKey(0),
                       init_kw={"d": 8, "s": 4, "m": 1}, input_hw=8)


def test_real_model_chaos_every_request_served(fsrcnn_runner):
    """fail-every-2nd-batch against a real runner: every request completes
    (tuned or rescued by the heuristic rung), outputs finite, counters
    consistent — the chaos invariant end to end.  Batch-1 buckets driven
    synchronously make the batch indices (and so the injections)
    deterministic: 6 requests -> batches 1..6, of which 2/4/6 fail."""
    inj = FaultInjector(fail_nth_batch=2)
    srv = TconvServer({"fsrcnn": fsrcnn_runner}, max_wait_s=60.0,
                      candidate_batches=(1,), default_batch=1,
                      fault_injector=inj)
    x = np.asarray(fsrcnn_runner.example_inputs(1, seed=0))[0]
    reqs = [srv.submit("fsrcnn", x) for _ in range(6)]
    assert srv.serve_once(force=True) == 6
    outs = [q.result(timeout=0) for q in reqs]
    assert all(np.isfinite(np.asarray(o)).all() for o in outs)
    [b] = srv.stats()["buckets"].values()
    assert b["completed"] == 6 and b["failed"] == 0
    assert b["degraded"] == 3 and b["retries"] == 3
    assert b["rungs"] == {RUNG_TUNED: 3, RUNG_HEURISTIC: 3}
    assert inj.injected["fail"] == 6             # 2 attempts per bad batch
    # rescued rows are numerically the same forward
    np.testing.assert_allclose(np.asarray(outs[1]), np.asarray(outs[0]),
                               rtol=1e-5, atol=1e-5)


def test_real_model_heuristic_rung_output_matches_reference(fsrcnn_runner):
    """The heuristic rung is numerically the same forward — explicit
    default plans change scheduling, not math."""
    ladder = DegradationLadder(fsrcnn_runner)
    x = jnp.asarray(np.asarray(fsrcnn_runner.example_inputs(2, seed=1)))
    tuned = np.asarray(ladder.fn(RUNG_TUNED, batch=2, precision="f32")(x))
    heur = np.asarray(ladder.fn(RUNG_HEURISTIC, batch=2, precision="f32")(x))
    lax = np.asarray(ladder.fn(RUNG_LAX, batch=2, precision="f32")(x))
    np.testing.assert_allclose(heur, tuned, rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(lax, tuned, rtol=1e-5, atol=1e-5)
