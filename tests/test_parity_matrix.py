"""The differential parity matrix: every registered method vs the gold.

Parametrizes over ``registry.names()`` **at collection time**, so any
kernel family registered through the ordinary ``KernelSpec`` entry point
— including ``mm2im_ks`` added by this PR, and any future or third-party
variant — is automatically enrolled in the full pinned grid of
``tests/parity.py`` with zero test wiring.
"""

import zlib

import numpy as np
import pytest

from _hypothesis_shim import given, settings, st
from parity import (ParityCase, assert_full_parity, assert_method_parity,
                    parity_grid)
from repro.kernels import ref, registry
from repro.kernels.ops import tconv

METHODS = tuple(sorted(registry.names()))
DTYPES = ("f32", "int8")


@pytest.mark.parametrize("dtype", DTYPES)
@pytest.mark.parametrize("method", METHODS)
def test_parity_matrix(method, dtype):
    """method × the full pinned grid (one dtype column per test node)."""
    assert_full_parity(method, dtype)


def test_grid_derives_legality():
    """The pinned grid excludes exactly the repo-wide illegal cells and
    emits fold cells only for plan-capable methods at batch > 1."""
    cells = list(parity_grid("mm2im"))
    # SAME with Ks < S is unsupported everywhere (ref.crop_offsets).
    assert not any(c.padding == "SAME" and c.ks < c.stride for c in cells)
    # VALID stride>kernel (gapped output) IS covered.
    assert any(c.padding == "VALID" and c.stride > c.ks for c in cells)
    assert any(c.fold for c in cells)
    assert not any(c.fold and c.batch == 1 for c in cells)
    # Non-plan methods get no fold cells (the fold rides a plan).
    assert not any(c.fold for c in parity_grid("lax"))
    # Both dtype columns and both batches are pinned.
    assert {c.dtype for c in cells} == {"f32", "int8"}
    assert {c.batch for c in cells} == {1, 8}


def test_grid_covers_activation_table():
    """The per-cell derived epilogues collectively exercise every
    activation and both bias arms (coverage without cell multiplication).
    """
    pairs = {c.bias_and_activation for c in parity_grid("mm2im")}
    assert {a for _, a in pairs} == {"none", "relu", "tanh", "leaky_relu"}
    assert {b for b, _ in pairs} == {True, False}


def test_new_registry_entry_auto_enrolls():
    """Registering a kernel is all it takes to be parity-checked: a
    plugin wrapping the direct reference passes a grid cell through the
    same harness entry the matrix uses, with no harness changes."""

    @registry.register("parity_probe",
                       description="ref.tconv_direct as a parity probe")
    def _probe(x, w, *, stride, padding, epilogue, plan):
        # Like the other unfused baselines: the dispatcher applies the
        # (entirely unfused) epilogue remainder.
        return ref.tconv_direct(x, w, stride=stride, padding=padding)

    try:
        assert any(True for _ in parity_grid("parity_probe"))
        case = ParityCase(2, "SAME", 3, "f32", 1, False)
        assert_method_parity("parity_probe", case)
    finally:
        assert registry.unregister("parity_probe") is not None


# ---------------------------------------------------------------------------
# Property-based shape fuzzing (the pinned grid's randomized complement)
# ---------------------------------------------------------------------------


@settings(max_examples=12, deadline=None)
@given(
    ih=st.integers(1, 7), iw=st.integers(1, 7),
    ks=st.integers(1, 6), s=st.integers(1, 5),
    padding=st.sampled_from(["SAME", "VALID"]),
    batch=st.integers(1, 3),
    activation=st.sampled_from(["none", "relu", "tanh", "leaky_relu"]),
    bias=st.booleans(),
)
def test_fuzz_shapes_all_methods(ih, iw, ks, s, padding, batch, activation,
                                 bias):
    """Randomized odd/even kernels, asymmetric H != W and stride > kernel
    edge shapes through ``ops.tconv`` — every registered method vs the
    gold.  The pinned grid freezes known-interesting cells; this sweeps
    the shape space between them (deterministic fallback sweep when
    hypothesis is absent)."""
    if padding == "SAME" and ks < s:
        return  # unsupported repo-wide (ref.crop_offsets raises)
    seed = zlib.crc32(f"{ih}:{iw}:{ks}:{s}:{padding}:{batch}".encode())
    rng = np.random.default_rng(seed)
    ic, oc = 3, 4
    x = rng.standard_normal((batch, ih, iw, ic)).astype(np.float32)
    w = (rng.standard_normal((ks, ks, oc, ic)) * 0.1).astype(np.float32)
    b = rng.standard_normal(oc).astype(np.float32) if bias else None
    gold = np.asarray(tconv(x, w, b, stride=s, padding=padding,
                            method="lax", activation=activation))
    for method in METHODS:
        if method == "lax":
            continue
        got = np.asarray(tconv(x, w, b, stride=s, padding=padding,
                               method=method, activation=activation))
        assert got.shape == gold.shape, \
            f"{method} ih{ih} iw{iw} ks{ks} s{s} {padding} b{batch}"
        np.testing.assert_allclose(
            got, gold, rtol=1e-4, atol=1e-4,
            err_msg=f"{method} ih{ih} iw{iw} ks{ks} s{s} {padding} "
                    f"b{batch} act={activation} bias={bias}")


# ---------------------------------------------------------------------------
# Large-image / stride-4 cells (the mm2im_og sweep regime, slow-marked)
# ---------------------------------------------------------------------------

#: (ih, iw, ks, stride, padding, batch, fold) — the FSRCNN/pix2pix decoder
#: regime of ``paper_models.large_image_sweep``: inputs far past the
#: pinned grid's 5x4, stride 4, odd kernels, including a folded batch-8
#: cell.  Channels stay tiny so interpret mode finishes in seconds.
LARGE_CELLS = (
    (16, 16, 5, 4, "SAME", 1, False),
    (32, 32, 5, 4, "SAME", 8, True),
    (32, 24, 7, 4, "VALID", 2, False),
)


@pytest.mark.slow
@pytest.mark.parametrize("method", [m for m in METHODS if m != "lax"])
def test_large_image_parity(method):
    """Every registered family vs the gold on large-image stride-4 shapes.

    The pinned grid's 5x4 inputs never exercise multi-row-block slab
    windows at stride 4; these cells do (plus rectangular VALID and a
    folded batch-8 run, which must stay bit-identical to grid-batch)."""
    from repro.kernels.registry import Plan

    supports_plan = registry.get(method).supports_plan
    ic, oc = 3, 4
    for ih, iw, ks, s, padding, batch, fold in LARGE_CELLS:
        rng = np.random.default_rng(zlib.crc32(
            f"large:{ih}:{iw}:{ks}:{s}:{padding}:{batch}".encode()))
        x = rng.standard_normal((batch, ih, iw, ic)).astype(np.float32)
        w = (rng.standard_normal((ks, ks, oc, ic)) * 0.1).astype(np.float32)
        gold = np.asarray(tconv(x, w, stride=s, padding=padding,
                                method="lax"))
        plan = Plan(2 * s, oc, "bcj", fold_batch=fold and supports_plan) \
            if supports_plan else None
        got = np.asarray(tconv(x, w, stride=s, padding=padding,
                               method=method, plan=plan))
        np.testing.assert_allclose(
            got, gold, rtol=1e-4, atol=1e-4,
            err_msg=f"{method} ih{ih} iw{iw} ks{ks} s{s} {padding} "
                    f"b{batch} fold={fold}")
        if fold and supports_plan:
            grid = np.asarray(tconv(
                x, w, stride=s, padding=padding, method=method,
                plan=Plan(2 * s, oc, "bcj", fold_batch=False)))
            assert (got == grid).all(), \
                f"{method}: folded large-image result != grid-batch"


@pytest.mark.slow
@settings(max_examples=6, deadline=None)
@given(
    ih=st.sampled_from([8, 12, 16, 24, 32]),
    iw=st.sampled_from([8, 16, 32]),
    ks=st.sampled_from([3, 5, 7, 9]),
    padding=st.sampled_from(["SAME", "VALID"]),
    batch=st.integers(1, 2),
)
def test_fuzz_large_image_stride4(ih, iw, ks, padding, batch):
    """Stride-4 complement of the small-shape fuzzer: large-image inputs
    through every registered method vs the gold (SAME cells only where
    Ks >= S, the repo-wide legality rule)."""
    s = 4
    if padding == "SAME" and ks < s:
        return  # unsupported repo-wide (ref.crop_offsets raises)
    seed = zlib.crc32(f"large:{ih}:{iw}:{ks}:{padding}:{batch}".encode())
    rng = np.random.default_rng(seed)
    ic, oc = 3, 4
    x = rng.standard_normal((batch, ih, iw, ic)).astype(np.float32)
    w = (rng.standard_normal((ks, ks, oc, ic)) * 0.1).astype(np.float32)
    gold = np.asarray(tconv(x, w, stride=s, padding=padding, method="lax"))
    for method in METHODS:
        if method == "lax":
            continue
        got = np.asarray(tconv(x, w, stride=s, padding=padding,
                               method=method))
        assert got.shape == gold.shape, \
            f"{method} ih{ih} iw{iw} ks{ks} s{s} {padding} b{batch}"
        np.testing.assert_allclose(
            got, gold, rtol=1e-4, atol=1e-4,
            err_msg=f"{method} ih{ih} iw{iw} ks{ks} s{s} {padding} b{batch}")


def test_gold_contract_stride_gt_kernel():
    """The repo's VALID output contract (``out_size``: S·(I-1)+Ks) is the
    gold for gapped stride>kernel shapes; ``lax.conv_transpose`` pads the
    same values with trailing zero gap rows — pin the relationship so the
    contract divergence stays understood rather than rediscovered."""
    rng = np.random.default_rng(0)
    x = rng.standard_normal((1, 5, 5, 3)).astype(np.float32)
    w = (rng.standard_normal((3, 3, 2, 3)) * 0.1).astype(np.float32)
    oh = ref.out_size(5, 3, 4, "VALID")
    full = np.asarray(ref.tconv_lax(x, w, stride=4, padding="VALID"))
    direct = np.asarray(ref.tconv_direct(x, w, stride=4, padding="VALID"))
    assert direct.shape[1] == oh
    np.testing.assert_allclose(full[:, :oh, :oh], direct, rtol=1e-4,
                               atol=1e-4)
    assert np.all(full[:, oh:] == 0) and np.all(full[:, :, oh:] == 0)
