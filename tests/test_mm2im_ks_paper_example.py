"""Golden worked example: stride-2 3x3 kernel segregation, by hand.

The smallest non-trivial segregation (DESIGN.md §2.6): a 3x3 kernel at
stride 2, SAME padding (crop offsets 0), splits into S² = 4 stride-1
sub-kernels.  Every number below — tap groups, packed-weight permutation,
interleave maps, and the full output of a 2x2 input with counting
weights — is pinned as a hand-computed literal, so a regression in the
decomposition shows up as a readable diff against the worked example
rather than an opaque allclose failure.

Tap derivation (kernels carry kh ≡ a' + ct (mod S) for output-row
residue a'; ct = 0 here):

    residue (0,0): kh ∈ {0,2}, kw ∈ {0,2}   -> 4 taps
    residue (0,1): kh ∈ {0,2}, kw ∈ {1}     -> 2 taps
    residue (1,0): kh ∈ {1},   kw ∈ {0,2}   -> 2 taps
    residue (1,1): kh ∈ {1},   kw ∈ {1}     -> 1 tap
                                               --------
                                               9 = Ks²
"""

import numpy as np

from repro.core.segregate import (interleave_maps, pack_weights, segregate,
                                  segregated_tconv_reference)
from repro.kernels import ref
from repro.kernels.mm2im_ks_pallas import mm2im_ks_tconv
from repro.kernels.ops import tconv

KS, S = 3, 2

# x = [[1, 2], [3, 4]]; w[kh, kw] = 3*kh + kw + 1 (counting weights).
X = np.arange(1, 5, dtype=np.float32).reshape(1, 2, 2, 1)
W = np.arange(1, 10, dtype=np.float32).reshape(KS, KS, 1, 1)

# Hand-computed 4x4 SAME output (out[oh, ow] = Σ x[ih,iw]·w[kh,kw] over
# oh = 2·ih + kh, ow = 2·iw + kw; e.g. out[2,2] = 1·9 + 2·7 + 3·3 + 4·1).
GOLD = np.array([[1.,  2.,  5.,  4.],
                 [4.,  5., 14., 10.],
                 [10., 14., 36., 24.],
                 [12., 15., 34., 20.]], np.float32)


def test_segregation_tap_groups():
    """The 4 sub-kernels, their tap tuples, shifts and packed offsets."""
    seg = segregate(KS, S, "SAME")
    assert (seg.ct, seg.cl) == (0, 0)
    assert seg.total_taps == KS * KS
    got = [(sk.row_phase, sk.col_phase, sk.kh_taps, sk.kw_taps,
            sk.row_shift, sk.col_shift, sk.offset)
           for sk in seg.subkernels]
    assert got == [
        (0, 0, (0, 2), (0, 2), 0, 0, 0),
        (0, 1, (0, 2), (1,),   0, 0, 4),
        (1, 0, (1,),   (0, 2), 0, 0, 6),
        (1, 1, (1,),   (1,),   0, 0, 8),
    ]


def test_packed_weight_permutation():
    """Tap axis grouped by sub-kernel: flat order [0,2,6,8, 1,7, 3,5, 4],
    so the counting weights pack to [1,3,7,9, 2,8, 4,6, 5]."""
    seg = segregate(KS, S, "SAME")
    np.testing.assert_array_equal(seg.permutation(),
                                  [0, 2, 6, 8, 1, 7, 3, 5, 4])
    packed = np.asarray(pack_weights(W, seg))
    assert packed.shape == (1, KS * KS, 1)  # (Ic, Ks², Oc)
    np.testing.assert_array_equal(packed[0, :, 0],
                                  [1, 3, 7, 9, 2, 8, 4, 6, 5])


def test_interleave_maps_tile_the_output():
    """Each plane writes out[a'::2, b'::2]; the four views tile 4x4."""
    seg = segregate(KS, S, "SAME")
    maps = interleave_maps(seg, 4, 4)
    want = {(0, 0): ([0, 2], [0, 2]), (0, 1): ([0, 2], [1, 3]),
            (1, 0): ([1, 3], [0, 2]), (1, 1): ([1, 3], [1, 3])}
    assert set(maps) == set(want)
    seen = np.zeros((4, 4), np.int32)
    for phase, (rows, cols) in maps.items():
        np.testing.assert_array_equal(rows, want[phase][0])
        np.testing.assert_array_equal(cols, want[phase][1])
        seen[np.ix_(rows, cols)] += 1
    assert (seen == 1).all()  # exactly-once cover, no overlap


def test_plane_shapes_and_worked_output():
    """Each sub-kernel's plane is 2x2, and its values are the hand table's
    residue class — then the reference assembles exactly GOLD."""
    seg = segregate(KS, S, "SAME")
    for sk in seg.subkernels:
        assert sk.plane_shape(4, 4) == (2, 2)
    out = np.asarray(segregated_tconv_reference(X, W, stride=S,
                                                padding="SAME"))[0, :, :, 0]
    np.testing.assert_array_equal(out, GOLD)
    # Residue-class spot check straight off the table: plane (1,1) is the
    # single-tap sub-kernel — w[1,1] = 5 times the input.
    np.testing.assert_array_equal(GOLD[1::2, 1::2], 5.0 * X[0, :, :, 0])


def test_kernel_matches_worked_example():
    """The Pallas kernel and registry dispatch reproduce the hand table."""
    got = np.asarray(mm2im_ks_tconv(X, W, stride=S, padding="SAME",
                                    interpret=True))[0, :, :, 0]
    np.testing.assert_array_equal(got, GOLD)
    via_ops = np.asarray(tconv(X, W, stride=S, method="mm2im_ks"))
    np.testing.assert_array_equal(via_ops[0, :, :, 0], GOLD)
    # And the lax gold agrees, closing the loop to the TCONV contract.
    np.testing.assert_allclose(
        np.asarray(ref.tconv_lax(X, W, stride=S))[0, :, :, 0], GOLD,
        rtol=1e-6, atol=1e-6)
