"""Optional-hypothesis shim shared by the property-style kernel tests.

When the real ``hypothesis`` package is installed (the ``test`` extra in
pyproject.toml), this module re-exports it untouched and the property tests
run with full randomized shrinking.  When it is absent — the minimal CI /
edge-device image — the same decorators fall back to a *deterministic*
sweep: each strategy draws from a seeded ``numpy`` generator (seeded from a
CRC of the test name, so every run and every machine sees the identical
example list), ``@settings`` only carries ``max_examples`` through, and the
test body runs once per drawn example.

Import as ``from _hypothesis_shim import given, settings, st`` — conftest.py
guarantees the tests directory is importable.
"""

from __future__ import annotations

try:  # pragma: no cover - exercised only when hypothesis is installed
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ModuleNotFoundError:
    import zlib

    import numpy as _np

    HAVE_HYPOTHESIS = False

    _DEFAULT_EXAMPLES = 10

    class _Strategy:
        def sample(self, rng):
            raise NotImplementedError

    class _Integers(_Strategy):
        def __init__(self, lo, hi):
            self.lo, self.hi = int(lo), int(hi)

        def sample(self, rng):
            return int(rng.integers(self.lo, self.hi + 1))

    class _SampledFrom(_Strategy):
        def __init__(self, options):
            self.options = list(options)

        def sample(self, rng):
            return self.options[int(rng.integers(len(self.options)))]

    class _Booleans(_Strategy):
        def sample(self, rng):
            return bool(rng.integers(2))

    class _Floats(_Strategy):
        def __init__(self, lo=0.0, hi=1.0, **_kw):
            self.lo, self.hi = float(lo), float(hi)

        def sample(self, rng):
            return float(rng.uniform(self.lo, self.hi))

    class _St:
        """The subset of ``hypothesis.strategies`` the test-suite uses."""

        @staticmethod
        def integers(min_value, max_value):
            return _Integers(min_value, max_value)

        @staticmethod
        def sampled_from(options):
            return _SampledFrom(options)

        @staticmethod
        def booleans():
            return _Booleans()

        @staticmethod
        def floats(min_value=0.0, max_value=1.0, **kw):
            return _Floats(min_value, max_value, **kw)

    st = _St()

    def settings(max_examples: int = _DEFAULT_EXAMPLES, **_ignored):
        """Fallback ``@settings``: records max_examples, ignores the rest."""

        def deco(fn):
            fn._fallback_max_examples = max_examples
            return fn

        return deco

    def given(**strategies):
        """Fallback ``@given``: deterministic example sweep, no shrinking."""

        def deco(fn):
            def wrapper():
                seed = zlib.crc32(fn.__qualname__.encode())
                rng = _np.random.default_rng(seed)
                n = getattr(wrapper, "_fallback_max_examples",
                            _DEFAULT_EXAMPLES)
                for _ in range(n):
                    fn(**{k: s.sample(rng) for k, s in strategies.items()})

            wrapper.__name__ = fn.__name__
            wrapper.__qualname__ = fn.__qualname__
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            return wrapper

        return deco
