"""Perf-model validation (§V-F analogue): model vs XLA cost_analysis."""

import jax
import jax.numpy as jnp
import pytest

from repro.core import perf_model
from repro.core.maps import TConvProblem, drop_stats
from repro.kernels import ref
from repro.kernels.baselines import zero_insertion_macs

PROBLEMS = [TConvProblem(8, 8, 64, 5, 32, 2), TConvProblem(16, 16, 32, 3, 16, 1)]


def _xla_flops(fn, *args):
    ca = jax.jit(fn).lower(*args).compile().cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    return float(ca.get("flops", 0.0))


@pytest.mark.parametrize("p", PROBLEMS, ids=str)
def test_unfused_iom_flops_within_10pct(p):
    x = jnp.zeros((1, p.ih, p.iw, p.ic), jnp.float32)
    w = jnp.zeros((p.ks, p.ks, p.oc, p.ic), jnp.float32)
    got = _xla_flops(lambda a, b: ref.iom_reference(a, b, stride=p.stride), x, w)
    assert abs(got - 2 * p.macs) / (2 * p.macs) < 0.10


@pytest.mark.parametrize("p", PROBLEMS, ids=str)
def test_zero_insertion_flops_within_tolerance(p):
    """XLA's conv cost model excludes border padding taps; our model uses
    the dense Oh*Ow*Ks^2 count (the paper's convention).  For small images
    the border fraction ~ 2*(Ks-1)/Oh — allow for it explicitly."""
    x = jnp.zeros((1, p.ih, p.iw, p.ic), jnp.float32)
    w = jnp.zeros((p.ks, p.ks, p.oc, p.ic), jnp.float32)
    got = _xla_flops(lambda a, b: ref.tconv_direct(a, b, stride=p.stride), x, w)
    want = 2 * zero_insertion_macs(p.ih, p.iw, p.ic, p.ks, p.oc, p.stride)
    border = 2.0 * (p.ks - 1) / (p.stride * p.ih)
    assert abs(got - want) / want < 0.10 + border


def test_estimates_ordering_sane():
    """Fused MM2IM must never be slower than the unfused IOM baseline."""
    for p in PROBLEMS + [TConvProblem(4, 4, 1024, 5, 512, 2)]:
        t_m = perf_model.mm2im_estimate(p, bits=8).t_overlapped
        t_u = perf_model.iom_unfused_estimate(p, bits=8).t_overlapped
        assert t_m <= t_u * 1.05


def test_mxu_utilization_bounds():
    for p in PROBLEMS:
        e = perf_model.mm2im_estimate(p, bits=8)
        assert 0.0 < e.mxu_utilization <= 1.0
        assert e.effectual_macs == drop_stats(p)["effectual_macs"]


def test_modeled_speedup_positive():
    for p in PROBLEMS:
        assert perf_model.modeled_speedup(p) > 0.5


def test_bottleneck_attributes_fill():
    """Regression: a fill-dominated estimate reports 'fill', not 'memory'.

    t_fill is the non-overlappable slice of t_memory, so the memory term
    competes with its overlappable remainder only — previously the whole
    t_memory won and pipeline-fill problems were misdiagnosed as traffic
    problems."""
    fill_dom = perf_model.Estimate("x", t_compute=1.0, t_memory=3.0,
                                   t_fill=2.5)
    assert fill_dom.bottleneck == "fill"
    mem_dom = perf_model.Estimate("x", t_compute=1.0, t_memory=3.0,
                                  t_fill=0.5)
    assert mem_dom.bottleneck == "memory"
    comp_dom = perf_model.Estimate("x", t_compute=9.0, t_memory=3.0,
                                   t_fill=2.5)
    assert comp_dom.bottleneck == "compute"


def test_int8_without_requant_stores_int32():
    """Regression: int8 WITHOUT a requant epilogue stores the int32
    accumulator (4 bytes/elem), not 1 byte — only the paper's requantizing
    mode narrows the store."""
    p = PROBLEMS[0]
    e_req = perf_model.mm2im_estimate(p, bits=8, requant=True)
    e_raw = perf_model.mm2im_estimate(p, bits=8, requant=False)
    out_elems = p.oh * (-(-p.ow // p.stride) * p.stride) * p.oc  # padded ow
    # Same traffic everywhere except the store width: 3 extra bytes/elem
    # (oc padding may add more; at these shapes oc tiles exactly).
    assert e_raw.hbm_bytes - e_req.hbm_bytes == 3 * out_elems
    # Default models the paper's precision (requantizing int8).
    assert perf_model.mm2im_estimate(p, bits=8).hbm_bytes == e_req.hbm_bytes
    # f32 ignores the knob (always a 4-byte store).
    assert (perf_model.mm2im_estimate(p, bits=32, requant=False).hbm_bytes
            == perf_model.mm2im_estimate(p, bits=32).hbm_bytes)


def test_t_compute_is_tile_quantized():
    """t_compute counts whole 128^3 MXU tiles, not raw MACs."""
    p = PROBLEMS[0]
    e = perf_model.mm2im_estimate(p, bits=8)
    mxu = perf_model.V5E.mxu_dim
    assert e.issued_macs % mxu**3 == 0
    # A starved M-dimension issues more tile-MACs than the dense count.
    raw = p.macs
    assert e.issued_macs > raw
    assert 0.0 < e.mxu_utilization <= 1.0


def test_fold_batch_raises_mxu_utilization():
    """Folding a small-spatial batch into M must cut issued tiles (and so
    raise utilization) on the paper's GAN layers; memory traffic does not
    grow."""
    dcgan1 = TConvProblem(4, 4, 1024, 5, 512, 2)
    grid = perf_model.mm2im_estimate(dcgan1, 8, bits=8)
    fold = perf_model.mm2im_estimate(dcgan1, 8, bits=8, fold_batch=True)
    assert fold.issued_macs < grid.issued_macs
    assert fold.mxu_utilization > grid.mxu_utilization
    assert fold.effectual_macs == grid.effectual_macs
    assert fold.t_compute < grid.t_compute
    assert fold.hbm_bytes <= grid.hbm_bytes
    # Same holds for the double-buffered pipeline's estimate.
    gdb = perf_model.mm2im_db_estimate(dcgan1, 8, bits=8)
    fdb = perf_model.mm2im_db_estimate(dcgan1, 8, bits=8, fold_batch=True)
    assert fdb.t_compute < gdb.t_compute


def test_modeled_speedup_threads_the_winning_plan():
    """Regression: modeled_speedup hardcoded heuristic single-buffered
    mm2im vs the baseline, silently ignoring the plan that actually won —
    fold_batch / method / explicit blocks must thread through both sides
    of the ratio."""
    from repro.kernels.registry import Plan

    dcgan1 = TConvProblem(4, 4, 1024, 5, 512, 2)
    base = perf_model.modeled_speedup(dcgan1, 8, bits=8)
    folded = Plan(8, 512, "bcj", "mm2im", fold_batch=True)
    threaded = perf_model.modeled_speedup(dcgan1, 8, bits=8, plan=folded)
    # Folding cuts issued tiles on this shape (test above), so the modeled
    # speedup over the unfused baseline must grow when the plan is folded.
    assert threaded > base
    # The ratio is exactly baseline / plan-threaded estimate.
    t_b = perf_model.iom_unfused_estimate(dcgan1, 8, bits=8).t_overlapped
    t_m = perf_model.mm2im_estimate(
        dcgan1, 8, bits=8, block_oh=8, block_oc=512, grid_order="bcj",
        fold_batch=True).t_overlapped
    assert threaded == pytest.approx(t_b / t_m)
    # method= on the plan selects the double-buffered estimator.
    db = Plan(4, 512, "bcj", "mm2im_db")
    t_db = perf_model.mm2im_db_estimate(
        dcgan1, 8, bits=8, block_oh=4, block_oc=512,
        grid_order="bcj").t_overlapped
    assert perf_model.modeled_speedup(dcgan1, 8, bits=8, plan=db) \
        == pytest.approx(t_b / t_db)
    # baseline_plan threads the other side of the ratio too.
    self_vs_self = perf_model.modeled_speedup(
        dcgan1, 8, bits=8, baseline="mm2im", plan=folded,
        baseline_plan=folded)
    assert self_vs_self == pytest.approx(1.0)


def test_estimate_for_plan_populates_fit_terms():
    """The raw cost terms core/model_fit regresses against must be
    populated and geometry-sensitive for every estimator."""
    from repro.kernels.registry import Plan

    p = PROBLEMS[0]
    e = perf_model.estimate_for_plan(p, 4, plan=Plan(8, 32, "bcj", "mm2im"))
    assert e.n_launches > 0 and e.issued_tiles > 0
    assert e.issued_macs == e.issued_tiles * perf_model.V5E.mxu_dim ** 3
    folded = perf_model.estimate_for_plan(
        p, 4, plan=Plan(8, 32, "bcj", "mm2im", fold_batch=True))
    assert folded.n_launches == e.n_launches // 4
    assert folded.fill_bytes >= e.fill_bytes
    # Baseline estimators fill the terms too (the fit's '*' regime).
    for m in ("iom_unfused", "zero_insertion", "tdc"):
        b = perf_model.estimate_for_plan(p, 2, method=m)
        assert b.n_launches > 0
    # Unknown methods degrade to the single-buffered estimate.
    unk = perf_model.estimate_for_plan(p, 1, method="exotic")
    assert unk.t_overlapped == pytest.approx(
        perf_model.mm2im_estimate(p, 1, bits=8).t_overlapped)


def test_mxu_tiles_quantization():
    mxu = perf_model.V5E.mxu_dim
    assert perf_model.mxu_tiles(1, 1, 1, mxu) == 1
    assert perf_model.mxu_tiles(mxu, mxu, mxu, mxu) == 1
    assert perf_model.mxu_tiles(mxu + 1, mxu, mxu, mxu) == 2
    assert perf_model.mxu_tiles(24, 800, 64, 128) == 1 * 7 * 1
