"""Perf-model validation (§V-F analogue): model vs XLA cost_analysis."""

import jax
import jax.numpy as jnp
import pytest

from repro.core import perf_model
from repro.core.maps import TConvProblem, drop_stats
from repro.kernels import ref
from repro.kernels.baselines import zero_insertion_macs

PROBLEMS = [TConvProblem(8, 8, 64, 5, 32, 2), TConvProblem(16, 16, 32, 3, 16, 1)]


def _xla_flops(fn, *args):
    ca = jax.jit(fn).lower(*args).compile().cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    return float(ca.get("flops", 0.0))


@pytest.mark.parametrize("p", PROBLEMS, ids=str)
def test_unfused_iom_flops_within_10pct(p):
    x = jnp.zeros((1, p.ih, p.iw, p.ic), jnp.float32)
    w = jnp.zeros((p.ks, p.ks, p.oc, p.ic), jnp.float32)
    got = _xla_flops(lambda a, b: ref.iom_reference(a, b, stride=p.stride), x, w)
    assert abs(got - 2 * p.macs) / (2 * p.macs) < 0.10


@pytest.mark.parametrize("p", PROBLEMS, ids=str)
def test_zero_insertion_flops_within_tolerance(p):
    """XLA's conv cost model excludes border padding taps; our model uses
    the dense Oh*Ow*Ks^2 count (the paper's convention).  For small images
    the border fraction ~ 2*(Ks-1)/Oh — allow for it explicitly."""
    x = jnp.zeros((1, p.ih, p.iw, p.ic), jnp.float32)
    w = jnp.zeros((p.ks, p.ks, p.oc, p.ic), jnp.float32)
    got = _xla_flops(lambda a, b: ref.tconv_direct(a, b, stride=p.stride), x, w)
    want = 2 * zero_insertion_macs(p.ih, p.iw, p.ic, p.ks, p.oc, p.stride)
    border = 2.0 * (p.ks - 1) / (p.stride * p.ih)
    assert abs(got - want) / want < 0.10 + border


def test_estimates_ordering_sane():
    """Fused MM2IM must never be slower than the unfused IOM baseline."""
    for p in PROBLEMS + [TConvProblem(4, 4, 1024, 5, 512, 2)]:
        t_m = perf_model.mm2im_estimate(p, bits=8).t_overlapped
        t_u = perf_model.iom_unfused_estimate(p, bits=8).t_overlapped
        assert t_m <= t_u * 1.05


def test_mxu_utilization_bounds():
    for p in PROBLEMS:
        e = perf_model.mm2im_estimate(p, bits=8)
        assert 0.0 < e.mxu_utilization <= 1.0
        assert e.effectual_macs == drop_stats(p)["effectual_macs"]


def test_modeled_speedup_positive():
    for p in PROBLEMS:
        assert perf_model.modeled_speedup(p) > 0.5
