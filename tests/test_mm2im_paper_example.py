"""Paper-claim anchors: the exact numbers from the paper's own text."""

import numpy as np

from repro.configs.paper_models import TABLE_II, synthetic_sweep
from repro.core.maps import TConvProblem, drop_stats, i_end_row, spatial_maps


def test_fig2_example_numbers():
    """tconv(2,2,2,3,2,1): D_r=0.55 (40/72), P/F=2.25, 9x with skip."""
    p = TConvProblem(2, 2, 2, 3, 2, 1)
    st = drop_stats(p)
    assert st["D_o"] == 40
    assert st["P_outs"] == 72
    assert st["F_outs"] == 32
    assert abs(st["D_r"] - 0.555) < 0.01          # paper: 0.55
    assert abs(st["buffer_saving_no_skip"] - 2.25) < 1e-9
    assert abs(st["buffer_saving_with_skip"] - 9.0) < 1e-9


def test_dcgan_ineffectual_fraction():
    """§II-A: 'up to 28% for DCGAN' ineffectual computations."""
    worst = max(drop_stats(r.problem)["D_r"] for r in TABLE_II
                if r.name.startswith("DCGAN"))
    assert 0.25 < worst < 0.30


def test_zero_insertion_overhead_75pct():
    """§II-A: zero-insertion ~75% overhead (stride 2: 3/4 of taps hit zeros)."""
    from repro.kernels.baselines import zero_insertion_macs
    p = TConvProblem(16, 16, 64, 5, 32, 2)
    dense = zero_insertion_macs(p.ih, p.iw, p.ic, p.ks, p.oc, p.stride)
    useful = drop_stats(p)["effectual_macs"]
    waste = 1 - useful / dense
    assert 0.65 < waste < 0.85


def test_sweep_is_261_configs():
    assert len(synthetic_sweep()) == 261


def test_table_ii_ops_match_paper():
    """OPs column: 2*M*N*K must reproduce the paper's numbers (±1%)."""
    paper = {"DCGAN_1": 420e6, "DCGAN_2": 420e6, "DCGAN_3": 420e6,
             "DCGAN_4": 20e6, "FCN": 14e3, "StyleTransfer_1": 604e6,
             "StyleTransfer_2": 604e6, "StyleTransfer_3": 1020e6,
             "FSRCNN": 11e6}
    for row in TABLE_II:
        got = row.problem.ops
        want = paper[row.name]
        assert abs(got - want) / want < 0.05, (row.name, got, want)


def test_omap_covers_all_outputs():
    """Every final output index receives >= 1 partial product."""
    for p in [TConvProblem(4, 4, 8, 5, 4, 2), TConvProblem(7, 7, 4, 3, 2, 1)]:
        omap, cmap = spatial_maps(p)
        got = np.unique(omap[omap >= 0])
        assert len(got) == p.oh * p.ow


def test_i_end_row_monotone_and_bounded():
    p = TConvProblem(9, 9, 4, 5, 4, 2)
    rows = i_end_row(p)
    assert (np.diff(rows) >= 0).all()
    assert rows[-1] == p.ih - 1
