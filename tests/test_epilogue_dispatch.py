"""Epilogue-typed unified dispatch: the ISSUE-4 acceptance surface.

Covers the one-pipeline contract of ``kernels/ops.py``:

* the gold itself — 'lax' f32 equals the hand-applied oracle epilogue,
  and the int8 'lax' fallback equals the hand-written PPU reference (the
  cross-method parity matrix that used to live here moved to
  ``tests/test_parity_matrix.py`` / ``tests/parity.py``, which enrolls
  every registered method automatically);
* the dequant -> compute -> requant fallback that makes every method
  (including unregistered-yesterday baselines and third-party plugins)
  quantization-capable with zero wiring;
* ``tconv_int8`` bit-identity with the direct Pallas kernel invocation
  (the pre-refactor implementation) for the committed ``cpu.json`` plan
  keys;
* the shared jit'd dispatcher's static-argname discipline (repeated
  ``tconv_int8`` calls on one shape compile exactly once — the op used to
  retrace the Pallas kernel from Python on every call);
* the :class:`~repro.core.epilogue.Epilogue` value type itself: stage
  split (prefix rule, requant tail rule), the promoted activation table
  and the single leaky-relu slope constant;
* ``autotune.KERNEL_RUNNERS`` is gone — int8 measurement and variant
  upgrade go through the registry only.
"""

import re

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import epilogue as epi
from repro.core.epilogue import Epilogue
from repro.kernels import ref, registry
from repro.kernels.ops import (dispatch_trace_count, run_registered, tconv,
                               tconv_int8)
from repro.kernels.registry import Plan

RNG = np.random.default_rng(21)

ACTS = ("none", "relu", "tanh", "leaky_relu")

# One small problem for the whole matrix: Ic*Ks^2 * 127^2 ~ 0.6M << 2^24,
# so the f32 fallback accumulation of int8 products is exact and the int8
# column can assert bitwise equality across methods.
IH, IW, IC, KS, OC, S = 5, 5, 4, 3, 4, 2


def _f32_operands():
    x = RNG.standard_normal((1, IH, IW, IC)).astype(np.float32)
    w = (RNG.standard_normal((KS, KS, OC, IC)) * 0.1).astype(np.float32)
    b = RNG.standard_normal(OC).astype(np.float32)
    return x, w, b


def _int8_operands():
    x = RNG.integers(-128, 128, (1, IH, IW, IC)).astype(np.int8)
    w = RNG.integers(-128, 128, (KS, KS, OC, IC)).astype(np.int8)
    b = RNG.integers(-500, 500, OC).astype(np.int32)
    return x, w, b


# ---------------------------------------------------------------------------
# The gold itself (cross-method parity lives in test_parity_matrix.py)
# ---------------------------------------------------------------------------


def test_f32_gold_is_really_lax():
    """The 'lax' column itself equals the hand-applied oracle epilogue."""
    x, w, b = _f32_operands()
    for act in ACTS:
        got = np.asarray(tconv(x, w, b, stride=S, method="lax",
                               activation=act))
        want = np.asarray(epi.ACTIVATIONS[act](
            jnp.asarray(ref.tconv_lax(x, w, stride=S)) + b))
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5,
                                   err_msg=act)


def test_int8_gold_matches_manual_ppu():
    """The int8 'lax' fallback equals the hand-written PPU reference:
    int32 accum -> bias -> requant round/clip -> activation -> int8."""
    xq, wq, bq = _int8_operands()
    scale = 0.004
    acc = np.asarray(ref.iom_reference_int8(xq, wq, bq, stride=S))
    for act in ACTS:
        want = np.clip(np.round(acc.astype(np.float32) * scale), -128, 127)
        want = np.asarray(epi.ACTIVATIONS[act](want))
        want = np.round(want).astype(np.int8)
        got = np.asarray(tconv_int8(xq, wq, bq, scale, stride=S, method="lax",
                                    activation=act))
        assert (got == want).all(), act


def test_int8_fallback_per_channel():
    """Per-channel requant also rides the fallback (traced scales)."""
    xq, wq, bq = _int8_operands()
    scales = RNG.uniform(1e-3, 6e-3, OC).astype(np.float32)
    got = np.asarray(tconv_int8(xq, wq, bq, scales, stride=S,
                                method="zero_insertion"))
    want = np.asarray(tconv_int8(xq, wq, bq, scales, stride=S,
                                 method="mm2im"))
    assert got.dtype == np.int8
    assert (got == want).all()


def test_third_party_variant_is_int8_capable_with_zero_wiring():
    """A plugin registered without supports_int8 serves tconv_int8 via the
    fallback, and measure_plan times it through the registry — no runner
    table, no extra wiring anywhere."""
    from repro.core.autotune import measure_plan
    from repro.core.maps import TConvProblem

    @registry.register("direct_plugin", supports_plan=True,
                       description="ref.tconv_direct as a plugin")
    def _direct(x, w, *, stride, padding, epilogue, plan):
        return ref.tconv_direct(x, w, stride=stride, padding=padding)

    try:
        xq, wq, bq = _int8_operands()
        got = np.asarray(tconv_int8(xq, wq, bq, 0.004, stride=S,
                                    method="direct_plugin"))
        want = np.asarray(tconv_int8(xq, wq, bq, 0.004, stride=S))
        assert got.dtype == np.int8 and (got == want).all()
        # Autotunable in both precisions straight off the registry.
        p = TConvProblem(IH, IW, IC, KS, OC, S)
        for dtype in (jnp.float32, jnp.int8):
            us = measure_plan(p, Plan(S, OC, "bcj", "direct_plugin"),
                              dtype=dtype, repeats=1, warmup=1)
            assert us > 0
    finally:
        assert registry.unregister("direct_plugin") is not None


# ---------------------------------------------------------------------------
# tconv_int8 bit-identity with the direct kernel (pre-refactor path)
# ---------------------------------------------------------------------------

_KEY_RE = re.compile(
    r"tconv:ih(\d+):iw(\d+):ic(\d+):ks(\d+):oc(\d+):s(\d+):(\w+)\|int8\|")


def test_tconv_int8_bit_identical_for_shipped_plan_keys():
    """For committed cpu.json int8 plan keys, the unified dispatcher's
    output is bit-identical to invoking the Pallas kernel directly with
    the plan's geometry — the pre-refactor ``tconv_int8`` implementation.
    """
    from repro.core import plan_table
    from repro.kernels.mm2im_db_pallas import mm2im_db_tconv
    from repro.kernels.mm2im_ks_pallas import mm2im_ks_tconv
    from repro.kernels.mm2im_pallas import mm2im_tconv

    table = plan_table.load_table("cpu", strict=True)
    keys = [k for k in table.keys() if "|int8|" in k and "|b1" in k]
    assert keys, "committed cpu.json lost its int8 coverage"
    checked = 0
    for key in keys:
        m = _KEY_RE.match(key)
        assert m, key
        ih, iw, ic, ks, oc, s = (int(g) for g in m.groups()[:6])
        padding = m.group(7)
        if ih * iw * ic > 7 * 9 * 64 or checked >= 3:
            continue  # keep the interpret-mode cost bounded
        plan = table.get(key)
        import zlib
        rng = np.random.default_rng(zlib.crc32(key.encode()))
        xq = rng.integers(-128, 128, (1, ih, iw, ic)).astype(np.int8)
        wq = rng.integers(-128, 128, (ks, ks, oc, ic)).astype(np.int8)
        bq = rng.integers(-500, 500, oc).astype(np.int32)
        got = np.asarray(tconv_int8(xq, wq, bq, 0.003, stride=s,
                                    padding=padding, plan=plan))
        kernel = {"mm2im": mm2im_tconv,
                  "mm2im_db": mm2im_db_tconv,
                  "mm2im_ks": mm2im_ks_tconv}[plan.method or "mm2im"]
        want = np.asarray(kernel(
            jnp.asarray(xq), jnp.asarray(wq), jnp.asarray(bq), stride=s,
            padding=padding, out_scale=0.003, block_oh=plan.block_oh,
            block_oc=plan.block_oc, grid_order=plan.grid_order))
        assert (got == want).all() and got.dtype == want.dtype, key
        checked += 1
    assert checked >= 2, "shipped table had no small int8 keys to check"


# ---------------------------------------------------------------------------
# jit / retrace discipline
# ---------------------------------------------------------------------------


def test_tconv_int8_compiles_once_per_shape():
    """Repeated tconv_int8 calls on one (shape, scale, static-args) key
    must not retrace the Pallas kernel (regression: the old entry point
    was plain Python and re-staged every call)."""
    # Unique shapes so earlier tests' jit entries cannot mask a retrace.
    xq = RNG.integers(-128, 128, (1, 3, 7, 2)).astype(np.int8)
    wq = RNG.integers(-128, 128, (3, 3, 5, 2)).astype(np.int8)
    bq = RNG.integers(-100, 100, 5).astype(np.int32)
    c0 = dispatch_trace_count()
    first = np.asarray(tconv_int8(xq, wq, bq, 0.02, stride=2))
    c1 = dispatch_trace_count()
    assert c1 == c0 + 1, "first call must trace exactly once"
    for _ in range(3):
        again = np.asarray(tconv_int8(xq, wq, bq, 0.02, stride=2))
        assert (again == first).all()
    assert dispatch_trace_count() == c1, "steady-state calls retraced"
    # A different per-tensor scale is a *static* epilogue knob -> retrace.
    tconv_int8(xq, wq, bq, 0.03, stride=2)
    assert dispatch_trace_count() == c1 + 1
    # tconv shares the same dispatcher and the same discipline.
    x = RNG.standard_normal((1, 3, 7, 2)).astype(np.float32)
    w = (RNG.standard_normal((3, 3, 5, 2)) * 0.1).astype(np.float32)
    c2 = dispatch_trace_count()
    tconv(x, w, stride=2)
    tconv(x, w, stride=2)
    assert dispatch_trace_count() == c2 + 1


# ---------------------------------------------------------------------------
# Epilogue value type
# ---------------------------------------------------------------------------


def test_epilogue_split_prefix_rule():
    b = np.ones(4, np.float32)
    ep = Epilogue(bias=b, activation="relu")
    # Fusing only the activation may not reorder it before the bias add.
    k, r = ep.split(frozenset({"activation"}))
    assert k.is_noop and r.activation == "relu" and r.bias is not None
    # Fusing the bias keeps it in-kernel, activation goes to the remainder.
    k, r = ep.split(frozenset({"bias"}))
    assert k.bias is not None and k.activation == "none"
    assert r.bias is None and r.activation == "relu"
    # Full fusion: nothing remains.
    k, r = ep.split(frozenset({"bias", "activation"}))
    assert (k.bias is not None and k.activation == "relu" and r.is_noop)


def test_epilogue_split_requant_tail_rule():
    """Requant only fuses when the whole remaining tail does: an in-kernel
    int8 cast ahead of a dispatcher-side activation would quantize too
    early."""
    ep = Epilogue(bias=np.ones(4, np.int32), activation="relu",
                  out_scale=0.05, out_dtype=jnp.int8)
    k, r = ep.split(frozenset({"bias", "requant"}))  # activation unfused
    assert k.out_scale is None, "requant fused ahead of an unfused stage"
    assert r.out_scale == 0.05 and r.activation == "relu"
    assert r.out_dtype == jnp.dtype(jnp.int8) and k.out_dtype is None
    k, r = ep.split(frozenset({"bias", "requant", "activation"}))
    assert k.out_scale == 0.05 and r.is_noop
    assert k.out_dtype == jnp.dtype(jnp.int8)


def test_epilogue_resolved_out_dtype():
    assert Epilogue().resolved_out_dtype(integer=False) is None
    assert Epilogue().resolved_out_dtype(integer=True) == jnp.int32
    assert Epilogue(out_scale=0.1).resolved_out_dtype(integer=True) == jnp.int8
    assert (Epilogue(out_dtype=jnp.bfloat16).resolved_out_dtype(True)
            == jnp.bfloat16)


def test_epilogue_is_jit_static_aware_pytree():
    """Arrays are traced leaves; activation/scalar scale/dtype are treedef."""
    b = jnp.ones(4)
    leaves, treedef = jax.tree_util.tree_flatten(
        Epilogue(bias=b, activation="relu", out_scale=0.5))
    assert len(leaves) == 1 and leaves[0] is b
    rebuilt = jax.tree_util.tree_unflatten(treedef, leaves)
    assert rebuilt.activation == "relu" and rebuilt.out_scale == 0.5
    # Per-channel scales are leaves (traced), not treedef (static).
    scales = jnp.ones(4)
    leaves, _ = jax.tree_util.tree_flatten(Epilogue(out_scale=scales))
    assert any(leaf is scales for leaf in leaves)
    with pytest.raises(ValueError, match="activation"):
        Epilogue(activation="sigmoid?")


def test_leaky_relu_slope_single_constant():
    """Forward table and custom_vjp backward share the one slope constant
    (it used to be hardcoded 0.2 in two places)."""
    x = jnp.asarray([-2.0, 3.0])
    np.testing.assert_allclose(
        np.asarray(epi.ACTIVATIONS["leaky_relu"](x)),
        [-2.0 * epi.LEAKY_RELU_SLOPE, 3.0])
    g = np.asarray(epi.activation_grad_from_output(
        "leaky_relu", x, jnp.ones_like(x)))
    np.testing.assert_allclose(g, [epi.LEAKY_RELU_SLOPE, 1.0])
    # The kernel module's table *is* the shared one (promotion, not copy).
    from repro.kernels import mm2im_pallas
    assert mm2im_pallas._ACTIVATIONS is epi.ACTIVATIONS
    # And the end-to-end gradient uses the same slope.
    x1 = RNG.standard_normal((1, 4, 4, 2)).astype(np.float32)
    w1 = (RNG.standard_normal((3, 3, 2, 2)) * 0.1).astype(np.float32)
    dx = jax.grad(lambda xx: jnp.sum(
        tconv(xx, w1, stride=2, activation="leaky_relu")))(x1)
    out = tconv(x1, w1, stride=2)
    want = np.asarray(jax.grad(lambda xx: jnp.sum(
        ref.tconv_direct(xx, w1, stride=2)
        * jnp.where(ref.tconv_direct(x1, w1, stride=2) >= 0, 1.0,
                    epi.LEAKY_RELU_SLOPE)))(x1))
    np.testing.assert_allclose(np.asarray(dx), want, rtol=1e-3, atol=1e-3)
    del out


# ---------------------------------------------------------------------------
# KERNEL_RUNNERS is gone; run_registered is the measurement surface
# ---------------------------------------------------------------------------


def test_kernel_runners_table_removed():
    from repro.core import autotune

    assert not hasattr(autotune, "KERNEL_RUNNERS")


def test_run_registered_matches_dispatch():
    """run_registered (the autotuner's measurement entry) computes the
    same function dispatch serves, in both precisions."""
    x, w, b = _f32_operands()
    ep = Epilogue(bias=jnp.asarray(b), activation="relu")
    got = np.asarray(run_registered("mm2im", x, w, stride=S, padding="SAME",
                                    epilogue=ep, plan=Plan(S, OC)))
    want = np.asarray(tconv(x, w, b, stride=S, activation="relu",
                            plan=Plan(S, OC)))
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)

    xq, wq, bq = _int8_operands()
    ep8 = Epilogue(bias=jnp.asarray(bq), out_scale=0.004)
    got = np.asarray(run_registered("tdc", xq, wq, stride=S, padding="SAME",
                                    epilogue=ep8))
    want = np.asarray(tconv_int8(xq, wq, bq, 0.004, stride=S, method="tdc"))
    assert (got == want).all()
