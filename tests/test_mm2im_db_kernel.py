"""Double-buffered MM2IM kernel: bit-identity, int8 requant, dispatch.
(Cross-method int8/f32 parity lives in ``tests/test_parity_matrix.py``.)

The contract of ``kernels/mm2im_db_pallas.py`` is strict: *bit-identical*
to the single-buffered kernel for every geometry (the two share the host
staging and block math; only the slab transport differs), on both the
async-DMA pipeline and the synchronous interpret-safe fallback.  That is
what lets the autotuner choose between the variants on speed alone.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref, registry
from repro.kernels.mm2im_db_pallas import mm2im_db_tconv
from repro.kernels.mm2im_pallas import mm2im_tconv
from repro.kernels.ops import tconv, tconv_int8
from repro.kernels.registry import Plan

RNG = np.random.default_rng(11)


def rand_problem(ih, iw, ic, ks, oc, b=1):
    x = RNG.standard_normal((b, ih, iw, ic), np.float32)
    w = RNG.standard_normal((ks, ks, oc, ic), np.float32) * 0.1
    return x, w


SWEEP = [
    # (B, Ih, Iw, Ic, Ks, Oc, S, padding)
    (1, 2, 2, 2, 3, 2, 1, "SAME"),      # paper Fig. 2
    (2, 4, 4, 3, 5, 2, 2, "SAME"),
    (1, 9, 9, 16, 5, 8, 2, "SAME"),
    (2, 5, 6, 4, 4, 3, 2, "SAME"),      # rectangular, even kernel
    (1, 8, 8, 16, 9, 3, 1, "SAME"),     # StyleTransfer_3-like
    (1, 3, 3, 4, 3, 2, 1, "VALID"),
    (1, 4, 5, 4, 5, 3, 2, "VALID"),
    (1, 5, 5, 4, 3, 2, 3, "VALID"),     # Ks < S (gapped output)
    (1, 6, 6, 4, 2, 3, 2, "SAME"),      # Ks == S (no crop)
]


@pytest.mark.parametrize("pipeline", ["async", "sync"])
@pytest.mark.parametrize("case", SWEEP, ids=[str(c) for c in SWEEP])
def test_db_bit_identical_to_sb(case, pipeline):
    """db == sb bitwise across strides/paddings, async and sync pipelines."""
    b, ih, iw, ic, ks, oc, s, pad = case
    x, w = rand_problem(ih, iw, ic, ks, oc, b)
    got = np.asarray(mm2im_db_tconv(x, w, stride=s, padding=pad,
                                    interpret=True, pipeline=pipeline))
    want_sb = np.asarray(mm2im_tconv(x, w, stride=s, padding=pad,
                                     interpret=True))
    assert (got == want_sb).all(), (case, pipeline)
    # And both agree with the unfused-IOM oracle.
    want = np.asarray(ref.iom_reference(x, w, stride=s, padding=pad))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("block_oh,block_oc,grid_order",
                         [(2, 4, "bcj"), (4, 8, "cbj"), (8, 16, "bcj"),
                          (2, 3, "cbj")])
def test_db_block_and_grid_invariance(block_oh, block_oc, grid_order):
    x, w = rand_problem(8, 8, 16, 5, 12, b=2)
    got = np.asarray(mm2im_db_tconv(x, w, stride=2, block_oh=block_oh,
                                    block_oc=block_oc, grid_order=grid_order,
                                    interpret=True))
    want = np.asarray(mm2im_tconv(x, w, stride=2, block_oh=block_oh,
                                  block_oc=block_oc, grid_order=grid_order,
                                  interpret=True))
    assert (got == want).all()


def test_int8_requant_through_db_plan():
    """tconv_int8 honors a plan pinning the double-buffered variant and
    still requantizes bit-exactly (int8 out)."""
    rng = np.random.default_rng(4)
    xq = rng.integers(-128, 128, (1, 6, 6, 8), dtype=np.int8)
    wq = rng.integers(-128, 128, (3, 3, 4, 8), dtype=np.int8)
    bq = rng.integers(-500, 500, (4,), dtype=np.int32)
    plan = Plan(4, 4, "bcj", "mm2im_db")
    got = np.asarray(tconv_int8(xq, wq, bq, 0.003, stride=2, plan=plan))
    acc = ref.iom_reference_int8(xq, wq, bq, stride=2)
    want = np.asarray(ref.requantize(acc, 0.003))
    assert (got == want).all()
    assert got.dtype == np.int8


def test_db_fused_epilogue():
    x, w = rand_problem(4, 4, 8, 3, 4)
    b = RNG.standard_normal(4).astype(np.float32)
    got = np.asarray(mm2im_db_tconv(x, w, jnp.asarray(b), stride=2,
                                    activation="relu", interpret=True))
    want = np.maximum(np.asarray(ref.tconv_lax(x, w, stride=2)) + b, 0)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_db_registered_and_plan_dispatch():
    """'mm2im_db' is a registered plan-capable method, and a Plan carrying
    method='mm2im_db' upgrades default dispatch to it."""
    assert "mm2im_db" in registry.names()
    spec = registry.get("mm2im_db")
    assert spec.supports_plan and spec.fuses_bias and spec.fuses_activation

    x, w = rand_problem(6, 6, 8, 5, 6)
    want = np.asarray(tconv(x, w, stride=2, method="mm2im"))
    # Explicit method request.
    got = np.asarray(tconv(x, w, stride=2, method="mm2im_db"))
    assert (got == want).all()
    # Variant selection via the plan (default method stays 'mm2im').
    got = np.asarray(tconv(x, w, stride=2,
                           plan=Plan(2, 6, "bcj", "mm2im_db")))
    want_geom = np.asarray(tconv(x, w, stride=2, plan=Plan(2, 6, "bcj")))
    np.testing.assert_allclose(got, want_geom, rtol=1e-4, atol=1e-4)


def test_db_gradients_match_reference():
    """Training runs through the db variant too (custom_vjp)."""
    x, w = rand_problem(5, 5, 6, 3, 4)
    b = np.zeros((4,), np.float32)

    def loss_kernel(xx, ww, bb):
        return jnp.sum(tconv(xx, ww, bb, stride=2, method="mm2im_db") ** 2)

    def loss_ref(xx, ww, bb):
        y = ref.tconv_direct(xx, ww, stride=2) + bb[None, None, None]
        return jnp.sum(y ** 2)

    g1 = jax.grad(loss_kernel, argnums=(0, 1, 2))(x, w, b)
    g2 = jax.grad(loss_ref, argnums=(0, 1, 2))(x, w, b)
    for a, c in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(c),
                                   rtol=1e-3, atol=1e-3)


def test_db_bad_pipeline_rejected():
    x, w = rand_problem(4, 4, 2, 3, 2)
    with pytest.raises(ValueError, match="pipeline"):
        mm2im_db_tconv(x, w, stride=2, interpret=True, pipeline="bogus")
