import os
import sys

# Tests run single-device (the dry-run sets its own 512-device flag in a
# subprocess); make sure src/ is importable regardless of cwd, and the tests
# directory itself so the shared `_hypothesis_shim` (optional-hypothesis
# fallback) resolves under any pytest import mode.
_HERE = os.path.dirname(__file__)
sys.path.insert(0, os.path.join(_HERE, "..", "src"))
if _HERE not in sys.path:
    sys.path.insert(0, _HERE)

import jax  # noqa: E402

jax.config.update("jax_platform_name", "cpu")
