import os
import sys

# Tests run single-device (the dry-run sets its own 512-device flag in a
# subprocess); make sure src/ is importable regardless of cwd.
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax  # noqa: E402

jax.config.update("jax_platform_name", "cpu")
