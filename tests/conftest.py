import os
import sys

# Tests run single-device (the dry-run sets its own 512-device flag in a
# subprocess); make sure src/ is importable regardless of cwd, and the tests
# directory itself so the shared `_hypothesis_shim` (optional-hypothesis
# fallback) resolves under any pytest import mode.
_HERE = os.path.dirname(__file__)
sys.path.insert(0, os.path.join(_HERE, "..", "src"))
if _HERE not in sys.path:
    sys.path.insert(0, _HERE)

import jax  # noqa: E402
import pytest  # noqa: E402

jax.config.update("jax_platform_name", "cpu")


@pytest.fixture(autouse=True, scope="module")
def _release_jax_executables():
    """Drop JAX's jit/pjit caches after every test module.

    XLA:CPU JIT-compiles every distinct (shape, method) executable into the
    one test process and never releases them while the Python-side caches
    hold references.  Past roughly 350 tests the accumulated LLVM JIT state
    segfaults inside ``backend_compile`` (reproducibly at the same test,
    while any half of the suite passes alone), so cap residency at one
    module's worth of executables.  Costs some cross-module recompilation;
    keeps the single-process tier-1 run viable as the suite grows.
    """
    yield
    jax.clear_caches()
