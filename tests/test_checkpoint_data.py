"""Checkpoint manager + data pipeline: atomicity, resume, determinism."""

import json
import pathlib

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.data.pipeline import DataConfig, Prefetcher, make_batch


def _state(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"a": jax.random.normal(k, (4, 8)),
            "nested": {"b": jnp.arange(6, dtype=jnp.int32)}}


def test_save_restore_roundtrip(tmp_path):
    ck = CheckpointManager(tmp_path)
    s = _state()
    ck.save(10, s, block=True)
    assert ck.latest_step() == 10
    got = ck.restore(10, s)
    for a, b in zip(jax.tree.leaves(s), jax.tree.leaves(got)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_atomicity_no_tmp_left_and_latest_valid(tmp_path):
    ck = CheckpointManager(tmp_path, keep=2)
    for step in (1, 2, 3):
        ck.save(step, _state(step), block=True)
    assert not list(pathlib.Path(tmp_path).glob("*.tmp"))
    assert ck.latest_step() == 3
    assert sorted(ck.all_steps()) == [2, 3]  # retention


def test_async_save_overlaps(tmp_path):
    ck = CheckpointManager(tmp_path)
    ck.save(5, _state())          # async
    ck.save(6, _state(), block=True)  # waits for 5 then writes 6
    assert set(ck.all_steps()) >= {6}


def test_manifest_records_specs(tmp_path):
    from jax.sharding import PartitionSpec as P
    ck = CheckpointManager(tmp_path)
    s = {"w": jnp.zeros((4, 4))}
    ck.save(1, s, specs={"w": P("data", "model")}, block=True)
    man = json.loads((pathlib.Path(tmp_path) / "step_00000001" /
                      "manifest.json").read_text())
    assert man["leaves"][0]["spec"] == ["data", "model"]


def test_elastic_restore_onto_mesh(tmp_path):
    """Restore a checkpoint onto a (1,1) mesh with specs — the elastic path."""
    from jax.sharding import PartitionSpec as P
    mesh = jax.make_mesh((1, 1), ("data", "model"))
    ck = CheckpointManager(tmp_path)
    s = {"w": jnp.arange(16.0).reshape(4, 4)}
    ck.save(1, s, specs={"w": P("data", "model")}, block=True)
    got = ck.restore(1, s, mesh=mesh, specs={"w": P("data", "model")})
    np.testing.assert_array_equal(np.asarray(got["w"]), np.asarray(s["w"]))
    assert got["w"].sharding.spec == P("data", "model")


def test_data_pure_function_of_step():
    cfg = DataConfig(vocab=100, seq_len=16, global_batch=4, seed=3)
    b1 = make_batch(cfg, 7)
    b2 = make_batch(cfg, 7)
    b3 = make_batch(cfg, 8)
    np.testing.assert_array_equal(np.asarray(b1["tokens"]), np.asarray(b2["tokens"]))
    assert not np.array_equal(np.asarray(b1["tokens"]), np.asarray(b3["tokens"]))


def test_data_skip_ahead_equivalence():
    """Restarting at step k yields the same stream as never stopping."""
    cfg = DataConfig(vocab=50, seq_len=8, global_batch=2)
    run1 = [np.asarray(make_batch(cfg, s)["tokens"]) for s in range(6)]
    run2 = [np.asarray(make_batch(cfg, s)["tokens"]) for s in range(3, 6)]
    for a, b in zip(run1[3:], run2):
        np.testing.assert_array_equal(a, b)


def test_prefetcher_yields_ordered_steps():
    cfg = DataConfig(vocab=50, seq_len=8, global_batch=2)
    pf = Prefetcher(cfg, start_step=5, depth=2)
    steps = [next(pf)[0] for _ in range(4)]
    pf.close()
    assert steps == [5, 6, 7, 8]
