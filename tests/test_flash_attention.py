"""Flash-attention Pallas kernel vs oracles (interpret mode).

Sweeps GQA ratios, causal/window, ragged lengths and block shapes, plus a
hypothesis property sweep; also asserts the model-level attend_flash path
matches attend exactly.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_shim import given, settings, st

from repro.kernels.flash_attention import flash_attention
from repro.layers import attention as att

RNG = np.random.default_rng(7)


def _ref(q, k, v, causal=True, window=None):
    b, lq, h, hd = q.shape
    hkv = k.shape[2]
    qg = q.reshape(b, lq, hkv, h // hkv, hd)
    sc = np.einsum("blgrd,bmgd->bgrlm", qg, k) / np.sqrt(hd)
    i = np.arange(lq)[:, None]
    j = np.arange(k.shape[1])[None, :]
    m = np.ones((lq, k.shape[1]), bool)
    if causal:
        m &= j <= i
    if window is not None:
        m &= j > i - window
    sc = np.where(m[None, None, None], sc, -2e38)
    p = np.exp(sc - sc.max(-1, keepdims=True))
    p = p / p.sum(-1, keepdims=True)
    return np.einsum("bgrlm,bmgd->blgrd", p, v).reshape(b, lq, h, hd)


CASES = [
    # (B, L, H, Hkv, hd, causal, window, bq, bk)
    (1, 64, 4, 2, 16, True, None, 16, 16),
    (2, 100, 8, 2, 32, True, None, 32, 16),   # ragged L
    (1, 128, 4, 4, 16, False, None, 32, 32),  # MHA, bidirectional
    (1, 96, 4, 1, 16, True, 24, 16, 16),      # MQA + window
    (1, 257, 6, 2, 8, True, None, 64, 32),    # odd length
    (1, 64, 2, 2, 64, True, None, 64, 64),    # single block pair
]


@pytest.mark.parametrize("case", CASES, ids=[str(c) for c in CASES])
def test_flash_vs_reference(case):
    b, l, h, kv, hd, causal, win, bq, bk = case
    q = RNG.standard_normal((b, l, h, hd), np.float32) * 0.3
    k = RNG.standard_normal((b, l, kv, hd), np.float32) * 0.3
    v = RNG.standard_normal((b, l, kv, hd), np.float32) * 0.3
    got = np.asarray(flash_attention(q, k, v, causal=causal, window=win,
                                     block_q=bq, block_k=bk, interpret=True))
    np.testing.assert_allclose(got, _ref(q, k, v, causal, win),
                               rtol=2e-5, atol=2e-5)


@settings(max_examples=10, deadline=None)
@given(l=st.integers(8, 80), h=st.sampled_from([2, 4]),
       kv=st.sampled_from([1, 2]), causal=st.booleans(),
       bq=st.sampled_from([8, 16, 32]), bk=st.sampled_from([8, 16]))
def test_flash_property(l, h, kv, causal, bq, bk):
    if h % kv:
        return
    q = RNG.standard_normal((1, l, h, 8), np.float32) * 0.3
    k = RNG.standard_normal((1, l, kv, 8), np.float32) * 0.3
    v = RNG.standard_normal((1, l, kv, 8), np.float32) * 0.3
    got = np.asarray(flash_attention(q, k, v, causal=causal, block_q=bq,
                                     block_k=bk, interpret=True))
    np.testing.assert_allclose(got, _ref(q, k, v, causal), rtol=3e-5, atol=3e-5)


def test_attend_flash_matches_attend():
    d, heads, kvh = 32, 4, 2
    p, _ = att.init_attention(jax.random.PRNGKey(0), d, heads, kvh,
                              qk_norm=True)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 48, d)) * 0.5
    y1 = att.attend(p, x, n_heads=heads, kv_heads=kvh)
    y2 = att.attend_flash(p, x, n_heads=heads, kv_heads=kvh,
                          block_q=16, block_k=16)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2),
                               rtol=2e-4, atol=2e-4)


def test_flash_bf16():
    q = (RNG.standard_normal((1, 64, 4, 16)) * 0.3).astype(jnp.bfloat16)
    k = (RNG.standard_normal((1, 64, 2, 16)) * 0.3).astype(jnp.bfloat16)
    v = (RNG.standard_normal((1, 64, 2, 16)) * 0.3).astype(jnp.bfloat16)
    got = flash_attention(q, k, v, block_q=16, block_k=16, interpret=True)
    want = _ref(np.asarray(q, np.float32), np.asarray(k, np.float32),
                np.asarray(v, np.float32))
    np.testing.assert_allclose(np.asarray(got, np.float32), want,
                               rtol=3e-2, atol=3e-2)
    assert got.dtype == jnp.bfloat16
