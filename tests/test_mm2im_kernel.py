"""Per-kernel validation: MM2IM Pallas (interpret=True) vs pure-jnp oracles.

Sweeps shapes / strides / paddings / dtypes / block sizes / grid orders and
asserts allclose against ref.py; hypothesis drives randomized geometry.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_shim import given, settings, st

from repro.kernels import ref
from repro.kernels.mm2im_pallas import mm2im_tconv, plan_blocks
from repro.kernels.ops import tconv, tconv_int8

RNG = np.random.default_rng(0)


def rand_problem(ih, iw, ic, ks, oc, b=1):
    x = RNG.standard_normal((b, ih, iw, ic), np.float32)
    w = RNG.standard_normal((ks, ks, oc, ic), np.float32) * 0.1
    return x, w


SWEEP = [
    # (B, Ih, Iw, Ic, Ks, Oc, S, padding)
    (1, 2, 2, 2, 3, 2, 1, "SAME"),      # paper Fig. 2
    (2, 4, 4, 3, 5, 2, 2, "SAME"),
    (1, 7, 7, 32, 3, 16, 1, "SAME"),
    (1, 9, 9, 16, 5, 8, 2, "SAME"),
    (2, 5, 6, 4, 4, 3, 2, "SAME"),      # rectangular, even kernel
    (1, 4, 4, 8, 7, 5, 2, "SAME"),
    (1, 8, 8, 16, 9, 3, 1, "SAME"),     # StyleTransfer_3-like
    (1, 3, 3, 4, 3, 2, 1, "VALID"),
    (1, 4, 5, 4, 5, 3, 2, "VALID"),
    (1, 5, 5, 4, 3, 2, 3, "VALID"),     # Ks < S (gapped output)
    (1, 6, 6, 4, 2, 3, 2, "SAME"),      # Ks == S (no crop)
    (1, 1, 1, 21, 4, 21, 2, "SAME"),    # FCN row (1x1 spatial)
]


@pytest.mark.parametrize("case", SWEEP, ids=[str(c) for c in SWEEP])
def test_mm2im_vs_oracles(case):
    b, ih, iw, ic, ks, oc, s, pad = case
    x, w = rand_problem(ih, iw, ic, ks, oc, b)
    got = np.asarray(mm2im_tconv(x, w, stride=s, padding=pad, interpret=True))
    want_iom = np.asarray(ref.iom_reference(x, w, stride=s, padding=pad))
    want_lax = np.asarray(ref.tconv_lax(x, w, stride=s, padding=pad))
    np.testing.assert_allclose(got, want_iom, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(want_iom, want_lax, rtol=1e-4, atol=1e-4)


@settings(max_examples=25, deadline=None)
@given(
    ih=st.integers(1, 10), iw=st.integers(1, 10),
    ic=st.integers(1, 16), ks=st.integers(1, 7),
    oc=st.integers(1, 12), s=st.integers(1, 3),
    padding=st.sampled_from(["SAME", "VALID"]),
)
def test_mm2im_property_random_geometry(ih, iw, ic, ks, oc, s, padding):
    if padding == "SAME" and ks < s:
        return  # unsupported contract (asserted elsewhere)
    x, w = rand_problem(ih, iw, ic, ks, oc)
    got = np.asarray(mm2im_tconv(x, w, stride=s, padding=padding,
                                 interpret=True))
    want = np.asarray(ref.iom_reference(x, w, stride=s, padding=padding))
    np.testing.assert_allclose(got, want, rtol=1e-3, atol=1e-3)


@pytest.mark.parametrize("block_oh,block_oc", [(2, 4), (4, 8), (8, 16), (2, 3)])
def test_block_size_invariance(block_oh, block_oc):
    x, w = rand_problem(8, 8, 16, 5, 12)
    want = np.asarray(ref.tconv_lax(x, w, stride=2))
    got = np.asarray(mm2im_tconv(x, w, stride=2, block_oh=block_oh,
                                 block_oc=block_oc, interpret=True))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("grid_order", ["bcj", "cbj"])
def test_grid_order_invariance(grid_order):
    x, w = rand_problem(6, 6, 8, 3, 8, b=2)
    want = np.asarray(ref.tconv_lax(x, w, stride=2))
    got = np.asarray(mm2im_tconv(x, w, stride=2, grid_order=grid_order,
                                 interpret=True))
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_dtypes(dtype):
    x, w = rand_problem(5, 5, 8, 3, 4)
    got = mm2im_tconv(jnp.asarray(x, dtype), jnp.asarray(w, dtype), stride=2,
                      interpret=True)
    want = ref.tconv_lax(x, w, stride=2)
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want), rtol=tol, atol=tol)


def test_int8_exact():
    rng = np.random.default_rng(1)
    xq = rng.integers(-128, 128, (2, 6, 6, 16), dtype=np.int8)
    wq = rng.integers(-128, 128, (5, 5, 8, 16), dtype=np.int8)
    bq = rng.integers(-1000, 1000, (8,), dtype=np.int32)
    acc = ref.iom_reference_int8(xq, wq, bq, stride=2)
    want = np.asarray(ref.requantize(acc, 0.003))
    got = np.asarray(tconv_int8(xq, wq, bq, 0.003, stride=2))
    assert (want == got).all()
    assert got.dtype == np.int8


def test_int8_accumulator_exact_int32():
    """No requant: int32 accumulation must be bit-exact."""
    rng = np.random.default_rng(2)
    xq = rng.integers(-128, 128, (1, 4, 4, 32), dtype=np.int8)
    wq = rng.integers(-128, 128, (3, 3, 8, 32), dtype=np.int8)
    bq = np.zeros((8,), np.int32)
    want = np.asarray(ref.iom_reference_int8(xq, wq, bq, stride=2))
    got = np.asarray(mm2im_tconv(jnp.asarray(xq), jnp.asarray(wq),
                                 jnp.asarray(bq), stride=2, interpret=True))
    assert (want == got).all()


def test_fused_epilogue_activation():
    x, w = rand_problem(4, 4, 8, 3, 4)
    b = RNG.standard_normal(4).astype(np.float32)
    got = np.asarray(mm2im_tconv(x, w, jnp.asarray(b), stride=2,
                                 activation="relu", interpret=True))
    want = np.maximum(np.asarray(ref.tconv_lax(x, w, stride=2)) + b, 0)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


def test_gradients_match_reference():
    x, w = rand_problem(5, 5, 6, 3, 4)
    b = np.zeros((4,), np.float32)

    def loss_kernel(xx, ww, bb):
        return jnp.sum(tconv(xx, ww, bb, stride=2, method="mm2im") ** 2)

    def loss_ref(xx, ww, bb):
        y = ref.tconv_direct(xx, ww, stride=2) + bb[None, None, None]
        return jnp.sum(y ** 2)

    g1 = jax.grad(loss_kernel, argnums=(0, 1, 2))(x, w, b)
    g2 = jax.grad(loss_ref, argnums=(0, 1, 2))(x, w, b)
    for a, c in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(c),
                                   rtol=1e-3, atol=1e-3)


def test_plan_blocks_fits_vmem():
    for args in [(4, 4, 1024, 5, 512, 2), (256, 256, 32, 9, 3, 1),
                 (128, 128, 64, 3, 32, 2)]:
        boh, boc = plan_blocks(*args, "SAME", vmem_budget=12 * 2**20)
        assert boh % args[5] == 0 and boc >= 1


def test_same_with_ks_lt_s_raises():
    x, w = rand_problem(4, 4, 4, 2, 4)
    with pytest.raises(NotImplementedError):
        mm2im_tconv(x, w, stride=3, padding="SAME", interpret=True)


def test_int8_per_channel_requant():
    """TFLite-style per-channel output scales, fused in the PPU epilogue."""
    rng = np.random.default_rng(5)
    xq = rng.integers(-128, 128, (1, 5, 5, 16), dtype=np.int8)
    wq = rng.integers(-128, 128, (3, 3, 6, 16), dtype=np.int8)
    bq = rng.integers(-500, 500, (6,), dtype=np.int32)
    scales = (rng.uniform(1e-4, 5e-3, 6)).astype(np.float32)
    from repro.kernels.ops import tconv_int8 as t8
    got = np.asarray(t8(xq, wq, bq, scales, stride=2))
    acc = np.asarray(ref.iom_reference_int8(xq, wq, bq, stride=2))
    want = np.clip(np.round(acc.astype(np.float32) * scales), -128, 127
                   ).astype(np.int8)
    assert (got == want).all()
