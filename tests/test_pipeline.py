"""GPipe pipeline-parallel primitive vs sequential execution (subprocess:
needs its own multi-device XLA flags)."""

import os
import subprocess
import sys

import pytest

from repro.compat import HAS_NATIVE_SHARD_MAP

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from repro.launch.mesh import use_mesh
from repro.distributed.pipeline import pipeline_apply

for S, M in [(2, 4), (4, 6), (2, 2)]:
    mesh = jax.make_mesh((S, 8 // S // 1, 1)[:3] if False else (S, 8 // S, 1),
                         ("pod", "data", "model"))
    mb, D = 8, 16
    k = jax.random.PRNGKey(S * 10 + M)
    W = jax.random.normal(k, (S, D, D)) * 0.3
    b = jax.random.normal(jax.random.fold_in(k, 1), (S, D)) * 0.1
    params = {"w": W, "b": b}
    x = jax.random.normal(jax.random.fold_in(k, 2), (M, mb, D))
    stage_fn = lambda p, a: jnp.tanh(a @ p["w"] + p["b"])
    with use_mesh(mesh):
        y = jax.jit(lambda pp, xx: pipeline_apply(
            stage_fn, pp, xx, mesh=mesh))(params, x)
    ref = x
    for s in range(S):
        ref = jnp.tanh(ref @ W[s] + b[s])
    err = float(jnp.abs(np.asarray(y) - np.asarray(ref)).max())
    assert err < 1e-6, (S, M, err)
    print(f"PIPE_OK S={S} M={M} err={err:.1e}")
"""


@pytest.mark.slow
@pytest.mark.skipif(
    not HAS_NATIVE_SHARD_MAP,
    reason="partial-manual shard_map unsupported by jax 0.4.x SPMD (PartitionId)")
def test_gpipe_matches_sequential():
    env = dict(os.environ, PYTHONPATH=os.path.join(REPO, "src"))
    out = subprocess.run([sys.executable, "-c", SCRIPT], capture_output=True,
                         text=True, env=env, timeout=560, cwd=REPO)
    assert out.stdout.count("PIPE_OK") == 3, out.stdout + out.stderr[-2000:]
