"""Layer-level invariants: recurrences vs step decodes, attention paths, MoE."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.layers import attention as att
from repro.layers import moe as moe_mod
from repro.layers import rglru, ssm

K = jax.random.PRNGKey(0)
D, B, L = 32, 2, 48
X = jax.random.normal(jax.random.PRNGKey(1), (B, L, D)) * 0.5


def test_mamba2_chunked_equals_stepwise():
    p, _ = ssm.init_mamba2(K, D, head_dim=8, expand=2, d_state=16)
    y = ssm.mamba2(p, X, head_dim=8, expand=2, d_state=16, chunk=16)
    st = ssm.mamba2_init_state(B, D, head_dim=8, expand=2, d_state=16)
    ys = []
    for t in range(L):
        o, st = ssm.mamba2_step(p, X[:, t:t + 1], st, head_dim=8, expand=2,
                                d_state=16)
        ys.append(o)
    np.testing.assert_allclose(np.asarray(y), np.asarray(jnp.concatenate(ys, 1)),
                               rtol=2e-3, atol=2e-3)


@pytest.mark.parametrize("chunk", [8, 16, 48])
def test_mamba2_chunk_size_invariance(chunk):
    p, _ = ssm.init_mamba2(K, D, head_dim=8, expand=2, d_state=16)
    base = ssm.mamba2(p, X, head_dim=8, expand=2, d_state=16, chunk=12)
    y = ssm.mamba2(p, X, head_dim=8, expand=2, d_state=16, chunk=chunk)
    np.testing.assert_allclose(np.asarray(base), np.asarray(y), rtol=1e-4,
                               atol=1e-4)


def test_rglru_scan_equals_stepwise():
    p, _ = rglru.init_rglru_block(jax.random.PRNGKey(2), D, d_rnn=24)
    y = rglru.rglru_block(p, X)
    st = rglru.rglru_init_state(B, 24)
    ys = []
    for t in range(L):
        o, st = rglru.rglru_step(p, X[:, t:t + 1], st)
        ys.append(o)
    np.testing.assert_allclose(np.asarray(y), np.asarray(jnp.concatenate(ys, 1)),
                               rtol=1e-4, atol=1e-5)


def test_rglru_decay_bounded():
    """|a_t| < 1 always (stability of the recurrence)."""
    p, _ = rglru.init_rglru_block(jax.random.PRNGKey(3), D)
    u = X @ p["w_x"]
    log_a, _ = rglru._gates(p, u)
    assert (np.asarray(log_a) < 0).all()


@pytest.mark.parametrize("window", [None, 8])
def test_attention_chunked_equals_full(window):
    p, _ = att.init_attention(jax.random.PRNGKey(3), D, 4, 2, qk_norm=True)
    y1 = att.attend(p, X, n_heads=4, kv_heads=2, window=window)
    y2 = att.attend_chunked(p, X, n_heads=4, kv_heads=2, window=window,
                            chunk_q=16, chunk_k=8)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=2e-4,
                               atol=2e-4)


def test_attention_decode_equals_full():
    p, _ = att.init_attention(jax.random.PRNGKey(4), D, 4, 2, qkv_bias=True)
    y = att.attend(p, X, n_heads=4, kv_heads=2)
    cache = att.KVCache.empty(B, L, 2, D // 4, dtype=jnp.float32)
    outs = []
    for t in range(L):
        o, cache = att.decode_step(p, X[:, t:t + 1], cache, n_heads=4,
                                   kv_heads=2)
        outs.append(o)
    np.testing.assert_allclose(np.asarray(y),
                               np.asarray(jnp.concatenate(outs, 1)),
                               rtol=2e-4, atol=2e-4)


def test_windowed_decode_ring_buffer():
    """Ring cache (window) must equal full attention with the same window."""
    w = 8
    p, _ = att.init_attention(jax.random.PRNGKey(5), D, 4, 2)
    y = att.attend(p, X, n_heads=4, kv_heads=2, window=w)
    cache = att.KVCache.empty(B, w, 2, D // 4, dtype=jnp.float32)
    outs = []
    for t in range(L):
        o, cache = att.decode_step(p, X[:, t:t + 1], cache, n_heads=4,
                                   kv_heads=2, window=w)
        outs.append(o)
    np.testing.assert_allclose(np.asarray(y),
                               np.asarray(jnp.concatenate(outs, 1)),
                               rtol=2e-4, atol=2e-4)


def test_moe_differentiable_and_balanced():
    p, _ = moe_mod.init_moe(jax.random.PRNGKey(6), D, 64, 8, 2, n_shared=1,
                            shared_d_ff=64)
    out, aux = moe_mod.moe(p, X, top_k=2)
    assert out.shape == X.shape
    assert float(aux) > 0
    g = jax.grad(lambda pp: moe_mod.moe(pp, X, top_k=2)[0].sum())(p)
    assert not any(bool(jnp.isnan(v).any()) for v in jax.tree.leaves(g))


def test_moe_capacity_drops_are_the_only_difference():
    """With capacity >> needed, grouped routing is exact vs huge capacity."""
    p, _ = moe_mod.init_moe(jax.random.PRNGKey(7), D, 32, 4, 2)
    y1, _ = moe_mod.moe(p, X, top_k=2, capacity_factor=64.0)
    y2, _ = moe_mod.moe(p, X, top_k=2, capacity_factor=64.0, group_size=16)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), rtol=2e-4,
                               atol=2e-4)
