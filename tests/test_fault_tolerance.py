"""Fault tolerance: preemption resume bit-exactness, stragglers, elastic."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint.manager import CheckpointManager
from repro.launch.mesh import use_mesh
from repro.configs import registry
from repro.data.pipeline import DataConfig
from repro.models import lm
from repro.optim import adamw
from repro.runtime import steps as steps_mod
from repro.runtime.fault_tolerance import (LoopConfig, Preempted,
                                           PreemptionSimulator,
                                           StragglerSimulator, TrainLoop,
                                           elastic_mesh)


def _setup(tmp_path, total=8, ckpt_every=2):
    cfg = registry.get("qwen2.5-3b").smoke
    mesh = elastic_mesh(1)
    opt_cfg = adamw.AdamWConfig(lr=1e-3, warmup_steps=0, schedule="constant")
    with use_mesh(mesh):
        bundle = steps_mod.make_train_step(cfg, mesh, opt_cfg, batch=2, seq=16,
                                           donate=False)
        params, specs = lm.init(cfg, jax.random.PRNGKey(0))
        state = {"params": params, "opt": adamw.init(params, opt_cfg)}
    data_cfg = DataConfig(vocab=cfg.vocab, seq_len=16, global_batch=2)
    loop = TrainLoop(bundle.fn, state, data_cfg,
                     LoopConfig(total_steps=total, ckpt_every=ckpt_every,
                                log_every=100),
                     CheckpointManager(tmp_path), mesh=mesh,
                     specs={"params": specs, "opt": adamw.state_specs(specs)},
                     log=lambda *_: None)
    return loop, mesh


def test_preemption_then_resume_bit_exact(tmp_path):
    # Uninterrupted run.
    loop_a, mesh = _setup(tmp_path / "a")
    with use_mesh(mesh):
        state_a, _ = loop_a.run()

    # Interrupted at step 5, then resumed.
    loop_b, _ = _setup(tmp_path / "b")
    loop_b.preempt = PreemptionSimulator(at_step=5)
    with use_mesh(mesh):
        with pytest.raises(Preempted):
            loop_b.run()
        loop_c, _ = _setup(tmp_path / "b")
        assert loop_c.resume() == 4  # last multiple of ckpt_every before 5
        state_c, _ = loop_c.run()

    for a, c in zip(jax.tree.leaves(state_a["params"]),
                    jax.tree.leaves(state_c["params"])):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(c, np.float32), rtol=1e-6,
                                   atol=1e-6)


def test_straggler_simulator_deterministic():
    s = StragglerSimulator(p=0.5, delay_s=0.0, seed=1)
    hits1 = [s.maybe_stall(i) for i in range(20)]
    hits2 = [s.maybe_stall(i) for i in range(20)]
    assert hits1 == hits2
    assert any(hits1) and not all(hits1)


def test_elastic_mesh_uses_all_devices():
    m = elastic_mesh(1)
    assert m.devices.size == len(jax.devices())
    assert m.axis_names == ("data", "model")
