"""Tiled matmul Pallas kernel vs jnp.dot (shape/dtype sweep + property)."""

import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_shim import given, settings, st

from repro.kernels.matmul_pallas import matmul

RNG = np.random.default_rng(11)


@pytest.mark.parametrize("m,k,n,bm,bn,bk", [
    (64, 64, 64, 32, 32, 32),
    (100, 70, 50, 32, 16, 32),     # ragged everything
    (8, 256, 16, 8, 16, 64),
    (128, 128, 128, 128, 128, 128),  # single block
])
def test_matmul_f32(m, k, n, bm, bn, bk):
    a = RNG.standard_normal((m, k), np.float32)
    b = RNG.standard_normal((k, n), np.float32)
    got = np.asarray(matmul(a, b, block_m=bm, block_n=bn, block_k=bk,
                            interpret=True))
    np.testing.assert_allclose(got, a @ b, rtol=1e-4, atol=1e-4)


def test_matmul_int8_exact():
    a = RNG.integers(-128, 128, (48, 96), dtype=np.int8)
    b = RNG.integers(-128, 128, (96, 32), dtype=np.int8)
    got = np.asarray(matmul(jnp.asarray(a), jnp.asarray(b), block_m=16,
                            block_n=16, block_k=32, interpret=True))
    want = a.astype(np.int32) @ b.astype(np.int32)
    assert (got == want).all() and got.dtype == np.int32


def test_matmul_bf16():
    a = (RNG.standard_normal((64, 64)) * 0.5).astype(jnp.bfloat16)
    b = (RNG.standard_normal((64, 64)) * 0.5).astype(jnp.bfloat16)
    got = np.asarray(matmul(a, b, block_m=32, block_n=32, block_k=32,
                            interpret=True), np.float32)
    want = np.asarray(a, np.float32) @ np.asarray(b, np.float32)
    np.testing.assert_allclose(got, want, rtol=2e-2, atol=2e-2)


@settings(max_examples=15, deadline=None)
@given(m=st.integers(1, 70), k=st.integers(1, 70), n=st.integers(1, 70))
def test_matmul_property(m, k, n):
    a = RNG.standard_normal((m, k), np.float32)
    b = RNG.standard_normal((k, n), np.float32)
    got = np.asarray(matmul(a, b, block_m=32, block_n=32, block_k=32,
                            interpret=True))
    np.testing.assert_allclose(got, a @ b, rtol=1e-4, atol=1e-4)
