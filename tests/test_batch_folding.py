"""Batch folding (plan schema v2): pipelines, enumeration, dispatch.

The fold contract is strict: collapsing ``(batch, slab-rows)`` into the
MatMul M-dimension must be **bit-identical** to the grid-batch dataflow
for every (stride, padding, dtype, kernel-variant) cell — col2im runs per
batch element over views of the folded product with the unfolded
reduction order, so the fold is purely a performance knob and the
autotuner/plan tiers may apply it without ever changing results.

The folded-vs-grid-vs-gold parity matrix itself lives in
``tests/test_parity_matrix.py`` (every registered method, both dtypes);
this file keeps the fold-specific machinery tests.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import tiling
from repro.core.maps import TConvProblem
from repro.kernels import ref, registry
from repro.kernels.mm2im_db_pallas import mm2im_db_tconv
from repro.kernels.mm2im_pallas import grid_semantics, mm2im_tconv
from repro.kernels.ops import tconv
from repro.kernels.registry import Plan

RNG = np.random.default_rng(21)

# One geometry per stride; SAME requires Ks >= S.
_GEOM = {1: (3, 4, 4), 2: (5, 4, 4), 4: (5, 4, 5)}  # s -> (ks, ih, iw)


def _f32_problem(s, b=3, ic=8, oc=5):
    ks, ih, iw = _GEOM[s]
    x = RNG.standard_normal((b, ih, iw, ic)).astype(np.float32)
    w = (RNG.standard_normal((ks, ks, oc, ic)) * 0.1).astype(np.float32)
    return x, w


# ---------------------------------------------------------------------------
# Fold-specific kernel machinery (parity matrix: test_parity_matrix.py)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("pipeline", ["async", "sync"])
def test_fold_db_pipelines_bit_identical(pipeline):
    """Folded db: async-DMA and sync fallback both match the folded sb."""
    x, w = _f32_problem(2, b=4)
    want = np.asarray(mm2im_tconv(x, w, stride=2, interpret=True,
                                  fold_batch=True))
    got = np.asarray(mm2im_db_tconv(x, w, stride=2, interpret=True,
                                    fold_batch=True, pipeline=pipeline))
    assert (got == want).all()


def test_fold_batch1_degenerates():
    """fold_batch with B == 1 is the unfolded kernel, bitwise."""
    x, w = _f32_problem(2, b=1)
    for method in ("mm2im", "mm2im_db"):
        base = np.asarray(tconv(x, w, stride=2, method=method))
        fold = np.asarray(tconv(x, w, stride=2, method=method,
                                plan=Plan(2, 4, "bcj", fold_batch=True)))
        assert (fold == base).all(), method


def test_fold_fused_epilogue_and_gradients():
    """Bias+activation fuse under the fold, and training runs through a
    folded plan (custom_vjp path) with reference gradients."""
    x, w = _f32_problem(2, b=4)
    bias = RNG.standard_normal(5).astype(np.float32)
    got = np.asarray(tconv(x, w, jnp.asarray(bias), stride=2,
                           activation="relu",
                           plan=Plan(2, 4, "bcj", fold_batch=True)))
    want = np.maximum(np.asarray(ref.tconv_lax(x, w, stride=2)) + bias, 0)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)

    plan = Plan(2, 4, "bcj", fold_batch=True)

    def loss_fold(xx, ww):
        return jnp.sum(tconv(xx, ww, stride=2, plan=plan) ** 2)

    def loss_ref(xx, ww):
        return jnp.sum(ref.tconv_direct(xx, ww, stride=2) ** 2)

    g1 = jax.grad(loss_fold, argnums=(0, 1))(x, w)
    g2 = jax.grad(loss_ref, argnums=(0, 1))(x, w)
    for a, c in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(a), np.asarray(c),
                                   rtol=1e-3, atol=1e-3)


# ---------------------------------------------------------------------------
# Plan schema v2 value type
# ---------------------------------------------------------------------------


def test_plan_v2_json_roundtrip():
    p = Plan(4, 8, "bcj", "mm2im_db", True)
    assert Plan.from_json(p.to_json()) == p
    # Serialized plans always carry the fold decision explicitly.
    assert Plan(4, 8).to_json()["fold_batch"] is False
    # v1 payloads (no fold_batch) load as unfolded.
    assert Plan.from_json({"block_oh": 4, "block_oc": 8}) == Plan(4, 8)
    # Tuple normalization stays the legacy 2/3-element contract.
    assert registry.as_plan((4, 8)).fold_batch is False


def test_grid_semantics_shapes():
    """The Mosaic partitioning hints match each kernel's grid rank."""
    assert grid_semantics(2).dimension_semantics == \
        ("parallel", "parallel", "arbitrary")       # sb, grid-batch
    assert grid_semantics(1).dimension_semantics == \
        ("parallel", "arbitrary")                   # sb, folded
    assert grid_semantics(2, inner_arbitrary=False).dimension_semantics == \
        ("parallel", "parallel")                    # db, grid-batch
    assert grid_semantics(1, inner_arbitrary=False).dimension_semantics == \
        ("parallel",)                               # db, folded


# ---------------------------------------------------------------------------
# Enumeration + consumption
# ---------------------------------------------------------------------------


def test_candidate_plans_enumerate_fold_only_batched():
    p = TConvProblem(4, 4, 32, 5, 16, 2)
    assert not any(c.fold_batch for c in tiling.candidate_plans(p, batch=1))
    cands = tiling.candidate_plans(p, batch=8)
    folded = [c for c in cands if c.fold_batch]
    assert folded, "batch-8 enumeration must include folded candidates"
    budget = int(tiling.V5E.vmem_bytes * 0.75)
    for c in folded:
        # Folded candidates are budgeted under the B-deep residency and
        # carry the single canonical grid order (bcj/cbj collapse).
        assert c.vmem_bytes <= budget
        assert c.grid_order == "bcj"
        assert tiling.vmem_bytes(p, c.block_oh, c.block_oc, bits=32,
                                 method=c.method, batch=8, fold_batch=True
                                 ) > tiling.vmem_bytes(
                                     p, c.block_oh, c.block_oc, bits=32,
                                     method=c.method)
    # Dedup key includes the fold: geometry-identical folded/unfolded
    # candidates coexist.
    keys = [(c.method, c.block_oh, c.block_oc, c.grid_order, c.fold_batch)
            for c in cands]
    assert len(keys) == len(set(keys))


def test_folded_plan_consumed_from_cache(monkeypatch, tmp_path):
    """A tuned fold_batch plan auto-consumed at trace time executes folded
    and never changes results (the plan-tier safety property)."""
    from repro.core import autotune, plan_table
    from repro.kernels import ops

    monkeypatch.setenv(autotune.CACHE_ENV, str(tmp_path / "cache.json"))
    monkeypatch.setenv(plan_table.TABLE_DIR_ENV, str(tmp_path / "none"))
    monkeypatch.delenv(ops.AUTOLOAD_ENV, raising=False)
    autotune.reset_shared_caches()
    plan_table.reset_shipped_tables()
    ops.clear_consumed_plans()

    p = TConvProblem(5, 4, 6, 3, 4, 2)
    batch = 4
    folded_plan = Plan(2, 4, "bcj", "mm2im_db", True)
    cache = autotune.PlanCache(tmp_path / "cache.json")
    cache.put(autotune.cache_key(p, batch=batch), folded_plan)

    x = RNG.standard_normal((batch, p.ih, p.iw, p.ic)).astype(np.float32)
    w = (RNG.standard_normal((p.ks, p.ks, p.oc, p.ic)) * 0.1
         ).astype(np.float32)
    got = np.asarray(tconv(x, w, stride=p.stride))
    key, plan, tier = ops.consumed_plans()[-1]
    assert plan == folded_plan and tier == autotune.TIER_USER_CACHE
    np.testing.assert_allclose(
        got, np.asarray(ref.tconv_lax(x, w, stride=p.stride)),
        rtol=1e-4, atol=1e-4)


def test_measure_plan_times_folded_geometry():
    """measure_plan keeps the fold knob when timing a candidate (a folded
    candidate must be timed folded, or tuning would rank a different
    program than dispatch runs)."""
    from repro.core.autotune import measure_plan

    p = TConvProblem(4, 4, 4, 3, 2, 2)
    us = measure_plan(p, Plan(2, 2, "bcj", "mm2im", True), batch=2,
                      repeats=1, warmup=1)
    assert np.isfinite(us) and us > 0


def test_autotune_b8_persists_fold_field(tmp_path):
    """A batch-8 tuning run persists the fold decision in the cache entry
    (schema v2), and the entry round-trips through a fresh PlanCache."""
    from repro.core.autotune import PlanCache, autotune_result

    p = TConvProblem(4, 4, 8, 3, 4, 2)
    cache = PlanCache(tmp_path / "c.json")
    res = autotune_result(p, batch=8, cache=cache, max_measure=2, repeats=1)
    entry = cache.get_entry(res.key)
    assert "fold_batch" in entry["plan"]
    assert PlanCache(tmp_path / "c.json").get(res.key) == res.plan
