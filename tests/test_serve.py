"""Serving layer: bucketed admission, wait-or-flush batching, warmup.

Covers the ISSUE-8 serve surface: ``bucketing.snap`` snapping to the
best tuned-plan batch (and rejecting what would trigger a recompile
storm), ``Batcher`` flush-on-full vs flush-on-deadline with an injected
clock, warmup really consuming shipped-table plans (asserted through
``ops.consumed_plans()`` tier attribution), and request -> response
round trips through ``TconvServer`` at f32 AND int8 — compared against
the batched padded forward, which is the *defined* behavior (the models
compute batch statistics inline, so outputs depend on batch
composition; see ``serve/server.py``).
"""

import json

import numpy as np
import pytest

import jax
import jax.numpy as jnp

from repro.core import autotune, plan_table
from repro.core.autotune import TIER_SHIPPED, TIER_USER_CACHE, cache_key
from repro.kernels import ops
from repro.kernels.registry import Plan
from repro.models.runner import make_runner
from repro.serve import bucketing
from repro.serve.batcher import (Batcher, FLUSH_DEADLINE, FLUSH_FULL,
                                 Request)
from repro.serve.bucketing import AdmissionError, BucketKey, BucketSpec
from repro.serve.server import TconvServer
from repro.serve.warmup import warm_runner

DCGAN_KW = dict(init_kw={"scale_down": 16})


@pytest.fixture(scope="module")
def dcgan_params():
    from repro.models import gan

    params, _ = gan.init_dcgan_g(jax.random.PRNGKey(0), **DCGAN_KW["init_kw"])
    return params


def _fresh_runner(dcgan_params):
    """New runner over shared params: fresh jit memo, so plan consumption
    happens inside the calling test."""
    return make_runner("dcgan", params=dcgan_params)


def _isolate_plans(monkeypatch, tmp_path):
    """Empty user cache + empty shipped-table dir, memos reset."""
    monkeypatch.setenv(autotune.CACHE_ENV, str(tmp_path / "cache.json"))
    monkeypatch.setenv(plan_table.TABLE_DIR_ENV, str(tmp_path / "plans"))
    monkeypatch.delenv(ops.AUTOLOAD_ENV, raising=False)
    autotune.reset_shared_caches()
    plan_table.reset_shipped_tables()
    ops.clear_consumed_plans()
    return autotune.shared_cache(), tmp_path / "plans"


def _write_shipped(table_dir, entries, backend="cpu"):
    table_dir.mkdir(parents=True, exist_ok=True)
    doc = {"version": plan_table.TABLE_VERSION,
           "provenance": {"backend": backend, "jax": "0.4.37", "repeats": 2,
                          "created": 1754000000.0, "note": "test"},
           "entries": {k: {"plan": p.to_json()} for k, p in entries.items()}}
    (table_dir / f"{backend}.json").write_text(json.dumps(doc))
    plan_table.reset_shipped_tables()


# ---------------------------------------------------------------------------
# Admission / bucketing.
# ---------------------------------------------------------------------------


def test_snap_prefers_fully_tuned_batch(monkeypatch, tmp_path, dcgan_params):
    cache, _ = _isolate_plans(monkeypatch, tmp_path)
    r = _fresh_runner(dcgan_params)
    for prob in r.tconv_problems().values():
        cache.put(cache_key(prob, dtype=jnp.float32, batch=4),
                  autotune.default_plan(prob))
    spec = bucketing.snap(r, r.input_shape(), "f32",
                          candidate_batches=(8, 4, 2, 1))
    assert spec.key.batch == 4 and spec.fully_tuned
    assert dict(spec.tiers) == {TIER_USER_CACHE: spec.total_layers}
    # int8 keys were not seeded: falls back to the heuristic default
    spec8 = bucketing.snap(r, r.input_shape(), "int8",
                           candidate_batches=(8, 4, 2, 1), default_batch=1)
    assert spec8.key.batch == 1 and spec8.tuned_layers == 0
    autotune.reset_shared_caches()


def test_snap_partial_coverage_beats_none(monkeypatch, tmp_path,
                                          dcgan_params):
    cache, _ = _isolate_plans(monkeypatch, tmp_path)
    r = _fresh_runner(dcgan_params)
    prob = next(iter(r.tconv_problems().values()))
    cache.put(cache_key(prob, dtype=jnp.float32, batch=2),
              autotune.default_plan(prob))
    spec = bucketing.snap(r, r.input_shape(), "f32",
                          candidate_batches=(8, 2, 1))
    assert spec.key.batch == 2
    assert 0 < spec.tuned_layers < spec.total_layers
    assert not spec.fully_tuned
    autotune.reset_shared_caches()


def test_snap_heuristic_fallback(monkeypatch, tmp_path, dcgan_params):
    _isolate_plans(monkeypatch, tmp_path)
    r = _fresh_runner(dcgan_params)
    spec = bucketing.snap(r, r.input_shape(), "f32", default_batch=2)
    assert spec.key.batch == 2 and spec.tuned_layers == 0
    assert dict(spec.tiers) == {bucketing.TIER_HEURISTIC: spec.total_layers}
    assert str(spec.key) == f"dcgan:{r.input_shape()[0]}:f32:b2"


def test_snap_rejects_bad_shape_and_precision(dcgan_params):
    r = _fresh_runner(dcgan_params)
    with pytest.raises(AdmissionError, match="shape"):
        bucketing.snap(r, (3, 3, 3), "f32")
    with pytest.raises(AdmissionError, match="precision"):
        bucketing.snap(r, r.input_shape(), "fp16")


# ---------------------------------------------------------------------------
# Batcher (pure, injected clock — no jax).
# ---------------------------------------------------------------------------


def _spec(batch, name="m"):
    return BucketSpec(key=BucketKey(name, (4,), "f32", batch),
                      tuned_layers=0, total_layers=0, tiers=())


def _req(rid, t):
    return Request(rid, "m", np.zeros(4, np.float32), "f32", t)


def test_batcher_flush_on_full_is_immediate():
    b = Batcher(max_wait_s=10.0)
    spec = _spec(2)
    for i in range(5):
        b.put(spec, _req(i, t=0.0))
    out = b.ready(now=0.0)
    assert [(len(reqs), reason) for _, reqs, reason in out] == [
        (2, FLUSH_FULL), (2, FLUSH_FULL)]
    assert b.pending() == 1                     # partial stays queued
    assert b.ready(now=5.0) == []               # deadline not reached
    [(_, reqs, reason)] = b.ready(now=10.0)     # oldest waited max_wait
    assert reason == FLUSH_DEADLINE and [r.rid for r in reqs] == [4]
    assert b.pending() == 0


def test_batcher_deadline_and_force():
    b = Batcher(max_wait_s=0.5)
    spec = _spec(8)
    b.put(spec, _req(0, t=1.0))
    b.put(spec, _req(1, t=1.2))
    assert b.next_deadline() == pytest.approx(1.5)   # oldest + max_wait
    assert b.ready(now=1.4) == []
    [(_, reqs, reason)] = b.ready(now=1.5)
    assert reason == FLUSH_DEADLINE and len(reqs) == 2
    # force flushes a fresh partial immediately (drain/shutdown path)
    b.put(spec, _req(2, t=2.0))
    [(_, reqs, reason)] = b.ready(now=2.0, force=True)
    assert reason == FLUSH_DEADLINE and [r.rid for r in reqs] == [2]


def test_request_result_timeout_and_error():
    r = _req(0, t=0.0)
    with pytest.raises(TimeoutError):
        r.result(timeout=0.01)
    r.set_error(RuntimeError("boom"), t_done=1.0)
    assert r.done() and r.latency_s == 1.0
    with pytest.raises(RuntimeError, match="boom"):
        r.result(timeout=0)


def test_request_unfulfilled_wait_never_returns_none():
    """Regression (ISSUE 10): an unfulfilled ``result(timeout=)`` must
    raise ``TimeoutError``, never return a value — ``None`` would be
    indistinguishable from a legitimately-``None`` payload."""
    r = _req(0, t=0.0)
    with pytest.raises(TimeoutError, match="not served"):
        r.result(timeout=0)
    # a real None payload, by contrast, is returned as-is
    r2 = _req(1, t=0.0)
    r2.set_result(None, t_done=1.0)
    assert r2.result(timeout=0) is None
    # and a fulfilled event with neither value nor error is an invariant
    # violation, reported as such rather than handed back as a result
    r3 = _req(2, t=0.0)
    r3._event.set()
    with pytest.raises(RuntimeError, match="no result/error"):
        r3.result(timeout=0)


def test_batcher_bounded_queue_and_expiry():
    from repro.serve.bucketing import QueueFullError

    b = Batcher(max_wait_s=10.0, max_queue_depth=2)
    spec = _spec(8)
    b.put(spec, _req(0, t=0.0))
    b.put(spec, _req(1, t=0.0))
    with pytest.raises(QueueFullError):
        b.put(spec, _req(2, t=0.0))
    assert b.pending() == 2                      # overflow was not enqueued
    # expiry: deadline-carrying request is removed before batching, FIFO
    # order of the survivors kept
    exp = Request(3, "m", np.zeros(4, np.float32), "f32", 0.0, deadline=1.0)
    b2 = Batcher(max_wait_s=10.0)
    b2.put(spec, _req(4, t=0.0))
    b2.put(spec, exp)
    b2.put(spec, _req(5, t=0.0))
    [(_, dead)] = b2.pop_expired(now=1.0)
    assert [r.rid for r in dead] == [3]
    [(_, live, _)] = b2.ready(now=0.0, force=True)
    assert [r.rid for r in live] == [4, 5]


# ---------------------------------------------------------------------------
# Warmup consumes the shipped table (tier attribution).
# ---------------------------------------------------------------------------


def test_warmup_consumes_shipped_table_plans(monkeypatch, tmp_path):
    from repro.models import gan

    _, table_dir = _isolate_plans(monkeypatch, tmp_path)
    # Unique channel widths (base=768): trace-time consumption records
    # only on a fresh trace, and ops._dispatch's jit cache is keyed by
    # shapes — a problem key another test already traced (under its own
    # plan environment) would replay without consulting the tiers (the
    # same caveat tests/test_plan_table.py documents).
    params, _ = gan.init_dcgan_g(jax.random.PRNGKey(3), base=768,
                                 scale_down=16)
    r = make_runner("dcgan", params=params)
    probs = r.tconv_problems()
    _write_shipped(table_dir,
                   {cache_key(p, dtype=jnp.float32, batch=2):
                    autotune.default_plan(p) for p in probs.values()})

    ops.clear_consumed_plans()
    rec = warm_runner(r, batch=2)
    assert rec.model == "dcgan" and rec.batch == 2 and rec.seconds > 0
    assert rec.tuned_layers == rec.total_layers == len(probs)
    assert dict(rec.tiers) == {TIER_SHIPPED: len(probs)}
    # the compile itself consumed shipped-table plans at trace time
    assert len(rec.consumed) == len(probs)
    assert {tier for _, tier in rec.consumed} == {TIER_SHIPPED}
    assert r.has_compiled(batch=2)
    autotune.reset_shared_caches()
    plan_table.reset_shipped_tables()


# ---------------------------------------------------------------------------
# Server round trips.
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("precision", ["f32", "int8"])
def test_server_round_trip_matches_batched_forward(dcgan_params, precision):
    """3 requests through a target-batch-2 bucket: one full batch + one
    zero-padded partial.  Outputs must equal the batched padded forward
    row-for-row (the defined behavior under inline batch statistics)."""
    r = _fresh_runner(dcgan_params)
    server = TconvServer({"dcgan": r}, max_wait_s=30.0,
                         candidate_batches=(2, 1), default_batch=2)
    xs = np.asarray(r.example_inputs(batch=3, seed=9))
    reqs = [server.submit("dcgan", xs[i], precision=precision)
            for i in range(3)]
    assert server.serve_once(force=True) == 3
    fn = r.jitted(batch=2, precision=precision)
    want_full = np.asarray(fn(jnp.asarray(xs[:2])))
    padded = np.zeros((2,) + xs.shape[1:], np.float32)
    padded[0] = xs[2]
    want_part = np.asarray(fn(jnp.asarray(padded)))[0]
    np.testing.assert_allclose(np.asarray(reqs[0].result(timeout=0)),
                               want_full[0], rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(np.asarray(reqs[1].result(timeout=0)),
                               want_full[1], rtol=1e-6, atol=1e-6)
    np.testing.assert_allclose(np.asarray(reqs[2].result(timeout=0)),
                               want_part, rtol=1e-6, atol=1e-6)

    stats = server.stats()
    key = f"dcgan:{r.input_shape()[0]}:{precision}:b2"
    b = stats["buckets"][key]
    assert b["requests"] == b["completed"] == 3 and b["failed"] == 0
    assert b["batches"] == 2
    assert b["flush_full"] == 1 and b["flush_deadline"] == 1
    assert b["batch_fill_ratio"] == pytest.approx(0.75)  # (2/2 + 1/2) / 2
    assert stats["pending"] == 0 and stats["rejected"] == 0


def test_server_threaded_with_warmup_compile_hits(dcgan_params):
    r = _fresh_runner(dcgan_params)
    server = TconvServer({"dcgan": r}, max_wait_s=0.02,
                         candidate_batches=(2, 1), default_batch=2)
    records = server.warmup()
    assert len(records) == 1 and records[0].batch == 2
    assert r.has_compiled(batch=2)
    xs = np.asarray(r.example_inputs(batch=2, seed=4))
    with server:
        reqs = [server.submit("dcgan", xs[i]) for i in range(2)]
        outs = [req.result(timeout=60) for req in reqs]
    assert all(np.isfinite(np.asarray(o)).all() for o in outs)
    b = server.stats()["buckets"][f"dcgan:{r.input_shape()[0]}:f32:b2"]
    assert b["completed"] == 2
    assert b["compile_hits"] == b["batches"]    # warmup pre-compiled
    assert b["queue_wait_max_s"] <= 0.02 + 0.25  # deadline-bounded (+slack)


ALL_MODELS = {
    "dcgan": dict(init_kw={"scale_down": 16}),
    "pix2pix": dict(init_kw={"depth": 4, "scale_down": 16}),
    "fsrcnn": dict(init_kw={"d": 8, "s": 4, "m": 1}, input_hw=8),
    "styletransfer": dict(init_kw={"base": 8, "n_res": 1}, input_hw=16),
}


@pytest.fixture(scope="module")
def all_runners():
    return {name: make_runner(name, key=jax.random.PRNGKey(i), **kw)
            for i, (name, kw) in enumerate(ALL_MODELS.items())}


@pytest.mark.parametrize("precision", ["f32", "int8"])
@pytest.mark.parametrize("name", sorted(ALL_MODELS))
def test_round_trip_every_ported_runner(all_runners, name, precision):
    """Request -> response through the server for each of the four ported
    families, f32 and int8: the output is the runner's own jitted bucket
    forward, row for row."""
    r = all_runners[name]
    server = TconvServer({name: r}, candidate_batches=(1,), default_batch=1)
    x = np.asarray(r.example_inputs(1, seed=2))[0]
    req = server.submit(name, x, precision=precision)
    assert server.serve_once(force=True) == 1
    want = np.asarray(r.jitted(batch=1, precision=precision)(
        jnp.asarray(x)[None]))[0]
    got = np.asarray(req.result(timeout=0))
    np.testing.assert_allclose(got, want, rtol=1e-6, atol=1e-6)
    assert np.isfinite(got).all()


def test_server_rejects_and_counts(dcgan_params):
    r = _fresh_runner(dcgan_params)
    server = TconvServer({"dcgan": r})
    with pytest.raises(AdmissionError, match="unknown model"):
        server.submit("vae", np.zeros(4, np.float32))
    with pytest.raises(AdmissionError, match="shape"):
        server.submit("dcgan", np.zeros(7, np.float32))
    assert server.stats()["rejected"] == 2


# ---------------------------------------------------------------------------
# Shutdown / drain edge paths (ISSUE 10): no request left unfulfilled.
# ---------------------------------------------------------------------------


class _EchoRunner:
    """Minimal duck-typed runner: instant zero outputs, no jax."""

    name = "echo"

    def input_shape(self):
        return (4,)

    def tconv_problems(self):
        return {}

    def has_compiled(self, *, batch, precision="f32"):
        return False

    def jitted(self, *, batch, precision="f32"):
        return lambda x: np.zeros((batch, 4), np.float32)


def test_server_drain_timeout_raises():
    """``drain`` must raise ``TimeoutError`` when the queue cannot empty
    within the budget — here execution re-submits a request per batch, so
    pending never reaches zero."""
    r = _EchoRunner()
    server = TconvServer({"echo": r}, candidate_batches=(1,),
                         default_batch=1)

    def resubmitting(x):
        server.submit("echo", np.zeros(4, np.float32))
        return np.zeros((1, 4), np.float32)

    r.jitted = lambda *, batch, precision="f32": resubmitting
    server.submit("echo", np.zeros(4, np.float32))
    with pytest.raises(TimeoutError, match="drain"):
        server.drain(timeout=0.2)
    assert server._batcher.pending() >= 1        # really never emptied


def test_server_stop_serves_requests_in_flight():
    """``stop()`` with queued requests drains them: every request is
    fulfilled, none left blocking its caller."""
    server = TconvServer({"echo": _EchoRunner()}, max_wait_s=60.0,
                         candidate_batches=(4,), default_batch=4)
    server.start()
    reqs = [server.submit("echo", np.zeros(4, np.float32))
            for _ in range(6)]
    server.stop()
    assert all(r.done() for r in reqs)
    outs = [r.result(timeout=0) for r in reqs]
    assert all(o.shape == (4,) for o in outs)
    s = server.stats()
    [b] = s["buckets"].values()
    assert b["completed"] == 6 and s["pending"] == 0


def test_server_stop_fails_unservable_requests_typed():
    """When execution cannot succeed at any ladder rung, ``stop()`` still
    settles every request — failed with a typed error, not wedged."""
    from repro.serve.resilience import LadderExhausted

    r = _EchoRunner()

    def broken(x):
        raise ValueError("permanently broken")

    r.jitted = lambda *, batch, precision="f32": broken
    server = TconvServer({"echo": r}, max_wait_s=60.0,
                         candidate_batches=(2,), default_batch=2)
    server.start()
    reqs = [server.submit("echo", np.zeros(4, np.float32))
            for _ in range(3)]
    server.stop()
    assert all(q.done() for q in reqs)
    for q in reqs:
        with pytest.raises(LadderExhausted):
            q.result(timeout=0)
    s = server.stats()
    [b] = s["buckets"].values()
    assert b["failed"] == 3 and s["pending"] == 0
