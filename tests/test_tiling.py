"""Property-style invariants for the tiling planner (core/tiling, core/maps).

The slab relations are the correctness backbone of the tiled kernel: if a
``rows_slab`` range ever misses a contributing input row, the Pallas kernel
silently drops partial products.  These tests pin the invariants across a
sweep of strides / paddings / kernel sizes, with a randomized-geometry
property pass on top (hypothesis when installed, deterministic fallback
otherwise).
"""

import numpy as np
import pytest
from _hypothesis_shim import given, settings, st

from repro.core import tiling
from repro.core.maps import TConvProblem, max_slab_rows, rows_slab
from repro.core.perf_model import V5E
from repro.kernels.ref import crop_offsets

# (ih, iw, ic, ks, oc, s, padding) — SAME requires Ks >= S.
PROBLEMS = [
    (2, 2, 2, 3, 2, 1, "SAME"),
    (4, 4, 3, 5, 2, 2, "SAME"),
    (7, 7, 32, 3, 16, 1, "SAME"),
    (9, 9, 16, 5, 8, 2, "SAME"),
    (5, 6, 4, 4, 3, 2, "SAME"),
    (4, 4, 8, 7, 5, 2, "SAME"),
    (6, 6, 4, 2, 3, 2, "SAME"),
    (3, 3, 4, 3, 2, 1, "VALID"),
    (4, 5, 4, 5, 3, 2, "VALID"),
    (5, 5, 4, 3, 2, 3, "VALID"),
    (8, 8, 16, 9, 3, 1, "SAME"),
]


def _contributing_rows(p: TConvProblem, oh0: int, oh1: int) -> set:
    """Brute-force input rows feeding output rows [oh0, oh1] via
    ``oh = S*ih - ct + kh`` (the kernel's mapping relation)."""
    ct, _ = crop_offsets(p.ks, p.stride, p.padding)
    rows = set()
    for ih in range(p.ih):
        for kh in range(p.ks):
            oh = p.stride * ih - ct + kh
            if oh0 <= oh <= oh1 and 0 <= oh < p.oh:
                rows.add(ih)
    return rows


def _check_slab_invariants(p: TConvProblem, block_oh: int):
    heights = []
    for oh0 in range(0, p.oh, block_oh):
        start, end = rows_slab(p, oh0, block_oh)
        # Contiguous, in range, non-degenerate.
        assert 0 <= start <= end <= p.ih, (p, oh0, start, end)
        oh1 = min(oh0 + block_oh, p.oh) - 1
        need = _contributing_rows(p, oh0, oh1)
        if need:
            # Every contributing input row is inside the slab.
            assert need <= set(range(start, end)), (p, oh0, need, (start, end))
        heights.append(end - start)
    # max_slab_rows bounds every aligned block's slab height.
    assert max(heights) <= max_slab_rows(p, block_oh), (p, block_oh)


@pytest.mark.parametrize("case", PROBLEMS, ids=[str(c) for c in PROBLEMS])
def test_rows_slab_covers_contributors(case):
    ih, iw, ic, ks, oc, s, pad = case
    p = TConvProblem(ih, iw, ic, ks, oc, s, pad)
    for block_oh in (s, 2 * s, 4 * s):
        if block_oh > max(p.oh, s):
            continue
        _check_slab_invariants(p, block_oh)


@settings(max_examples=25, deadline=None)
@given(ih=st.integers(1, 12), iw=st.integers(1, 10), ks=st.integers(1, 7),
       s=st.integers(1, 3), padding=st.sampled_from(["SAME", "VALID"]),
       bi=st.integers(1, 6))
def test_rows_slab_property_random_geometry(ih, iw, ks, s, padding, bi):
    if padding == "SAME" and ks < s:
        return  # unsupported contract (asserted elsewhere)
    p = TConvProblem(ih, iw, 4, ks, 4, s, padding)
    block_oh = s * bi
    if block_oh > max(p.oh, s):
        return
    _check_slab_invariants(p, block_oh)


@pytest.mark.parametrize("case", PROBLEMS, ids=[str(c) for c in PROBLEMS])
def test_default_plan_vmem_within_budget(case):
    ih, iw, ic, ks, oc, s, pad = case
    p = TConvProblem(ih, iw, ic, ks, oc, s, pad)
    tp = tiling.plan(p)
    assert tp.vmem_bytes <= int(V5E.vmem_bytes * 0.75), tp.describe()
    assert tp.block_oh % s == 0 and tp.block_oc >= 1
    assert tp.grid_order in ("bcj", "cbj")


@pytest.mark.parametrize("case", PROBLEMS, ids=[str(c) for c in PROBLEMS])
def test_candidate_plans_legal_and_include_default(case):
    ih, iw, ic, ks, oc, s, pad = case
    p = TConvProblem(ih, iw, ic, ks, oc, s, pad)
    budget = int(V5E.vmem_bytes * 0.75)
    cands = tiling.candidate_plans(p)
    assert cands, p
    seen = set()
    for c in cands:
        assert c.block_oh % s == 0 and c.block_oh >= s
        assert 1 <= c.block_oc
        assert c.grid_order in ("bcj", "cbj")
        assert c.method in ("mm2im", "mm2im_db", "mm2im_ks", "mm2im_og")
        assert c.vmem_bytes <= budget, c.describe()
        if c.method == "mm2im_db":
            # Pipelining needs at least two row blocks to overlap.
            assert c.n_row_blocks >= 2, c.describe()
        key = (c.method, c.block_oh, c.block_oc, c.grid_order)
        assert key not in seen, f"duplicate candidate {key}"
        seen.add(key)
    # The heuristic default geometry is in the enumerated space.
    tp = tiling.plan(p)
    assert (tp.method, tp.block_oh, tp.block_oc, tp.grid_order) in seen


def test_candidate_plans_db_variant_coverage():
    """Problems with >= 2 row blocks enumerate every registered kernel
    family, and the db residency model frees VMEM vs whole-input
    residency."""
    p = TConvProblem(16, 16, 32, 3, 16, 1)
    cands = tiling.candidate_plans(p)
    methods = {c.method for c in cands}
    assert methods == {"mm2im", "mm2im_db", "mm2im_ks", "mm2im_og"}
    assert (tiling.vmem_bytes(p, 4, 16, bits=32, method="mm2im_db")
            < tiling.vmem_bytes(p, 4, 16, bits=32, method="mm2im"))
    # Geometry-identical pairs differ only in modeled residency.
    sb = tiling.plan(p, block_oh=4, block_oc=16, grid_order="bcj")
    db = tiling.plan(p, block_oh=4, block_oc=16, grid_order="bcj",
                     method="mm2im_db")
    assert (sb.n_slab, sb.n_row_blocks) == (db.n_slab, db.n_row_blocks)
    assert db.vmem_bytes < sb.vmem_bytes


def test_explicit_plan_override_roundtrip():
    p = TConvProblem(8, 8, 16, 5, 12, 2)
    tp = tiling.plan(p, block_oh=4, block_oc=8, grid_order="cbj")
    assert (tp.block_oh, tp.block_oc, tp.grid_order) == (4, 8, "cbj")
    # Partial override keeps the explicit half.
    tp2 = tiling.plan(p, block_oc=8)
    assert tp2.block_oc == 8


def test_invalid_block_oh_rejected():
    p = TConvProblem(8, 8, 16, 5, 12, 2)
    with pytest.raises(ValueError):
        tiling.plan(p, block_oh=3, block_oc=8)  # not a multiple of stride
    with pytest.raises(ValueError):
        tiling.plan(p, block_oh=0, block_oc=8)
