"""Per-architecture smoke tests (REQUIRED): reduced config of each family,
one forward + one train step on CPU, asserting output shapes + no NaNs."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.models import lm
from repro.optim import adamw

ARCHS = registry.list_archs()


def _inputs(cfg, b=2, l=16):
    toks = jax.random.randint(jax.random.PRNGKey(1), (b, l), 0, cfg.vocab)
    kw = {}
    if cfg.modality == "vision":
        kw["prefix_embeds"] = jnp.zeros((b, cfg.frontend_len, cfg.d_model))
    if cfg.enc_layers:
        kw["enc_embeds"] = jnp.zeros((b, cfg.frontend_len, cfg.d_model))
    return toks, kw


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_forward(arch):
    spec = registry.get(arch)
    cfg = spec.smoke
    params, specs = lm.init(cfg, jax.random.PRNGKey(0))
    assert jax.tree.structure(params) is not None
    toks, kw = _inputs(cfg)
    logits, aux = lm.forward(cfg, params, toks, **kw)
    expect_len = toks.shape[1] + (cfg.frontend_len if cfg.modality == "vision" else 0)
    padded_vocab = params["embed"]["table"].shape[0]
    assert logits.shape == (2, expect_len, padded_vocab)
    assert not bool(jnp.isnan(logits).any()), arch
    # padded logit columns are masked to -inf
    if padded_vocab > cfg.vocab:
        assert float(logits[..., cfg.vocab:].max()) < -1e30


@pytest.mark.parametrize("arch", ARCHS)
def test_smoke_train_step(arch):
    spec = registry.get(arch)
    cfg = spec.smoke
    params, _ = lm.init(cfg, jax.random.PRNGKey(0))
    toks, kw = _inputs(cfg)
    opt_cfg = adamw.AdamWConfig(lr=1e-3, warmup_steps=0, schedule="constant")
    opt = adamw.init(params, opt_cfg)

    @jax.jit
    def step(p, o):
        (loss, m), g = jax.value_and_grad(
            lambda pp: lm.loss_fn(cfg, pp, toks, toks, **kw),
            has_aux=True)(p)
        p2, o2, _ = adamw.apply(g, o, p, opt_cfg)
        return p2, o2, loss

    p1, o1, loss1 = step(params, opt)
    p2, o2, loss2 = step(p1, o1)
    assert np.isfinite(float(loss1)) and np.isfinite(float(loss2))
    # params actually moved
    delta = sum(float(jnp.abs(a - b).sum())
                for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(p1)))
    assert delta > 0


@pytest.mark.parametrize("arch", ["mamba2_370m", "recurrentgemma_9b",
                                  "qwen2_5_3b", "qwen2_moe_a2_7b",
                                  "grok_1_314b", "deepseek_67b",
                                  "qwen2_7b", "qwen3_32b"])
def test_smoke_decode_matches_forward(arch):
    cfg = registry.get(arch).smoke
    if cfg.n_experts:
        cfg = type(cfg)(**{**cfg.__dict__, "capacity_factor": 64.0})
    params, _ = lm.init(cfg, jax.random.PRNGKey(0))
    toks, kw = _inputs(cfg, b=2, l=8)
    if kw:
        pytest.skip("decode-vs-forward check is for pure decoder archs")
    fwd, _ = lm.forward(cfg, params, toks)
    cache = lm.init_cache(cfg, 2, 16, dtype=jnp.float32)
    outs = []
    for t in range(8):
        lg, cache = lm.decode(cfg, params, toks[:, t:t + 1], cache)
        outs.append(lg)
    dec = jnp.concatenate(outs, 1)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(fwd),
                               rtol=5e-3, atol=5e-3)


def test_registry_cells():
    cells = registry.all_cells()
    assert len(cells) == 32  # 10*4 - 8 long_500k skips
    assert ("mamba2_370m", "long_500k") in cells
    assert ("deepseek_67b", "long_500k") not in cells


def test_full_configs_match_assignment():
    """The exact published numbers from the assignment block."""
    c = registry.get("deepseek-67b").model
    assert (c.n_layers, c.d_model, c.n_heads, c.kv_heads, c.d_ff, c.vocab) == \
        (95, 8192, 64, 8, 22016, 102400)
    c = registry.get("qwen2-moe-a2.7b").model
    assert (c.n_experts, c.top_k, c.n_shared_experts, c.moe_d_ff) == (60, 4, 4, 1408)
    c = registry.get("grok-1-314b").model
    assert (c.n_layers, c.d_model, c.n_experts, c.top_k) == (64, 6144, 8, 2)
    c = registry.get("recurrentgemma-9b").model
    assert c.pattern == ("rglru", "rglru", "local_attn") and c.window == 2048
    assert c.n_layers == 38
    c = registry.get("mamba2-370m").model
    assert c.ssm_state == 128 and c.pattern == ("mamba2",)
    c = registry.get("qwen3-32b").model
    assert c.qk_norm and c.kv_heads == 8
    c = registry.get("seamless-m4t-large-v2").model
    assert c.enc_layers == 24 and c.vocab == 256206
