"""Zero-Insertion and TDC baselines vs the lax gold oracle."""

import numpy as np
import pytest
from _hypothesis_shim import given, settings, st

from repro.kernels import ref
from repro.kernels.baselines import (tdc_macs, tdc_tconv,
                                     zero_insertion_macs, zero_insertion_tconv)

RNG = np.random.default_rng(3)

CASES = [
    (1, 2, 2, 2, 3, 2, 1, "SAME"), (2, 4, 4, 3, 5, 2, 2, "SAME"),
    (1, 9, 9, 8, 5, 8, 2, "SAME"), (1, 4, 4, 8, 7, 5, 2, "SAME"),
    (1, 3, 3, 4, 3, 2, 1, "VALID"), (1, 4, 5, 4, 5, 3, 2, "VALID"),
    (1, 6, 6, 4, 2, 3, 2, "SAME"), (1, 8, 8, 4, 9, 3, 1, "SAME"),
    (1, 5, 5, 4, 4, 2, 4, "VALID"),
]


@pytest.mark.parametrize("case", CASES, ids=[str(c) for c in CASES])
def test_baselines_match_gold(case):
    b, ih, iw, ic, ks, oc, s, pad = case
    x = RNG.standard_normal((b, ih, iw, ic), np.float32)
    w = RNG.standard_normal((ks, ks, oc, ic), np.float32)
    gold = np.asarray(ref.tconv_lax(x, w, stride=s, padding=pad))
    zi = np.asarray(zero_insertion_tconv(x, w, stride=s, padding=pad))
    td = np.asarray(tdc_tconv(x, w, stride=s, padding=pad))
    np.testing.assert_allclose(zi, gold, rtol=1e-4, atol=1e-4)
    np.testing.assert_allclose(td, gold, rtol=1e-4, atol=1e-4)


@settings(max_examples=20, deadline=None)
@given(ih=st.integers(2, 9), ic=st.integers(1, 8), ks=st.integers(1, 6),
       oc=st.integers(1, 6), s=st.integers(1, 3))
def test_tdc_property(ih, ic, ks, oc, s):
    if ks < s:
        return
    x = RNG.standard_normal((1, ih, ih, ic), np.float32)
    w = RNG.standard_normal((ks, ks, oc, ic), np.float32)
    gold = np.asarray(ref.tconv_lax(x, w, stride=s))
    td = np.asarray(tdc_tconv(x, w, stride=s))
    np.testing.assert_allclose(td, gold, rtol=1e-3, atol=1e-3)


def test_mac_counters_ordering():
    """TDC is MAC-optimal-ish; zero-insertion is the most wasteful."""
    from repro.core.maps import TConvProblem, drop_stats
    p = TConvProblem(16, 16, 32, 5, 16, 2)
    effectual = drop_stats(p)["effectual_macs"]
    zi = zero_insertion_macs(p.ih, p.iw, p.ic, p.ks, p.oc, p.stride)
    td = tdc_macs(p.ih, p.iw, p.ic, p.ks, p.oc, p.stride)
    assert effectual <= td <= zi
    assert zi > 2 * effectual  # most of the dense conv hits inserted zeros
