"""HLO collective/FLOP parser unit tests on synthetic module text."""

from repro.distributed import hlo

MODULE = """
HloModule jit_step

%fused_computation (p0: f32[64,128]) -> f32[64,128] {
  %p0 = f32[64,128]{1,0} parameter(0)
  ROOT %m = f32[64,128]{1,0} multiply(%p0, %p0)
}

%body (arg: (s32[], f32[64,128])) -> (s32[], f32[64,128]) {
  %arg = (s32[], f32[64,128]) parameter(0)
  %g = f32[64,128]{1,0} get-tuple-element(%arg), index=1
  %ar = f32[64,128]{1,0} all-reduce(%g), replica_groups={}, to_apply=%sum
  %ag = f32[128,128]{1,0} all-gather(%ar), dimensions={0}
  %i = s32[] get-tuple-element(%arg), index=0
  ROOT %t = (s32[], f32[64,128]) tuple(%i, %ar)
}

ENTRY %main (a: f32[64,128], b: f32[128,256]) -> f32[64,256] {
  %a = f32[64,128]{1,0} parameter(0)
  %b = f32[128,256]{1,0} parameter(1)
  %t0 = (s32[], f32[64,128]) tuple(%c, %a)
  %w = (s32[], f32[64,128]) while(%t0), condition=%cond, body=%body, backend_config={"known_trip_count":{"n":"10"}}
  %cp = f32[64,128]{1,0} collective-permute(%a), source_target_pairs={{0,1}}
  ROOT %d = f32[64,256]{1,0} dot(%a, %b), lhs_contracting_dims={1}, rhs_contracting_dims={0}
}
"""


def test_collective_bytes_weighted_by_trip_count():
    out = hlo.collective_bytes(MODULE)
    # all-reduce operand: 64*128*4 = 32768 bytes, x10 trips
    assert out["all-reduce"] == 32768 * 10
    # all-gather operand = the all-reduce result (same shape), x10
    assert out["all-gather"] == 32768 * 10
    # permute in entry: x1
    assert out["collective-permute"] == 32768
    assert out["total"] == 32768 * 21


def test_collective_count():
    c = hlo.collective_count(MODULE)
    assert c == {"all-reduce": 1, "all-gather": 1, "collective-permute": 1}


def test_weighted_dot_flops():
    out = hlo.weighted_cost(MODULE)
    # dot: 2 * 64*256 * 128 (entry, weight 1)
    assert out["weighted_dot_flops"] == 2 * 64 * 256 * 128
