"""Cross-method differential parity harness (ISSUE-7 satellite #1).

One reusable check — :func:`assert_method_parity` — verifies any
registered TCONV method against the ``'lax'`` gold over a *pinned* grid
of configurations:

    stride ∈ {1, 2, 4} × padding ∈ {SAME, VALID} × kernel ∈ {3, 4, 5}
    × dtype ∈ {f32, int8+requant} × batch ∈ {1, 8} × fold ∈ {off, on}

This replaces the copy-pasted per-file parity loops that accumulated as
the kernel-family count grew (``test_epilogue_dispatch`` /
``test_batch_folding`` / ``test_mm2im_db_kernel``): a new registry entry
is enrolled automatically — ``tests/test_parity_matrix.py`` parametrizes
over ``registry.names()`` at collection time, so registering a kernel is
all it takes to be differential-tested against the gold.

Conventions baked into the grid:

* **Legality is derived, not hand-listed.** SAME with ``Ks < S`` is
  unsupported repo-wide (``ref.crop_offsets`` raises), so those cells are
  excluded for every method; ``fold`` cells exist only for
  ``supports_plan`` methods at ``batch > 1`` (the fold rides a plan).
* **Epilogue coverage without cell multiplication.** Each cell carries a
  deterministic (bias?, activation) pair derived from the cell key, so
  the whole activation table is exercised across the grid instead of
  multiplying every cell by every activation.
* **Tolerances per dtype.** f32 compares ``allclose(rtol=atol=1e-4)``
  against the gold (different summation orders are legal); int8+requant
  compares **bit-exact** — the operand ranges keep every accumulation
  inside the exactly-representable integer range, so any deviation is a
  real bug, not rounding.
* **Fold cells additionally assert bit-identity** with the same plan run
  unfolded: ``fold_batch`` is a performance knob and may never change
  results (the plan-v2 contract).

The gold itself is memoized per (geometry, dtype, batch, epilogue): the
grid costs one gold evaluation per cell *total*, not per method.
"""

from __future__ import annotations

import dataclasses
import zlib
from typing import Iterator, Optional, Tuple

import numpy as np

from repro.kernels import ref, registry
from repro.kernels.ops import tconv, tconv_int8
from repro.kernels.registry import Plan

STRIDES = (1, 2, 4)
PADDINGS = ("SAME", "VALID")
KERNELS = (3, 4, 5)
DTYPES = ("f32", "int8")
BATCHES = (1, 8)

#: Activation table cycled across cells (epilogue coverage without
#: multiplying the grid).
_ACTS = ("none", "relu", "tanh", "leaky_relu")

# Small rectangular spatial extent: trace cost dominates interpret-mode
# runtime, so bigger images buy nothing.  ic*ks^2*127^2 stays far below
# 2^24 — the int8 fallback's f32 accumulation is exact and the int8
# column can assert bitwise equality.
IH, IW, IC, OC = 5, 4, 4, 5
REQUANT_SCALE = 0.004


@dataclasses.dataclass(frozen=True)
class ParityCase:
    """One cell of the pinned parity grid."""

    stride: int
    padding: str
    ks: int
    dtype: str      # 'f32' | 'int8'
    batch: int
    fold: bool

    @property
    def key(self) -> str:
        return (f"{self.problem_key}:{'fold' if self.fold else 'grid'}")

    @property
    def problem_key(self) -> str:
        """Cell identity *minus* the fold knob: folded and grid runs of a
        geometry share operands, epilogue and gold (the fold may never
        change the math)."""
        return (f"s{self.stride}:{self.padding}:ks{self.ks}:{self.dtype}"
                f":b{self.batch}")

    @property
    def bias_and_activation(self) -> Tuple[bool, str]:
        """Deterministic epilogue for this cell (fold-independent)."""
        h = zlib.crc32(self.problem_key.encode())
        return bool(h & 1), _ACTS[(h >> 1) % len(_ACTS)]


def _same_legal(ks: int, stride: int, padding: str) -> bool:
    return padding != "SAME" or ks >= stride


def parity_grid(method: Optional[str] = None) -> Iterator[ParityCase]:
    """Legal cells of the pinned grid, optionally filtered for a method.

    With ``method`` given, fold cells are emitted only when the method's
    registry spec is plan-capable (the fold is threaded via a plan).
    """
    plan_capable = (method is None
                    or registry.get(method).supports_plan)
    for s in STRIDES:
        for pad in PADDINGS:
            for ks in KERNELS:
                if not _same_legal(ks, s, pad):
                    continue
                for dt in DTYPES:
                    for b in BATCHES:
                        folds = (False, True) if (b > 1 and plan_capable) \
                            else (False,)
                        for fold in folds:
                            yield ParityCase(s, pad, ks, dt, b, fold)


def _operands(case: ParityCase):
    """Deterministic operands for one cell (shared across all methods)."""
    seed = zlib.crc32(case.problem_key.encode())
    rng = np.random.default_rng(seed)
    if case.dtype == "int8":
        x = rng.integers(-128, 128, (case.batch, IH, IW, IC), dtype=np.int8)
        w = rng.integers(-128, 128, (case.ks, case.ks, OC, IC),
                         dtype=np.int8)
        bias = rng.integers(-500, 500, (OC,), dtype=np.int32)
    else:
        x = rng.standard_normal((case.batch, IH, IW, IC)).astype(np.float32)
        w = (rng.standard_normal((case.ks, case.ks, OC, IC)) * 0.1
             ).astype(np.float32)
        bias = rng.standard_normal(OC).astype(np.float32)
    use_bias, act = case.bias_and_activation
    return x, w, (bias if use_bias else None), act


def _run(method: str, case: ParityCase, plan) -> np.ndarray:
    x, w, bias, act = _operands(case)
    if case.dtype == "int8":
        out = tconv_int8(x, w, bias, REQUANT_SCALE, stride=case.stride,
                         padding=case.padding, method=method,
                         activation=act, plan=plan)
    else:
        out = tconv(x, w, bias, stride=case.stride, padding=case.padding,
                    method=method, activation=act, plan=plan)
    return np.asarray(out)


_GOLD_CACHE: dict = {}


def _gold(case: ParityCase) -> np.ndarray:
    """'lax' gold for the cell's geometry/epilogue — fold-independent."""
    key = case.problem_key
    if key not in _GOLD_CACHE:
        _GOLD_CACHE[key] = _run("lax", dataclasses.replace(case, fold=False),
                                plan=None)
    return _GOLD_CACHE[key]


def _cell_plan(case: ParityCase, *, fold: bool) -> Plan:
    # block_oh = stride => bi = 1 row per block: the smallest legal row
    # block, so every method exercises real multi-block grids.
    return Plan(case.stride, min(OC, 4), "bcj", fold_batch=fold)


def assert_method_parity(method: str, case: ParityCase) -> None:
    """Check one method on one cell of the grid against the gold.

    f32 cells compare within 1e-4; int8+requant cells compare bit-exact.
    Fold cells additionally assert bit-identity with the unfolded run of
    the same plan.
    """
    spec = registry.get(method)
    plan = _cell_plan(case, fold=case.fold) if spec.supports_plan else None
    got = _run(method, case, plan)
    want = _gold(case)
    assert got.shape == want.shape, \
        f"{method} {case.key}: shape {got.shape} != gold {want.shape}"
    if case.dtype == "int8":
        assert got.dtype == np.int8, (method, case.key, got.dtype)
        dev = np.abs(got.astype(np.int32) - want.astype(np.int32)).max()
        assert (got == want).all(), \
            f"{method} {case.key}: int8 max deviation {dev}"
    else:
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4,
                                   err_msg=f"{method} {case.key}")
    if case.fold:
        grid = _run(method, case, _cell_plan(case, fold=False))
        assert (got == grid).all(), \
            f"{method} {case.key}: folded result != grid-batch result"


def assert_full_parity(method: str, dtype: Optional[str] = None) -> None:
    """Run a method over every legal cell of the pinned grid."""
    for case in parity_grid(method):
        if dtype is not None and case.dtype != dtype:
            continue
        assert_method_parity(method, case)
