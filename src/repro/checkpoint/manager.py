"""Fault-tolerant checkpointing: atomic, async, elastic-reshardable.

Format (one directory per step):

    ckpt_dir/
      step_000100.tmp/ ...    (in-flight write)
      step_000100/
        manifest.json         (tree structure, shapes, dtypes, specs)
        arr_00000.npy ...     (one file per leaf, tree-path keyed)
      LATEST                  (atomic pointer file)

Guarantees:
* **Atomicity** — write to ``.tmp`` then ``os.rename`` (POSIX-atomic);
  a crash mid-save never corrupts the latest checkpoint.
* **Async** — ``save(...)`` snapshots to host (device_get) then writes on a
  background thread; training continues during serialization.
* **Elastic restore** — the manifest stores *global* shapes + PartitionSpecs;
  ``restore(...)`` device_puts onto ANY mesh shape (re-sharding on load), so
  a job can resume on a different pod count after failures.
* **Retention** — keep the most recent ``keep`` checkpoints.
"""

from __future__ import annotations

import json
import os
import pathlib
import shutil
import threading
from typing import Any, Optional

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P


def _flatten(tree):
    leaves, treedef = jax.tree.flatten(tree)
    return leaves, treedef


def _spec_to_json(s) -> list:
    out = []
    for part in (s or P()):
        if part is None:
            out.append(None)
        elif isinstance(part, tuple):
            out.append(list(part))
        else:
            out.append(part)
    return out


def _spec_from_json(parts) -> P:
    fixed = [tuple(p) if isinstance(p, list) else p for p in parts]
    return P(*fixed)


class CheckpointManager:
    def __init__(self, directory: str, *, keep: int = 3):
        self.dir = pathlib.Path(directory)
        self.dir.mkdir(parents=True, exist_ok=True)
        self.keep = keep
        self._thread: Optional[threading.Thread] = None

    # ------------------------------------------------------------------ save

    def save(self, step: int, state: Any, specs: Any = None,
             block: bool = False):
        """Snapshot state (device->host) and write asynchronously."""
        self.wait()  # one in-flight save at a time
        leaves, treedef = _flatten(state)
        host = [np.asarray(jax.device_get(x)) for x in leaves]
        spec_leaves = (jax.tree.leaves(
            specs, is_leaf=lambda s: isinstance(s, P))
            if specs is not None else [None] * len(host))
        if len(spec_leaves) != len(host):
            spec_leaves = [None] * len(host)
        treedef_str = str(treedef)

        def write():
            tmp = self.dir / f"step_{step:08d}.tmp"
            final = self.dir / f"step_{step:08d}"
            if tmp.exists():
                shutil.rmtree(tmp)
            tmp.mkdir()
            manifest = {"step": step, "n_leaves": len(host),
                        "treedef": treedef_str,
                        "leaves": []}
            for i, (arr, sp) in enumerate(zip(host, spec_leaves)):
                np.save(tmp / f"arr_{i:05d}.npy", arr)
                manifest["leaves"].append({
                    "shape": list(arr.shape),
                    "dtype": str(arr.dtype),
                    "spec": _spec_to_json(sp) if sp is not None else None,
                })
            (tmp / "manifest.json").write_text(json.dumps(manifest))
            if final.exists():
                shutil.rmtree(final)
            os.rename(tmp, final)  # atomic publish
            latest_tmp = self.dir / "LATEST.tmp"
            latest_tmp.write_text(str(step))
            os.rename(latest_tmp, self.dir / "LATEST")
            self._gc()

        self._thread = threading.Thread(target=write, daemon=True)
        self._thread.start()
        if block:
            self.wait()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _gc(self):
        steps = sorted(self.all_steps())
        for s in steps[: -self.keep]:
            shutil.rmtree(self.dir / f"step_{s:08d}", ignore_errors=True)

    # --------------------------------------------------------------- restore

    def all_steps(self):
        return [int(p.name.split("_")[1]) for p in self.dir.glob("step_*")
                if not p.name.endswith(".tmp")]

    def latest_step(self) -> Optional[int]:
        f = self.dir / "LATEST"
        if f.exists():
            s = int(f.read_text())
            if (self.dir / f"step_{s:08d}").exists():
                return s
        steps = self.all_steps()
        return max(steps) if steps else None

    def restore(self, step: int, like: Any, mesh=None, specs: Any = None):
        """Load a checkpoint into the structure of ``like``.

        With ``mesh`` + ``specs`` (or specs recorded in the manifest), each
        leaf is device_put with a NamedSharding built on the *target* mesh —
        elastic restore onto any topology.
        """
        path = self.dir / f"step_{step:08d}"
        manifest = json.loads((path / "manifest.json").read_text())
        leaves, treedef = _flatten(like)
        assert manifest["n_leaves"] == len(leaves), \
            f"checkpoint has {manifest['n_leaves']} leaves, state has {len(leaves)}"
        spec_leaves = (jax.tree.leaves(specs, is_leaf=lambda s: isinstance(s, P))
                       if specs is not None else [None] * len(leaves))
        if len(spec_leaves) != len(leaves):
            spec_leaves = [None] * len(leaves)
        out = []
        for i, (ref, sp) in enumerate(zip(leaves, spec_leaves)):
            arr = np.load(path / f"arr_{i:05d}.npy")
            rec = manifest["leaves"][i]
            if sp is None and rec["spec"] is not None:
                sp = _spec_from_json(rec["spec"])
            if mesh is not None:
                from repro.distributed.sharding import pad_specs_for_mesh
                sp_m = pad_specs_for_mesh(sp if sp is not None else P(), mesh)
                arr = jax.device_put(arr, NamedSharding(mesh, sp_m))
            else:
                arr = jax.device_put(arr)
            if hasattr(ref, "dtype") and str(ref.dtype) != str(arr.dtype):
                arr = arr.astype(ref.dtype)
            out.append(arr)
        return jax.tree.unflatten(treedef, out)

    def restore_latest(self, like: Any, mesh=None, specs: Any = None):
        step = self.latest_step()
        if step is None:
            return None, None
        return step, self.restore(step, like, mesh=mesh, specs=specs)
