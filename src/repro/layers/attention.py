"""Attention layers: GQA (+bias, +qk-norm), local-window, cross-attention.

Three execution paths share one parameter layout:

* :func:`attend`       — full quadratic attention (training / short prefill).
* :func:`attend_chunked` — lax.scan online-softmax ("flash-style") attention;
  bounded activation memory for 32k prefill.  Chosen by ``chunk_q``.
* :func:`decode_step`  — single-token decode against a (possibly
  sequence-sharded) KV cache; supports local-window ring caches.

Sharding: q/k/v are column-parallel over 'model' (heads), o row-parallel —
one all-reduce per layer (Megatron).  KV caches for long decode are sharded
over 'model' on the *sequence* dim (flash-decode style partial softmax —
XLA inserts the cross-shard max/sum reductions automatically).
"""

from __future__ import annotations

from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.layers.common import apply_rope, dense_init, init_rmsnorm, rmsnorm

NEG_INF = -2.0e38


def init_attention(key, d_model: int, n_heads: int, kv_heads: int,
                   head_dim: Optional[int] = None, *, qkv_bias: bool = False,
                   qk_norm: bool = False, dtype=jnp.float32):
    hd = head_dim or d_model // n_heads
    ks = jax.random.split(key, 4)
    params = {
        "wq": dense_init(ks[0], d_model, n_heads * hd, dtype),
        "wk": dense_init(ks[1], d_model, kv_heads * hd, dtype),
        "wv": dense_init(ks[2], d_model, kv_heads * hd, dtype),
        "wo": dense_init(ks[3], n_heads * hd, d_model, dtype),
    }
    specs = {
        "wq": P("data", "model"), "wk": P("data", "model"),
        "wv": P("data", "model"), "wo": P("model", "data"),
    }
    if qkv_bias:
        params.update(bq=jnp.zeros((n_heads * hd,), dtype),
                      bk=jnp.zeros((kv_heads * hd,), dtype),
                      bv=jnp.zeros((kv_heads * hd,), dtype))
        specs.update(bq=P("model"), bk=P("model"), bv=P("model"))
    if qk_norm:
        qn, qs = init_rmsnorm(hd, dtype)
        kn, _ = init_rmsnorm(hd, dtype)
        params.update(q_norm=qn, k_norm=kn)
        specs.update(q_norm=qs, k_norm=qs)
    return params, specs


def _project_qkv(params, x, n_heads: int, kv_heads: int, positions,
                 *, rope_theta: float = 10000.0, use_rope: bool = True):
    b, l, _ = x.shape
    q = x @ params["wq"]
    k = x @ params["wk"]
    v = x @ params["wv"]
    if "bq" in params:
        q, k, v = q + params["bq"], k + params["bk"], v + params["bv"]
    hd = q.shape[-1] // n_heads
    q = q.reshape(b, l, n_heads, hd)
    k = k.reshape(b, l, kv_heads, hd)
    v = v.reshape(b, l, kv_heads, hd)
    if "q_norm" in params:
        q = rmsnorm(params["q_norm"], q)
        k = rmsnorm(params["k_norm"], k)
    if use_rope:
        q = apply_rope(q, positions, rope_theta)
        k = apply_rope(k, positions, rope_theta)
    return q, k, v


def _gqa_scores(q, k):
    """q: (B,Lq,H,hd), k: (B,Lk,Hkv,hd) -> (B, Hkv, H/Hkv, Lq, Lk)."""
    b, lq, h, hd = q.shape
    hkv = k.shape[2]
    return jnp.einsum("blgrd,bmgd->bgrlm", q.reshape(b, lq, hkv, h // hkv, hd), k)


def attend(params, x, *, n_heads: int, kv_heads: int, positions=None,
           causal: bool = True, window: Optional[int] = None,
           rope_theta: float = 10000.0, use_rope: bool = True):
    """Full materialized-scores attention (train_4k path)."""
    b, l, d = x.shape
    if positions is None:
        positions = jnp.arange(l)[None, :]
    q, k, v = _project_qkv(params, x, n_heads, kv_heads, positions,
                           rope_theta=rope_theta, use_rope=use_rope)
    hd = q.shape[-1]
    with jax.named_scope("attn_core"):
        scores = _gqa_scores(q, k) / jnp.sqrt(hd).astype(jnp.float32)
        i = jnp.arange(l)[:, None]
        j = jnp.arange(l)[None, :]
        mask = jnp.ones((l, l), bool)
        if causal:
            mask &= j <= i
        if window is not None:
            mask &= j > i - window
        scores = jnp.where(mask[None, None, None], scores.astype(jnp.float32),
                           NEG_INF)
        p = jax.nn.softmax(scores, axis=-1).astype(v.dtype)
        out = jnp.einsum("bgrlm,bmgd->blgrd", p, v)
    out = out.reshape(b, l, n_heads * hd)
    return out @ params["wo"]


def attend_flash(params, x, *, n_heads: int, kv_heads: int, positions=None,
                 causal: bool = True, window: Optional[int] = None,
                 block_q: int = 512, block_k: int = 512,
                 rope_theta: float = 10000.0, use_rope: bool = True):
    """Attention through the Pallas flash kernel (TPU execution path).

    Numerically identical to :func:`attend` (tests assert it); scores
    never leave VMEM, which removes the O(L^2) HBM traffic that dominates
    the *_prefill_32k roofline cells (EXPERIMENTS §Perf hillclimb B).
    """
    from repro.kernels.flash_attention import flash_attention

    b, l, d = x.shape
    if positions is None:
        positions = jnp.arange(l)[None, :]
    q, k, v = _project_qkv(params, x, n_heads, kv_heads, positions,
                           rope_theta=rope_theta, use_rope=use_rope)
    hd = q.shape[-1]
    out = flash_attention(q, k, v, causal=causal, window=window,
                          block_q=min(block_q, l), block_k=min(block_k, l))
    return out.reshape(b, l, n_heads * hd) @ params["wo"]


def attend_chunked(params, x, *, n_heads: int, kv_heads: int, positions=None,
                   causal: bool = True, window: Optional[int] = None,
                   chunk_q: int = 512, chunk_k: int = 1024,
                   rope_theta: float = 10000.0, use_rope: bool = True):
    """Online-softmax chunked attention — O(chunk_q * L) live memory.

    Pure-JAX flash-style formulation (lax.scan over KV chunks inside a scan
    over Q chunks); numerically identical to :func:`attend` up to fp
    reassociation.  This keeps 32k-prefill activation memory bounded.
    """
    b, l, d = x.shape
    if positions is None:
        positions = jnp.arange(l)[None, :]
    q, k, v = _project_qkv(params, x, n_heads, kv_heads, positions,
                           rope_theta=rope_theta, use_rope=use_rope)
    hd = q.shape[-1]
    g = kv_heads
    r = n_heads // kv_heads
    nq = -(-l // chunk_q)
    nk = -(-l // chunk_k)
    lq_p, lk_p = nq * chunk_q, nk * chunk_k
    qp = jnp.pad(q, ((0, 0), (0, lq_p - l), (0, 0), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, lk_p - l), (0, 0), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, lk_p - l), (0, 0), (0, 0)))
    qp = qp.reshape(b, nq, chunk_q, g, r, hd).transpose(1, 0, 3, 4, 2, 5)
    kp = kp.reshape(b, nk, chunk_k, g, hd).transpose(1, 0, 3, 2, 4)
    vp = vp.reshape(b, nk, chunk_k, g, hd).transpose(1, 0, 3, 2, 4)
    scale = 1.0 / jnp.sqrt(hd).astype(jnp.float32)

    def q_step(_, qi_and_idx):
        qi, iq = qi_and_idx  # qi: (B,G,R,cq,hd)
        q_pos = iq * chunk_q + jnp.arange(chunk_q)

        def kv_step(carry, kv_and_idx):  # noqa: ANN001 — attn_core scope below
            m, s, acc = carry
            (ki, vi), ik = kv_and_idx  # ki: (B,G,ck,hd)
            k_pos = ik * chunk_k + jnp.arange(chunk_k)
            sc = jnp.einsum("bgrqd,bgkd->bgrqk", qi, ki).astype(jnp.float32) * scale
            msk = k_pos[None, :] < l
            if causal:
                msk &= k_pos[None, :] <= q_pos[:, None]
            if window is not None:
                msk &= k_pos[None, :] > q_pos[:, None] - window
            sc = jnp.where(msk[None, None, None], sc, NEG_INF)
            m_new = jnp.maximum(m, sc.max(-1))
            alpha = jnp.exp(m - m_new)
            pexp = jnp.exp(sc - m_new[..., None])
            s_new = s * alpha + pexp.sum(-1)
            acc_new = acc * alpha[..., None] + jnp.einsum(
                "bgrqk,bgkd->bgrqd", pexp.astype(vi.dtype), vi).astype(jnp.float32)
            return (m_new, s_new, acc_new), None

        m0 = jnp.full((b, g, r, chunk_q), NEG_INF, jnp.float32)
        s0 = jnp.zeros((b, g, r, chunk_q), jnp.float32)
        a0 = jnp.zeros((b, g, r, chunk_q, hd), jnp.float32)
        with jax.named_scope("attn_core"):
            (m, s, acc), _ = jax.lax.scan(kv_step, (m0, s0, a0),
                                          ((kp, vp), jnp.arange(nk)))
        out = acc / jnp.maximum(s, 1e-30)[..., None]
        return None, out.astype(x.dtype)

    _, outs = jax.lax.scan(q_step, None, (qp, jnp.arange(nq)))
    # outs: (nq, B, G, R, cq, hd) -> (B, L, H*hd)
    out = outs.transpose(1, 0, 4, 2, 3, 5).reshape(b, lq_p, n_heads * hd)[:, :l]
    return out @ params["wo"]


class KVCache(NamedTuple):
    k: jax.Array  # (B, S, Hkv, hd)
    v: jax.Array  # (B, S, Hkv, hd)
    length: jax.Array  # scalar int32 — tokens filled so far

    @staticmethod
    def empty(batch: int, seq: int, kv_heads: int, head_dim: int, dtype=jnp.bfloat16):
        shape = (batch, seq, kv_heads, head_dim)
        return KVCache(jnp.zeros(shape, dtype), jnp.zeros(shape, dtype),
                       jnp.zeros((), jnp.int32))

    @staticmethod
    def specs(seq_axis: Optional[str] = "model", batch_axis="data"):
        s = P(batch_axis, seq_axis, None, None)
        return KVCache(s, s, P())


def decode_step(params, x, cache: KVCache, *, n_heads: int, kv_heads: int,
                window: Optional[int] = None, rope_theta: float = 10000.0,
                use_rope: bool = True):
    """One-token decode.  x: (B, 1, D).  Returns (out, new_cache).

    The cache may be sequence-sharded over 'model' (flash-decode): the
    softmax reductions below contract over the sharded S dim and XLA
    inserts the partial-max/partial-sum collectives.
    For ``window`` caches the buffer is a ring of size ``window``.
    """
    b, one, d = x.shape
    s_max = cache.k.shape[1]
    pos = cache.length
    positions = jnp.full((b, 1), pos, jnp.int32)
    q, k, v = _project_qkv(params, x, n_heads, kv_heads, positions,
                           rope_theta=rope_theta, use_rope=use_rope)
    hd = q.shape[-1]
    slot = pos % s_max if window is not None else pos
    k_new = jax.lax.dynamic_update_slice(cache.k, k.astype(cache.k.dtype),
                                         (0, slot, 0, 0))
    v_new = jax.lax.dynamic_update_slice(cache.v, v.astype(cache.v.dtype),
                                         (0, slot, 0, 0))
    g, r = kv_heads, n_heads // kv_heads
    qg = q.reshape(b, g, r, hd)
    sc = jnp.einsum("bgrd,bsgd->bgrs", qg, k_new.astype(q.dtype))
    sc = sc.astype(jnp.float32) / jnp.sqrt(hd)
    idx = jnp.arange(s_max)
    if window is None:
        valid = idx <= pos
    else:
        # Ring buffer: the first min(pos+1, window) slots hold the most
        # recent tokens (in rotated order — softmax is order-invariant).
        valid = idx < jnp.minimum(pos + 1, s_max)
    sc = jnp.where(valid[None, None, None, :], sc, NEG_INF)
    p = jax.nn.softmax(sc, axis=-1)
    out = jnp.einsum("bgrs,bsgd->bgrd", p.astype(v.dtype), v_new.astype(v.dtype))
    out = out.reshape(b, 1, n_heads * hd)
    out = out @ params["wo"]
    return out, KVCache(k_new, v_new, pos + 1)


def init_cross_attention(key, d_model: int, n_heads: int, kv_heads: int,
                         dtype=jnp.float32):
    return init_attention(key, d_model, n_heads, kv_heads, dtype=dtype)


def cross_attend(params, x, enc_kv, *, n_heads: int, kv_heads: int):
    """Encoder-decoder cross attention.  enc_kv: precomputed (k, v) tuple."""
    b, l, d = x.shape
    q = (x @ params["wq"])
    if "bq" in params:
        q = q + params["bq"]
    hd = q.shape[-1] // n_heads
    q = q.reshape(b, l, n_heads, hd)
    k, v = enc_kv
    g, r = kv_heads, n_heads // kv_heads
    sc = jnp.einsum("blgrd,bmgd->bgrlm", q.reshape(b, l, g, r, hd), k)
    p = jax.nn.softmax(sc.astype(jnp.float32) / jnp.sqrt(hd), axis=-1)
    out = jnp.einsum("bgrlm,bmgd->blgrd", p.astype(v.dtype), v)
    return out.reshape(b, l, n_heads * hd) @ params["wo"]


def encoder_kv(params, enc_out, *, kv_heads: int):
    b, m, d = enc_out.shape
    k = enc_out @ params["wk"]
    v = enc_out @ params["wv"]
    if "bk" in params:
        k, v = k + params["bk"], v + params["bv"]
    hd = k.shape[-1] // kv_heads
    return k.reshape(b, m, kv_heads, hd), v.reshape(b, m, kv_heads, hd)
