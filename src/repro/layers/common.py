"""Common NN layers — functional style.

Every ``init_*`` returns ``(params, specs)`` — two trees with identical
structure, the second holding ``jax.sharding.PartitionSpec`` leaves over the
production mesh axes ``('pod', 'data', 'model')`` (see docs/DESIGN.md §6).
Sharding conventions:

* FSDP ("zero-3") storage axis is ``'data'``; tensor-parallel axis is
  ``'model'``; ``'pod'`` extends the batch axis (pure DP) unless a config
  repurposes it.
* Megatron pattern: column-parallel into the hidden (shard out-dim over
  'model'), row-parallel back out (shard in-dim over 'model').
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Optional, Sequence

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

Params = Any  # nested dict of arrays
Specs = Any  # nested dict of PartitionSpec


def dense_init(key, in_dim: int, out_dim: int, dtype=jnp.float32,
               scale: Optional[float] = None) -> jax.Array:
    scale = scale if scale is not None else 1.0 / math.sqrt(in_dim)
    return (jax.random.normal(key, (in_dim, out_dim), jnp.float32) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def init_rmsnorm(dim: int, dtype=jnp.float32):
    return {"scale": jnp.ones((dim,), dtype)}, {"scale": P(None)}


def rmsnorm(params, x, eps: float = 1e-6):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    y = xf * jax.lax.rsqrt(var + eps)
    return (y * params["scale"].astype(jnp.float32)).astype(dt)


def init_layernorm(dim: int, dtype=jnp.float32):
    return ({"scale": jnp.ones((dim,), dtype), "bias": jnp.zeros((dim,), dtype)},
            {"scale": P(None), "bias": P(None)})


def layernorm(params, x, eps: float = 1e-5):
    dt = x.dtype
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    y = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (y * params["scale"] + params["bias"]).astype(dt)


# ---------------------------------------------------------------------------
# Rotary position embedding
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float = 10000.0) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float = 10000.0) -> jax.Array:
    """x: (..., L, H, hd); positions: broadcastable to (..., L)."""
    hd = x.shape[-1]
    freqs = rope_freqs(hd, theta)  # (hd/2,)
    ang = positions[..., :, None, None].astype(jnp.float32) * freqs  # (...,L,1,hd/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# MLP (SwiGLU / GeGLU / plain)
# ---------------------------------------------------------------------------


def init_mlp(key, d_model: int, d_ff: int, kind: str = "swiglu", dtype=jnp.float32):
    ks = jax.random.split(key, 3)
    if kind in ("swiglu", "geglu"):
        params = {
            "w_gate": dense_init(ks[0], d_model, d_ff, dtype),
            "w_up": dense_init(ks[1], d_model, d_ff, dtype),
            "w_down": dense_init(ks[2], d_ff, d_model, dtype),
        }
        specs = {
            "w_gate": P("data", "model"),
            "w_up": P("data", "model"),
            "w_down": P("model", "data"),
        }
    else:  # plain gelu/relu FFN
        params = {
            "w_up": dense_init(ks[0], d_model, d_ff, dtype),
            "b_up": jnp.zeros((d_ff,), dtype),
            "w_down": dense_init(ks[1], d_ff, d_model, dtype),
            "b_down": jnp.zeros((d_model,), dtype),
        }
        specs = {
            "w_up": P("data", "model"), "b_up": P("model"),
            "w_down": P("model", "data"), "b_down": P(None),
        }
    return params, specs


def mlp(params, x, kind: str = "swiglu"):
    if kind == "swiglu":
        h = jax.nn.silu(x @ params["w_gate"]) * (x @ params["w_up"])
        return h @ params["w_down"]
    if kind == "geglu":
        h = jax.nn.gelu(x @ params["w_gate"]) * (x @ params["w_up"])
        return h @ params["w_down"]
    h = jax.nn.relu(x @ params["w_up"] + params["b_up"])
    return h @ params["w_down"] + params["b_down"]


# ---------------------------------------------------------------------------
# Transposed convolution (MM2IM-backed)
# ---------------------------------------------------------------------------


def init_tconv(key, ks: int, oc: int, ic: int, dtype=jnp.float32,
               scale: float = 0.02):
    """TCONV layer params: HWOI weights (paper layout) + bias.

    Sharding: output channels over 'model' (column-parallel), input channels
    over 'data' (FSDP storage), matching the GAN generators.
    """
    w = (jax.random.normal(key, (ks, ks, oc, ic), jnp.float32) * scale)
    params = {"w": w.astype(dtype), "b": jnp.zeros((oc,), dtype)}
    specs = {"w": P(None, None, "model", "data"), "b": P("model")}
    return params, specs


def tconv_layer(params, x, *, stride: int, padding: str = "SAME",
                method: str = "mm2im", activation: str = "none", plan=None,
                out_scale=None, out_dtype=None):
    """Apply a TCONV layer through the kernel registry.

    ``plan`` is an explicit tile plan (``kernels.registry.Plan`` or a
    ``(block_oh, block_oc[, grid_order])`` tuple), typically produced by
    ``core.autotune.autotune`` — this is how tuned plans reach model code.

    With ``plan=None`` the dispatcher consumes the on-disk autotuner cache
    automatically: if this layer's problem key (shapes, dtype, batch) was
    ever tuned, the tuned plan — including a double-buffered kernel
    preference (``Plan.method``) — applies with no threading here.
    Precedence: explicit ``plan`` > cache hit > heuristic
    (docs/AUTOTUNER.md).

    ``out_scale`` (and optionally ``out_dtype``) attach the PPU requant
    epilogue stage, making a quantized *inference* layer out of the same
    call: int8 params/activations run the paper's int8 datapath on
    kernels that fuse requant, and the dispatcher's dequant -> requant
    fallback on every other registered method — the layer code does not
    change either way.  Requantization is not differentiable (round/clip;
    the paper quantizes frozen models) — keep ``out_scale=None`` on
    training paths.
    """
    from repro.kernels.ops import tconv

    return tconv(x, params["w"], params["b"], stride=stride, padding=padding,
                 method=method, activation=activation, plan=plan,
                 out_scale=out_scale, out_dtype=out_dtype)


# ---------------------------------------------------------------------------
# Embedding / unembedding
# ---------------------------------------------------------------------------


def init_embedding(key, vocab: int, d_model: int, dtype=jnp.float32,
                   pad_to: int = 512):
    """Vocab-sharded embedding table, padded so the vocab dim divides the
    'model' axis (production convention — e.g. 50280 -> 50688)."""
    vp = -(-vocab // pad_to) * pad_to
    emb = (jax.random.normal(key, (vp, d_model), jnp.float32) * 0.02).astype(dtype)
    return {"table": emb}, {"table": P("model", "data")}


def embed(params, tokens):
    return jnp.take(params["table"], tokens, axis=0)


def unembed(params, x, vocab: Optional[int] = None):
    """Logits over the padded table; padded columns masked to -inf so the
    softmax/CE semantics match the unpadded vocab exactly."""
    logits = x @ params["table"].T
    vp = params["table"].shape[0]
    if vocab is not None and vocab != vp:
        mask = jnp.arange(vp) < vocab
        logits = jnp.where(mask, logits, jnp.finfo(jnp.float32).min)
    return logits
