"""Mixture-of-Experts layer: shared experts + routed top-k (GShard-style).

Design (DESIGN.md §6, EP):

* **Local dispatch**: each data shard routes its *local* tokens into
  per-expert capacity buffers (capacity ``C = ceil(k*T_local/E * cf)``)
  via one-hot dispatch einsums — differentiable, pjit-friendly, no host
  control flow.  Tokens over capacity are dropped (standard GShard).
* **Expert sharding**: expert weights are stored ``P(None, 'data', 'model')``
  (experts replicated, FSDP over d_model, TP over d_ff) — this keeps
  grok-1's 8x32768 experts and qwen2-moe's 60 small experts under the HBM
  budget on a (16,16) pod.  The d_model contraction over 'data' surfaces as
  an all-reduce in the collective roofline — an explicit hillclimb lever.
* **Router**: f32 logits, softmax-then-topk (qwen) with renormalization;
  auxiliary load-balancing loss returned to the train step.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.layers.common import dense_init


def init_moe(key, d_model: int, d_ff: int, n_experts: int, top_k: int,
             *, n_shared: int = 0, shared_d_ff: Optional[int] = None,
             dtype=jnp.float32):
    ks = jax.random.split(key, 5)
    e = n_experts
    params = {
        "router": dense_init(ks[0], d_model, e, jnp.float32),
        "w_gate": (jax.random.normal(ks[1], (e, d_model, d_ff), jnp.float32)
                   / jnp.sqrt(d_model)).astype(dtype),
        "w_up": (jax.random.normal(ks[2], (e, d_model, d_ff), jnp.float32)
                 / jnp.sqrt(d_model)).astype(dtype),
        "w_down": (jax.random.normal(ks[3], (e, d_ff, d_model), jnp.float32)
                   / jnp.sqrt(d_ff)).astype(dtype),
    }
    specs = {
        "router": P(None, None),
        "w_gate": P(None, "data", "model"),
        "w_up": P(None, "data", "model"),
        "w_down": P(None, "model", "data"),
    }
    if n_shared:
        sff = shared_d_ff or d_ff
        params["shared"] = {
            "w_gate": dense_init(ks[4], d_model, n_shared * sff, dtype),
            "w_up": dense_init(jax.random.fold_in(ks[4], 1), d_model, n_shared * sff, dtype),
            "w_down": dense_init(jax.random.fold_in(ks[4], 2), n_shared * sff, d_model, dtype),
        }
        specs["shared"] = {
            "w_gate": P("data", "model"),
            "w_up": P("data", "model"),
            "w_down": P("model", "data"),
        }
    return params, specs


def moe(params, x, *, top_k: int, capacity_factor: float = 1.25,
        router_softmax_before_topk: bool = True, group_size: int = 1024,
        sharding_mode: str = "replicated_gather"):
    """x: (B, L, D) -> (out, aux_loss).

    Tokens are split into groups of ``group_size`` (GShard-style) so the
    dispatch/combine tensors stay O(T * k * g * cf) instead of O(T^2) —
    the difference between 1.3 GB and 21 TB of transients at train_4k.
    Capacity is per-group: C = ceil(k * g / E * cf).

    Sharding modes (EXPERIMENTS.md §Perf, hillclimb A — chosen so no
    capacity-inflated (E, C, *) tensor is ever reduced across the mesh):

    * ``replicated_gather`` — groups stay aligned with the (data, model)
      token sharding (``group_size`` must divide the per-shard sequence),
      so dispatch/expert/combine einsums are all *batch-sharded over G*
      and fully local.  Expert weights are stored FSDP-sharded and
      ZeRO-3-gathered to replicated just-in-time inside each scanned
      layer (reverse = reduce-scatter of dw).  Right when per-layer
      expert weights are small (qwen2-moe: 60 x 2048 x 1408).
    * ``tensor_parallel`` — sequence sharding is collapsed before routing
      (one (T, D) all-gather), groups are data-sharded, expert weights
      keep d_ff sharded over 'model' (Megatron style): one (T-sized)
      all-reduce after combine.  Right when per-layer expert weights are
      too big to replicate even transiently (grok: 8 x 6144 x 32768).
    """
    from repro.distributed.sharding import constrain

    b, l, d = x.shape
    t = b * l
    g = min(group_size, t)
    while t % g:  # shrink to a divisor (shapes here are powers of two)
        g -= 1

    # Keep batch and chunk as SEPARATE leading dims (B, L/g, g, D): merging
    # them into one product-sharded axis makes XLA fall back to zero-pad +
    # all-reduce resharding (measured: a 17 GB AR per layer) — per-dim
    # shardings propagate cleanly through the un-merged reshape.
    g = min(g, l)
    while l % g:
        g -= 1
    if sharding_mode == "tensor_parallel":
        out, aux = _moe_tensor_parallel(
            params, x, g, top_k=top_k, capacity_factor=capacity_factor,
            router_softmax_before_topk=router_softmax_before_topk)
    elif sharding_mode == "fsdp_merged":
        # Flat (T//g, g) grouping with no explicit constraints: leaves all
        # collective placement to SPMD.  For grok-scale experts this
        # remains the best *expressible* layout (EXPERIMENTS §Perf C) —
        # the superior deferred-AR layout needs manual collectives that
        # crash this XLA build.
        xt = x.reshape(t // g, g, d)
        fn = lambda xg: _moe_group(
            {k: params[k] for k in ("router", "w_gate", "w_up", "w_down")},
            xg, top_k=top_k, capacity_factor=capacity_factor,
            router_softmax_before_topk=router_softmax_before_topk)
        out, aux = jax.vmap(fn)(xt)
        out = out.reshape(b, l, d)
    else:
        # Groups aligned with the (data, model) token sharding so every
        # dispatch/expert/combine einsum is batch-sharded over (B, chunk).
        # 'replicated_gather' additionally ZeRO-3-gathers the expert
        # weights to replicated just-in-time (small experts);  'fsdp'
        # leaves them FSDP-sharded and lets SPMD pick the collectives
        # (large experts that cannot be replicated even transiently).
        if sharding_mode == "replicated_gather":
            w_gate = constrain(params["w_gate"], P(None, None, None))
            w_up = constrain(params["w_up"], P(None, None, None))
            w_down = constrain(params["w_down"], P(None, None, None))
        else:  # fsdp
            w_gate, w_up, w_down = (params["w_gate"], params["w_up"],
                                    params["w_down"])
        xt = x.reshape(b, l // g, g, d)
        xt = constrain(xt, P("data", "model", None, None))
        eparams = {"router": params["router"], "w_gate": w_gate,
                   "w_up": w_up, "w_down": w_down}
        group_fn = lambda xg: _moe_group(
            eparams, xg, top_k=top_k, capacity_factor=capacity_factor,
            router_softmax_before_topk=router_softmax_before_topk)
        out, aux = jax.vmap(jax.vmap(group_fn))(xt)
        out = out.reshape(b, l, d)
    if "shared" in params:
        sp = params["shared"]
        xf = x.reshape(t, d)
        hs = jax.nn.silu(xf @ sp["w_gate"]) * (xf @ sp["w_up"])
        out = out + (hs @ sp["w_down"]).reshape(b, l, d)
    return out, aux.mean()


def _moe_tensor_parallel(params, x, g, *, top_k: int, capacity_factor: float,
                         router_softmax_before_topk: bool):
    """Expert block with d_ff tensor-parallel over 'model' (grok-scale).

    Auto-SPMD all-reduces the capacity-inflated (E, C, D) expert outputs
    (measured: 12 GB/layer on grok).  The *ideal* layout applies the
    (linear) combine einsum to the partial per-shard expert outputs under
    manual shard_map and psums only the token-sized (T, D) result — the
    same output-stationary "accumulate at the final destination"
    discipline as MM2IM's col2im (DESIGN.md §2) — but that nesting crashes
    this XLA build inside the remat'd layer scan (EXPERIMENTS §Perf C2);
    the constraint-based layout below is the best expressible fallback.
    """
    from repro.distributed.sharding import constrain

    b, l, d = x.shape
    # Keep sequence sharding (SP): collapsing it 16x-inflates the
    # per-device dispatch work and the (E, C, D) all-reduce payloads
    # (measured: C1 regression, EXPERIMENTS §Perf).  Chunks align with
    # the sequence shards when group_size divides the per-shard length.
    xt = x.reshape(b, l // g, g, d)
    xt = constrain(xt, P("data", "model", None, None))

    # NOTE: the ideal here is shard_map manual over 'model' with the
    # combine applied to *partial* expert outputs and a token-sized psum
    # (tried; hits an XLA:CPU crash — "Invalid binary instruction opcode
    # copy" — when nested in the remat'd layer scan; see EXPERIMENTS
    # §Perf C2-refuted).  The constraint-based layout below keeps d_ff
    # tensor-parallel and relies on explicit low-precision casts in
    # _moe_group to halve the capacity-inflated all-reduce.
    eparams = {
        "router": params["router"],
        "w_gate": constrain(params["w_gate"], P(None, None, "model")),
        "w_up": constrain(params["w_up"], P(None, None, "model")),
        "w_down": constrain(params["w_down"], P(None, "model", None)),
    }
    fn = lambda xx: _moe_group(
        eparams, xx, top_k=top_k, capacity_factor=capacity_factor,
        router_softmax_before_topk=router_softmax_before_topk)
    out, aux = jax.vmap(jax.vmap(fn))(xt)
    return out.reshape(b, l, d), aux


def _moe_group(params, xt, *, top_k: int, capacity_factor: float,
               router_softmax_before_topk: bool):
    """Route one token group.  xt: (g, D)."""
    t, d = xt.shape
    e = params["router"].shape[-1]
    cap = max(int(top_k * t / e * capacity_factor), 1)

    logits = (xt.astype(jnp.float32) @ params["router"])  # (T, E)
    if router_softmax_before_topk:
        probs = jax.nn.softmax(logits, axis=-1)
        gate_vals, sel = jax.lax.top_k(probs, top_k)  # (T, k)
        gate_vals = gate_vals / jnp.maximum(gate_vals.sum(-1, keepdims=True), 1e-9)
    else:
        top_logits, sel = jax.lax.top_k(logits, top_k)
        gate_vals = jax.nn.softmax(top_logits, axis=-1)

    # Position of each (token, choice) within its expert's capacity buffer.
    onehot = jax.nn.one_hot(sel, e, dtype=jnp.int32)  # (T, k, E)
    flat = onehot.reshape(t * top_k, e)
    pos_in_e = (jnp.cumsum(flat, axis=0) - flat).reshape(t, top_k, e)
    pos = (pos_in_e * onehot).sum(-1)  # (T, k)
    keep = pos < cap

    # Dispatch/combine tensors (T, E, C): one-hot expert x one-hot slot.
    # Dropped (over-capacity) choices land in a sacrificial slot `cap`
    # that is sliced away.
    e_oh = jax.nn.one_hot(sel, e, dtype=xt.dtype)  # (T, k, E)
    c_oh = jax.nn.one_hot(jnp.where(keep, pos, cap), cap + 1,
                          dtype=xt.dtype)[..., :cap]  # (T, k, C)
    disp = jnp.einsum("tke,tkc->tec", e_oh, c_oh)
    combine = jnp.einsum("tk,tke,tkc->tec", gate_vals.astype(xt.dtype), e_oh, c_oh)

    # Keep every capacity-inflated tensor in the activation dtype — the
    # (E, C, *) tensors are what tensor-parallel mode all-reduces, and an
    # f32 upcast here doubles that traffic (EXPERIMENTS §Perf C).
    dt = xt.dtype
    xe = jnp.einsum("tec,td->ecd", disp, xt).astype(dt)  # (E, C, D)
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, params["w_gate"])).astype(dt)
    h = h * jnp.einsum("ecd,edf->ecf", xe, params["w_up"]).astype(dt)
    ye = jnp.einsum("ecf,efd->ecd", h, params["w_down"]).astype(dt)  # (E, C, D)
    out = jnp.einsum("tec,ecd->td", combine, ye)

    # GShard aux loss: mean_e (fraction_tokens_e * mean_router_prob_e) * E.
    me = jax.nn.one_hot(sel[:, 0], e, dtype=jnp.float32).mean(0)
    pe = jax.nn.softmax(logits, axis=-1).mean(0)
    aux = (me * pe).sum() * e
    return out, aux
