"""RG-LRU recurrent block (RecurrentGemma / Griffin, arXiv:2402.19427).

Recurrence (per channel):
    r_t = sigmoid(W_a x_t + b_a)          (recurrence gate)
    i_t = sigmoid(W_x x_t + b_x)          (input gate)
    a_t = exp(-c * softplus(Lambda) * r_t)
    h_t = a_t * h_{t-1} + sqrt(1 - a_t^2) * (i_t * x_t)

Linear diagonal recurrence -> training uses ``jax.lax.associative_scan``
(log-depth, scan-parallel); decode is O(1) per step.  The full residual
block is: conv1d(4) -> RG-LRU inside a gated (GeGLU-style) branch, as in
Griffin's "recurrent block".
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.layers.common import dense_init

_C = 8.0  # Griffin's fixed scaling constant


def init_rglru_block(key, d_model: int, *, d_rnn: int | None = None,
                     d_conv: int = 4, dtype=jnp.float32):
    d_rnn = d_rnn or d_model
    ks = jax.random.split(key, 6)
    params = {
        "w_x": dense_init(ks[0], d_model, d_rnn, dtype),      # main branch in
        "w_gate": dense_init(ks[1], d_model, d_rnn, dtype),   # gelu gate branch
        "conv_w": (jax.random.normal(ks[2], (d_conv, d_rnn), jnp.float32) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((d_rnn,), dtype),
        "w_a": dense_init(ks[3], d_rnn, d_rnn, dtype),
        "b_a": jnp.zeros((d_rnn,), jnp.float32),
        "w_i": dense_init(ks[4], d_rnn, d_rnn, dtype),
        "b_i": jnp.zeros((d_rnn,), jnp.float32),
        "lam": jnp.full((d_rnn,), 0.5, jnp.float32),  # Lambda (pre-softplus)
        "w_out": dense_init(ks[5], d_rnn, d_model, dtype),
    }
    specs = {
        "w_x": P("data", "model"), "w_gate": P("data", "model"),
        "conv_w": P(None, "model"), "conv_b": P("model"),
        "w_a": P("data", "model"), "b_a": P("model"),
        "w_i": P("data", "model"), "b_i": P("model"),
        "lam": P("model"),
        "w_out": P("model", "data"),
    }
    return params, specs


def _gates(params, u):
    r = jax.nn.sigmoid(u.astype(jnp.float32) @ params["w_a"].astype(jnp.float32)
                       + params["b_a"])
    i = jax.nn.sigmoid(u.astype(jnp.float32) @ params["w_i"].astype(jnp.float32)
                       + params["b_i"])
    log_a = -_C * jax.nn.softplus(params["lam"]) * r  # (B,L,Drnn), negative
    return log_a, i


class RGLRUState(NamedTuple):
    conv: jax.Array  # (B, d_conv-1, d_rnn)
    h: jax.Array     # (B, d_rnn) f32
    length: jax.Array

    @staticmethod
    def specs(batch_axis="data"):
        return RGLRUState(P(batch_axis, None, "model"), P(batch_axis, "model"), P())


def _causal_conv(x, w, b):
    k = w.shape[0]
    pad = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    return sum(pad[:, i: pad.shape[1] - (k - 1 - i), :] * w[i][None, None]
               for i in range(k)) + b[None, None]


def rglru_block(params, x):
    """Full recurrent block forward.  x: (B, L, D) -> (B, L, D)."""
    gate = jax.nn.gelu(x @ params["w_gate"])
    u = x @ params["w_x"]
    u = _causal_conv(u, params["conv_w"], params["conv_b"])

    log_a, i_gate = _gates(params, u)
    a = jnp.exp(log_a)
    gated_in = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) \
        * (i_gate * u.astype(jnp.float32))

    def combine(l, r):
        (al, hl), (ar, hr) = l, r
        return al * ar, hl * ar + hr

    a_t = a.transpose(1, 0, 2)          # (L, B, D)
    x_t = gated_in.transpose(1, 0, 2)
    _, h = jax.lax.associative_scan(combine, (a_t, x_t))
    h = h.transpose(1, 0, 2).astype(x.dtype)
    return (h * gate) @ params["w_out"]


def rglru_init_state(batch: int, d_rnn: int, *, d_conv: int = 4,
                     dtype=jnp.float32) -> RGLRUState:
    return RGLRUState(jnp.zeros((batch, d_conv - 1, d_rnn), dtype),
                      jnp.zeros((batch, d_rnn), jnp.float32),
                      jnp.zeros((), jnp.int32))


def rglru_step(params, x, state: RGLRUState):
    """Single-token decode.  x: (B, 1, D)."""
    gate = jax.nn.gelu(x[:, 0] @ params["w_gate"])
    u = x[:, 0] @ params["w_x"]
    hist = jnp.concatenate([state.conv, u[:, None]], 1)
    u = (hist * params["conv_w"][None]).sum(1) + params["conv_b"][None]

    log_a, i_gate = _gates(params, u[:, None])
    log_a, i_gate = log_a[:, 0], i_gate[:, 0]
    a = jnp.exp(log_a)
    h = state.h * a + jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) \
        * (i_gate * u.astype(jnp.float32))
    out = ((h.astype(x.dtype) * gate) @ params["w_out"])[:, None]
    return out, RGLRUState(hist[:, 1:], h, state.length + 1)
