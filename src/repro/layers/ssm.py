"""Mamba-2 SSD (state-space duality) block — arXiv:2405.21060.

Scalar-identity SSM per head:  h_t = a_t * h_{t-1} + (b_t dt_t) x_t,
y_t = c_t^T h_t, with a_t = exp(-dt_t * A_head).  The SSD *chunked* algorithm
computes, per chunk of length Q:

  * intra-chunk: a masked quadratic "attention" term  (C_i^T B_j) * decay
  * inter-chunk: chunk-final states carried by an exclusive cumulative
    product of chunk decays (associative scan over chunks)

This gives O(L*Q) work (linear in L) and is the reason mamba2 *runs* the
``long_500k`` shape that quadratic attention cannot.

Decode is a single recurrent state update: state (B, H, P, N).

Layout: x is expanded to (B, L, H, P=head_dim); B/C are (B, L, G, N) with G
state groups (G=1 here, the mamba2 default ngroups=1, broadcast to heads).
A short depthwise causal conv1d precedes the SSM as in the paper.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.layers.common import dense_init


def init_mamba2(key, d_model: int, *, head_dim: int = 64, expand: int = 2,
                d_state: int = 128, d_conv: int = 4, dtype=jnp.float32):
    d_inner = expand * d_model
    n_heads = d_inner // head_dim
    ks = jax.random.split(key, 6)
    # Fused input projection: [x (d_inner), z gate (d_inner), B (N), C (N), dt (H)]
    d_proj = 2 * d_inner + 2 * d_state + n_heads
    params = {
        "w_in": dense_init(ks[0], d_model, d_proj, dtype),
        "conv_w": (jax.random.normal(ks[1], (d_conv, d_inner + 2 * d_state),
                                     jnp.float32) * 0.1).astype(dtype),
        "conv_b": jnp.zeros((d_inner + 2 * d_state,), dtype),
        "a_log": jnp.zeros((n_heads,), jnp.float32),  # A = -exp(a_log)
        "dt_bias": jnp.zeros((n_heads,), jnp.float32),
        "d_skip": jnp.ones((n_heads,), jnp.float32),
        "norm_scale": jnp.ones((d_inner,), dtype),
        "w_out": dense_init(ks[2], d_inner, d_model, dtype),
    }
    specs = {
        "w_in": P("data", "model"),
        "conv_w": P(None, "model"), "conv_b": P("model"),
        "a_log": P(None), "dt_bias": P(None), "d_skip": P(None),
        "norm_scale": P("model"),
        "w_out": P("model", "data"),
    }
    return params, specs


def _split_proj(params, proj, d_model: int, head_dim: int, expand: int, d_state: int):
    d_inner = expand * d_model
    n_heads = d_inner // head_dim
    xbc, z, dt = jnp.split(proj, [d_inner + 2 * d_state,
                                  2 * d_inner + 2 * d_state], axis=-1)
    return xbc, z, dt, d_inner, n_heads


def _causal_conv(xbc, w, b):
    """Depthwise causal conv1d over (B, L, C) with kernel (K, C)."""
    k = w.shape[0]
    pad = jnp.pad(xbc, ((0, 0), (k - 1, 0), (0, 0)))
    out = sum(pad[:, i : pad.shape[1] - (k - 1 - i), :] * w[i][None, None]
              for i in range(k))
    return jax.nn.silu(out + b[None, None])


class SSMState(NamedTuple):
    conv: jax.Array   # (B, d_conv-1, d_inner + 2N) — conv tail buffer
    ssm: jax.Array    # (B, H, P, N) — recurrent state
    length: jax.Array

    @staticmethod
    def specs(batch_axis="data"):
        return SSMState(P(batch_axis, None, "model"),
                        P(batch_axis, "model", None, None), P())


def mamba2(params, x, *, head_dim: int = 64, expand: int = 2,
           d_state: int = 128, chunk: int = 256):
    """Chunked SSD forward.  x: (B, L, D) -> (B, L, D)."""
    b, l, d = x.shape
    proj = x @ params["w_in"]
    xbc, z, dt, d_inner, n_heads = _split_proj(params, proj, d, head_dim,
                                               expand, d_state)
    xbc = _causal_conv(xbc, params["conv_w"], params["conv_b"])
    xs, bmat, cmat = jnp.split(xbc, [d_inner, d_inner + d_state], axis=-1)
    xh = xs.reshape(b, l, n_heads, head_dim)  # (B,L,H,P)

    dt = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])  # (B,L,H)
    a = -jnp.exp(params["a_log"])  # (H,)
    # log decay per step: la[t] = dt[t] * a  (negative)
    la = dt * a[None, None]  # (B,L,H)
    xdt = xh * dt[..., None].astype(xh.dtype)  # fold dt into input

    nq = -(-l // chunk)
    lp = nq * chunk
    pad = lambda t: jnp.pad(t, ((0, 0), (0, lp - l)) + ((0, 0),) * (t.ndim - 2))
    xdt_c = pad(xdt).reshape(b, nq, chunk, n_heads, head_dim)
    b_c = pad(bmat).reshape(b, nq, chunk, d_state)
    c_c = pad(cmat).reshape(b, nq, chunk, d_state)
    la_c = pad(la).reshape(b, nq, chunk, n_heads)

    # Within-chunk cumulative log-decay (inclusive) and chunk totals.
    cum = jnp.cumsum(la_c, axis=2)              # (B,nq,Q,H)
    tot = cum[:, :, -1]                          # (B,nq,H)

    # ---- intra-chunk (quadratic within chunk): y_intra[t] =
    #   sum_{s<=t} C_t.B_s * exp(cum[t]-cum[s]) * xdt[s]
    decay = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # (B,nq,T,S,H)
    mask = jnp.tril(jnp.ones((chunk, chunk), bool))
    gm = jnp.where(mask[None, None, :, :, None], jnp.exp(decay), 0.0)
    cb = jnp.einsum("bqtn,bqsn->bqts", c_c, b_c)  # (B,nq,T,S)
    y_intra = jnp.einsum("bqts,bqtsh,bqshp->bqthp", cb.astype(jnp.float32),
                         gm, xdt_c.astype(jnp.float32))

    # ---- chunk-final states: S_q = sum_s exp(tot - cum[s]) B_s xdt_s^T
    state_w = jnp.exp(tot[:, :, None, :] - cum)  # (B,nq,S,H)
    chunk_states = jnp.einsum("bqsn,bqsh,bqshp->bqhpn", b_c.astype(jnp.float32),
                              state_w, xdt_c.astype(jnp.float32))

    # ---- inter-chunk scan: H_q = exp(tot_q) H_{q-1} + S_q  (associative)
    def combine(left, right):
        (gl, sl), (gr, sr) = left, right
        return gl * gr, sl * gr[..., None, None] + sr

    gains = jnp.exp(tot).transpose(1, 0, 2)  # (nq,B,H)
    states = chunk_states.transpose(1, 0, 2, 3, 4)  # (nq,B,H,P,N)
    g_sc, s_sc = jax.lax.associative_scan(combine, (gains, states))
    # exclusive prefix: state entering chunk q
    init = jnp.zeros_like(s_sc[:1])
    s_in = jnp.concatenate([init, s_sc[:-1]], 0).transpose(1, 0, 2, 3, 4)

    # ---- inter-chunk contribution: y_inter[t] = exp(cum[t]) C_t . H_in
    y_inter = jnp.einsum("bqtn,bqth,bqhpn->bqthp", c_c.astype(jnp.float32),
                         jnp.exp(cum), s_in)

    y = (y_intra + y_inter).reshape(b, lp, n_heads, head_dim)[:, :l]
    y = y + params["d_skip"][None, None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(b, l, d_inner).astype(x.dtype)
    # Gated RMSNorm (mamba2's norm-before-out with z gate).
    y = y * jax.nn.silu(z)
    var = jnp.mean(jnp.square(y.astype(jnp.float32)), -1, keepdims=True)
    y = (y.astype(jnp.float32) * jax.lax.rsqrt(var + 1e-6)).astype(x.dtype)
    y = y * params["norm_scale"][None, None]
    return y @ params["w_out"]


def mamba2_init_state(batch: int, d_model: int, *, head_dim: int = 64,
                      expand: int = 2, d_state: int = 128, d_conv: int = 4,
                      dtype=jnp.float32) -> SSMState:
    d_inner = expand * d_model
    n_heads = d_inner // head_dim
    return SSMState(
        conv=jnp.zeros((batch, d_conv - 1, d_inner + 2 * d_state), dtype),
        ssm=jnp.zeros((batch, n_heads, head_dim, d_state), jnp.float32),
        length=jnp.zeros((), jnp.int32),
    )


def mamba2_step(params, x, state: SSMState, *, head_dim: int = 64,
                expand: int = 2, d_state: int = 128):
    """Single-token recurrent decode.  x: (B, 1, D)."""
    b, _, d = x.shape
    proj = x[:, 0] @ params["w_in"]
    d_inner = expand * d
    n_heads = d_inner // head_dim
    xbc, z, dt = jnp.split(proj, [d_inner + 2 * d_state,
                                  2 * d_inner + 2 * d_state], axis=-1)
    # conv ring: append, convolve last d_conv entries
    hist = jnp.concatenate([state.conv, xbc[:, None]], 1)  # (B, d_conv, C)
    w = params["conv_w"]
    conv_out = jax.nn.silu((hist * w[None]).sum(1) + params["conv_b"][None])
    xs, bvec, cvec = jnp.split(conv_out, [d_inner, d_inner + d_state], axis=-1)
    xh = xs.reshape(b, n_heads, head_dim)

    dtv = jax.nn.softplus(dt.astype(jnp.float32) + params["dt_bias"])  # (B,H)
    a = -jnp.exp(params["a_log"])
    gain = jnp.exp(dtv * a[None])  # (B,H)
    upd = jnp.einsum("bn,bhp->bhpn", bvec.astype(jnp.float32),
                     (xh * dtv[..., None].astype(xh.dtype)).astype(jnp.float32))
    new_ssm = state.ssm * gain[..., None, None] + upd
    y = jnp.einsum("bn,bhpn->bhp", cvec.astype(jnp.float32), new_ssm)
    y = y + params["d_skip"][None, :, None] * xh.astype(jnp.float32)
    y = y.reshape(b, d_inner).astype(x.dtype) * jax.nn.silu(z)
    var = jnp.mean(jnp.square(y.astype(jnp.float32)), -1, keepdims=True)
    y = (y.astype(jnp.float32) * jax.lax.rsqrt(var + 1e-6)).astype(x.dtype)
    y = y * params["norm_scale"][None]
    out = (y @ params["w_out"])[:, None]
    return out, SSMState(hist[:, 1:], new_ssm, state.length + 1)
