"""Double-buffered MM2IM — the pipelined-DMA variant of the fused kernel.

The single-buffered kernel (``mm2im_pallas.py``) keeps the *whole* padded
input resident in VMEM and lets Pallas's automatic pipelining stage the
weight/output blocks.  That leaves two things on the table (docs/DESIGN.md
§2.4):

* the initial whole-input copy is serial — compute cannot start until the
  full ``(Ihp, Iw, Ic)`` slab landed in VMEM (the paper's SECDA profiling
  shows exactly this data-in stall, and its MM2IM engine pipelines
  ``SendInputRows`` against the MACs to hide it);
* VMEM must hold the whole input, which caps the legal block space for
  large images.

This variant restores the paper's pipeline on TPU: the input stays in HBM
(``ANY`` memory space) and the per-row-block input slab is DMA'd into a
**two-slot VMEM scratch** while the MatMul + col2im of the *previous* block
runs — classic double buffering (``pltpu.make_async_copy`` + DMA
semaphores).  Output row-blocks leave through a mirrored two-slot scratch,
so the HBM write of block ``j-1`` overlaps the compute of block ``j`` too.
The row-block loop that the single-buffered kernel expresses as the inner
grid dimension becomes an in-kernel ``fori_loop``.

Numerics: host staging, the MXU MatMul, the col2im residue adds and the
PPU epilogue are *shared code* with the single-buffered kernel
(``prepare_mm2im`` / ``matmul_slab`` / ``col2im_accumulate`` /
``ppu_epilogue``), so both variants are **bit-identical** — the autotuner
(``core/autotune.py``) is free to pick per problem on speed alone.

Interpret-mode note: the async-copy/semaphore path itself runs under
``interpret=True`` (Pallas simulates the DMAs), and a plain synchronous
copy fallback is kept behind ``pipeline='sync'`` (or
``REPRO_MM2IM_DB_SYNC=1``) for environments whose interpreter lacks
semaphore support.  Both paths execute the same shared block math.
"""

from __future__ import annotations

import functools
import os
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.mm2im_pallas import (
    MM2IMPrep,
    col2im_accumulate,
    grid_semantics,
    matmul_slab,
    ppu_epilogue,
    prepare_mm2im,
)

_N_SLOTS = 2  # two-slot scratch: fill slot A while computing from slot B


def _mm2im_db_kernel(
    x_hbm_ref, w_ref, b_ref, s_ref, o_hbm_ref,   # operands (x/o in ANY/HBM)
    slab_ref, outb_ref, *sems,                   # two-slot scratch (+ sems)
    batch_axis: int, n_j: int, block_oh: int, oc_p: int, async_copies: bool,
    s: int, ks: int, ct: int, cl: int, bi: int, n_slab: int, iw: int,
    ow: int, ow_p: int, boc: int, delta: int, acc_dtype, out_dtype,
    activation: str, out_scale, per_channel: bool,
):
    """One grid cell: ALL row blocks of one (batch, oc-block) pair.

    Pipeline (async path), steady state at block ``j``:

        in-DMA  slab[j+1]  ──start──┐                 (hides SendInputRows)
        in-DMA  slab[j]    ──wait───┤
        out-DMA out[j-2]   ──wait───┤  (slot j%2 free)
        MXU+VPU block j    ─────────┤  MatMul + col2im + PPU epilogue
        out-DMA out[j]     ──start──┘                 (hides the HBM write)

    The sync fallback replaces the four DMA arrows with direct VMEM
    reads/writes of the same slices — identical block math either way.
    """
    bsel = pl.program_id(batch_axis)
    csel = pl.program_id(1 - batch_axis)
    if async_copies:
        in_sem, out_sem = sems

    def in_dma(slot, j):
        return pltpu.make_async_copy(
            x_hbm_ref.at[bsel, pl.dslice(j * bi, n_slab)],
            slab_ref.at[pl.dslice(slot * n_slab, n_slab)],
            in_sem.at[slot])

    def out_dma(slot, j):
        return pltpu.make_async_copy(
            outb_ref.at[pl.dslice(slot * block_oh, block_oh)],
            o_hbm_ref.at[bsel, pl.dslice(j * block_oh, block_oh), :,
                         pl.dslice(csel * boc, boc)],
            out_sem.at[slot])

    if async_copies:
        in_dma(0, 0).start()  # pipeline warm-up: first slab in flight

    def body(j, _):
        slot = jax.lax.rem(j, _N_SLOTS)
        if async_copies:
            @pl.when(j + 1 < n_j)
            def _prefetch():
                in_dma(jax.lax.rem(j + 1, _N_SLOTS), j + 1).start()
            in_dma(slot, j).wait()
            # Slot j%2 last carried block j-2; its out-DMA must land before
            # the epilogue below overwrites the scratch.
            @pl.when(j >= _N_SLOTS)
            def _retire():
                out_dma(slot, j - _N_SLOTS).wait()
        else:
            slab_ref[pl.dslice(slot * n_slab, n_slab)] = (
                x_hbm_ref[bsel, pl.dslice(j * bi, n_slab)])

        slab = slab_ref[pl.dslice(slot * n_slab, n_slab)]
        mm5 = matmul_slab(slab, w_ref[...], n_slab=n_slab, iw=iw, ks=ks,
                          boc=boc, acc_dtype=acc_dtype)
        out = col2im_accumulate(
            mm5, s=s, ks=ks, ct=ct, cl=cl, bi=bi, n_slab=n_slab, iw=iw,
            ow=ow, ow_p=ow_p, boc=boc, delta=delta, acc_dtype=acc_dtype)
        out = ppu_epilogue(
            out, b_ref[...], s_ref[...], acc_dtype=acc_dtype,
            activation=activation, out_scale=out_scale,
            per_channel=per_channel, out_dtype=out_dtype)

        if async_copies:
            outb_ref[pl.dslice(slot * block_oh, block_oh)] = out
            out_dma(slot, j).start()
        else:
            o_hbm_ref[bsel, pl.dslice(j * block_oh, block_oh), :,
                      pl.dslice(csel * boc, boc)] = out
        return 0

    jax.lax.fori_loop(0, n_j, body, 0)

    if async_copies:
        # Drain: the last one or two output DMAs are still in flight.
        if n_j >= _N_SLOTS:
            out_dma((n_j - 2) % _N_SLOTS, n_j - 2).wait()
        out_dma((n_j - 1) % _N_SLOTS, n_j - 1).wait()


def _mm2im_db_folded_kernel(
    x_hbm_ref, w_ref, b_ref, s_ref, o_hbm_ref,   # operands (x/o in ANY/HBM)
    slab_ref, outb_ref, *sems,                   # two-slot scratch (+ sems)
    b: int, n_j: int, block_oh: int, oc_p: int, async_copies: bool,
    s: int, ks: int, ct: int, cl: int, bi: int, n_slab: int, iw: int,
    ow: int, ow_p: int, boc: int, delta: int, acc_dtype, out_dtype,
    activation: str, out_scale, per_channel: bool,
):
    """Batch-folded grid cell: ALL row blocks of one oc-block, all batches.

    Same two-slot pipeline as :func:`_mm2im_db_kernel`, but each in-DMA
    fetches the *batch-concatenated* slab ``x[:, j*bi : j*bi+n_slab]``
    (shape ``(B, n_slab, Iw, Ic)``) into one slot, the MatMul folds it
    into a single ``(B·n_slab·Iw, Ic)`` MXU product, and col2im + the PPU
    epilogue run per batch element over views of the folded product (the
    unfolded reduction order, so bit-identical — docs/DESIGN.md §2.5).
    The grid drops both the batch axis and the row-block axis:
    ``grid = (oc-blocks,)``.
    """
    csel = pl.program_id(0)
    if async_copies:
        in_sem, out_sem = sems

    def in_dma(slot, j):
        return pltpu.make_async_copy(
            x_hbm_ref.at[:, pl.dslice(j * bi, n_slab)],
            slab_ref.at[slot],
            in_sem.at[slot])

    def out_dma(slot, j):
        return pltpu.make_async_copy(
            outb_ref.at[slot],
            o_hbm_ref.at[:, pl.dslice(j * block_oh, block_oh), :,
                         pl.dslice(csel * boc, boc)],
            out_sem.at[slot])

    if async_copies:
        in_dma(0, 0).start()  # pipeline warm-up: first folded slab in flight

    def body(j, _):
        slot = jax.lax.rem(j, _N_SLOTS)
        if async_copies:
            @pl.when(j + 1 < n_j)
            def _prefetch():
                in_dma(jax.lax.rem(j + 1, _N_SLOTS), j + 1).start()
            in_dma(slot, j).wait()
            @pl.when(j >= _N_SLOTS)
            def _retire():
                out_dma(slot, j - _N_SLOTS).wait()
        else:
            slab_ref[slot] = x_hbm_ref[:, pl.dslice(j * bi, n_slab)]

        slab = slab_ref[slot]  # (B, n_slab, iw, ic)
        mm5 = matmul_slab(slab, w_ref[...], n_slab=b * n_slab, iw=iw, ks=ks,
                          boc=boc, acc_dtype=acc_dtype)
        for e in range(b):
            out = col2im_accumulate(
                mm5[e * n_slab:(e + 1) * n_slab], s=s, ks=ks, ct=ct, cl=cl,
                bi=bi, n_slab=n_slab, iw=iw, ow=ow, ow_p=ow_p, boc=boc,
                delta=delta, acc_dtype=acc_dtype)
            out = ppu_epilogue(
                out, b_ref[...], s_ref[...], acc_dtype=acc_dtype,
                activation=activation, out_scale=out_scale,
                per_channel=per_channel, out_dtype=out_dtype)
            if async_copies:
                outb_ref[slot, e] = out
            else:
                o_hbm_ref[e, pl.dslice(j * block_oh, block_oh), :,
                          pl.dslice(csel * boc, boc)] = out
        if async_copies:
            out_dma(slot, j).start()
        return 0

    jax.lax.fori_loop(0, n_j, body, 0)

    if async_copies:
        # Drain: the last one or two output DMAs are still in flight.
        if n_j >= _N_SLOTS:
            out_dma((n_j - 2) % _N_SLOTS, n_j - 2).wait()
        out_dma((n_j - 1) % _N_SLOTS, n_j - 1).wait()


def mm2im_db_tconv(
    x: jax.Array,
    w: jax.Array,
    bias: Optional[jax.Array] = None,
    *,
    stride: int,
    padding: str = "SAME",
    block_oh: Optional[int] = None,
    block_oc: Optional[int] = None,
    activation: str = "none",
    out_scale: Optional[float] = None,
    out_dtype=None,
    grid_order: str = "auto",
    interpret: Optional[bool] = None,
    pipeline: str = "auto",
    fold_batch: bool = False,
) -> jax.Array:
    """Double-buffered MM2IM transposed convolution.

    Same contract as ``mm2im_pallas.mm2im_tconv`` (same dtypes, epilogue
    fusions and plan knobs incl. ``fold_batch``), bit-identical outputs.
    ``pipeline`` selects the slab-copy mechanism: ``'async'`` (pltpu async
    copy + semaphores), ``'sync'`` (direct VMEM copies — the
    interpret-safe fallback), or ``'auto'`` (async unless
    ``REPRO_MM2IM_DB_SYNC=1``).  With ``fold_batch=True`` the two-slot
    pipeline fetches batch-concatenated slabs and the grid is the
    oc-block axis alone.
    """
    p = prepare_mm2im(
        x, w, bias, stride=stride, padding=padding, block_oh=block_oh,
        block_oc=block_oc, activation=activation, out_scale=out_scale,
        out_dtype=out_dtype, grid_order=grid_order, interpret=interpret,
        fold_batch=fold_batch)

    if pipeline == "auto":
        pipeline = ("sync" if os.environ.get("REPRO_MM2IM_DB_SYNC", "")
                    .lower() in ("1", "true", "yes", "on") else "async")
    if pipeline not in ("async", "sync"):
        raise ValueError(
            f"pipeline must be 'auto'|'async'|'sync', got {pipeline!r}")
    async_copies = pipeline == "async"

    # j (the row-block sweep) is pipelined inside the kernel, so the grid is
    # only the outer pair of the Alg. 1 loop nest — or, batch-folded, the
    # oc-block axis alone (bcj/cbj collapse with the batch axis).
    if p.fold_batch:
        grid = (p.n_c,)
        iw_ = lambda c: (0, 0, c)
        ib = lambda c: (c,)
        kernel = functools.partial(
            _mm2im_db_folded_kernel,
            b=p.b, n_j=p.n_j, block_oh=p.block_oh, oc_p=p.oc_p,
            async_copies=async_copies, **p.kernel_kwargs())
        scratch = [
            pltpu.VMEM((_N_SLOTS, p.b, p.n_slab, p.iw, p.ic), p.x_p.dtype),
            pltpu.VMEM((_N_SLOTS, p.b, p.block_oh, p.ow_p, p.boc),
                       p.out_dtype),
        ]
    else:
        if p.grid_order == "bcj":
            grid = (p.b, p.n_c)
            batch_axis = 0
        else:  # "cbj"
            grid = (p.n_c, p.b)
            batch_axis = 1
        iw_ = lambda *ids: (0, 0, ids[1 - batch_axis])
        ib = lambda *ids: (ids[1 - batch_axis],)
        kernel = functools.partial(
            _mm2im_db_kernel,
            batch_axis=batch_axis, n_j=p.n_j, block_oh=p.block_oh,
            oc_p=p.oc_p, async_copies=async_copies, **p.kernel_kwargs())
        scratch = [
            pltpu.VMEM((_N_SLOTS * p.n_slab, p.iw, p.ic), p.x_p.dtype),
            pltpu.VMEM((_N_SLOTS * p.block_oh, p.ow_p, p.boc), p.out_dtype),
        ]
    if async_copies:
        scratch += [pltpu.SemaphoreType.DMA((_N_SLOTS,)),
                    pltpu.SemaphoreType.DMA((_N_SLOTS,))]

    any_space = pl.BlockSpec(memory_space=pltpu.TPUMemorySpace.ANY)
    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            any_space,  # x stays in HBM; slabs are DMA'd per row-block
            pl.BlockSpec((p.ic, p.ks * p.ks, p.boc), iw_),
            pl.BlockSpec((p.boc,), ib),
            pl.BlockSpec((p.boc,), ib),
        ],
        out_specs=any_space,  # o written per row-block via the out pipeline
        out_shape=jax.ShapeDtypeStruct(
            (p.b, p.n_j * p.block_oh, p.ow_p, p.oc_p), p.out_dtype),
        scratch_shapes=scratch,
        compiler_params=grid_semantics(len(grid), inner_arbitrary=False),
        interpret=p.interpret,
    )(p.x_p, p.w3, p.bias_p, p.scales_p)

    return out[:, :p.oh, :p.ow, :p.oc]
