"""Tiled MXU matmul Pallas kernel — the dense-MatMul perf control.

Used by benchmarks as the "pure MatMul" reference point for the IOM
pipeline (the unfused baseline = this + a scatter pass) and as a
standalone primitive.  Canonical 3-D blocked schedule:

  grid = (M/bm, N/bn, K/bk)   — K innermost (revisiting accumulation)
  A block (bm, bk), B block (bk, bn), out block (bm, bn) revisited across
  the K sweep with a VMEM f32 scratch accumulator.

Validated against jnp.dot in interpret mode (f32/bf16/int8 paths).
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _mm_kernel(a_ref, b_ref, o_ref, acc_ref, *, n_k: int, out_dtype):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        a_ref[...], b_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=acc_ref.dtype)

    @pl.when(ki == n_k - 1)
    def _store():
        o_ref[...] = acc_ref[...].astype(out_dtype)


def matmul(a: jax.Array, b: jax.Array, *, block_m: int = 256,
           block_n: int = 256, block_k: int = 256,
           out_dtype=None, interpret: Optional[bool] = None) -> jax.Array:
    """a (M, K) @ b (K, N) with explicit MXU tiling."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    m, k = a.shape
    k2, n = b.shape
    assert k == k2
    integer = jnp.issubdtype(a.dtype, jnp.integer)
    acc_dtype = jnp.int32 if integer else jnp.float32
    out_dtype = out_dtype or (jnp.int32 if integer else a.dtype)
    bm, bn, bk = min(block_m, m), min(block_n, n), min(block_k, k)
    gm, gn, gk = -(-m // bm), -(-n // bn), -(-k // bk)
    a_p = jnp.pad(a, ((0, gm * bm - m), (0, gk * bk - k)))
    b_p = jnp.pad(b, ((0, gk * bk - k), (0, gn * bn - n)))

    out = pl.pallas_call(
        functools.partial(_mm_kernel, n_k=gk, out_dtype=out_dtype),
        grid=(gm, gn, gk),
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, ki: (i, ki)),
            pl.BlockSpec((bk, bn), lambda i, j, ki: (ki, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, ki: (i, j)),
        out_shape=jax.ShapeDtypeStruct((gm * bm, gn * bn), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm, bn), acc_dtype)],
        interpret=interpret,
    )(a_p, b_p)
    return out[:m, :n]
