"""MM2IM-KS — kernel-segregated zero-skipping TCONV as a Pallas TPU kernel.

Third kernel family of the registry (after ``mm2im`` / ``mm2im_db``),
implementing kernel segregation (Tida et al., PAPERS.md;
``core/segregate.py`` for the math and docs/DESIGN.md §2.6 for the
dataflow).  Per grid cell — one output row-block x one Oc block — the
``Ks²`` taps are regrouped into ``S²`` stride-1 sub-kernels and each
sub-problem runs as **one dense MatMul** over exactly the input rows that
feed it:

    (B_fold · (bi + Jh - 1) · Iw, Ic) @ (Ic, Jh·Jw·boc)

followed by stride-1 shifted adds into a *plane* and a single interleaved
view write ``acc[:, a', :, b', :] = plane``.  Compared to MM2IM's
dataflow this

* issues no ineffectual MACs: each sub-MatMul's M covers only the
  ``bi + Jh - 1`` slab rows its taps touch (MM2IM's single MatMul runs
  all ``n_slab`` rows against all ``Ks²`` taps and drops the misses), and
  a residue class with no taps (stride > kernel) issues nothing;
* needs no col2im scatter and no inter-sub-kernel accumulation: residue
  classes partition the output, so every accumulator element is written
  by exactly one sub-kernel (the overlapping-sums problem disappears by
  construction instead of being resolved in VMEM);
* degenerates to plain MM2IM at stride 1: one sub-kernel owning all taps,
  one full-slab MatMul, one plane covering the whole block.

Host staging is shared with the MM2IM family (``prepare_mm2im`` — same
padding, same slab geometry, same grid orders, same folded-batch rule);
only the weight relayout differs: the ``(Ic, Ks², Oc)`` tap axis is
permuted so each sub-kernel's taps form one contiguous static slice
(``core/segregate.pack_weights``).  The epilogue (bias + requant +
activation, f32/bf16 and the paper's int8 mode) and the custom_vjp
training path come from the same shared pieces as the other two kernels,
so the family is registered through the ordinary ``KernelSpec`` entry
point with full plan/int8/fold support.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.segregate import Segregation, segregate
from repro.kernels.mm2im_pallas import (MM2IMPrep, grid_semantics,
                                        ppu_epilogue, prepare_mm2im)


def _sub_matmul(slab, w_ref, sk, *, m_rows: int, iw: int, boc: int,
                acc_dtype):
    """One sub-kernel's dense MatMul: (m_rows*iw, ic) @ (ic, Jh*Jw*boc).

    ``slab`` is the sub-kernel's exact input-row window (already sliced to
    ``bi + Jh - 1`` rows per batch element — possibly batch-concatenated
    when folded); the weight slice is the sub-kernel's contiguous tap
    range in the packed layout.
    """
    ic = slab.shape[-1]
    wsub = w_ref[:, sk.offset:sk.offset + sk.taps, :]  # (ic, Jh*Jw, boc)
    mm = jax.lax.dot_general(
        slab.reshape(m_rows * iw, ic), wsub.reshape(ic, sk.taps * boc),
        (((1,), (0,)), ((), ())),
        preferred_element_type=acc_dtype,
    )
    return mm.reshape(m_rows, iw, sk.jh, sk.jw, boc)


def _sub_plane(mm5, sk, *, bi: int, iw: int, iw_p: int, boc: int, acc_dtype):
    """Stride-1 shifted adds: fold one sub-kernel's taps into its plane.

    ``mm5`` is ``(bi + Jh - 1, Iw, Jh, Jw, boc)`` for ONE batch element;
    plane cell ``(r, p)`` sums ``mm5[Jh-1-jh + r, p + mw - jw, jh, jw]``
    over the taps, with out-of-image columns clamped (zero contribution).
    All slice bounds are static — the Mapper-as-affine-arithmetic idea of
    the MM2IM kernel, at stride 1.
    """
    plane = jnp.zeros((bi, iw_p, boc), acc_dtype)
    for jh in range(sk.jh):
        r0 = sk.jh - 1 - jh  # top tap reads the deepest slab row
        for jw in range(sk.jw):
            c_ofs = sk.col_shift - jw
            p0, p1 = max(0, -c_ofs), min(iw_p, iw - c_ofs)
            if p1 <= p0:
                continue  # tap never intersects the image columns
            part = mm5[r0:r0 + bi, p0 + c_ofs:p1 + c_ofs, jh, jw, :]
            # Pad-and-add rather than .at[].add — the scatter-add lowering
            # captures an index-array constant, which pallas_call rejects.
            plane = plane + jnp.pad(
                part, ((0, 0), (p0, iw_p - p1), (0, 0)))
    return plane


def _ks_accumulate(slab, seg: Segregation, w_ref, *, b_fold: int, s: int,
                   bi: int, n_slab: int, iw: int, ow_p: int, boc: int,
                   delta: int, acc_dtype):
    """All S² sub-kernels for one row-block -> (b_fold, block_oh, ow_p, boc).

    ``slab`` is ``(b_fold, n_slab, iw, ic)``.  The accumulator is viewed
    ``(bi, S, Iw', S, boc)`` exactly like MM2IM's, but each ``(a', b')``
    lane is *written once* by its sub-kernel's plane — interleave, not
    accumulation.  Empty residue classes (stride > kernel) stay zero: the
    genuine gaps of the gapped TCONV output.
    """
    iw_p = ow_p // s
    zero = jnp.zeros((bi, iw_p, boc), acc_dtype)
    planes = [{} for _ in range(b_fold)]
    for sk in seg.subkernels:
        if sk.taps == 0:
            continue
        # Exact input-row window of this sub-kernel: plane row r (tap jh)
        # reads slab row delta + row_shift - jh + r  ∈  [rlo, rlo+bi+Jh-1).
        rlo = delta + sk.row_shift - (sk.jh - 1)
        m_rows = bi + sk.jh - 1
        window = slab[:, rlo:rlo + m_rows]  # (b_fold, m_rows, iw, ic)
        mm5 = _sub_matmul(window, w_ref, sk, m_rows=b_fold * m_rows, iw=iw,
                          boc=boc, acc_dtype=acc_dtype)
        for e in range(b_fold):
            planes[e][sk.row_phase, sk.col_phase] = _sub_plane(
                mm5[e * m_rows:(e + 1) * m_rows], sk, bi=bi, iw=iw,
                iw_p=iw_p, boc=boc, acc_dtype=acc_dtype)
    outs = []
    for e in range(b_fold):
        # Interleave by construction: stack the residue planes into
        # (bi, S, Iw', S, boc) — each (a', b') lane is exactly one plane,
        # no scatter, no inter-sub-kernel accumulation.
        acc = jnp.stack(
            [jnp.stack([planes[e].get((a, b), zero) for b in range(s)],
                       axis=2)
             for a in range(s)], axis=1)
        outs.append(acc.reshape(s * bi, ow_p, boc))
    return outs


def _mm2im_ks_kernel(
    x_ref, w_ref, b_ref, s_ref, o_ref, *, seg: Segregation,
    s: int, ks: int, ct: int, cl: int,
    bi: int, n_slab: int, iw: int, ow: int, ow_p: int, boc: int,
    delta: int, acc_dtype, out_dtype, activation: str, out_scale,
    per_channel: bool,
):
    """One grid cell of the unfolded grid (same loop nest as mm2im)."""
    j = pl.program_id(2)
    slab = x_ref[:, pl.dslice(j * bi, n_slab)]  # (1, n_slab, iw, ic)
    (out,) = _ks_accumulate(slab, seg, w_ref, b_fold=1, s=s, bi=bi,
                            n_slab=n_slab, iw=iw, ow_p=ow_p, boc=boc,
                            delta=delta, acc_dtype=acc_dtype)
    o_ref[0] = ppu_epilogue(
        out, b_ref[...], s_ref[...], acc_dtype=acc_dtype,
        activation=activation, out_scale=out_scale, per_channel=per_channel,
        out_dtype=out_dtype)


def _mm2im_ks_folded_kernel(
    x_ref, w_ref, b_ref, s_ref, o_ref, *, seg: Segregation, b: int,
    s: int, ks: int, ct: int, cl: int,
    bi: int, n_slab: int, iw: int, ow: int, ow_p: int, boc: int,
    delta: int, acc_dtype, out_dtype, activation: str, out_scale,
    per_channel: bool,
):
    """Batch-folded cell: each sub-MatMul's M carries all B elements."""
    j = pl.program_id(1)
    slab = x_ref[:, pl.dslice(j * bi, n_slab)]  # (B, n_slab, iw, ic)
    outs = _ks_accumulate(slab, seg, w_ref, b_fold=b, s=s, bi=bi,
                          n_slab=n_slab, iw=iw, ow_p=ow_p, boc=boc,
                          delta=delta, acc_dtype=acc_dtype)
    for e in range(b):
        o_ref[e] = ppu_epilogue(
            outs[e], b_ref[...], s_ref[...], acc_dtype=acc_dtype,
            activation=activation, out_scale=out_scale,
            per_channel=per_channel, out_dtype=out_dtype)


def _pack_prep_weights(p: MM2IMPrep, seg: Segregation) -> jax.Array:
    """Permute the staged ``(Ic, Ks², Oc_p)`` relayout into sub-kernel order."""
    return jnp.take(p.w3, jnp.asarray(seg.permutation()), axis=1)


def mm2im_ks_tconv(
    x: jax.Array,
    w: jax.Array,
    bias: Optional[jax.Array] = None,
    *,
    stride: int,
    padding: str = "SAME",
    block_oh: Optional[int] = None,
    block_oc: Optional[int] = None,
    activation: str = "none",
    out_scale: Optional[float] = None,
    out_dtype=None,
    grid_order: str = "auto",
    interpret: Optional[bool] = None,
    fold_batch: bool = False,
) -> jax.Array:
    """Kernel-segregated transposed convolution (same contract as
    ``mm2im_tconv`` — drop-in third family behind the registry).

    Args match ``mm2im_pallas.mm2im_tconv``; see the module docstring for
    the dataflow difference.  ``fold_batch=True`` folds the batch into
    every sub-MatMul's M-dimension (plan schema v2), composing the
    MXU-filling trick with the zero-skipping decomposition.
    """
    p = prepare_mm2im(
        x, w, bias, stride=stride, padding=padding, block_oh=block_oh,
        block_oc=block_oc, activation=activation, out_scale=out_scale,
        out_dtype=out_dtype, grid_order=grid_order, interpret=interpret,
        fold_batch=fold_batch)
    seg = segregate(p.ks, p.s, padding)
    w_ks = _pack_prep_weights(p, seg)

    kw = dict(p.kernel_kwargs(), seg=seg)
    if p.fold_batch:
        kernel = functools.partial(_mm2im_ks_folded_kernel, b=p.b, **kw)
        grid = (p.n_c, p.n_j)
        in_specs = [
            pl.BlockSpec((p.b, p.ihp, p.iw, p.ic), lambda c, j: (0, 0, 0, 0)),
            pl.BlockSpec((p.ic, p.ks * p.ks, p.boc), lambda c, j: (0, 0, c)),
            pl.BlockSpec((p.boc,), lambda c, j: (c,)),
            pl.BlockSpec((p.boc,), lambda c, j: (c,)),
        ]
        out_specs = pl.BlockSpec((p.b, p.block_oh, p.ow_p, p.boc),
                                 lambda c, j: (0, j, 0, c))
        n_parallel = 1
    else:
        kernel = functools.partial(_mm2im_ks_kernel, **kw)
        if p.grid_order == "bcj":
            grid = (p.b, p.n_c, p.n_j)
            ix = lambda b_, c, j: (b_, 0, 0, 0)
            iw_ = lambda b_, c, j: (0, 0, c)
            ib = lambda b_, c, j: (c,)
            io = lambda b_, c, j: (b_, j, 0, c)
        else:  # "cbj"
            grid = (p.n_c, p.b, p.n_j)
            ix = lambda c, b_, j: (b_, 0, 0, 0)
            iw_ = lambda c, b_, j: (0, 0, c)
            ib = lambda c, b_, j: (c,)
            io = lambda c, b_, j: (b_, j, 0, c)
        in_specs = [
            pl.BlockSpec((1, p.ihp, p.iw, p.ic), ix),
            pl.BlockSpec((p.ic, p.ks * p.ks, p.boc), iw_),
            pl.BlockSpec((p.boc,), ib),
            pl.BlockSpec((p.boc,), ib),
        ]
        out_specs = pl.BlockSpec((1, p.block_oh, p.ow_p, p.boc), io)
        n_parallel = 2

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=jax.ShapeDtypeStruct(
            (p.b, p.n_j * p.block_oh, p.ow_p, p.oc_p), p.out_dtype),
        compiler_params=grid_semantics(n_parallel),
        interpret=p.interpret,
    )(p.x_p, w_ks, p.bias_p, p.scales_p)

    return out[:, :p.oh, :p.ow, :p.oc]
