"""Pure-jnp oracles for the MM2IM transposed convolution (TCONV).

Semantics contract (see DESIGN.md §4)
-------------------------------------
``tconv(I_h, I_w, I_c, Ks, O_c, S)`` over NHWC activations ``x`` and
HWOI weights ``w[Ks, Ks, O_c, I_c]``:

  full[S*ih + kh, S*iw + kw, oc] += sum_ic x[ih, iw, ic] * w[kh, kw, oc, ic]

* ``padding='VALID'``: output is ``full`` — shape ``(S*(I-1)+Ks, ...)``.
* ``padding='SAME'``:  output is ``full`` cropped by ``(Ks-S)//2`` at the
  top/left to shape ``(S*I_h, S*I_w)`` — verified numerically identical to
  ``lax.conv_transpose(..., 'SAME')`` with a spatially-flipped HWIO kernel
  (the TF/TFLite convention used by the paper).  Requires ``Ks >= S``.

Three independent oracles are provided; tests assert they agree:

* :func:`tconv_lax`       — XLA's ``lax.conv_transpose`` (gold).
* :func:`iom_reference`   — the paper's Eq. (2): ``col2im(mm(I, W_T))``,
  with the MatMul and scatter-add col2im written out explicitly.  This is
  also the *unfused IOM baseline* for benchmarks: it materializes the full
  ``(M, Ks^2*O_c)`` partial-product matrix (dropped outputs included).
* :func:`tconv_direct`    — direct python-free scatter via dilated padding.
"""

from __future__ import annotations

import functools
from typing import Tuple

import jax
import jax.numpy as jnp
from jax import lax


def out_size(i: int, ks: int, s: int, padding: str) -> int:
    if padding == "SAME":
        return s * i
    if padding == "VALID":
        return s * (i - 1) + ks
    raise ValueError(f"unknown padding {padding!r}")


def crop_offsets(ks: int, s: int, padding: str) -> Tuple[int, int]:
    """(crop_top, crop_left) of the SAME crop applied to the full IOM output."""
    if padding == "VALID":
        return 0, 0
    if ks < s:
        raise NotImplementedError("SAME TCONV with Ks < S is unsupported")
    c = (ks - s) // 2
    return c, c


# ---------------------------------------------------------------------------
# Oracle 1: XLA conv_transpose (gold standard)
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("stride", "padding"))
def tconv_lax(x: jax.Array, w: jax.Array, *, stride: int, padding: str = "SAME") -> jax.Array:
    """TCONV via lax.conv_transpose.  x: (B,Ih,Iw,Ic), w: (Ks,Ks,Oc,Ic)."""
    # Our scatter semantics == conv_transpose with HWIO kernel flipped in H/W.
    w_hwio = jnp.transpose(w, (0, 1, 3, 2))[::-1, ::-1]  # (Ks,Ks,Ic,Oc)
    out = lax.conv_transpose(
        x.astype(jnp.float32),
        w_hwio.astype(jnp.float32),
        strides=(stride, stride),
        padding=padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    return out


# ---------------------------------------------------------------------------
# Oracle 2: the paper's IOM method — MatMul + explicit col2im scatter-add
# ---------------------------------------------------------------------------


def iom_matmul(x: jax.Array, w: jax.Array) -> jax.Array:
    """The IOM MatMul: (B, M, K) @ (K, N) -> (B, M, N).

    M = Ih*Iw, K = Ic, N = Ks*Ks*Oc.  This materializes every partial
    product, including the ones col2im will drop (the paper's P1).
    """
    b, ih, iw, ic = x.shape
    ks, _, oc, _ = w.shape
    x2 = x.reshape(b, ih * iw, ic)
    w2 = jnp.transpose(w, (3, 0, 1, 2)).reshape(ic, ks * ks * oc)  # (K, N)
    return jnp.einsum("bmk,kn->bmn", x2.astype(jnp.float32), w2.astype(jnp.float32))


def col2im(
    mm_out: jax.Array,
    *,
    ih: int,
    iw: int,
    ks: int,
    oc: int,
    stride: int,
    padding: str = "SAME",
) -> jax.Array:
    """Scatter-accumulate MatMul partial products into the final output.

    mm_out: (B, M=Ih*Iw, N=Ks*Ks*Oc).  Returns (B, Oh, Ow, Oc).
    Dropped (cropped) partial products are discarded here — exactly the
    ineffectual computations MM2IM skips.
    """
    b = mm_out.shape[0]
    oh = out_size(ih, ks, stride, padding)
    ow = out_size(iw, ks, stride, padding)
    ct, cl = crop_offsets(ks, stride, padding)

    m5 = mm_out.reshape(b, ih, iw, ks, ks, oc)

    # Flat scatter indices: out[S*r - ct + kh, S*c - cl + kw] += m5[r, c, kh, kw]
    r = jnp.arange(ih)[:, None, None, None]
    c = jnp.arange(iw)[None, :, None, None]
    kh = jnp.arange(ks)[None, None, :, None]
    kw = jnp.arange(ks)[None, None, None, :]
    toh = stride * r - ct + kh  # (ih,iw,ks,ks)
    tow = stride * c - cl + kw
    valid = (toh >= 0) & (toh < oh) & (tow >= 0) & (tow < ow)
    flat = jnp.where(valid, toh * ow + tow, oh * ow)  # OOB bucket at end

    out = jnp.zeros((b, oh * ow + 1, oc), mm_out.dtype)
    upd = m5.reshape(b, ih * iw * ks * ks, oc)
    idx = flat.reshape(ih * iw * ks * ks)
    out = out.at[:, idx].add(upd)
    return out[:, : oh * ow].reshape(b, oh, ow, oc)


@functools.partial(jax.jit, static_argnames=("stride", "padding"))
def iom_reference(x: jax.Array, w: jax.Array, *, stride: int, padding: str = "SAME") -> jax.Array:
    """The paper's Eq. (2): out = col2im(mm(I, W_T)).  Unfused IOM baseline."""
    _, ih, iw, _ = x.shape
    ks, _, oc, _ = w.shape
    mm = iom_matmul(x, w)
    return col2im(mm, ih=ih, iw=iw, ks=ks, oc=oc, stride=stride, padding=padding)


# ---------------------------------------------------------------------------
# Oracle 3: direct dilated scatter (used as a third opinion in tests)
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("stride", "padding"))
def tconv_direct(x: jax.Array, w: jax.Array, *, stride: int, padding: str = "SAME") -> jax.Array:
    """TCONV = conv(interior-dilated input, flipped kernel, full padding)."""
    b, ih, iw, ic = x.shape
    ks, _, oc, _ = w.shape
    s = stride
    xf = x.astype(jnp.float32)
    # Interior-dilate the input by S-1 zeros: shape S*(I-1)+1.
    xd = lax.pad(xf, jnp.float32(0), [(0, 0, 0), (0, 0, s - 1), (0, 0, s - 1), (0, 0, 0)])
    # Full correlation with w viewed as (Ks,Ks,Ic,Oc), flipped spatially.
    w_f = jnp.transpose(w, (0, 1, 3, 2))[::-1, ::-1].astype(jnp.float32)
    full = lax.conv_general_dilated(
        xd, w_f, window_strides=(1, 1), padding=[(ks - 1, ks - 1), (ks - 1, ks - 1)],
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    ct, cl = crop_offsets(ks, s, padding)
    ohf, owf = s * (ih - 1) + ks, s * (iw - 1) + ks
    full = full[:, : ohf, : owf]  # conv output is exactly full size already
    if padding == "VALID":
        return full
    oh, ow = s * ih, s * iw
    return lax.dynamic_slice(full, (0, ct, cl, 0), (b, oh, ow, oc))


# ---------------------------------------------------------------------------
# Quantized oracle (paper runs 8-bit): int8 x int8 -> int32 accum -> requant
# ---------------------------------------------------------------------------


@functools.partial(jax.jit, static_argnames=("stride", "padding"))
def iom_reference_int8(
    x_q: jax.Array,  # (B,Ih,Iw,Ic) int8
    w_q: jax.Array,  # (Ks,Ks,Oc,Ic) int8
    bias_q: jax.Array,  # (Oc,) int32
    *,
    stride: int,
    padding: str = "SAME",
) -> jax.Array:
    """Integer IOM TCONV with exact int32 accumulation (no requant)."""
    b, ih, iw, ic = x_q.shape
    ks, _, oc, _ = w_q.shape
    x2 = x_q.reshape(b, ih * iw, ic).astype(jnp.int32)
    w2 = jnp.transpose(w_q, (3, 0, 1, 2)).reshape(ic, ks * ks * oc).astype(jnp.int32)
    mm = jnp.einsum("bmk,kn->bmn", x2, w2)
    out = col2im(mm, ih=ih, iw=iw, ks=ks, oc=oc, stride=stride, padding=padding)
    return out + bias_q[None, None, None, :]


def requantize(acc_i32: jax.Array, scale: jax.Array, zero_point: int = 0) -> jax.Array:
    """Requantize int32 accumulators to int8 (per-tensor scale), TFLite-style."""
    y = jnp.round(acc_i32.astype(jnp.float32) * scale) + zero_point
    return jnp.clip(y, -128, 127).astype(jnp.int8)
