"""MM2IM — fused MatMul + col2im transposed convolution, as a Pallas TPU kernel.

This is the TPU-native adaptation of the paper's accelerator
(docs/DESIGN.md §2):

* **Tiled MM2IM (Alg. 1)** -> the Pallas ``grid = (batch, O_h row-blocks,
  O_c blocks)``.  Each grid cell is *weight-stationary* in its O_c block
  (``filter_step`` == ``block_oc``) and *output-stationary* in a VMEM
  accumulator holding ``block_oh`` complete output rows.  The contiguous
  input-row slab needed per output row-block (the ``i_end_row`` relation) is
  loaded with a dynamic VMEM slice — the analogue of ``SendInputRows``.

* **MM2IM Mapper (Alg. 2)** -> compile-time affine arithmetic.  For a fixed
  kernel offset ``(kh, kw)`` every partial product lands at
  ``oh = S*ih - ct + kh``, ``ow = S*iw - cl + kw``; the kernel unrolls the
  ``Ks^2`` offsets and turns cmap/omap into *static slice bounds* — zero
  bytes of map traffic (the paper's third key insight, taken to its limit).

* **Out-Muxer / overlapping sums** -> the accumulator is viewed as
  ``(bi, S, Iw', S, boc)`` so each ``(kh, kw)`` contribution is one static
  strided-slice add (stride-``S`` residue decomposition).  Overlaps
  accumulate in VMEM; every final output is written to HBM exactly once and
  **no partial product is ever materialized in HBM** (paper P2/P3).

* **cmap skip of cropped outputs** -> ``(kh, kw)`` terms whose target range
  misses the current output block are skipped *at trace time* (no vector op
  is ever issued), and the MatMul only covers the contributing input-row
  slab.  Residual dense-tile waste relative to the paper's per-element PE
  gating is accounted for in ``core/perf_model.py`` (dense-MXU reality).

The kernel supports f32 / bf16 inputs (f32 accumulation) and the paper's
8-bit mode (int8 x int8 -> int32 accumulation, optional requantization), and
fuses the PPU epilogue (bias + activation + requant).

The host-side staging (:func:`prepare_mm2im`) and the per-block math
(:func:`col2im_accumulate`, :func:`ppu_epilogue`) are shared with the
double-buffered pipeline variant (``kernels/mm2im_db_pallas.py``), so the
two kernels are bit-identical by construction — they differ only in how
the input slab reaches VMEM (resident whole-input block here vs. pipelined
two-slot DMA there; docs/DESIGN.md §2.4).

**Batch folding** (plan schema v2, ``fold_batch=True``): for batched
small-spatial problems (the paper's GAN layers — DCGAN's first TCONV has
``n_slab·Iw`` ≈ 24 MatMul rows against a 128-lane MXU) the per-element
MatMul runs mostly empty.  Folding collapses ``(batch, slab-rows)`` into
the M-dimension — one ``(B·n_slab·Iw, Ic)`` product per row-block, grid
without a batch axis — and runs col2im per element over views of the
folded product, so the result stays bit-identical to the unfolded
dataflow while the MXU M-occupancy grows ``B``-fold (docs/DESIGN.md §2.5).
"""

from __future__ import annotations

import dataclasses
import functools
import math
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.epilogue import ACTIVATIONS
from repro.kernels.ref import crop_offsets, out_size

# Back-compat alias: the activation table (and the leaky-relu slope) moved
# to the shared PPU epilogue module so the kernel forward, the dispatcher's
# unfused remainder and the custom_vjp backward agree by construction.
_ACTIVATIONS = ACTIVATIONS


def _ceil_div(a: int, b: int) -> int:
    return -(-a // b)


def grid_semantics(n_parallel: int,
                   inner_arbitrary: bool = True) -> "pltpu.TPUCompilerParams":
    """Mosaic dimension semantics for an MM2IM grid.

    Every outer grid dimension (batch / oc-block — and, folded, just the
    oc-block) indexes independent work, so Mosaic may partition those grid
    cells across TensorCores (``"parallel"``).  The single-buffered
    kernel's inner output-row sweep stays ``"arbitrary"`` (it revisits the
    resident input block across ``j`` steps); the double-buffered kernel
    pipelines ``j`` in-kernel, so its grid is outer dims only
    (``inner_arbitrary=False``).  Interpret mode accepts and ignores the
    annotation, so one call site serves both backends.
    """
    sem = ("parallel",) * n_parallel
    if inner_arbitrary:
        sem += ("arbitrary",)
    return pltpu.TPUCompilerParams(dimension_semantics=sem)


def plan_blocks(
    ih: int, iw: int, ic: int, ks: int, oc: int, stride: int, padding: str,
    *, vmem_budget: int = 12 * 2**20, in_bytes: int = 4,
    override: Optional[tuple[int, int]] = None,
    batch: int = 1, fold_batch: bool = False,
) -> tuple[int, int]:
    """Pick (block_oh, block_oc) within a VMEM budget.

    block_oh = S * bi (aligned so the input slab per block is a static-size
    contiguous row range); block_oc tiles the N dimension of the MatMul.
    This is the host-driver role of the paper's 0x01 Configure instruction.

    ``fold_batch=True`` shrinks the working budget by ``batch``: the
    folded launch holds B-deep input/product/output blocks, so heuristic
    blocks must be picked as if each byte cost B — this is the single
    definition of the folded-budget rule (``prepare_mm2im`` and
    ``core/tiling.plan`` both rely on it).

    ``override=(block_oh, block_oc)`` bypasses the heuristic entirely (the
    autotuner's explicit-plan path); it is validated, not second-guessed.
    """
    s = stride
    if fold_batch:
        vmem_budget = max(vmem_budget // max(batch, 1), 1)
    if override is not None:
        boh, boc = int(override[0]), int(override[1])
        if boh % s != 0 or boh < s:
            raise ValueError(
                f"override block_oh={boh} must be a positive multiple of "
                f"stride {s}")
        if boc < 1:
            raise ValueError(f"override block_oc={boc} must be positive")
        return boh, boc
    ct, _ = crop_offsets(ks, s, padding)
    oh = out_size(ih, ks, s, padding)
    ow = out_size(iw, ks, s, padding)
    ow_p = _ceil_div(ow, s) * s
    delta = _ceil_div(max(ks - 1 - ct, 0), s)
    eps = (ct - 1) // s

    def vmem(bi: int, boc: int) -> int:
        n_slab = bi + delta + eps + 1
        x_whole = (min(_ceil_div(oh, s * bi), _ceil_div(ih, bi)) * bi + delta + eps + 1) * iw * ic * in_bytes
        w_blk = ic * ks * ks * boc * in_bytes
        mm = n_slab * iw * ks * ks * boc * 4
        acc = s * bi * ow_p * boc * 4
        return x_whole + w_blk + 2 * mm + 2 * acc

    # Prefer large bi (amortizes halo recompute) and boc giving N-block >= 128.
    best = None
    for boc in sorted({min(oc, b) for b in (8, 16, 32, 64, 128, 256)}, reverse=True):
        if ks * ks * boc > 4096 and boc > 8:
            continue
        for bi in (64, 32, 16, 8, 4, 2, 1):
            if s * bi > max(oh, s):
                continue
            if vmem(bi, boc) <= vmem_budget:
                cand = (s * bi, boc)
                if best is None or (bi * boc) > (best[0] // s) * best[1]:
                    best = cand
                break
    if best is None:
        best = (s, min(oc, 8))
    return best


def matmul_slab(slab, wb, *, n_slab: int, iw: int, ks: int, boc: int,
                acc_dtype):
    """IOM MatMul on the MXU: (n_slab*iw, ic) @ (ic, ks*ks*boc) -> mm5.

    Shared by the single- and double-buffered kernels; identical operand
    shapes and reduction order is what makes the two variants bit-identical.
    """
    ic = slab.shape[-1]
    mm = jax.lax.dot_general(
        slab.reshape(n_slab * iw, ic), wb.reshape(ic, ks * ks * boc),
        (((1,), (0,)), ((), ())),
        preferred_element_type=acc_dtype,
    )
    return mm.reshape(n_slab, iw, ks, ks, boc)


def col2im_accumulate(mm5, *, s: int, ks: int, ct: int, cl: int, bi: int,
                      n_slab: int, iw: int, ow: int, ow_p: int, boc: int,
                      delta: int, acc_dtype):
    """col2im for one row-block: output-stationary residue-decomposed adds.

    The accumulator is viewed as ``(bi, S, Iw', S, boc)`` so every (kh, kw)
    contribution is one static strided-slice add; fully cropped offsets are
    skipped at trace time (cmap).  Returns ``(block_oh, ow_p, boc)``.
    """
    block_oh = s * bi
    iw_p = ow_p // s
    acc = jnp.zeros((bi, s, iw_p, s, boc), acc_dtype)
    for kh in range(ks):
        phi_h = kh - ct - s * delta
        a, qh = phi_h % s, (phi_h - (phi_h % s)) // s
        r0 = 0 if phi_h >= 0 else _ceil_div(-phi_h, s)
        r1 = min(n_slab, (block_oh - 1 - phi_h) // s + 1)
        if r1 <= r0:
            continue  # cmap: entire kh row cropped for every block — skip.
        for kw in range(ks):
            phi_w = kw - cl
            b_, qw = phi_w % s, (phi_w - (phi_w % s)) // s
            c0 = 0 if phi_w >= 0 else _ceil_div(-phi_w, s)
            c1 = min(iw, (ow - 1 - phi_w) // s + 1)
            if c1 <= c0:
                continue  # cmap: fully cropped column offset — skip.
            part = mm5[r0:r1, c0:c1, kh, kw, :]
            acc = acc.at[r0 + qh : r1 + qh, a, c0 + qw : c1 + qw, b_, :].add(part)
    return acc.reshape(block_oh, ow_p, boc)


def ppu_epilogue(out, bias_vec, scales_vec, *, acc_dtype, activation: str,
                 out_scale, per_channel: bool, out_dtype):
    """PPU epilogue: bias + (per-tensor or per-channel, TFLite-style)
    requant + activation, fused before the single HBM write.

    Same stage order and rounding as the dispatcher-side
    ``core.epilogue.apply_epilogue`` (an integer store rounds, never
    truncates), so fused and unfused execution of one epilogue agree.
    """
    out = out + bias_vec.astype(acc_dtype)[None, None, :]
    if per_channel:
        out = jnp.round(out.astype(jnp.float32) * scales_vec[None, None, :])
        out = jnp.clip(out, -128.0, 127.0)
    elif out_scale is not None:
        out = jnp.round(out.astype(jnp.float32) * out_scale)
        out = jnp.clip(out, -128.0, 127.0)
    out = ACTIVATIONS[activation](out)
    if (jnp.issubdtype(jnp.dtype(out_dtype), jnp.integer)
            and not jnp.issubdtype(out.dtype, jnp.integer)):
        out = jnp.round(out)
    return out.astype(out_dtype)


def _mm2im_kernel(
    x_ref, w_ref, b_ref, s_ref, o_ref, *,
    s: int, ks: int, ct: int, cl: int,
    bi: int, n_slab: int, iw: int, ow: int, ow_p: int, boc: int,
    delta: int, acc_dtype, out_dtype, activation: str, out_scale,
    per_channel: bool,
):
    """One grid cell: output rows [j*S*bi, (j+1)*S*bi) x channels [c*boc, ...).

    Grid order is (batch, oc-block, oh-block) — the paper's Alg. 1 loop nest:
    weight-stationary across the inner output-row sweep (the w block index is
    constant while j advances, so Pallas keeps it resident in VMEM), and the
    whole-input block is resident for an entire batch element.
    """
    j = pl.program_id(2)  # inner output-row sweep (both grid orders)

    # --- SendInputRows: the contiguous slab feeding this output row-block.
    slab = x_ref[0, pl.dslice(j * bi, n_slab)]  # (n_slab, iw, ic)

    mm5 = matmul_slab(slab, w_ref[...], n_slab=n_slab, iw=iw, ks=ks, boc=boc,
                      acc_dtype=acc_dtype)
    out = col2im_accumulate(mm5, s=s, ks=ks, ct=ct, cl=cl, bi=bi,
                            n_slab=n_slab, iw=iw, ow=ow, ow_p=ow_p, boc=boc,
                            delta=delta, acc_dtype=acc_dtype)
    o_ref[0, :, :, :] = ppu_epilogue(
        out, b_ref[...], s_ref[...], acc_dtype=acc_dtype,
        activation=activation, out_scale=out_scale, per_channel=per_channel,
        out_dtype=out_dtype)


def _mm2im_folded_kernel(
    x_ref, w_ref, b_ref, s_ref, o_ref, *,
    b: int, s: int, ks: int, ct: int, cl: int,
    bi: int, n_slab: int, iw: int, ow: int, ow_p: int, boc: int,
    delta: int, acc_dtype, out_dtype, activation: str, out_scale,
    per_channel: bool,
):
    """Batch-folded grid cell: one row-block of EVERY batch element.

    The grid drops its batch axis — ``grid = (oc-block, oh-block)`` — and
    the ``B`` per-element slabs are stacked into the MatMul M-dimension:
    a single ``(B·n_slab·Iw, Ic) @ (Ic, Ks²·boc)`` product replaces ``B``
    starved ``(n_slab·Iw, Ic)`` products, filling the 128-lane MXU on the
    paper's small-spatial GAN layers (docs/DESIGN.md §2.5).

    col2im + the PPU epilogue then run per batch element over *views* of
    the folded product: each element sees exactly the ``mm5`` slice the
    unfolded kernel would have computed, with the identical reduction
    order, so folded and unfolded execution are bit-identical by
    construction.
    """
    j = pl.program_id(1)  # inner output-row sweep

    # SendInputRows, batch-concatenated: (B, n_slab, iw, ic).
    slab = x_ref[:, pl.dslice(j * bi, n_slab)]
    # One MXU launch with M = B*n_slab*iw; mm5 is (B*n_slab, iw, ks, ks, boc).
    mm5 = matmul_slab(slab, w_ref[...], n_slab=b * n_slab, iw=iw, ks=ks,
                      boc=boc, acc_dtype=acc_dtype)
    for e in range(b):
        out = col2im_accumulate(
            mm5[e * n_slab:(e + 1) * n_slab], s=s, ks=ks, ct=ct, cl=cl,
            bi=bi, n_slab=n_slab, iw=iw, ow=ow, ow_p=ow_p, boc=boc,
            delta=delta, acc_dtype=acc_dtype)
        o_ref[e] = ppu_epilogue(
            out, b_ref[...], s_ref[...], acc_dtype=acc_dtype,
            activation=activation, out_scale=out_scale,
            per_channel=per_channel, out_dtype=out_dtype)


@dataclasses.dataclass
class MM2IMPrep:
    """Staged operands + resolved tile geometry for one MM2IM launch.

    Produced by :func:`prepare_mm2im` and consumed by both the single-
    buffered kernel below and the double-buffered pipeline variant
    (``mm2im_db_pallas``), so the host-side staging — padding, weight
    relayout, block validation, grid-order resolution — is decided in
    exactly one place.
    """

    # Staged arrays.
    x_p: jax.Array        # (B, Ihp, Iw, Ic) zero-padded input
    w3: jax.Array         # (Ic, Ks^2, Oc_p) relaid-out filters
    bias_p: jax.Array     # (Oc_p,) accumulator-dtype bias
    scales_p: jax.Array   # (Oc_p,) per-channel requant scales (or ones)
    # Problem geometry.
    b: int; ih: int; iw: int; ic: int; ks: int; oc: int
    s: int; ct: int; cl: int; oh: int; ow: int
    # Tile geometry (paper Alg. 1).
    block_oh: int; boc: int; bi: int; delta: int
    n_slab: int; n_j: int; n_c: int; ihp: int; ow_p: int; oc_p: int
    # Dtypes / epilogue.
    acc_dtype: object; out_dtype: object
    per_channel: bool; out_scale: Optional[float]; activation: str
    grid_order: str; interpret: bool
    # Plan v2: batch folded into the MatMul M-dimension (grid drops batch).
    fold_batch: bool = False

    def kernel_kwargs(self) -> dict:
        """The static kwargs shared by both kernel bodies."""
        return dict(
            s=self.s, ks=self.ks, ct=self.ct, cl=self.cl, bi=self.bi,
            n_slab=self.n_slab, iw=self.iw, ow=self.ow, ow_p=self.ow_p,
            boc=self.boc, delta=self.delta, acc_dtype=self.acc_dtype,
            out_dtype=self.out_dtype, activation=self.activation,
            out_scale=None if self.per_channel else self.out_scale,
            per_channel=self.per_channel)


def prepare_mm2im(
    x: jax.Array,
    w: jax.Array,
    bias: Optional[jax.Array],
    *,
    stride: int,
    padding: str,
    block_oh: Optional[int],
    block_oc: Optional[int],
    activation: str,
    out_scale,
    out_dtype,
    grid_order: str,
    interpret: Optional[bool],
    fold_batch: bool = False,
) -> MM2IMPrep:
    """Host-side staging (the driver role / 0x01 Configure instruction)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    b, ih, iw, ic = x.shape
    ks, ks2, oc, wic = w.shape
    assert ks == ks2 and wic == ic, (w.shape, x.shape)
    s = stride
    ct, cl = crop_offsets(ks, s, padding)
    oh = out_size(ih, ks, s, padding)
    ow = out_size(iw, ks, s, padding)

    integer = jnp.issubdtype(x.dtype, jnp.integer)
    acc_dtype = jnp.int32 if integer else jnp.float32
    per_channel = out_scale is not None and not isinstance(out_scale, float)
    if out_dtype is None:
        out_dtype = jnp.int8 if (integer and out_scale is not None) else acc_dtype

    if block_oh is None or block_oc is None:
        p_oh, p_oc = plan_blocks(ih, iw, ic, ks, oc, s, padding,
                                 in_bytes=x.dtype.itemsize,
                                 batch=b, fold_batch=fold_batch)
        block_oh = block_oh or p_oh
        block_oc = block_oc or p_oc
    # Explicit-plan path: plan_blocks validates the override (stride
    # alignment, positivity) in one place for every caller.
    block_oh, block_oc = plan_blocks(ih, iw, ic, ks, oc, s, padding,
                                     override=(block_oh, block_oc))
    bi = block_oh // s
    boc = block_oc

    # Geometry of the input slab per output row-block (docs/DESIGN.md §2).
    delta = _ceil_div(max(ks - 1 - ct, 0), s)  # top halo (in input rows)
    eps = (ct - 1) // s                        # bottom halo correction
    n_slab = bi + delta + eps + 1
    n_j = _ceil_div(oh, block_oh)
    n_c = _ceil_div(oc, boc)
    ow_p = _ceil_div(ow, s) * s

    # Host-side data staging: zero-pad so every slab and every block index
    # is in range; jit fuses these pads into the caller.
    ihp = (n_j - 1) * bi + n_slab
    x_p = jnp.pad(x, ((0, 0), (delta, ihp - delta - ih), (0, 0), (0, 0)))
    oc_p = n_c * boc
    w3 = jnp.transpose(w, (3, 0, 1, 2)).reshape(ic, ks * ks, oc)  # (K, Ks^2, Oc)
    w3 = jnp.pad(w3, ((0, 0), (0, 0), (0, oc_p - oc)))
    if bias is None:
        bias = jnp.zeros((oc,), acc_dtype)
    bias_p = jnp.pad(bias.astype(acc_dtype), (0, oc_p - oc))
    if per_channel:
        scales_p = jnp.pad(jnp.asarray(out_scale, jnp.float32),
                           (0, oc_p - oc), constant_values=1.0)
    else:
        scales_p = jnp.ones((oc_p,), jnp.float32)

    # Grid order (Alg. 1 loop-nest choice): j (output rows) is always the
    # inner sweep; the outer pair decides which operand stays resident in
    # VMEM across the most steps.  'bcj' = activation-stationary (input
    # fetched once per batch element), 'cbj' = weight-stationary (each
    # filter block fetched exactly once, the paper's Alg. 1 order).  'auto'
    # picks by which operand carries more HBM traffic.
    if grid_order == "auto":
        w_bytes = ic * ks * ks * oc_p * w.dtype.itemsize
        x_bytes = b * ihp * iw * ic * x.dtype.itemsize
        grid_order = "cbj" if w_bytes > x_bytes else "bcj"
    if grid_order not in ("bcj", "cbj"):
        raise ValueError(
            f"grid_order must be 'auto'|'bcj'|'cbj', got {grid_order!r}")

    return MM2IMPrep(
        x_p=x_p, w3=w3, bias_p=bias_p, scales_p=scales_p,
        b=b, ih=ih, iw=iw, ic=ic, ks=ks, oc=oc, s=s, ct=ct, cl=cl,
        oh=oh, ow=ow, block_oh=block_oh, boc=boc, bi=bi, delta=delta,
        n_slab=n_slab, n_j=n_j, n_c=n_c, ihp=ihp, ow_p=ow_p, oc_p=oc_p,
        acc_dtype=acc_dtype, out_dtype=out_dtype, per_channel=per_channel,
        out_scale=out_scale, activation=activation, grid_order=grid_order,
        interpret=interpret, fold_batch=bool(fold_batch))


def mm2im_tconv(
    x: jax.Array,
    w: jax.Array,
    bias: Optional[jax.Array] = None,
    *,
    stride: int,
    padding: str = "SAME",
    block_oh: Optional[int] = None,
    block_oc: Optional[int] = None,
    activation: str = "none",
    out_scale: Optional[float] = None,
    out_dtype=None,
    grid_order: str = "auto",
    interpret: Optional[bool] = None,
    fold_batch: bool = False,
) -> jax.Array:
    """Fused MM2IM transposed convolution.

    Args:
      x: (B, Ih, Iw, Ic) activations — f32, bf16 or int8.
      w: (Ks, Ks, Oc, Ic) filters (HWOI, paper layout).
      bias: (Oc,) or None.
      stride / padding: TCONV geometry (padding in {'SAME','VALID'}).
      block_oh / block_oc: Tiled-MM2IM block sizes; auto-planned if None.
      activation: fused epilogue nonlinearity.
      out_scale: if set (int8 mode), requantize int32 accum -> int8.
      interpret: force Pallas interpret mode (defaults to True off-TPU).
      fold_batch: collapse (batch, slab-rows) into the MatMul M-dimension
        — the grid drops its batch axis, one (B*n_slab*Iw, Ic) product per
        row-block feeds the MXU, and col2im runs per element over views of
        it (bit-identical to unfolded; docs/DESIGN.md §2.5).
    """
    p = prepare_mm2im(
        x, w, bias, stride=stride, padding=padding, block_oh=block_oh,
        block_oc=block_oc, activation=activation, out_scale=out_scale,
        out_dtype=out_dtype, grid_order=grid_order, interpret=interpret,
        fold_batch=fold_batch)

    if p.fold_batch:
        # Batch folded into M: the grid is (oc-block, oh row-block) only
        # — grid_order's bcj/cbj distinction collapses with the batch axis.
        kernel = functools.partial(_mm2im_folded_kernel, b=p.b,
                                   **p.kernel_kwargs())
        grid = (p.n_c, p.n_j)
        in_specs = [
            pl.BlockSpec((p.b, p.ihp, p.iw, p.ic), lambda c, j: (0, 0, 0, 0)),
            pl.BlockSpec((p.ic, p.ks * p.ks, p.boc), lambda c, j: (0, 0, c)),
            pl.BlockSpec((p.boc,), lambda c, j: (c,)),
            pl.BlockSpec((p.boc,), lambda c, j: (c,)),
        ]
        out_specs = pl.BlockSpec((p.b, p.block_oh, p.ow_p, p.boc),
                                 lambda c, j: (0, j, 0, c))
        n_parallel = 1
    else:
        kernel = functools.partial(_mm2im_kernel, **p.kernel_kwargs())
        if p.grid_order == "bcj":
            grid = (p.b, p.n_c, p.n_j)
            ix = lambda b_, c, j: (b_, 0, 0, 0)
            iw_ = lambda b_, c, j: (0, 0, c)
            ib = lambda b_, c, j: (c,)
            io = lambda b_, c, j: (b_, j, 0, c)
        else:  # "cbj"
            grid = (p.n_c, p.b, p.n_j)
            ix = lambda c, b_, j: (b_, 0, 0, 0)
            iw_ = lambda c, b_, j: (0, 0, c)
            ib = lambda c, b_, j: (c,)
            io = lambda c, b_, j: (b_, j, 0, c)
        in_specs = [
            pl.BlockSpec((1, p.ihp, p.iw, p.ic), ix),
            pl.BlockSpec((p.ic, p.ks * p.ks, p.boc), iw_),
            pl.BlockSpec((p.boc,), ib),
            pl.BlockSpec((p.boc,), ib),
        ]
        out_specs = pl.BlockSpec((1, p.block_oh, p.ow_p, p.boc), io)
        n_parallel = 2

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=jax.ShapeDtypeStruct(
            (p.b, p.n_j * p.block_oh, p.ow_p, p.oc_p), p.out_dtype),
        compiler_params=grid_semantics(n_parallel),
        interpret=p.interpret,
    )(p.x_p, p.w3, p.bias_p, p.scales_p)

    return out[:, :p.oh, :p.ow, :p.oc]
