"""Flash attention (forward) as a Pallas TPU kernel — GQA + causal + window.

Motivation (EXPERIMENTS.md §Perf, hillclimb B): at 32k prefill the pure-JAX
chunked attention writes O(B·H·L²) score tensors through HBM — the
dominant roofline term for every *_prefill_32k cell (e.g. deepseek-67b:
t_mem ≈ 766 s vs t_comp ≈ 37 s).  Holding the running softmax state in
VMEM removes that traffic entirely; the layer becomes compute-bound.

Structure (canonical TPU flash):
  grid = (batch, q_heads, q_blocks, kv_blocks)   — kv innermost
  q block    (1, 1, bq, hd)   stationary across the kv sweep
  k/v blocks (1, 1, bk, hd)   indexed by kv step; GQA maps q-head h to
                              kv-head h // (H/Hkv) inside the index_map
  out block  (1, 1, bq, hd)   written once, on the last *contributing* step
  VMEM scratch: m (bq,1), s (bq,1), acc (bq, hd) — survives the kv sweep

Causality is exploited at *block* granularity: kv blocks strictly above
the diagonal are predicated off with ``pl.when`` (no MXU work issued) —
the same tile-level skip discipline as the MM2IM cmap (DESIGN.md §2).
Validated in interpret mode against ``layers.attention.attend``.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.0e38


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, s_scr, acc_scr, *,
                  bq: int, bk: int, n_k: int, l_q: int, l_k: int,
                  scale: float, causal: bool, window: Optional[int]):
    qi = pl.program_id(2)
    ki = pl.program_id(3)

    q_start = qi * bq
    k_start = ki * bk

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        s_scr[...] = jnp.zeros_like(s_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    # Block-level cmap: does this kv block contribute to this q block?
    live = jnp.asarray(True)
    if causal:
        live = jnp.logical_and(live, k_start <= q_start + bq - 1)
    if window is not None:
        live = jnp.logical_and(live, k_start + bk - 1 > q_start - window)

    @pl.when(live)
    def _step():
        q = q_ref[0, 0].astype(jnp.float32)              # (bq, hd)
        k = k_ref[0, 0].astype(jnp.float32)              # (bk, hd)
        v = v_ref[0, 0].astype(jnp.float32)
        sc = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32) * scale
        q_pos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        k_pos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = k_pos < l_k
        if causal:
            mask = jnp.logical_and(mask, k_pos <= q_pos)
        if window is not None:
            mask = jnp.logical_and(mask, k_pos > q_pos - window)
        sc = jnp.where(mask, sc, NEG_INF)

        m_prev = m_scr[...][:, 0]                         # (bq,)
        m_new = jnp.maximum(m_prev, sc.max(axis=1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(sc - m_new[:, None])
        s_scr[...] = (s_scr[...][:, 0] * alpha + p.sum(axis=1))[:, None]
        acc_scr[...] = acc_scr[...] * alpha[:, None] + jax.lax.dot_general(
            p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
        m_scr[...] = m_new[:, None]

    @pl.when(ki == n_k - 1)
    def _finalize():
        s = jnp.maximum(s_scr[...][:, 0], 1e-30)
        o_ref[0, 0] = (acc_scr[...] / s[:, None]).astype(o_ref.dtype)


def flash_attention(
    q: jax.Array,   # (B, Lq, H, hd)
    k: jax.Array,   # (B, Lk, Hkv, hd)
    v: jax.Array,   # (B, Lk, Hkv, hd)
    *,
    causal: bool = True,
    window: Optional[int] = None,
    block_q: int = 512,
    block_k: int = 512,
    interpret: Optional[bool] = None,
) -> jax.Array:
    """Flash attention forward.  Returns (B, Lq, H, hd)."""
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    b, l_q, h, hd = q.shape
    _, l_k, hkv, _ = k.shape
    assert h % hkv == 0
    r = h // hkv
    bq = min(block_q, l_q)
    bk = min(block_k, l_k)
    n_q = -(-l_q // bq)
    n_k = -(-l_k // bk)
    lq_p, lk_p = n_q * bq, n_k * bk

    qt = jnp.pad(q, ((0, 0), (0, lq_p - l_q), (0, 0), (0, 0))).transpose(0, 2, 1, 3)
    kt = jnp.pad(k, ((0, 0), (0, lk_p - l_k), (0, 0), (0, 0))).transpose(0, 2, 1, 3)
    vt = jnp.pad(v, ((0, 0), (0, lk_p - l_k), (0, 0), (0, 0))).transpose(0, 2, 1, 3)

    kernel = functools.partial(
        _flash_kernel, bq=bq, bk=bk, n_k=n_k, l_q=l_q, l_k=l_k,
        scale=1.0 / (hd ** 0.5), causal=causal, window=window)

    out = pl.pallas_call(
        kernel,
        grid=(b, h, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda b_, h_, qi, ki: (b_, h_, qi, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda b_, h_, qi, ki, r=r: (b_, h_ // r, ki, 0)),
            pl.BlockSpec((1, 1, bk, hd),
                         lambda b_, h_, qi, ki, r=r: (b_, h_ // r, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, hd),
                               lambda b_, h_, qi, ki: (b_, h_, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, lq_p, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, 1), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        interpret=interpret,
    )(qt, kt, vt)
    return out.transpose(0, 2, 1, 3)[:, :l_q]
