"""MM2IM-OG — output-gathered implicit-GEMM TCONV as a Pallas TPU kernel.

Fourth kernel family of the registry (after ``mm2im`` / ``mm2im_db`` /
``mm2im_ks``), implementing the *output-gathered* dataflow (the
AttentionEngine ``conv_transpose_example`` exemplar in SNIPPETS.md;
EcoFlow's dataflow taxonomy in PAPERS.md names this the gather-style
TCONV).  Where MM2IM computes a dense input-stationary product and
*scatters* it through col2im, and MM2IM-KS computes per-sub-kernel
products and folds taps with post-MatMul shifted adds, MM2IM-OG inverts
the direction entirely: each output tile *gathers* the strided input
contributions that feed it and reduces over the taps **inside the MXU
K-dimension**.

For output pixel ``(oh, ow)`` the contributing input taps are the
``(kh, kw)`` with ``(oh + ct - kh) % S == 0`` — exactly the tap groups of
``core/segregate.py``'s residue decomposition, so the host-side sub-kernel
bookkeeping is shared.  Per residue class ``(a', b')`` the kernel builds a
gathered operand by stacking the ``Jh·Jw`` statically-shifted input
windows along a new tap axis,

    G : (B_fold · bi · Iw', Jh·Jw·Ic)      (VMEM-staged, static slices)

and issues **one dense MXU product** against the tap-major weight slice,

    G @ W[a', b'] : (Jh·Jw·Ic, boc)  ->  plane (B_fold · bi · Iw', boc).

The plane *is* the output restricted to its residue class — written once
by an interleaved view, like MM2IM-KS.  Compared to the other families:

* **no col2im scatter and no inter-block accumulation**: every output
  element is produced by exactly one MatMul row — residue classes
  partition the output and the tap reduction happens inside the
  contraction, so nothing is ever read back and re-added (MM2IM
  accumulates ``Ks²`` shifted contributions in VMEM; KS still folds each
  sub-kernel's taps with ``Jh·Jw`` post-MatMul shifted adds);
* **no ineffectual MACs**: like KS, empty residue classes of a gapped
  stride > kernel TCONV issue nothing and no inserted zero is multiplied;
* **exact-size output tiles**: M = ``bi·Iw'`` output pixels, not the
  ``(bi + Jh - 1)·Iw`` halo-extended input window KS runs — the win
  grows with the image (large-image / stride-4 decoder shapes, the
  FSRCNN/pix2pix regime), which is exactly where slab residency caps
  MM2IM.  The cost is gather-read amplification: each input element is
  re-read once per tap that uses it while the gathered operand is staged
  in VMEM (``core/perf_model.mm2im_og_estimate`` models the trade).

Host staging is shared with the MM2IM family (``prepare_mm2im`` — same
padding, slab geometry, grid orders, folded-batch rule).  The weight
layout is the KS packed permutation transposed to tap-major
``(Ks², Ic, Oc_p)`` so each sub-kernel's ``(Jh·Jw·Ic, boc)`` slice is one
contiguous static block whose K ordering matches the gathered operand.
Epilogue (bias + requant + activation, f32/bf16 and the paper's int8
mode) and the custom_vjp training path ride the same shared pieces as the
other kernels; the family registers through the ordinary ``KernelSpec``
entry point with full plan/int8/fold support.  docs/DESIGN.md §2.7 walks
through the gather index math.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.core.segregate import Segregation, segregate
from repro.kernels.mm2im_pallas import (MM2IMPrep, grid_semantics,
                                        ppu_epilogue, prepare_mm2im)


def _og_gather(slab, sk, *, bi: int, iw: int, iw_p: int, delta: int):
    """Stage one residue class's gathered operand: (b_fold, bi, Iw', taps, ic).

    Plane cell ``(r, p)`` of residue ``(a', b')`` gathers input element
    ``x[r + row_shift - jh, p + col_shift - jw]`` for each tap — in slab
    coordinates (the input is top-padded by ``delta`` rows) tap ``jh``
    reads the static ``bi``-row slice starting at
    ``delta + row_shift - jh``, and tap ``jw`` reads the static column
    window shifted by ``col_shift - jw`` (out-of-image columns are zero
    contributions, padded back to ``Iw'``).  A tap whose column window
    never intersects the image still contributes a zero block: the
    gathered K extent must match the sub-kernel's contiguous weight slice.
    All bounds are static — the Mapper-as-affine-arithmetic idea of the
    MM2IM kernel, pointed at the gather direction.
    """
    taps = []
    for jh in range(sk.jh):
        r0 = delta + sk.row_shift - jh
        rows = slab[:, r0:r0 + bi]  # (b_fold, bi, iw, ic)
        for jw in range(sk.jw):
            c_ofs = sk.col_shift - jw
            p0, p1 = max(0, -c_ofs), min(iw_p, iw - c_ofs)
            if p1 <= p0:
                cols = jnp.zeros(rows.shape[:2] + (iw_p,) + rows.shape[3:],
                                 rows.dtype)
            else:
                part = rows[:, :, p0 + c_ofs:p1 + c_ofs, :]
                cols = jnp.pad(part, ((0, 0), (0, 0), (p0, iw_p - p1),
                                      (0, 0)))
            taps.append(cols)
    return jnp.stack(taps, axis=3)  # (b_fold, bi, iw_p, taps, ic)


def _og_plane(slab, w_ref, sk, *, b_fold: int, bi: int, iw: int, iw_p: int,
              boc: int, delta: int, acc_dtype):
    """One residue class: gather + ONE dense MXU product -> its plane.

    ``(b_fold·bi·Iw', Jh·Jw·Ic) @ (Jh·Jw·Ic, boc)`` — the tap reduction
    lives inside the contraction, so each plane element is written exactly
    once with no post-MatMul adds.  The weight slice is the sub-kernel's
    contiguous tap range of the tap-major packed layout, whose
    ``(tap, ic)`` K order matches the gathered operand's by construction.
    """
    g = _og_gather(slab, sk, bi=bi, iw=iw, iw_p=iw_p, delta=delta)
    ic = g.shape[-1]
    wsub = w_ref[sk.offset:sk.offset + sk.taps]  # (taps, ic, boc)
    mm = jax.lax.dot_general(
        g.reshape(b_fold * bi * iw_p, sk.taps * ic),
        wsub.reshape(sk.taps * ic, boc),
        (((1,), (0,)), ((), ())),
        preferred_element_type=acc_dtype,
    )
    return mm.reshape(b_fold, bi, iw_p, boc)


def _og_accumulate(slab, seg: Segregation, w_ref, *, b_fold: int, s: int,
                   bi: int, iw: int, ow_p: int, boc: int, delta: int,
                   acc_dtype):
    """All S² residue planes for one row-block -> (b_fold, block_oh, ow_p, boc).

    ``slab`` is ``(b_fold, n_slab, iw, ic)``.  Planes are assembled by the
    same interleave-by-construction stack as MM2IM-KS — each ``(a', b')``
    lane is exactly one plane, no scatter — but here each plane arrives
    from a single MatMul with the taps already reduced.  Empty residue
    classes (stride > kernel) stay zero: the genuine gaps of the gapped
    TCONV output.
    """
    iw_p = ow_p // s
    zero = jnp.zeros((bi, iw_p, boc), acc_dtype)
    planes = {}
    for sk in seg.subkernels:
        if sk.taps == 0:
            continue
        planes[sk.row_phase, sk.col_phase] = _og_plane(
            slab, w_ref, sk, b_fold=b_fold, bi=bi, iw=iw, iw_p=iw_p,
            boc=boc, delta=delta, acc_dtype=acc_dtype)
    outs = []
    for e in range(b_fold):
        acc = jnp.stack(
            [jnp.stack([planes[a, b][e] if (a, b) in planes else zero
                        for b in range(s)], axis=2)
             for a in range(s)], axis=1)
        outs.append(acc.reshape(s * bi, ow_p, boc))
    return outs


def _mm2im_og_kernel(
    x_ref, w_ref, b_ref, s_ref, o_ref, *, seg: Segregation,
    s: int, ks: int, ct: int, cl: int,
    bi: int, n_slab: int, iw: int, ow: int, ow_p: int, boc: int,
    delta: int, acc_dtype, out_dtype, activation: str, out_scale,
    per_channel: bool,
):
    """One grid cell of the unfolded grid (same loop nest as mm2im)."""
    j = pl.program_id(2)
    slab = x_ref[:, pl.dslice(j * bi, n_slab)]  # (1, n_slab, iw, ic)
    (out,) = _og_accumulate(slab, seg, w_ref, b_fold=1, s=s, bi=bi, iw=iw,
                            ow_p=ow_p, boc=boc, delta=delta,
                            acc_dtype=acc_dtype)
    o_ref[0] = ppu_epilogue(
        out, b_ref[...], s_ref[...], acc_dtype=acc_dtype,
        activation=activation, out_scale=out_scale, per_channel=per_channel,
        out_dtype=out_dtype)


def _mm2im_og_folded_kernel(
    x_ref, w_ref, b_ref, s_ref, o_ref, *, seg: Segregation, b: int,
    s: int, ks: int, ct: int, cl: int,
    bi: int, n_slab: int, iw: int, ow: int, ow_p: int, boc: int,
    delta: int, acc_dtype, out_dtype, activation: str, out_scale,
    per_channel: bool,
):
    """Batch-folded cell: every gathered product's M carries all B elements.

    Folding only grows the M-dimension of each residue MatMul; every
    output element's K-reduction vector is unchanged, so folded and
    unfolded execution are bit-identical by construction (plan v2
    contract).
    """
    j = pl.program_id(1)
    slab = x_ref[:, pl.dslice(j * bi, n_slab)]  # (B, n_slab, iw, ic)
    outs = _og_accumulate(slab, seg, w_ref, b_fold=b, s=s, bi=bi, iw=iw,
                          ow_p=ow_p, boc=boc, delta=delta,
                          acc_dtype=acc_dtype)
    for e in range(b):
        o_ref[e] = ppu_epilogue(
            outs[e], b_ref[...], s_ref[...], acc_dtype=acc_dtype,
            activation=activation, out_scale=out_scale,
            per_channel=per_channel, out_dtype=out_dtype)


def _pack_og_weights(p: MM2IMPrep, seg: Segregation) -> jax.Array:
    """Tap-major packed weights: (Ic, Ks², Oc_p) -> (Ks², Ic, Oc_p).

    The KS permutation groups each sub-kernel's taps contiguously; the
    transpose makes the tap axis leading so the kernel's static slice
    ``w[offset : offset + taps]`` reshapes to a ``(taps·Ic, boc)`` operand
    whose K order (tap-major, ic-minor) matches the gathered input's.
    """
    w_ks = jnp.take(p.w3, jnp.asarray(seg.permutation()), axis=1)
    return jnp.transpose(w_ks, (1, 0, 2))


def mm2im_og_tconv(
    x: jax.Array,
    w: jax.Array,
    bias: Optional[jax.Array] = None,
    *,
    stride: int,
    padding: str = "SAME",
    block_oh: Optional[int] = None,
    block_oc: Optional[int] = None,
    activation: str = "none",
    out_scale: Optional[float] = None,
    out_dtype=None,
    grid_order: str = "auto",
    interpret: Optional[bool] = None,
    fold_batch: bool = False,
) -> jax.Array:
    """Output-gathered transposed convolution (same contract as
    ``mm2im_tconv`` — drop-in fourth family behind the registry).

    Args match ``mm2im_pallas.mm2im_tconv``; see the module docstring for
    the dataflow difference.  ``fold_batch=True`` folds the batch into
    every gathered product's M-dimension (plan schema v2).
    """
    p = prepare_mm2im(
        x, w, bias, stride=stride, padding=padding, block_oh=block_oh,
        block_oc=block_oc, activation=activation, out_scale=out_scale,
        out_dtype=out_dtype, grid_order=grid_order, interpret=interpret,
        fold_batch=fold_batch)
    seg = segregate(p.ks, p.s, padding)
    w_og = _pack_og_weights(p, seg)

    kw = dict(p.kernel_kwargs(), seg=seg)
    if p.fold_batch:
        kernel = functools.partial(_mm2im_og_folded_kernel, b=p.b, **kw)
        grid = (p.n_c, p.n_j)
        in_specs = [
            pl.BlockSpec((p.b, p.ihp, p.iw, p.ic), lambda c, j: (0, 0, 0, 0)),
            pl.BlockSpec((p.ks * p.ks, p.ic, p.boc), lambda c, j: (0, 0, c)),
            pl.BlockSpec((p.boc,), lambda c, j: (c,)),
            pl.BlockSpec((p.boc,), lambda c, j: (c,)),
        ]
        out_specs = pl.BlockSpec((p.b, p.block_oh, p.ow_p, p.boc),
                                 lambda c, j: (0, j, 0, c))
        n_parallel = 1
    else:
        kernel = functools.partial(_mm2im_og_kernel, **kw)
        if p.grid_order == "bcj":
            grid = (p.b, p.n_c, p.n_j)
            ix = lambda b_, c, j: (b_, 0, 0, 0)
            iw_ = lambda b_, c, j: (0, 0, c)
            ib = lambda b_, c, j: (c,)
            io = lambda b_, c, j: (b_, j, 0, c)
        else:  # "cbj"
            grid = (p.n_c, p.b, p.n_j)
            ix = lambda c, b_, j: (b_, 0, 0, 0)
            iw_ = lambda c, b_, j: (0, 0, c)
            ib = lambda c, b_, j: (c,)
            io = lambda c, b_, j: (b_, j, 0, c)
        in_specs = [
            pl.BlockSpec((1, p.ihp, p.iw, p.ic), ix),
            pl.BlockSpec((p.ks * p.ks, p.ic, p.boc), iw_),
            pl.BlockSpec((p.boc,), ib),
            pl.BlockSpec((p.boc,), ib),
        ]
        out_specs = pl.BlockSpec((1, p.block_oh, p.ow_p, p.boc), io)
        n_parallel = 2

    out = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=in_specs,
        out_specs=out_specs,
        out_shape=jax.ShapeDtypeStruct(
            (p.b, p.n_j * p.block_oh, p.ow_p, p.oc_p), p.out_dtype),
        compiler_params=grid_semantics(n_parallel),
        interpret=p.interpret,
    )(p.x_p, w_og, p.bias_p, p.scales_p)

    return out[:, :p.oh, :p.ow, :p.oc]
