"""Public TCONV op: jit'd, differentiable dispatch over implementations.

``tconv(x, w, bias, stride=…, method=…)`` is the framework-facing API used
by ``layers.TConv`` and the GAN models.  Methods:

  * ``'mm2im'``         — the paper's technique: fused Pallas kernel
                          (``mm2im_pallas.mm2im_tconv``).  Default.
  * ``'iom_unfused'``   — paper Eq. (2) unfused: MatMul -> HBM -> col2im
                          scatter (the XLA-level baseline).
  * ``'zero_insertion'``— §II-A method (i) baseline.
  * ``'tdc'``           — §II-A method (ii) baseline.
  * ``'lax'``           — XLA's native conv_transpose (gold).

Training support: the Pallas forward is wrapped in ``jax.custom_vjp`` whose
backward pass is the (automatically derived) VJP of the mathematically
identical dilated-conv formulation — so examples/train_dcgan.py trains
*through* the MM2IM kernel.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import baselines, ref
from repro.kernels.mm2im_pallas import mm2im_tconv

_METHODS = ("mm2im", "iom_unfused", "zero_insertion", "tdc", "lax")


def _fwd_math(x, w, bias, *, stride, padding):
    """Differentiable mathematical definition (dilated-conv formulation)."""
    out = ref.tconv_direct(x, w, stride=stride, padding=padding)
    if bias is not None:
        out = out + bias[None, None, None, :]
    return out


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5))
def _mm2im_diff(x, w, bias, stride, padding, activation):
    out = mm2im_tconv(x, w, bias, stride=stride, padding=padding,
                      activation=activation)
    return out


def _mm2im_fwd(x, w, bias, stride, padding, activation):
    out = _mm2im_diff(x, w, bias, stride, padding, activation)
    return out, (x, w, bias, out)


def _mm2im_bwd(stride, padding, activation, res, g):
    x, w, bias, out = res
    # Activation backward (epilogue was fused into the kernel).
    if activation == "relu":
        g = g * (out > 0)
    elif activation == "tanh":
        g = g * (1.0 - out * out)
    elif activation == "leaky_relu":
        g = g * jnp.where(out >= 0, 1.0, 0.2)
    bias0 = jnp.zeros((w.shape[2],), jnp.float32) if bias is None else bias
    _, vjp = jax.vjp(
        lambda xx, ww, bb: _fwd_math(xx, ww, bb, stride=stride, padding=padding),
        x, w, bias0)
    dx, dw, db = vjp(g)
    return dx, dw, None if bias is None else db


_mm2im_diff.defvjp(_mm2im_fwd, _mm2im_bwd)


@functools.partial(jax.jit, static_argnames=("stride", "padding", "method", "activation"))
def tconv(
    x: jax.Array,
    w: jax.Array,
    bias: Optional[jax.Array] = None,
    *,
    stride: int,
    padding: str = "SAME",
    method: str = "mm2im",
    activation: str = "none",
) -> jax.Array:
    """Transposed convolution.  x: (B,Ih,Iw,Ic); w: (Ks,Ks,Oc,Ic) HWOI."""
    if method not in _METHODS:
        raise ValueError(f"method must be one of {_METHODS}, got {method!r}")
    if method == "mm2im":
        return _mm2im_diff(x, w, bias, stride, padding, activation)
    if method == "iom_unfused":
        out = ref.iom_reference(x, w, stride=stride, padding=padding)
    elif method == "zero_insertion":
        out = baselines.zero_insertion_tconv(x, w, stride=stride, padding=padding)
    elif method == "tdc":
        out = baselines.tdc_tconv(x, w, stride=stride, padding=padding)
    else:
        out = ref.tconv_lax(x, w, stride=stride, padding=padding)
    if bias is not None:
        out = out + bias[None, None, None, :]
    if activation != "none":
        from repro.kernels.mm2im_pallas import _ACTIVATIONS
        out = _ACTIVATIONS[activation](out)
    return out


def tconv_int8(
    x_q: jax.Array,
    w_q: jax.Array,
    bias_q: jax.Array,
    out_scale,
    *,
    stride: int,
    padding: str = "SAME",
) -> jax.Array:
    """8-bit MM2IM TCONV (the paper's precision): int8 in, int8 out.

    ``out_scale`` is a python float (per-tensor requant) or a length-Oc
    array (TFLite-style per-channel requant, fused in the PPU epilogue).
    """
    if not isinstance(out_scale, float):
        import numpy as _np
        out_scale = _np.asarray(out_scale, _np.float32)
    return mm2im_tconv(x_q, w_q, bias_q, stride=stride, padding=padding,
                       out_scale=out_scale)
