"""Public TCONV ops: one jit'd, differentiable dispatch pipeline.

``tconv(x, w, bias, stride=…, method=…)`` and ``tconv_int8(x_q, w_q,
bias_q, out_scale, stride=…)`` are the framework-facing API used by
``layers`` and the GAN models.  Both are thin wrappers that build an
:class:`~repro.core.epilogue.Epilogue` (bias + optional requant +
activation + output dtype) and hand it to a single shared dispatcher —
there is exactly one implementation of plan normalization/validation, the
four-tier plan lookup, the ``Plan.method`` variant-upgrade rule, and the
unfused-epilogue remainder, for every precision.  The built-in methods:

  * ``'mm2im'``         — the paper's technique: fused Pallas kernel
                          (``mm2im_pallas.mm2im_tconv``).  Default.
  * ``'mm2im_db'``      — double-buffered pipeline variant: per-row-block
                          slab DMA overlapped with MatMul+col2im
                          (``mm2im_db_pallas``); bit-identical to 'mm2im'.
  * ``'mm2im_ks'``      — kernel-segregated family: S² stride-1 dense
                          sub-MatMuls written to interleaved output views,
                          no col2im scatter, no ineffectual MACs
                          (``mm2im_ks_pallas``; core/segregate.py).
  * ``'mm2im_og'``      — output-gathered implicit GEMM: each output tile
                          gathers its strided input contributions and
                          reduces taps inside the MXU K-dimension — no
                          scatter, no inter-block accumulation
                          (``mm2im_og_pallas``; DESIGN.md §2.7).
  * ``'iom_unfused'``   — paper Eq. (2) unfused: MatMul -> HBM -> col2im
                          scatter (the XLA-level baseline).
  * ``'zero_insertion'``— §II-A method (i) baseline.
  * ``'tdc'``           — §II-A method (ii) baseline.
  * ``'lax'``           — XLA's native conv_transpose (gold).

**Epilogue contract.**  Each registered :class:`~repro.kernels.registry.
KernelSpec` declares which PPU stages it fuses; the dispatcher splits the
requested epilogue into the fused prefix (handed to the kernel) and the
unfused remainder (applied here, ``core.epilogue.apply_epilogue``).  A
method without ``supports_int8`` still serves int8 problems: the
dispatcher dequantizes the operands to f32, runs the kernel, and applies
the integer epilogue (bias, requant round/clip, int8 store) itself — so
**every** registered method is quantization-capable, which is what lets
the benchmarks compare the paper's int8 mode against the §II-A baselines.
Fallback precision caveat: the f32 accumulation is exact only while
partial sums stay below 2^24 (|acc| ≲ ``Ic*Ks^2 * 127^2``); past that the
fallback can differ from the native int32 path by an LSB or two around
requant rounding boundaries — fine for baseline comparisons, which is
what it exists for (the native kernels stay bit-exact at every size).

An explicit tile plan (``registry.Plan`` or a ``(block_oh, block_oc[,
grid_order])`` tuple — typically produced by ``core/autotune.py``) can be
passed as ``plan=``; it flows into the Pallas kernel's block geometry
(incl. the schema-v2 ``fold_batch`` knob, which folds the batch into the
MatMul M-dimension — bit-identical, so plan consumption never changes
results), and a plan carrying ``method='mm2im_db'`` upgrades the default
dispatch to the variant it was tuned for.  Methods that don't tile reject
explicit plans.

**Automatic plan consumption** (docs/AUTOTUNER.md): when no ``plan=`` is
given and the method supports plans, the dispatcher looks up the tuned
plan by problem key — shapes, dtype, batch — at trace time.  Precedence:
explicit ``plan=`` > user cache hit > shipped per-backend plan table
(``core/plan_table.py``) > ``plan_blocks`` heuristic; ``consumed_plans()``
records which tier served each hit.  Disable with
``REPRO_AUTOTUNE_AUTOLOAD=0``.  The lookup happens once per jit trace, so
a cache written *after* a shape was first compiled is only seen by new
traces.  Both entry points share the jit'd dispatcher (same static-argname
discipline), so repeated ``tconv_int8`` calls on one shape compile once —
``dispatch_trace_count()`` observes the retrace behaviour in tests.

Training support: the Pallas forwards are wrapped in ``jax.custom_vjp``
whose backward pass is the (automatically derived) VJP of the
mathematically identical dilated-conv formulation — so
examples/train_dcgan.py trains *through* the MM2IM kernels.
"""

from __future__ import annotations

import functools
import os
from typing import Optional

import jax
import jax.numpy as jnp

from repro.core import epilogue as epi
from repro.core.epilogue import Epilogue
from repro.kernels import baselines, ref, registry
from repro.kernels.mm2im_db_pallas import mm2im_db_tconv
from repro.kernels.mm2im_ks_pallas import mm2im_ks_tconv
from repro.kernels.mm2im_og_pallas import mm2im_og_tconv
from repro.kernels.mm2im_pallas import mm2im_tconv
from repro.kernels.registry import Plan, PlanLike

DEFAULT_METHOD = "mm2im"

# The rung of last resort for degraded-mode re-dispatch
# (serve/resilience.py): XLA's native conv_transpose — no Pallas, no tile
# plans, no tuned state to be wrong.  Kept as a named constant so the
# degradation ladder and the tests agree on what "fully degraded" runs.
FALLBACK_METHOD = "lax"


def _fwd_math(x, w, bias, *, stride, padding):
    """Differentiable mathematical definition (dilated-conv formulation)."""
    out = ref.tconv_direct(x, w, stride=stride, padding=padding)
    if bias is not None:
        out = out + bias[None, None, None, :]
    return out


def _make_mm2im_diff(kernel_fn):
    """custom_vjp wrapper for a fused MM2IM-family forward kernel.

    The backward pass is the VJP of the mathematically identical
    dilated-conv formulation; both Pallas variants share it because they
    compute the same function (bit-identical forwards).
    """

    @functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
    def diff(x, w, bias, stride, padding, activation, plan):
        kw = {}
        if plan is not None:
            kw = dict(block_oh=plan.block_oh, block_oc=plan.block_oc,
                      grid_order=plan.grid_order,
                      fold_batch=plan.fold_batch)
        return kernel_fn(x, w, bias, stride=stride, padding=padding,
                         activation=activation, **kw)

    def fwd(x, w, bias, stride, padding, activation, plan):
        out = diff(x, w, bias, stride, padding, activation, plan)
        return out, (x, w, bias, out)

    def bwd(stride, padding, activation, plan, res, g):
        x, w, bias, out = res
        # Activation backward (the epilogue was fused into the kernel); the
        # shared table keeps e.g. the leaky-relu slope in one place.
        g = epi.activation_grad_from_output(activation, out, g)
        # Zero-bias placeholder in the *weight* dtype: an f32 constant here
        # silently promotes the replayed bf16 forward to f32.
        bias0 = jnp.zeros((w.shape[2],), w.dtype) if bias is None else bias
        _, vjp = jax.vjp(
            lambda xx, ww, bb: _fwd_math(xx, ww, bb, stride=stride,
                                         padding=padding),
            x, w, bias0)
        dx, dw, db = vjp(g)
        return dx, dw, None if bias is None else db

    diff.defvjp(fwd, bwd)
    return diff


_mm2im_diff = _make_mm2im_diff(mm2im_tconv)
_mm2im_db_diff = _make_mm2im_diff(mm2im_db_tconv)
_mm2im_ks_diff = _make_mm2im_diff(mm2im_ks_tconv)
_mm2im_og_diff = _make_mm2im_diff(mm2im_og_tconv)


# ---------------------------------------------------------------------------
# Built-in method registration.
# ---------------------------------------------------------------------------


def _make_mm2im_impl(diff_fn, kernel_fn):
    """Registry entry point for one MM2IM-family kernel variant.

    The requant path calls the kernel directly (the PPU epilogue incl.
    int8 store is fused, nothing to differentiate through); every other
    epilogue goes through the custom_vjp wrapper so training works.
    """

    def impl(x, w, *, stride, padding, epilogue, plan):
        if epilogue.out_scale is not None:
            kw = {}
            if plan is not None:
                kw = dict(block_oh=plan.block_oh, block_oc=plan.block_oc,
                          grid_order=plan.grid_order,
                          fold_batch=plan.fold_batch)
            return kernel_fn(x, w, epilogue.bias, stride=stride,
                             padding=padding, activation=epilogue.activation,
                             out_scale=epilogue.out_scale,
                             out_dtype=epilogue.out_dtype, **kw)
        # No requant -> the differentiable path; the dispatcher owns any
        # remaining stages and the final store cast (Epilogue.split).
        return diff_fn(x, w, epilogue.bias, stride, padding,
                       epilogue.activation, plan)

    return impl


registry.register(
    "mm2im", fuses=("bias", "requant", "activation"), supports_plan=True,
    supports_int8=True,
    description="fused Pallas MM2IM kernel (paper technique; default)")(
        _make_mm2im_impl(_mm2im_diff, mm2im_tconv))

registry.register(
    "mm2im_db", fuses=("bias", "requant", "activation"), supports_plan=True,
    supports_int8=True,
    description="double-buffered MM2IM: slab DMA pipelined against compute")(
        _make_mm2im_impl(_mm2im_db_diff, mm2im_db_tconv))

registry.register(
    "mm2im_ks", fuses=("bias", "requant", "activation"), supports_plan=True,
    supports_int8=True,
    description="kernel-segregated MM2IM: S^2 stride-1 dense sub-MatMuls, "
                "interleaved output views, zero ineffectual MACs")(
        _make_mm2im_impl(_mm2im_ks_diff, mm2im_ks_tconv))

registry.register(
    "mm2im_og", fuses=("bias", "requant", "activation"), supports_plan=True,
    supports_int8=True,
    description="output-gathered implicit GEMM: per-residue gathered "
                "operands, tap reduction inside the MXU K-dimension, "
                "no scatter and no inter-block accumulation")(
        _make_mm2im_impl(_mm2im_og_diff, mm2im_og_tconv))


@registry.register(
    "iom_unfused",
    description="paper Eq. (2) unfused: MatMul -> HBM -> col2im scatter")
def _iom_unfused_impl(x, w, *, stride, padding, epilogue, plan):
    return ref.iom_reference(x, w, stride=stride, padding=padding)


@registry.register(
    "zero_insertion", description="§II-A method (i) baseline")
def _zero_insertion_impl(x, w, *, stride, padding, epilogue, plan):
    return baselines.zero_insertion_tconv(x, w, stride=stride, padding=padding)


@registry.register("tdc", description="§II-A method (ii) baseline")
def _tdc_impl(x, w, *, stride, padding, epilogue, plan):
    return baselines.tdc_tconv(x, w, stride=stride, padding=padding)


@registry.register("lax", description="XLA native conv_transpose (gold)")
def _lax_impl(x, w, *, stride, padding, epilogue, plan):
    out = ref.tconv_lax(x, w, stride=stride, padding=padding)
    # XLA pads gapped stride>kernel VALID outputs to S·(I-1)+max(Ks, S);
    # the repo contract (ref.out_size, DESIGN.md §4) is S·(I-1)+Ks.  The
    # extra rows/cols are pure zero gaps — crop them so 'lax' serves as
    # the gold for every geometry the other methods support.
    oh = ref.out_size(x.shape[1], w.shape[0], stride, padding)
    ow = ref.out_size(x.shape[2], w.shape[0], stride, padding)
    return out[:, :oh, :ow]


# ---------------------------------------------------------------------------
# Automatic plan-cache consumption.
# ---------------------------------------------------------------------------

AUTOLOAD_ENV = "REPRO_AUTOTUNE_AUTOLOAD"

# Ring of (cache_key, Plan, tier) triples auto-consumed by tconv/tconv_int8
# — observability for tests and debugging (appends happen at trace time).
# tier is which precedence tier served the hit: autotune.TIER_USER_CACHE
# (the on-disk user cache) or autotune.TIER_SHIPPED (a committed
# per-backend table from core/plan_table.py).
_CONSUMED: list = []
_CONSUMED_CAP = 64


def consumed_plans() -> tuple:
    """(cache_key, Plan, tier) triples auto-consumed so far, oldest first."""
    return tuple(_CONSUMED)


def clear_consumed_plans() -> None:
    _CONSUMED.clear()


def _autoload_enabled() -> bool:
    return os.environ.get(AUTOLOAD_ENV, "1").lower() not in ("0", "false",
                                                             "off")


def _auto_plan(x, w, stride: int, padding: str) -> Optional[Plan]:
    """Trace-time lookup of a tuned plan for this problem key (or None).

    Runs while the dispatcher traces, so shapes/dtypes are concrete; any
    cache problem degrades to the heuristic default rather than raising.
    """
    if not _autoload_enabled():
        return None
    try:
        from repro.core.autotune import lookup_plan, cache_key
        from repro.core.maps import TConvProblem

        b, ih, iw, ic = x.shape
        ks, _, oc, _ = w.shape
        p = TConvProblem(ih, iw, ic, ks, oc, stride, padding)
        hit = lookup_plan(p, dtype=x.dtype, batch=b)
        if hit is None:
            return None
        plan, tier = hit
        if plan.block_oh % stride != 0:
            # Corrupt/hand-edited geometry: an auto-loaded plan degrades to
            # the heuristic instead of failing dispatch (explicit plans
            # with the same defect still raise — that's a caller error).
            return None
        _CONSUMED.append((cache_key(p, dtype=x.dtype, batch=b), plan, tier))
        del _CONSUMED[:-_CONSUMED_CAP]
        return plan
    except Exception:
        return None  # never let a broken cache break dispatch


# ---------------------------------------------------------------------------
# Dispatch — the one pipeline both public entry points share.
# ---------------------------------------------------------------------------


def _check_explicit_plan(plan: Plan, stride: int) -> None:
    """Reject explicit-plan geometry the kernels cannot tile.

    Shared by ``tconv`` and ``tconv_int8`` (one dispatcher) so both entry
    points surface the same caller error; auto-loaded plans with these
    defects are silently discarded by ``_auto_plan`` instead.
    """
    if plan.block_oh % stride != 0:
        raise ValueError(
            f"plan block_oh={plan.block_oh} must be a multiple of "
            f"stride {stride}")


def _run_spec(spec: registry.KernelSpec, x, w, *, stride, padding,
              epilogue: Epilogue, plan: Optional[Plan]):
    """Execute one registered spec: fused prefix in-kernel, remainder here.

    For int8 problems on a spec without native int8 support this is the
    dequant -> compute -> requant fallback: operands are dequantized to
    f32, the kernel fuses nothing, and the full integer epilogue (bias,
    requant round/clip, int8 store) is applied by the dispatcher — the
    path that makes every registered method quantization-capable.
    """
    integer = jnp.issubdtype(jnp.dtype(x.dtype), jnp.integer)
    ep = epilogue.with_resolved_out_dtype(integer)
    fallback = integer and not spec.supports_int8
    if fallback:
        x = x.astype(jnp.float32)
        w = w.astype(jnp.float32)
    kernel_ep, rest = ep.split(frozenset() if fallback else spec.fuses)
    out = spec.fn(x, w, stride=stride, padding=padding, epilogue=kernel_ep,
                  plan=plan)
    return epi.apply_epilogue(out, rest)


def run_registered(method: str, x, w, *, stride, padding,
                   epilogue: Epilogue, plan: Optional[Plan] = None):
    """Run one registered method with the dispatcher's epilogue contract.

    Exactly the execution half of the dispatch pipeline — no plan-cache
    lookup, no variant upgrade.  This is what ``core/autotune.py`` times,
    so any registered variant is autotunable in both precisions with zero
    extra wiring (and measured on the same program dispatch will run).
    """
    return _run_spec(registry.get(method), x, w, stride=stride,
                     padding=padding, epilogue=epilogue, plan=plan)


# Trace counter: incremented each time the shared dispatcher actually
# retraces.  Tests assert the static-argname discipline (e.g. repeated
# tconv_int8 calls on one shape compile exactly once).
_TRACE_COUNT = 0


def dispatch_trace_count() -> int:
    """How many times the shared jit'd dispatcher has (re)traced."""
    return _TRACE_COUNT


def _dispatch_impl(x, w, epilogue: Epilogue, *, stride: int, padding: str,
                   method: str, plan: Optional[Plan]):
    global _TRACE_COUNT
    _TRACE_COUNT += 1
    spec = registry.get(method)
    if plan is not None and not spec.supports_plan:
        raise ValueError(
            f"method {method!r} does not accept an explicit tile plan")
    if plan is not None:
        _check_explicit_plan(plan, stride)
    elif spec.supports_plan:
        plan = _auto_plan(x, w, stride, padding)  # cache > shipped > heur.
    if plan is not None and plan.method is not None:
        # A plan tuned for a specific kernel variant upgrades the *default*
        # dispatch to that variant; an explicitly requested non-default
        # method wins over the plan's preference (geometry still applies).
        # An unregistered plan.method (stale cache entry, plugin variant
        # not imported in this process) quietly keeps the default — a bad
        # cache must never break inference.
        if plan.method != method and method == DEFAULT_METHOD:
            try:
                variant = registry.get(plan.method)
            except ValueError:
                variant = None
            if variant is not None and variant.supports_plan:
                spec = variant
    return _run_spec(spec, x, w, stride=stride, padding=padding,
                     epilogue=epilogue, plan=plan)


_dispatch = jax.jit(
    _dispatch_impl, static_argnames=("stride", "padding", "method", "plan"))


def _norm_out_scale(out_scale):
    """Normalize the requant scale: float stays static, arrays are traced."""
    if out_scale is None or isinstance(out_scale, float):
        return out_scale
    if isinstance(out_scale, int):
        return float(out_scale)
    import numpy as _np
    return _np.asarray(out_scale, _np.float32)


def tconv(
    x: jax.Array,
    w: jax.Array,
    bias: Optional[jax.Array] = None,
    *,
    stride: int,
    padding: str = "SAME",
    method: str = DEFAULT_METHOD,
    activation: str = "none",
    plan: PlanLike = None,
    out_scale=None,
    out_dtype=None,
) -> jax.Array:
    """Transposed convolution.  x: (B,Ih,Iw,Ic); w: (Ks,Ks,Oc,Ic) HWOI.

    ``out_scale`` / ``out_dtype`` optionally attach the PPU requant stage
    (round/clip to int8) to any method — for int8 operands prefer the
    :func:`tconv_int8` wrapper, which documents the quantized contract.
    The requant epilogue is **inference-only** (the paper quantizes frozen
    models): round/clip is not usefully differentiable, and the fused
    requant kernels bypass the ``custom_vjp`` — do not take gradients
    through a requantizing call (ROADMAP tracks a QAT story).
    """
    ep = Epilogue(bias=bias, activation=activation,
                  out_scale=_norm_out_scale(out_scale), out_dtype=out_dtype)
    return _dispatch(x, w, ep, stride=stride, padding=padding, method=method,
                     plan=registry.as_plan(plan))


def tconv_reference(
    x: jax.Array,
    w: jax.Array,
    bias: Optional[jax.Array] = None,
    *,
    stride: int,
    padding: str = "SAME",
    activation: str = "none",
) -> jax.Array:
    """Degraded-mode re-dispatch entry: the ``'lax'`` reference, f32.

    The bottom rung of the serving degradation ladder
    (``serve/resilience.py``) — when the tuned Pallas kernel and the
    heuristic re-plan both fail, the batch is re-dispatched through this
    entry: XLA-native ``conv_transpose``, no explicit plan, no plan-cache
    consultation (``'lax'`` is not plan-capable, so ``_auto_plan`` never
    runs), so none of the tuned state that may have caused the failure is
    in the program.  Same Epilogue contract as :func:`tconv` (bias and
    activation applied by the dispatcher's unfused remainder).
    """
    return tconv(x, w, bias, stride=stride, padding=padding,
                 method=FALLBACK_METHOD, activation=activation)


def tconv_int8(
    x_q: jax.Array,
    w_q: jax.Array,
    bias_q: Optional[jax.Array],
    out_scale,
    *,
    stride: int,
    padding: str = "SAME",
    method: str = DEFAULT_METHOD,
    activation: str = "none",
    plan: PlanLike = None,
) -> jax.Array:
    """8-bit TCONV (the paper's precision): int8 in, int8 out.

    ``out_scale`` is a python float (per-tensor requant) or a length-Oc
    array (TFLite-style per-channel requant, fused in the PPU epilogue).
    Runs through the same jit'd dispatcher as :func:`tconv` — same
    static-argname discipline (no per-call retraces), same plan tiers,
    same ``Plan.method`` variant upgrade.  ``method`` may name *any*
    registered implementation: kernels without native int8 support run via
    the dispatcher's dequant -> compute -> requant fallback, which is how
    the §II-A baselines join the paper's int8 comparison.
    """
    ep = Epilogue(bias=bias_q, activation=activation,
                  out_scale=_norm_out_scale(out_scale))
    return _dispatch(x_q, w_q, ep, stride=stride, padding=padding,
                     method=method, plan=registry.as_plan(plan))
