"""Public TCONV op: jit'd, differentiable dispatch over implementations.

``tconv(x, w, bias, stride=…, method=…)`` is the framework-facing API used
by ``layers`` and the GAN models.  Dispatch goes through the pluggable
kernel registry (``kernels/registry.py``); the built-in methods are:

  * ``'mm2im'``         — the paper's technique: fused Pallas kernel
                          (``mm2im_pallas.mm2im_tconv``).  Default.
  * ``'iom_unfused'``   — paper Eq. (2) unfused: MatMul -> HBM -> col2im
                          scatter (the XLA-level baseline).
  * ``'zero_insertion'``— §II-A method (i) baseline.
  * ``'tdc'``           — §II-A method (ii) baseline.
  * ``'lax'``           — XLA's native conv_transpose (gold).

An explicit tile plan (``registry.Plan`` or a ``(block_oh, block_oc[,
grid_order])`` tuple — typically produced by ``core/autotune.py``) can be
passed as ``plan=``; it flows into the Pallas kernel's block geometry.
Methods that don't tile (everything but ``'mm2im'``) reject explicit plans.

Training support: the Pallas forward is wrapped in ``jax.custom_vjp`` whose
backward pass is the (automatically derived) VJP of the mathematically
identical dilated-conv formulation — so examples/train_dcgan.py trains
*through* the MM2IM kernel.
"""

from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import baselines, ref, registry
from repro.kernels.mm2im_pallas import mm2im_tconv
from repro.kernels.registry import Plan, PlanLike


def _fwd_math(x, w, bias, *, stride, padding):
    """Differentiable mathematical definition (dilated-conv formulation)."""
    out = ref.tconv_direct(x, w, stride=stride, padding=padding)
    if bias is not None:
        out = out + bias[None, None, None, :]
    return out


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def _mm2im_diff(x, w, bias, stride, padding, activation, plan):
    kw = {}
    if plan is not None:
        kw = dict(block_oh=plan.block_oh, block_oc=plan.block_oc,
                  grid_order=plan.grid_order)
    out = mm2im_tconv(x, w, bias, stride=stride, padding=padding,
                      activation=activation, **kw)
    return out


def _mm2im_fwd(x, w, bias, stride, padding, activation, plan):
    out = _mm2im_diff(x, w, bias, stride, padding, activation, plan)
    return out, (x, w, bias, out)


def _mm2im_bwd(stride, padding, activation, plan, res, g):
    x, w, bias, out = res
    # Activation backward (epilogue was fused into the kernel).
    if activation == "relu":
        g = g * (out > 0)
    elif activation == "tanh":
        g = g * (1.0 - out * out)
    elif activation == "leaky_relu":
        g = g * jnp.where(out >= 0, 1.0, 0.2)
    bias0 = jnp.zeros((w.shape[2],), jnp.float32) if bias is None else bias
    _, vjp = jax.vjp(
        lambda xx, ww, bb: _fwd_math(xx, ww, bb, stride=stride, padding=padding),
        x, w, bias0)
    dx, dw, db = vjp(g)
    return dx, dw, None if bias is None else db


_mm2im_diff.defvjp(_mm2im_fwd, _mm2im_bwd)


# ---------------------------------------------------------------------------
# Built-in method registration.
# ---------------------------------------------------------------------------


@registry.register(
    "mm2im", fuses_bias=True, fuses_activation=True, supports_plan=True,
    description="fused Pallas MM2IM kernel (paper technique; default)")
def _mm2im_impl(x, w, bias, *, stride, padding, activation, plan):
    return _mm2im_diff(x, w, bias, stride, padding, activation, plan)


@registry.register(
    "iom_unfused",
    description="paper Eq. (2) unfused: MatMul -> HBM -> col2im scatter")
def _iom_unfused_impl(x, w, bias, *, stride, padding, activation, plan):
    return ref.iom_reference(x, w, stride=stride, padding=padding)


@registry.register(
    "zero_insertion", description="§II-A method (i) baseline")
def _zero_insertion_impl(x, w, bias, *, stride, padding, activation, plan):
    return baselines.zero_insertion_tconv(x, w, stride=stride, padding=padding)


@registry.register("tdc", description="§II-A method (ii) baseline")
def _tdc_impl(x, w, bias, *, stride, padding, activation, plan):
    return baselines.tdc_tconv(x, w, stride=stride, padding=padding)


@registry.register("lax", description="XLA native conv_transpose (gold)")
def _lax_impl(x, w, bias, *, stride, padding, activation, plan):
    return ref.tconv_lax(x, w, stride=stride, padding=padding)


# ---------------------------------------------------------------------------
# Dispatch.
# ---------------------------------------------------------------------------


@functools.partial(
    jax.jit,
    static_argnames=("stride", "padding", "method", "activation", "plan"))
def tconv(
    x: jax.Array,
    w: jax.Array,
    bias: Optional[jax.Array] = None,
    *,
    stride: int,
    padding: str = "SAME",
    method: str = "mm2im",
    activation: str = "none",
    plan: PlanLike = None,
) -> jax.Array:
    """Transposed convolution.  x: (B,Ih,Iw,Ic); w: (Ks,Ks,Oc,Ic) HWOI."""
    spec = registry.get(method)
    plan = registry.as_plan(plan)
    if plan is not None:
        if not spec.supports_plan:
            raise ValueError(
                f"method {method!r} does not accept an explicit tile plan")
        if plan.block_oh % stride != 0:
            raise ValueError(
                f"plan block_oh={plan.block_oh} must be a multiple of "
                f"stride {stride}")
    # Epilogue order is bias -> activation, so activation may only be fused
    # into the kernel when the bias is also applied inside it (fused or
    # absent); otherwise the kernel would activate before the bias add.
    fuse_act = spec.fuses_activation and (bias is None or spec.fuses_bias)
    out = spec.fn(x, w, bias if spec.fuses_bias else None,
                  stride=stride, padding=padding,
                  activation=activation if fuse_act else "none",
                  plan=plan)
    if bias is not None and not spec.fuses_bias:
        out = out + bias[None, None, None, :]
    if activation != "none" and not fuse_act:
        from repro.kernels.mm2im_pallas import _ACTIVATIONS
        out = _ACTIVATIONS[activation](out)
    return out


def tconv_int8(
    x_q: jax.Array,
    w_q: jax.Array,
    bias_q: jax.Array,
    out_scale,
    *,
    stride: int,
    padding: str = "SAME",
    plan: PlanLike = None,
) -> jax.Array:
    """8-bit MM2IM TCONV (the paper's precision): int8 in, int8 out.

    ``out_scale`` is a python float (per-tensor requant) or a length-Oc
    array (TFLite-style per-channel requant, fused in the PPU epilogue).
    """
    if not isinstance(out_scale, float):
        import numpy as _np
        out_scale = _np.asarray(out_scale, _np.float32)
    plan = registry.as_plan(plan)
    kw = {}
    if plan is not None:
        kw = dict(block_oh=plan.block_oh, block_oc=plan.block_oc,
                  grid_order=plan.grid_order)
    return mm2im_tconv(x_q, w_q, bias_q, stride=stride, padding=padding,
                       out_scale=out_scale, **kw)
