"""Public TCONV op: jit'd, differentiable dispatch over implementations.

``tconv(x, w, bias, stride=…, method=…)`` is the framework-facing API used
by ``layers`` and the GAN models.  Dispatch goes through the pluggable
kernel registry (``kernels/registry.py``); the built-in methods are:

  * ``'mm2im'``         — the paper's technique: fused Pallas kernel
                          (``mm2im_pallas.mm2im_tconv``).  Default.
  * ``'mm2im_db'``      — double-buffered pipeline variant: per-row-block
                          slab DMA overlapped with MatMul+col2im
                          (``mm2im_db_pallas``); bit-identical to 'mm2im'.
  * ``'iom_unfused'``   — paper Eq. (2) unfused: MatMul -> HBM -> col2im
                          scatter (the XLA-level baseline).
  * ``'zero_insertion'``— §II-A method (i) baseline.
  * ``'tdc'``           — §II-A method (ii) baseline.
  * ``'lax'``           — XLA's native conv_transpose (gold).

An explicit tile plan (``registry.Plan`` or a ``(block_oh, block_oc[,
grid_order])`` tuple — typically produced by ``core/autotune.py``) can be
passed as ``plan=``; it flows into the Pallas kernel's block geometry, and
a plan carrying ``method='mm2im_db'`` upgrades the default dispatch to the
variant it was tuned for.  Methods that don't tile reject explicit plans.

**Automatic plan consumption** (docs/AUTOTUNER.md): when no ``plan=`` is
given and the method supports plans, the dispatcher looks up the tuned
plan by problem key — shapes, dtype, batch — at trace time.  Precedence:
explicit ``plan=`` > user cache hit > shipped per-backend plan table
(``core/plan_table.py``) > ``plan_blocks`` heuristic; ``consumed_plans()``
records which tier served each hit.  Disable with
``REPRO_AUTOTUNE_AUTOLOAD=0``.  The lookup happens once per jit trace, so
a cache written *after* a shape was first compiled is only seen by new
traces.

Training support: the Pallas forwards are wrapped in ``jax.custom_vjp``
whose backward pass is the (automatically derived) VJP of the
mathematically identical dilated-conv formulation — so
examples/train_dcgan.py trains *through* the MM2IM kernels.
"""

from __future__ import annotations

import functools
import os
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels import baselines, ref, registry
from repro.kernels.mm2im_db_pallas import mm2im_db_tconv
from repro.kernels.mm2im_pallas import mm2im_tconv
from repro.kernels.registry import Plan, PlanLike


def _fwd_math(x, w, bias, *, stride, padding):
    """Differentiable mathematical definition (dilated-conv formulation)."""
    out = ref.tconv_direct(x, w, stride=stride, padding=padding)
    if bias is not None:
        out = out + bias[None, None, None, :]
    return out


def _make_mm2im_diff(kernel_fn):
    """custom_vjp wrapper for a fused MM2IM-family forward kernel.

    The backward pass is the VJP of the mathematically identical
    dilated-conv formulation; both Pallas variants share it because they
    compute the same function (bit-identical forwards).
    """

    @functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
    def diff(x, w, bias, stride, padding, activation, plan):
        kw = {}
        if plan is not None:
            kw = dict(block_oh=plan.block_oh, block_oc=plan.block_oc,
                      grid_order=plan.grid_order)
        return kernel_fn(x, w, bias, stride=stride, padding=padding,
                         activation=activation, **kw)

    def fwd(x, w, bias, stride, padding, activation, plan):
        out = diff(x, w, bias, stride, padding, activation, plan)
        return out, (x, w, bias, out)

    def bwd(stride, padding, activation, plan, res, g):
        x, w, bias, out = res
        # Activation backward (epilogue was fused into the kernel).
        if activation == "relu":
            g = g * (out > 0)
        elif activation == "tanh":
            g = g * (1.0 - out * out)
        elif activation == "leaky_relu":
            g = g * jnp.where(out >= 0, 1.0, 0.2)
        # Zero-bias placeholder in the *weight* dtype: an f32 constant here
        # silently promotes the replayed bf16 forward to f32.
        bias0 = jnp.zeros((w.shape[2],), w.dtype) if bias is None else bias
        _, vjp = jax.vjp(
            lambda xx, ww, bb: _fwd_math(xx, ww, bb, stride=stride,
                                         padding=padding),
            x, w, bias0)
        dx, dw, db = vjp(g)
        return dx, dw, None if bias is None else db

    diff.defvjp(fwd, bwd)
    return diff


_mm2im_diff = _make_mm2im_diff(mm2im_tconv)
_mm2im_db_diff = _make_mm2im_diff(mm2im_db_tconv)


# ---------------------------------------------------------------------------
# Built-in method registration.
# ---------------------------------------------------------------------------


@registry.register(
    "mm2im", fuses_bias=True, fuses_activation=True, supports_plan=True,
    description="fused Pallas MM2IM kernel (paper technique; default)")
def _mm2im_impl(x, w, bias, *, stride, padding, activation, plan):
    return _mm2im_diff(x, w, bias, stride, padding, activation, plan)


@registry.register(
    "mm2im_db", fuses_bias=True, fuses_activation=True, supports_plan=True,
    description="double-buffered MM2IM: slab DMA pipelined against compute")
def _mm2im_db_impl(x, w, bias, *, stride, padding, activation, plan):
    return _mm2im_db_diff(x, w, bias, stride, padding, activation, plan)


@registry.register(
    "iom_unfused",
    description="paper Eq. (2) unfused: MatMul -> HBM -> col2im scatter")
def _iom_unfused_impl(x, w, bias, *, stride, padding, activation, plan):
    return ref.iom_reference(x, w, stride=stride, padding=padding)


@registry.register(
    "zero_insertion", description="§II-A method (i) baseline")
def _zero_insertion_impl(x, w, bias, *, stride, padding, activation, plan):
    return baselines.zero_insertion_tconv(x, w, stride=stride, padding=padding)


@registry.register("tdc", description="§II-A method (ii) baseline")
def _tdc_impl(x, w, bias, *, stride, padding, activation, plan):
    return baselines.tdc_tconv(x, w, stride=stride, padding=padding)


@registry.register("lax", description="XLA native conv_transpose (gold)")
def _lax_impl(x, w, bias, *, stride, padding, activation, plan):
    return ref.tconv_lax(x, w, stride=stride, padding=padding)


# ---------------------------------------------------------------------------
# Automatic plan-cache consumption.
# ---------------------------------------------------------------------------

AUTOLOAD_ENV = "REPRO_AUTOTUNE_AUTOLOAD"

# Ring of (cache_key, Plan, tier) triples auto-consumed by tconv/tconv_int8
# — observability for tests and debugging (appends happen at trace time).
# tier is which precedence tier served the hit: autotune.TIER_USER_CACHE
# (the on-disk user cache) or autotune.TIER_SHIPPED (a committed
# per-backend table from core/plan_table.py).
_CONSUMED: list = []
_CONSUMED_CAP = 64


def consumed_plans() -> tuple:
    """(cache_key, Plan, tier) triples auto-consumed so far, oldest first."""
    return tuple(_CONSUMED)


def clear_consumed_plans() -> None:
    _CONSUMED.clear()


def _autoload_enabled() -> bool:
    return os.environ.get(AUTOLOAD_ENV, "1").lower() not in ("0", "false",
                                                             "off")


def _auto_plan(x, w, stride: int, padding: str) -> Optional[Plan]:
    """Trace-time lookup of a tuned plan for this problem key (or None).

    Runs while ``tconv`` traces, so shapes/dtypes are concrete; any cache
    problem degrades to the heuristic default rather than raising.
    """
    if not _autoload_enabled():
        return None
    try:
        from repro.core.autotune import lookup_plan, cache_key
        from repro.core.maps import TConvProblem

        b, ih, iw, ic = x.shape
        ks, _, oc, _ = w.shape
        p = TConvProblem(ih, iw, ic, ks, oc, stride, padding)
        hit = lookup_plan(p, dtype=x.dtype, batch=b)
        if hit is None:
            return None
        plan, tier = hit
        if plan.block_oh % stride != 0:
            # Corrupt/hand-edited geometry: an auto-loaded plan degrades to
            # the heuristic instead of failing dispatch (explicit plans
            # with the same defect still raise — that's a caller error).
            return None
        _CONSUMED.append((cache_key(p, dtype=x.dtype, batch=b), plan, tier))
        del _CONSUMED[:-_CONSUMED_CAP]
        return plan
    except Exception:
        return None  # never let a broken cache break dispatch


# ---------------------------------------------------------------------------
# Dispatch.
# ---------------------------------------------------------------------------


def _check_explicit_plan(plan: Plan, stride: int) -> None:
    """Reject explicit-plan geometry the kernels cannot tile.

    Shared by ``tconv`` and ``tconv_int8`` so both entry points surface
    the same caller error (auto-loaded plans with these defects are
    silently discarded by ``_auto_plan`` instead).
    """
    if plan.block_oh % stride != 0:
        raise ValueError(
            f"plan block_oh={plan.block_oh} must be a multiple of "
            f"stride {stride}")


@functools.partial(
    jax.jit,
    static_argnames=("stride", "padding", "method", "activation", "plan"))
def tconv(
    x: jax.Array,
    w: jax.Array,
    bias: Optional[jax.Array] = None,
    *,
    stride: int,
    padding: str = "SAME",
    method: str = "mm2im",
    activation: str = "none",
    plan: PlanLike = None,
) -> jax.Array:
    """Transposed convolution.  x: (B,Ih,Iw,Ic); w: (Ks,Ks,Oc,Ic) HWOI."""
    spec = registry.get(method)
    plan = registry.as_plan(plan)
    if plan is not None and not spec.supports_plan:
        raise ValueError(
            f"method {method!r} does not accept an explicit tile plan")
    if plan is None and spec.supports_plan:
        plan = _auto_plan(x, w, stride, padding)  # cache > shipped > heur.
    if plan is not None:
        _check_explicit_plan(plan, stride)
        # A plan tuned for a specific kernel variant upgrades the *default*
        # dispatch to that variant; an explicitly requested non-default
        # method wins over the plan's preference (geometry still applies).
        # An unregistered plan.method (stale cache entry, plugin variant
        # not imported in this process) quietly keeps the default — a bad
        # cache must never break inference.
        if (plan.method is not None and plan.method != method
                and method == "mm2im"):
            try:
                variant = registry.get(plan.method)
            except ValueError:
                variant = None
            if variant is not None and variant.supports_plan:
                spec = variant
    # Epilogue order is bias -> activation, so activation may only be fused
    # into the kernel when the bias is also applied inside it (fused or
    # absent); otherwise the kernel would activate before the bias add.
    fuse_act = spec.fuses_activation and (bias is None or spec.fuses_bias)
    out = spec.fn(x, w, bias if spec.fuses_bias else None,
                  stride=stride, padding=padding,
                  activation=activation if fuse_act else "none",
                  plan=plan)
    if bias is not None and not spec.fuses_bias:
        out = out + bias[None, None, None, :]
    if activation != "none" and not fuse_act:
        from repro.kernels.mm2im_pallas import _ACTIVATIONS
        out = _ACTIVATIONS[activation](out)
    return out


def tconv_int8(
    x_q: jax.Array,
    w_q: jax.Array,
    bias_q: jax.Array,
    out_scale,
    *,
    stride: int,
    padding: str = "SAME",
    plan: PlanLike = None,
) -> jax.Array:
    """8-bit MM2IM TCONV (the paper's precision): int8 in, int8 out.

    ``out_scale`` is a python float (per-tensor requant) or a length-Oc
    array (TFLite-style per-channel requant, fused in the PPU epilogue).
    With no explicit ``plan=``, the autotuner cache is consulted under the
    int8 problem key; a plan tuned for ``'mm2im_db'`` runs the
    double-buffered kernel (bit-identical int32 accumulation either way).
    """
    if not isinstance(out_scale, float):
        import numpy as _np
        out_scale = _np.asarray(out_scale, _np.float32)
    plan = registry.as_plan(plan)
    if plan is not None:
        # Same contract as tconv: surfaced here rather than as a deeper
        # kernel block-shape assert.
        _check_explicit_plan(plan, stride)
    if plan is None:
        plan = _auto_plan(x_q, w_q, stride, padding)
    kernel = mm2im_tconv
    kw = {}
    if plan is not None:
        kw = dict(block_oh=plan.block_oh, block_oc=plan.block_oc,
                  grid_order=plan.grid_order)
        if plan.method not in (None, "mm2im"):
            # Same variant-upgrade rule as tconv, through the autotuner's
            # runner table (these entry points take out_scale, unlike the
            # registry dispatch signature).  Unknown variants degrade to
            # the default kernel — a bad cache must never break inference.
            from repro.core.autotune import KERNEL_RUNNERS
            kernel = KERNEL_RUNNERS.get(plan.method, mm2im_tconv)
    return kernel(x_q, w_q, bias_q, stride=stride, padding=padding,
                  out_scale=out_scale, **kw)
