"""Pluggable TCONV kernel registry — the dispatch substrate for ``ops.tconv``.

The seed hard-coded a closed ``_METHODS`` tuple inside ``kernels/ops.py``;
this module replaces it with an open registry so new implementations (a
pipelined-DMA kernel, a sparse variant, a GPU port) plug in without
touching the dispatch site, and so the autotuner (``core/autotune.py``)
can hand any implementation an explicit tile plan.

Two value types live here because every other layer depends on them:

* :class:`Plan` — an explicit ``(block_oh, block_oc, grid_order)`` tile
  plan, optionally pinning the kernel variant that should execute it
  (``method`` — e.g. ``'mm2im'`` vs ``'mm2im_db'``).  Hashable (frozen
  dataclass) so it can ride through ``jax.jit`` static arguments; produced
  by ``core/autotune.py`` or built by hand.
* :class:`KernelSpec` — one registered implementation plus its dispatch
  contract: the single entry point
  ``fn(x, w, *, stride, padding, epilogue, plan)`` and the declared
  epilogue capabilities — which PPU stages it fuses (``fuses``, a
  frozenset over ``core.epilogue.STAGES``), whether it accepts an explicit
  :class:`Plan`, and whether it computes int8 × int8 natively
  (``supports_int8``).

Registration happens at import time in ``kernels/ops.py`` for the six
built-in methods; tests and extensions use :func:`register` /
:func:`unregister` directly.

Registering a third-party kernel variant
----------------------------------------
A variant is one function with the dispatch signature plus a
:func:`register` decoration — nothing else in the stack changes
(docs/DESIGN.md §3 walks through the dataflow contract):

    from repro.kernels import registry

    @registry.register(
        "my_variant",
        fuses=("bias", "activation"),  # PPU stages the kernel fuses
        supports_plan=True,            # accepts an explicit registry.Plan
        supports_int8=True,            # int8 x int8 -> int32 natively
        description="sparse MM2IM with 2:4 weight pruning")
    def my_variant(x, w, *, stride, padding, epilogue, plan):
        # epilogue is the already-split kernel part: only stages this
        # spec declared in `fuses` (plus the final out_dtype cast when
        # the kernel runs last) ever arrive here.
        ...
        return out_nhwc

    out = ops.tconv(x, w, stride=2, method="my_variant")

Declare only the PPU stages the kernel truly fuses: the dispatcher
(``ops._dispatch``) splits every :class:`~repro.core.epilogue.Epilogue`
into the fused prefix (handed to the kernel) and the unfused remainder
(applied by the dispatcher), which is what keeps every method numerically
interchangeable.  A variant with ``supports_plan=True`` is *autotunable
with zero extra wiring*: ``core/autotune.py`` measures candidates through
this registry, tuned plans carry ``Plan.method = "my_variant"``, and both
``ops.tconv`` and ``ops.tconv_int8`` dispatch back to it automatically.
A variant without ``supports_int8`` still serves int8 problems — the
dispatcher runs it through the dequant -> compute -> requant fallback.
"""

from __future__ import annotations

import dataclasses
from typing import (Callable, FrozenSet, Iterable, Optional, Sequence, Tuple,
                    Union)

from repro.core.epilogue import STAGES


@dataclasses.dataclass(frozen=True)
class Plan:
    """Explicit Tiled-MM2IM plan (paper Alg. 1 geometry knobs) — schema v2.

    ``block_oh`` must be a multiple of the stride it is used with;
    ``grid_order`` is ``'bcj'`` (activation-stationary), ``'cbj'``
    (weight-stationary, the paper's Alg. 1 order) or ``'auto'``.

    ``method`` optionally pins the kernel variant the plan was tuned for
    (e.g. ``'mm2im_db'`` for the double-buffered pipeline).  ``None`` means
    "no preference": the dispatcher's requested method runs the geometry.

    ``fold_batch`` (schema v2) collapses ``(batch, slab-rows)`` into the
    MatMul M-dimension: one ``(B·n_slab·Iw, Ic)`` product per row-block
    instead of one starved ``(n_slab·Iw, Ic)`` product per batch element,
    and the Pallas grid drops its batch axis.  Bit-identical to the
    unfolded dataflow by construction (per-element reduction order is
    unchanged — docs/DESIGN.md §2.5); with ``batch == 1`` it degenerates
    to the unfolded kernel.  Serialized plans always carry the field
    (``to_json``); v1 plans without it load as unfolded (``from_json``).
    """

    block_oh: int
    block_oc: int
    grid_order: str = "auto"
    method: Optional[str] = None
    fold_batch: bool = False

    def __post_init__(self):
        if self.block_oh < 1 or self.block_oc < 1:
            raise ValueError(f"non-positive plan blocks: {self}")
        if self.grid_order not in ("auto", "bcj", "cbj"):
            raise ValueError(
                f"grid_order must be 'auto'|'bcj'|'cbj', got {self.grid_order!r}")

    def to_json(self) -> dict:
        d = {"block_oh": self.block_oh, "block_oc": self.block_oc,
             "grid_order": self.grid_order,
             "fold_batch": bool(self.fold_batch)}
        if self.method is not None:
            d["method"] = self.method
        return d

    @classmethod
    def from_json(cls, d: dict) -> "Plan":
        method = d.get("method")
        return cls(int(d["block_oh"]), int(d["block_oc"]),
                   str(d.get("grid_order", "auto")),
                   None if method is None else str(method),
                   bool(d.get("fold_batch", False)))


PlanLike = Union[Plan, Tuple[int, int], Tuple[int, int, str], None]


def as_plan(plan: PlanLike) -> Optional[Plan]:
    """Normalize user input (Plan | (boh, boc) | (boh, boc, order)) -> Plan."""
    if plan is None or isinstance(plan, Plan):
        return plan
    if isinstance(plan, (tuple, list)) and len(plan) in (2, 3):
        return Plan(int(plan[0]), int(plan[1]),
                    str(plan[2]) if len(plan) == 3 else "auto")
    raise ValueError(f"cannot interpret {plan!r} as a tile plan")


@dataclasses.dataclass(frozen=True)
class KernelSpec:
    """One registered TCONV implementation and its dispatch contract.

    ``fn(x, w, *, stride, padding, epilogue, plan)`` returns the NHWC
    output.  ``epilogue`` is the *kernel part* of the requested
    :class:`~repro.core.epilogue.Epilogue` — the dispatcher has already
    removed every stage this spec does not declare in ``fuses`` and
    applies them itself afterwards, so an implementation only ever sees
    stages it promised to fuse.  Implementations with
    ``supports_plan=False`` receive ``plan=None`` (passing an explicit
    plan to them is a dispatch error); implementations without
    ``supports_int8`` receive float operands even for int8 problems (the
    dispatcher's dequant -> requant fallback).
    """

    name: str
    fn: Callable
    fuses: FrozenSet[str] = frozenset()
    supports_plan: bool = False
    supports_int8: bool = False
    differentiable: bool = True
    description: str = ""

    # Convenience views of the fused-stage set.
    @property
    def fuses_bias(self) -> bool:
        return "bias" in self.fuses

    @property
    def fuses_activation(self) -> bool:
        return "activation" in self.fuses

    @property
    def fuses_requant(self) -> bool:
        return "requant" in self.fuses


_REGISTRY: dict[str, KernelSpec] = {}


def register(
    name: str,
    *,
    fuses: Iterable[str] = (),
    supports_plan: bool = False,
    supports_int8: bool = False,
    differentiable: bool = True,
    description: str = "",
) -> Callable:
    """Decorator: register ``fn`` as TCONV method ``name``.

    ``fuses`` names the PPU epilogue stages the implementation fuses — a
    subset of ``core.epilogue.STAGES`` (``'bias'``, ``'requant'``,
    ``'activation'``).  Re-registering an existing name replaces it
    (latest wins) so tests can shadow a built-in and restore it afterwards.
    """
    fuses = frozenset(fuses)
    bad = fuses - set(STAGES)
    if bad:
        raise ValueError(
            f"fuses must be a subset of {STAGES}, got extras {sorted(bad)}")

    def deco(fn: Callable) -> Callable:
        _REGISTRY[name] = KernelSpec(
            name=name, fn=fn, fuses=fuses, supports_plan=supports_plan,
            supports_int8=supports_int8, differentiable=differentiable,
            description=description)
        return fn

    return deco


def unregister(name: str) -> Optional[KernelSpec]:
    """Remove a method; returns the removed spec (None if absent)."""
    return _REGISTRY.pop(name, None)


def get(name: str) -> KernelSpec:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"method must be one of {names()}, got {name!r}") from None


def names() -> Tuple[str, ...]:
    """Registered method names, in registration order."""
    return tuple(_REGISTRY)


def specs() -> Sequence[KernelSpec]:
    return tuple(_REGISTRY.values())
