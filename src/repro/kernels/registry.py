"""Pluggable TCONV kernel registry — the dispatch substrate for ``ops.tconv``.

The seed hard-coded a closed ``_METHODS`` tuple inside ``kernels/ops.py``;
this module replaces it with an open registry so new implementations (a
pipelined-DMA kernel, a sparse variant, a GPU port) plug in without
touching the dispatch site, and so the autotuner (``core/autotune.py``)
can hand any implementation an explicit tile plan.

Two value types live here because every other layer depends on them and
they must stay import-cycle-free (this module imports only the stdlib):

* :class:`Plan` — an explicit ``(block_oh, block_oc, grid_order)`` tile
  plan, optionally pinning the kernel variant that should execute it
  (``method`` — e.g. ``'mm2im'`` vs ``'mm2im_db'``).  Hashable (frozen
  dataclass) so it can ride through ``jax.jit`` static arguments; produced
  by ``core/autotune.py`` or built by hand.
* :class:`KernelSpec` — one registered implementation plus its dispatch
  capabilities (does it fuse bias/activation, does it accept a Plan, is it
  differentiable).

Registration happens at import time in ``kernels/ops.py`` for the six
built-in methods; tests and extensions use :func:`register` /
:func:`unregister` directly.

Registering a third-party kernel variant
----------------------------------------
A variant is one function with the dispatch signature plus a
:func:`register` decoration — nothing else in the stack changes
(docs/DESIGN.md §3 walks through the dataflow contract):

    from repro.kernels import registry

    @registry.register(
        "my_variant",
        fuses_bias=True,          # dispatcher skips its own bias add
        fuses_activation=True,    # dispatcher skips its own activation
        supports_plan=True,       # accepts an explicit registry.Plan
        description="sparse MM2IM with 2:4 weight pruning")
    def my_variant(x, w, bias, *, stride, padding, activation, plan):
        ...
        return out_nhwc

    out = ops.tconv(x, w, stride=2, method="my_variant")

Declare only the epilogue stages the kernel truly fuses: ``ops.tconv``
applies whatever the implementation does not fuse, which is what keeps
every method numerically interchangeable.  A variant with
``supports_plan=True`` becomes autotunable the moment
``core/autotune.py``'s measure loop knows how to call it (see
``core.autotune.KERNEL_RUNNERS``); tuned plans then carry
``Plan.method = "my_variant"`` and ``ops.tconv`` dispatches back to it
automatically.  The int8 requant path (``ops.tconv_int8``) bypasses the
registry signature (it needs ``out_scale``) and honors ``Plan.method``
via ``KERNEL_RUNNERS`` instead — a variant that should serve tuned int8
plans must provide a runner there with the ``mm2im_tconv`` signature.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Iterable, Optional, Sequence, Tuple, Union


@dataclasses.dataclass(frozen=True)
class Plan:
    """Explicit Tiled-MM2IM plan (paper Alg. 1 geometry knobs).

    ``block_oh`` must be a multiple of the stride it is used with;
    ``grid_order`` is ``'bcj'`` (activation-stationary), ``'cbj'``
    (weight-stationary, the paper's Alg. 1 order) or ``'auto'``.

    ``method`` optionally pins the kernel variant the plan was tuned for
    (e.g. ``'mm2im_db'`` for the double-buffered pipeline).  ``None`` means
    "no preference": the dispatcher's requested method runs the geometry.
    """

    block_oh: int
    block_oc: int
    grid_order: str = "auto"
    method: Optional[str] = None

    def __post_init__(self):
        if self.block_oh < 1 or self.block_oc < 1:
            raise ValueError(f"non-positive plan blocks: {self}")
        if self.grid_order not in ("auto", "bcj", "cbj"):
            raise ValueError(
                f"grid_order must be 'auto'|'bcj'|'cbj', got {self.grid_order!r}")

    def to_json(self) -> dict:
        d = {"block_oh": self.block_oh, "block_oc": self.block_oc,
             "grid_order": self.grid_order}
        if self.method is not None:
            d["method"] = self.method
        return d

    @classmethod
    def from_json(cls, d: dict) -> "Plan":
        method = d.get("method")
        return cls(int(d["block_oh"]), int(d["block_oc"]),
                   str(d.get("grid_order", "auto")),
                   None if method is None else str(method))


PlanLike = Union[Plan, Tuple[int, int], Tuple[int, int, str], None]


def as_plan(plan: PlanLike) -> Optional[Plan]:
    """Normalize user input (Plan | (boh, boc) | (boh, boc, order)) -> Plan."""
    if plan is None or isinstance(plan, Plan):
        return plan
    if isinstance(plan, (tuple, list)) and len(plan) in (2, 3):
        return Plan(int(plan[0]), int(plan[1]),
                    str(plan[2]) if len(plan) == 3 else "auto")
    raise ValueError(f"cannot interpret {plan!r} as a tile plan")


@dataclasses.dataclass(frozen=True)
class KernelSpec:
    """One registered TCONV implementation and its dispatch contract.

    ``fn(x, w, bias, *, stride, padding, activation, plan)`` returns the
    NHWC output.  Implementations that do not fuse bias/activation receive
    ``bias=None`` / ``activation='none'`` and the dispatcher applies the
    epilogue itself; implementations with ``supports_plan=False`` receive
    ``plan=None`` (passing an explicit plan to them is a dispatch error).
    """

    name: str
    fn: Callable
    fuses_bias: bool = False
    fuses_activation: bool = False
    supports_plan: bool = False
    differentiable: bool = True
    description: str = ""


_REGISTRY: dict[str, KernelSpec] = {}


def register(
    name: str,
    *,
    fuses_bias: bool = False,
    fuses_activation: bool = False,
    supports_plan: bool = False,
    differentiable: bool = True,
    description: str = "",
) -> Callable:
    """Decorator: register ``fn`` as TCONV method ``name``.

    Re-registering an existing name replaces it (latest wins) so tests can
    shadow a built-in and restore it afterwards.
    """

    def deco(fn: Callable) -> Callable:
        _REGISTRY[name] = KernelSpec(
            name=name, fn=fn, fuses_bias=fuses_bias,
            fuses_activation=fuses_activation, supports_plan=supports_plan,
            differentiable=differentiable, description=description)
        return fn

    return deco


def unregister(name: str) -> Optional[KernelSpec]:
    """Remove a method; returns the removed spec (None if absent)."""
    return _REGISTRY.pop(name, None)


def get(name: str) -> KernelSpec:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"method must be one of {names()}, got {name!r}") from None


def names() -> Tuple[str, ...]:
    """Registered method names, in registration order."""
    return tuple(_REGISTRY)


def specs() -> Sequence[KernelSpec]:
    return tuple(_REGISTRY.values())
