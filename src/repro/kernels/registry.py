"""Pluggable TCONV kernel registry — the dispatch substrate for ``ops.tconv``.

The seed hard-coded a closed ``_METHODS`` tuple inside ``kernels/ops.py``;
this module replaces it with an open registry so new implementations (a
future fully-pipelined DMA kernel, a sparse variant, a GPU port) plug in
without touching the dispatch site, and so the autotuner
(``core/autotune.py``) can hand any implementation an explicit tile plan.

Two value types live here because every other layer depends on them and
they must stay import-cycle-free (this module imports only the stdlib):

* :class:`Plan` — an explicit ``(block_oh, block_oc, grid_order)`` tile
  plan.  Hashable (frozen dataclass) so it can ride through ``jax.jit``
  static arguments; produced by ``core/autotune.py`` or built by hand.
* :class:`KernelSpec` — one registered implementation plus its dispatch
  capabilities (does it fuse bias/activation, does it accept a Plan, is it
  differentiable).

Registration happens at import time in ``kernels/ops.py`` for the five
built-in methods; tests and extensions use :func:`register` /
:func:`unregister` directly.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Iterable, Optional, Sequence, Tuple, Union


@dataclasses.dataclass(frozen=True)
class Plan:
    """Explicit Tiled-MM2IM plan (paper Alg. 1 geometry knobs).

    ``block_oh`` must be a multiple of the stride it is used with;
    ``grid_order`` is ``'bcj'`` (activation-stationary), ``'cbj'``
    (weight-stationary, the paper's Alg. 1 order) or ``'auto'``.
    """

    block_oh: int
    block_oc: int
    grid_order: str = "auto"

    def __post_init__(self):
        if self.block_oh < 1 or self.block_oc < 1:
            raise ValueError(f"non-positive plan blocks: {self}")
        if self.grid_order not in ("auto", "bcj", "cbj"):
            raise ValueError(
                f"grid_order must be 'auto'|'bcj'|'cbj', got {self.grid_order!r}")

    def to_json(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_json(cls, d: dict) -> "Plan":
        return cls(int(d["block_oh"]), int(d["block_oc"]),
                   str(d.get("grid_order", "auto")))


PlanLike = Union[Plan, Tuple[int, int], Tuple[int, int, str], None]


def as_plan(plan: PlanLike) -> Optional[Plan]:
    """Normalize user input (Plan | (boh, boc) | (boh, boc, order)) -> Plan."""
    if plan is None or isinstance(plan, Plan):
        return plan
    if isinstance(plan, (tuple, list)) and len(plan) in (2, 3):
        return Plan(int(plan[0]), int(plan[1]),
                    str(plan[2]) if len(plan) == 3 else "auto")
    raise ValueError(f"cannot interpret {plan!r} as a tile plan")


@dataclasses.dataclass(frozen=True)
class KernelSpec:
    """One registered TCONV implementation and its dispatch contract.

    ``fn(x, w, bias, *, stride, padding, activation, plan)`` returns the
    NHWC output.  Implementations that do not fuse bias/activation receive
    ``bias=None`` / ``activation='none'`` and the dispatcher applies the
    epilogue itself; implementations with ``supports_plan=False`` receive
    ``plan=None`` (passing an explicit plan to them is a dispatch error).
    """

    name: str
    fn: Callable
    fuses_bias: bool = False
    fuses_activation: bool = False
    supports_plan: bool = False
    differentiable: bool = True
    description: str = ""


_REGISTRY: dict[str, KernelSpec] = {}


def register(
    name: str,
    *,
    fuses_bias: bool = False,
    fuses_activation: bool = False,
    supports_plan: bool = False,
    differentiable: bool = True,
    description: str = "",
) -> Callable:
    """Decorator: register ``fn`` as TCONV method ``name``.

    Re-registering an existing name replaces it (latest wins) so tests can
    shadow a built-in and restore it afterwards.
    """

    def deco(fn: Callable) -> Callable:
        _REGISTRY[name] = KernelSpec(
            name=name, fn=fn, fuses_bias=fuses_bias,
            fuses_activation=fuses_activation, supports_plan=supports_plan,
            differentiable=differentiable, description=description)
        return fn

    return deco


def unregister(name: str) -> Optional[KernelSpec]:
    """Remove a method; returns the removed spec (None if absent)."""
    return _REGISTRY.pop(name, None)


def get(name: str) -> KernelSpec:
    try:
        return _REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"method must be one of {names()}, got {name!r}") from None


def names() -> Tuple[str, ...]:
    """Registered method names, in registration order."""
    return tuple(_REGISTRY)


def specs() -> Sequence[KernelSpec]:
    return tuple(_REGISTRY.values())
