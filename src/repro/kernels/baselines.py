"""The paper's comparison TCONV methods, implemented in pure JAX.

* :func:`zero_insertion_tconv` — §II-A method (i): interior-pad the input
  with S-1 zeros and run a plain convolution with the flipped kernel.
  ~75% of MACs multiply inserted zeros (the overhead the paper cites [11]).
* :func:`tdc_tconv` — §II-A method (ii): Transforming Deconvolution to
  Convolution.  Decomposes the TCONV into S^2 stride-residue sub-filters,
  computes S^2 small dense convolutions, and interleaves the results.
  MAC-optimal but pays the sub-filter transformation + output interleave
  (the overhead the paper cites [8]).
* The unfused IOM baseline (MatMul -> HBM -> scatter col2im) lives in
  ``ref.iom_reference``.

All agree bit-for-bit (up to fp accumulation order) with ``ref.tconv_lax``;
tests sweep them jointly.  Benchmarks use them for the Table-III-style
method comparison on TPU terms (effectual-FLOP ratio / MXU utilization).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax import lax

from repro.kernels.ref import crop_offsets, out_size


@functools.partial(jax.jit, static_argnames=("stride", "padding"))
def zero_insertion_tconv(x, w, *, stride: int, padding: str = "SAME"):
    """TCONV via zero insertion + dense convolution (method (i))."""
    b, ih, iw, ic = x.shape
    ks, _, oc, _ = w.shape
    s = stride
    ct, cl = crop_offsets(ks, s, padding)
    oh = out_size(ih, ks, s, padding)
    ow = out_size(iw, ks, s, padding)
    xf = x.astype(jnp.float32)
    xd = lax.pad(xf, jnp.float32(0),
                 [(0, 0, 0), (0, 0, s - 1), (0, 0, s - 1), (0, 0, 0)])
    w_f = jnp.transpose(w, (0, 1, 3, 2))[::-1, ::-1].astype(jnp.float32)
    # Full-size conv then crop == SAME TCONV. padding (Ks-1) both sides.
    full = lax.conv_general_dilated(
        xd, w_f, (1, 1), [(ks - 1, ks - 1), (ks - 1, ks - 1)],
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    return lax.dynamic_slice(full, (0, ct, cl, 0), (b, oh, ow, oc))


def zero_insertion_macs(ih, iw, ic, ks, oc, stride, padding="SAME") -> int:
    """MACs a dense conv engine performs under zero-insertion."""
    oh = out_size(ih, ks, stride, padding)
    ow = out_size(iw, ks, stride, padding)
    return oh * ow * ks * ks * ic * oc


@functools.partial(jax.jit, static_argnames=("stride", "padding"))
def tdc_tconv(x, w, *, stride: int, padding: str = "SAME"):
    """TCONV via TDC: S^2 stride-residue sub-convolutions (method (ii)).

    For output residue class (a, b) mod S:
        out[S*q + a, S*p + b] = sum_{t,u} x[q + gh - t, p + gw - u]
                                          * w[S*t + rh, S*u + rw]
    with rh = (a + ct) % S, gh = (a + ct) // S  (similarly for width).
    """
    bsz, ih, iw, ic = x.shape
    ks, _, oc, _ = w.shape
    s = stride
    ct, cl = crop_offsets(ks, s, padding)
    oh = out_size(ih, ks, s, padding)
    ow = out_size(iw, ks, s, padding)
    xf = x.astype(jnp.float32)

    n_qh = -(-oh // s)  # sub-output rows per residue
    n_qw = -(-ow // s)
    outs = []
    for a in range(min(s, oh)):
        row = []
        rh, gh = (a + ct) % s, (a + ct) // s
        nth = (ks - 1 - rh) // s + 1  # sub-filter height
        for b in range(min(s, ow)):
            rw, gw = (b + cl) % s, (b + cl) // s
            ntw = (ks - 1 - rw) // s + 1
            if nth == 0 or ntw == 0:
                # Gapped residue (stride > kernel): no tap of w lands on
                # this (a, b) class — the sub-output is identically zero.
                row.append(jnp.zeros((bsz, n_qh, n_qw, oc), jnp.float32))
                continue
            # Sub-filter, flipped in t/u to express the sum as a conv.
            sub = w[rh::s, rw::s][::-1, ::-1]  # (nth, ntw, oc, ic)
            sub = jnp.transpose(sub, (0, 1, 3, 2))  # HWIO
            # out_sub[q] = sum_t' x[q + t' - (nth-1-gh)] * flipped_sub[t']
            # => conv padding: pad_lo = nth-1-gh; out length n_qh fixes pad_hi.
            pad_h = (nth - 1 - gh, n_qh - ih + gh)
            pad_w = (ntw - 1 - gw, n_qw - iw + gw)
            sub_out = lax.conv_general_dilated(
                xf, sub.astype(jnp.float32), (1, 1), [pad_h, pad_w],
                dimension_numbers=("NHWC", "HWIO", "NHWC"))
            row.append(sub_out)  # (B, n_qh, n_qw, oc)
        outs.append(jnp.stack(row, axis=3))  # (B, n_qh, n_qw, s_w, oc)
    grid = jnp.stack(outs, axis=2)  # (B, n_qh, s_h, n_qw, s_w, oc)
    full = grid.reshape(bsz, n_qh * grid.shape[2], n_qw * grid.shape[4], oc)
    return full[:, :oh, :ow, :]


def tdc_macs(ih, iw, ic, ks, oc, stride, padding="SAME") -> int:
    """MACs performed by the TDC decomposition (== effectual MACs + edge pad)."""
    s = stride
    ct, cl = crop_offsets(ks, s, padding)
    oh = out_size(ih, ks, s, padding)
    ow = out_size(iw, ks, s, padding)
    total = 0
    for a in range(min(s, oh)):
        rh = (a + ct) % s
        nth = (ks - 1 - rh) // s + 1
        for b in range(min(s, ow)):
            rw = (b + cl) % s
            ntw = (ks - 1 - rw) // s + 1
            total += (-(-oh // s)) * (-(-ow // s)) * nth * ntw * ic * oc
    return total
