"""Model configuration — one dataclass drives every assigned architecture.

A model is a stack of *units*; each unit is a fixed pattern of blocks
(e.g. ``("rglru", "rglru", "local_attn")`` for recurrentgemma's 2:1
hybrid).  Homogeneous unit stacks are parameter-stacked and executed with
``lax.scan`` so compile time is depth-independent (DESIGN.md §8).
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    vocab: int
    d_model: int
    n_layers: int
    n_heads: int = 0
    kv_heads: int = 0
    d_ff: int = 0
    head_dim: Optional[int] = None

    # Block pattern (cycled to fill n_layers; remainder becomes a tail).
    pattern: Tuple[str, ...] = ("attn",)
    window: Optional[int] = None          # local_attn window
    mlp_kind: str = "swiglu"

    # Attention flavor flags
    qkv_bias: bool = False
    qk_norm: bool = False
    rope_theta: float = 10000.0

    # MoE (block type "attn" uses MoE FFN when n_experts > 0)
    n_experts: int = 0
    top_k: int = 0
    n_shared_experts: int = 0
    moe_d_ff: Optional[int] = None
    capacity_factor: float = 1.25
    moe_sharding: str = "replicated_gather"   # | "tensor_parallel"
    moe_group_size: int = 1024

    # SSM (mamba2)
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_state: int = 128
    ssm_chunk: int = 256
    ssm_conv: int = 4

    # Encoder-decoder (audio / seq2seq)
    enc_layers: int = 0                   # >0 => enc-dec model
    modality: str = "text"                # text | audio | vision
    frontend_len: int = 0                 # stub frontend sequence length

    # Numerics / execution
    param_dtype: str = "float32"
    activ_dtype: str = "bfloat16"
    norm_eps: float = 1e-6
    tied_embeddings: bool = True
    remat: bool = True
    attn_impl: str = "auto"               # auto | dense | chunked | flash
    attn_chunk_threshold: int = 8192      # auto: switch to chunked above
    attn_chunk_q: int = 1024
    attn_chunk_k: int = 1024
    opt_state_dtype: str = "float32"      # bf16 for grok-scale models
    microbatches: int = 1                 # gradient-accumulation per step

    @property
    def resolved_head_dim(self) -> int:
        return self.head_dim or (self.d_model // max(self.n_heads, 1))

    @property
    def n_units(self) -> int:
        return self.n_layers // len(self.pattern)

    @property
    def tail(self) -> Tuple[str, ...]:
        return self.pattern[: self.n_layers % len(self.pattern)]

    @property
    def is_subquadratic(self) -> bool:
        """True if no *global* full attention appears (long_500k runnable)."""
        blocks = set(self.pattern) | set(self.tail)
        return "attn" not in blocks

    def param_count(self) -> int:
        """Analytic parameter count (embedding + blocks)."""
        d, f, v = self.d_model, self.d_ff, self.vocab
        hd = self.resolved_head_dim
        counts = {"embed": v * d * (1 if self.tied_embeddings else 2)}
        per = {}
        per["attn"] = d * hd * (self.n_heads + 2 * self.kv_heads) + self.n_heads * hd * d
        per["local_attn"] = per["attn"]
        if self.n_experts:
            ff = self.moe_d_ff or f
            per["attn"] += d * self.n_experts + 3 * self.n_experts * d * ff \
                + (3 * d * ff * self.n_shared_experts)
        elif f:
            mlp = 3 * d * f if self.mlp_kind in ("swiglu", "geglu") else 2 * d * f
            per["attn"] += mlp
            per["local_attn"] += mlp
        din = self.ssm_expand * d
        nh = din // self.ssm_head_dim
        per["mamba2"] = d * (2 * din + 2 * self.ssm_state + nh) + din * d \
            + self.ssm_conv * (din + 2 * self.ssm_state)
        per["rglru"] = 2 * d * d + 3 * d * d + d * d  # w_x,w_gate,w_a,w_i,w_out ~5d^2
        if f:
            per["rglru"] += 3 * d * f if self.mlp_kind in ("swiglu", "geglu") else 2 * d * f
        blocks = list(self.pattern) * self.n_units + list(self.tail)
        total = counts["embed"] + sum(per.get(b, 0) for b in blocks)
        if self.enc_layers:
            total += self.enc_layers * per["attn"]  # encoder stack
            total += self.n_layers * per["attn"] // max(self.n_layers, 1) * 0
        return total
