"""GeneratorRunner — the one serving contract for every TCONV model.

Before this layer each generator (`dcgan_generator`, `pix2pix_generator`,
`fsrcnn`, `styletransfer`) carried its own copy of the `method=`/`plans=`
dispatch plumbing, only DCGAN could enumerate its TCONV problem shapes,
and every caller (step builders, benchmarks, a would-be server) had to
special-case each model's geometry.  The runner layer collapses that into
one uniform contract:

    runner = make_runner("dcgan", key=jax.random.PRNGKey(0))
    runner.apply(z)                       # f32; tuned plans consumed per tier
    runner.apply(z, precision="int8")     # every TCONV through the requant PPU
    runner.tconv_problems()               # {layer: TConvProblem} for warmup/sweep
    runner.input_spec(batch=8)            # what the server batches to
    runner.jitted(batch=8)                # memoized jit per (batch, precision)

Two pieces make it work:

* **Policies** (:class:`TconvPolicy`, :class:`Int8TconvPolicy`): a policy
  is the object a model forward delegates every named TCONV layer to
  (``models/gan.py::_tconv_policy``).  The f32 policy reproduces the
  legacy behavior (explicit plan > trace-time tier lookup); the int8
  policy statically quantizes operands with calibrated per-layer scales
  and runs the genuine ``tconv_int8`` requant-Epilogue path, dequantizing
  only for the activation (the Epilogue applies requant *before* the
  activation — see ``core/epilogue.py::STAGES`` — so a tanh in the int8
  domain would saturate; serving keeps the kernel store int8 and applies
  the nonlinearity on the dequantized output instead).
* **Specs** (:class:`RunnerSpec`): per-model closures for init / forward /
  problem enumeration / input geometry, registered below for all four
  models.  Geometry a model cannot recover from its params (FSRCNN and
  style-transfer input resolution, FSRCNN upscale) lives in runner
  *options* with per-spec defaults.

Int8 calibration is one-shot static post-training quantization (the
paper deploys quantized frozen models): a single eager f32 forward on a
synthetic sample records per-layer symmetric absmax scales for the
input, weight, and pre-activation accumulator; scales are python floats,
so they are static under jit and the requant epilogue never retraces.

Serving caveat: the models compute batch statistics inline (BN folding
is a deployment-time transform the repo doesn't model), so a request's
output depends on its co-batched neighbors.  The serving layer
(`repro/serve/`) documents and tests against the batched forward.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, Mapping, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.core import epilogue as epi
from repro.kernels import ops
from repro.models import gan

DEFAULT_METHOD = ops.DEFAULT_METHOD
PRECISIONS: Tuple[str, ...] = ("f32", "int8")


def _check_precision(precision: str) -> None:
    if precision not in PRECISIONS:
        raise ValueError(
            f"precision must be one of {PRECISIONS}, got {precision!r}")


# ---------------------------------------------------------------------------
# Per-layer TCONV policies.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TconvPolicy:
    """f32 execution policy: one kernel method + optional per-layer plans.

    A ``None`` plan for a layer is not "no plan": ``ops.tconv`` resolves
    the problem key through the four plan tiers (explicit > user cache >
    shipped table > heuristic) at trace time.
    """

    method: str = DEFAULT_METHOD
    plans: Optional[Mapping[str, Any]] = None

    def plan_for(self, name: str):
        return None if self.plans is None else self.plans.get(name)

    def tconv(self, x, w, bias=None, *, name: str, stride: int,
              padding: str = "SAME", activation: str = "none"):
        return ops.tconv(x, w, bias, stride=stride, padding=padding,
                         method=self.method, activation=activation,
                         plan=self.plan_for(name))


@dataclasses.dataclass(frozen=True)
class LayerQuant:
    """Calibrated symmetric absmax scales for one TCONV layer.

    ``out_scale`` is the requant multiplier the PPU epilogue applies to
    the int32 accumulator: acc is in units of ``x_scale * w_scale``, and
    the int8 output should be in units of ``y_scale``.
    """

    x_scale: float
    w_scale: float
    y_scale: float

    @property
    def out_scale(self) -> float:
        return (self.x_scale * self.w_scale) / self.y_scale


def quantize_int8(t, scale: float):
    """Symmetric per-tensor quantization to int8 (saturating at ±127)."""
    return jnp.clip(jnp.round(t / scale), -127, 127).astype(jnp.int8)


@dataclasses.dataclass(frozen=True)
class Int8TconvPolicy:
    """Int8 execution policy: every TCONV through the requant Epilogue.

    Operands are quantized with static calibrated scales, the kernel runs
    ``tconv_int8`` (int8 in, int8 out, bias+requant fused in the PPU
    epilogue), and only the activation runs on the dequantized output.
    """

    quant: Mapping[str, LayerQuant]
    method: str = DEFAULT_METHOD
    plans: Optional[Mapping[str, Any]] = None

    def plan_for(self, name: str):
        return None if self.plans is None else self.plans.get(name)

    def tconv(self, x, w, bias=None, *, name: str, stride: int,
              padding: str = "SAME", activation: str = "none"):
        q = self.quant[name]
        x_q = quantize_int8(x, q.x_scale)
        w_q = quantize_int8(w, q.w_scale)
        bias_q = None if bias is None else jnp.round(
            bias / (q.x_scale * q.w_scale)).astype(jnp.int32)
        y_q = ops.tconv_int8(x_q, w_q, bias_q, q.out_scale, stride=stride,
                             padding=padding, method=self.method,
                             activation="none", plan=self.plan_for(name))
        return epi.apply_activation(activation,
                                    y_q.astype(jnp.float32) * q.y_scale)


class _CalibrationPolicy:
    """Records per-layer quant scales from one eager f32 forward.

    Uses the 'lax' reference method (XLA-native, fast on CPU) — the scales
    depend only on value ranges, which every registered method agrees on.
    """

    def __init__(self):
        self.quant: Dict[str, LayerQuant] = {}

    @staticmethod
    def _scale(t) -> float:
        return max(float(jnp.max(jnp.abs(t))), 1e-6) / 127.0

    def tconv(self, x, w, bias=None, *, name: str, stride: int,
              padding: str = "SAME", activation: str = "none"):
        acc = ops.tconv(x, w, bias, stride=stride, padding=padding,
                        method="lax")
        self.quant[name] = LayerQuant(self._scale(x), self._scale(w),
                                      self._scale(acc))
        return epi.apply_activation(activation, acc)


# ---------------------------------------------------------------------------
# Model specs + registry.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class RunnerSpec:
    """Everything the runner layer needs to know about one model family.

    ``init(key, **kw) -> (params, specs)`` (the models' existing inits);
    ``forward(params, inputs, options, *, policy)``;
    ``problems(params, options) -> {layer: TConvProblem}``;
    ``input_shape(params, options) -> per-request input shape`` (no batch
    dim).  ``defaults`` declares the legal runner options and their
    values — geometry that is not recoverable from the params.
    """

    name: str
    init: Callable
    forward: Callable
    problems: Callable
    input_shape: Callable
    defaults: Mapping[str, Any] = dataclasses.field(default_factory=dict)


_SPECS: Dict[str, RunnerSpec] = {}


def register_spec(spec: RunnerSpec) -> None:
    _SPECS[spec.name] = spec


def get_spec(name: str) -> RunnerSpec:
    try:
        return _SPECS[name]
    except KeyError:
        raise ValueError(f"unknown runner {name!r}; registered: "
                         f"{sorted(_SPECS)}") from None


def runner_names() -> tuple:
    return tuple(sorted(_SPECS))


# ---------------------------------------------------------------------------
# The runner.
# ---------------------------------------------------------------------------


class GeneratorRunner:
    """One model + params behind the uniform serving contract."""

    def __init__(self, spec: RunnerSpec, params, *,
                 method: str = DEFAULT_METHOD, **options):
        unknown = set(options) - set(spec.defaults)
        if unknown:
            raise TypeError(f"runner {spec.name!r} accepts options "
                            f"{sorted(spec.defaults)}, got {sorted(unknown)}")
        self.spec = spec
        self.params = params
        self.method = method
        self.options = dict(spec.defaults)
        self.options.update(options)
        self._quant: Optional[Dict[str, LayerQuant]] = None
        self._jitted: Dict[tuple, Callable] = {}
        self._warm: set = set()

    @property
    def name(self) -> str:
        return self.spec.name

    # -- geometry -----------------------------------------------------------

    def tconv_problems(self) -> dict:
        """{layer_name: TConvProblem} — warmup, sweep, and bucketing input."""
        return self.spec.problems(self.params, self.options)

    def input_shape(self) -> tuple:
        """Per-request input shape (no batch dim)."""
        return tuple(self.spec.input_shape(self.params, self.options))

    def input_spec(self, batch: int = 1) -> jax.ShapeDtypeStruct:
        return jax.ShapeDtypeStruct((batch,) + self.input_shape(),
                                    jnp.float32)

    def example_inputs(self, batch: int = 1, seed: int = 0):
        """Synthetic inputs of the right geometry (warmup / calibration)."""
        return jax.random.normal(jax.random.PRNGKey(seed),
                                 (batch,) + self.input_shape(), jnp.float32)

    # -- plans ----------------------------------------------------------------

    def resolve_plans(self, *, batch: int, dtype=jnp.float32,
                      plans: Optional[dict] = None) -> dict:
        """Per-layer tile plans, cache-backed (the generic form of the old
        DCGAN-only ``runtime/steps.resolve_gan_plans``).

        Precedence per layer: explicit ``plans`` entry > autotuner cache
        hit > nothing (trace-time tier lookup / heuristic).  Plan-incapable
        methods skip the cache — only the caller's explicit entries pass
        through (their mistake to make).
        """
        from repro.kernels import registry as kernel_registry

        if not kernel_registry.get(self.method).supports_plan:
            return dict(plans) if plans else {}
        resolved = gan.auto_plans(self.tconv_problems(), batch=batch,
                                  dtype=dtype)
        if plans:
            resolved.update(plans)
        return resolved

    # -- precision ----------------------------------------------------------

    def quant_scales(self) -> Dict[str, LayerQuant]:
        """Calibrated per-layer int8 scales (memoized one-shot PTQ)."""
        if self._quant is None:
            cal = _CalibrationPolicy()
            self.spec.forward(self.params, self.example_inputs(batch=1),
                              self.options, policy=cal)
            self._quant = dict(cal.quant)
        return self._quant

    def policy(self, *, precision: str = "f32",
               plans: Optional[dict] = None):
        _check_precision(precision)
        if precision == "int8":
            return Int8TconvPolicy(quant=self.quant_scales(),
                                   method=self.method, plans=plans)
        return TconvPolicy(method=self.method, plans=plans)

    # -- execution ----------------------------------------------------------

    def apply(self, inputs, *, precision: str = "f32",
              plans: Optional[dict] = None):
        """Eager forward: inputs (B, *input_shape) -> outputs."""
        return self.spec.forward(self.params, inputs, self.options,
                                 policy=self.policy(precision=precision,
                                                    plans=plans))

    def jitted(self, *, batch: int, precision: str = "f32") -> Callable:
        """Memoized jit'd forward for one (batch, precision) bucket.

        Plans are left to the trace-time tier lookup (``ops._auto_plan``)
        so the compile records (key, plan, tier) in
        ``ops.consumed_plans()`` — the attribution the warmup layer and
        its tests read.
        """
        _check_precision(precision)
        key = (int(batch), precision)
        fn = self._jitted.get(key)
        if fn is None:
            policy = self.policy(precision=precision)
            jfn = jax.jit(functools.partial(self.spec.forward,
                                            options=self.options,
                                            policy=policy))

            def fn(x, _jfn=jfn, _key=key):
                try:
                    return _jfn(self.params, x)
                finally:
                    self._warm.add(_key)

            self._jitted[key] = fn
        return fn

    def has_compiled(self, *, batch: int, precision: str = "f32") -> bool:
        """Whether the (batch, precision) bucket has executed at least once
        (i.e. a further call is a jit-cache hit) — compile-hit counters."""
        return (int(batch), precision) in self._warm


def make_runner(name: str, *, params=None, key=None, init_kw=None,
                method: str = DEFAULT_METHOD, **options) -> GeneratorRunner:
    """Build a runner by registry name, initializing params if not given."""
    spec = get_spec(name)
    if params is None:
        if key is None:
            key = jax.random.PRNGKey(0)
        params, _ = spec.init(key, **(init_kw or {}))
    return GeneratorRunner(spec, params, method=method, **options)


# ---------------------------------------------------------------------------
# Registrations — the four generator families of the paper's evaluation.
# ---------------------------------------------------------------------------


def _dcgan_forward(params, z, options, *, policy):
    return gan.dcgan_generator(params, z, policy=policy)


def _pix2pix_forward(params, img, options, *, policy):
    return gan.pix2pix_generator(params, img, depth=gan.pix2pix_depth(params),
                                 policy=policy)


def _fsrcnn_forward(params, img, options, *, policy):
    return gan.fsrcnn(params, img, upscale=options["upscale"], policy=policy)


def _styletransfer_forward(params, img, options, *, policy):
    return gan.styletransfer(params, img, policy=policy)


register_spec(RunnerSpec(
    name="dcgan",
    init=gan.init_dcgan_g,
    forward=_dcgan_forward,
    problems=lambda p, opt: gan.dcgan_tconv_problems(p),
    input_shape=lambda p, opt: (p["proj"].shape[0],),
))

register_spec(RunnerSpec(
    name="pix2pix",
    init=gan.init_pix2pix_g,
    forward=_pix2pix_forward,
    problems=lambda p, opt: gan.pix2pix_tconv_problems(p),
    input_shape=lambda p, opt: ((2 ** gan.pix2pix_depth(p),) * 2
                                + (p["e0"].shape[2],)),
))

register_spec(RunnerSpec(
    name="fsrcnn",
    init=gan.init_fsrcnn,
    forward=_fsrcnn_forward,
    problems=lambda p, opt: gan.fsrcnn_tconv_problems(
        p, input_hw=opt["input_hw"], upscale=opt["upscale"]),
    input_shape=lambda p, opt: (opt["input_hw"], opt["input_hw"],
                                p["feat"].shape[2]),
    defaults={"upscale": 3, "input_hw": 16},
))

register_spec(RunnerSpec(
    name="styletransfer",
    init=gan.init_styletransfer,
    forward=_styletransfer_forward,
    problems=lambda p, opt: gan.styletransfer_tconv_problems(
        p, input_hw=opt["input_hw"]),
    input_shape=lambda p, opt: (opt["input_hw"], opt["input_hw"],
                                p["c1"].shape[2]),
    defaults={"input_hw": 32},
))
