"""Generative models from the paper's evaluation — DCGAN, pix2pix, FSRCNN,
StyleTransfer — with every TCONV layer running through MM2IM.

These are the end-to-end vehicles for Tables II/IV: the generator forward
is `method`-switchable ('mm2im' fused kernel vs baselines), and the DCGAN
discriminator + GAN losses support examples/train_dcgan.py.

Layout: NHWC, HWOI tconv weights (paper convention), NCHW nowhere.
Norms: batch statistics computed inline (running averages omitted — the
paper runs inference on quantized frozen models where BN is folded anyway).
"""

from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P


def _tconv_policy(method, plans, policy):
    """The one copy of the per-model ``method=``/``plans=`` plumbing.

    Every generator forward takes an optional ``policy`` — an object with
    ``.tconv(x, w, bias, *, name, stride, padding, activation)`` deciding
    how each named TCONV layer executes (kernel method, tile plan,
    precision).  With ``policy=None`` the legacy kwargs build the default
    f32 :class:`repro.models.runner.TconvPolicy`, which preserves the old
    behavior exactly: explicit ``plans`` entries win, and a missing entry
    lets ``ops.tconv`` consult the autotuner plan tiers at trace time.

    The import is lazy because ``models/runner.py`` imports this module at
    module level (for its model registry) — the runner layer depends on
    the models, not vice versa.
    """
    if policy is not None:
        return policy
    from repro.models.runner import TconvPolicy
    return TconvPolicy(method=method, plans=plans)


def auto_plans(problems: dict, *, batch: int = 1, dtype=None) -> dict:
    """Cached tile plans for a ``{layer_name: TConvProblem}`` mapping.

    The explicit form of what ``ops.tconv`` does implicitly: look each
    layer's problem key up in the autotuner cache (misses are simply
    omitted).  Useful when the caller wants to *inspect or log* which
    layers run tuned (e.g. ``runtime/steps.py``'s GAN step builders)
    rather than rely on the silent trace-time lookup.
    """
    import jax.numpy as jnp

    from repro.core.autotune import cached_plan

    dtype = jnp.float32 if dtype is None else dtype
    plans = {}
    for name, prob in problems.items():
        plan = cached_plan(prob, dtype=dtype, batch=batch)
        if plan is not None:
            plans[name] = plan
    return plans


def _conv_init(key, ks, cin, cout, scale=0.02):
    return jax.random.normal(key, (ks, ks, cin, cout), jnp.float32) * scale


def _tconv_init(key, ks, cout, cin, scale=0.02):
    return jax.random.normal(key, (ks, ks, cout, cin), jnp.float32) * scale


def conv2d(x, w, stride=1, padding="SAME"):
    return lax.conv_general_dilated(x, w, (stride, stride), padding,
                                    dimension_numbers=("NHWC", "HWIO", "NHWC"))


def batchnorm(x, eps=1e-5):
    mu = x.mean((0, 1, 2), keepdims=True)
    var = x.var((0, 1, 2), keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps)


# ---------------------------------------------------------------------------
# DCGAN (paper Table II/IV layer stack: 4->8->16->32, 1024->512->256->128->3)
# ---------------------------------------------------------------------------

DCGAN_LAYERS = [  # (oc, ks, ih, ic, stride) — Table II rows DCGAN_1..4
    (512, 5, 4, 1024, 2),
    (256, 5, 8, 512, 2),
    (128, 5, 16, 256, 2),
    (3, 5, 32, 128, 2),
]


def init_dcgan_g(key, z_dim: int = 100, base: int = 1024, out_ch: int = 3,
                 scale_down: int = 1):
    """DCGAN generator.  scale_down shrinks channel widths for CPU tests."""
    b = base // scale_down
    ks = jax.random.split(key, 6)
    params = {
        "proj": jax.random.normal(ks[0], (z_dim, 4 * 4 * b), jnp.float32) * 0.02,
        "t1": _tconv_init(ks[1], 5, b // 2, b),
        "t2": _tconv_init(ks[2], 5, b // 4, b // 2),
        "t3": _tconv_init(ks[3], 5, b // 8, b // 4),
        "t4": _tconv_init(ks[4], 5, out_ch, b // 8),
        "b1": jnp.zeros((b // 2,)), "b2": jnp.zeros((b // 4,)),
        "b3": jnp.zeros((b // 8,)), "b4": jnp.zeros((out_ch,)),
    }
    specs = {
        "proj": P("data", "model"),
        "t1": P(None, None, "model", "data"), "t2": P(None, None, "model", "data"),
        "t3": P(None, None, "model", "data"), "t4": P(None, None, None, "data"),
        "b1": P("model"), "b2": P("model"), "b3": P("model"), "b4": P(None),
    }
    return params, specs


def dcgan_generator(params, z, *, method: str = "mm2im", plans=None,
                    policy=None):
    """z: (B, z_dim) -> images (B, 64, 64, 3) in [-1, 1].

    ``plans`` maps TCONV param names ('t1'..'t4') to explicit tile plans
    (``kernels.registry.Plan``) — see ``dcgan_tconv_problems`` +
    ``core.autotune`` for producing them.  ``policy`` supersedes both
    kwargs (see :func:`_tconv_policy`) — it is how the runner layer routes
    every layer through e.g. the int8 requant epilogue.

    The output tanh is expressed as the last TCONV's fused activation (the
    paper's PPU epilogue): the MM2IM kernels apply it before the single
    HBM store, and the dispatcher applies the identical shared activation
    for baseline methods — same numbers either way (DESIGN.md §3/§4).
    """
    tc = _tconv_policy(method, plans, policy)
    b = z.shape[0]
    base = params["t1"].shape[3]
    x = (z @ params["proj"]).reshape(b, 4, 4, base)
    x = jax.nn.relu(batchnorm(x))
    for i in (1, 2, 3):
        x = tc.tconv(x, params[f"t{i}"], params[f"b{i}"], name=f"t{i}",
                     stride=2)
        x = jax.nn.relu(batchnorm(x))
    return tc.tconv(x, params["t4"], params["b4"], name="t4", stride=2,
                    activation="tanh")


def dcgan_tconv_layers(params) -> list:
    """Generator TCONV layer names ('t1'..'tN'), in forward order."""
    names = []
    i = 1
    while f"t{i}" in params:
        names.append(f"t{i}")
        i += 1
    return names


def dcgan_output_geometry(params) -> tuple:
    """(image_size, out_channels) of the generator: 4x4 base, one stride-2
    doubling per TCONV layer, channels from the last layer's HWOI weight.

    The single source of truth for the DCGAN topology assumptions —
    ``runtime/steps.py`` derives its abstract input shapes from this.
    """
    names = dcgan_tconv_layers(params)
    return 4 * 2 ** len(names), params[names[-1]].shape[2]


def dcgan_tconv_problems(params) -> dict:
    """The TConvProblem of each generator TCONV layer (autotuner input)."""
    from repro.core.maps import TConvProblem

    probs = {}
    ih = 4
    for name in dcgan_tconv_layers(params):
        ks, _, oc, ic = params[name].shape
        probs[name] = TConvProblem(ih, ih, ic, ks, oc, 2)
        ih *= 2
    return probs


def init_dcgan_d(key, in_ch: int = 3, base: int = 64, img_size: int = 64):
    ks = jax.random.split(key, 5)
    flat = (img_size // 4) ** 2 * base * 4  # two stride-2 convs
    params = {
        "c1": _conv_init(ks[0], 5, in_ch, base),
        "c2": _conv_init(ks[1], 5, base, base * 2),
        "c3": _conv_init(ks[2], 5, base * 2, base * 4),
        "head": jax.random.normal(ks[3], (flat, 1), jnp.float32) * 0.02,
    }
    specs = {"c1": P(None, None, None, "model"), "c2": P(None, None, None, "model"),
             "c3": P(None, None, None, "model"), "head": P("model", None)}
    return params, specs


def dcgan_discriminator(params, img):
    x = jax.nn.leaky_relu(conv2d(img, params["c1"], 2), 0.2)
    x = jax.nn.leaky_relu(batchnorm(conv2d(x, params["c2"], 2)), 0.2)
    x = jax.nn.leaky_relu(batchnorm(conv2d(x, params["c3"], 1)), 0.2)
    return x.reshape(x.shape[0], -1) @ params["head"]


# ---------------------------------------------------------------------------
# pix2pix U-Net generator (8 down / 8 up, Ks=4, S=2) — Table IV
# ---------------------------------------------------------------------------


def init_pix2pix_g(key, in_ch: int = 3, out_ch: int = 3, base: int = 64,
                   depth: int = 8, scale_down: int = 1):
    b = max(base // scale_down, 4)
    enc_chs = [min(b * (2 ** i), b * 8) for i in range(depth)]
    params: dict[str, Any] = {}
    specs: dict[str, Any] = {}
    ks = jax.random.split(key, 2 * depth + 1)
    cin = in_ch
    for i, c in enumerate(enc_chs):
        params[f"e{i}"] = _conv_init(ks[i], 4, cin, c)
        specs[f"e{i}"] = P(None, None, None, "model")
        cin = c
    for i in range(depth):
        skip = enc_chs[depth - 2 - i] if i < depth - 1 else out_ch
        cout = skip if i < depth - 1 else out_ch
        cin_up = enc_chs[depth - 1 - i] * (1 if i == 0 else 2)
        params[f"d{i}"] = _tconv_init(ks[depth + i], 4, cout, cin_up)
        specs[f"d{i}"] = P(None, None, "model", "data")
        params[f"db{i}"] = jnp.zeros((cout,))
        specs[f"db{i}"] = P("model") if i < depth - 1 else P(None)
    return params, specs


def pix2pix_generator(params, img, *, method: str = "mm2im", depth: int = 8,
                      plans=None, policy=None):
    """U-Net: img (B, 2^depth, 2^depth, C) -> (B, same, same, out_ch)."""
    tc = _tconv_policy(method, plans, policy)
    skips = []
    x = img
    for i in range(depth):
        x = conv2d(x, params[f"e{i}"], 2)
        if i > 0:
            x = batchnorm(x)
        skips.append(x)
        x = jax.nn.leaky_relu(x, 0.2)
    x = jax.nn.relu(skips[-1])
    for i in range(depth):
        # The final up-TCONV fuses the output tanh (PPU epilogue).
        x = tc.tconv(x, params[f"d{i}"], params[f"db{i}"], name=f"d{i}",
                     stride=2,
                     activation="tanh" if i == depth - 1 else "none")
        if i < depth - 1:
            x = batchnorm(x)
            x = jnp.concatenate([jax.nn.relu(x), skips[depth - 2 - i]], -1)
    return x


def pix2pix_depth(params) -> int:
    """U-Net depth recovered from the encoder param names ('e0'..'e{d-1}')."""
    depth = 0
    while f"e{depth}" in params:
        depth += 1
    return depth


def pix2pix_tconv_problems(params) -> dict:
    """TConvProblem per decoder up-TCONV ('d0'..'d{depth-1}').

    Up-layer ``i`` runs at spatial ``2^i`` (the bottleneck is 1x1 after
    ``depth`` stride-2 encoder halvings of a ``2^depth`` input); channels
    come from the HWOI weights.
    """
    from repro.core.maps import TConvProblem

    probs = {}
    for i in range(pix2pix_depth(params)):
        w = params[f"d{i}"]
        probs[f"d{i}"] = TConvProblem(2 ** i, 2 ** i, w.shape[3],
                                      w.shape[0], w.shape[2], 2)
    return probs


# ---------------------------------------------------------------------------
# FSRCNN (super-resolution; final Ks=9 TCONV does the upscale) — Table II
# ---------------------------------------------------------------------------


def init_fsrcnn(key, d: int = 32, s: int = 5, m: int = 2, upscale: int = 3,
                in_ch: int = 1):
    ks = jax.random.split(key, m + 4)
    params = {
        "feat": _conv_init(ks[0], 5, in_ch, d),
        "shrink": _conv_init(ks[1], 1, d, s),
        "expand": _conv_init(ks[2], 1, s, d),
        "deconv": _tconv_init(ks[3], 9, in_ch, d),
        "db": jnp.zeros((in_ch,)),
    }
    specs = {k: P(None) for k in params}
    for i in range(m):
        params[f"map{i}"] = _conv_init(ks[4 + i], 3, s, s)
        specs[f"map{i}"] = P(None)
    return params, specs


def fsrcnn(params, img, *, upscale: int = 3, method: str = "mm2im",
           plans=None, policy=None):
    tc = _tconv_policy(method, plans, policy)
    x = jax.nn.relu(conv2d(img, params["feat"]))
    x = jax.nn.relu(conv2d(x, params["shrink"]))
    i = 0
    while f"map{i}" in params:
        x = jax.nn.relu(conv2d(x, params[f"map{i}"]))
        i += 1
    x = jax.nn.relu(conv2d(x, params["expand"]))
    return tc.tconv(x, params["deconv"], params["db"], name="deconv",
                    stride=upscale)


def fsrcnn_tconv_problems(params, *, input_hw: int = 16,
                          upscale: int = 3) -> dict:
    """TConvProblem of the FSRCNN deconv tail at a given input resolution.

    Unlike DCGAN/pix2pix, spatial geometry is not recoverable from the
    params (every conv preserves hw), so the caller names ``input_hw``.
    """
    from repro.core.maps import TConvProblem

    w = params["deconv"]
    return {"deconv": TConvProblem(input_hw, input_hw, w.shape[3],
                                   w.shape[0], w.shape[2], upscale)}


# ---------------------------------------------------------------------------
# Johnson style-transfer network (2 TCONV upsamples + 9x9 output) — Table II
# ---------------------------------------------------------------------------


def init_styletransfer(key, base: int = 32, n_res: int = 5):
    ks = jax.random.split(key, n_res * 2 + 6)
    params = {
        "c1": _conv_init(ks[0], 9, 3, base),
        "c2": _conv_init(ks[1], 3, base, base * 2),
        "c3": _conv_init(ks[2], 3, base * 2, base * 4),
        "t1": _tconv_init(ks[3], 3, base * 2, base * 4),
        "tb1": jnp.zeros((base * 2,)),
        "t2": _tconv_init(ks[4], 3, base, base * 2),
        "tb2": jnp.zeros((base,)),
        "out": _tconv_init(ks[5], 9, 3, base),  # 9x9 S=1 TCONV (Table II row 3)
        "ob": jnp.zeros((3,)),
    }
    specs = {k: P(None) for k in params}
    for i in range(n_res):
        params[f"r{i}a"] = _conv_init(ks[6 + 2 * i], 3, base * 4, base * 4)
        params[f"r{i}b"] = _conv_init(ks[7 + 2 * i], 3, base * 4, base * 4)
        specs[f"r{i}a"] = specs[f"r{i}b"] = P(None)
    return params, specs


def styletransfer(params, img, *, method: str = "mm2im", plans=None,
                  policy=None):
    tc = _tconv_policy(method, plans, policy)
    x = jax.nn.relu(batchnorm(conv2d(img, params["c1"])))
    x = jax.nn.relu(batchnorm(conv2d(x, params["c2"], 2)))
    x = jax.nn.relu(batchnorm(conv2d(x, params["c3"], 2)))
    i = 0
    while f"r{i}a" in params:
        h = jax.nn.relu(batchnorm(conv2d(x, params[f"r{i}a"])))
        x = x + batchnorm(conv2d(h, params[f"r{i}b"]))
        i += 1
    x = jax.nn.relu(batchnorm(tc.tconv(x, params["t1"], params["tb1"],
                                       name="t1", stride=2)))
    x = jax.nn.relu(batchnorm(tc.tconv(x, params["t2"], params["tb2"],
                                       name="t2", stride=2)))
    return tc.tconv(x, params["out"], params["ob"], name="out", stride=1,
                    activation="tanh")


def styletransfer_tconv_problems(params, *, input_hw: int = 32) -> dict:
    """TConvProblem per style-transfer TCONV at a given input resolution
    (hw must be divisible by 4: two stride-2 downsamples precede 't1')."""
    from repro.core.maps import TConvProblem

    t1, t2, out = params["t1"], params["t2"], params["out"]
    return {
        "t1": TConvProblem(input_hw // 4, input_hw // 4, t1.shape[3],
                           t1.shape[0], t1.shape[2], 2),
        "t2": TConvProblem(input_hw // 2, input_hw // 2, t2.shape[3],
                           t2.shape[0], t2.shape[2], 2),
        "out": TConvProblem(input_hw, input_hw, out.shape[3],
                            out.shape[0], out.shape[2], 1),
    }
