"""Unified language-model stack: dense / MoE / SSM / hybrid / enc-dec / VLM.

Functional API:

    params, specs = init(cfg, rng)
    logits, aux   = forward(cfg, params, tokens, prefix_embeds=...)
    cache         = init_cache(cfg, batch, max_seq)
    logits, cache = decode(cfg, params, tokens_1, cache)

All unit stacks are parameter-stacked and executed with ``lax.scan``
(+ optional ``jax.checkpoint`` remat), so HLO size is depth-independent.
Specs trees mirror params trees with PartitionSpec leaves; scanned stacks
get their leading (unit) axis unsharded.
"""

from __future__ import annotations

import functools
from typing import Any, NamedTuple, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.distributed.sharding import shard_activations
from repro.layers import attention as att
from repro.layers import moe as moe_mod
from repro.layers import rglru as rglru_mod
from repro.layers import ssm as ssm_mod
from repro.layers.common import (embed, init_embedding, init_mlp, init_rmsnorm,
                                 mlp, rmsnorm, unembed)
from repro.models.config import ModelConfig


def _dtype(name: str):
    return {"float32": jnp.float32, "bfloat16": jnp.bfloat16}[name]


# ---------------------------------------------------------------------------
# Block init / apply
# ---------------------------------------------------------------------------


def _init_block(cfg: ModelConfig, kind: str, key, *, cross: bool = False):
    dt = _dtype(cfg.param_dtype)
    d = cfg.d_model
    ks = jax.random.split(key, 6)
    params: dict[str, Any] = {}
    specs: dict[str, Any] = {}

    def add(name, pr, sp):
        params[name], specs[name] = pr, sp

    if kind in ("attn", "local_attn"):
        add("ln1", *init_rmsnorm(d, dt))
        a_p, a_s = att.init_attention(ks[0], d, cfg.n_heads, cfg.kv_heads,
                                      cfg.resolved_head_dim,
                                      qkv_bias=cfg.qkv_bias,
                                      qk_norm=cfg.qk_norm, dtype=dt)
        add("attn", a_p, a_s)
        if cross:
            add("ln_x", *init_rmsnorm(d, dt))
            x_p, x_s = att.init_cross_attention(ks[1], d, cfg.n_heads,
                                                cfg.kv_heads, dtype=dt)
            add("xattn", x_p, x_s)
        add("ln2", *init_rmsnorm(d, dt))
        if cfg.n_experts:
            m_p, m_s = moe_mod.init_moe(ks[2], d, cfg.moe_d_ff or cfg.d_ff,
                                        cfg.n_experts, cfg.top_k,
                                        n_shared=cfg.n_shared_experts,
                                        shared_d_ff=cfg.d_ff, dtype=dt)
            add("moe", m_p, m_s)
        else:
            m_p, m_s = init_mlp(ks[2], d, cfg.d_ff, cfg.mlp_kind, dtype=dt)
            add("mlp", m_p, m_s)
    elif kind == "mamba2":
        add("ln1", *init_rmsnorm(d, dt))
        s_p, s_s = ssm_mod.init_mamba2(ks[0], d, head_dim=cfg.ssm_head_dim,
                                       expand=cfg.ssm_expand,
                                       d_state=cfg.ssm_state,
                                       d_conv=cfg.ssm_conv, dtype=dt)
        add("ssm", s_p, s_s)
    elif kind == "rglru":
        add("ln1", *init_rmsnorm(d, dt))
        r_p, r_s = rglru_mod.init_rglru_block(ks[0], d, dtype=dt)
        add("rec", r_p, r_s)
        if cfg.d_ff:
            add("ln2", *init_rmsnorm(d, dt))
            m_p, m_s = init_mlp(ks[1], d, cfg.d_ff, cfg.mlp_kind, dtype=dt)
            add("mlp", m_p, m_s)
    else:
        raise ValueError(f"unknown block kind {kind!r}")
    return params, specs


def _apply_block(cfg: ModelConfig, kind: str, params, x, *,
                 enc_kv=None, positions=None, causal: bool = True):
    aux = jnp.zeros((), jnp.float32)
    in_dtype = x.dtype
    l = x.shape[1]
    window = cfg.window if kind == "local_attn" else None
    if kind in ("attn", "local_attn"):
        h = rmsnorm(params["ln1"], x, cfg.norm_eps)
        impl = cfg.attn_impl
        if impl == "auto":
            impl = "chunked" if l > cfg.attn_chunk_threshold else "dense"
        if impl == "flash":
            y = att.attend_flash(
                params["attn"], h, n_heads=cfg.n_heads, kv_heads=cfg.kv_heads,
                positions=positions, causal=causal, window=window,
                rope_theta=cfg.rope_theta)
        elif impl == "chunked":
            y = att.attend_chunked(
                params["attn"], h, n_heads=cfg.n_heads, kv_heads=cfg.kv_heads,
                positions=positions, causal=causal, window=window,
                chunk_q=cfg.attn_chunk_q, chunk_k=cfg.attn_chunk_k,
                rope_theta=cfg.rope_theta)
        else:
            y = att.attend(params["attn"], h, n_heads=cfg.n_heads,
                           kv_heads=cfg.kv_heads, positions=positions,
                           causal=causal, window=window,
                           rope_theta=cfg.rope_theta)
        x = x + y
        if "xattn" in params and enc_kv is not None:
            h = rmsnorm(params["ln_x"], x, cfg.norm_eps)
            x = x + att.cross_attend(params["xattn"], h, enc_kv,
                                     n_heads=cfg.n_heads, kv_heads=cfg.kv_heads)
        h = rmsnorm(params["ln2"], x, cfg.norm_eps)
        if "moe" in params:
            y, aux = moe_mod.moe(params["moe"], h, top_k=cfg.top_k,
                                 capacity_factor=cfg.capacity_factor,
                                 group_size=cfg.moe_group_size,
                                 sharding_mode=cfg.moe_sharding)
        else:
            y = mlp(params["mlp"], h, cfg.mlp_kind)
        x = x + y
    elif kind == "mamba2":
        h = rmsnorm(params["ln1"], x, cfg.norm_eps)
        x = x + ssm_mod.mamba2(
            params["ssm"], h, head_dim=cfg.ssm_head_dim,
            expand=cfg.ssm_expand, d_state=cfg.ssm_state, chunk=cfg.ssm_chunk)
    elif kind == "rglru":
        h = rmsnorm(params["ln1"], x, cfg.norm_eps)
        x = x + rglru_mod.rglru_block(params["rec"], h)
        if "mlp" in params:
            h = rmsnorm(params["ln2"], x, cfg.norm_eps)
            x = x + mlp(params["mlp"], h, cfg.mlp_kind)
    return x.astype(in_dtype), aux


# ---------------------------------------------------------------------------
# Whole-model init
# ---------------------------------------------------------------------------


def init(cfg: ModelConfig, key):
    dt = _dtype(cfg.param_dtype)
    keys = jax.random.split(key, 8)
    params: dict[str, Any] = {}
    specs: dict[str, Any] = {}

    e_p, e_s = init_embedding(keys[0], cfg.vocab, cfg.d_model, dt)
    params["embed"], specs["embed"] = e_p, e_s
    if not cfg.tied_embeddings:
        u_p, u_s = init_embedding(keys[6], cfg.vocab, cfg.d_model, dt)
        params["unembed"], specs["unembed"] = u_p, u_s

    def stacked(kinds, key, n, cross=False):
        """Stack n units of the given block-kind tuple (vmap over init)."""
        def one(k):
            ps, ss = [], None
            sub = jax.random.split(k, len(kinds))
            out = {}
            for i, kind in enumerate(kinds):
                p_i, s_i = _init_block(cfg, kind, sub[i], cross=cross)
                out[f"b{i}"] = p_i
                if ss is None:
                    ss = {}
                ss[f"b{i}"] = s_i
            return out, ss
        _, sspec = one(key)  # spec structure (shared across units)
        stacked_p = jax.vmap(lambda k: one(k)[0])(jax.random.split(key, n))
        # prepend unsharded unit axis to each leaf spec
        sspec = jax.tree.map(lambda s: P(*((None,) + tuple(s))), sspec,
                             is_leaf=lambda s: isinstance(s, P))
        return stacked_p, sspec

    if cfg.enc_layers:
        params["encoder"], specs["encoder"] = stacked(("attn",), keys[1],
                                                      cfg.enc_layers)
        e_ln, e_ls = init_rmsnorm(cfg.d_model, dt)
        params["enc_norm"], specs["enc_norm"] = e_ln, e_ls
        cross = True
    else:
        cross = False

    params["units"], specs["units"] = stacked(cfg.pattern, keys[2],
                                              cfg.n_units, cross=cross)
    if cfg.tail:
        tail_p, tail_s = {}, {}
        sub = jax.random.split(keys[3], len(cfg.tail))
        for i, kind in enumerate(cfg.tail):
            p_i, s_i = _init_block(cfg, kind, sub[i], cross=cross)
            tail_p[f"t{i}"], tail_s[f"t{i}"] = p_i, s_i
        params["tail"], specs["tail"] = tail_p, tail_s

    n_p, n_s = init_rmsnorm(cfg.d_model, dt)
    params["final_norm"], specs["final_norm"] = n_p, n_s
    return params, specs


# ---------------------------------------------------------------------------
# Forward (teacher-forced) pass
# ---------------------------------------------------------------------------


def _run_stack(cfg: ModelConfig, kinds, stacked_params, x, *, enc_kv=None,
               causal=True, positions=None):
    def unit(carry, unit_params):
        h, aux = carry
        h = shard_activations(h)  # DP batch + SP sequence constraint
        for i, kind in enumerate(kinds):
            h, a = _apply_block(cfg, kind, unit_params[f"b{i}"], h,
                                enc_kv=enc_kv, positions=positions,
                                causal=causal)
            aux = aux + a
        return (h, aux), None

    fn = jax.checkpoint(unit) if cfg.remat else unit
    (x, aux), _ = jax.lax.scan(fn, (x, jnp.zeros((), jnp.float32)),
                               stacked_params)
    return x, aux


def forward(cfg: ModelConfig, params, tokens, *, prefix_embeds=None,
            enc_tokens=None, enc_embeds=None):
    """Teacher-forced forward.  Returns (logits_f32, aux_losses).

    prefix_embeds: (B, Lp, D) — VLM patch / audio-frame stub embeddings
    prepended to the decoder input.
    enc_tokens / enc_embeds: encoder input for enc-dec configs.
    """
    adt = _dtype(cfg.activ_dtype)
    x = embed(params["embed"], tokens).astype(adt)
    if prefix_embeds is not None:
        x = jnp.concatenate([prefix_embeds.astype(adt), x], axis=1)
    b, l, _ = x.shape
    positions = jnp.arange(l)[None, :]

    enc_kv = None
    if cfg.enc_layers:
        if enc_embeds is None:
            enc_embeds = embed(params["embed"], enc_tokens)
        h = enc_embeds.astype(adt)
        h, _ = _run_stack(cfg, ("attn",), params["encoder"], h, causal=False,
                          positions=jnp.arange(h.shape[1])[None, :])
        h = rmsnorm(params["enc_norm"], h, cfg.norm_eps)
        # All decoder cross-attn layers share the encoder output; each unit
        # projects its own K/V from it (params live in the unit), so here we
        # pass the raw encoder states and let blocks project lazily.
        enc_kv = h

    def with_kv(unit_params_block, h_enc):
        return att.encoder_kv(unit_params_block, h_enc, kv_heads=cfg.kv_heads)

    if enc_kv is not None:
        # Pre-binding per-unit KV would break the scan; instead wrap
        # _apply_block via closure that projects inside the unit.
        pass

    aux_total = jnp.zeros((), jnp.float32)
    if cfg.enc_layers:
        # run decoder units with cross-attn: project kv inside each block
        def unit(carry, unit_params):
            h, aux = carry
            h = shard_activations(h)
            for i, kind in enumerate(cfg.pattern):
                blk = unit_params[f"b{i}"]
                kv = with_kv(blk["xattn"], enc_kv) if "xattn" in blk else None
                h, a = _apply_block(cfg, kind, blk, h, enc_kv=kv,
                                    positions=positions)
                aux = aux + a
            return (h, aux), None

        fn = jax.checkpoint(unit) if cfg.remat else unit
        (x, aux_total), _ = jax.lax.scan(fn, (x, aux_total), params["units"])
    else:
        x, aux_total = _run_stack(cfg, cfg.pattern, params["units"], x,
                                  positions=positions)

    if cfg.tail:
        for i, kind in enumerate(cfg.tail):
            x, a = _apply_block(cfg, kind, params["tail"][f"t{i}"], x,
                                positions=positions)
            aux_total = aux_total + a

    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    table = params["embed"] if cfg.tied_embeddings else params["unembed"]
    logits = unembed(table, x, cfg.vocab).astype(jnp.float32)
    return logits, aux_total


def loss_fn(cfg: ModelConfig, params, tokens, targets, mask=None, *,
            prefix_embeds=None, enc_tokens=None, enc_embeds=None,
            aux_weight: float = 0.01, z_weight: float = 1e-4):
    logits, aux = forward(cfg, params, tokens, prefix_embeds=prefix_embeds,
                          enc_tokens=enc_tokens, enc_embeds=enc_embeds)
    if prefix_embeds is not None:
        logits = logits[:, prefix_embeds.shape[1]:]
    logz = jax.nn.logsumexp(logits, -1)
    ll = jnp.take_along_axis(logits, targets[..., None], -1)[..., 0] - logz
    if mask is None:
        mask = jnp.ones_like(targets, jnp.float32)
    n = jnp.maximum(mask.sum(), 1.0)
    ce = -(ll * mask).sum() / n
    zl = (jnp.square(logz) * mask).sum() / n
    return ce + aux_weight * aux + z_weight * zl, {"ce": ce, "aux": aux, "z": zl}


# ---------------------------------------------------------------------------
# Decode (one token, stateful)
# ---------------------------------------------------------------------------


class Cache(NamedTuple):
    units: Any       # stacked per-unit state trees
    tail: Any
    enc_kv: Any      # encoder K/V for enc-dec (None otherwise)
    length: jax.Array


def _block_state(cfg: ModelConfig, kind: str, batch: int, max_seq: int,
                 dtype=jnp.bfloat16, length: int = 0):
    if kind in ("attn", "local_attn"):
        seq = min(max_seq, cfg.window) if kind == "local_attn" and cfg.window else max_seq
        c = att.KVCache.empty(batch, seq, cfg.kv_heads,
                              cfg.resolved_head_dim, dtype)
        return c._replace(length=jnp.full((), length, jnp.int32))
    if kind == "mamba2":
        s = ssm_mod.mamba2_init_state(batch, cfg.d_model,
                                      head_dim=cfg.ssm_head_dim,
                                      expand=cfg.ssm_expand,
                                      d_state=cfg.ssm_state,
                                      d_conv=cfg.ssm_conv, dtype=dtype)
        return s._replace(length=jnp.full((), length, jnp.int32))
    if kind == "rglru":
        s = rglru_mod.rglru_init_state(batch, cfg.d_model, dtype=dtype)
        return s._replace(length=jnp.full((), length, jnp.int32))
    raise ValueError(kind)


def _block_state_spec(cfg: ModelConfig, kind: str, *, seq_axis="model",
                      batch_axis="data"):
    if kind in ("attn", "local_attn"):
        sa = None if (kind == "local_attn" and cfg.window) else seq_axis
        return att.KVCache.specs(seq_axis=sa, batch_axis=batch_axis)
    if kind == "mamba2":
        return ssm_mod.SSMState.specs(batch_axis=batch_axis)
    if kind == "rglru":
        return rglru_mod.RGLRUState.specs(batch_axis=batch_axis)
    raise ValueError(kind)


def init_cache(cfg: ModelConfig, batch: int, max_seq: int, dtype=jnp.bfloat16,
               length: int = 0) -> Cache:
    """Empty decode state.  ``length`` pre-positions the cache (e.g. the
    decode_32k dry-run lowers one step with 32k-1 tokens already cached)."""
    def unit_state(_):
        return {f"b{i}": _block_state(cfg, kind, batch, max_seq, dtype, length)
                for i, kind in enumerate(cfg.pattern)}
    units = jax.vmap(unit_state)(jnp.arange(cfg.n_units))
    tail = {f"t{i}": _block_state(cfg, kind, batch, max_seq, dtype, length)
            for i, kind in enumerate(cfg.tail)} if cfg.tail else None
    enc_kv = None
    if cfg.enc_layers:
        # Decoder cross-attn state: raw encoder output (stub length).
        enc_kv = jnp.zeros((batch, cfg.frontend_len or 128, cfg.d_model), dtype)
    return Cache(units, tail, enc_kv, jnp.full((), length, jnp.int32))


def cache_specs(cfg: ModelConfig, *, seq_axis="model",
                batch_axis="data") -> Cache:
    def unit_spec():
        return {f"b{i}": _block_state_spec(cfg, kind, seq_axis=seq_axis,
                                           batch_axis=batch_axis)
                for i, kind in enumerate(cfg.pattern)}
    units = jax.tree.map(lambda s: P(*((None,) + tuple(s))), unit_spec(),
                         is_leaf=lambda s: isinstance(s, P))
    tail = {f"t{i}": _block_state_spec(cfg, kind, seq_axis=seq_axis,
                                       batch_axis=batch_axis)
            for i, kind in enumerate(cfg.tail)} if cfg.tail else None
    enc_kv = P(batch_axis, None, None) if cfg.enc_layers else None
    return Cache(units, tail, enc_kv, P())


def _decode_block(cfg: ModelConfig, kind: str, params, x, state, enc_kv=None):
    in_dtype = x.dtype
    if kind in ("attn", "local_attn"):
        h = rmsnorm(params["ln1"], x, cfg.norm_eps)
        window = cfg.window if kind == "local_attn" else None
        y, state = att.decode_step(params["attn"], h, state,
                                   n_heads=cfg.n_heads, kv_heads=cfg.kv_heads,
                                   window=window, rope_theta=cfg.rope_theta)
        x = x + y
        if "xattn" in params and enc_kv is not None:
            # enc_kv here is the raw encoder output (B, Lenc, D); each block
            # projects its own K/V (weights live in the unit's params).
            h = rmsnorm(params["ln_x"], x, cfg.norm_eps)
            kv = att.encoder_kv(params["xattn"], enc_kv, kv_heads=cfg.kv_heads)
            x = x + att.cross_attend(params["xattn"], h, kv,
                                     n_heads=cfg.n_heads, kv_heads=cfg.kv_heads)
        h = rmsnorm(params["ln2"], x, cfg.norm_eps)
        if "moe" in params:
            y, _ = moe_mod.moe(params["moe"], h, top_k=cfg.top_k,
                               capacity_factor=cfg.capacity_factor,
                               group_size=cfg.moe_group_size,
                               sharding_mode=cfg.moe_sharding)
        else:
            y = mlp(params["mlp"], h, cfg.mlp_kind)
        x = x + y
    elif kind == "mamba2":
        h = rmsnorm(params["ln1"], x, cfg.norm_eps)
        y, state = ssm_mod.mamba2_step(params["ssm"], h,
                                       state, head_dim=cfg.ssm_head_dim,
                                       expand=cfg.ssm_expand,
                                       d_state=cfg.ssm_state)
        x = x + y
    elif kind == "rglru":
        h = rmsnorm(params["ln1"], x, cfg.norm_eps)
        y, state = rglru_mod.rglru_step(params["rec"], h, state)
        x = x + y
        if "mlp" in params:
            h = rmsnorm(params["ln2"], x, cfg.norm_eps)
            x = x + mlp(params["mlp"], h, cfg.mlp_kind)
    return x.astype(in_dtype), state


def decode(cfg: ModelConfig, params, tokens, cache: Cache):
    """One decode step.  tokens: (B, 1) -> (logits (B,1,V), new cache)."""
    adt = _dtype(cfg.activ_dtype)
    x = embed(params["embed"], tokens).astype(adt)

    def unit(h, scanned):
        unit_params, unit_state = scanned
        h = shard_activations(h)
        new_states = {}
        for i, kind in enumerate(cfg.pattern):
            h, s = _decode_block(cfg, kind, unit_params[f"b{i}"], h,
                                 unit_state[f"b{i}"], enc_kv=cache.enc_kv)
            new_states[f"b{i}"] = s
        return h, new_states

    x, new_unit_states = jax.lax.scan(unit, x, (params["units"], cache.units))
    new_tail = None
    if cfg.tail:
        new_tail = {}
        for i, kind in enumerate(cfg.tail):
            x, s = _decode_block(cfg, kind, params["tail"][f"t{i}"], x,
                                 cache.tail[f"t{i}"], enc_kv=cache.enc_kv)
            new_tail[f"t{i}"] = s

    x = rmsnorm(params["final_norm"], x, cfg.norm_eps)
    table = params["embed"] if cfg.tied_embeddings else params["unembed"]
    logits = unembed(table, x, cfg.vocab).astype(jnp.float32)
    return logits, Cache(new_unit_states, new_tail, cache.enc_kv,
                         cache.length + 1)
