"""Calibrated perf-model coefficients fit from persisted measurements.

The analytical model (``core/perf_model.py``) has the right *vocabulary*
— issued MXU tiles, HBM traffic, pipeline fill, kernel launches — but
datasheet constants for the coefficients, and the recorded trajectory
shows what that costs: ``BENCH_mm2im.json`` shipped head-to-heads where
the double-buffered variant was predicted 1.06x faster and measured
0.22x, and batch folding predicted 6.93x and measured 0.62x
(``rank_agree=0``), so the autotuner's a-priori pruning could discard the
true winner before ever timing it.  The paper's own model earns its §V-F
"within 10%" only because it is calibrated against the target; EcoFlow
makes the same point for dataflow cost models generally, and GANAX's
irregular-vs-dense phase split is why a single constant per term misranks
across dataflow *regimes*.

This module closes the gap with a measurement-driven calibration layer:

1. **collect** — every tuned-plan cache / shipped table entry already
   persists a measured ``us`` for its winning plan and for the heuristic
   default (``core/autotune.py`` stamps them), and the distilled
   ``BENCH_mm2im.json`` records the sb-vs-db and folded-vs-grid
   head-to-heads.  :func:`samples_from_store` / :func:`pairs_from_bench`
   parse both back into ``(problem, plan, batch, bits, us)`` samples.
2. **fit** — one small nonnegative least-squares per dataflow regime
   ``(method, fold_batch)`` over the model's raw terms: per-MXU-tile
   cost, effective HBM cost per byte, a fill (non-overlappable copy)
   multiplier, per-launch overhead, and a constant.  Per-regime because
   the regimes stress the backend differently (GANAX: irregular scatter
   vs dense MatMul phases) — interpret-mode CPU and real TPU disagree
   wildly on what a slab DMA costs.
3. **persist** — a :class:`FittedHW` record with provenance, stored next
   to the shipped plan tables (``src/repro/data/plans/<backend>.fit.json``)
   and loaded per-backend (:func:`shipped_fit`) the same way plan tables
   are.
4. **consume** — ``core/autotune.py`` ranks candidates with
   :meth:`FittedHW.predict_us` when a calibration is available (falling
   back to the uncalibrated roofline), which is what makes a small
   ``max_measure`` trustworthy; :func:`rank_agreement` scores predicted
   vs measured *order* (plus magnitude error) over recorded
   head-to-heads, and ``tools/bench_gate.py`` turns that score into a CI
   gate.

Nothing here ever measures: fitting replays persisted numbers only, so
``tools/tune_sweep.py --fit`` is safe on a resumed cache (zero
re-measurements by construction).
"""

from __future__ import annotations

import dataclasses
import json
import math
import os
import re
import time
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Sequence, Tuple, Union

import numpy as np

from repro.core.maps import TConvProblem
from repro.core.perf_model import HW, V5E, estimate_for_plan
from repro.kernels.registry import Plan

FIT_VERSION = 1
FIT_DIR_ENV = "REPRO_MODEL_FIT_DIR"
#: Coefficient feature order (matches :func:`features`).
FEATURES = ("issued_tiles", "hbm_bytes", "fill_bytes", "n_launches", "const")
#: provenance keys every shipped fit must carry.
REQUIRED_PROVENANCE = ("backend", "jax", "created", "n_samples")
#: A regime with fewer samples than this falls back to the global fit.
MIN_REGIME_SAMPLES = 4

_DTYPE_BITS = {"float32": 32, "f32": 32, "bfloat16": 16, "bf16": 16,
               "float16": 16, "int8": 8}

# The batch-8 DCGAN layer-1 shape the fold head-to-head benches use
# (benchmarks/bench_autotune.fold_head_to_head) — needed to replay old
# BENCH docs whose derived strings predate the explicit prob=/geom= keys.
_FOLD_BENCH_PROBLEM = TConvProblem(4, 4, 256, 5, 128, 2)
_FOLD_BENCH_GEOM = {"mm2im": Plan(8, 128, "bcj", "mm2im"),
                    "mm2im_db": Plan(4, 128, "bcj", "mm2im_db")}


def parse_cache_key(key: str) -> Tuple[TConvProblem, str, str, int]:
    """Inverse of ``autotune.cache_key``: key -> (problem, dtype, hw, batch)."""
    head, dt, hw, b = key.split("|")
    m = re.fullmatch(r"tconv:ih(\d+):iw(\d+):ic(\d+):ks(\d+):oc(\d+)"
                     r":s(\d+):(\w+)", head)
    if m is None or not b.startswith("b"):
        raise ValueError(f"unparseable cache key: {key!r}")
    ih, iw, ic, ks, oc, s = (int(g) for g in m.groups()[:6])
    return TConvProblem(ih, iw, ic, ks, oc, s, m.group(7)), dt, hw, int(b[1:])


@dataclasses.dataclass(frozen=True)
class Sample:
    """One persisted measurement: a plan run on a problem took ``us``."""

    problem: TConvProblem
    plan: Plan
    batch: int
    bits: int
    us: float
    source: str = ""

    @property
    def regime(self) -> Tuple[str, bool, bool]:
        return (self.plan.method or "mm2im", bool(self.plan.fold_batch),
                is_large_problem(self.problem))


@dataclasses.dataclass(frozen=True)
class RankPair:
    """A recorded head-to-head: variant ``a`` vs ``b`` on one problem."""

    name: str
    problem: TConvProblem
    batch: int
    bits: int
    plan_a: Plan
    plan_b: Plan
    us_a: float
    us_b: float

    @property
    def measured_ratio(self) -> float:
        """t_a / t_b — > 1 means variant ``b`` measured faster."""
        return self.us_a / max(self.us_b, 1e-9)

    def samples(self) -> Tuple[Sample, Sample]:
        return (Sample(self.problem, self.plan_a, self.batch, self.bits,
                       self.us_a, source=self.name),
                Sample(self.problem, self.plan_b, self.batch, self.bits,
                       self.us_b, source=self.name))


def features(p: TConvProblem, plan: Plan, *, batch: int = 1, bits: int = 32,
             hw: HW = V5E) -> np.ndarray:
    """Raw cost-model terms for one (problem, plan) — :data:`FEATURES` order."""
    e = estimate_for_plan(p, batch, plan=plan, bits=bits, hw=hw)
    return np.array([float(e.issued_tiles), float(e.hbm_bytes),
                     float(e.fill_bytes), float(e.n_launches), 1.0])


def samples_from_entries(entries: Dict[str, dict], *,
                         backend: Optional[str] = None,
                         source: str = "") -> List[Sample]:
    """Samples from PlanCache/PlanTable ``entries`` (winner + default).

    Every tuned entry carries the winning plan's measured ``us`` and the
    heuristic default's ``default_us`` under the same key — two samples
    per entry.  Entries stamped with a different ``backend`` than
    requested are skipped (their microseconds are another machine's);
    entries without timings (imported tables) contribute nothing.
    """
    out: List[Sample] = []
    for key, e in entries.items():
        if backend is not None and e.get("backend") not in (None, backend):
            continue
        try:
            p, dt, _hw, batch = parse_cache_key(key)
        except ValueError:
            continue
        bits = _DTYPE_BITS.get(dt)
        if bits is None:
            continue
        for plan_field, us_field in (("plan", "us"),
                                     ("default_plan", "default_us")):
            us = e.get(us_field)
            pd = e.get(plan_field)
            if us is None or pd is None or not math.isfinite(float(us)):
                continue
            try:
                plan = Plan.from_json(pd)
            except Exception:
                continue
            out.append(Sample(p, plan, batch, bits, float(us),
                              source=source or key))
    return out


def samples_from_store(path: Union[str, Path], *,
                       backend: Optional[str] = None) -> List[Sample]:
    """Samples from an on-disk plan cache or shipped plan table."""
    path = Path(path)
    raw = json.loads(path.read_text())
    return samples_from_entries(raw.get("entries", {}), backend=backend,
                                source=str(path))


# ---------------------------------------------------------------------------
# Recorded head-to-heads (the distilled BENCH_mm2im.json rows).
# ---------------------------------------------------------------------------

def _parse_derived_str(derived: str) -> Dict[str, str]:
    return {k: v for part in derived.split(";") if "=" in part
            for k, _, v in [part.partition("=")]}


def _parse_geom(d: Dict[str, str], method: str, fold: bool = False,
                key: str = "geom") -> Optional[Plan]:
    m = re.fullmatch(r"oh(\d+)/oc(\d+)/(\w+)", d.get(key, ""))
    if m is None:
        return None
    return Plan(int(m.group(1)), int(m.group(2)), m.group(3), method, fold)


def _default_geometry(p: TConvProblem, batch: int) -> Plan:
    # Lazy import: tiling imports perf_model; keep this module cycle-free.
    from repro.core import tiling

    tp = tiling.plan(p, batch=batch, bits=32)
    return Plan(tp.block_oh, tp.block_oc, tp.grid_order)


def pairs_from_bench(doc: dict) -> List[RankPair]:
    """Head-to-head pairs recorded in a ``BENCH_mm2im.json``-style doc.

    Two row families carry a measured A-vs-B comparison at identical
    geometry (``benchmarks/bench_autotune.py`` emits both):

    * ``autotune_ih*_..._dbcmp`` — single- vs double-buffered at the
      heuristic default geometry (``sb_us`` / ``db_us``);
    * ``autotune_fold_dcgan1_<method>`` — grid-batch vs folded at fixed
      geometry (``grid_us`` / ``fold_us``);
    * ``autotune_large_*_ogcmp`` — the large-image cross-method
      head-to-head (``og_us`` / ``mm2im_us`` / ``ks_us`` at a shared
      geometry), yielding one og-vs-mm2im and one og-vs-mm2im_ks pair
      per problem.

    Newer docs embed the timed geometry (``geom=ohX/ocY/<order>``); for
    older docs the dbcmp geometry is recomputed from the heuristic (it is
    deterministic for a given problem) and the fold geometry falls back
    to the benchmark's fixed constants.  All these rows are measured at
    float32 (``autotune.measure_plan``'s default dtype).
    """
    pairs: List[RankPair] = []
    for r in doc.get("autotune", []):
        name = r.get("name", "")
        d = _parse_derived_str(r.get("derived", ""))
        m = re.fullmatch(r"autotune_ih(\d+)_ic(\d+)_ks(\d+)_oc(\d+)"
                         r"_s(\d+)_dbcmp", name)
        if m and "sb_us" in d and "db_us" in d:
            ih, ic, ks, oc, s = (int(g) for g in m.groups())
            p = TConvProblem(ih, ih, ic, ks, oc, s)
            geom = _parse_geom(d, "mm2im") or _default_geometry(p, 1)
            pa = Plan(geom.block_oh, geom.block_oc, geom.grid_order, "mm2im")
            pb = Plan(geom.block_oh, geom.block_oc, geom.grid_order,
                      "mm2im_db")
            pairs.append(RankPair(name, p, 1, 32, pa, pb,
                                  float(d["sb_us"]), float(d["db_us"])))
            continue
        m = re.fullmatch(r"autotune_large_ih(\d+)_ic(\d+)_ks(\d+)_oc(\d+)"
                         r"_s(\d+)_ogcmp", name)
        if m and "og_us" in d:
            ih, ic, ks, oc, s = (int(g) for g in m.groups())
            p = TConvProblem(ih, ih, ic, ks, oc, s)
            geom = _parse_geom(d, "mm2im_og") or _default_geometry(p, 1)
            pog = Plan(geom.block_oh, geom.block_oc, geom.grid_order,
                       "mm2im_og")
            for rival, us_key in (("mm2im", "mm2im_us"),
                                  ("mm2im_ks", "ks_us")):
                if us_key not in d:
                    continue
                pr = Plan(geom.block_oh, geom.block_oc, geom.grid_order,
                          rival)
                pairs.append(RankPair(f"{name}:og_vs_{rival}", p, 1, 32,
                                      pog, pr, float(d["og_us"]),
                                      float(d[us_key])))
            continue
        m = re.fullmatch(r"autotune_fold_dcgan1_(mm2im(?:_db|_ks|_og)?)",
                         name)
        if m and "grid_us" in d and "fold_us" in d:
            method = m.group(1)
            p = _FOLD_BENCH_PROBLEM
            batch = int(d.get("batch", 8))
            geom = (_parse_geom(d, method)
                    or _FOLD_BENCH_GEOM.get(method))
            if geom is None:
                continue
            pa = Plan(geom.block_oh, geom.block_oc, geom.grid_order, method)
            pb = Plan(geom.block_oh, geom.block_oc, geom.grid_order, method,
                      fold_batch=True)
            pairs.append(RankPair(name, p, batch, 32, pa, pb,
                                  float(d["grid_us"]), float(d["fold_us"])))
    return pairs


def samples_from_bench(doc: dict) -> List[Sample]:
    """Flatten a doc's head-to-head pairs into fit samples.

    Deduplicated: the large-image rows share one og measurement across
    two pairs, and a repeated timing must not vote twice in the fit.
    """
    out: List[Sample] = []
    for pair in pairs_from_bench(doc):
        for s in pair.samples():
            if dataclasses.replace(s, source="") not in {
                    dataclasses.replace(o, source="") for o in out}:
                out.append(s)
    return out


# ---------------------------------------------------------------------------
# The fit itself.
# ---------------------------------------------------------------------------

@dataclasses.dataclass(frozen=True)
class Coeffs:
    """Fitted cost coefficients for one dataflow regime (all in us).

    ``us_per_byte`` is the reciprocal of the backend's *effective* HBM
    bandwidth; ``us_per_fill_byte`` the fill (non-overlappable copy)
    multiplier; ``us_per_tile`` the per-issued-MXU-tile cost;
    ``us_per_launch`` the per-kernel-launch overhead.  Nonnegative by
    construction (the fit clips at zero).
    """

    us_per_tile: float = 0.0
    us_per_byte: float = 0.0
    us_per_fill_byte: float = 0.0
    us_per_launch: float = 0.0
    us_const: float = 0.0
    n_samples: int = 0
    mean_abs_log_err: float = float("nan")

    @property
    def vector(self) -> np.ndarray:
        return np.array([self.us_per_tile, self.us_per_byte,
                         self.us_per_fill_byte, self.us_per_launch,
                         self.us_const])

    @property
    def effective_hbm_gbps(self) -> float:
        """Fitted effective HBM bandwidth (GB/s); inf when memory is free."""
        return (float("inf") if self.us_per_byte <= 0
                else 1.0 / (self.us_per_byte * 1e3))

    def to_json(self) -> dict:
        d = dataclasses.asdict(self)
        if math.isnan(self.mean_abs_log_err):
            d["mean_abs_log_err"] = None
        return d

    @classmethod
    def from_json(cls, d: dict) -> "Coeffs":
        kw = {f.name: d[f.name] for f in dataclasses.fields(cls)
              if f.name in d}
        if kw.get("mean_abs_log_err") is None:
            kw["mean_abs_log_err"] = float("nan")
        return cls(**kw)


def _nnls(X: np.ndarray, y: np.ndarray) -> np.ndarray:
    """Nonnegative least squares by iterative negative-column dropping.

    Column-scaled for conditioning (bytes dwarf tile counts).  Not the
    full Lawson–Hanson active-set dance, but deterministic, dependency
    free, and exact whenever the unconstrained optimum is interior or a
    single face away — which these four-term fits are in practice.
    """
    scale = np.maximum(np.abs(X).max(axis=0), 1e-12)
    Xs = X / scale
    cols = list(range(X.shape[1]))
    while True:
        sol, *_ = np.linalg.lstsq(Xs[:, cols], y, rcond=None)
        if (sol >= -1e-12).all() or len(cols) == 1:
            break
        cols = [c for c, v in zip(cols, sol) if v > 0] or [cols[:1][0]]
    full = np.zeros(X.shape[1])
    full[cols] = np.clip(sol, 0.0, None)
    return full / scale


_GLOBAL_REGIME = "*"

#: Scale split for the fit regimes.  The large-image stride-4 slice
#: (``configs/paper_models.large_image_sweep``) runs 1-2 orders of
#: magnitude longer than the small sweep members, and the deliberately
#: absolute-error NNLS of :func:`fit_coefficients` is only well-posed
#: within one scale class — without the split, 100ms large-image samples
#: outvote the sub-millisecond shapes inside a shared ``mm2im_db`` regime
#: and the recorded small-shape sb/db rankings regress.
LARGE_IH_MIN = 16
LARGE_STRIDE_MIN = 4


def is_large_problem(p: TConvProblem) -> bool:
    """Canonical large-image predicate: fit-regime scale split *and*
    sweep-slice membership (``configs/paper_models`` re-exports it)."""
    return p.ih >= LARGE_IH_MIN and p.stride >= LARGE_STRIDE_MIN


def _regime_key(method: str, fold: bool, large: bool = False) -> str:
    key = f"{method}+fold" if fold else method
    return f"{key}@large" if large else key


def _fit_one(samples: Sequence[Sample], hw: HW) -> Coeffs:
    X = np.stack([features(s.problem, s.plan, batch=s.batch, bits=s.bits,
                           hw=hw) for s in samples])
    y = np.array([s.us for s in samples])
    coef = _nnls(X, y)
    pred = np.maximum(X @ coef, 1e-9)
    return Coeffs(*(float(c) for c in coef), n_samples=len(samples),
                  mean_abs_log_err=float(np.abs(np.log(pred / y)).mean()))


@dataclasses.dataclass(frozen=True)
class FittedHW:
    """Per-backend calibrated cost model: regime -> :class:`Coeffs`.

    ``regimes`` keys are ``'<method>'`` / ``'<method>+fold'`` with an
    ``'@large'`` suffix for the large-image scale class, plus the ``'*'``
    global fallback fit over every sample, so ``predict_us`` always
    returns a finite, mutually comparable score — a third-party kernel
    variant with no samples ranks with the global coefficients, not a
    different unit system.  A large-problem lookup degrades to the same
    method's small-scale regime before the global one, so fit files
    predating the scale split keep their old behavior.
    """

    backend: str
    hw_name: str
    regimes: Dict[str, Coeffs]
    provenance: dict

    def coeffs_for(self, method: Optional[str], fold: bool = False,
                   large: bool = False) -> Coeffs:
        key = _regime_key(method or "mm2im", fold, large)
        c = self.regimes.get(key)
        if large and (c is None or c.n_samples < MIN_REGIME_SAMPLES):
            c = self.regimes.get(_regime_key(method or "mm2im", fold))
        if c is None or c.n_samples < MIN_REGIME_SAMPLES:
            c = self.regimes.get(_GLOBAL_REGIME, c) or Coeffs()
        return c

    def predict_us(self, p: TConvProblem, plan: Plan, *, batch: int = 1,
                   bits: int = 32, hw: HW = V5E) -> float:
        """Calibrated wall-time prediction (us) for a plan on a problem."""
        c = self.coeffs_for(plan.method, plan.fold_batch,
                            large=is_large_problem(p))
        return float(features(p, plan, batch=batch, bits=bits, hw=hw)
                     @ c.vector)

    def to_json(self) -> dict:
        return {"version": FIT_VERSION, "backend": self.backend,
                "hw": self.hw_name, "provenance": dict(self.provenance),
                "regimes": {k: c.to_json()
                            for k, c in sorted(self.regimes.items())}}

    @classmethod
    def from_json(cls, d: dict) -> "FittedHW":
        if d.get("version") != FIT_VERSION:
            raise ValueError(f"unsupported fit version {d.get('version')!r}")
        return cls(backend=str(d.get("backend", "")),
                   hw_name=str(d.get("hw", V5E.name)),
                   regimes={k: Coeffs.from_json(v)
                            for k, v in d.get("regimes", {}).items()},
                   provenance=dict(d.get("provenance", {})))


def fit_coefficients(samples: Iterable[Sample], *, backend: str,
                     hw: HW = V5E, note: str = "",
                     sources: Sequence[str] = ()) -> FittedHW:
    """Per-regime + global NNLS over measured samples -> :class:`FittedHW`.

    Plain absolute-error least squares, deliberately: the large problems
    are where misranks cost real time, and relative weighting lets the
    sub-millisecond tail outvote them (that is exactly how the recorded
    fold-db misrank survived the uncalibrated model's sanity checks).
    The complementary guard is the ``@large`` regime split
    (:func:`is_large_problem`): absolute error is only well-posed within
    one scale class, so the large-image stride-4 samples fit their own
    regimes instead of outvoting the small-shape ones.
    """
    samples = list(samples)
    if not samples:
        raise ValueError("no samples to fit (empty caches / bench docs?)")
    import jax

    groups: Dict[str, List[Sample]] = {_GLOBAL_REGIME: samples}
    for s in samples:
        groups.setdefault(_regime_key(*s.regime), []).append(s)
    regimes = {key: _fit_one(group, hw) for key, group in groups.items()
               if len(group) >= min(MIN_REGIME_SAMPLES, len(samples))}
    prov = {"backend": backend, "jax": jax.__version__,
            "created": time.time(), "n_samples": len(samples),
            "sources": list(sources), "note": note}
    return FittedHW(backend=backend, hw_name=hw.name, regimes=regimes,
                    provenance=prov)


# ---------------------------------------------------------------------------
# Rank agreement — the score CI gates on.
# ---------------------------------------------------------------------------

#: Measured ratios closer to 1 than this band are non-decisive: interpret
#: mode times sub-millisecond candidates with repeats=2-3 on shared CPUs,
#: so ordering inside the band is noise, not signal.
DECISIVE_BAND = 1.5


def _predict(pair: RankPair, plan: Plan, fit: Optional[FittedHW],
             hw: HW) -> float:
    if fit is not None:
        return fit.predict_us(pair.problem, plan, batch=pair.batch,
                              bits=pair.bits, hw=hw)
    return estimate_for_plan(pair.problem, pair.batch, plan=plan,
                             bits=pair.bits, hw=hw).t_overlapped * 1e6


def rank_agreement(pairs: Sequence[RankPair], fit: Optional[FittedHW] = None,
                   *, hw: HW = V5E,
                   decisive_band: float = DECISIVE_BAND) -> dict:
    """Score predicted vs measured ordering over recorded head-to-heads.

    Per pair: the model (fitted when ``fit`` is given, else the
    uncalibrated roofline) predicts both sides; ``agree`` is order
    correctness, ``abs_log2_err`` the magnitude error between predicted
    and measured ratio — the old per-row ``rank_agree`` flag checked the
    sign only, which is how "predicted 7.09x, measured 1.36x" passed as
    agreement.  Pairs whose measured ratio is within ``decisive_band`` of
    1.0 are scored but not *decisive*: ordering noise-level candidates is
    not a model failure.  Aggregates:

    * ``rank_score`` — agreeing fraction over all pairs;
    * ``decisive_score`` — agreeing fraction over decisive pairs (the CI
      hard-gate metric);
    * ``n_misranks`` — decisive disagreements (hard-gate count);
    * ``mean_abs_log2_err`` — magnitude error, all pairs.
    """
    rows = []
    for pair in pairs:
        pred_a = _predict(pair, pair.plan_a, fit, hw)
        pred_b = _predict(pair, pair.plan_b, fit, hw)
        pred_ratio = pred_a / max(pred_b, 1e-9)
        meas_ratio = pair.measured_ratio
        agree = (pred_ratio >= 1.0) == (meas_ratio >= 1.0)
        decisive = max(meas_ratio, 1.0 / max(meas_ratio, 1e-9)) \
            >= decisive_band
        rows.append({
            "name": pair.name,
            "measured_ratio": round(meas_ratio, 4),
            "predicted_ratio": round(pred_ratio, 4),
            "agree": bool(agree),
            "decisive": bool(decisive),
            "abs_log2_err": round(abs(math.log2(
                max(pred_ratio, 1e-9) / max(meas_ratio, 1e-9))), 4),
        })
    n = len(rows)
    dec = [r for r in rows if r["decisive"]]
    agree_all = sum(r["agree"] for r in rows)
    agree_dec = sum(r["agree"] for r in dec)
    return {
        "calibrated": fit is not None,
        "decisive_band": decisive_band,
        "n_pairs": n,
        "n_agree": agree_all,
        "rank_score": round(agree_all / n, 4) if n else None,
        "n_decisive": len(dec),
        "decisive_agree": agree_dec,
        "decisive_score": (round(agree_dec / len(dec), 4) if dec else None),
        "n_misranks": len(dec) - agree_dec,
        "mean_abs_log2_err": (round(
            float(np.mean([r["abs_log2_err"] for r in rows])), 4)
            if rows else None),
        "pairs": rows,
    }


# ---------------------------------------------------------------------------
# Persistence next to the shipped plan tables.
# ---------------------------------------------------------------------------

def fit_dir() -> Path:
    """Directory holding ``<backend>.fit.json`` (default: the plan tables')."""
    env = os.environ.get(FIT_DIR_ENV)
    if env:
        return Path(env).expanduser()
    from repro.core.plan_table import table_dir

    return table_dir()


def fit_path(backend: str, directory: Union[str, Path, None] = None) -> Path:
    return (Path(directory) if directory else fit_dir()) \
        / f"{backend}.fit.json"


def validate_fit_json(raw: object, *, source: str = "fit") -> List[str]:
    """Schema-check one parsed fit doc; returns problems (empty == valid)."""
    errs: List[str] = []
    if not isinstance(raw, dict):
        return [f"{source}: top level must be an object"]
    if raw.get("version") != FIT_VERSION:
        errs.append(f"{source}: version must be {FIT_VERSION}, "
                    f"got {raw.get('version')!r}")
    prov = raw.get("provenance")
    if not isinstance(prov, dict):
        errs.append(f"{source}: missing 'provenance' object")
    else:
        for field in REQUIRED_PROVENANCE:
            if field not in prov:
                errs.append(f"{source}: provenance missing {field!r}")
    regimes = raw.get("regimes")
    if not isinstance(regimes, dict) or not regimes:
        errs.append(f"{source}: missing non-empty 'regimes' object")
        return errs
    if _GLOBAL_REGIME not in regimes:
        errs.append(f"{source}: missing the '{_GLOBAL_REGIME}' global "
                    f"fallback regime")
    for key, c in regimes.items():
        where = f"{source}: regimes[{key!r}]"
        if not isinstance(c, dict):
            errs.append(f"{where}: must be an object")
            continue
        for f in ("us_per_tile", "us_per_byte", "us_per_fill_byte",
                  "us_per_launch", "us_const"):
            v = c.get(f)
            if not isinstance(v, (int, float)) or v < 0:
                errs.append(f"{where}: {f!r} must be a nonnegative number")
    return errs


def save_fit(fit: FittedHW, path: Union[str, Path]) -> Path:
    path = Path(path)
    doc = fit.to_json()
    errs = validate_fit_json(doc, source=str(path))
    if errs:
        raise ValueError("refusing to save an invalid fit:\n  "
                         + "\n  ".join(errs))
    path.parent.mkdir(parents=True, exist_ok=True)
    path.write_text(json.dumps(doc, indent=1, sort_keys=True) + "\n")
    return path


def load_fit(path: Union[str, Path], *,
             strict: bool = False) -> Optional[FittedHW]:
    """Parse + validate one fit file; None when absent/invalid (lenient)."""
    path = Path(path)
    try:
        raw = json.loads(path.read_text())
    except (OSError, ValueError) as e:
        if strict:
            raise ValueError(f"{path}: {e}") from None
        return None
    errs = validate_fit_json(raw, source=str(path))
    if errs:
        if strict:
            raise ValueError("invalid model fit:\n  " + "\n  ".join(errs))
        return None
    return FittedHW.from_json(raw)


_SHIPPED_FITS: dict = {}  # backend -> Optional[FittedHW] (per-process memo)


def shipped_fit(backend: Optional[str] = None) -> Optional[FittedHW]:
    """The shipped calibration for ``backend`` (default: the JAX backend).

    Memoized like ``plan_table.shipped_table`` — fits are immutable
    release artifacts.  None when no calibration ships for this backend;
    consumers then fall back to the uncalibrated roofline, so a missing
    or invalid fit can never break tuning.
    """
    if backend is None:
        import jax

        backend = jax.default_backend()
    if backend not in _SHIPPED_FITS:
        _SHIPPED_FITS[backend] = load_fit(fit_path(backend))
    return _SHIPPED_FITS[backend]


def reset_shipped_fits() -> None:
    """Drop the memo (tests; after pointing REPRO_MODEL_FIT_DIR elsewhere)."""
    _SHIPPED_FITS.clear()
