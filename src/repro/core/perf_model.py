"""Analytical performance model for MM2IM on TPU (paper §III-C, adapted).

The paper models ``T_total = T_PM + T_Data`` for its FPGA (Eq. 3/4) and uses
the model to guide design (validated within 10%, §V-F).  On TPU the same
three-term structure becomes a roofline:

    T_compute    = issued_FLOPs            / peak_FLOPs
    T_memory     = HBM bytes moved         / HBM bandwidth
    T_collective = collective bytes        / ICI link bandwidth  (0 on-chip)

    T_total      = max(...)  (overlapped)   /   sum(...) (unoverlapped bound)

The model knows the *exact* dataflow of every implementation method, so it
can predict method-vs-method speedups (the role Fig. 6 / Table II play in
the paper) without hardware.  §V-F's validation becomes: model FLOPs/bytes
vs the XLA-compiled ``cost_analysis()`` (tests assert agreement), and the
hillclimbing loop in docs/EXPERIMENTS.md §Perf iterates on whichever term
this model says dominates.

The MM2IM family additionally models the **overlapped-copy term**
(``Estimate.t_fill``): the part of data movement that compute cannot hide.
For the single-buffered kernel that is the serial whole-input VMEM landing
(the SECDA data-in stall the paper pipelines away); for the double-buffered
variant (``'mm2im_db'``) it shrinks to one slab copy — the pipeline fill —
at the price of halo re-reads in ``t_memory``.  This is what lets
``core/autotune.py`` rank single- vs double-buffered candidates before
measuring, and ``benchmarks/bench_autotune.py`` compare predicted vs
measured rankings.

Hardware constants are TPU v5e per the assignment: 197 TFLOP/s bf16
(we model int8 at 2x), 819 GB/s HBM, ~50 GB/s/link ICI.
"""

from __future__ import annotations

import dataclasses
from typing import Optional

from repro.core.maps import TConvProblem, drop_stats, max_slab_rows
from repro.kernels.baselines import tdc_macs, zero_insertion_macs


@dataclasses.dataclass(frozen=True)
class HW:
    name: str = "tpu-v5e"
    peak_flops_bf16: float = 197e12
    peak_flops_int8: float = 394e12
    hbm_bw: float = 819e9
    ici_bw_per_link: float = 50e9
    vmem_bytes: int = 16 * 2**20
    mxu_dim: int = 128


V5E = HW()


@dataclasses.dataclass
class Estimate:
    """Roofline terms (seconds) + bookkeeping for one op/method.

    ``t_fill`` is the non-overlappable slice OF ``t_memory`` (pipeline
    fill): bytes that must land before the first MAC can issue.  It is not
    extra traffic — ``t_memory`` already contains it — so the overlapped
    bound overlaps compute only with the *rest* of the memory time:
    ``max(compute, memory - fill, collective) + fill``.
    """

    method: str
    t_compute: float
    t_memory: float
    t_collective: float = 0.0
    t_fill: float = 0.0
    issued_macs: int = 0
    effectual_macs: int = 0
    hbm_bytes: int = 0
    # Raw cost-model terms, exposed for coefficient fitting
    # (core/model_fit.py): the calibration layer regresses measured wall
    # time against (issued_tiles, hbm_bytes, fill_bytes, n_launches)
    # instead of the hardware-datasheet-derived t_* seconds above.
    n_launches: int = 0
    fill_bytes: int = 0
    issued_tiles: int = 0

    @property
    def t_overlapped(self) -> float:
        return (max(self.t_compute, self.t_memory - self.t_fill,
                    self.t_collective) + self.t_fill)

    @property
    def t_serial(self) -> float:
        return self.t_compute + self.t_memory + self.t_collective

    @property
    def bottleneck(self) -> str:
        """Dominant term of the overlapped bound.

        ``t_fill`` competes as its own term: it is the non-overlappable
        slice of ``t_memory``, so the memory term here is the *remainder*
        (what compute can hide).  A fill-dominated problem reports
        ``'fill'`` — previously it was misattributed to plain
        ``'memory'``, hiding that the cure is pipelining (the db variant),
        not less traffic.
        """
        terms = {"compute": self.t_compute,
                 "memory": self.t_memory - self.t_fill,
                 "fill": self.t_fill,
                 "collective": self.t_collective}
        return max(terms, key=terms.get)

    @property
    def mxu_utilization(self) -> float:
        """Effectual fraction of issued MXU work (the GOPs/DSP analogue)."""
        return self.effectual_macs / max(self.issued_macs, 1)


def _dtype_peak(hw: HW, bits: int) -> float:
    return hw.peak_flops_int8 if bits == 8 else hw.peak_flops_bf16


def mxu_tiles(m: int, n: int, k: int, mxu: int) -> int:
    """Issued MXU tiles for an (m, k) @ (k, n) product.

    The systolic array computes whole ``mxu x mxu`` tiles: a matmul with
    ``m < mxu`` rows issues the same tile row as one with ``m == mxu``
    rows.  This quantization is exactly what batch folding exploits — the
    folded M-dimension packs ``B`` starved row slabs into the tiles the
    grid-batch dataflow would issue ``B`` times over.
    """
    return -(-m // mxu) * (-(-n // mxu)) * (-(-k // mxu))


def mm2im_estimate(
    p: TConvProblem,
    batch: int = 1,
    *,
    block_oh: Optional[int] = None,
    block_oc: Optional[int] = None,
    bits: int = 8,
    grid_order: str = "auto",
    hw: HW = V5E,
    double_buffered: bool = False,
    fold_batch: bool = False,
    requant: Optional[bool] = None,
) -> Estimate:
    """Model the fused Pallas MM2IM kernel's dataflow exactly.

    ``double_buffered=False`` models ``kernels/mm2im_pallas`` (whole input
    resident in VMEM; fill = the serial whole-input landing).
    ``double_buffered=True`` models ``kernels/mm2im_db_pallas`` (two-slot
    slab pipeline: fill shrinks to one slab copy, but every row block
    re-reads its halo rows from HBM).

    ``t_compute`` counts **issued MXU tiles** (:func:`mxu_tiles` — the
    ``ceil(M/128)·ceil(N/128)·ceil(K/128)`` quantization of the systolic
    array), not raw MACs, so a starved M-dimension costs what it costs on
    the hardware.  ``fold_batch=True`` models the plan-v2 folded dataflow:
    M grows to ``B·n_slab·Iw`` and the per-batch grid multiplicity
    disappears — this is what lets the autotuner rank folded vs grid-batch
    candidates a priori.

    ``requant`` selects the output store width for int8 problems: the
    paper's requantizing mode stores int8 (1 byte), int8 *without* a
    requant epilogue stores the int32 accumulator (4 bytes).  ``None``
    defaults to requantizing when ``bits == 8`` (the paper's precision).
    """
    from repro.kernels.mm2im_pallas import plan_blocks  # avoid cycle

    if block_oh is None or block_oc is None:
        block_oh, block_oc = plan_blocks(
            p.ih, p.iw, p.ic, p.ks, p.oc, p.stride, p.padding,
            in_bytes=bits // 8, vmem_budget=int(hw.vmem_bytes * 0.75))
    s = p.stride
    bi = block_oh // s
    n_j = -(-p.oh // block_oh)
    n_c = -(-p.oc // block_oc)
    # Static slab height (mm2im_pallas geometry).
    from repro.kernels.ref import crop_offsets

    ct, _ = crop_offsets(p.ks, s, p.padding)
    delta = -(-max(p.ks - 1 - ct, 0) // s)
    eps = (ct - 1) // s
    n_slab = bi + delta + eps + 1

    ebytes = bits // 8
    oc_p = n_c * block_oc
    ihp = (n_j - 1) * bi + n_slab

    # MXU work actually issued, tile-quantized (halo overlap + Oc padding
    # + M/N/K tile padding included).  Folded: one (B*n_slab*Iw, Ic)
    # product per (j, c) cell; grid-batch: B starved (n_slab*Iw, Ic)
    # products.  mxu_utilization is the GOPs/DSP analogue: the effectual
    # fraction of the dense tile work the systolic array actually clocks.
    m_rows = (batch if fold_batch else 1) * n_slab * p.iw
    tiles = mxu_tiles(m_rows, p.ks**2 * block_oc, p.ic, hw.mxu_dim)
    n_launches = n_c * n_j * (1 if fold_batch else batch)
    issued = n_launches * tiles * hw.mxu_dim**3
    eff = drop_stats(p)["effectual_macs"] * batch

    # HBM traffic under the chosen grid order (resident-block model).
    w_bytes = p.ic * p.ks**2 * oc_p * ebytes
    slab_bytes = n_slab * p.iw * p.ic * ebytes
    if double_buffered:
        # Slab-granular reads: each row block re-fetches its halo rows.
        x_bytes_once = n_j * slab_bytes
    else:
        x_bytes_once = ihp * p.iw * p.ic * ebytes
    # Output store width follows the epilogue: the paper's int8 mode
    # requantizes to int8 (1 byte); int8 WITHOUT requant stores the int32
    # accumulator (4 bytes) — previously mis-modeled as 1 byte.
    if requant is None:
        requant = bits == 8
    out_store = 1 if (bits == 8 and requant) else 4
    out_bytes = batch * n_j * block_oh * (-(-p.ow // s) * s) * oc_p * out_store
    if grid_order == "auto":
        grid_order = "cbj" if w_bytes > batch * x_bytes_once else "bcj"
    if fold_batch:
        # Folding removes the per-batch grid multiplicity: weights are
        # fetched once, and the batch-concatenated input lands once for
        # the single-buffered kernel (resident across the (c, j) sweep) or
        # once per oc-block for the pipeline (slabs re-DMA'd per cell).
        hbm = (w_bytes + (n_c if double_buffered else 1) * batch
               * x_bytes_once + out_bytes)
    elif double_buffered:
        # The pipeline never keeps x resident: every (batch, oc-block) grid
        # cell re-DMAs all its slabs from HBM under BOTH grid orders, so
        # the x term always carries the n_c multiplicity; grid order only
        # decides whether the weight blocks are re-fetched per batch.
        hbm = ((w_bytes if grid_order == "cbj" else batch * w_bytes)
               + n_c * batch * x_bytes_once + out_bytes)
    elif grid_order == "cbj":
        hbm = w_bytes + n_c * batch * x_bytes_once + out_bytes
    else:
        hbm = batch * (x_bytes_once + w_bytes) + out_bytes

    # Overlapped-copy term: what the compute pipeline cannot hide.  The
    # single-buffered kernel stalls until the whole padded input landed in
    # VMEM; the double-buffered pipeline stalls only for its first slab.
    # Folded variants move batch-concatenated blocks, so the fill scales
    # with B either way.
    fill_once = slab_bytes if double_buffered else ihp * p.iw * p.ic * ebytes
    fill_bytes = (batch if fold_batch else 1) * fill_once

    return Estimate(
        method="mm2im_db" if double_buffered else "mm2im",
        t_compute=2 * issued / _dtype_peak(hw, bits),
        t_memory=hbm / hw.hbm_bw,
        t_fill=fill_bytes / hw.hbm_bw,
        issued_macs=issued,
        effectual_macs=eff,
        hbm_bytes=hbm,
        n_launches=n_launches,
        fill_bytes=fill_bytes,
        issued_tiles=n_launches * tiles,
    )


def mm2im_db_estimate(p: TConvProblem, batch: int = 1, **kw) -> Estimate:
    """Double-buffered MM2IM pipeline (``kernels/mm2im_db_pallas``)."""
    return mm2im_estimate(p, batch, double_buffered=True, **kw)


def mm2im_ks_estimate(
    p: TConvProblem,
    batch: int = 1,
    *,
    block_oh: Optional[int] = None,
    block_oc: Optional[int] = None,
    bits: int = 8,
    grid_order: str = "auto",
    hw: HW = V5E,
    fold_batch: bool = False,
    requant: Optional[bool] = None,
) -> Estimate:
    """Kernel-segregated MM2IM (``kernels/mm2im_ks_pallas``).

    Host staging, grid structure and HBM traffic are identical to the
    single-buffered MM2IM (the weight relayout is a permutation — same
    bytes), so every memory-side term is inherited.  Only the compute
    term differs: instead of one ``(n_slab·Iw, Ks²·boc)`` MatMul per grid
    cell, each non-empty sub-kernel issues a dense
    ``((bi + Jh - 1)·Iw, Jh·Jw·boc)`` product over exactly the slab rows
    its taps touch — the tile count sums only **effectual** MXU work
    (empty residue classes of a gapped stride > kernel TCONV issue
    nothing).  At stride 1 the sum degenerates to MM2IM's single-MatMul
    tile count, and ``fold_batch`` scales each sub-MatMul's M by B just
    like the plan-v2 folded family.
    """
    from repro.core.segregate import segregate  # avoid cycle
    from repro.kernels.mm2im_pallas import plan_blocks

    base = mm2im_estimate(
        p, batch, block_oh=block_oh, block_oc=block_oc, bits=bits,
        grid_order=grid_order, hw=hw, fold_batch=fold_batch, requant=requant)
    if block_oh is None or block_oc is None:
        block_oh, block_oc = plan_blocks(
            p.ih, p.iw, p.ic, p.ks, p.oc, p.stride, p.padding,
            in_bytes=bits // 8, vmem_budget=int(hw.vmem_bytes * 0.75))
    bi = block_oh // p.stride
    seg = segregate(p.ks, p.stride, p.padding)
    m_unit = batch if fold_batch else 1
    tiles = sum(
        mxu_tiles(m_unit * (bi + sk.jh - 1) * p.iw, sk.taps * block_oc,
                  p.ic, hw.mxu_dim)
        for sk in seg.subkernels if sk.taps)
    issued = base.n_launches * tiles * hw.mxu_dim**3
    return dataclasses.replace(
        base,
        method="mm2im_ks",
        t_compute=2 * issued / _dtype_peak(hw, bits),
        issued_macs=issued,
        issued_tiles=base.n_launches * tiles,
    )


def mm2im_og_estimate(
    p: TConvProblem,
    batch: int = 1,
    *,
    block_oh: Optional[int] = None,
    block_oc: Optional[int] = None,
    bits: int = 8,
    grid_order: str = "auto",
    hw: HW = V5E,
    fold_batch: bool = False,
    requant: Optional[bool] = None,
) -> Estimate:
    """Output-gathered implicit GEMM (``kernels/mm2im_og_pallas``).

    Host staging and HBM-resident traffic match the single-buffered MM2IM
    (whole input lands once, weights are a permuted relayout — same
    bytes), but both roofline terms change shape:

    * **compute** — per residue class one ``(bi·Iw', Jh·Jw·Ic) @
      (Jh·Jw·Ic, boc)`` product: M covers exactly the output pixels
      (no ``n_slab`` halo rows, no ``Ks²``-wide N), and the tap reduction
      rides the K-dimension.  Tile count sums only effectual work, like
      the KS family, but with output-exact M and tap-deep K.
    * **memory** — the differentiating term is **gather-read bytes vs
      scatter-write bytes**: staging the gathered operand re-reads each
      input element once per tap that uses it (``Σ_sk Jh·Jw·bi·Iw'·Ic``
      bytes per grid cell), where the scatter-style families instead pay
      accumulator/plane read-modify-write traffic.  The gather bytes are
      added to ``hbm_bytes`` so the calibration layer
      (``core/model_fit.py``) can fit the trade as a regime-distinct
      coefficient; in exchange every output element is written exactly
      once and no partial sum is ever re-read.

    At stride 1 the single residue class gathers all ``Ks²`` taps — the
    amplification is maximal and MM2IM should win; at large stride and
    large image the per-class tap count collapses toward 1 while MM2IM's
    slab residency (and KS's halo-extended M) keep growing — the regime
    this family exists for.
    """
    from repro.core.segregate import segregate  # avoid cycle
    from repro.kernels.mm2im_pallas import plan_blocks

    base = mm2im_estimate(
        p, batch, block_oh=block_oh, block_oc=block_oc, bits=bits,
        grid_order=grid_order, hw=hw, fold_batch=fold_batch, requant=requant)
    if block_oh is None or block_oc is None:
        block_oh, block_oc = plan_blocks(
            p.ih, p.iw, p.ic, p.ks, p.oc, p.stride, p.padding,
            in_bytes=bits // 8, vmem_budget=int(hw.vmem_bytes * 0.75))
    bi = block_oh // p.stride
    iw_p = -(-p.ow // p.stride)  # padded residue-plane width (ow_p / S)
    seg = segregate(p.ks, p.stride, p.padding)
    m_unit = batch if fold_batch else 1
    tiles = sum(
        mxu_tiles(m_unit * bi * iw_p, block_oc, sk.taps * p.ic, hw.mxu_dim)
        for sk in seg.subkernels if sk.taps)
    issued = base.n_launches * tiles * hw.mxu_dim**3
    gather_bytes = (base.n_launches * m_unit
                    * sum(sk.taps * bi * iw_p * p.ic
                          for sk in seg.subkernels if sk.taps)
                    * (bits // 8))
    hbm = base.hbm_bytes + gather_bytes
    return dataclasses.replace(
        base,
        method="mm2im_og",
        t_compute=2 * issued / _dtype_peak(hw, bits),
        t_memory=hbm / hw.hbm_bw,
        issued_macs=issued,
        hbm_bytes=hbm,
        issued_tiles=base.n_launches * tiles,
    )


def iom_unfused_estimate(p: TConvProblem, batch: int = 1, *, bits: int = 8,
                         hw: HW = V5E) -> Estimate:
    """Unfused IOM: dense MatMul -> HBM intermediate -> col2im scatter pass.

    The MatMul is tile-quantized like the MM2IM family's (same MXU, same
    starved-M penalty for small images) so cross-method modeled speedups
    compare equal model fidelities — one ``(Ih·Iw, Ic) @ (Ic, Ks²·Oc)``
    launch per batch element.
    """
    ebytes = bits // 8
    macs = (batch * mxu_tiles(p.m, p.n, p.k, hw.mxu_dim) * hw.mxu_dim**3)
    inter = batch * p.m * p.n * 4  # f32/i32 partial-product matrix
    hbm = (batch * p.m * p.k * ebytes + p.k * p.n * ebytes  # mm reads
           + inter                                            # mm write
           + inter                                            # col2im read
           + batch * p.oh * p.ow * p.oc * 4)                  # scatter out
    return Estimate(
        method="iom_unfused",
        t_compute=2 * macs / _dtype_peak(hw, bits),
        t_memory=hbm / hw.hbm_bw,
        issued_macs=macs,
        effectual_macs=drop_stats(p)["effectual_macs"] * batch,
        hbm_bytes=hbm,
        n_launches=2 * batch,  # one MatMul + one col2im pass per element
        issued_tiles=macs // hw.mxu_dim**3,
    )


def zero_insertion_estimate(p: TConvProblem, batch: int = 1, *, bits: int = 8,
                            hw: HW = V5E) -> Estimate:
    """§II-A method (i).  Direct-conv dataflow: raw dense MAC count (the
    paper's convention) — XLA's implicit-im2col conv tiling differs from a
    plain matmul's, so no MXU tile quantization is applied here (a modeled
    lower bound on compute time, same for :func:`tdc_estimate`)."""
    macs = batch * zero_insertion_macs(p.ih, p.iw, p.ic, p.ks, p.oc, p.stride, p.padding)
    ebytes = bits // 8
    sd = p.stride * (p.ih - 1) + 1
    hbm = (batch * sd * sd * p.ic * ebytes + p.ks**2 * p.oc * p.ic * ebytes
           + batch * p.oh * p.ow * p.oc * 4)
    return Estimate(
        method="zero_insertion",
        t_compute=2 * macs / _dtype_peak(hw, bits),
        t_memory=hbm / hw.hbm_bw,
        issued_macs=macs,
        effectual_macs=drop_stats(p)["effectual_macs"] * batch,
        hbm_bytes=hbm,
        n_launches=batch,
    )


def tdc_estimate(p: TConvProblem, batch: int = 1, *, bits: int = 8,
                 hw: HW = V5E) -> Estimate:
    macs = batch * tdc_macs(p.ih, p.iw, p.ic, p.ks, p.oc, p.stride, p.padding)
    ebytes = bits // 8
    # S^2 conv passes re-read the input once each; sub-filters read once;
    # interleave pass rewrites the output once.
    hbm = (batch * min(p.stride, p.oh) * min(p.stride, p.ow) * p.ih * p.iw * p.ic * ebytes
           + p.ks**2 * p.oc * p.ic * ebytes
           + 2 * batch * p.oh * p.ow * p.oc * 4)
    return Estimate(
        method="tdc",
        t_compute=2 * macs / _dtype_peak(hw, bits),
        t_memory=hbm / hw.hbm_bw,
        issued_macs=macs,
        effectual_macs=drop_stats(p)["effectual_macs"] * batch,
        hbm_bytes=hbm,
        n_launches=p.stride**2 * batch,  # one conv pass per sub-kernel
    )


ESTIMATORS = {
    "mm2im": mm2im_estimate,
    "mm2im_db": mm2im_db_estimate,
    "mm2im_ks": mm2im_ks_estimate,
    "mm2im_og": mm2im_og_estimate,
    "iom_unfused": iom_unfused_estimate,
    "zero_insertion": zero_insertion_estimate,
    "tdc": tdc_estimate,
}


#: Methods whose estimators accept the full plan-geometry kwargs
#: (``block_oh``/``block_oc``/``grid_order``/``fold_batch``).
PLAN_AWARE_METHODS = frozenset({"mm2im", "mm2im_db", "mm2im_ks", "mm2im_og"})


def estimate_for_plan(p: TConvProblem, batch: int = 1, *, plan=None,
                      method: Optional[str] = None, bits: int = 8,
                      hw: HW = V5E) -> Estimate:
    """Estimate for the exact dataflow a concrete ``Plan`` selects.

    ``plan`` is a :class:`repro.kernels.registry.Plan` (or None for the
    heuristic default).  ``plan.method`` picks the estimator
    (``method=`` overrides it — e.g. to model a non-MM2IM baseline);
    the block geometry, grid order and ``fold_batch`` knob are threaded
    through for the plan-aware MM2IM family, so the modeled time is the
    time of the plan that actually runs, not the heuristic
    single-buffered default.  Unknown registered methods fall back to the
    single-buffered estimate (same convention as the autotuner's
    :data:`repro.core.autotune.METHOD_ESTIMATORS`).
    """
    m = method or (plan.method if plan is not None and plan.method
                   else "mm2im")
    est = ESTIMATORS.get(m)
    if est is None:  # third-party variant: rank with the sb estimate
        est, m = mm2im_estimate, "mm2im"
    if m in PLAN_AWARE_METHODS and plan is not None:
        return est(p, batch, bits=bits, hw=hw,
                   block_oh=plan.block_oh, block_oc=plan.block_oc,
                   grid_order=plan.grid_order, fold_batch=plan.fold_batch)
    return est(p, batch, bits=bits, hw=hw)


def modeled_speedup(p: TConvProblem, batch: int = 1, *, bits: int = 8,
                    baseline: str = "iom_unfused", hw: HW = V5E,
                    plan=None, baseline_plan=None) -> float:
    """Predicted speedup of a plan's dataflow over a baseline (Fig. 6).

    Both sides of the ratio honour an explicit plan: ``plan`` selects the
    MM2IM-side kernel variant (single- vs double-buffered), block
    geometry and ``fold_batch`` — previously this side silently modeled
    the heuristic single-buffered dataflow even when a tuned
    double-buffered/folded plan was the one measured.  ``baseline_plan``
    does the same for a plan-aware ``baseline`` method (ignored for the
    unfused/direct baselines, which have no plan knobs).
    """
    t_b = estimate_for_plan(p, batch, plan=baseline_plan, method=baseline,
                            bits=bits, hw=hw).t_overlapped
    t_m = estimate_for_plan(p, batch, plan=plan, bits=bits,
                            hw=hw).t_overlapped
    return t_b / t_m
