"""MM2IM compute/output map generation and IOM-efficiency analytics.

This is the host-side counterpart of the paper's *MM2IM Mapper* (Alg. 2).
On the accelerator the maps are never materialized (the Pallas kernel derives
them from compile-time affine arithmetic — DESIGN.md §2); this module exists
for (a) the oracle / analytics path, (b) the drop-rate figures (Fig. 1/7),
(c) the tiling planner's ``i_end_row`` relation (Alg. 1), and (d) tests.

Conventions match ``kernels/ref.py``: MatMul row ``m = ih*Iw + iw``; column
``n = (kh*Ks + kw)*Oc + oc``; target output pixel
``(S*ih - ct + kh, S*iw - cl + kw)`` with SAME crop ``ct = cl = (Ks-S)//2``.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Tuple

import numpy as np

from repro.kernels.ref import crop_offsets, out_size


@dataclasses.dataclass(frozen=True)
class TConvProblem:
    """A TCONV problem configuration: tconv(Ih, Iw, Ic, Ks, Oc, S)."""

    ih: int
    iw: int
    ic: int
    ks: int
    oc: int
    stride: int
    padding: str = "SAME"

    @property
    def oh(self) -> int:
        return out_size(self.ih, self.ks, self.stride, self.padding)

    @property
    def ow(self) -> int:
        return out_size(self.iw, self.ks, self.stride, self.padding)

    # IOM MatMul dimensions (paper §II-B).
    @property
    def m(self) -> int:
        return self.ih * self.iw

    @property
    def n(self) -> int:
        return self.ks * self.ks * self.oc

    @property
    def k(self) -> int:
        return self.ic

    @property
    def macs(self) -> int:
        """MACs of the (unskipped) IOM MatMul: M*N*K."""
        return self.m * self.n * self.k

    @property
    def ops(self) -> int:
        """Paper's 'OPs' convention (Table II): 2 * MACs."""
        return 2 * self.macs


def spatial_maps(p: TConvProblem) -> Tuple[np.ndarray, np.ndarray]:
    """Return (omap, cmap) over spatial partial products.

    omap: int32 (M, Ks, Ks) — flat output pixel index ``oh*Ow + ow`` for each
          partial product, or -1 where dropped (the paper's gray squares).
    cmap: bool  (M, Ks, Ks) — True where the partial product survives.

    Channel dim is omitted: all Oc channels of one (m, kh, kw) cell share the
    same spatial fate, exactly like the paper's per-row maps broadcast to PMs.
    """
    ct, cl = crop_offsets(p.ks, p.stride, p.padding)
    m = np.arange(p.m)
    ihs, iws = m // p.iw, m % p.iw
    kh = np.arange(p.ks)
    kw = np.arange(p.ks)
    toh = p.stride * ihs[:, None, None] - ct + kh[None, :, None]
    tow = p.stride * iws[:, None, None] - cl + kw[None, None, :]
    valid = (toh >= 0) & (toh < p.oh) & (tow >= 0) & (tow < p.ow)
    omap = np.where(valid, toh * p.ow + tow, -1).astype(np.int32)
    return omap, valid


def drop_stats(p: TConvProblem) -> dict:
    """IOM inefficiency metrics from §III-A (Fig. 1/7 and the Fig. 2 example).

    Returns D_o (dropped partial outputs incl. channels), D_r = D_o/(M*N),
    P_outs = M*N, F_outs = Oc*Oh*Ow, buffer-efficiency ratios, and the
    effectual MAC count (MACs actually needed after skipping).
    """
    _, cmap = spatial_maps(p)
    kept_spatial = int(cmap.sum())
    total_spatial = p.m * p.ks * p.ks
    d_o = (total_spatial - kept_spatial) * p.oc
    p_outs = p.m * p.n
    # Paper convention (§III-A2 example): F_outs counts the *uncropped*
    # col2im buffer a naive implementation must hold (72/32 = 2.25x for
    # Fig. 2); with crop-skipping only the final cropped outputs remain
    # (72/8 = 9x for Fig. 2).
    fh = p.stride * (p.ih - 1) + p.ks
    fw = p.stride * (p.iw - 1) + p.ks
    f_outs_full = p.oc * fh * fw
    f_outs = p.oc * p.oh * p.ow
    return {
        "D_o": d_o,
        "D_r": d_o / p_outs,
        "P_outs": p_outs,
        "F_outs": f_outs_full,
        "F_outs_cropped": f_outs,
        "buffer_saving_no_skip": p_outs / f_outs_full,
        "buffer_saving_with_skip": p_outs / f_outs,
        "effectual_macs": kept_spatial * p.oc * p.ic,
        "total_macs": p.macs,
        "effectual_fraction": kept_spatial / total_spatial,
    }


def i_end_row(p: TConvProblem) -> np.ndarray:
    """Alg. 1's ``i_end_row``: last input row needed for each output row.

    Output row ``oh`` receives contributions from input rows ``ih`` with
    ``oh = S*ih - ct + kh`` for some ``kh in [0, Ks)`` =>
    ``ih in [ceil((oh + ct - Ks + 1)/S), floor((oh + ct)/S)]`` (clipped).
    """
    ct, _ = crop_offsets(p.ks, p.stride, p.padding)
    ohs = np.arange(p.oh)
    last = np.minimum((ohs + ct) // p.stride, p.ih - 1)
    return last.astype(np.int32)


def i_start_row(p: TConvProblem) -> np.ndarray:
    ct, _ = crop_offsets(p.ks, p.stride, p.padding)
    ohs = np.arange(p.oh)
    first = np.maximum(-(-(ohs + ct - p.ks + 1) // p.stride), 0)  # ceil div
    return first.astype(np.int32)


def rows_slab(p: TConvProblem, oh0: int, block_oh: int) -> Tuple[int, int]:
    """Contiguous input-row range [start, end) feeding output rows
    [oh0, oh0+block_oh) — the tiled generalization of ``i_end_row``."""
    oh1 = min(oh0 + block_oh, p.oh) - 1
    start = int(i_start_row(p)[oh0])
    end = int(i_end_row(p)[oh1]) + 1
    return start, end


def max_slab_rows(p: TConvProblem, block_oh: int) -> int:
    """Static upper bound on slab height for any aligned output row block."""
    best = 0
    for oh0 in range(0, p.oh, block_oh):
        s, e = rows_slab(p, oh0, block_oh)
        best = max(best, e - s)
    return best
