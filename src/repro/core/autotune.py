"""Empirical block-size autotuner for the fused MM2IM Pallas kernel.

The paper picks its tile geometry per TCONV configuration with Alg. 1 and
validates the choice over 261 problem configs; the seed port instead ran
one ``plan_blocks`` heuristic everywhere.  This module closes that gap
with a measure-don't-guess loop:

  1. **enumerate** — every legal ``(block_oh, block_oc, grid_order)`` under
     the VMEM budget (``core/tiling.candidate_plans``);
  2. **prune** — rank candidates by the analytical roofline
     (``core/perf_model.mm2im_estimate``) and keep the top few, always
     including the heuristic default;
  3. **measure** — wall-time the survivors through the real kernel
     (``mm2im_pallas.mm2im_tconv`` — the Pallas TPU kernel on TPU,
     interpret mode elsewhere);
  4. **persist** — store the winner in an on-disk JSON cache keyed by
     ``(TConvProblem, dtype, hw, batch)`` so later processes skip straight
     to the tuned plan.

The returned :class:`~repro.kernels.registry.Plan` is accepted verbatim by
``ops.tconv(..., plan=...)``, ``layers.common.tconv_layer`` and the GAN
models' ``plans=`` mapping.

Cache location: ``$REPRO_AUTOTUNE_CACHE`` if set, else
``~/.cache/repro/autotune_cache.json``.
"""

from __future__ import annotations

import dataclasses
import json
import os
import time
from pathlib import Path
from typing import Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import tiling
from repro.core.maps import TConvProblem
from repro.core.perf_model import HW, V5E, mm2im_estimate
from repro.kernels.mm2im_pallas import mm2im_tconv
from repro.kernels.registry import Plan

CACHE_ENV = "REPRO_AUTOTUNE_CACHE"
DEFAULT_CACHE_PATH = "~/.cache/repro/autotune_cache.json"
_CACHE_VERSION = 1


def default_cache_path() -> Path:
    return Path(os.environ.get(CACHE_ENV, DEFAULT_CACHE_PATH)).expanduser()


def cache_key(p: TConvProblem, *, dtype=jnp.float32, hw: HW = V5E,
              batch: int = 1) -> str:
    """Stable, human-readable cache key for one tuning instance."""
    dt = jnp.dtype(dtype).name
    return (f"tconv:ih{p.ih}:iw{p.iw}:ic{p.ic}:ks{p.ks}:oc{p.oc}"
            f":s{p.stride}:{p.padding}|{dt}|{hw.name}|b{batch}")


class PlanCache:
    """On-disk JSON store of tuned plans; safe to share across processes.

    The file holds ``{"version": 1, "entries": {key: {"plan": {...},
    "us": ..., ...}}}``.  Writes are atomic (tmp file + ``os.replace``);
    a corrupt or version-mismatched file is treated as empty rather than
    raising, so a bad cache can never break inference.
    """

    def __init__(self, path: Union[str, Path, None] = None):
        self.path = Path(path).expanduser() if path else default_cache_path()
        self._entries: Optional[dict] = None

    # -- storage ------------------------------------------------------------

    def _load(self) -> dict:
        if self._entries is None:
            try:
                raw = json.loads(self.path.read_text())
                if raw.get("version") == _CACHE_VERSION:
                    self._entries = dict(raw.get("entries", {}))
                else:
                    self._entries = {}
            except (OSError, ValueError):
                self._entries = {}
        return self._entries

    def _save(self) -> None:
        self.path.parent.mkdir(parents=True, exist_ok=True)
        tmp = self.path.with_name(self.path.name + f".tmp{os.getpid()}")
        tmp.write_text(json.dumps(
            {"version": _CACHE_VERSION, "entries": self._load()}, indent=1,
            sort_keys=True))
        os.replace(tmp, self.path)

    # -- API ----------------------------------------------------------------

    def get(self, key: str) -> Optional[Plan]:
        e = self._load().get(key)
        return Plan.from_json(e["plan"]) if e else None

    def get_entry(self, key: str) -> Optional[dict]:
        e = self._load().get(key)
        return dict(e) if e else None

    def put(self, key: str, plan: Plan, meta: Optional[dict] = None) -> None:
        entry = {"plan": plan.to_json(), "created": time.time()}
        if meta:
            entry.update(meta)
        self._load()[key] = entry
        self._save()

    def keys(self) -> Sequence[str]:
        return tuple(self._load())

    def __len__(self) -> int:
        return len(self._load())


@dataclasses.dataclass(frozen=True)
class TuningResult:
    """What :func:`autotune_result` learned about one problem."""

    key: str
    plan: Plan
    us: float                 # measured time of the winning plan
    default_plan: Plan
    default_us: float         # measured time of the heuristic default
    n_candidates: int         # legal plans enumerated
    n_measured: int           # survivors actually timed
    from_cache: bool

    @property
    def speedup_vs_default(self) -> float:
        return self.default_us / max(self.us, 1e-9)


def _rand_inputs(p: TConvProblem, batch: int, dtype):
    rng = np.random.default_rng(0)
    if jnp.issubdtype(jnp.dtype(dtype), jnp.integer):
        x = rng.integers(-128, 128, (batch, p.ih, p.iw, p.ic)).astype(dtype)
        w = rng.integers(-128, 128, (p.ks, p.ks, p.oc, p.ic)).astype(dtype)
    else:
        x = rng.standard_normal((batch, p.ih, p.iw, p.ic)).astype(dtype)
        w = (rng.standard_normal((p.ks, p.ks, p.oc, p.ic)) * 0.1).astype(dtype)
    return jnp.asarray(x), jnp.asarray(w)


def measure_plan(p: TConvProblem, plan: Plan, *, batch: int = 1,
                 dtype=jnp.float32, repeats: int = 3,
                 warmup: int = 1) -> float:
    """Median wall-time (us) of the kernel under an explicit plan."""
    x, w = _rand_inputs(p, batch, dtype)

    fn = jax.jit(lambda xx, ww: mm2im_tconv(
        xx, ww, stride=p.stride, padding=p.padding,
        block_oh=plan.block_oh, block_oc=plan.block_oc,
        grid_order=plan.grid_order))
    for _ in range(warmup):
        jax.block_until_ready(fn(x, w))
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(x, w))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts) * 1e6)


def _bits(dtype) -> int:
    return jnp.dtype(dtype).itemsize * 8


def default_plan(p: TConvProblem, *, batch: int = 1, dtype=jnp.float32,
                 hw: HW = V5E) -> Plan:
    """The seed heuristic's choice, as an explicit Plan."""
    tp = tiling.plan(p, batch=batch, bits=_bits(dtype), hw=hw)
    return Plan(tp.block_oh, tp.block_oc, tp.grid_order)


def autotune_result(
    p: TConvProblem,
    *,
    batch: int = 1,
    dtype=jnp.float32,
    hw: HW = V5E,
    cache: Union[PlanCache, str, Path, None] = None,
    max_measure: int = 6,
    repeats: int = 3,
    force: bool = False,
) -> TuningResult:
    """Enumerate -> prune -> measure -> persist; full diagnostics returned.

    ``cache`` may be a :class:`PlanCache`, a path, or None (default
    location).  ``force=True`` re-measures even on a cache hit.
    """
    if not isinstance(cache, PlanCache):
        cache = PlanCache(cache)
    key = cache_key(p, dtype=dtype, hw=hw, batch=batch)
    dflt = default_plan(p, batch=batch, dtype=dtype, hw=hw)

    if not force:
        hit = cache.get_entry(key)
        if hit is not None:
            return TuningResult(
                key=key, plan=Plan.from_json(hit["plan"]),
                us=float(hit.get("us", 0.0)), default_plan=dflt,
                default_us=float(hit.get("default_us", 0.0)),
                n_candidates=int(hit.get("n_candidates", 0)),
                n_measured=0, from_cache=True)

    bits = _bits(dtype)
    cands = tiling.candidate_plans(p, batch=batch, bits=bits, hw=hw)
    plans = [Plan(c.block_oh, c.block_oc, c.grid_order) for c in cands]
    if dflt not in plans:
        plans.append(dflt)

    # Prune by the analytical roofline; keep the default in the field so the
    # measurement is always at least a default-vs-challenger comparison.
    def score(pl: Plan) -> float:
        return mm2im_estimate(p, batch, block_oh=pl.block_oh,
                              block_oc=pl.block_oc, bits=bits,
                              grid_order=pl.grid_order, hw=hw).t_overlapped

    ranked = sorted(plans, key=score)
    survivors = ranked[:max(max_measure - 1, 1)]
    if dflt not in survivors:
        survivors.append(dflt)

    timed = {pl: measure_plan(p, pl, batch=batch, dtype=dtype,
                              repeats=repeats) for pl in survivors}
    winner = min(timed, key=timed.get)
    result = TuningResult(
        key=key, plan=winner, us=timed[winner], default_plan=dflt,
        default_us=timed[dflt], n_candidates=len(plans),
        n_measured=len(survivors), from_cache=False)
    cache.put(key, winner, meta={
        "us": result.us, "default_us": result.default_us,
        "default_plan": dflt.to_json(), "n_candidates": result.n_candidates,
        "backend": jax.default_backend(),
    })
    return result


def autotune(p: TConvProblem, **kw) -> Plan:
    """Tuned :class:`Plan` for ``p`` (cache-backed). See autotune_result."""
    return autotune_result(p, **kw).plan
