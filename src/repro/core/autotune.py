"""Empirical block-size + kernel-variant autotuner for the MM2IM kernels.

The paper picks its tile geometry per TCONV configuration with Alg. 1 and
validates the choice over 261 problem configs; the seed port instead ran
one ``plan_blocks`` heuristic everywhere.  This module closes that gap
with a measure-don't-guess loop:

  1. **enumerate** — every legal ``(method, block_oh, block_oc,
     grid_order)`` under the VMEM budget
     (``core/tiling.candidate_plans``) — ``method`` picks between the
     single-buffered kernel and the double-buffered DMA pipeline
     (``kernels/mm2im_db_pallas``), which are bit-identical, so the choice
     is purely empirical;
  2. **prune** — rank candidates by the cost model and keep the top few,
     always including the heuristic default.  When a shipped calibration
     exists for this backend (``core/model_fit.py`` — coefficients fit
     from persisted sweep measurements), ranking uses the fitted
     microsecond predictions and fewer survivors are timed; otherwise
     the datasheet roofline (``core/perf_model.mm2im_estimate`` /
     ``mm2im_db_estimate``, including the overlapped-copy term) orders
     the field;
  3. **measure** — wall-time the survivors **through the kernel registry**
     (``kernels.ops.run_registered`` — Pallas TPU kernels on TPU,
     interpret mode elsewhere), with the same epilogue-splitting contract
     dispatch uses, so the timed program is the program inference runs;
  4. **persist** — store the winner in an on-disk JSON cache keyed by
     ``(TConvProblem, dtype, hw, batch)`` so later processes skip straight
     to the tuned plan.

The returned :class:`~repro.kernels.registry.Plan` is accepted verbatim by
``ops.tconv(..., plan=...)``, ``layers.common.tconv_layer`` and the GAN
models' ``plans=`` mapping — and, because ``ops.tconv`` consults this
cache automatically at trace time (:func:`cached_plan`), a tuned problem
needs **no** explicit ``plans=`` threading at all: tune once, every later
process with the same cache hits the tuned plan.  See docs/AUTOTUNER.md
for the file format, the key schema and the consumption precedence.

Tuning a third-party registry variant needs **no wiring here**: register
the kernel with ``supports_plan=True`` (``kernels/registry.register`` —
see that module's docstring) and ``core/tiling.candidate_plans``
enumerates it, this module measures it through the registry (both f32 and
int8 — specs without native int8 are timed through the dispatcher's
dequant->requant fallback, the program they would actually serve), and
tuned plans carry ``Plan.method`` naming the variant so both ``ops.tconv``
and ``ops.tconv_int8`` dispatch back to it.  Variants with a bespoke
roofline can extend :data:`METHOD_ESTIMATORS`; unknown methods rank with
the single-buffered estimate.

Cache location: ``$REPRO_AUTOTUNE_CACHE`` if set, else
``~/.cache/repro/autotune_cache.json``.  Below the user cache sits the
read-only **shipped plan table** tier (``core/plan_table.py`` — tables
committed under ``src/repro/data/plans/`` and produced by
``tools/tune_sweep.py``), so a fresh checkout starts from the full-sweep
tuning shipped with the package; full precedence is ``plan=`` > user
cache > shipped table > heuristic.
"""

from __future__ import annotations

import contextlib
import dataclasses
import json
import math
import os
import time
import warnings
from pathlib import Path
from typing import Iterable, Optional, Sequence, Tuple, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import model_fit, tiling
from repro.core.epilogue import Epilogue
from repro.core.maps import TConvProblem
from repro.core.perf_model import (HW, V5E, mm2im_db_estimate,
                                   mm2im_estimate, mm2im_ks_estimate,
                                   mm2im_og_estimate)
from repro.kernels import ops as kernel_ops
from repro.kernels.registry import Plan

CACHE_ENV = "REPRO_AUTOTUNE_CACHE"
DEFAULT_CACHE_PATH = "~/.cache/repro/autotune_cache.json"
# Deliberately still 1 although plans now serialize the v2 fold_batch
# field: bumping would make every existing user cache read as empty
# (_read_disk discards version mismatches).  A pre-fold reader sharing a
# new cache ignores the unknown field and runs the tuned geometry
# unfolded — bit-identical results, possibly suboptimal speed — which is
# the cheaper failure than discarding all prior tuning.  The *shipped
# tables* (immutable release artifacts) do gate the field via their own
# version bump (core/plan_table.py).
_CACHE_VERSION = 1

# method name -> roofline estimator used by the pruning stage.  Methods
# without an entry (third-party variants) rank with the single-buffered
# estimate — measurement, not the model, decides the winner anyway.
# Estimators take (p, batch, *, block_oh, block_oc, bits, grid_order, hw,
# fold_batch) — the plan-v2 ``fold_batch`` kwarg is part of the contract
# since candidates are ranked folded vs grid-batch a priori.
METHOD_ESTIMATORS = {
    "mm2im": mm2im_estimate,
    "mm2im_db": mm2im_db_estimate,
    "mm2im_ks": mm2im_ks_estimate,
    "mm2im_og": mm2im_og_estimate,
}


def default_cache_path() -> Path:
    return Path(os.environ.get(CACHE_ENV, DEFAULT_CACHE_PATH)).expanduser()


def cache_key(p: TConvProblem, *, dtype=jnp.float32, hw: HW = V5E,
              batch: int = 1) -> str:
    """Stable, human-readable cache key for one tuning instance."""
    dt = jnp.dtype(dtype).name
    return (f"tconv:ih{p.ih}:iw{p.iw}:ic{p.ic}:ks{p.ks}:oc{p.oc}"
            f":s{p.stride}:{p.padding}|{dt}|{hw.name}|b{batch}")


@contextlib.contextmanager
def _file_lock(path: Path):
    """Advisory lock serializing a read-merge-replace window on ``path``.

    Best effort: POSIX ``flock`` on a ``.lock`` sidecar; a no-op where
    ``fcntl`` is unavailable (non-POSIX), falling back to atomic-replace
    semantics only.
    """
    try:
        import fcntl
    except ImportError:  # non-POSIX: atomic os.replace is all we have
        yield
        return
    with open(path.with_name(path.name + ".lock"), "w") as f:
        fcntl.flock(f, fcntl.LOCK_EX)
        try:
            yield
        finally:
            fcntl.flock(f, fcntl.LOCK_UN)


# Paths already warned about as corrupt — one UserWarning per file per
# process, not one per lookup.  Cleared by reset_shared_caches() (tests).
_WARNED_CORRUPT: set = set()


class PlanCache:
    """On-disk JSON store of tuned plans; safe to share across processes.

    The file holds ``{"version": 1, "entries": {key: {"plan": {...},
    "us": ..., ...}}}``.  Writes are atomic (tmp file + ``os.replace``)
    and merge with the current on-disk entries under an advisory file
    lock, so concurrent tuners sharing one cache lose no keys.  A bad
    cache can never break inference: a missing or version-mismatched file
    reads as empty, and a file that does not parse at all — truncated
    write, disk corruption, stray hand-edit — is **quarantined** to
    ``<path>.corrupt`` with a one-shot ``UserWarning`` naming the file,
    rather than being silently treated as empty forever (the old
    behavior, which hid that all tuned plans had quietly vanished).
    """

    def __init__(self, path: Union[str, Path, None] = None):
        self.path = Path(path).expanduser() if path else default_cache_path()
        self._entries: Optional[dict] = None
        self._loaded_mtime: Optional[float] = None

    # -- storage ------------------------------------------------------------

    def _mtime(self) -> Optional[float]:
        try:
            return self.path.stat().st_mtime_ns
        except OSError:
            return None

    def _read_disk(self) -> dict:
        """Fresh parse of the on-disk entries — no memo, no mtime check.

        Three distinct empty-read cases, deliberately told apart:

        * missing file (or unreadable: permissions) — the normal first-run
          state, silently empty;
        * parses but ``version`` mismatches — a cache written by a
          different schema generation; silently empty by design (see the
          ``_CACHE_VERSION`` note above — the file is *valid*, just not
          ours to consume);
        * does not parse as a JSON object with object ``entries`` —
          corruption.  Quarantined via :meth:`_quarantine` so the bad
          bytes stop shadowing the cache path (the next ``_save`` starts
          a fresh cache) and the operator is warned once instead of
          every tuned plan silently disappearing.
        """
        try:
            text = self.path.read_text()
        except OSError:  # missing (first run) or unreadable: empty cache
            return {}
        try:
            raw = json.loads(text)
            if not isinstance(raw, dict):
                raise ValueError(
                    f"top-level JSON is {type(raw).__name__}, not an object")
        except ValueError as err:
            self._quarantine(err)
            return {}
        if raw.get("version") != _CACHE_VERSION:
            return {}
        entries = raw.get("entries", {})
        if not isinstance(entries, dict):
            self._quarantine(ValueError(
                f"'entries' is {type(entries).__name__}, not an object"))
            return {}
        return dict(entries)

    def _quarantine(self, err: Exception) -> None:
        """Move a corrupt cache aside to ``<path>.corrupt`` and warn once.

        ``os.replace`` keeps the bad bytes for post-mortem (restore the
        file after fixing it, or re-run ``tools/tune_sweep.py``) while
        clearing the cache path for fresh writes.  The warning is one-shot
        per path per process so a hot lookup path does not spam.
        """
        dest = self.path.with_name(self.path.name + ".corrupt")
        try:
            os.replace(self.path, dest)
            action = f"quarantined to {dest}"
        except OSError as mv_err:  # read-only fs etc.: warn anyway
            action = f"could not quarantine to {dest} ({mv_err})"
        key = str(self.path)
        if key not in _WARNED_CORRUPT:
            _WARNED_CORRUPT.add(key)
            warnings.warn(
                f"plan cache {self.path} is corrupt ({err}); {action}. "
                "Tuned plans from it are unavailable — restore the file "
                "or re-run tools/tune_sweep.py to regenerate.",
                UserWarning, stacklevel=3)

    def _load(self) -> dict:
        # Re-read when the file changed on disk (another PlanCache instance
        # or another process tuned since) — one stat() per lookup, so the
        # long-lived shared_cache() instance behind automatic consumption
        # sees same-process tune-then-train writes too.
        mtime = self._mtime()
        if self._entries is None or mtime != self._loaded_mtime:
            self._loaded_mtime = mtime
            self._entries = self._read_disk()
        return self._entries

    def _save(self, dirty: dict) -> None:
        # Merge only the keys *this write actually changed* over the
        # current on-disk entries: another process may have tuned other
        # keys (or re-tuned ones we merely hold memoized) between our last
        # _load() and now, and replaying our whole memo would clobber
        # them.  Last writer wins per key, not per file.  The advisory
        # lock serializes the read-merge-replace window itself (two
        # unserialized merges could each miss the other's key); os.replace
        # additionally keeps the swap atomic for lock-less readers and
        # non-POSIX writers.
        self.path.parent.mkdir(parents=True, exist_ok=True)
        with _file_lock(self.path):
            merged = self._read_disk()
            merged.update(dirty)
            self._entries = merged
            tmp = self.path.with_name(self.path.name + f".tmp{os.getpid()}")
            tmp.write_text(json.dumps(
                {"version": _CACHE_VERSION, "entries": merged}, indent=1,
                sort_keys=True))
            # Record the *tmp* file's mtime (os.replace preserves it as
            # the destination's): statting self.path after the replace
            # would race a concurrent writer landing in between,
            # permanently memoizing our entries under *their* mtime and
            # hiding their keys.
            tmp_mtime = tmp.stat().st_mtime_ns
            os.replace(tmp, self.path)
            self._loaded_mtime = tmp_mtime

    # -- API ----------------------------------------------------------------

    def get(self, key: str) -> Optional[Plan]:
        e = self._load().get(key)
        return Plan.from_json(e["plan"]) if e else None

    def get_entry(self, key: str) -> Optional[dict]:
        e = self._load().get(key)
        return dict(e) if e else None

    def put(self, key: str, plan: Plan, meta: Optional[dict] = None) -> None:
        entry = {"plan": plan.to_json(), "created": time.time()}
        if meta:
            entry.update(meta)
        self._save({key: entry})

    def keys(self) -> Sequence[str]:
        return tuple(self._load())

    def __len__(self) -> int:
        return len(self._load())


@dataclasses.dataclass(frozen=True)
class TuningResult:
    """What :func:`autotune_result` learned about one problem."""

    key: str
    plan: Plan
    us: float                 # measured time of the winning plan
    default_plan: Plan
    default_us: float         # measured time of the heuristic default
    n_candidates: int         # legal plans enumerated
    n_measured: int           # survivors actually timed
    from_cache: bool

    @property
    def speedup_vs_default(self) -> float:
        """Tuned-vs-heuristic ratio; NaN when either time is unknown.

        Cache-hit results replayed from an entry that never recorded
        timings (e.g. imported from a shipped table) have ``us`` /
        ``default_us`` of NaN — reporting 0.0 here would read as a 0x
        slowdown, so "unknown" stays unknown.
        """
        if math.isnan(self.us) or math.isnan(self.default_us):
            return float("nan")
        return self.default_us / max(self.us, 1e-9)


def _rand_inputs(p: TConvProblem, batch: int, dtype):
    rng = np.random.default_rng(0)
    if jnp.issubdtype(jnp.dtype(dtype), jnp.integer):
        x = rng.integers(-128, 128, (batch, p.ih, p.iw, p.ic)).astype(dtype)
        w = rng.integers(-128, 128, (p.ks, p.ks, p.oc, p.ic)).astype(dtype)
    else:
        x = rng.standard_normal((batch, p.ih, p.iw, p.ic)).astype(dtype)
        w = (rng.standard_normal((p.ks, p.ks, p.oc, p.ic)) * 0.1).astype(dtype)
    return jnp.asarray(x), jnp.asarray(w)


def measure_epilogue(p: TConvProblem, dtype) -> tuple:
    """Representative ``(bias, out_scale)`` for timing one candidate.

    Integer dtypes get a per-tensor requant scale and an int32 bias so the
    measured program includes the PPU epilogue (int32 accum -> requant ->
    int8 store) that ``ops.tconv_int8`` will actually run; without them
    the tuner would rank int8 plans on an int32-output kernel — a
    different program with different store traffic.  Float dtypes keep
    the plain no-epilogue forward (bias/activation fusion costs are
    epilogue-invariant across plans there).
    """
    if jnp.issubdtype(jnp.dtype(dtype), jnp.integer):
        rng = np.random.default_rng(1)
        bias = jnp.asarray(rng.integers(-8, 8, (p.oc,)), jnp.int32)
        return bias, 0.05
    return None, None


def measure_plan(p: TConvProblem, plan: Plan, *, batch: int = 1,
                 dtype=jnp.float32, repeats: int = 3,
                 warmup: int = 1) -> float:
    """Median wall-time (us) of the plan's kernel variant under the plan.

    ``plan.method`` names the registered method to time (``None`` means
    the single-buffered default); the candidate runs through the registry
    itself (``kernels.ops.run_registered``) with the dispatcher's
    epilogue-splitting contract, so any registered variant is measurable
    with zero wiring and the timed program matches what dispatch executes
    — including the dequant->requant fallback for variants without native
    int8.  Integer dtypes are timed with the requant epilogue attached
    (:func:`measure_epilogue`).
    """
    x, w = _rand_inputs(p, batch, dtype)
    method = plan.method or "mm2im"
    bias, out_scale = measure_epilogue(p, dtype)
    ep = Epilogue(bias=bias, out_scale=out_scale)
    # Strip the method (it is dispatched explicitly above) but keep the
    # fold_batch knob — a folded candidate must be timed folded.
    geom = Plan(plan.block_oh, plan.block_oc, plan.grid_order,
                fold_batch=plan.fold_batch)

    fn = jax.jit(lambda xx, ww: kernel_ops.run_registered(
        method, xx, ww, stride=p.stride, padding=p.padding, epilogue=ep,
        plan=geom))
    for _ in range(warmup):
        jax.block_until_ready(fn(x, w))
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(x, w))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts) * 1e6)


def _bits(dtype) -> int:
    return jnp.dtype(dtype).itemsize * 8


def default_plan(p: TConvProblem, *, batch: int = 1, dtype=jnp.float32,
                 hw: HW = V5E) -> Plan:
    """The seed heuristic's choice, as an explicit Plan."""
    tp = tiling.plan(p, batch=batch, bits=_bits(dtype), hw=hw)
    return Plan(tp.block_oh, tp.block_oc, tp.grid_order, tp.method,
                tp.fold_batch)


def autotune_result(
    p: TConvProblem,
    *,
    batch: int = 1,
    dtype=jnp.float32,
    hw: HW = V5E,
    cache: Union[PlanCache, str, Path, None] = None,
    max_measure: Optional[int] = None,
    repeats: int = 3,
    force: bool = False,
    fit="auto",
) -> TuningResult:
    """Enumerate -> prune -> measure -> persist; full diagnostics returned.

    ``cache`` may be a :class:`PlanCache`, a path, or None (default
    location).  ``force=True`` re-measures even on a cache hit.

    ``fit`` selects the pruning model: ``"auto"`` (default) uses the
    shipped per-backend calibration (``core/model_fit.shipped_fit``) when
    one exists, an explicit :class:`~repro.core.model_fit.FittedHW` uses
    that, and None forces the uncalibrated datasheet roofline.
    ``max_measure=None`` adapts to the model's trustworthiness: 4 timed
    survivors under a calibration, 6 under the bare roofline — the whole
    point of fitting coefficients from sweep measurements is that the
    a-priori ranking stops discarding true winners (the recorded sb/db
    and fold/grid misranks), so fewer candidates need wall-timing.
    """
    if not isinstance(cache, PlanCache):
        cache = PlanCache(cache)
    key = cache_key(p, dtype=dtype, hw=hw, batch=batch)
    dflt = default_plan(p, batch=batch, dtype=dtype, hw=hw)

    if not force:
        hit = cache.get_entry(key)
        if hit is not None:
            # Entries without timings (imported/hand-written) report NaN,
            # not 0.0 — speedup_vs_default then stays NaN instead of
            # masquerading as a 0x slowdown.
            return TuningResult(
                key=key, plan=Plan.from_json(hit["plan"]),
                us=float(hit.get("us", float("nan"))), default_plan=dflt,
                default_us=float(hit.get("default_us", float("nan"))),
                n_candidates=int(hit.get("n_candidates", 0)),
                n_measured=0, from_cache=True)

    bits = _bits(dtype)
    cands = tiling.candidate_plans(p, batch=batch, bits=bits, hw=hw)
    plans = [Plan(c.block_oh, c.block_oc, c.grid_order, c.method,
                  c.fold_batch)
             for c in cands]
    if dflt not in plans:
        plans.append(dflt)

    # Prune by the model — the measurement-calibrated one when available
    # (core/model_fit.py), the datasheet roofline otherwise (overlapped-
    # copy term + MXU tile quantization included, so single- vs
    # double-buffered and folded vs grid-batch candidates all rank
    # against each other a priori); keep the default in the field so the
    # measurement is always at least a default-vs-challenger comparison.
    if fit == "auto":
        fit = model_fit.shipped_fit()
    if max_measure is None:
        max_measure = 4 if fit is not None else 6

    def score(pl: Plan) -> float:
        if fit is not None:
            return fit.predict_us(p, pl, batch=batch, bits=bits, hw=hw)
        est = METHOD_ESTIMATORS.get(pl.method or "mm2im", mm2im_estimate)
        return est(p, batch, block_oh=pl.block_oh, block_oc=pl.block_oc,
                   bits=bits, grid_order=pl.grid_order, hw=hw,
                   fold_batch=pl.fold_batch).t_overlapped

    ranked = sorted(plans, key=score)
    # Up to max_measure survivors, always including the default: when the
    # model already ranks the default on top, the remaining slots go to
    # challengers instead of shrinking the field to a self-comparison.
    survivors = ranked[:max(max_measure, 1)]
    if dflt not in survivors:
        survivors = survivors[:max(max_measure - 1, 1)] + [dflt]

    timed = {pl: measure_plan(p, pl, batch=batch, dtype=dtype,
                              repeats=repeats) for pl in survivors}
    winner = min(timed, key=timed.get)
    result = TuningResult(
        key=key, plan=winner, us=timed[winner], default_plan=dflt,
        default_us=timed[dflt], n_candidates=len(plans),
        n_measured=len(survivors), from_cache=False)
    cache.put(key, winner, meta={
        "us": result.us, "default_us": result.default_us,
        "default_plan": dflt.to_json(), "n_candidates": result.n_candidates,
        # Measurement conditions, per entry — tools/tune_sweep.py --export
        # derives a table's provenance from these rather than trusting
        # whatever flags the (possibly later, possibly different) export
        # invocation happened to use.
        "backend": jax.default_backend(), "repeats": repeats,
        "jax": jax.__version__,
        # Whether a fitted calibration pruned the field (model_fit) —
        # distinguishes "ranked by measured coefficients" entries from
        # datasheet-roofline ones when auditing a cache.
        "calibrated": fit is not None,
    })
    return result


def autotune(p: TConvProblem, **kw) -> Plan:
    """Tuned :class:`Plan` for ``p`` (cache-backed). See autotune_result."""
    return autotune_result(p, **kw).plan


# ---------------------------------------------------------------------------
# Automatic consumption — the read-only fast path used by ops.tconv.
# ---------------------------------------------------------------------------

_SHARED_CACHES: dict = {}  # resolved path -> PlanCache (per-process memo)


def shared_cache(path: Union[str, Path, None] = None) -> PlanCache:
    """Process-wide :class:`PlanCache` for ``path`` (default location).

    ``ops.tconv`` consults the cache once per jit trace; sharing one
    instance per path means the JSON file is parsed once per process, not
    once per trace.
    """
    resolved = str(Path(path).expanduser() if path else default_cache_path())
    c = _SHARED_CACHES.get(resolved)
    if c is None:
        c = _SHARED_CACHES[resolved] = PlanCache(resolved)
    return c


def reset_shared_caches() -> None:
    """Drop the per-process cache memo (tests; after external cache edits)."""
    _SHARED_CACHES.clear()
    _WARNED_CORRUPT.clear()


# Tier names recorded by kernels.ops.consumed_plans() — who served a hit.
TIER_USER_CACHE = "user-cache"
TIER_SHIPPED = "shipped-table"


def lookup_plan(p: TConvProblem, *, dtype=jnp.float32, batch: int = 1,
                hw: HW = V5E,
                cache: Union[PlanCache, str, Path, None] = None
                ) -> Optional[Tuple[Plan, str]]:
    """Tuned ``(plan, tier)`` for ``p``, or None; never measures.

    This is the lookup behind automatic plan consumption (``ops.tconv``
    with no ``plan=``).  Precedence within the read path: the user's
    on-disk cache (:data:`TIER_USER_CACHE`) beats the shipped per-backend
    table (:data:`TIER_SHIPPED`, ``core/plan_table.py``); a miss in both
    returns None and the caller falls back to the ``plan_blocks``
    heuristic.  A pure read either way.

    Forward compatibility: an entry whose ``Plan.method`` names a kernel
    that is *not* in this checkout's registry (e.g. a table exported by a
    newer release with an extra family) is skipped with a warning and the
    lookup falls through to the next tier — a stale plan must degrade to
    the heuristic, never fail dispatch.
    """
    if not isinstance(cache, PlanCache):
        cache = shared_cache(cache)
    key = cache_key(p, dtype=dtype, hw=hw, batch=batch)
    plan = cache.get(key)
    if plan is not None:
        if _method_registered(plan):
            return plan, TIER_USER_CACHE
        warnings.warn(
            f"autotune cache entry {key!r} selects unregistered kernel "
            f"method {plan.method!r}; ignoring it (re-tune or upgrade to "
            f"a build that provides the method)", stacklevel=2)
    from repro.core.plan_table import shipped_table

    table = shipped_table()
    if table is not None:
        plan = table.get(key)
        if plan is not None:
            if _method_registered(plan):
                return plan, TIER_SHIPPED
            warnings.warn(
                f"shipped plan table entry {key!r} selects unregistered "
                f"kernel method {plan.method!r}; ignoring it (table "
                f"exported by a newer build?)", stacklevel=2)
    return None


def _method_registered(plan: Plan) -> bool:
    """True when the plan's kernel variant exists in this checkout."""
    from repro.kernels import registry as kernel_registry

    return not plan.method or plan.method in kernel_registry.names()


def cached_plan(p: TConvProblem, *, dtype=jnp.float32, batch: int = 1,
                hw: HW = V5E,
                cache: Union[PlanCache, str, Path, None] = None
                ) -> Optional[Plan]:
    """Tuned plan for ``p`` from any read tier (:func:`lookup_plan`)."""
    hit = lookup_plan(p, dtype=dtype, batch=batch, hw=hw, cache=cache)
    return hit[0] if hit else None


def autotune_sweep(
    problems: Iterable[TConvProblem],
    *,
    dtypes: Sequence = (jnp.float32, jnp.int8),
    batches: Sequence[int] = (1,),
    hw: HW = V5E,
    cache: Union[PlanCache, str, Path, None] = None,
    **kw,
) -> list:
    """Tune the cross product problems x dtypes x batches; return results.

    This is how the cache gets its int8 (the paper's precision) and
    batch>1 coverage so the GAN training/serve paths hit tuned plans out
    of the box — e.g.::

        autotune_sweep(gan.dcgan_tconv_problems(params).values(),
                       dtypes=(jnp.float32, jnp.int8), batches=(1, 8))

    Extra kwargs flow to :func:`autotune_result` (``max_measure``,
    ``repeats``, ``force``, ...).
    """
    if not isinstance(cache, PlanCache):
        cache = PlanCache(cache) if cache is not None else shared_cache()
    results = []
    for p in problems:
        for dtype in dtypes:
            for batch in batches:
                results.append(autotune_result(
                    p, batch=batch, dtype=dtype, hw=hw, cache=cache, **kw))
    return results
