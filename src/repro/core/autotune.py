"""Empirical block-size + kernel-variant autotuner for the MM2IM kernels.

The paper picks its tile geometry per TCONV configuration with Alg. 1 and
validates the choice over 261 problem configs; the seed port instead ran
one ``plan_blocks`` heuristic everywhere.  This module closes that gap
with a measure-don't-guess loop:

  1. **enumerate** — every legal ``(method, block_oh, block_oc,
     grid_order)`` under the VMEM budget
     (``core/tiling.candidate_plans``) — ``method`` picks between the
     single-buffered kernel and the double-buffered DMA pipeline
     (``kernels/mm2im_db_pallas``), which are bit-identical, so the choice
     is purely empirical;
  2. **prune** — rank candidates by the analytical roofline
     (``core/perf_model.mm2im_estimate`` / ``mm2im_db_estimate``,
     including the overlapped-copy term) and keep the top few, always
     including the heuristic default;
  3. **measure** — wall-time the survivors through the real kernels
     (:data:`KERNEL_RUNNERS` — Pallas TPU kernels on TPU, interpret mode
     elsewhere);
  4. **persist** — store the winner in an on-disk JSON cache keyed by
     ``(TConvProblem, dtype, hw, batch)`` so later processes skip straight
     to the tuned plan.

The returned :class:`~repro.kernels.registry.Plan` is accepted verbatim by
``ops.tconv(..., plan=...)``, ``layers.common.tconv_layer`` and the GAN
models' ``plans=`` mapping — and, because ``ops.tconv`` consults this
cache automatically at trace time (:func:`cached_plan`), a tuned problem
needs **no** explicit ``plans=`` threading at all: tune once, every later
process with the same cache hits the tuned plan.  See docs/AUTOTUNER.md
for the file format, the key schema and the consumption precedence.

Tuning a third-party registry variant: register the kernel
(``kernels/registry.register`` — see that module's docstring), add its
runner to :data:`KERNEL_RUNNERS` and, if ``core/tiling.candidate_plans``
should enumerate it, pass it in that function's ``methods=``.  Tuned plans
then carry ``Plan.method`` naming the variant and dispatch back to it.

Cache location: ``$REPRO_AUTOTUNE_CACHE`` if set, else
``~/.cache/repro/autotune_cache.json``.
"""

from __future__ import annotations

import dataclasses
import json
import os
import time
from pathlib import Path
from typing import Dict, Iterable, Optional, Sequence, Union

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import tiling
from repro.core.maps import TConvProblem
from repro.core.perf_model import HW, V5E, mm2im_db_estimate, mm2im_estimate
from repro.kernels.mm2im_db_pallas import mm2im_db_tconv
from repro.kernels.mm2im_pallas import mm2im_tconv
from repro.kernels.registry import Plan

CACHE_ENV = "REPRO_AUTOTUNE_CACHE"
DEFAULT_CACHE_PATH = "~/.cache/repro/autotune_cache.json"
_CACHE_VERSION = 1

# method name -> direct kernel entry point with the mm2im_tconv signature.
# The autotuner times these (registry dispatch adds jit/epilogue layers the
# measurement should not include); extend for third-party plan-capable
# variants.
KERNEL_RUNNERS: Dict[str, object] = {
    "mm2im": mm2im_tconv,
    "mm2im_db": mm2im_db_tconv,
}

# method name -> roofline estimator used by the pruning stage.
_METHOD_ESTIMATORS = {
    "mm2im": mm2im_estimate,
    "mm2im_db": mm2im_db_estimate,
}


def default_cache_path() -> Path:
    return Path(os.environ.get(CACHE_ENV, DEFAULT_CACHE_PATH)).expanduser()


def cache_key(p: TConvProblem, *, dtype=jnp.float32, hw: HW = V5E,
              batch: int = 1) -> str:
    """Stable, human-readable cache key for one tuning instance."""
    dt = jnp.dtype(dtype).name
    return (f"tconv:ih{p.ih}:iw{p.iw}:ic{p.ic}:ks{p.ks}:oc{p.oc}"
            f":s{p.stride}:{p.padding}|{dt}|{hw.name}|b{batch}")


class PlanCache:
    """On-disk JSON store of tuned plans; safe to share across processes.

    The file holds ``{"version": 1, "entries": {key: {"plan": {...},
    "us": ..., ...}}}``.  Writes are atomic (tmp file + ``os.replace``);
    a corrupt or version-mismatched file is treated as empty rather than
    raising, so a bad cache can never break inference.
    """

    def __init__(self, path: Union[str, Path, None] = None):
        self.path = Path(path).expanduser() if path else default_cache_path()
        self._entries: Optional[dict] = None
        self._loaded_mtime: Optional[float] = None

    # -- storage ------------------------------------------------------------

    def _mtime(self) -> Optional[float]:
        try:
            return self.path.stat().st_mtime_ns
        except OSError:
            return None

    def _load(self) -> dict:
        # Re-read when the file changed on disk (another PlanCache instance
        # or another process tuned since) — one stat() per lookup, so the
        # long-lived shared_cache() instance behind automatic consumption
        # sees same-process tune-then-train writes too.
        mtime = self._mtime()
        if self._entries is None or mtime != self._loaded_mtime:
            self._loaded_mtime = mtime
            try:
                raw = json.loads(self.path.read_text())
                if raw.get("version") == _CACHE_VERSION:
                    self._entries = dict(raw.get("entries", {}))
                else:
                    self._entries = {}
            except (OSError, ValueError):
                self._entries = {}
        return self._entries

    def _save(self) -> None:
        self.path.parent.mkdir(parents=True, exist_ok=True)
        tmp = self.path.with_name(self.path.name + f".tmp{os.getpid()}")
        tmp.write_text(json.dumps(
            {"version": _CACHE_VERSION, "entries": self._load()}, indent=1,
            sort_keys=True))
        os.replace(tmp, self.path)

    # -- API ----------------------------------------------------------------

    def get(self, key: str) -> Optional[Plan]:
        e = self._load().get(key)
        return Plan.from_json(e["plan"]) if e else None

    def get_entry(self, key: str) -> Optional[dict]:
        e = self._load().get(key)
        return dict(e) if e else None

    def put(self, key: str, plan: Plan, meta: Optional[dict] = None) -> None:
        entry = {"plan": plan.to_json(), "created": time.time()}
        if meta:
            entry.update(meta)
        self._load()[key] = entry
        self._save()

    def keys(self) -> Sequence[str]:
        return tuple(self._load())

    def __len__(self) -> int:
        return len(self._load())


@dataclasses.dataclass(frozen=True)
class TuningResult:
    """What :func:`autotune_result` learned about one problem."""

    key: str
    plan: Plan
    us: float                 # measured time of the winning plan
    default_plan: Plan
    default_us: float         # measured time of the heuristic default
    n_candidates: int         # legal plans enumerated
    n_measured: int           # survivors actually timed
    from_cache: bool

    @property
    def speedup_vs_default(self) -> float:
        return self.default_us / max(self.us, 1e-9)


def _rand_inputs(p: TConvProblem, batch: int, dtype):
    rng = np.random.default_rng(0)
    if jnp.issubdtype(jnp.dtype(dtype), jnp.integer):
        x = rng.integers(-128, 128, (batch, p.ih, p.iw, p.ic)).astype(dtype)
        w = rng.integers(-128, 128, (p.ks, p.ks, p.oc, p.ic)).astype(dtype)
    else:
        x = rng.standard_normal((batch, p.ih, p.iw, p.ic)).astype(dtype)
        w = (rng.standard_normal((p.ks, p.ks, p.oc, p.ic)) * 0.1).astype(dtype)
    return jnp.asarray(x), jnp.asarray(w)


def measure_plan(p: TConvProblem, plan: Plan, *, batch: int = 1,
                 dtype=jnp.float32, repeats: int = 3,
                 warmup: int = 1) -> float:
    """Median wall-time (us) of the plan's kernel variant under the plan.

    ``plan.method`` selects the entry point from :data:`KERNEL_RUNNERS`
    (``None`` means the single-buffered default).
    """
    x, w = _rand_inputs(p, batch, dtype)
    kernel = KERNEL_RUNNERS[plan.method or "mm2im"]

    fn = jax.jit(lambda xx, ww: kernel(
        xx, ww, stride=p.stride, padding=p.padding,
        block_oh=plan.block_oh, block_oc=plan.block_oc,
        grid_order=plan.grid_order))
    for _ in range(warmup):
        jax.block_until_ready(fn(x, w))
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(x, w))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts) * 1e6)


def _bits(dtype) -> int:
    return jnp.dtype(dtype).itemsize * 8


def default_plan(p: TConvProblem, *, batch: int = 1, dtype=jnp.float32,
                 hw: HW = V5E) -> Plan:
    """The seed heuristic's choice, as an explicit Plan."""
    tp = tiling.plan(p, batch=batch, bits=_bits(dtype), hw=hw)
    return Plan(tp.block_oh, tp.block_oc, tp.grid_order, tp.method)


def autotune_result(
    p: TConvProblem,
    *,
    batch: int = 1,
    dtype=jnp.float32,
    hw: HW = V5E,
    cache: Union[PlanCache, str, Path, None] = None,
    max_measure: int = 6,
    repeats: int = 3,
    force: bool = False,
) -> TuningResult:
    """Enumerate -> prune -> measure -> persist; full diagnostics returned.

    ``cache`` may be a :class:`PlanCache`, a path, or None (default
    location).  ``force=True`` re-measures even on a cache hit.
    """
    if not isinstance(cache, PlanCache):
        cache = PlanCache(cache)
    key = cache_key(p, dtype=dtype, hw=hw, batch=batch)
    dflt = default_plan(p, batch=batch, dtype=dtype, hw=hw)

    if not force:
        hit = cache.get_entry(key)
        if hit is not None:
            return TuningResult(
                key=key, plan=Plan.from_json(hit["plan"]),
                us=float(hit.get("us", 0.0)), default_plan=dflt,
                default_us=float(hit.get("default_us", 0.0)),
                n_candidates=int(hit.get("n_candidates", 0)),
                n_measured=0, from_cache=True)

    bits = _bits(dtype)
    cands = tiling.candidate_plans(p, batch=batch, bits=bits, hw=hw)
    plans = [Plan(c.block_oh, c.block_oc, c.grid_order, c.method)
             for c in cands]
    if dflt not in plans:
        plans.append(dflt)

    # Prune by the analytical roofline (overlapped-copy term included, so
    # single- and double-buffered candidates rank against each other); keep
    # the default in the field so the measurement is always at least a
    # default-vs-challenger comparison.
    def score(pl: Plan) -> float:
        est = _METHOD_ESTIMATORS[pl.method or "mm2im"]
        return est(p, batch, block_oh=pl.block_oh, block_oc=pl.block_oc,
                   bits=bits, grid_order=pl.grid_order, hw=hw).t_overlapped

    ranked = sorted(plans, key=score)
    survivors = ranked[:max(max_measure - 1, 1)]
    if dflt not in survivors:
        survivors.append(dflt)

    timed = {pl: measure_plan(p, pl, batch=batch, dtype=dtype,
                              repeats=repeats) for pl in survivors}
    winner = min(timed, key=timed.get)
    result = TuningResult(
        key=key, plan=winner, us=timed[winner], default_plan=dflt,
        default_us=timed[dflt], n_candidates=len(plans),
        n_measured=len(survivors), from_cache=False)
    cache.put(key, winner, meta={
        "us": result.us, "default_us": result.default_us,
        "default_plan": dflt.to_json(), "n_candidates": result.n_candidates,
        "backend": jax.default_backend(),
    })
    return result


def autotune(p: TConvProblem, **kw) -> Plan:
    """Tuned :class:`Plan` for ``p`` (cache-backed). See autotune_result."""
    return autotune_result(p, **kw).plan


# ---------------------------------------------------------------------------
# Automatic consumption — the read-only fast path used by ops.tconv.
# ---------------------------------------------------------------------------

_SHARED_CACHES: dict = {}  # resolved path -> PlanCache (per-process memo)


def shared_cache(path: Union[str, Path, None] = None) -> PlanCache:
    """Process-wide :class:`PlanCache` for ``path`` (default location).

    ``ops.tconv`` consults the cache once per jit trace; sharing one
    instance per path means the JSON file is parsed once per process, not
    once per trace.
    """
    resolved = str(Path(path).expanduser() if path else default_cache_path())
    c = _SHARED_CACHES.get(resolved)
    if c is None:
        c = _SHARED_CACHES[resolved] = PlanCache(resolved)
    return c


def reset_shared_caches() -> None:
    """Drop the per-process cache memo (tests; after external cache edits)."""
    _SHARED_CACHES.clear()


def cached_plan(p: TConvProblem, *, dtype=jnp.float32, batch: int = 1,
                hw: HW = V5E,
                cache: Union[PlanCache, str, Path, None] = None
                ) -> Optional[Plan]:
    """Tuned plan for ``p`` if the on-disk cache has one; never measures.

    This is the lookup behind automatic plan consumption
    (``ops.tconv`` with no ``plan=``): a pure read — a miss returns None
    and the caller falls back to the ``plan_blocks`` heuristic.
    """
    if not isinstance(cache, PlanCache):
        cache = shared_cache(cache)
    return cache.get(cache_key(p, dtype=dtype, hw=hw, batch=batch))


def autotune_sweep(
    problems: Iterable[TConvProblem],
    *,
    dtypes: Sequence = (jnp.float32, jnp.int8),
    batches: Sequence[int] = (1,),
    hw: HW = V5E,
    cache: Union[PlanCache, str, Path, None] = None,
    **kw,
) -> list:
    """Tune the cross product problems x dtypes x batches; return results.

    This is how the cache gets its int8 (the paper's precision) and
    batch>1 coverage so the GAN training/serve paths hit tuned plans out
    of the box — e.g.::

        autotune_sweep(gan.dcgan_tconv_problems(params).values(),
                       dtypes=(jnp.float32, jnp.int8), batches=(1, 8))

    Extra kwargs flow to :func:`autotune_result` (``max_measure``,
    ``repeats``, ``force``, ...).
    """
    if not isinstance(cache, PlanCache):
        cache = PlanCache(cache) if cache is not None else shared_cache()
    results = []
    for p in problems:
        for dtype in dtypes:
            for batch in batches:
                results.append(autotune_result(
                    p, batch=batch, dtype=dtype, hw=hw, cache=cache, **kw))
    return results
