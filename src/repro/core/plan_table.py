"""Shipped tuned-plan tables — read-only, backend-keyed, packaged with repro.

The autotuner's on-disk :class:`~repro.core.autotune.PlanCache` only ever
holds what one machine happened to tune; a fresh checkout runs the
``plan_blocks`` heuristic everywhere.  This module closes that gap: plan
tables produced by ``tools/tune_sweep.py`` (the full 261-config sweep
harness) are committed under ``src/repro/data/plans/`` and consulted as a
**third precedence tier** during automatic plan consumption
(docs/AUTOTUNER.md):

    explicit ``plan=``  >  user cache  >  shipped table  >  heuristic

Tables are keyed by JAX backend: ``shipped_table()`` loads
``<backend>.json`` for ``jax.default_backend()`` (``cpu.json``,
``tpu.json``, ...), so a TPU host never consumes interpret-mode timings
and vice versa.  The file format is the :class:`PlanCache` schema plus a
required ``provenance`` block recording how the table was produced::

    {
      "version": 2,
      "provenance": {"backend": "tpu", "jax": "0.4.37", "repeats": 5,
                     "created": 1754012345.0, "note": "full 261 sweep"},
      "entries": {"tconv:ih8:...|float32|tpu-v5e|b1": {"plan": {...}, ...}}
    }

Schema v2 adds the per-plan ``fold_batch`` field (batch folded into the
MatMul M-dimension); v1 tables still load leniently
(:data:`SUPPORTED_TABLE_VERSIONS`) with their plans read as unfolded, but
a v1 table *carrying* ``fold_batch`` fails validation — the field is
gated to version 2 so pre-fold readers never silently drop it.

Tables are **read-only**: nothing in the runtime ever writes one.  The
tune -> export -> commit workflow lives in ``tools/tune_sweep.py``; CI
schema-validates every committed table (:func:`validate_table_json`) and a
bad or missing table always degrades to the next tier — a shipped table
can never break inference.

``REPRO_PLAN_TABLE_DIR`` overrides the packaged directory (tests; site
deployments shipping their own tables).
"""

from __future__ import annotations

import json
import os
from pathlib import Path
from typing import Dict, List, Optional, Sequence, Tuple, Union

from repro.kernels.registry import Plan

TABLE_DIR_ENV = "REPRO_PLAN_TABLE_DIR"
#: Current table schema.  v2 adds the per-plan ``fold_batch`` field
#: (batch folded into the MatMul M-dimension — kernels/registry.Plan).
TABLE_VERSION = 2
#: Versions the loader accepts.  v1 tables (no ``fold_batch`` anywhere)
#: keep loading leniently so committed pre-fold tables and site tables
#: survive the schema bump; their plans read back as unfolded.
SUPPORTED_TABLE_VERSIONS = (1, 2)

#: provenance keys every shipped table must carry (tools/tune_sweep.py
#: --export writes them; validate_table_json enforces them).
REQUIRED_PROVENANCE = ("backend", "jax", "repeats", "created")


def table_dir() -> Path:
    """Directory holding the shipped ``<backend>.json`` tables.

    ``$REPRO_PLAN_TABLE_DIR`` wins; otherwise the packaged
    ``repro/data/plans/`` directory.  The repo is importable both as a
    plain source tree on ``PYTHONPATH`` and as an installed distribution,
    so we try ``importlib.resources`` first (wheel/zip safe) and fall back
    to the path relative to this file (namespace-package source tree).
    """
    env = os.environ.get(TABLE_DIR_ENV)
    if env:
        return Path(env).expanduser()
    try:
        from importlib.resources import files

        p = files("repro.data").joinpath("plans")
        # files() may return a non-filesystem Traversable in zipped
        # installs; all current deployments are directories, so resolve to
        # a real Path and let the fallback cover anything else.
        return Path(str(p))
    except Exception:
        return Path(__file__).resolve().parent.parent / "data" / "plans"


def available_backends(directory: Union[str, Path, None] = None
                       ) -> Tuple[str, ...]:
    """Backends with a shipped table present (``cpu``, ``tpu``, ...).

    Calibration records (``<backend>.fit.json`` — ``core/model_fit.py``)
    live in the same directory but are not plan tables; they are skipped.
    """
    d = Path(directory) if directory else table_dir()
    try:
        return tuple(sorted(f.stem for f in d.glob("*.json")
                            if not f.name.endswith(".fit.json")))
    except OSError:
        return ()


def validate_table_json(raw: object, *, source: str = "table") -> List[str]:
    """Schema-check one parsed table; returns problems (empty == valid).

    Enforced: the version tag (any of
    :data:`SUPPORTED_TABLE_VERSIONS` — v1 loads leniently), the
    :data:`REQUIRED_PROVENANCE` block, the ``tconv:...|dtype|hw|bN`` key
    shape, and that every entry's ``plan`` round-trips through
    :class:`~repro.kernels.registry.Plan` (positive blocks, known grid
    order).  The v2 ``fold_batch`` plan field is *gated*: a table claiming
    ``version: 1`` must not carry it (old readers would silently drop the
    fold and run a geometry the plan was never timed at).  Timing metadata
    (``us`` etc.) is optional but must be numeric when present.
    """
    errs: List[str] = []
    if not isinstance(raw, dict):
        return [f"{source}: top level must be an object, got {type(raw).__name__}"]
    version = raw.get("version")
    if version not in SUPPORTED_TABLE_VERSIONS:
        errs.append(f"{source}: version must be one of "
                    f"{SUPPORTED_TABLE_VERSIONS}, got {version!r}")
    prov = raw.get("provenance")
    if not isinstance(prov, dict):
        errs.append(f"{source}: missing 'provenance' object")
    else:
        for field in REQUIRED_PROVENANCE:
            if field not in prov:
                errs.append(f"{source}: provenance missing {field!r}")
    entries = raw.get("entries")
    if not isinstance(entries, dict):
        errs.append(f"{source}: missing 'entries' object")
        return errs
    for key, entry in entries.items():
        where = f"{source}: entries[{key!r}]"
        parts = key.split("|")
        if not key.startswith("tconv:") or len(parts) != 4 \
                or not parts[3].startswith("b"):
            errs.append(f"{where}: malformed cache key (want "
                        f"'tconv:...|dtype|hw|bN')")
        if not isinstance(entry, dict) or "plan" not in entry:
            errs.append(f"{where}: entry must be an object with a 'plan'")
            continue
        try:
            Plan.from_json(entry["plan"])
        except Exception as e:  # noqa: BLE001 — report, don't raise
            errs.append(f"{where}: bad plan {entry['plan']!r} ({e})")
        else:
            if version == 1:
                # The exporter writes the field into both plan dicts, so
                # the v1 gate must inspect both.
                for field in ("plan", "default_plan"):
                    if isinstance(entry.get(field), dict) \
                            and "fold_batch" in entry[field]:
                        errs.append(
                            f"{where}: {field!r} carries 'fold_batch', a "
                            f"schema-v2 field — stamp the table version 2 "
                            f"(tools/tune_sweep.py --export does)")
        for f in ("us", "default_us"):
            if f in entry and not isinstance(entry[f], (int, float)):
                errs.append(f"{where}: {f!r} must be numeric")
    return errs


class PlanTable:
    """One loaded, validated, immutable shipped-plan table.

    Read-side twin of :class:`~repro.core.autotune.PlanCache`: same
    ``get`` / ``get_entry`` / ``keys`` surface so the precedence chain in
    ``autotune.lookup_plan`` treats the tiers uniformly — but there is no
    ``put`` and nothing is ever written back.
    """

    def __init__(self, entries: Dict[str, dict], provenance: dict,
                 source: str = ""):
        self._entries = dict(entries)
        self.provenance = dict(provenance)
        self.source = source

    def get(self, key: str) -> Optional[Plan]:
        e = self._entries.get(key)
        return Plan.from_json(e["plan"]) if e else None

    def get_entry(self, key: str) -> Optional[dict]:
        e = self._entries.get(key)
        return dict(e) if e else None

    def keys(self) -> Sequence[str]:
        return tuple(self._entries)

    def __len__(self) -> int:
        return len(self._entries)

    def __repr__(self) -> str:
        return (f"PlanTable({self.source or '<memory>'}, "
                f"backend={self.provenance.get('backend')!r}, "
                f"{len(self)} entries)")


def load_table(backend: str, *, directory: Union[str, Path, None] = None,
               strict: bool = False) -> Optional[PlanTable]:
    """Parse + validate ``<backend>.json``; None when absent or invalid.

    ``strict=True`` raises ``ValueError`` with the validation report
    instead of degrading — that's the CI/tooling mode
    (``tools/tune_sweep.py --validate-tables``); the runtime always uses
    the lenient default so a bad table falls through to the heuristic.
    """
    d = Path(directory) if directory else table_dir()
    path = d / f"{backend}.json"
    try:
        raw = json.loads(path.read_text())
    except OSError:
        if strict:
            raise ValueError(f"no shipped table at {path}")
        return None
    except ValueError as e:
        if strict:
            raise ValueError(f"{path}: not valid JSON ({e})") from None
        return None
    errs = validate_table_json(raw, source=str(path))
    if errs:
        if strict:
            raise ValueError("invalid shipped plan table:\n  "
                             + "\n  ".join(errs))
        return None
    return PlanTable(raw["entries"], raw["provenance"], source=str(path))


_SHIPPED: dict = {}  # backend -> Optional[PlanTable] (per-process memo)


def shipped_table(backend: Optional[str] = None) -> Optional[PlanTable]:
    """The shipped table for ``backend`` (default: ``jax.default_backend()``).

    Memoized per process — shipped tables are immutable release artifacts,
    so unlike the user cache there is no mtime re-check.  Returns None
    when no table ships for this backend (most backends, until someone
    runs the sweep there).
    """
    if backend is None:
        import jax

        backend = jax.default_backend()
    if backend not in _SHIPPED:
        _SHIPPED[backend] = load_table(backend)
    return _SHIPPED[backend]


def reset_shipped_tables() -> None:
    """Drop the memo (tests; after pointing REPRO_PLAN_TABLE_DIR elsewhere)."""
    _SHIPPED.clear()
