"""Tiled MM2IM planning (paper Alg. 1) — the host-driver role.

Given a TCONV problem and a VMEM budget, produce the full tile plan the
Pallas kernel executes: output-row block (``block_oh = S*bi``), output
channel block (``block_oc`` — the ``filter_step`` / #PM analogue), the
input-row slab geometry (``i_end_row`` relation), grid order, and the
modeled VMEM footprint.  ``kernels/ops.py`` consumes this implicitly via
``plan_blocks``; benchmarks and tests consume the explicit plan.
"""

from __future__ import annotations

import dataclasses

from repro.core.maps import TConvProblem, rows_slab
from repro.core.perf_model import HW, V5E, mm2im_estimate
from repro.kernels.mm2im_pallas import plan_blocks
from repro.kernels.ref import crop_offsets


@dataclasses.dataclass(frozen=True)
class TilePlan:
    problem: TConvProblem
    block_oh: int
    block_oc: int
    n_slab: int
    n_row_blocks: int
    n_oc_blocks: int
    grid_order: str
    vmem_bytes: int
    halo_overhead: float  # recomputed-slab fraction vs ideal (dense-MXU cost)

    def describe(self) -> str:
        p = self.problem
        return (f"tconv({p.ih},{p.iw},{p.ic},{p.ks},{p.oc},{p.stride}) "
                f"block_oh={self.block_oh} block_oc={self.block_oc} "
                f"slab={self.n_slab} grid={self.grid_order} "
                f"vmem={self.vmem_bytes/2**20:.2f}MiB halo=+{self.halo_overhead:.0%}")


def plan(p: TConvProblem, *, batch: int = 1, bits: int = 8, hw: HW = V5E) -> TilePlan:
    ebytes = bits // 8
    block_oh, block_oc = plan_blocks(
        p.ih, p.iw, p.ic, p.ks, p.oc, p.stride, p.padding,
        vmem_budget=int(hw.vmem_bytes * 0.75), in_bytes=ebytes)
    s = p.stride
    bi = block_oh // s
    ct, _ = crop_offsets(p.ks, s, p.padding)
    delta = -(-max(p.ks - 1 - ct, 0) // s)
    eps = (ct - 1) // s
    n_slab = bi + delta + eps + 1
    n_j = -(-p.oh // block_oh)
    n_c = -(-p.oc // block_oc)
    ihp = (n_j - 1) * bi + n_slab
    ow_p = -(-p.ow // s) * s

    w_bytes = p.ic * p.ks**2 * n_c * block_oc * ebytes
    x_bytes = batch * ihp * p.iw * p.ic * ebytes
    grid_order = "cbj" if w_bytes > x_bytes else "bcj"

    vmem = (ihp * p.iw * p.ic * ebytes                      # resident input
            + p.ic * p.ks**2 * block_oc * ebytes            # weight block
            + 2 * n_slab * p.iw * p.ks**2 * block_oc * 4    # mm + acc dbl-buf
            + 2 * block_oh * ow_p * block_oc * 4)
    halo = (n_j * n_slab) / max(p.ih, 1) - 1.0
    return TilePlan(p, block_oh, block_oc, n_slab, n_j, n_c, grid_order,
                    vmem, max(halo, 0.0))


def slab_table(p: TConvProblem, block_oh: int) -> list[tuple[int, int]]:
    """Per-row-block (start, end) input slab ranges — Alg. 1's i_end_row."""
    return [rows_slab(p, oh0, block_oh) for oh0 in range(0, p.oh, block_oh)]
