"""Tiled MM2IM planning (paper Alg. 1) — the host-driver role.

Given a TCONV problem and a VMEM budget, produce the full tile plan the
Pallas kernel executes: output-row block (``block_oh = S*bi``), output
channel block (``block_oc`` — the ``filter_step`` / #PM analogue), the
input-row slab geometry (``i_end_row`` relation), grid order, and the
modeled VMEM footprint.  ``kernels/ops.py`` consumes this implicitly via
``plan_blocks``; benchmarks, tests and the autotuner
(``core/autotune.py``) consume the explicit plan: :func:`plan` accepts
explicit ``block_oh``/``block_oc``/``grid_order`` overrides and
:func:`candidate_plans` enumerates every legal tile geometry under the
budget for empirical tuning.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional

from repro.core.maps import TConvProblem, rows_slab
from repro.core.perf_model import HW, V5E, mm2im_estimate
from repro.kernels.mm2im_pallas import plan_blocks
from repro.kernels.ref import crop_offsets


@dataclasses.dataclass(frozen=True)
class TilePlan:
    problem: TConvProblem
    block_oh: int
    block_oc: int
    n_slab: int
    n_row_blocks: int
    n_oc_blocks: int
    grid_order: str
    vmem_bytes: int
    halo_overhead: float  # recomputed-slab fraction vs ideal (dense-MXU cost)
    method: str = "mm2im"  # 'mm2im' | 'mm2im_db' | 'mm2im_ks' | 'mm2im_og'
    fold_batch: bool = False  # plan v2: batch folded into the MatMul M-dim

    def describe(self) -> str:
        p = self.problem
        return (f"tconv({p.ih},{p.iw},{p.ic},{p.ks},{p.oc},{p.stride}) "
                f"[{self.method}{'+fold' if self.fold_batch else ''}] "
                f"block_oh={self.block_oh} block_oc={self.block_oc} "
                f"slab={self.n_slab} grid={self.grid_order} "
                f"vmem={self.vmem_bytes/2**20:.2f}MiB halo=+{self.halo_overhead:.0%}")


def _geometry(p: TConvProblem, block_oh: int):
    """Shared slab/grid geometry for a given output-row block."""
    s = p.stride
    bi = block_oh // s
    ct, _ = crop_offsets(p.ks, s, p.padding)
    delta = -(-max(p.ks - 1 - ct, 0) // s)
    eps = (ct - 1) // s
    n_slab = bi + delta + eps + 1
    n_j = -(-p.oh // block_oh)
    ihp = (n_j - 1) * bi + n_slab
    ow_p = -(-p.ow // s) * s
    return bi, n_slab, n_j, ihp, ow_p


def vmem_bytes(p: TConvProblem, block_oh: int, block_oc: int,
               *, bits: int = 8, method: str = "mm2im",
               batch: int = 1, fold_batch: bool = False) -> int:
    """Modeled VMEM footprint of one grid cell.

    ``'mm2im'`` keeps the whole padded input resident
    (``mm2im_pallas`` residency); ``'mm2im_db'`` holds only the two-slot
    slab + output scratch of the DMA pipeline (``mm2im_db_pallas``), which
    is what lets the double-buffered variant run blocks the single-buffered
    kernel cannot fit.

    ``'mm2im_ks'`` shares the whole-input residency but replaces the
    single ``(n_slab·Iw, Ks²·boc)`` product with the per-sub-kernel dense
    products of the segregated dataflow (each over only the slab rows its
    taps touch) plus the residue planes — strictly smaller MatMul scratch
    whenever the stride drops taps.

    ``'mm2im_og'`` also keeps the whole input resident but stages a
    *gathered* operand per residue class — ``(bi·Iw', Jh·Jw·Ic)`` input
    bytes for the widest sub-kernel (one class is staged at a time) —
    plus the S² residue planes it writes exactly once; there is no
    ``Ks²``-wide MatMul scratch and no accumulator re-read at all.

    ``fold_batch=True`` multiplies the batch-concatenated residencies by
    ``batch``: the folded single-buffered kernel holds the whole
    ``(B, Ihp, Iw, Ic)`` input block, the folded pipeline two
    ``(B, n_slab, Iw, Ic)`` slab slots, and both hold the ``B``-deep
    folded MatMul product and output block — this is the per-variant
    budget that gates ``fold_batch`` candidates in :func:`candidate_plans`.
    """
    ebytes = bits // 8
    bi, n_slab, _, ihp, ow_p = _geometry(p, block_oh)
    bmul = batch if fold_batch else 1
    if method == "mm2im_db":
        x_resident = 2 * bmul * n_slab * p.iw * p.ic * ebytes  # slab slots
    else:
        x_resident = bmul * ihp * p.iw * p.ic * ebytes         # whole input
    if method == "mm2im_ks":
        from repro.core.segregate import segregate  # local: avoid cycle

        seg = segregate(p.ks, p.stride, p.padding)
        mm_acc = (sum(bmul * (bi + sk.jh - 1) * p.iw * sk.taps
                      * block_oc * 4
                      for sk in seg.subkernels if sk.taps)
                  + bmul * block_oh * ow_p * block_oc * 4)     # planes
    elif method == "mm2im_og":
        from repro.core.segregate import segregate  # local: avoid cycle

        seg = segregate(p.ks, p.stride, p.padding)
        iw_p = ow_p // p.stride
        gmax = max((sk.taps for sk in seg.subkernels), default=0)
        mm_acc = (bmul * bi * iw_p * gmax * p.ic * ebytes      # gathered op
                  + bmul * block_oh * ow_p * block_oc * 4)     # planes
    else:
        mm_acc = 2 * bmul * n_slab * p.iw * p.ks**2 * block_oc * 4  # mm+acc
    return (x_resident
            + p.ic * p.ks**2 * block_oc * ebytes               # weight block
            + mm_acc
            + 2 * bmul * block_oh * ow_p * block_oc * 4)       # out blocks


def plan(p: TConvProblem, *, batch: int = 1, bits: int = 8, hw: HW = V5E,
         block_oh: Optional[int] = None, block_oc: Optional[int] = None,
         grid_order: Optional[str] = None,
         method: str = "mm2im", fold_batch: bool = False) -> TilePlan:
    """Tile plan for ``p`` — heuristic by default, explicit when overridden.

    Passing ``block_oh``/``block_oc`` (and optionally ``grid_order`` /
    ``method``) bypasses the ``plan_blocks`` heuristic; this is how
    autotuned plans are rendered back into a full :class:`TilePlan` with
    their modeled VMEM footprint and halo overhead.
    """
    ebytes = bits // 8
    if block_oh is None or block_oc is None:
        # plan_blocks owns the folded-budget rule (B-deep residency =>
        # budget/B): heuristic folded blocks fit VMEM with the fold on.
        h_oh, h_oc = plan_blocks(
            p.ih, p.iw, p.ic, p.ks, p.oc, p.stride, p.padding,
            vmem_budget=int(hw.vmem_bytes * 0.75), in_bytes=ebytes,
            batch=batch, fold_batch=fold_batch)
        block_oh = block_oh if block_oh is not None else h_oh
        block_oc = block_oc if block_oc is not None else h_oc
    s = p.stride
    if block_oh % s != 0 or block_oh < s:
        raise ValueError(f"block_oh={block_oh} must be a positive multiple "
                         f"of stride {s}")
    bi, n_slab, n_j, ihp, ow_p = _geometry(p, block_oh)
    n_c = -(-p.oc // block_oc)

    if grid_order is None or grid_order == "auto":
        w_bytes = p.ic * p.ks**2 * n_c * block_oc * ebytes
        x_bytes = batch * ihp * p.iw * p.ic * ebytes
        grid_order = "cbj" if w_bytes > x_bytes else "bcj"

    vmem = vmem_bytes(p, block_oh, block_oc, bits=bits, method=method,
                      batch=batch, fold_batch=fold_batch)
    halo = (n_j * n_slab) / max(p.ih, 1) - 1.0
    return TilePlan(p, block_oh, block_oc, n_slab, n_j, n_c, grid_order,
                    vmem, max(halo, 0.0), method, fold_batch)


# Candidate grids mirror plan_blocks' search space; the autotuner measures
# instead of guessing, so it also explores both explicit grid orders and
# every plan-capable kernel variant.
_CAND_BI = (1, 2, 4, 8, 16, 32, 64)
_CAND_BOC = (8, 16, 32, 64, 128, 256)
# Fallback when the registry has not been populated yet (built-ins register
# on `kernels.ops` import).
_CAND_METHODS = ("mm2im", "mm2im_db")


def _registered_plan_methods() -> tuple:
    """Plan-capable methods currently in the kernel registry.

    This is what makes a third-party ``supports_plan=True`` variant
    autotunable with zero wiring: registering it is enough for the
    enumeration stage to produce candidates carrying its name.  Unknown
    variants are budget-modeled with the (conservative) whole-input
    residency of the single-buffered kernel.
    """
    from repro.kernels import ops  # noqa: F401  (registers the built-ins)
    from repro.kernels import registry as kernel_registry

    names = tuple(s.name for s in kernel_registry.specs() if s.supports_plan)
    return names or _CAND_METHODS


def candidate_plans(
    p: TConvProblem, *, batch: int = 1, bits: int = 8, hw: HW = V5E,
    vmem_fraction: float = 0.75,
    methods: Optional[tuple] = None,
) -> List[TilePlan]:
    """Every legal (method, block_oh, block_oc, grid_order, fold) under
    the budget.

    This is the autotuner's enumeration stage (paper Alg. 1 evaluated
    per-problem instead of once): all stride-aligned output-row blocks that
    don't overrun the output, all channel blocks up to O_c, both explicit
    grid orders, and every plan-capable registered kernel variant
    (``methods=None`` queries the registry — see
    :func:`_registered_plan_methods`).  Where the pipeline has fewer than
    two row blocks to overlap, the double-buffered variant is skipped.
    Each variant is budget-filtered under its *own* VMEM residency model,
    so 'mm2im_db' legally reaches block geometries 'mm2im' cannot hold.

    For ``batch > 1`` each geometry is additionally enumerated with
    ``fold_batch=True`` where the ``B``-deep folded residency still fits
    the budget (plan v2 — batch collapsed into the MatMul M-dimension).
    Folded plans carry a single canonical ``'bcj'`` grid order: the
    bcj/cbj distinction collapses with the batch grid axis, so enumerating
    both would measure the same program twice.
    Deduplicated; order is deterministic.
    """
    if methods is None:
        methods = _registered_plan_methods()
    budget = int(hw.vmem_bytes * vmem_fraction)
    s = p.stride
    seen = set()
    out: List[TilePlan] = []
    bocs = sorted({min(p.oc, b) for b in _CAND_BOC})
    folds = (False,) if batch <= 1 else (False, True)
    for bi in _CAND_BI:
        block_oh = s * bi
        if block_oh > max(p.oh, s):
            continue  # row block would exceed the whole output
        n_j = -(-p.oh // block_oh)
        for boc in bocs:
            for method in methods:
                if method == "mm2im_db" and n_j < 2:
                    continue  # nothing to pipeline against
                for fold in folds:
                    if vmem_bytes(p, block_oh, boc, bits=bits, method=method,
                                  batch=batch, fold_batch=fold) > budget:
                        continue
                    orders = ("bcj",) if fold else ("bcj", "cbj")
                    for order in orders:
                        key = (method, block_oh, boc, order, fold)
                        if key in seen:
                            continue
                        seen.add(key)
                        out.append(plan(p, batch=batch, bits=bits, hw=hw,
                                        block_oh=block_oh, block_oc=boc,
                                        grid_order=order, method=method,
                                        fold_batch=fold))
    return out


def rank_plans(p: TConvProblem, plans: Optional[List[TilePlan]] = None,
               *, batch: int = 1, bits: int = 8, hw: HW = V5E,
               fit=None) -> List[TilePlan]:
    """Candidates sorted best-first by modeled cost, calibrated when possible.

    ``fit`` is a :class:`~repro.core.model_fit.FittedHW` (measurement-
    calibrated coefficients), ``"auto"`` to use the shipped calibration
    for the current JAX backend, or None for the uncalibrated roofline.
    With a fit, every candidate — any method, folded or not — scores in
    the same fitted microsecond scale, which is what makes a small
    ``max_measure`` in the autotuner trustworthy; without one the
    datasheet roofline still orders geometries sanely but has the
    recorded sb/db and fold/grid misranks (see ``BENCH_mm2im.json`` and
    docs/AUTOTUNER.md §Calibration).
    """
    # Lazy import: model_fit imports this module for default-geometry
    # reconstruction, so the dependency must not be circular at import time.
    from repro.core import model_fit
    from repro.core.perf_model import estimate_for_plan
    from repro.kernels.registry import Plan

    if plans is None:
        plans = candidate_plans(p, batch=batch, bits=bits, hw=hw)
    if fit == "auto":
        fit = model_fit.shipped_fit()

    def score(tp: TilePlan) -> float:
        pl = Plan(tp.block_oh, tp.block_oc, tp.grid_order, tp.method,
                  tp.fold_batch)
        if fit is not None:
            return fit.predict_us(p, pl, batch=batch, bits=bits, hw=hw)
        return estimate_for_plan(p, batch, plan=pl, bits=bits,
                                 hw=hw).t_overlapped

    return sorted(plans, key=score)


def slab_table(p: TConvProblem, block_oh: int) -> list[tuple[int, int]]:
    """Per-row-block (start, end) input slab ranges — Alg. 1's i_end_row."""
    return [rows_slab(p, oh0, block_oh) for oh0 in range(0, p.oh, block_oh)]
