"""Kernel segregation (Tida et al.) — S² stride-1 sub-kernels + interleave maps.

MM2IM (DESIGN.md §2) fixes the overlapping-sums problem of the IOM
formulation but still issues the full ``Ks²`` tap range per MatMul row and
resolves the stride-``S`` output interleave with residue-decomposed
scatter-adds.  *Kernel segregation* ("Kernel-Segregated Transpose
Convolution Operation" and its "Unified" follow-up, PAPERS.md) restructures
the same arithmetic so neither is needed:

Every partial product of the TCONV contract (``kernels/ref.py``) lands at

    out[o_h, o_w] += x[ih, iw] * w[kh, kw]   where  o_h + ct = S*ih + kh

so for a fixed *output-row residue* ``a' = o_h % S`` only kernel rows with
``kh ≡ a' + ct (mod S)`` can ever contribute — and symmetrically for
columns.  Grouping the ``Ks²`` taps by output residue ``(a', b')``
therefore splits the kernel into ``S²`` disjoint **sub-kernels**, each a
plain *stride-1* convolution over the unexpanded input:

    plane[q, p] = sum_{jh, jw} x[q + mh - jh, p + mw - jw] * w[kh(jh), kw(jw)]

with ``kh(jh) = ah + S*jh`` (``ah = (a' + ct) % S``), row shift
``mh = (a' + ct) // S`` and tap count ``Jh = ceil((Ks - ah)/S)``.  The
plane *is* the final output restricted to its residue class —
``out[a'::S, b'::S] = plane`` — an interleaved strided **view write** with
no accumulation between sub-kernels and no col2im scatter.  Every MAC of
every sub-problem contributes to exactly one final output (no inserted
zeros, no cropped-tap waste beyond the image boundary), which is the
paper's "ineffectual MAC" elimination.  At ``S == 1`` there is exactly one
sub-kernel (the whole kernel) and the dataflow degenerates to plain MM2IM.

This module is the pure host-side decomposition: tap groups, packed weight
layout (a permutation of MM2IM's ``(Ic, Ks², Oc)`` relayout, grouped so
each sub-kernel's taps are one contiguous slice), interleave maps for
tests/analytics, and a reference implementation.  The Pallas kernel that
executes it is ``kernels/mm2im_ks_pallas.py``.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import numpy as np

from repro.kernels.ref import crop_offsets, out_size


def _ceil_div(a: int, b: int) -> int:
    return -(-a // b)


@dataclasses.dataclass(frozen=True)
class SubKernel:
    """One stride-1 sub-problem: the taps feeding output residue (a', b').

    ``plane[q, p] = sum_{jh, jw} x[q + row_shift - jh, p + col_shift - jw]
    * w[kh_taps[jh], kw_taps[jw]]`` and the plane interleaves into the
    output as ``out[row_phase::S, col_phase::S]``.  ``offset`` is the
    first tap's position in the packed ``(Ic, Ks², Oc)`` weight layout
    (:func:`pack_weights`); the sub-kernel owns the contiguous tap range
    ``[offset, offset + taps)``.
    """

    stride: int
    row_phase: int          # a' — output-row residue this sub-kernel fills
    col_phase: int          # b' — output-column residue
    kh_taps: Tuple[int, ...]  # kernel rows, ascending: (a'+ct)%S + S*jh
    kw_taps: Tuple[int, ...]
    row_shift: int          # mh = (a' + ct) // S
    col_shift: int          # mw = (b' + cl) // S
    offset: int             # tap offset into the packed weight layout

    @property
    def jh(self) -> int:
        return len(self.kh_taps)

    @property
    def jw(self) -> int:
        return len(self.kw_taps)

    @property
    def taps(self) -> int:
        """Effectual taps of this sub-problem (0 for stride > kernel gaps)."""
        return self.jh * self.jw

    def plane_shape(self, oh: int, ow: int) -> Tuple[int, int]:
        """(rows, cols) of the interleaved output view this plane fills."""
        return (len(range(self.row_phase, oh, self.stride)),
                len(range(self.col_phase, ow, self.stride)))


@dataclasses.dataclass(frozen=True)
class Segregation:
    """Full S² decomposition of a ``(Ks, stride, padding)`` TCONV kernel."""

    ks: int
    stride: int
    ct: int                       # SAME crop offsets (0 for VALID)
    cl: int
    subkernels: Tuple[SubKernel, ...]  # ordered (row_phase, col_phase)

    @property
    def total_taps(self) -> int:
        """Packed tap count — always Ks² (taps partition the kernel)."""
        return sum(sk.taps for sk in self.subkernels)

    def permutation(self) -> np.ndarray:
        """Flat tap order of :func:`pack_weights`: packed index -> kh*Ks+kw.

        The packed layout is MM2IM's ``(Ic, Ks², Oc)`` relayout with the
        tap axis permuted so each sub-kernel's ``Jh*Jw`` taps form one
        contiguous slice at ``sk.offset`` — one static weight-slice per
        dense sub-MatMul in the Pallas kernel.
        """
        perm = [kh * self.ks + kw
                for sk in self.subkernels
                for kh in sk.kh_taps for kw in sk.kw_taps]
        assert len(perm) == self.ks * self.ks, (len(perm), self.ks)
        return np.asarray(perm, np.int32)


def segregate(ks: int, stride: int, padding: str = "SAME") -> Segregation:
    """Decompose a ``Ks x Ks`` stride-``S`` kernel into S² sub-kernels.

    Sub-kernels are emitted in ``(row_phase, col_phase)`` row-major order;
    a residue class beyond the kernel (``stride > Ks``, VALID) gets an
    empty tap tuple — its output rows/columns are the genuine zero gaps of
    the gapped TCONV output.
    """
    s = stride
    ct, cl = crop_offsets(ks, s, padding)

    def taps(phase: int, crop: int) -> Tuple[int, ...]:
        base = (phase + crop) % s
        return tuple(range(base, ks, s))

    subs = []
    off = 0
    for a in range(s):
        kh = taps(a, ct)
        for b in range(s):
            kw = taps(b, cl)
            sk = SubKernel(stride=s, row_phase=a, col_phase=b,
                           kh_taps=kh, kw_taps=kw,
                           row_shift=(a + ct) // s, col_shift=(b + cl) // s,
                           offset=off)
            subs.append(sk)
            off += sk.taps
    seg = Segregation(ks=ks, stride=s, ct=ct, cl=cl, subkernels=tuple(subs))
    assert seg.total_taps == ks * ks
    return seg


def pack_weights(w, seg: Optional[Segregation] = None, *,
                 stride: Optional[int] = None, padding: str = "SAME"):
    """Relayout HWOI filters ``(Ks, Ks, Oc, Ic)`` -> packed ``(Ic, Ks², Oc)``.

    Same target layout as MM2IM's ``prepare_mm2im`` relayout, but with the
    tap axis grouped by sub-kernel (see :meth:`Segregation.permutation`).
    Works on numpy or jax arrays (pure transpose/reshape/take).
    """
    import jax.numpy as jnp

    if seg is None:
        seg = segregate(w.shape[0], stride, padding)
    ks, _, oc, ic = w.shape
    w3 = jnp.transpose(jnp.asarray(w), (3, 0, 1, 2)).reshape(ic, ks * ks, oc)
    return jnp.take(w3, jnp.asarray(seg.permutation()), axis=1)


def interleave_maps(seg: Segregation, oh: int, ow: int) -> dict:
    """(row_phase, col_phase) -> (rows, cols) output index arrays.

    The strided views each sub-kernel's plane is written to — the
    analytics/test counterpart of the kernel's interleaved writes.  Every
    output pixel appears in exactly one map (the views tile the output).
    """
    out = {}
    for sk in seg.subkernels:
        out[(sk.row_phase, sk.col_phase)] = (
            np.arange(sk.row_phase, oh, seg.stride, dtype=np.int32),
            np.arange(sk.col_phase, ow, seg.stride, dtype=np.int32))
    return out


def segregated_tconv_reference(x, w, *, stride: int, padding: str = "SAME"):
    """Reference TCONV via explicit segregation: S² stride-1 sub-convs +
    interleaved view writes.  Oracle for the Pallas kernel and the golden
    worked-example test; mirrors ``ref.iom_reference`` in role.

    x: (B, Ih, Iw, Ic); w: (Ks, Ks, Oc, Ic) HWOI.  Integer inputs
    accumulate in int32 (exact), floats in f32.
    """
    import jax.numpy as jnp

    x = jnp.asarray(x)
    w = jnp.asarray(w)
    b, ih, iw, ic = x.shape
    ks, _, oc, _ = w.shape
    s = stride
    seg = segregate(ks, s, padding)
    oh = out_size(ih, ks, s, padding)
    ow = out_size(iw, ks, s, padding)
    integer = jnp.issubdtype(x.dtype, jnp.integer)
    acc_dtype = jnp.int32 if integer else jnp.float32
    xw = x.astype(acc_dtype)
    out = jnp.zeros((b, oh, ow, oc), acc_dtype)
    for sk in seg.subkernels:
        qh, qw = sk.plane_shape(oh, ow)
        if qh == 0 or qw == 0:
            continue
        plane = jnp.zeros((b, qh, qw, oc), acc_dtype)
        for jh, kh in enumerate(sk.kh_taps):
            for jw, kw in enumerate(sk.kw_taps):
                # Plane cell (q, p) reads x[q + mh - jh, p + mw - jw];
                # clamp to the input extent (outside = zero contribution).
                r_ofs = sk.row_shift - jh
                c_ofs = sk.col_shift - jw
                q0, q1 = max(0, -r_ofs), min(qh, ih - r_ofs)
                p0, p1 = max(0, -c_ofs), min(qw, iw - c_ofs)
                if q1 <= q0 or p1 <= p0:
                    continue
                patch = xw[:, q0 + r_ofs:q1 + r_ofs, p0 + c_ofs:p1 + c_ofs, :]
                tap = w[kh, kw].astype(acc_dtype)  # (Oc, Ic)
                plane = plane.at[:, q0:q1, p0:p1, :].add(
                    jnp.einsum("bhwi,oi->bhwo", patch, tap))
        out = out.at[:, sk.row_phase::s, sk.col_phase::s, :].set(plane)
    return out
