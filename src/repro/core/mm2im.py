"""MM2IM — the paper's contribution as a composable JAX module (public API).

    from repro.core import mm2im

    out = mm2im.transposed_conv2d(x, w, bias, stride=2)         # fused kernel
    stats = mm2im.analyze(mm2im.problem(4, 4, 1024, 5, 512, 2)) # Fig-7 stats
    plan  = mm2im.tile_plan(problem)                            # Alg.-1 plan

Everything here is differentiable, jit-safe and usable under pjit/shard_map
(the op is spatially local, so it shards trivially over batch and O_c; the
GAN configs shard it over ('pod','data') batch and 'model' O_c).
"""

from __future__ import annotations

from repro.core import maps, perf_model, tiling
from repro.core.maps import TConvProblem, drop_stats, spatial_maps
from repro.core.perf_model import ESTIMATORS, V5E, Estimate, modeled_speedup
from repro.core.tiling import TilePlan, plan as tile_plan
from repro.kernels.ops import tconv as transposed_conv2d, tconv_int8

problem = TConvProblem
analyze = drop_stats

__all__ = [
    "transposed_conv2d", "tconv_int8", "problem", "analyze", "spatial_maps",
    "tile_plan", "TilePlan", "TConvProblem", "Estimate", "ESTIMATORS",
    "modeled_speedup", "V5E", "maps", "perf_model", "tiling",
]
