"""PPU epilogue — the post-MatMul stage pipeline every TCONV method shares.

The paper's accelerator treats requantization as one stage of the PPU
epilogue, not a separate datapath: every output element flows through

    bias add  ->  requant (round/clip to int8)  ->  activation  ->  store

regardless of precision.  This module makes that pipeline a first-class
value type so the whole repo agrees on it:

* :class:`Epilogue` — what should happen to the raw accumulator before the
  single HBM store: optional bias, optional requant (``out_scale`` — a
  python float for per-tensor, a length-``Oc`` array for TFLite-style
  per-channel), an activation name, and the output dtype.  Registered as a
  jax pytree: the arrays (bias, per-channel scales) are traced leaves, the
  static knobs (activation, per-tensor scale, output dtype) live in the
  treedef — so an ``Epilogue`` rides through ``jax.jit`` with the correct
  retrace semantics and no manual static-argname bookkeeping.
* :data:`STAGES` — the canonical stage order.  A kernel may fuse any
  *prefix* of the present stages (:meth:`Epilogue.split`); the dispatcher
  (``kernels/ops.py``) applies the remaining suffix with
  :func:`apply_epilogue`, which is what keeps every registered method
  numerically interchangeable — and every method quantization-capable via
  the dispatcher's dequant -> compute -> requant fallback.
* :data:`ACTIVATIONS` / :data:`LEAKY_RELU_SLOPE` — the one activation
  table (previously private to ``kernels/mm2im_pallas.py``) and the one
  leaky-relu slope, shared by the Pallas kernel forwards, the dispatcher's
  unfused remainder, and the ``custom_vjp`` backward
  (:func:`activation_grad_from_output`).

This module imports nothing from the rest of the repo, so every layer
(kernels, registry, dispatcher, autotuner) can depend on it cycle-free.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, FrozenSet, Optional, Tuple, Union

import jax
import jax.numpy as jnp

# The single definition of the leaky-relu negative slope.  The kernel
# forward (via ACTIVATIONS) and the custom_vjp backward (via
# activation_grad_from_output) both read it from here.
LEAKY_RELU_SLOPE = 0.2

ACTIVATIONS: Dict[str, Callable] = {
    "none": lambda x: x,
    "relu": lambda x: jnp.maximum(x, 0),
    "tanh": jnp.tanh,
    "leaky_relu": lambda x: jnp.where(x >= 0, x, LEAKY_RELU_SLOPE * x),
}

# Canonical stage order (paper PPU; DESIGN.md §4).  A kernel may fuse any
# prefix of the *present* stages; the dispatcher applies the rest.
STAGES: Tuple[str, ...] = ("bias", "requant", "activation")


def apply_activation(name: str, x):
    """Apply a named activation (the PPU nonlinearity stage)."""
    return ACTIVATIONS[name](x)


def activation_grad_from_output(name: str, out, g):
    """VJP of the activation given its *output* (custom_vjp residuals hold
    the post-activation tensor, not the pre-activation one)."""
    if name == "none":
        return g
    if name == "relu":
        return g * (out > 0)
    if name == "tanh":
        return g * (1.0 - out * out)
    if name == "leaky_relu":
        return g * jnp.where(out >= 0, 1.0, LEAKY_RELU_SLOPE)
    raise ValueError(f"activation must be one of {tuple(ACTIVATIONS)}, "
                     f"got {name!r}")


@jax.tree_util.register_pytree_node_class
@dataclasses.dataclass(frozen=True)
class Epilogue:
    """What happens to the raw accumulator before the HBM store.

    ``bias``: optional ``(Oc,)`` vector (int32 for the int8 path).
    ``out_scale``: ``None`` (no requant), a python float (per-tensor
    requant — static under jit), or a ``(Oc,)`` array (per-channel requant
    — a traced operand).
    ``activation``: a key of :data:`ACTIVATIONS`.
    ``out_dtype``: final store dtype; ``None`` means the natural
    accumulator dtype (f32, or int32 for integer inputs without requant,
    int8 with requant — see :meth:`resolved_out_dtype`).
    """

    bias: Optional[Any] = None
    activation: str = "none"
    out_scale: Union[None, float, Any] = None
    out_dtype: Optional[Any] = None

    def __post_init__(self):
        if self.activation not in ACTIVATIONS:
            raise ValueError(
                f"activation must be one of {tuple(ACTIVATIONS)}, "
                f"got {self.activation!r}")

    # -- pytree protocol ----------------------------------------------------
    # Arrays (bias, per-channel scales) are children; the static knobs
    # (activation, per-tensor float scale, out dtype) are hashable aux data,
    # so jit retraces exactly when the static epilogue shape changes.

    def tree_flatten(self):
        per_channel = self.per_channel
        children = (self.bias, self.out_scale if per_channel else None)
        aux = (self.activation,
               None if per_channel else self.out_scale,
               per_channel,
               None if self.out_dtype is None
               else jnp.dtype(self.out_dtype).name)
        return children, aux

    @classmethod
    def tree_unflatten(cls, aux, children):
        activation, scalar_scale, per_channel, dtype_name = aux
        bias, channel_scale = children
        return cls(bias=bias, activation=activation,
                   out_scale=channel_scale if per_channel else scalar_scale,
                   out_dtype=None if dtype_name is None
                   else jnp.dtype(dtype_name))

    # -- introspection ------------------------------------------------------

    @property
    def per_channel(self) -> bool:
        """True when ``out_scale`` is a per-channel array (not float/None)."""
        return self.out_scale is not None and not isinstance(
            self.out_scale, (int, float))

    def stages(self) -> Tuple[str, ...]:
        """The present stages, in canonical order."""
        out = []
        if self.bias is not None:
            out.append("bias")
        if self.out_scale is not None:
            out.append("requant")
        if self.activation != "none":
            out.append("activation")
        return tuple(out)

    @property
    def is_noop(self) -> bool:
        return not self.stages() and self.out_dtype is None

    def resolved_out_dtype(self, integer: bool):
        """The concrete store dtype this epilogue produces.

        Mirrors the kernel-side inference in ``prepare_mm2im``: integer
        inputs accumulate in int32 and requantize to int8; floats keep the
        f32 accumulator (``None`` = leave unchanged).
        """
        if self.out_dtype is not None:
            return self.out_dtype
        if not integer:
            return None
        return jnp.int8 if self.out_scale is not None else jnp.int32

    def with_resolved_out_dtype(self, integer: bool) -> "Epilogue":
        dt = self.resolved_out_dtype(integer)
        if dt is None or self.out_dtype is not None:
            return self
        return dataclasses.replace(self, out_dtype=dt)

    # -- the fusion contract ------------------------------------------------

    def split(self, fuses: FrozenSet[str]) -> Tuple["Epilogue", "Epilogue"]:
        """Split into ``(kernel_part, dispatcher_part)`` under ``fuses``.

        The kernel may fuse any *prefix* of the present stages (a stage can
        only run inside the kernel if every earlier present stage does too
        — fusing the activation before an unfused bias add would change
        the math).  Additionally the requant stage only fuses when the
        whole remaining tail does: requant decides the store dtype, and a
        dispatcher-side stage after an in-kernel int8 cast would see
        already-quantized values.

        ``out_dtype`` (the final store cast) stays with the dispatcher
        unless the kernel fuses requant — only a requant-fusing kernel
        commits to the quantized store dtype.
        """
        present = self.stages()
        kernel_stages = []
        for s in present:
            if s not in fuses:
                break
            kernel_stages.append(s)
        if "requant" in kernel_stages and len(kernel_stages) < len(present):
            kernel_stages = kernel_stages[:kernel_stages.index("requant")]
        ks = frozenset(kernel_stages)
        rest_stages = [s for s in present if s not in ks]
        # The final store cast belongs to the dispatcher unless the kernel
        # fuses the requant stage (fusing requant means the kernel commits
        # to the quantized store dtype; a kernel that fuses nothing cannot
        # be asked to cast).
        kernel_casts = "requant" in ks
        kernel_part = Epilogue(
            bias=self.bias if "bias" in ks else None,
            activation=self.activation if "activation" in ks else "none",
            out_scale=self.out_scale if "requant" in ks else None,
            out_dtype=self.out_dtype if kernel_casts else None)
        rest_part = Epilogue(
            bias=self.bias if "bias" in rest_stages else None,
            activation=self.activation if "activation" in rest_stages
            else "none",
            out_scale=self.out_scale if "requant" in rest_stages else None,
            out_dtype=None if kernel_casts else self.out_dtype)
        return kernel_part, rest_part


def apply_epilogue(out, ep: Epilogue):
    """Apply an epilogue (or remainder) outside a kernel, canonical order.

    This is the dispatcher's implementation of the PPU for stages a kernel
    did not fuse; it performs the same operations in the same order as the
    fused ``ppu_epilogue`` in ``kernels/mm2im_pallas.py`` (bias -> requant
    round/clip -> activation -> store cast), so fused and unfused
    execution of the same :class:`Epilogue` agree.
    """
    if ep.bias is not None:
        out = out + ep.bias.astype(out.dtype)[None, None, None, :]
    if ep.out_scale is not None:
        scale = jnp.asarray(ep.out_scale, jnp.float32)
        out = jnp.round(out.astype(jnp.float32) * scale)
        out = jnp.clip(out, -128.0, 127.0)
    out = ACTIVATIONS[ep.activation](out)
    if ep.out_dtype is not None:
        dt = jnp.dtype(ep.out_dtype)
        if (jnp.issubdtype(dt, jnp.integer)
                and not jnp.issubdtype(out.dtype, jnp.integer)):
            out = jnp.round(out)
        out = out.astype(dt)
    return out
