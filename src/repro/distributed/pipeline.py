"""GPipe-style pipeline parallelism over the 'pod' axis.

DESIGN.md §6 names PP as the optional strategy for cross-pod-bound
workloads: instead of replicating the model across pods and paying the DCN
gradient all-reduce, the `pod` axis is re-purposed as a pipeline axis —
each pod holds a *stage* (a contiguous slice of layers) and microbatch
activations flow pod-to-pod through `ppermute` (activations are orders of
magnitude smaller than gradients for deep models).

`pipeline_apply` is the schedule primitive: a manual shard_map over 'pod'
running the classic GPipe bubble schedule (T = n_micro + n_stages - 1
ticks).  Stage s processes microbatch m at tick t = m + s; stage 0 injects
inputs; the last stage's outputs are collected and broadcast.  All stages
execute the same SPMD program — per-stage behaviour is `jnp.where` /
dynamic indexing on `lax.axis_index('pod')`.
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P


def pipeline_apply(stage_fn: Callable, stage_params, x_micro, *,
                   mesh, axis: str = "pod"):
    """Run microbatches through pipeline stages sharded over ``axis``.

    Args:
      stage_fn: (params_one_stage, activation (mb, ...)) -> activation.
      stage_params: pytree with leading dim = n_stages (sharded over axis).
      x_micro: (n_micro, mb, ...) inputs (replicated over axis).
      mesh: mesh containing ``axis``.
    Returns:
      (n_micro, mb, ...) outputs of the final stage (replicated over axis).
    """
    n_stages = mesh.shape[axis]
    n_micro = x_micro.shape[0]
    ticks = n_micro + n_stages - 1

    def body(params_local, xs):
        params_one = jax.tree.map(lambda p: p[0], params_local)
        stage = jax.lax.axis_index(axis)
        mb_shape = xs.shape[1:]
        carry_in = jnp.zeros(mb_shape, xs.dtype)   # from previous stage
        outputs = jnp.zeros((n_micro,) + mb_shape, xs.dtype)

        def tick(t, state):
            carry_in, outputs = state
            m_in = jnp.clip(t, 0, n_micro - 1)
            inject = jax.lax.dynamic_index_in_dim(xs, m_in, keepdims=False)
            live = jnp.logical_and(stage <= t, t - stage < n_micro)
            x_in = jnp.where(stage == 0, inject, carry_in)
            y = stage_fn(params_one, x_in)
            y = jnp.where(live, y, jnp.zeros_like(y))
            # collect at the last stage (microbatch index t - (S-1))
            m_out = jnp.clip(t - (n_stages - 1), 0, n_micro - 1)
            take = jnp.logical_and(stage == n_stages - 1,
                                   t >= n_stages - 1)
            upd = jnp.where(take, y, jax.lax.dynamic_index_in_dim(
                outputs, m_out, keepdims=False))
            outputs = jax.lax.dynamic_update_index_in_dim(
                outputs, upd, m_out, axis=0)
            # hand activations to the next stage
            carry_next = jax.lax.ppermute(
                y, axis, [(i, i + 1) for i in range(n_stages - 1)])
            return carry_next, outputs

        _, outputs = jax.lax.fori_loop(0, ticks, tick,
                                       (carry_in, outputs))
        # Only the last stage holds real outputs; mask + psum broadcasts
        # them to every stage (ppermute requires unique sources).
        outputs = jnp.where(stage == n_stages - 1, outputs,
                            jnp.zeros_like(outputs))
        return jax.lax.psum(outputs, axis)

    from repro.compat import shard_map

    sm = shard_map(
        body, mesh=mesh,
        in_specs=(P(axis), P()),
        out_specs=P(),
        axis_names={axis}, check_vma=False)
    return sm(stage_params, x_micro)
