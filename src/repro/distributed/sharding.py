"""Sharding utilities: spec->sharding trees, activation constraints (SP).

Activation sharding (sequence parallelism) is applied *inside* the model
via :func:`shard_activations`; it resolves the current mesh lazily and
silently no-ops on meshless (CPU smoke) traces, so model code stays
mesh-agnostic.
"""

from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

# Set by the step builders; read by model-internal constraints.
_HINTS: dict = {"batch": ("data",), "seq": None, "enabled": False}


def set_activation_hints(*, batch_axes=("data",), seq_axis: Optional[str] = None,
                         enabled: bool = True):
    _HINTS.update(batch=tuple(batch_axes), seq=seq_axis, enabled=enabled)


def _mesh_axes() -> tuple:
    try:
        m = jax.sharding.get_abstract_mesh()
        return tuple(m.axis_names) if m is not None else ()
    except Exception:
        return ()


def _mesh_shape() -> dict:
    try:
        m = jax.sharding.get_abstract_mesh()
        return dict(m.shape) if m is not None else {}
    except Exception:
        return {}


def constrain(x, spec: P):
    """with_sharding_constraint that drops axes the mesh lacks and axes
    whose size does not divide the corresponding array dimension."""
    axes = _mesh_axes()
    if not axes:
        return x
    sizes = _mesh_shape()
    flat = []
    for i, part in enumerate(spec):
        dim = x.shape[i] if i < x.ndim else 1
        if part is None:
            flat.append(None)
            continue
        cand = part if isinstance(part, tuple) else (part,)
        kept, prod = [], 1
        for a in cand:
            n = sizes.get(a, 0)
            if a in axes and n and dim % (prod * n) == 0:
                kept.append(a)
                prod *= n
        flat.append(tuple(kept) if len(kept) > 1 else (kept[0] if kept else None))
    try:
        return jax.lax.with_sharding_constraint(x, P(*flat))
    except Exception:
        return x


def shard_activations(x):
    """Constrain a (B, L, D) residual-stream tensor: batch over DP axes and
    (optionally) sequence over the TP axis — Megatron-SP style.  The
    compiler inserts the all-gather at attention Q/K/V and reduce-scatter
    after o_proj/mlp automatically."""
    if not _HINTS["enabled"]:
        return x
    seq = _HINTS["seq"] if x.ndim >= 3 and x.shape[1] > 1 else None
    if x.ndim == 3:
        return constrain(x, P(_HINTS["batch"], seq, None))
    if x.ndim == 2:
        return constrain(x, P(_HINTS["batch"], None))
    return x


def tree_shardings(mesh, spec_tree):
    """PartitionSpec tree -> NamedSharding tree.

    ``None`` is preserved as an *empty subtree* (jax pytree semantics) so
    structures with optional components (e.g. Cache.tail) keep matching.
    Replicated leaves must therefore be spelled ``P()``, not ``None``.
    """
    return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree,
                        is_leaf=lambda s: isinstance(s, P))


def batch_axes(mesh) -> tuple:
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))


def batch_pspec(mesh) -> P:
    return P(batch_axes(mesh))


def pad_specs_for_mesh(spec_tree, mesh):
    """Drop mesh axes that don't exist (e.g. 'pod' specs on single-pod)."""
    axes = set(mesh.axis_names)

    def fix(s):
        out = []
        for part in s:
            if part is None:
                out.append(None)
            elif isinstance(part, tuple):
                kept = tuple(a for a in part if a in axes)
                out.append(kept if kept else None)
            else:
                out.append(part if part in axes else None)
        return P(*out)

    return jax.tree.map(fix, spec_tree, is_leaf=lambda s: isinstance(s, P))
