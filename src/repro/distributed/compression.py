"""Gradient compression for the cross-pod (DCN) all-reduce.

At 512+ chips the pod-to-pod gradient reduction crosses slow DCN links;
int8 error-feedback compression cuts that traffic ~4x (vs f32) at
negligible quality cost — the quantization error is carried to the next
step (Seide et al. 2014 / 1-bit Adam lineage).

Mechanics: the train step computes *pod-local* gradients under
``shard_map`` that is manual over 'pod' and automatic over (data, model)
(``axis_names`` subset).  Each pod quantizes (per-tensor max-abs scale),
all-gathers the int8 payload + f32 scalar scales over 'pod', dequantizes
the mean, and feeds the residual back.  Intra-pod reductions stay full
precision (fast ICI).
"""

from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp


def quantize_int8(x: jax.Array) -> Tuple[jax.Array, jax.Array]:
    scale = jnp.maximum(jnp.max(jnp.abs(x)), 1e-12) / 127.0
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def pod_mean_int8(g: jax.Array, e: jax.Array, axis: str = "pod"):
    """Inside shard_map (manual over `axis`): error-feedback int8 mean.

    Returns (mean over pods, new local error).  Cross-pod traffic is the
    int8 payload + one f32 scalar per tensor (4x less than f32 psum).
    """
    x = g.astype(jnp.float32) + e
    q, scale = quantize_int8(x)
    qs = jax.lax.all_gather(q, axis)       # (n_pods, ...) int8 traffic
    scales = jax.lax.all_gather(scale, axis)
    mean = jnp.tensordot(scales, qs.astype(jnp.float32), axes=(0, 0)) \
        / scales.shape[0]
    err_new = x - q.astype(jnp.float32) * scale
    return mean.astype(g.dtype), err_new


def pod_mean_exact(g: jax.Array, axis: str = "pod"):
    return jax.lax.pmean(g, axis)


def tree_pod_mean_int8(grads: Any, err: Any, axis: str = "pod"):
    """Apply pod_mean_int8 leaf-wise (call under manual-'pod' shard_map)."""
    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(err)
    outs = [pod_mean_int8(g, e, axis) for g, e in zip(flat_g, flat_e)]
    return (jax.tree.unflatten(tdef, [o[0] for o in outs]),
            jax.tree.unflatten(tdef, [o[1] for o in outs]))


def init_error_state(params_like: Any) -> Any:
    return jax.tree.map(lambda g: jnp.zeros(g.shape, jnp.float32), params_like)
