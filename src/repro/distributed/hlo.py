"""HLO analysis: collective-traffic extraction for the roofline.

``cost_analysis()`` reports FLOPs and HBM bytes but not collective bytes;
we parse the post-SPMD HLO text and sum operand sizes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute
(+ their -start async forms), per the assignment's §Roofline instructions.
"""

from __future__ import annotations

import re
from collections import defaultdict
from typing import Dict

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1, "c64": 8, "c128": 16,
}

_COLLECTIVES = ("all-reduce", "all-gather", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g. "f32[16,128]{1,0}" or "bf16[2,4096,512]"
_SHAPE_RE = re.compile(r"\b(" + "|".join(_DTYPE_BYTES) + r")\[([0-9,]*)\]")
# instruction def: "%name = TYPE opcode(...)"  (TYPE may be a tuple)
_DEF_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*((?:\([^)]*\))|(?:[a-z0-9_]+\[[0-9,]*\](?:\{[^}]*\})?))\s+([\w\-]+)\(")
# computation header: "%name (params...) -> type {" / "ENTRY %name ...{"
# (param lists contain nested parens — match only the leading name).
_COMP_RE = re.compile(r"^(?:ENTRY\s+)?%([\w.\-]+)\s*\(")
_WHILE_RE = re.compile(r"body=%([\w.\-]+)")
_TRIP_RE = re.compile(r"known_trip_count\D*(\d+)")
_OPERAND_RE = re.compile(r"%([\w.\-]+)")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            if d:
                n *= int(d)
    return n * _DTYPE_BYTES[dtype]


def _type_bytes(type_str: str) -> int:
    return sum(_shape_bytes(d, s) for d, s in _SHAPE_RE.findall(type_str))


def _parse_computations(hlo_text: str):
    """Split module text into {computation: [instruction lines]}."""
    comps: Dict[str, list] = {}
    cur = None
    for line in hlo_text.splitlines():
        m = _COMP_RE.match(line)
        if m and line.rstrip().endswith("{"):
            cur = m.group(1)
            comps[cur] = []
        elif cur is not None:
            if line.startswith("}"):
                cur = None
            else:
                comps[cur].append(line)
    return comps


def collective_bytes(hlo_text: str) -> Dict[str, int]:
    """Collective traffic per kind, weighting while-bodies by trip count.

    Operand sizes are resolved through a per-computation def map (compiled
    HLO prints operands without types); scan-over-layers bodies multiply by
    ``known_trip_count`` from the backend config.  Returns bytes *per
    device per step* (SPMD module shapes are per-device).
    """
    comps = _parse_computations(hlo_text)
    # name -> result bytes, per computation (fallback to global map).
    defs: Dict[str, Dict[str, int]] = {}
    glob: Dict[str, int] = {}
    body_trip: Dict[str, int] = {}
    per_comp: Dict[str, Dict[str, int]] = {}

    for cname, lines in comps.items():
        dmap: Dict[str, int] = {}
        for line in lines:
            dm = _DEF_RE.match(line)
            if dm:
                nbytes = _type_bytes(dm.group(2))
                dmap[dm.group(1)] = nbytes
                glob[dm.group(1)] = nbytes
            if " while(" in line:
                wb = _WHILE_RE.search(line)
                tm = _TRIP_RE.search(line)
                if wb:
                    body_trip[wb.group(1)] = int(tm.group(1)) if tm else 1
        defs[cname] = dmap

    for cname, lines in comps.items():
        counts: Dict[str, int] = defaultdict(int)
        for line in lines:
            dm = _DEF_RE.match(line)
            if not dm:
                continue
            op = dm.group(3)
            base = op[:-6] if op.endswith("-start") else op
            if base not in _COLLECTIVES:
                continue
            operands = line[dm.end():]  # dm ends just past the op's '('
            depth = 1
            for i, ch in enumerate(operands):
                if ch == "(":
                    depth += 1
                elif ch == ")":
                    depth -= 1
                    if depth == 0:
                        operands = operands[:i]
                        break
            nbytes = 0
            for on in _OPERAND_RE.findall(operands):
                nbytes += defs[cname].get(on, glob.get(on, 0))
            counts[base] += nbytes
            counts["total"] += nbytes
        per_comp[cname] = dict(counts)

    # Weight computations: entry = 1; while bodies = product of trip counts
    # (nested whiles resolved by fixpoint iteration).
    weight = {c: 1 for c in comps}
    for _ in range(4):
        for body, trips in body_trip.items():
            # find which computation contains the while referencing body
            for cname, lines in comps.items():
                if any(f"body=%{body}" in ln for ln in lines):
                    weight[body] = weight.get(cname, 1) * trips
    # Computations that are only reachable from while bodies (e.g. nested
    # fusion comps) carry no collectives of their own in practice.
    total: Dict[str, int] = defaultdict(int)
    for cname, counts in per_comp.items():
        w = weight.get(cname, 1)
        for k, v in counts.items():
            total[k] += v * w
    return dict(total)


_SHAPE_FULL_RE = re.compile(r"\b(" + "|".join(_DTYPE_BYTES) + r")\[([0-9,]*)\]")
_DIMS_RE = {
    "lhs_c": re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}"),
    "lhs_b": re.compile(r"lhs_batch_dims=\{([0-9,]*)\}"),
}


def _dims(type_str: str):
    m = _SHAPE_FULL_RE.search(type_str)
    if not m:
        return None, None
    dt, dims = m.group(1), m.group(2)
    shape = tuple(int(d) for d in dims.split(",") if d) if dims else ()
    return dt, shape


def weighted_cost(hlo_text: str) -> Dict[str, float]:
    """Trip-count-weighted per-device FLOPs and HBM-byte proxy.

    ``compiled.cost_analysis()`` counts each while body ONCE; with
    scan-over-layers that understates work by n_layers.  Here:

      * dot FLOPs: 2 * prod(result dims) * prod(lhs contracting dims),
        weighted by the enclosing computation's trip-count product.
      * bytes: operand + result sizes of every *top-level* (fused)
        instruction — a proxy for HBM traffic of each fused kernel.

    Elementwise FLOPs outside dots are not counted (dots dominate LM
    steps); the unweighted cost_analysis() number is reported alongside.
    """
    comps = _parse_computations(hlo_text)
    shapes: Dict[str, tuple] = {}
    body_trip: Dict[str, int] = {}
    for cname, lines in comps.items():
        for line in lines:
            dm = _DEF_RE.match(line)
            if dm:
                shapes[dm.group(1)] = _dims(dm.group(2))
            if " while(" in line:
                wb = _WHILE_RE.search(line)
                tm = _TRIP_RE.search(line)
                if wb:
                    body_trip[wb.group(1)] = int(tm.group(1)) if tm else 1

    weight = {c: 1 for c in comps}
    for _ in range(4):
        for body, trips in body_trip.items():
            for cname, lines in comps.items():
                if any(f"body=%{body}" in ln for ln in lines):
                    weight[body] = weight.get(cname, 1) * trips

    flops = 0.0
    byts = 0.0
    for cname, lines in comps.items():
        w = weight.get(cname, 1)
        # Skip fusion sub-computations for the bytes proxy: only reduce
        # double counting for computations called as fusions (heuristic:
        # name starts with 'fused_' / 'region_' / wrapped_).
        is_sub = cname.startswith(("fused_", "wrapped_", "region_")) \
            or ".clone" in cname and "wide." not in cname
        for line in lines:
            dm = _DEF_RE.match(line)
            if not dm:
                continue
            name, tstr, op = dm.groups()
            if op == "dot":
                _, rshape = _dims(tstr)
                ons = _OPERAND_RE.findall(line[dm.end():].split(")")[0])
                lc = _DIMS_RE["lhs_c"].search(line)
                k = 1
                if ons and lc and ons[0] in shapes:
                    _, lshape = shapes[ons[0]]
                    if lshape:
                        for d in (int(x) for x in lc.group(1).split(",") if x):
                            if d < len(lshape):
                                k *= lshape[d]
                if rshape is not None:
                    n = 1
                    for d in rshape:
                        n *= d
                    flops += w * 2.0 * n * k
            elif op == "convolution":
                # rare outside GAN models; approximate 2*out*K — skipped
                pass
            # HBM-byte proxy: count only ops that are real kernel
            # boundaries on TPU (fusions, dots, convs, data-movement
            # collectives, scatter/gather/dus).  Pure layout/plumbing ops
            # (copy/transpose/bitcast/broadcast/reshape/convert/iota) are
            # fused or elided by the TPU compiler and would over-count
            # traffic by 3-20x if included (measured on the 32-cell sweep).
            countable = op == "fusion" or op == "dot" or op == "convolution" \
                or op in _COLLECTIVES or op.endswith("-start") \
                or op in ("dynamic-slice", "dynamic-update-slice", "gather",
                          "scatter", "reduce", "reduce-window", "sort",
                          "select-and-scatter", "concatenate", "pad")
            if not is_sub and countable:
                nb = _type_bytes(tstr)
                ons = _OPERAND_RE.findall(line[dm.end():].split("),")[0])
                for on in ons:
                    dt_sh = shapes.get(on)
                    if dt_sh and dt_sh[1] is not None:
                        sz = 1
                        for d in dt_sh[1]:
                            sz *= d
                        nb += sz * _DTYPE_BYTES.get(dt_sh[0], 4)
                byts += w * nb
    return {"weighted_dot_flops": flops, "weighted_bytes_proxy": byts}


def scoped_bytes(hlo_text: str, scope: str = "attn_core") -> float:
    """Trip-weighted byte proxy restricted to ops whose op_name metadata
    contains ``scope`` (set via jax.named_scope in the model code).

    Used for the flash-attention roofline correction: the Pallas kernel
    keeps everything inside the ``attn_core`` scope in VMEM, so the
    corrected memory term is (weighted_bytes_proxy - scoped_bytes + the
    kernel's q/k/v/o HBM I/O, which the surrounding dots already count).
    """
    comps = _parse_computations(hlo_text)
    shapes: Dict[str, tuple] = {}
    body_trip: Dict[str, int] = {}
    for cname, lines in comps.items():
        for line in lines:
            dm = _DEF_RE.match(line)
            if dm:
                shapes[dm.group(1)] = _dims(dm.group(2))
            if " while(" in line:
                wb = _WHILE_RE.search(line)
                tm = _TRIP_RE.search(line)
                if wb:
                    body_trip[wb.group(1)] = int(tm.group(1)) if tm else 1
    weight = {c: 1 for c in comps}
    for _ in range(4):
        for body, trips in body_trip.items():
            for cname, lines in comps.items():
                if any(f"body=%{body}" in ln for ln in lines):
                    weight[body] = weight.get(cname, 1) * trips
    total = 0.0
    for cname, lines in comps.items():
        w = weight.get(cname, 1)
        is_sub = cname.startswith(("fused_", "wrapped_", "region_")) \
            or ".clone" in cname and "wide." not in cname
        if is_sub:
            continue
        for line in lines:
            dm = _DEF_RE.match(line)
            if not dm or scope not in line:
                continue
            op = dm.group(3)
            countable = op in ("fusion", "dot", "convolution",
                               "dynamic-slice", "dynamic-update-slice",
                               "gather", "scatter", "reduce", "concatenate",
                               "pad") or op in _COLLECTIVES
            if not countable:
                continue
            nb = _type_bytes(dm.group(2))
            ons = _OPERAND_RE.findall(line[dm.end():].split("),")[0])
            for on in ons:
                dt_sh = shapes.get(on)
                if dt_sh and dt_sh[1] is not None:
                    sz = 1
                    for d in dt_sh[1]:
                        sz *= d
                    nb += sz * _DTYPE_BYTES.get(dt_sh[0], 4)
            total += w * nb
    return total


def score_like_bytes(hlo_text: str, min_dim: int = 512) -> float:
    """Weighted bytes of *untagged* ops whose result is attention-score
    shaped (rank >= 4 with both trailing dims >= min_dim).  XLA drops the
    op_name metadata on some fused score chains; this catches them for the
    flash-correction (see scoped_bytes).  Verified against the tagged set:
    no overlap (only ops without 'attn_core' in their line are counted)."""
    comps = _parse_computations(hlo_text)
    body_trip: Dict[str, int] = {}
    for cname, lines in comps.items():
        for line in lines:
            if " while(" in line:
                wb = _WHILE_RE.search(line)
                tm = _TRIP_RE.search(line)
                if wb:
                    body_trip[wb.group(1)] = int(tm.group(1)) if tm else 1
    weight = {c: 1 for c in comps}
    for _ in range(4):
        for body, trips in body_trip.items():
            for cname, lines in comps.items():
                if any(f"body=%{body}" in ln for ln in lines):
                    weight[body] = weight.get(cname, 1) * trips
    total = 0.0
    for cname, lines in comps.items():
        w = weight.get(cname, 1)
        is_sub = cname.startswith(("fused_", "wrapped_", "region_")) \
            or ".clone" in cname and "wide." not in cname
        if is_sub:
            continue
        for line in lines:
            dm = _DEF_RE.match(line)
            if not dm or "attn_core" in line:
                continue
            op = dm.group(3)
            if op not in ("fusion", "dot", "reduce", "pad", "concatenate"):
                continue
            dt, shape = _dims(dm.group(2))
            if shape is None or len(shape) < 4:
                continue
            if shape[-1] >= min_dim and shape[-2] >= min_dim:
                total += w * _type_bytes(dm.group(2))
    return total


def nested_scan_bytes(hlo_text: str) -> float:
    """Weighted bytes inside *nested* while loops (weight > any single
    trip count).  In this framework the only nested scans are the chunked
    attention's (q-chunk x kv-chunk) loops inside the layer scan, so this
    is a structural attribution of attention-interior traffic — the part
    a flash kernel keeps in VMEM."""
    comps = _parse_computations(hlo_text)
    shapes: Dict[str, tuple] = {}
    body_trip: Dict[str, int] = {}
    for cname, lines in comps.items():
        for line in lines:
            dm = _DEF_RE.match(line)
            if dm:
                shapes[dm.group(1)] = _dims(dm.group(2))
            if " while(" in line:
                wb = _WHILE_RE.search(line)
                tm = _TRIP_RE.search(line)
                if wb:
                    body_trip[wb.group(1)] = int(tm.group(1)) if tm else 1
    if not body_trip:
        return 0.0
    weight = {c: 1 for c in comps}
    for _ in range(4):
        for body, trips in body_trip.items():
            for cname, lines in comps.items():
                if any(f"body=%{body}" in ln for ln in lines):
                    weight[body] = weight.get(cname, 1) * trips
    max_single = max(body_trip.values())
    total = 0.0
    for cname, lines in comps.items():
        w = weight.get(cname, 1)
        if w <= max_single:
            continue  # not a nested-scan interior
        is_sub = cname.startswith(("fused_", "wrapped_", "region_")) \
            or ".clone" in cname and "wide." not in cname
        if is_sub:
            continue
        for line in lines:
            dm = _DEF_RE.match(line)
            if not dm:
                continue
            op = dm.group(3)
            countable = op in ("fusion", "dot", "convolution",
                               "dynamic-slice", "dynamic-update-slice",
                               "gather", "scatter", "reduce", "reduce-window",
                               "concatenate", "pad") or op in _COLLECTIVES
            if not countable:
                continue
            nb = _type_bytes(dm.group(2))
            ons = _OPERAND_RE.findall(line[dm.end():].split("),")[0])
            for on in ons:
                dt_sh = shapes.get(on)
                if dt_sh and dt_sh[1] is not None:
                    sz = 1
                    for d in dt_sh[1]:
                        sz *= d
                    nb += sz * _DTYPE_BYTES.get(dt_sh[0], 4)
            total += w * nb
    return total


def collective_count(hlo_text: str) -> Dict[str, int]:
    out: Dict[str, int] = defaultdict(int)
    for line in hlo_text.splitlines():
        dm = _DEF_RE.match(line)
        if dm:
            op = dm.group(3)
            base = op[:-6] if op.endswith("-start") else op
            if base in _COLLECTIVES:
                out[base] += 1
    return dict(out)


def flops_and_bytes(compiled) -> Dict[str, float]:
    """Pull FLOPs / bytes-accessed from compiled.cost_analysis() (robust to
    the dict / list-of-dict API variants across jax versions)."""
    ca = compiled.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0] if ca else {}
    flops = float(ca.get("flops", 0.0))
    byts = float(ca.get("bytes accessed", 0.0))
    return {"flops": flops, "bytes": byts, "raw_keys": len(ca)}


def memory_analysis_dict(compiled) -> Dict[str, float]:
    ma = compiled.memory_analysis()
    keys = ("argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes", "generated_code_size_in_bytes",
            "alias_size_in_bytes")
    out = {}
    for k in keys:
        out[k] = float(getattr(ma, k, 0) or 0)
    # Donated inputs alias outputs — count them once (true live peak).
    out["total_hbm_bytes"] = (out["argument_size_in_bytes"]
                              + out["output_size_in_bytes"]
                              + out["temp_size_in_bytes"]
                              - out["alias_size_in_bytes"])
    return out
