"""qwen2.5-3b — 36L d_model=2048 16H (GQA kv=2) d_ff=11008 vocab=151936,
QKV bias.  [hf:Qwen/Qwen2.5-0.5B; hf]"""

from repro.configs.registry import ArchSpec
from repro.models.config import ModelConfig

arch = ArchSpec(
    name="qwen2.5-3b",
    family="dense",
    source="hf:Qwen/Qwen2.5-0.5B; hf",
    model=ModelConfig(
        name="qwen2.5-3b",
        vocab=151936, d_model=2048, n_layers=36, n_heads=16, kv_heads=2,
        d_ff=11008, qkv_bias=True, rope_theta=1e6, tied_embeddings=True,
    ),
    smoke=ModelConfig(
        name="qwen2.5-3b-smoke",
        vocab=512, d_model=64, n_layers=2, n_heads=4, kv_heads=2,
        d_ff=128, qkv_bias=True, remat=False,
    ),
)
