"""qwen2-7b — 28L d_model=3584 28H (GQA kv=4) d_ff=18944 vocab=152064,
QKV bias.  [arXiv:2407.10671; hf]"""

from repro.configs.registry import ArchSpec
from repro.models.config import ModelConfig

arch = ArchSpec(
    name="qwen2-7b",
    family="dense",
    source="arXiv:2407.10671; hf",
    model=ModelConfig(
        name="qwen2-7b",
        vocab=152064, d_model=3584, n_layers=28, n_heads=28, kv_heads=4,
        d_ff=18944, qkv_bias=True, rope_theta=1e6, tied_embeddings=False,
    ),
    smoke=ModelConfig(
        name="qwen2-7b-smoke",
        vocab=512, d_model=56, n_layers=2, n_heads=4, kv_heads=2,
        d_ff=128, qkv_bias=True, tied_embeddings=False, remat=False,
    ),
)
