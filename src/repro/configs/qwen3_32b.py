"""qwen3-32b — 64L d_model=5120 64H (GQA kv=8) d_ff=25600 vocab=151936,
qk_norm.  [hf:Qwen/Qwen3-8B; hf]"""

from repro.configs.registry import ArchSpec
from repro.models.config import ModelConfig

arch = ArchSpec(
    name="qwen3-32b",
    family="dense",
    source="hf:Qwen/Qwen3-8B; hf",
    model=ModelConfig(
        name="qwen3-32b",
        vocab=151936, d_model=5120, n_layers=64, n_heads=64, kv_heads=8,
        head_dim=128, d_ff=25600, qk_norm=True, rope_theta=1e6,
        microbatches=4,
        tied_embeddings=False, param_dtype="bfloat16",
    ),
    smoke=ModelConfig(
        name="qwen3-32b-smoke",
        vocab=512, d_model=64, n_layers=2, n_heads=4, kv_heads=2,
        head_dim=16, d_ff=128, qk_norm=True, tied_embeddings=False,
        remat=False,
    ),
)
