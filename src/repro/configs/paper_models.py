"""The paper's own evaluation models (Tables II/IV) as selectable configs.

These exercise MM2IM end-to-end.  Layer tables reproduce the exact TCONV
problem rows the paper benchmarks.
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Tuple

from repro.core.maps import TConvProblem


@dataclasses.dataclass(frozen=True)
class TconvLayerRow:
    """One row of paper Table II."""
    name: str
    oc: int
    ks: int
    ihw: int
    ic: int
    stride: int
    paper_ops: str        # OPs column, for cross-checking
    paper_speedup: float  # 'Speedup (vs CPU)' column

    @property
    def problem(self) -> TConvProblem:
        return TConvProblem(self.ihw, self.ihw, self.ic, self.ks, self.oc,
                            self.stride)


# Paper Table II (stride inferred: DCGAN/StyleTransfer_1,2 upsample x2;
# StyleTransfer_3 is the 9x9 output layer (S=1); FCN/FSRCNN upsamplers).
TABLE_II = (
    TconvLayerRow("DCGAN_1", 512, 5, 4, 1024, 2, "420M", 3.60),
    TconvLayerRow("DCGAN_2", 256, 5, 8, 512, 2, "420M", 4.15),
    TconvLayerRow("DCGAN_3", 128, 5, 16, 256, 2, "420M", 4.17),
    TconvLayerRow("DCGAN_4", 3, 5, 32, 128, 2, "20M", 2.29),
    TconvLayerRow("FCN", 21, 4, 1, 21, 2, "14K", 1.00),
    TconvLayerRow("StyleTransfer_1", 64, 3, 64, 128, 2, "604M", 1.85),
    TconvLayerRow("StyleTransfer_2", 32, 3, 128, 64, 2, "604M", 1.63),
    TconvLayerRow("StyleTransfer_3", 3, 9, 256, 32, 1, "1020M", 3.96),
    TconvLayerRow("FSRCNN", 2, 9, 32, 32, 3, "11M", 2.39),
)

# Paper §V-B synthetic sweep: 3*3*3*4*2 = 216 base permutations plus the
# Iw != Ih / padding variants the paper counts toward 261; we sweep the
# published grid and add VALID-padding + rectangular variants to reach 261.
SWEEP_OC = (16, 32, 64)
SWEEP_KS = (3, 5, 7)
SWEEP_IH = (7, 9, 11)
SWEEP_IC = (32, 64, 128, 256)
SWEEP_S = (1, 2)


def is_small_problem(p: TConvProblem) -> bool:
    """Interpret-mode-friendly sweep member: small enough that off-TPU
    Pallas interpret mode tunes it in seconds.  The single definition of
    the "small-problem slice" used by ``benchmarks/bench_autotune.py``,
    ``tools/tune_sweep.py --small`` (CI smoke) and the committed
    ``src/repro/data/plans/cpu.json`` table."""
    return (p.ih <= 7 and p.iw <= 9 and p.ic <= 64 and p.oc <= 32
            and p.ks <= 5)


# Large-image / stride-4 slice (FSRCNN/pix2pix decoder shapes): the 261
# paper configs stop at 11x11 inputs, so the shipped tables could never
# attribute the regime where slab residency caps MM2IM and the gather-style
# family (kernels/mm2im_og_pallas.py) is expected to win.  Odd kernels >=
# the stride (SAME TCONV requires Ks >= S); channels stay small so
# interpret-mode tuning of a 64x64 input finishes in seconds, matching the
# is_small_problem philosophy of the committed cpu.json.
LARGE_IH = (16, 32, 64)
LARGE_KS = (5, 7)
LARGE_IC = (16, 32)
LARGE_OC = (16,)
LARGE_S = 4


def is_large_problem(p: TConvProblem) -> bool:
    """Member of the large-image sweep regime (the mm2im_og target).

    Delegates to ``core.model_fit.is_large_problem`` — the same predicate
    splits the calibration's ``@large`` fit regimes, so sweep membership
    and cost-model scale class can never drift apart.
    """
    from repro.core.model_fit import is_large_problem as _canonical
    return _canonical(p)


def large_image_sweep() -> Tuple[TConvProblem, ...]:
    """Large-image / stride-4 sweep slice appended to the 261 configs.

    A separate function (not part of :func:`synthetic_sweep`) so the
    paper's published 261-config count stays exact; ``tools/tune_sweep.py``
    concatenates both.
    """
    probs = []
    for ih in LARGE_IH:
        for ks in LARGE_KS:
            for ic in LARGE_IC:
                for oc in LARGE_OC:
                    probs.append(TConvProblem(ih, ih, ic, ks, oc, LARGE_S))
    # The FSRCNN h32 serve bucket (d=16 feature width, single-channel
    # output, x4 upscale): the exact deconv key serve admission / warmup
    # resolve, so the serving path hits a tuned large-image plan.
    probs.append(TConvProblem(32, 32, 16, 9, 1, LARGE_S))
    return tuple(probs)


def synthetic_sweep() -> Tuple[TConvProblem, ...]:
    """The 261 TCONV problem configurations of Fig. 6/7."""
    probs = []
    for oc in SWEEP_OC:
        for ks in SWEEP_KS:
            for ih in SWEEP_IH:
                for ic in SWEEP_IC:
                    for s in SWEEP_S:
                        probs.append(TConvProblem(ih, ih, ic, ks, oc, s))
    # 216 base; fill to 261 with rectangular + VALID variants (documented).
    extra = []
    for ks in SWEEP_KS:
        for ih in SWEEP_IH:
            for s in SWEEP_S:
                extra.append(TConvProblem(ih, ih + 2, 64, ks, 32, s))
    for ks in SWEEP_KS:
        for ih in SWEEP_IH:
            for s in SWEEP_S:
                extra.append(TConvProblem(ih, ih, 96, ks, 48, s, "VALID"))
    for ih in SWEEP_IH:  # even-kernel (pix2pix/FCN-style Ks=4) variants
        for ic in (32, 64, 128):
            extra.append(TConvProblem(ih, ih, ic, 4, 32, 2))
    out = (probs + extra)[:261]
    assert len(out) == 261, len(out)
    return tuple(out)
