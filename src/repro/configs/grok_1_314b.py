"""grok-1-314b — 64L d_model=6144 48H (GQA kv=8) d_ff=32768 vocab=131072,
MoE 8 experts top-2.  [hf:xai-org/grok-1; unverified]"""

from repro.configs.registry import ArchSpec
from repro.models.config import ModelConfig

arch = ArchSpec(
    name="grok-1-314b",
    family="moe",
    source="hf:xai-org/grok-1; unverified",
    model=ModelConfig(
        name="grok-1-314b",
        vocab=131072, d_model=6144, n_layers=64, n_heads=48, kv_heads=8,
        head_dim=128, d_ff=32768, n_experts=8, top_k=2,
        tied_embeddings=True, param_dtype="bfloat16",
        moe_sharding="fsdp_merged", moe_group_size=1024,
        microbatches=2,
        opt_state_dtype="bfloat16",  # 314B: Adam m/v in bf16 to fit HBM
    ),
    smoke=ModelConfig(
        name="grok-1-314b-smoke",
        vocab=512, d_model=64, n_layers=2, n_heads=4, kv_heads=2,
        head_dim=16, d_ff=128, n_experts=4, top_k=2, remat=False,
    ),
    notes="Largest assigned model; parameters fully sharded over "
          "(data, model); bf16 params + bf16 Adam state (DESIGN.md §6).",
)
