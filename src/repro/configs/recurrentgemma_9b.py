"""recurrentgemma-9b — 38L d_model=4096 16H (MQA kv=1) d_ff=12288
vocab=256000; RG-LRU + local attention, 2 recurrent : 1 attn.
[arXiv:2402.19427; unverified]"""

from repro.configs.registry import ArchSpec
from repro.models.config import ModelConfig

arch = ArchSpec(
    name="recurrentgemma-9b",
    family="hybrid",
    source="arXiv:2402.19427; unverified",
    model=ModelConfig(
        name="recurrentgemma-9b",
        vocab=256000, d_model=4096, n_layers=38,
        pattern=("rglru", "rglru", "local_attn"), window=2048,
        n_heads=16, kv_heads=1, head_dim=256, d_ff=12288, mlp_kind="geglu",
        microbatches=2,
        tied_embeddings=True,
    ),
    smoke=ModelConfig(
        name="recurrentgemma-9b-smoke",
        vocab=512, d_model=64, n_layers=5,
        pattern=("rglru", "rglru", "local_attn"), window=8,
        n_heads=4, kv_heads=1, head_dim=16, d_ff=128, mlp_kind="geglu",
        remat=False,
    ),
    notes="38 = 12x(rglru,rglru,local_attn) + 2-layer rglru tail.  Bounded "
          "2048-token window + O(1) recurrent state => long_500k RUNS.",
)
