"""Architecture registry: 10 assigned archs + the paper's own model set.

Each ``src/repro/configs/<id>.py`` defines an :class:`ArchSpec` named
``arch`` with the exact published configuration (FULL) and a reduced SMOKE
config for CPU tests.  ``get(name)`` / ``list_archs()`` are the lookup API
used by the launcher (``--arch <id>``), dry-run, benchmarks and tests.
"""

from __future__ import annotations

import dataclasses
import importlib
from typing import Dict, Optional, Tuple

from repro.models.config import ModelConfig

# (seq_len, global_batch, kind) — kind: train | prefill | decode
SHAPES: Dict[str, Tuple[int, int, str]] = {
    "train_4k": (4096, 256, "train"),
    "prefill_32k": (32768, 32, "prefill"),
    "decode_32k": (32768, 128, "decode"),
    "long_500k": (524288, 1, "decode"),
}


@dataclasses.dataclass(frozen=True)
class ArchSpec:
    name: str
    family: str               # moe | ssm | audio | hybrid | dense | vlm
    source: str               # provenance tag from the assignment
    model: ModelConfig        # FULL published config
    smoke: ModelConfig        # reduced config for CPU smoke tests
    notes: str = ""

    def supported_shapes(self) -> Tuple[str, ...]:
        out = []
        for shape, (_seq, _bs, kind) in SHAPES.items():
            if shape == "long_500k" and not self.model.is_subquadratic:
                continue  # quadratic full attention — skip per DESIGN.md §5
            out.append(shape)
        return tuple(out)


_ARCH_IDS = (
    "qwen2_moe_a2_7b", "grok_1_314b", "mamba2_370m", "seamless_m4t_large_v2",
    "recurrentgemma_9b", "deepseek_67b", "qwen2_5_3b", "qwen2_7b",
    "qwen3_32b", "internvl2_1b",
)

_ALIASES = {
    "qwen2-moe-a2.7b": "qwen2_moe_a2_7b",
    "grok-1-314b": "grok_1_314b",
    "mamba2-370m": "mamba2_370m",
    "seamless-m4t-large-v2": "seamless_m4t_large_v2",
    "recurrentgemma-9b": "recurrentgemma_9b",
    "deepseek-67b": "deepseek_67b",
    "qwen2.5-3b": "qwen2_5_3b",
    "qwen2-7b": "qwen2_7b",
    "qwen3-32b": "qwen3_32b",
    "internvl2-1b": "internvl2_1b",
}

_cache: Dict[str, ArchSpec] = {}


def get(name: str) -> ArchSpec:
    key = _ALIASES.get(name, name).replace("-", "_").replace(".", "_")
    if key not in _cache:
        if key not in _ARCH_IDS:
            raise KeyError(f"unknown arch {name!r}; known: {list(_ARCH_IDS)}")
        mod = importlib.import_module(f"repro.configs.{key}")
        _cache[key] = mod.arch
    return _cache[key]


def list_archs() -> Tuple[str, ...]:
    return _ARCH_IDS


def all_cells() -> list[tuple[str, str]]:
    """Every (arch, shape) dry-run cell (skips applied) — 32 total."""
    cells = []
    for a in _ARCH_IDS:
        spec = get(a)
        for s in spec.supported_shapes():
            cells.append((a, s))
    return cells
