"""deepseek-67b — 95L d_model=8192 64H (GQA kv=8) d_ff=22016 vocab=102400,
llama-arch.  [arXiv:2401.02954; hf]"""

from repro.configs.registry import ArchSpec
from repro.models.config import ModelConfig

arch = ArchSpec(
    name="deepseek-67b",
    family="dense",
    source="arXiv:2401.02954; hf",
    model=ModelConfig(
        name="deepseek-67b",
        vocab=102400, d_model=8192, n_layers=95, n_heads=64, kv_heads=8,
        d_ff=22016, tied_embeddings=False, param_dtype="bfloat16",
        microbatches=4,
    ),
    smoke=ModelConfig(
        name="deepseek-67b-smoke",
        vocab=512, d_model=64, n_layers=3, n_heads=4, kv_heads=2,
        d_ff=128, tied_embeddings=False, remat=False,
    ),
    notes="Deepest assigned model (95L) — scan-over-layers keeps compile "
          "time flat.  Full attention => long_500k skipped.",
)
