"""mamba2-370m — 48L d_model=1024, attention-free SSD, ssm_state=128,
vocab=50280.  [arXiv:2405.21060; unverified]"""

from repro.configs.registry import ArchSpec
from repro.models.config import ModelConfig

arch = ArchSpec(
    name="mamba2-370m",
    family="ssm",
    source="arXiv:2405.21060; unverified",
    model=ModelConfig(
        name="mamba2-370m",
        vocab=50280, d_model=1024, n_layers=48, pattern=("mamba2",),
        ssm_head_dim=64, ssm_expand=2, ssm_state=128, ssm_chunk=256,
        tied_embeddings=True,
    ),
    smoke=ModelConfig(
        name="mamba2-370m-smoke",
        vocab=512, d_model=64, n_layers=2, pattern=("mamba2",),
        ssm_head_dim=16, ssm_expand=2, ssm_state=16, ssm_chunk=8,
        remat=False,
    ),
    notes="SSD (state-space duality) chunked scan — linear in L, so the "
          "long_500k cell RUNS for this arch (sub-quadratic).",
)
