"""internvl2-1b — 24L d_model=896 14H (GQA kv=2) d_ff=4864 vocab=151655;
InternViT frontend is a STUB (precomputed patch embeddings) over a
Qwen2-0.5B-style backbone.  [arXiv:2404.16821; hf]"""

from repro.configs.registry import ArchSpec
from repro.models.config import ModelConfig

arch = ArchSpec(
    name="internvl2-1b",
    family="vlm",
    source="arXiv:2404.16821; hf",
    model=ModelConfig(
        name="internvl2-1b",
        vocab=151655, d_model=896, n_layers=24, n_heads=14, kv_heads=2,
        d_ff=4864, qkv_bias=True, rope_theta=1e6, tied_embeddings=True,
        modality="vision", frontend_len=256,
    ),
    smoke=ModelConfig(
        name="internvl2-1b-smoke",
        vocab=512, d_model=56, n_layers=2, n_heads=4, kv_heads=2,
        d_ff=128, qkv_bias=True, modality="vision", frontend_len=8,
        remat=False,
    ),
    notes="Vision frontend stubbed: input_specs() provides 256 precomputed "
          "patch embeddings (B, 256, D) prepended to token embeddings.",
)
