"""seamless-m4t-large-v2 — enc-dec 24L d_model=1024 16H (kv=16) d_ff=8192
vocab=256206, multimodal (speech frontend is a STUB: input_specs feeds
precomputed frame embeddings).  [arXiv:2308.11596; hf]"""

from repro.configs.registry import ArchSpec
from repro.models.config import ModelConfig

arch = ArchSpec(
    name="seamless-m4t-large-v2",
    family="audio",
    source="arXiv:2308.11596; hf",
    model=ModelConfig(
        name="seamless-m4t-large-v2",
        vocab=256206, d_model=1024, n_layers=24, enc_layers=24,
        n_heads=16, kv_heads=16, d_ff=8192, mlp_kind="relu",
        microbatches=2,
        modality="audio", frontend_len=1024, tied_embeddings=True,
    ),
    smoke=ModelConfig(
        name="seamless-m4t-large-v2-smoke",
        vocab=512, d_model=64, n_layers=2, enc_layers=2,
        n_heads=4, kv_heads=4, d_ff=128, mlp_kind="relu",
        modality="audio", frontend_len=16, remat=False,
    ),
    notes="Encoder-decoder backbone only; the speech frontend is a stub — "
          "encoder consumes precomputed frame embeddings (B, Lenc, D).  "
          "Decoder runs the decode shapes (has causal self-attn + cross).",
)
