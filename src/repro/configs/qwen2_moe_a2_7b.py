"""qwen2-moe-a2.7b — 24L d_model=2048 16H (GQA kv=16) d_ff=1408 vocab=151936,
MoE: 4 shared + 60 routed experts, top-4.  [hf:Qwen/Qwen1.5-MoE-A2.7B; hf]"""

from repro.configs.registry import ArchSpec
from repro.models.config import ModelConfig

arch = ArchSpec(
    name="qwen2-moe-a2.7b",
    family="moe",
    source="hf:Qwen/Qwen1.5-MoE-A2.7B; hf",
    model=ModelConfig(
        name="qwen2-moe-a2.7b",
        vocab=151936, d_model=2048, n_layers=24, n_heads=16, kv_heads=16,
        d_ff=1408, qkv_bias=True, tied_embeddings=True,
        n_experts=60, top_k=4, n_shared_experts=4, moe_d_ff=1408,
        rope_theta=1e6, param_dtype="float32",
        moe_sharding="replicated_gather", moe_group_size=256,
    ),
    smoke=ModelConfig(
        name="qwen2-moe-a2.7b-smoke",
        vocab=512, d_model=64, n_layers=2, n_heads=4, kv_heads=4,
        d_ff=48, qkv_bias=True, n_experts=8, top_k=4, n_shared_experts=2,
        moe_d_ff=48, remat=False,
    ),
    notes="4 always-on shared experts (combined hidden 4*1408=5632) + 60 "
          "routed top-4; MHA (kv=16).  MM2IM inapplicable (no TCONV).",
)
