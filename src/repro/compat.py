"""jax version-compat shims (repo targets the image's pinned jax).

The source was written against the post-0.5 public API; the pinned image
ships 0.4.x.  Two surfaces differ:

* ``jax.set_mesh`` — see ``launch/mesh.py:use_mesh``.
* ``jax.shard_map`` — on 0.4.x it lives in ``jax.experimental.shard_map``
  with ``check_rep``/``auto`` instead of ``check_vma``/``axis_names``.
  :func:`shard_map` translates: ``axis_names`` (manual axes) becomes
  ``auto = mesh.axis_names - axis_names``.
"""

from __future__ import annotations

from typing import Optional, Set

import jax

# Partial-manual shard_map (manual over a subset of mesh axes) only works
# reliably with the native post-0.5 API; the 0.4.x experimental `auto=`
# path hits unimplemented PartitionId / IsManualSubgroup paths in XLA's
# CPU SPMD partitioner.  Tests that need it gate on this flag.
HAS_NATIVE_SHARD_MAP = hasattr(jax, "shard_map")


def shard_map(f, *, mesh, in_specs, out_specs,
              axis_names: Optional[Set[str]] = None,
              check_vma: bool = False):
    """``jax.shard_map`` when present, else the 0.4.x experimental API."""
    if hasattr(jax, "shard_map"):
        kw = {}
        if axis_names is not None:
            kw["axis_names"] = axis_names
        return jax.shard_map(f, mesh=mesh, in_specs=in_specs,
                             out_specs=out_specs, check_vma=check_vma, **kw)
    from jax.experimental.shard_map import shard_map as _sm

    manual = frozenset(axis_names) if axis_names is not None else frozenset(
        mesh.axis_names)
    auto = frozenset(mesh.axis_names) - manual
    return _sm(f, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
               check_rep=check_vma, auto=auto)
