"""Step builders: train / prefill / decode, with full sharding annotations.

Each builder returns ``(jitted_fn, abstract_inputs, shardings)`` ready for
``.lower(...).compile()`` (the dry-run path) or direct execution (examples
and smoke tests).  All lowering happens under ``jax.set_mesh`` so
PartitionSpec-level constraints resolve against the production mesh.

The GAN builders (:func:`make_gan_train_step`,
:func:`make_gan_sample_step`) are the training/serve entry points for the
paper's TCONV models; with no explicit ``plans=`` they resolve each
generator layer's tile plan from the autotuner's on-disk cache
(``core/autotune.py``) — tune once with ``autotune_sweep``, and every
later training or serving process runs the tuned plans (and tuned kernel
variant, single- vs double-buffered) with zero plan threading.  Every
TCONV here goes through the single Epilogue-typed dispatch pipeline
(``kernels/ops.py``), so the f32 training steps and the int8 serve path
share one plan-consumption and variant-upgrade implementation.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs import registry
from repro.distributed import sharding as shd
from repro.models import gan, lm
from repro.models import runner as runner_mod
from repro.models.config import ModelConfig
from repro.optim import adamw


@dataclasses.dataclass
class StepBundle:
    fn: Any                 # jitted function
    abstract_args: tuple    # ShapeDtypeStruct pytrees for .lower(*args)
    kind: str
    meta: Optional[dict] = None  # builder diagnostics (e.g. resolved plans)


def usable_batch_axes(batch: int, mesh) -> tuple:
    """DP axes whose product divides the global batch (long_500k has B=1:
    no batch sharding — parallelism comes from the model axes only).
    Greedy: accumulate axes while divisibility holds."""
    axes, prod = [], 1
    for a in shd.batch_axes(mesh):
        if batch % (prod * mesh.shape[a]) == 0:
            axes.append(a)
            prod *= mesh.shape[a]
    return tuple(axes)


def _model_inputs(cfg: ModelConfig, batch: int, seq: int, mesh) -> Dict[str, Any]:
    """Abstract model inputs (the batch pytree) for one training/prefill step."""
    bspec = P(usable_batch_axes(batch, mesh))
    sds = lambda shape, dt, spec: jax.ShapeDtypeStruct(
        shape, dt, sharding=NamedSharding(mesh, spec))
    out = {
        "tokens": sds((batch, seq), jnp.int32, P(*bspec)),
        "targets": sds((batch, seq), jnp.int32, P(*bspec)),
    }
    if cfg.modality == "vision":
        out["prefix_embeds"] = sds((batch, cfg.frontend_len, cfg.d_model),
                                   jnp.bfloat16, P(*bspec, None, None))
    if cfg.enc_layers:
        out["enc_embeds"] = sds((batch, seq, cfg.d_model),
                                jnp.bfloat16, P(*bspec, None, None))
    return out


def abstract_params(cfg: ModelConfig):
    """(ShapeDtypeStruct params tree, PartitionSpec tree) — no allocation.

    Specs are plain python objects built during tracing, so they are
    captured through a side channel while eval_shape abstracts the arrays.
    """
    box = []

    def f(k):
        p, s = lm.init(cfg, k)
        box.append(s)
        return p

    shapes = jax.eval_shape(f, jax.random.PRNGKey(0))
    return shapes, box[0]


def abstract_state(cfg: ModelConfig, opt_cfg: adamw.AdamWConfig, mesh):
    """Abstract TrainState (params + opt) with shardings attached."""
    p_shape, specs = abstract_params(cfg)
    specs = shd.pad_specs_for_mesh(specs, mesh)
    p_shard = shd.tree_shardings(mesh, specs)
    params = jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        p_shape, p_shard)
    opt_shape = jax.eval_shape(functools.partial(adamw.init, cfg=opt_cfg), params)
    opt_specs = adamw.state_specs(specs)
    opt_shard = shd.tree_shardings(mesh, shd.pad_specs_for_mesh(opt_specs, mesh))
    opt = jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        opt_shape, opt_shard)
    return {"params": params, "opt": opt}, specs


def loss_from_batch(cfg: ModelConfig, params, batch):
    kw = {}
    if "prefix_embeds" in batch:
        kw["prefix_embeds"] = batch["prefix_embeds"]
    if "enc_embeds" in batch:
        kw["enc_embeds"] = batch["enc_embeds"]
    return lm.loss_fn(cfg, params, batch["tokens"], batch["targets"], **kw)


def make_train_step(cfg: ModelConfig, mesh, opt_cfg: Optional[adamw.AdamWConfig] = None,
                    *, batch: int, seq: int, donate: bool = True,
                    seq_shard: bool = True,
                    n_micro: Optional[int] = None) -> StepBundle:
    opt_cfg = opt_cfg or adamw.AdamWConfig(state_dtype=cfg.opt_state_dtype)
    n_micro = cfg.microbatches if n_micro is None else n_micro
    # Microbatch count must divide the per-DP-shard batch.
    dp = 1
    for a in usable_batch_axes(batch, mesh):
        dp *= mesh.shape[a]
    while (batch // dp) % n_micro:
        n_micro -= 1
    shd.set_activation_hints(batch_axes=usable_batch_axes(batch, mesh),
                             seq_axis="model" if seq_shard else None)
    _, pspecs = abstract_params(cfg)
    pspecs = shd.pad_specs_for_mesh(pspecs, mesh)

    def lg(p, b_in):
        (loss, metrics), grads = jax.value_and_grad(
            lambda pp: loss_from_batch(cfg, pp, b_in), has_aux=True)(p)
        return (loss, metrics), grads

    lg_acc = adamw.accumulate(lg, n_micro)

    def train_step(state, batch_in):
        (loss, metrics), grads = lg_acc(state["params"], batch_in)
        # Pin gradients to the parameters' (FSDP) sharding: reductions of
        # dW for ZeRO-gathered weights become reduce-scatters instead of
        # all-reduces (halves cross-device dW traffic — EXPERIMENTS §Perf).
        grads = jax.tree.map(
            lambda g, s: shd.constrain(g, s), grads, pspecs)
        new_p, new_opt, om = adamw.apply(grads, state["opt"], state["params"], opt_cfg)
        metrics = dict(metrics, loss=loss, **om)
        return {"params": new_p, "opt": new_opt}, metrics

    astate, _specs = abstract_state(cfg, opt_cfg, mesh)
    abatch = _model_inputs(cfg, batch, seq, mesh)
    fn = jax.jit(train_step, donate_argnums=(0,) if donate else ())
    return StepBundle(fn=fn, abstract_args=(astate, abatch), kind="train")


def make_train_step_compressed(cfg: ModelConfig, mesh,
                               opt_cfg: Optional[adamw.AdamWConfig] = None,
                               *, batch: int, seq: int) -> StepBundle:
    """Train step with int8 error-feedback gradient compression on the
    cross-pod ('pod' axis / DCN) reduction — DESIGN.md §6.

    shard_map is *manual* over 'pod' and automatic over (data, model):
    gradients are pod-local, compressed, all-gathered as int8 + scalar
    scales, and the dequantized mean feeds AdamW.  The error state carries
    a leading pod dim (one residual per pod).
    """
    from repro.distributed import compression

    if "pod" not in mesh.axis_names:
        return make_train_step(cfg, mesh, opt_cfg, batch=batch, seq=seq)
    opt_cfg = opt_cfg or adamw.AdamWConfig(state_dtype=cfg.opt_state_dtype)
    shd.set_activation_hints(batch_axes=("data",), seq_axis="model")
    n_pods = mesh.shape["pod"]

    def body(state, err, batch_in):
        def lf(p):
            loss, metrics = loss_from_batch(cfg, p, batch_in)
            return loss, metrics

        (loss, metrics), grads = jax.value_and_grad(lf, has_aux=True)(state["params"])
        err_local = jax.tree.map(lambda e: e[0], err)
        gmean, err_new = compression.tree_pod_mean_int8(grads, err_local)
        new_p, new_opt, om = adamw.apply(gmean, state["opt"], state["params"],
                                         opt_cfg)
        metrics = dict(metrics, loss=jax.lax.pmean(loss, "pod"), **om)
        metrics = jax.tree.map(lambda m: jax.lax.pmean(m, "pod"), metrics)
        return ({"params": new_p, "opt": new_opt},
                jax.tree.map(lambda e: e[None], err_new), metrics)

    astate, specs = abstract_state(cfg, opt_cfg, mesh)
    err_specs = jax.tree.map(
        lambda s: P(*(("pod",) + tuple(s))), shd.pad_specs_for_mesh(specs, mesh),
        is_leaf=lambda s: isinstance(s, P))
    err_shape = jax.tree.map(
        lambda s, sp: jax.ShapeDtypeStruct(
            (n_pods,) + s.shape, jnp.float32,
            sharding=NamedSharding(mesh, sp)),
        astate["params"], err_specs)
    abatch = _model_inputs(cfg, batch, seq, mesh)

    # Partial-manual shard_map: in/out specs may reference ONLY the manual
    # axis ('pod'); the data/model shardings of each leaf are handled by
    # the automatic axes (and are carried by the abstract args' shardings).
    state_pod_specs = jax.tree.map(lambda _: P(), astate)
    err_pod_specs = jax.tree.map(lambda _: P("pod"), astate["params"])
    batch_pod_specs = jax.tree.map(lambda _: P("pod"), abatch)
    from repro.compat import shard_map

    fn_sm = shard_map(
        body, mesh=mesh,
        in_specs=(state_pod_specs, err_pod_specs, batch_pod_specs),
        out_specs=(state_pod_specs, err_pod_specs,
                   {"loss": P(), "ce": P(), "aux": P(), "z": P(),
                    "lr": P(), "grad_norm": P()}),
        axis_names={"pod"}, check_vma=False)
    fn = jax.jit(fn_sm, donate_argnums=(0, 1))
    return StepBundle(fn=fn, abstract_args=(astate, err_shape, abatch),
                      kind="train_compressed")


def make_prefill_step(cfg: ModelConfig, mesh, *, batch: int, seq: int,
                      seq_shard: bool = True) -> StepBundle:
    shd.set_activation_hints(batch_axes=usable_batch_axes(batch, mesh),
                             seq_axis="model" if seq_shard else None)

    def prefill(params, batch_in):
        kw = {}
        if "prefix_embeds" in batch_in:
            kw["prefix_embeds"] = batch_in["prefix_embeds"]
        if "enc_embeds" in batch_in:
            kw["enc_embeds"] = batch_in["enc_embeds"]
        logits, _ = lm.forward(cfg, params, batch_in["tokens"], **kw)
        # Serve-prefill returns only the last-position logits (next token).
        return logits[:, -1]

    opt_cfg = adamw.AdamWConfig()
    astate, _ = abstract_state(cfg, opt_cfg, mesh)
    abatch = _model_inputs(cfg, batch, seq, mesh)
    abatch.pop("targets")
    fn = jax.jit(prefill)
    return StepBundle(fn=fn, abstract_args=(astate["params"], abatch),
                      kind="prefill")


def _weight_stationary_specs(pspecs):
    """Decode-profile param shardings: drop the FSDP ('data') axis so no
    weight is gathered per generated token — weights are read from local
    HBM only (model-sharded), trading replication memory for zero
    weight-collective traffic on the decode path (EXPERIMENTS §Perf D)."""
    def fix(s):
        parts = []
        for part in s:
            if part == "data":
                parts.append(None)
            elif isinstance(part, tuple):
                kept = tuple(a for a in part if a != "data")
                parts.append(kept if kept else None)
            else:
                parts.append(part)
        return P(*parts)
    return jax.tree.map(fix, pspecs, is_leaf=lambda s: isinstance(s, P))


def make_decode_step(cfg: ModelConfig, mesh, *, batch: int, seq: int,
                     kv_seq_shard: bool = True,
                     weight_stationary: bool = False) -> StepBundle:
    """One-token serve_step with the KV cache at fill level seq-1."""
    baxes = usable_batch_axes(batch, mesh)
    shd.set_activation_hints(batch_axes=baxes, seq_axis=None)

    def serve_step(params, cache, tokens):
        logits, new_cache = lm.decode(cfg, params, tokens, cache)
        return logits, new_cache

    opt_cfg = adamw.AdamWConfig()
    if weight_stationary:
        p_shape, pspecs = abstract_params(cfg)
        pspecs = _weight_stationary_specs(shd.pad_specs_for_mesh(pspecs, mesh))
        p_shard = shd.tree_shardings(mesh, pspecs)
        params_abs = jax.tree.map(
            lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
            p_shape, p_shard)
        astate = {"params": params_abs}
    else:
        astate, _ = abstract_state(cfg, opt_cfg, mesh)
    cache_shape = jax.eval_shape(
        lambda: lm.init_cache(cfg, batch, seq, length=seq - 1))
    seq_axis = "model" if kv_seq_shard else None
    cspecs = lm.cache_specs(cfg, seq_axis=seq_axis,
                            batch_axis=baxes if baxes else None)
    cspecs = shd.pad_specs_for_mesh(cspecs, mesh)
    cshard = shd.tree_shardings(mesh, cspecs)
    cache = jax.tree.map(
        lambda s, sh: jax.ShapeDtypeStruct(s.shape, s.dtype, sharding=sh),
        cache_shape, cshard)
    tokens = jax.ShapeDtypeStruct((batch, 1), jnp.int32,
                                  sharding=NamedSharding(mesh, P(baxes)))
    fn = jax.jit(serve_step, donate_argnums=(1,))
    return StepBundle(fn=fn, abstract_args=(astate["params"], cache, tokens),
                      kind="decode")


# ---------------------------------------------------------------------------
# GAN steps (the paper's TCONV models) — plan-cache-aware.
# ---------------------------------------------------------------------------


def resolve_gan_plans(g_params, *, batch: int, dtype=jnp.float32,
                      plans: Optional[dict] = None,
                      method: str = "mm2im") -> dict:
    """Per-layer tile plans for a DCGAN generator, cache-backed.

    Compat wrapper over the generic
    :meth:`repro.models.runner.GeneratorRunner.resolve_plans` (which any
    registered model family gets for free).  Precedence per layer:
    explicit ``plans`` entry > autotuner cache hit > nothing (trace-time
    tier lookup / heuristic); plan-incapable methods skip the cache and
    pass only the caller's explicit entries through.
    """
    r = runner_mod.GeneratorRunner(runner_mod.get_spec("dcgan"), g_params,
                                   method=method)
    return r.resolve_plans(batch=batch, dtype=dtype, plans=plans)


def make_gan_train_step(
    g_params, d_params,
    opt_cfg: Optional[adamw.AdamWConfig] = None,
    *,
    batch: int,
    z_dim: int = 100,
    method: str = "mm2im",
    plans: Optional[dict] = None,
) -> StepBundle:
    """Alternating D/G DCGAN update with every generator TCONV on MM2IM.

    State is ``(g_params, g_opt, d_params, d_opt)``; the returned fn maps
    ``(state, z, real) -> (state, (d_loss, g_loss))``.  With ``plans=None``
    the generator layers consume cached autotuner plans automatically
    (see :func:`resolve_gan_plans`).
    """
    opt_cfg = opt_cfg or adamw.AdamWConfig(
        lr=2e-4, b1=0.5, b2=0.999, weight_decay=0.0, clip_norm=None,
        warmup_steps=0, total_steps=1, schedule="constant")
    plans = resolve_gan_plans(g_params, batch=batch, plans=plans,
                              method=method)
    policy = runner_mod.TconvPolicy(method=method, plans=plans)
    img_size, out_ch = gan.dcgan_output_geometry(g_params)

    def bce(logits, is_real: bool):
        sign = 1.0 if is_real else -1.0
        return jnp.mean(jax.nn.softplus(-sign * logits))

    def train_step(state, z, real):
        gp, g_opt, dp, d_opt = state

        def d_loss(dpp):
            fake = gan.dcgan_generator(gp, z, policy=policy)
            return bce(gan.dcgan_discriminator(dpp, real), True) + \
                bce(gan.dcgan_discriminator(dpp, fake), False)

        dl, dg = jax.value_and_grad(d_loss)(dp)
        dp, d_opt, _ = adamw.apply(dg, d_opt, dp, opt_cfg)

        def g_loss(gpp):
            fake = gan.dcgan_generator(gpp, z, policy=policy)
            return bce(gan.dcgan_discriminator(dp, fake), True)

        gl, gg = jax.value_and_grad(g_loss)(gp)
        gp, g_opt, _ = adamw.apply(gg, g_opt, gp, opt_cfg)
        return (gp, g_opt, dp, d_opt), (dl, gl)

    astate = jax.eval_shape(
        lambda: ((g_params, adamw.init(g_params, opt_cfg),
                  d_params, adamw.init(d_params, opt_cfg))))
    az = jax.ShapeDtypeStruct((batch, z_dim), jnp.float32)
    areal = jax.ShapeDtypeStruct((batch, img_size, img_size, out_ch),
                                 jnp.float32)
    fn = jax.jit(train_step, donate_argnums=(0,))
    return StepBundle(fn=fn, abstract_args=(astate, az, areal),
                      kind="gan_train",
                      meta={"plans": plans, "method": method})


def make_runner_sample_step(
    runner: "runner_mod.GeneratorRunner",
    *,
    batch: int,
    precision: str = "f32",
    plans: Optional[dict] = None,
    kind: Optional[str] = None,
) -> StepBundle:
    """Serve step for ANY registered generator family: inputs -> outputs.

    The generic successor of the DCGAN-only sample step: plans resolve
    through the runner's problem enumeration (so pix2pix/FSRCNN/style-
    transfer get cache-backed plans too), and ``precision='int8'`` routes
    every TCONV through the calibrated requant-Epilogue policy.
    """
    dtype = jnp.int8 if precision == "int8" else jnp.float32
    plans = runner.resolve_plans(batch=batch, dtype=dtype, plans=plans)
    policy = runner.policy(precision=precision, plans=plans)

    def sample(params, x):
        return runner.spec.forward(params, x, runner.options, policy=policy)

    fn = jax.jit(sample)
    return StepBundle(
        fn=fn,
        abstract_args=(jax.eval_shape(lambda: runner.params),
                       runner.input_spec(batch)),
        kind=kind or f"{runner.name}_sample",
        meta={"plans": plans, "method": runner.method,
              "precision": precision})


def make_gan_sample_step(
    g_params,
    *,
    batch: int,
    z_dim: int = 100,
    method: str = "mm2im",
    plans: Optional[dict] = None,
) -> StepBundle:
    """Generator-only serve step: ``z -> images``, cached plans consumed.

    DCGAN compat wrapper over :func:`make_runner_sample_step` (``z_dim``
    is recovered from the params; the kwarg is kept for callers that
    passed it explicitly and must agree with the projection weight).
    """
    r = runner_mod.GeneratorRunner(runner_mod.get_spec("dcgan"), g_params,
                                   method=method)
    if z_dim != r.input_shape()[0]:
        raise ValueError(f"z_dim={z_dim} disagrees with params "
                         f"(proj expects {r.input_shape()[0]})")
    return make_runner_sample_step(r, batch=batch, plans=plans,
                                   kind="gan_sample")


def make_step_for_cell(arch: str, shape: str, mesh) -> StepBundle:
    """The (architecture x input-shape) cell entry point used by dryrun.py."""
    spec = registry.get(arch)
    cfg = spec.model
    seq, gbatch, kind = registry.SHAPES[shape]
    if shape not in spec.supported_shapes():
        raise ValueError(f"cell ({arch}, {shape}) is skipped per DESIGN.md §5")
    if kind == "train":
        return make_train_step(cfg, mesh, batch=gbatch, seq=seq)
    if kind == "prefill":
        return make_prefill_step(cfg, mesh, batch=gbatch, seq=seq)
    return make_decode_step(cfg, mesh, batch=gbatch, seq=seq)
