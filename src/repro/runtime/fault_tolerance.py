"""Fault-tolerance runtime: preemption-safe training, elastic re-meshing,
straggler mitigation.

Design for 1000+ nodes (DESIGN.md §6):

* **Checkpoint/restart** — CheckpointManager (atomic + async) saves every
  ``ckpt_every`` steps; on restart the loop resumes from LATEST, and the
  data pipeline skips ahead deterministically (batches are pure functions
  of (seed, step) — no stream replay).
* **Elastic scaling** — ``elastic_mesh()`` builds the largest valid
  (data, model) mesh from *currently live* devices; checkpoints restore
  onto any topology (specs travel in the manifest).  A pod loss at 512
  chips => resume on 256 with the same global batch (per-device batch
  doubles) and identical numerics.
* **Straggler mitigation** — at-scale, the scheduler re-dispatches a slow
  shard's work; because batches are (seed, step)-pure, any host can
  recompute any shard.  ``StragglerSimulator`` injects synthetic delays to
  exercise the path in tests; on real clusters this hooks the collective
  timeout watchdog.
* **Preemption simulation** — ``PreemptionSimulator`` raises at a chosen
  step; tests assert bit-exact resume.

The injection primitives are shared with the *serving* chaos harness
(``serve/resilience.py``): ``FaultInjector`` composes a
``StragglerSimulator`` for per-batch stalls, and its transient-fault
retry uses :func:`jittered_backoff` — one backoff policy for training
re-dispatch and serving retries.
"""

from __future__ import annotations

import dataclasses
import time
from typing import Any, Callable, Optional

import jax
import numpy as np

from repro.checkpoint.manager import CheckpointManager
from repro.data.pipeline import DataConfig, make_batch


class Preempted(RuntimeError):
    pass


@dataclasses.dataclass
class PreemptionSimulator:
    at_step: Optional[int] = None

    def check(self, step: int):
        if self.at_step is not None and step == self.at_step:
            raise Preempted(f"simulated preemption at step {step}")


@dataclasses.dataclass
class StragglerSimulator:
    """Inject per-step delay with probability p (tests the watchdog path).

    Deterministic per ``(seed, step)`` — replaying the same step sequence
    stalls the same steps — and observable via the ``stalls`` counter
    (the serving chaos harness surfaces it in ``server.stats()``).
    """
    p: float = 0.0
    delay_s: float = 0.05
    seed: int = 0
    stalls: int = 0

    def maybe_stall(self, step: int):
        if self.p <= 0:
            return False
        rng = np.random.default_rng((self.seed, step))
        if rng.random() < self.p:
            self.stalls += 1
            time.sleep(self.delay_s)
            return True
        return False


def jittered_backoff(attempt: int, *, base_s: float = 0.01,
                     jitter: float = 0.5,
                     rng: Optional[np.random.Generator] = None) -> float:
    """Exponential backoff with multiplicative jitter, in seconds.

    ``base_s * 2**attempt`` scaled by a uniform factor in
    ``[1 - jitter, 1 + jitter]`` — the jitter decorrelates retriers that
    failed together (the classic thundering-herd fix), and a caller-owned
    seeded ``rng`` keeps chaos tests deterministic.
    """
    rng = np.random.default_rng(0) if rng is None else rng
    jitter = min(max(float(jitter), 0.0), 1.0)
    scale = 1.0 + jitter * (2.0 * float(rng.random()) - 1.0)
    return float(base_s) * (2.0 ** attempt) * scale


def elastic_mesh(model_parallel: int = 1, devices=None):
    """Largest (data, model) mesh over the devices that are live NOW."""
    devices = devices if devices is not None else jax.devices()
    n = len(devices)
    mp = min(model_parallel, n)
    while n % mp:
        mp -= 1
    return jax.make_mesh((n // mp, mp), ("data", "model"),
                         devices=devices[: (n // mp) * mp])


@dataclasses.dataclass
class LoopConfig:
    total_steps: int = 100
    ckpt_every: int = 20
    log_every: int = 10


class TrainLoop:
    """Preemption-safe training loop with deterministic skip-ahead."""

    def __init__(self, step_fn: Callable, state: Any, data_cfg: DataConfig,
                 loop_cfg: LoopConfig, ckpt: CheckpointManager,
                 mesh=None, specs: Any = None,
                 preempt: Optional[PreemptionSimulator] = None,
                 straggler: Optional[StragglerSimulator] = None,
                 log: Callable[[str], None] = print):
        self.step_fn, self.state = step_fn, state
        self.data_cfg, self.loop_cfg = data_cfg, loop_cfg
        self.ckpt, self.mesh, self.specs = ckpt, mesh, specs
        self.preempt = preempt or PreemptionSimulator()
        self.straggler = straggler or StragglerSimulator()
        self.log = log
        self.start_step = 0

    def resume(self):
        """Restore from LATEST if present (elastic: onto the current mesh)."""
        got = self.ckpt.restore_latest(self.state, mesh=self.mesh,
                                       specs=self.specs)
        if got[0] is not None:
            self.start_step = got[0]
            self.state = got[1]
            self.log(f"[resume] restored step {self.start_step}")
        return self.start_step

    def run(self) -> Any:
        metrics = {}
        for step in range(self.start_step, self.loop_cfg.total_steps):
            self.preempt.check(step)
            self.straggler.maybe_stall(step)
            batch = make_batch(self.data_cfg, step, self.mesh)
            self.state, metrics = self.step_fn(self.state, batch)
            if (step + 1) % self.loop_cfg.ckpt_every == 0:
                self.ckpt.save(step + 1, self.state, specs=self.specs)
            if (step + 1) % self.loop_cfg.log_every == 0:
                loss = float(jax.device_get(metrics.get("loss", np.nan)))
                self.log(f"[train] step {step + 1} loss {loss:.4f}")
        self.ckpt.wait()
        return self.state, metrics
