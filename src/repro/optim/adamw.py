"""AdamW + schedules + clipping + grad accumulation — self-contained.

Optimizer state is sharded identically to the parameters (the specs tree is
reused leaf-for-leaf), i.e. ZeRO-style: each device holds only its shard of
m/v.  ``state_dtype='bfloat16'`` halves optimizer HBM for 314B-scale runs
(grok config) at a documented precision cost.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, NamedTuple, Optional

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    step: jax.Array
    m: Any
    v: Any


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: Optional[float] = 1.0
    state_dtype: str = "float32"
    warmup_steps: int = 100
    total_steps: int = 10000
    schedule: str = "cosine"  # cosine | linear | constant


def make_schedule(cfg: AdamWConfig) -> Callable[[jax.Array], jax.Array]:
    def sched(step):
        step = step.astype(jnp.float32)
        if cfg.warmup_steps <= 0:
            warm = 1.0
        else:
            warm = jnp.minimum(step / cfg.warmup_steps, 1.0)
        if cfg.schedule == "constant":
            decay = 1.0
        elif cfg.schedule == "linear":
            decay = jnp.maximum(
                1.0 - (step - cfg.warmup_steps)
                / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1), 0.0)
        else:
            frac = jnp.clip((step - cfg.warmup_steps)
                            / jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1),
                            0.0, 1.0)
            decay = 0.5 * (1.0 + jnp.cos(jnp.pi * frac))
        return cfg.lr * warm * decay
    return sched


def init(params, cfg: AdamWConfig) -> AdamWState:
    dt = {"float32": jnp.float32, "bfloat16": jnp.bfloat16}[cfg.state_dtype]
    zeros = lambda p: jnp.zeros(p.shape, dt)
    return AdamWState(step=jnp.zeros((), jnp.int32),
                      m=jax.tree.map(zeros, params),
                      v=jax.tree.map(zeros, params))


def state_specs(param_specs) -> AdamWState:
    from jax.sharding import PartitionSpec as P
    return AdamWState(step=P(), m=param_specs, v=param_specs)


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def apply(grads, state: AdamWState, params, cfg: AdamWConfig):
    """One AdamW update.  Returns (new_params, new_state, metrics)."""
    sched = make_schedule(cfg)
    step = state.step + 1
    lr = sched(state.step)

    gnorm = global_norm(grads)
    if cfg.clip_norm is not None:
        scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
        grads = jax.tree.map(lambda g: g * scale.astype(g.dtype), grads)

    bc1 = 1.0 - cfg.b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - cfg.b2 ** step.astype(jnp.float32)

    def upd(g, m, v, p):
        gf = g.astype(jnp.float32)
        mf = m.astype(jnp.float32) * cfg.b1 + gf * (1 - cfg.b1)
        vf = v.astype(jnp.float32) * cfg.b2 + jnp.square(gf) * (1 - cfg.b2)
        mhat = mf / bc1
        vhat = vf / bc2
        delta = mhat / (jnp.sqrt(vhat) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        new_p = p.astype(jnp.float32) - lr * delta
        return new_p.astype(p.dtype), mf.astype(m.dtype), vf.astype(v.dtype)

    flat_p, tdef = jax.tree.flatten(params)
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state.m)
    flat_v = jax.tree.leaves(state.v)
    out = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = jax.tree.unflatten(tdef, [o[0] for o in out])
    new_m = jax.tree.unflatten(tdef, [o[1] for o in out])
    new_v = jax.tree.unflatten(tdef, [o[2] for o in out])
    return new_p, AdamWState(step, new_m, new_v), {"lr": lr, "grad_norm": gnorm}


# ---------------------------------------------------------------------------
# Gradient accumulation (microbatching) helper
# ---------------------------------------------------------------------------


def accumulate(loss_and_grad_fn, n_micro: int, *, has_aux: bool = False):
    """Wrap a (params, batch)->((loss[, aux]), grads) fn to accumulate over
    ``n_micro`` microbatches split along the leading batch dim.

    This is the activation-memory lever for the big train cells: peak
    transients (attention scores, MoE capacity tensors, saved residuals)
    scale with the microbatch, so n_micro=8 cuts grok-1's 48 GiB of
    temps to ~6 GiB at unchanged math (EXPERIMENTS §Perf C-final)."""
    if n_micro <= 1:
        return loss_and_grad_fn

    def wrapped(params, batch):
        def slice_mb(x, i):
            sz = x.shape[0] // n_micro
            return jax.lax.dynamic_slice_in_dim(x, i * sz, sz)

        def run(i):
            mb = jax.tree.map(lambda x: slice_mb(x, i), batch)
            return loss_and_grad_fn(params, mb)

        out0, g0 = run(0)

        def micro(i, carry):
            out_acc, grad_acc = carry
            out, grads = run(i)
            return (jax.tree.map(jnp.add, out_acc, out),
                    jax.tree.map(jnp.add, grad_acc, grads))

        out, grads = jax.lax.fori_loop(1, n_micro, micro, (out0, g0))
        inv = 1.0 / n_micro
        return (jax.tree.map(lambda x: x * inv, out),
                jax.tree.map(lambda g: g * inv, grads))

    return wrapped
