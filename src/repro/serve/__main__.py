"""Smoke the TCONV server from the command line.

``python -m repro.serve --models dcgan,fsrcnn --requests 24 --rate 200``

Builds CPU-sized runners, warms every (model, precision) bucket, pushes
open-loop synthetic traffic through the background drain thread, and
prints the per-bucket stats snapshot.  The measured version of this loop
(arrival-rate x image-size x precision sweep, percentile reporting) is
``benchmarks/bench_serve_tconv.py``.

Resilience knobs (``serve/resilience.py``): ``--max-queue-depth`` bounds
each bucket's queue (overflow sheds), ``--deadline-ms`` attaches a
per-request deadline, and ``--chaos-fail-nth`` injects a deterministic
transient fault every Nth batch to exercise the degradation ladder.
Exit status is **nonzero when any bucket ends with ``failed > 0``** (the
full stats dump goes to stdout first), so the CI smoke legs can assert
healthy runs with a plain shell check.
"""

from __future__ import annotations

import argparse
import json
import sys
import time

import jax
import numpy as np

from repro.serve.resilience import FaultInjector, ResilienceConfig
from repro.models.runner import make_runner
from repro.serve.server import TconvServer

SMOKE_RUNNERS = {
    "dcgan": dict(init_kw={"scale_down": 16}),
    "pix2pix": dict(init_kw={"depth": 4, "scale_down": 16}),
    "fsrcnn": dict(init_kw={"d": 8, "s": 4, "m": 1}, input_hw=8),
    "styletransfer": dict(init_kw={"base": 8, "n_res": 1}, input_hw=16),
}


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--models", default="dcgan,fsrcnn")
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--rate", type=float, default=200.0,
                    help="mean arrival rate, requests/s (Poisson)")
    ap.add_argument("--precisions", default="f32,int8")
    ap.add_argument("--max-wait-ms", type=float, default=50.0)
    ap.add_argument("--max-queue-depth", type=int, default=None,
                    help="per-bucket queue cap; overflow is shed "
                         "(default: unbounded)")
    ap.add_argument("--deadline-ms", type=float, default=None,
                    help="per-request deadline; expired requests fail "
                         "fast with DeadlineExceeded (default: none)")
    ap.add_argument("--chaos-fail-nth", type=int, default=None,
                    help="inject a transient fault every Nth batch "
                         "(degradation-ladder smoke)")
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    names = [m for m in args.models.split(",") if m]
    precisions = tuple(p for p in args.precisions.split(",") if p)
    runners = {n: make_runner(n, key=jax.random.PRNGKey(i),
                              **SMOKE_RUNNERS[n])
               for i, n in enumerate(names)}
    config = ResilienceConfig(
        max_queue_depth=args.max_queue_depth,
        default_deadline_s=(None if args.deadline_ms is None
                            else args.deadline_ms / 1e3))
    injector = (FaultInjector(fail_nth_batch=args.chaos_fail_nth,
                              seed=args.seed)
                if args.chaos_fail_nth else None)
    server = TconvServer(runners, max_wait_s=args.max_wait_ms / 1e3,
                         resilience_config=config, fault_injector=injector)

    t0 = time.perf_counter()
    records = server.warmup(precisions=precisions)
    print(f"[serve] warmed {len(records)} buckets in "
          f"{time.perf_counter() - t0:.2f}s")
    for rec in records:
        print(f"[serve]   {rec.model}:b{rec.batch}:{rec.precision} "
              f"compile={rec.seconds:.2f}s tuned={rec.tuned_layers}"
              f"/{rec.total_layers} tiers={dict(rec.tiers)}")

    rng = np.random.default_rng(args.seed)
    gaps = rng.exponential(1.0 / args.rate, args.requests)
    reqs, shed, failed = [], 0, 0
    with server:
        t0 = time.perf_counter()
        for i in range(args.requests):
            time.sleep(gaps[i])
            name = names[i % len(names)]
            precision = precisions[(i // len(names)) % len(precisions)]
            x = np.asarray(runners[name].example_inputs(1, seed=i))[0]
            try:
                reqs.append(server.submit(name, x, precision=precision))
            except Exception as err:  # noqa: BLE001 — shed/open breaker
                shed += 1
                print(f"[serve] request {i} shed: {err}")
        done = []
        for r in reqs:
            try:
                done.append(r.result(timeout=300))
            except Exception as err:  # noqa: BLE001 — typed request failure
                failed += 1
                print(f"[serve] request {r.rid} failed: "
                      f"{type(err).__name__}: {err}")
        wall = time.perf_counter() - t0

    lats = sorted(1e3 * r.latency_s for r in reqs if r.latency_s is not None)
    if lats:
        print(f"[serve] {len(done)}/{len(reqs)} requests ok "
              f"({shed} shed, {failed} failed) in {wall:.2f}s "
              f"({len(reqs) / wall:.1f} req/s), "
              f"p50={lats[len(lats) // 2]:.1f}ms p99={lats[-1]:.1f}ms")
    stats = server.stats()
    print(json.dumps(stats, indent=2, default=str))
    bad = {key: b["failed"] for key, b in stats["buckets"].items()
           if b["failed"] > 0}
    if bad:
        print(f"[serve] FAILED buckets: {bad}", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
