"""Smoke the TCONV server from the command line.

``python -m repro.serve --models dcgan,fsrcnn --requests 24 --rate 200``

Builds CPU-sized runners, warms every (model, precision) bucket, pushes
open-loop synthetic traffic through the background drain thread, and
prints the per-bucket stats snapshot.  The measured version of this loop
(arrival-rate x image-size x precision sweep, percentile reporting) is
``benchmarks/bench_serve_tconv.py``.
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import numpy as np

from repro.models.runner import make_runner
from repro.serve.server import TconvServer

SMOKE_RUNNERS = {
    "dcgan": dict(init_kw={"scale_down": 16}),
    "pix2pix": dict(init_kw={"depth": 4, "scale_down": 16}),
    "fsrcnn": dict(init_kw={"d": 8, "s": 4, "m": 1}, input_hw=8),
    "styletransfer": dict(init_kw={"base": 8, "n_res": 1}, input_hw=16),
}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--models", default="dcgan,fsrcnn")
    ap.add_argument("--requests", type=int, default=24)
    ap.add_argument("--rate", type=float, default=200.0,
                    help="mean arrival rate, requests/s (Poisson)")
    ap.add_argument("--precisions", default="f32,int8")
    ap.add_argument("--max-wait-ms", type=float, default=50.0)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    names = [m for m in args.models.split(",") if m]
    precisions = tuple(p for p in args.precisions.split(",") if p)
    runners = {n: make_runner(n, key=jax.random.PRNGKey(i),
                              **SMOKE_RUNNERS[n])
               for i, n in enumerate(names)}
    server = TconvServer(runners, max_wait_s=args.max_wait_ms / 1e3)

    t0 = time.perf_counter()
    records = server.warmup(precisions=precisions)
    print(f"[serve] warmed {len(records)} buckets in "
          f"{time.perf_counter() - t0:.2f}s")
    for rec in records:
        print(f"[serve]   {rec.model}:b{rec.batch}:{rec.precision} "
              f"compile={rec.seconds:.2f}s tuned={rec.tuned_layers}"
              f"/{rec.total_layers} tiers={dict(rec.tiers)}")

    rng = np.random.default_rng(args.seed)
    gaps = rng.exponential(1.0 / args.rate, args.requests)
    reqs = []
    with server:
        t0 = time.perf_counter()
        for i in range(args.requests):
            time.sleep(gaps[i])
            name = names[i % len(names)]
            precision = precisions[(i // len(names)) % len(precisions)]
            x = np.asarray(runners[name].example_inputs(1, seed=i))[0]
            reqs.append(server.submit(name, x, precision=precision))
        for r in reqs:
            r.result(timeout=300)
        wall = time.perf_counter() - t0

    lats = sorted(1e3 * r.latency_s for r in reqs)
    print(f"[serve] {len(reqs)} requests in {wall:.2f}s "
          f"({len(reqs) / wall:.1f} req/s), "
          f"p50={lats[len(lats) // 2]:.1f}ms p99={lats[-1]:.1f}ms")
    print(json.dumps(server.stats(), indent=2, default=str))


if __name__ == "__main__":
    main()
