"""Resilient serving: deadlines, shedding, a degradation ladder, breakers.

The serve stack (DESIGN.md §9) was built for the sunny day: every batch
forward succeeds, every queue drains.  This module is the rainy-day half
— the paper's whole premise is *resource-constrained edge devices*, where
overload, stragglers and partial failure are the norm — and it follows
the GANAX split (PAPERS.md): all irregular control work (retry, rung
selection, breaker state) lives here, outside the dense kernel hot path,
which stays exactly as fast as before when nothing is failing.

Four pieces, threaded through ``serve/server.py``:

* **Deadlines + bounded queues.**  ``submit(deadline_s=...)`` attaches an
  absolute deadline; expired requests fail fast with
  :class:`DeadlineExceeded` *before* batches form instead of occupying a
  tuned batch slot (``batcher.Batcher.pop_expired``).  Per-bucket queues
  are capped by ``max_queue_depth``; the overflow is shed at admission
  with :class:`~repro.serve.bucketing.QueueFullError` and counted in the
  bucket's ``shed`` stat.
* **Degradation ladder.**  A failing batch is retried once with jittered
  backoff when the fault looks transient
  (``runtime/fault_tolerance.jittered_backoff``), then re-dispatched down
  the rungs: tuned plans -> explicit *heuristic* plans (the
  ``plan_blocks`` default — bypasses whatever tuned state may be the
  culprit) -> [int8 buckets only: the tuned **f32** forward — the
  precision rung] -> the ``'lax'`` reference
  (``kernels.ops.tconv_reference``: no Pallas, no plans).  The rung that
  served each batch lands in the bucket's ``rungs`` stat, so degraded
  traffic is visible, not silent.
* **Circuit breaker.**  K consecutive *fully-failed* batches (every rung
  exhausted) trip the bucket's breaker: open buckets shed at admission
  (:class:`~repro.serve.bucketing.CircuitOpenError`) instead of queueing
  work that will fail, and after ``cooldown_s`` one half-open probe is
  admitted — success closes the breaker, failure re-opens it.
* **Fault injection.**  :class:`FaultInjector` is the seeded,
  deterministic chaos hook the server accepts (``fault_injector=``):
  fail-every-Nth-batch (transient, exercises retry + ladder),
  raise-in-dispatch (non-transient, from inside the jitted call),
  per-batch latency spikes, poison-one-bucket (all rungs fail — drives
  the breaker), drain-loop crash (outside the batch guard — drives the
  supervisor), plus composition with the training-side
  ``runtime.fault_tolerance.StragglerSimulator``.  Everything keys off
  the global batch index, so a replayed request sequence injects the
  same faults.

Drain-loop *supervision* itself lives in ``serve/server.py`` (the
supervisor restarts a crashed drain thread and fails the crashed
iteration's in-flight requests); this module supplies the typed crash it
is tested with.
"""

from __future__ import annotations

import dataclasses
import functools
import threading
import time
from typing import Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.runtime.fault_tolerance import StragglerSimulator, jittered_backoff
from repro.serve.bucketing import (AdmissionError, CircuitOpenError,
                                   QueueFullError, ShedError)

__all__ = [
    "AdmissionError", "CircuitBreaker", "CircuitOpenError", "DeadlineExceeded",
    "DegradationLadder", "DispatchFault", "DrainLoopCrash", "FaultInjector",
    "InjectedFault", "LadderExhausted", "PoisonedBucket", "QueueFullError",
    "ResilienceConfig", "RUNG_F32", "RUNG_HEURISTIC", "RUNG_LAX",
    "RUNG_TUNED", "ShedError", "TransientFault", "is_transient",
    "ladder_rungs",
]


# ---------------------------------------------------------------------------
# Typed failures.
# ---------------------------------------------------------------------------


class DeadlineExceeded(TimeoutError):
    """The request's deadline passed before a batch executed it."""


class TransientFault(RuntimeError):
    """A fault worth retrying once in place (backoff + same rung)."""


class InjectedFault(TransientFault):
    """Raised by :class:`FaultInjector` (fail-Nth-batch): transient, so it
    exercises the retry-then-descend path."""


class DispatchFault(RuntimeError):
    """Raised by :class:`FaultInjector` from *inside* the dispatch call
    (raise-in-dispatch): non-transient, so the ladder descends without a
    retry — the shape of a real kernel/lowering failure."""


class PoisonedBucket(RuntimeError):
    """Raised by :class:`FaultInjector` on every rung of a poisoned
    bucket: the persistent-failure shape that trips the breaker."""


class DrainLoopCrash(RuntimeError):
    """Raised by :class:`FaultInjector` *outside* the per-batch guard:
    kills the drain thread, which is the supervisor's job to survive."""


class LadderExhausted(RuntimeError):
    """Every rung (and the transient retry) failed for this batch.  The
    ``__cause__`` chain carries the last rung's error."""


def is_transient(err: BaseException) -> bool:
    """Whether a batch-execution fault deserves one in-place retry.

    :class:`TransientFault` (and subclasses — injected faults included)
    plus the OS-level hiccups a busy edge box actually throws
    (``OSError``: DMA timeouts, interconnect resets surfaced as errno).
    Everything else — shape errors, lowering failures, NaN guards — is
    assumed deterministic: retrying the identical program wastes the
    deadline budget, so the ladder descends immediately.
    """
    return isinstance(err, (TransientFault, OSError))


# ---------------------------------------------------------------------------
# Configuration.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ResilienceConfig:
    """Knobs for the resilient serve path (``TconvServer(resilience=...)``).

    ``max_queue_depth`` / ``default_deadline_s`` default to None —
    unbounded queues and no deadline, the pre-ISSUE-10 behavior — so
    existing callers see identical semantics until they opt in.
    """

    max_queue_depth: Optional[int] = None   # per-bucket queue cap
    default_deadline_s: Optional[float] = None  # applied when submit() has none
    breaker_threshold: int = 3              # K consecutive failures -> open
    breaker_cooldown_s: float = 1.0         # open -> half-open probe delay
    retry_transient: bool = True            # one in-place retry per rung
    backoff_base_s: float = 0.01
    backoff_jitter: float = 0.5
    seed: int = 0                           # backoff jitter rng


# ---------------------------------------------------------------------------
# Circuit breaker (one per bucket; mutated under the server lock).
# ---------------------------------------------------------------------------

BREAKER_CLOSED = "closed"
BREAKER_OPEN = "open"
BREAKER_HALF_OPEN = "half-open"


class CircuitBreaker:
    """Closed -> open after K consecutive batch failures -> half-open probe.

    * **closed**: traffic flows; each fully-failed batch increments the
      consecutive-failure count, any success resets it.
    * **open**: admission sheds (``CircuitOpenError``) until
      ``cooldown_s`` has passed.
    * **half-open**: the first ``allow()`` after the cooldown admits one
      probe; further admissions shed until the probe's batch resolves.
      Probe success closes the breaker, failure re-opens it (and restarts
      the cooldown).

    Time is injected for determinism; the server passes
    ``time.monotonic()``.
    """

    def __init__(self, *, threshold: int = 3, cooldown_s: float = 1.0):
        self.threshold = max(int(threshold), 1)
        self.cooldown_s = float(cooldown_s)
        self.state = BREAKER_CLOSED
        self.consecutive_failures = 0
        self.trips = 0                      # closed/half-open -> open edges
        self._cooldown_until = 0.0
        self._probe_in_flight = False

    def allow(self, now: float) -> bool:
        """Admission check; transitions open -> half-open on first call
        past the cooldown (and claims the single probe slot)."""
        if self.state == BREAKER_CLOSED:
            return True
        if self.state == BREAKER_OPEN:
            if now < self._cooldown_until:
                return False
            self.state = BREAKER_HALF_OPEN
            self._probe_in_flight = True
            return True
        # half-open: one probe at a time
        if self._probe_in_flight:
            return False
        self._probe_in_flight = True
        return True

    def record_success(self) -> None:
        self.consecutive_failures = 0
        self._probe_in_flight = False
        self.state = BREAKER_CLOSED

    def record_failure(self, now: float) -> bool:
        """Count one fully-failed batch; returns True when this failure
        trips (or re-trips) the breaker open."""
        self.consecutive_failures += 1
        tripping = (self.state == BREAKER_HALF_OPEN
                    or (self.state == BREAKER_CLOSED
                        and self.consecutive_failures >= self.threshold))
        if tripping:
            self.state = BREAKER_OPEN
            self._cooldown_until = now + self.cooldown_s
            self._probe_in_flight = False
            self.trips += 1
        return tripping

    def snapshot(self) -> dict:
        return {"state": self.state, "trips": self.trips,
                "consecutive_failures": self.consecutive_failures}


# ---------------------------------------------------------------------------
# Degradation ladder.
# ---------------------------------------------------------------------------

RUNG_TUNED = "tuned"          # the normal path: tuned plans, asked precision
RUNG_HEURISTIC = "heuristic"  # explicit plan_blocks plans: no tuned state
RUNG_F32 = "f32"              # precision rung (int8 buckets): tuned f32 path
RUNG_LAX = "lax"              # ops.tconv_reference: no Pallas, no plans


def ladder_rungs(precision: str) -> Tuple[str, ...]:
    """Rung order for one bucket precision, top (fastest) first."""
    if precision == "int8":
        return (RUNG_TUNED, RUNG_HEURISTIC, RUNG_F32, RUNG_LAX)
    return (RUNG_TUNED, RUNG_HEURISTIC, RUNG_LAX)


def heuristic_plans(runner, *, batch: int, precision: str) -> dict:
    """Explicit ``plan_blocks`` defaults for every runner layer.

    The heuristic rung cannot just "disable the plan cache": the shared
    dispatcher's inner jit is keyed by shapes + static plan, so a
    ``plan=None`` trace of a problem another forward already compiled
    replays the *tuned* program without re-consulting the tiers.  Passing
    the heuristic geometry as explicit per-layer plans makes the rung a
    genuinely different static key — guaranteed to re-trace without the
    tuned state.
    """
    from repro.core.autotune import default_plan

    dtype = jnp.int8 if precision == "int8" else jnp.float32
    return {name: default_plan(prob, batch=batch, dtype=dtype)
            for name, prob in runner.tconv_problems().items()}


class _ReferencePolicy:
    """Ladder bottom: every TCONV through ``ops.tconv_reference`` (f32)."""

    def tconv(self, x, w, bias=None, *, name: str, stride: int,
              padding: str = "SAME", activation: str = "none"):
        from repro.kernels import ops

        return ops.tconv_reference(x, w, bias, stride=stride,
                                   padding=padding, activation=activation)


class DegradationLadder:
    """Per-runner memo of compiled rung forwards.

    Rung forwards are built lazily (a healthy server never compiles the
    lax rung) and memoized per ``(rung, batch, precision)`` — a rung that
    rescued one batch serves the next failure from the jit cache.
    """

    def __init__(self, runner):
        self.runner = runner
        self._fns: Dict[tuple, Callable] = {}
        self._lock = threading.Lock()

    def rungs(self, precision: str) -> Tuple[str, ...]:
        return ladder_rungs(precision)

    def fn(self, rung: str, *, batch: int, precision: str) -> Callable:
        key = (rung, int(batch), precision)
        with self._lock:
            f = self._fns.get(key)
        if f is None:
            f = self._build(rung, batch=batch, precision=precision)
            with self._lock:
                f = self._fns.setdefault(key, f)
        return f

    def _build(self, rung: str, *, batch: int, precision: str) -> Callable:
        r = self.runner
        if rung == RUNG_TUNED:
            return r.jitted(batch=batch, precision=precision)
        if rung == RUNG_F32:
            # Precision rung: serve the int8 bucket's requests through the
            # tuned f32 forward.  Both policies produce outputs in the
            # same (dequantized) domain, so a row is a valid — merely
            # higher-precision — response.
            return r.jitted(batch=batch, precision="f32")
        if rung == RUNG_HEURISTIC:
            policy = r.policy(precision=precision,
                              plans=heuristic_plans(r, batch=batch,
                                                    precision=precision))
        elif rung == RUNG_LAX:
            policy = _ReferencePolicy()
        else:
            raise ValueError(f"unknown ladder rung {rung!r}")
        jfn = jax.jit(functools.partial(r.spec.forward, options=r.options,
                                        policy=policy))
        return lambda x, _jfn=jfn: _jfn(r.params, x)


# ---------------------------------------------------------------------------
# Fault injection.
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class FaultInjector:
    """Seeded, deterministic chaos hook for ``TconvServer``.

    All triggers key off the server's global batch index (1-based,
    assigned in execution order), so a replayed request sequence injects
    the same faults; the only randomness (straggler stalls) is seeded.
    Targeting: ``fail_nth_batch`` and ``raise_in_dispatch_nth`` fire only
    on the *tuned* rung (lower rungs are the recovery under test);
    ``poison_bucket`` fires on every rung of matching buckets (the
    persistent failure that must trip the breaker).

    Injection counts are kept in ``injected`` (a plain dict) and surfaced
    by ``server.stats()['fault_injection']``.
    """

    fail_nth_batch: Optional[int] = None      # every Nth: InjectedFault
    raise_in_dispatch_nth: Optional[int] = None  # every Nth: DispatchFault
    spike_every: Optional[int] = None         # every Nth: sleep(spike_s)
    spike_s: float = 0.05
    poison_bucket: Optional[str] = None       # substring of str(BucketKey)
    crash_drain_at_batch: Optional[int] = None  # once, outside the guard
    straggler: Optional[StragglerSimulator] = None
    seed: int = 0
    injected: Dict[str, int] = dataclasses.field(default_factory=dict)
    _crashed: bool = dataclasses.field(default=False, repr=False)

    def _count(self, what: str) -> None:
        self.injected[what] = self.injected.get(what, 0) + 1

    def maybe_crash(self, batch_index: int) -> None:
        """Called by ``serve_once`` outside the per-batch guard — a raise
        here escapes the drain loop (exactly once)."""
        if (self.crash_drain_at_batch is not None and not self._crashed
                and batch_index >= self.crash_drain_at_batch):
            self._crashed = True
            self._count("drain_crash")
            raise DrainLoopCrash(
                f"injected drain-loop crash at batch {batch_index}")

    def before_batch(self, bucket: str, batch_index: int, *, rung: str,
                     attempt: int) -> None:
        """Called before each execution attempt; may sleep or raise."""
        if rung == RUNG_TUNED and attempt == 0:
            if self.straggler is not None and \
                    self.straggler.maybe_stall(batch_index):
                self._count("stall")
            if (self.spike_every is not None
                    and batch_index % self.spike_every == 0):
                self._count("spike")
                time.sleep(self.spike_s)
        if self.poison_bucket is not None and self.poison_bucket in bucket:
            self._count("poison")
            raise PoisonedBucket(
                f"injected poison in bucket {bucket} "
                f"(batch {batch_index}, rung {rung})")
        if (self.fail_nth_batch is not None and rung == RUNG_TUNED
                and batch_index % self.fail_nth_batch == 0):
            self._count("fail")
            raise InjectedFault(
                f"injected transient fault at batch {batch_index} "
                f"(attempt {attempt})")

    def wrap(self, fn: Callable, bucket: str, batch_index: int, *,
             rung: str, attempt: int) -> Callable:
        """Wrap one execution attempt: raise-in-dispatch surfaces the
        fault from *inside* the call, where a real kernel failure would."""
        if (self.raise_in_dispatch_nth is not None and rung == RUNG_TUNED
                and batch_index % self.raise_in_dispatch_nth == 0):
            def raising(x, _n=batch_index):
                self._count("dispatch_raise")
                raise DispatchFault(
                    f"injected dispatch failure at batch {_n}")
            return raising
        return fn

    def stats(self) -> dict:
        out = dict(self.injected)
        if self.straggler is not None:
            out["straggler_stalls"] = self.straggler.stalls
        return out


# ---------------------------------------------------------------------------
# Ladder execution (called by the server with the batch already padded).
# ---------------------------------------------------------------------------


def run_ladder(ladder: DegradationLadder, xs, *, bucket: str, batch: int,
               precision: str, batch_index: int,
               config: ResilienceConfig,
               injector: Optional[FaultInjector] = None,
               rng: Optional[np.random.Generator] = None,
               sleep: Callable[[float], None] = time.sleep
               ) -> Tuple[np.ndarray, str, int]:
    """Execute one batch down the ladder; ``(output, rung, retries)``.

    Per rung: one attempt, plus one backoff-jittered retry when the fault
    is transient (``is_transient``) and retries are enabled.  Exhausting
    every rung raises :class:`LadderExhausted` chained onto the last
    rung's error — the server fails the batch's requests with it and
    feeds the breaker.
    """
    retries = 0
    last: Optional[BaseException] = None
    x_dev = jnp.asarray(xs)
    for rung in ladder.rungs(precision):
        try:
            fn = ladder.fn(rung, batch=batch, precision=precision)
        except Exception as err:  # building/compiling the rung itself failed
            last = err
            continue
        for attempt in (0, 1):
            try:
                if injector is not None:
                    injector.before_batch(bucket, batch_index, rung=rung,
                                          attempt=attempt)
                    call = injector.wrap(fn, bucket, batch_index, rung=rung,
                                         attempt=attempt)
                else:
                    call = fn
                return np.asarray(call(x_dev)), rung, retries
            except Exception as err:  # noqa: BLE001 — every rung may fail
                last = err
                if (attempt == 0 and config.retry_transient
                        and is_transient(err)):
                    retries += 1
                    sleep(jittered_backoff(attempt,
                                           base_s=config.backoff_base_s,
                                           jitter=config.backoff_jitter,
                                           rng=rng))
                    continue
                break  # next rung
    raise LadderExhausted(
        f"bucket {bucket}: every ladder rung failed for batch "
        f"{batch_index} (last rung error: {last!r})") from last
