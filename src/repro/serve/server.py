"""The in-process TCONV model server: admission -> batcher -> jit cache.

``TconvServer`` owns a set of named :class:`GeneratorRunner`s and serves
single-sample requests against them:

    server = TconvServer({"dcgan": make_runner("dcgan", ...)})
    server.warmup()                       # plan-table-warmed compiles
    with server:                          # background drain thread
        req = server.submit("dcgan", z, precision="int8", deadline_s=0.5)
        img = req.result(timeout=5)

Dataflow per request: :func:`bucketing.snap` validates the input and
picks the tuned-batch bucket (memoized per ``(model, shape, precision)``
so admission does not re-stat the plan cache per request); the
:class:`batcher.Batcher` queues it under the wait-or-flush policy; the
drain loop pops due batches, pads partials with zeros up to the bucket's
target batch (the tuned jit shape is reused — no recompiles), executes
the runner's memoized jit'd forward, and fulfills each request with its
row of the output.

Failure semantics (``serve/resilience.py``, DESIGN.md §9.4): admission
sheds when the bucket's queue is full or its circuit breaker is open;
requests past their deadline fail fast with ``DeadlineExceeded`` before
batches form; a failing batch retries once (transient faults, jittered
backoff) then descends the degradation ladder
(tuned -> heuristic plans [-> f32] -> lax reference); the drain thread is
supervised — a crash fails that iteration's in-flight requests and the
thread restarts.  The invariant, enforced by the chaos suite: **no
submitted request is ever left unfulfilled** — each completes (possibly
on a lower rung), or fails with a typed error.

Execution is synchronous under the hood (``serve_once``) so tests can
drive the server deterministically with an injected clock; ``start()``
wraps the same drain in a daemon thread for real traffic.

Numerics caveat: the models compute batch statistics inline (see
``models/gan.py``), so outputs depend on batch composition — a padded
partial batch is the *defined* behavior, matching the batched forward at
the bucket shape, not a per-request isolated forward.
"""

from __future__ import annotations

import itertools
import threading
import time
from collections import Counter
from typing import Dict, List, Mapping, Optional, Tuple

import numpy as np

from repro.serve import bucketing, resilience, warmup as warmup_mod
from repro.serve.batcher import Batcher, FLUSH_FULL, Request
from repro.serve.bucketing import (AdmissionError, BucketKey, BucketSpec,
                                   CircuitOpenError, ShedError)
from repro.serve.resilience import (CircuitBreaker, DeadlineExceeded,
                                    FaultInjector, ResilienceConfig,
                                    RUNG_TUNED)


class ServerClosed(RuntimeError):
    """The server stopped before this request could be served."""


class _BucketStats:
    """Mutable per-bucket counters (one lock-guarded instance each)."""

    __slots__ = ("requests", "completed", "failed", "batches", "flush_full",
                 "flush_deadline", "fill_sum", "wait_sum", "wait_max",
                 "compile_hits", "shed", "deadline_expired", "retries",
                 "degraded", "rungs")

    def __init__(self):
        self.requests = 0       # successfully enqueued (excludes sheds)
        self.completed = 0
        self.failed = 0         # includes deadline_expired
        self.batches = 0
        self.flush_full = 0
        self.flush_deadline = 0
        self.fill_sum = 0.0
        self.wait_sum = 0.0
        self.wait_max = 0.0
        self.compile_hits = 0
        self.shed = 0           # rejected at admission for load (not queued)
        self.deadline_expired = 0
        self.retries = 0        # in-place transient retries across batches
        self.degraded = 0       # batches served below the tuned rung
        self.rungs: Counter = Counter()  # rung -> batches served by it

    def snapshot(self, spec: BucketSpec,
                 breaker: Optional[CircuitBreaker] = None) -> dict:
        return {
            "target_batch": spec.target_batch,
            "tuned_layers": spec.tuned_layers,
            "total_layers": spec.total_layers,
            "tiers": dict(spec.tiers),
            "requests": self.requests,
            "completed": self.completed,
            "failed": self.failed,
            "batches": self.batches,
            "flush_full": self.flush_full,
            "flush_deadline": self.flush_deadline,
            "batch_fill_ratio": (self.fill_sum / self.batches
                                 if self.batches else 0.0),
            "queue_wait_mean_s": (self.wait_sum / self.completed
                                  if self.completed else 0.0),
            "queue_wait_max_s": self.wait_max,
            "compile_hits": self.compile_hits,
            "shed": self.shed,
            "deadline_expired": self.deadline_expired,
            "retries": self.retries,
            "degraded": self.degraded,
            "rungs": dict(self.rungs),
            "breaker": breaker.snapshot() if breaker is not None else None,
        }


class TconvServer:
    """Shape-bucketed continuous batching over GeneratorRunners."""

    def __init__(self, runners: Mapping[str, object], *,
                 max_wait_s: float = 0.05,
                 candidate_batches: Tuple[int, ...] = (8, 4, 2, 1),
                 default_batch: int = 1,
                 resilience_config: Optional[ResilienceConfig] = None,
                 fault_injector: Optional[FaultInjector] = None):
        self.runners: Dict[str, object] = dict(runners)
        self.max_wait_s = float(max_wait_s)
        self.candidate_batches = tuple(candidate_batches)
        self.default_batch = int(default_batch)
        self.config = resilience_config or ResilienceConfig()
        self.injector = fault_injector
        self._batcher = Batcher(max_wait_s=max_wait_s,
                                max_queue_depth=self.config.max_queue_depth)
        self._rid = itertools.count()
        self._lock = threading.Lock()
        self._buckets: Dict[tuple, BucketSpec] = {}
        self._stats: Dict[BucketKey, _BucketStats] = {}
        self._breakers: Dict[BucketKey, CircuitBreaker] = {}
        self._ladders: Dict[str, resilience.DegradationLadder] = {}
        self._rejected = 0
        self._thread: Optional[threading.Thread] = None
        self._supervisor: Optional[threading.Thread] = None
        self._wake = threading.Event()
        self._running = False
        self._batch_seq = itertools.count(1)   # global batch index (1-based)
        self._inflight: List[Tuple[BucketSpec, Request]] = []
        self._drain_crashes = 0
        self._drain_restarts = 0
        self._rng = np.random.default_rng(self.config.seed)  # backoff jitter

    # -- admission ----------------------------------------------------------

    def bucket_for(self, model: str, shape, precision: str) -> BucketSpec:
        """Snap (model, shape, precision) to its bucket, memoized."""
        if model not in self.runners:
            raise AdmissionError(f"unknown model {model!r}; serving "
                                 f"{sorted(self.runners)}")
        memo_key = (model, tuple(shape), precision)
        with self._lock:
            spec = self._buckets.get(memo_key)
        if spec is None:
            spec = bucketing.snap(self.runners[model], shape, precision,
                                  candidate_batches=self.candidate_batches,
                                  default_batch=self.default_batch,
                                  name=model)
            with self._lock:
                self._buckets[memo_key] = spec
                self._stats.setdefault(spec.key, _BucketStats())
                self._breakers.setdefault(spec.key, CircuitBreaker(
                    threshold=self.config.breaker_threshold,
                    cooldown_s=self.config.breaker_cooldown_s))
        return spec

    def submit(self, model: str, inputs, precision: str = "f32", *,
               deadline_s: Optional[float] = None) -> Request:
        """Enqueue one single-sample request; returns its result handle.

        ``deadline_s`` (relative, seconds; falls back to the config's
        ``default_deadline_s``) bounds how long the request may wait —
        past it the server fails it with :class:`DeadlineExceeded` rather
        than executing stale work.  Raises a :class:`ShedError` subclass
        without enqueueing when the bucket's queue is full or its circuit
        breaker is open; ``requests``/``shed`` counters stay consistent
        (``requests == completed + failed + pending``).
        """
        arr = np.asarray(inputs, np.float32)
        try:
            spec = self.bucket_for(model, arr.shape, precision)
        except AdmissionError:
            with self._lock:
                self._rejected += 1
            raise
        now = time.monotonic()
        with self._lock:
            stats = self._stats[spec.key]
            breaker = self._breakers[spec.key]
            if not breaker.allow(now):
                stats.shed += 1
                raise CircuitOpenError(
                    f"bucket {spec.key} breaker is {breaker.state} "
                    f"(after {breaker.consecutive_failures} consecutive "
                    f"batch failures); shedding")
        if deadline_s is None:
            deadline_s = self.config.default_deadline_s
        deadline = None if deadline_s is None else now + float(deadline_s)
        req = Request(next(self._rid), model, arr, precision, now,
                      deadline=deadline)
        try:
            self._batcher.put(spec, req)
        except ShedError:
            with self._lock:
                stats.shed += 1
            raise
        with self._lock:
            stats.requests += 1
        self._wake.set()
        return req

    # -- execution ----------------------------------------------------------

    def _ladder_for(self, model: str) -> resilience.DegradationLadder:
        with self._lock:
            ladder = self._ladders.get(model)
            if ladder is None:
                ladder = self._ladders[model] = \
                    resilience.DegradationLadder(self.runners[model])
        return ladder

    def _fail_requests(self, spec: BucketSpec, reqs,
                       err: BaseException) -> None:
        t = time.monotonic()
        n = 0
        for r in reqs:
            if not r.done():
                r.set_error(err, t)
                n += 1
        with self._lock:
            self._stats[spec.key].failed += n

    def _run_batch(self, spec: BucketSpec, reqs, reason: str, now: float,
                   batch_index: int) -> None:
        runner = self.runners[spec.key.model]
        target = spec.target_batch
        precision = spec.key.precision
        stats = self._stats[spec.key]
        breaker = self._breakers[spec.key]
        hit = runner.has_compiled(batch=target, precision=precision)
        xs = np.zeros((target,) + spec.key.shape, np.float32)
        for i, r in enumerate(reqs):
            xs[i] = r.inputs
        try:
            out, rung, retries = resilience.run_ladder(
                self._ladder_for(spec.key.model), xs,
                bucket=str(spec.key), batch=target, precision=precision,
                batch_index=batch_index, config=self.config,
                injector=self.injector, rng=self._rng)
        except Exception as err:  # noqa: BLE001 — fulfil, don't wedge
            self._fail_requests(spec, reqs, err)
            with self._lock:
                stats.batches += 1
                breaker.record_failure(time.monotonic())
            return
        t_done = time.monotonic()
        for i, r in enumerate(reqs):
            r.set_result(out[i], t_done)
        waits = [max(now - r.t_enqueue, 0.0) for r in reqs]
        with self._lock:
            stats.completed += len(reqs)
            stats.batches += 1
            stats.compile_hits += int(hit)
            stats.fill_sum += len(reqs) / target
            stats.wait_sum += sum(waits)
            stats.wait_max = max(stats.wait_max, max(waits))
            stats.retries += retries
            stats.rungs[rung] += 1
            if rung != RUNG_TUNED:
                stats.degraded += 1
            if reason == FLUSH_FULL:
                stats.flush_full += 1
            else:
                stats.flush_deadline += 1
            breaker.record_success()

    def _expire(self, now: float) -> int:
        """Fail every queued request whose deadline has passed."""
        expired = 0
        for spec, dead in self._batcher.pop_expired(now):
            t = time.monotonic()
            for r in dead:
                r.set_error(DeadlineExceeded(
                    f"request {r.rid} deadline passed before execution "
                    f"(bucket {spec.key})"), t)
            with self._lock:
                st = self._stats[spec.key]
                st.failed += len(dead)
                st.deadline_expired += len(dead)
            expired += len(dead)
        return expired

    def serve_once(self, now: Optional[float] = None, *,
                   force: bool = False) -> int:
        """Run every batch due at ``now`` (injected for tests); returns the
        number of requests served (completed or failed, expiries included).

        Popped batches are tracked as in-flight until resolved: anything
        that escapes the per-batch handling (e.g. an injected drain-loop
        crash) leaves requests registered for :meth:`_fail_inflight`, so a
        crashed drain iteration never wedges its callers.
        """
        now = time.monotonic() if now is None else now
        served = self._expire(now)
        for spec, reqs, reason in self._batcher.ready(now, force=force):
            batch_index = next(self._batch_seq)
            with self._lock:
                self._inflight.extend((spec, r) for r in reqs)
            if self.injector is not None:
                self.injector.maybe_crash(batch_index)
            self._run_batch(spec, reqs, reason, now, batch_index)
            with self._lock:
                self._inflight.clear()
            served += len(reqs)
        return served

    def _fail_inflight(self, err: BaseException) -> None:
        with self._lock:
            inflight, self._inflight = self._inflight, []
        t = time.monotonic()
        for spec, r in inflight:
            if not r.done():
                r.set_error(err, t)
                with self._lock:
                    self._stats[spec.key].failed += 1

    def drain(self, timeout: float = 30.0) -> None:
        """Serve until the queue is empty (flushing partials immediately)."""
        deadline = time.monotonic() + timeout
        while self._batcher.pending():
            self.serve_once(force=True)
            if time.monotonic() > deadline:
                raise TimeoutError("drain did not empty the queue "
                                   f"within {timeout}s")

    # -- background loop ----------------------------------------------------

    def start(self) -> "TconvServer":
        if self._thread is None:
            self._running = True
            self._thread = self._spawn_drain()
            self._supervisor = threading.Thread(
                target=self._supervise, name="tconv-serve-supervisor",
                daemon=True)
            self._supervisor.start()
        return self

    def stop(self) -> None:
        """Stop the loop and settle every queued request (served, failed,
        or — last resort — errored with :class:`ServerClosed`): no caller
        is ever left blocked on :meth:`Request.result`."""
        if self._thread is None:
            return
        self._running = False
        self._wake.set()
        self._thread.join(timeout=30.0)
        self._thread = None
        if self._supervisor is not None:
            self._supervisor.join(timeout=30.0)
            self._supervisor = None
        try:
            self.drain()  # whatever raced in after the loop exited
        except Exception:  # noqa: BLE001 — never leave requests hanging
            pass
        closing = ServerClosed("server stopped before request was served")
        self._fail_inflight(closing)
        for spec, reqs in self._batcher.pop_all():
            self._fail_requests(spec, reqs, closing)

    def _spawn_drain(self) -> threading.Thread:
        t = threading.Thread(target=self._loop_guard, name="tconv-serve",
                             daemon=True)
        t.start()
        return t

    def _loop_guard(self) -> None:
        """One drain-thread lifetime.  A crash that escapes ``serve_once``
        fails the crashed iteration's in-flight requests (never wedges
        their callers) and ends the thread; the supervisor restarts it."""
        try:
            self._loop()
        except BaseException as err:  # noqa: BLE001 — supervised
            with self._lock:
                self._drain_crashes += 1
            self._fail_inflight(err)

    def _supervise(self) -> None:
        """Restart the drain thread whenever it dies while serving."""
        while self._running:
            t = self._thread
            if t is None:
                break
            t.join(timeout=0.05)
            if self._running and not t.is_alive():
                with self._lock:
                    self._drain_restarts += 1
                self._thread = self._spawn_drain()

    def _loop(self) -> None:
        while self._running:
            if self.serve_once():
                continue
            nd = self._batcher.next_deadline()
            wait = (self.max_wait_s if nd is None
                    else max(nd - time.monotonic(), 0.0))
            self._wake.wait(min(wait, 0.05))
            self._wake.clear()

    def __enter__(self) -> "TconvServer":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # -- observability ------------------------------------------------------

    def warmup(self, *, precisions: Tuple[str, ...] = ("f32",),
               batches: Optional[Tuple[int, ...]] = None):
        """Pre-compile every admitted bucket (see ``serve/warmup.py``)."""
        return warmup_mod.warm_server(self, precisions=precisions,
                                      batches=batches)

    def stats(self) -> dict:
        """Point-in-time snapshot of every bucket's counters."""
        with self._lock:
            by_key = {spec.key: spec for spec in self._buckets.values()}
            buckets = {str(key): self._stats[key].snapshot(
                           by_key[key], self._breakers.get(key))
                       for key in self._stats}
            out = {"buckets": buckets, "rejected": self._rejected,
                   "pending": self._batcher.pending(),
                   "drain_crashes": self._drain_crashes,
                   "drain_restarts": self._drain_restarts}
        if self.injector is not None:
            out["fault_injection"] = self.injector.stats()
        return out
