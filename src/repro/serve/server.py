"""The in-process TCONV model server: admission -> batcher -> jit cache.

``TconvServer`` owns a set of named :class:`GeneratorRunner`s and serves
single-sample requests against them:

    server = TconvServer({"dcgan": make_runner("dcgan", ...)})
    server.warmup()                       # plan-table-warmed compiles
    with server:                          # background drain thread
        req = server.submit("dcgan", z, precision="int8")
        img = req.result(timeout=5)

Dataflow per request: :func:`bucketing.snap` validates the input and
picks the tuned-batch bucket (memoized per ``(model, shape, precision)``
so admission does not re-stat the plan cache per request); the
:class:`batcher.Batcher` queues it under the wait-or-flush policy; the
drain loop pops due batches, pads partials with zeros up to the bucket's
target batch (the tuned jit shape is reused — no recompiles), executes
the runner's memoized jit'd forward, and fulfills each request with its
row of the output.

Execution is synchronous under the hood (``serve_once``) so tests can
drive the server deterministically with an injected clock; ``start()``
wraps the same drain in a daemon thread for real traffic.

Numerics caveat: the models compute batch statistics inline (see
``models/gan.py``), so outputs depend on batch composition — a padded
partial batch is the *defined* behavior, matching the batched forward at
the bucket shape, not a per-request isolated forward.
"""

from __future__ import annotations

import itertools
import threading
import time
from typing import Dict, Mapping, Optional, Tuple

import jax.numpy as jnp
import numpy as np

from repro.serve import bucketing, warmup as warmup_mod
from repro.serve.batcher import Batcher, FLUSH_FULL, Request
from repro.serve.bucketing import AdmissionError, BucketKey, BucketSpec


class _BucketStats:
    """Mutable per-bucket counters (one lock-guarded instance each)."""

    __slots__ = ("requests", "completed", "failed", "batches", "flush_full",
                 "flush_deadline", "fill_sum", "wait_sum", "wait_max",
                 "compile_hits")

    def __init__(self):
        self.requests = 0
        self.completed = 0
        self.failed = 0
        self.batches = 0
        self.flush_full = 0
        self.flush_deadline = 0
        self.fill_sum = 0.0
        self.wait_sum = 0.0
        self.wait_max = 0.0
        self.compile_hits = 0

    def snapshot(self, spec: BucketSpec) -> dict:
        return {
            "target_batch": spec.target_batch,
            "tuned_layers": spec.tuned_layers,
            "total_layers": spec.total_layers,
            "tiers": dict(spec.tiers),
            "requests": self.requests,
            "completed": self.completed,
            "failed": self.failed,
            "batches": self.batches,
            "flush_full": self.flush_full,
            "flush_deadline": self.flush_deadline,
            "batch_fill_ratio": (self.fill_sum / self.batches
                                 if self.batches else 0.0),
            "queue_wait_mean_s": (self.wait_sum / self.completed
                                  if self.completed else 0.0),
            "queue_wait_max_s": self.wait_max,
            "compile_hits": self.compile_hits,
        }


class TconvServer:
    """Shape-bucketed continuous batching over GeneratorRunners."""

    def __init__(self, runners: Mapping[str, object], *,
                 max_wait_s: float = 0.05,
                 candidate_batches: Tuple[int, ...] = (8, 4, 2, 1),
                 default_batch: int = 1):
        self.runners: Dict[str, object] = dict(runners)
        self.max_wait_s = float(max_wait_s)
        self.candidate_batches = tuple(candidate_batches)
        self.default_batch = int(default_batch)
        self._batcher = Batcher(max_wait_s=max_wait_s)
        self._rid = itertools.count()
        self._lock = threading.Lock()
        self._buckets: Dict[tuple, BucketSpec] = {}
        self._stats: Dict[BucketKey, _BucketStats] = {}
        self._rejected = 0
        self._thread: Optional[threading.Thread] = None
        self._wake = threading.Event()
        self._running = False

    # -- admission ----------------------------------------------------------

    def bucket_for(self, model: str, shape, precision: str) -> BucketSpec:
        """Snap (model, shape, precision) to its bucket, memoized."""
        if model not in self.runners:
            raise AdmissionError(f"unknown model {model!r}; serving "
                                 f"{sorted(self.runners)}")
        memo_key = (model, tuple(shape), precision)
        with self._lock:
            spec = self._buckets.get(memo_key)
        if spec is None:
            spec = bucketing.snap(self.runners[model], shape, precision,
                                  candidate_batches=self.candidate_batches,
                                  default_batch=self.default_batch,
                                  name=model)
            with self._lock:
                self._buckets[memo_key] = spec
                self._stats.setdefault(spec.key, _BucketStats())
        return spec

    def submit(self, model: str, inputs, precision: str = "f32") -> Request:
        """Enqueue one single-sample request; returns its result handle."""
        arr = np.asarray(inputs, np.float32)
        try:
            spec = self.bucket_for(model, arr.shape, precision)
        except AdmissionError:
            with self._lock:
                self._rejected += 1
            raise
        req = Request(next(self._rid), model, arr, precision,
                      time.monotonic())
        self._batcher.put(spec, req)
        with self._lock:
            self._stats[spec.key].requests += 1
        self._wake.set()
        return req

    # -- execution ----------------------------------------------------------

    def _run_batch(self, spec: BucketSpec, reqs, reason: str,
                   now: float) -> None:
        runner = self.runners[spec.key.model]
        target = spec.target_batch
        precision = spec.key.precision
        stats = self._stats[spec.key]
        hit = runner.has_compiled(batch=target, precision=precision)
        xs = np.zeros((target,) + spec.key.shape, np.float32)
        for i, r in enumerate(reqs):
            xs[i] = r.inputs
        try:
            fn = runner.jitted(batch=target, precision=precision)
            out = np.asarray(fn(jnp.asarray(xs)))
        except Exception as err:  # noqa: BLE001 — fulfil, don't wedge
            t = time.monotonic()
            for r in reqs:
                r.set_error(err, t)
            with self._lock:
                stats.failed += len(reqs)
                stats.batches += 1
            return
        t_done = time.monotonic()
        for i, r in enumerate(reqs):
            r.set_result(out[i], t_done)
        waits = [max(now - r.t_enqueue, 0.0) for r in reqs]
        with self._lock:
            stats.completed += len(reqs)
            stats.batches += 1
            stats.compile_hits += int(hit)
            stats.fill_sum += len(reqs) / target
            stats.wait_sum += sum(waits)
            stats.wait_max = max(stats.wait_max, max(waits))
            if reason == FLUSH_FULL:
                stats.flush_full += 1
            else:
                stats.flush_deadline += 1

    def serve_once(self, now: Optional[float] = None, *,
                   force: bool = False) -> int:
        """Run every batch due at ``now`` (injected for tests); returns the
        number of requests served."""
        now = time.monotonic() if now is None else now
        served = 0
        for spec, reqs, reason in self._batcher.ready(now, force=force):
            self._run_batch(spec, reqs, reason, now)
            served += len(reqs)
        return served

    def drain(self, timeout: float = 30.0) -> None:
        """Serve until the queue is empty (flushing partials immediately)."""
        deadline = time.monotonic() + timeout
        while self._batcher.pending():
            self.serve_once(force=True)
            if time.monotonic() > deadline:
                raise TimeoutError("drain did not empty the queue "
                                   f"within {timeout}s")

    # -- background loop ----------------------------------------------------

    def start(self) -> "TconvServer":
        if self._thread is None:
            self._running = True
            self._thread = threading.Thread(target=self._loop,
                                            name="tconv-serve", daemon=True)
            self._thread.start()
        return self

    def stop(self) -> None:
        if self._thread is not None:
            self._running = False
            self._wake.set()
            self._thread.join(timeout=30.0)
            self._thread = None
            self.drain()  # whatever raced in after the loop exited

    def _loop(self) -> None:
        while self._running:
            if self.serve_once():
                continue
            nd = self._batcher.next_deadline()
            wait = (self.max_wait_s if nd is None
                    else max(nd - time.monotonic(), 0.0))
            self._wake.wait(min(wait, 0.05))
            self._wake.clear()

    def __enter__(self) -> "TconvServer":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # -- observability ------------------------------------------------------

    def warmup(self, *, precisions: Tuple[str, ...] = ("f32",),
               batches: Optional[Tuple[int, ...]] = None):
        """Pre-compile every admitted bucket (see ``serve/warmup.py``)."""
        return warmup_mod.warm_server(self, precisions=precisions,
                                      batches=batches)

    def stats(self) -> dict:
        """Point-in-time snapshot of every bucket's counters."""
        with self._lock:
            by_key = {spec.key: spec for spec in self._buckets.values()}
            buckets = {str(key): self._stats[key].snapshot(by_key[key])
                       for key in self._stats}
            return {"buckets": buckets, "rejected": self._rejected,
                    "pending": self._batcher.pending()}
