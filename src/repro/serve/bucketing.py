"""Shape-bucketed admission — snap each request to a tuned-plan bucket.

A serving bucket is one ``(model, input shape, precision, batch)`` key:
every request admitted to a bucket executes through the same memoized
jit'd forward at the bucket's target batch size.  Admission does two
jobs:

* **Reject** what the server cannot run without a recompile storm: an
  input whose shape is not the model's (shape-polymorphic serving would
  defeat the tuned-plan premise), or an unknown precision.
* **Snap** the rest to the best batch size the tuning substrate knows
  about.  Batch folding (plan schema v2) made large batches the fast
  path, so candidate batches are scored by how many of the model's TCONV
  layers resolve a tuned plan (user cache or shipped table) at that
  ``(problem, dtype, batch)`` key — a fully-tuned batch-8 bucket beats a
  partially-tuned batch-4 one, and with no tuned coverage at all the
  request falls back to ``default_batch`` on the ``plan_blocks``
  heuristic (correct, just not tuned).

The tier accounting rides ``core.autotune.lookup_plan`` — the same
four-tier precedence the dispatcher consults at trace time — so what the
bucket *predicts* is exactly what the compile will *consume* (asserted by
the warmup tests via ``ops.consumed_plans()``).
"""

from __future__ import annotations

import dataclasses
import logging
import math
from collections import Counter
from typing import Optional, Tuple

import jax.numpy as jnp

TIER_HEURISTIC = "heuristic"

log = logging.getLogger(__name__)


class AdmissionError(ValueError):
    """Request rejected at admission (shape/precision/model mismatch)."""


class ShedError(AdmissionError):
    """Request shed at admission for *load* reasons, not caller error.

    Unlike the base :class:`AdmissionError` (the caller sent something the
    server will never run), a shed is a point-in-time overload signal —
    the same request resubmitted later may be admitted.  Sheds are counted
    per bucket (``TconvServer.stats()['buckets'][key]['shed']``) so
    operators can see which buckets are saturating.  Defined here rather
    than in ``serve/resilience.py`` so ``batcher`` can raise it without an
    import cycle.
    """


class QueueFullError(ShedError):
    """Shed because the bucket's queue is at ``max_queue_depth``."""


class CircuitOpenError(ShedError):
    """Shed because the bucket's circuit breaker is open (see
    ``serve/resilience.py``: K consecutive batch failures trip the
    breaker; a half-open probe is admitted after the cooldown)."""


@dataclasses.dataclass(frozen=True)
class BucketKey:
    model: str
    shape: Tuple[int, ...]          # per-request input shape (no batch dim)
    precision: str                  # 'f32' | 'int8'
    batch: int                      # target (padded) execution batch

    def __str__(self) -> str:
        hw = "x".join(str(d) for d in self.shape)
        return f"{self.model}:{hw}:{self.precision}:b{self.batch}"


@dataclasses.dataclass(frozen=True)
class BucketSpec:
    """A bucket plus its plan-coverage attribution at admission time."""

    key: BucketKey
    tuned_layers: int               # layers with a user-cache/shipped plan
    total_layers: int
    tiers: Tuple[Tuple[str, int], ...]  # (tier, layer count), sorted

    @property
    def target_batch(self) -> int:
        return self.key.batch

    @property
    def fully_tuned(self) -> bool:
        return self.total_layers > 0 and self.tuned_layers == self.total_layers


def plan_tiers(runner, *, batch: int, precision: str) -> Tuple[Counter, int]:
    """(tier -> layer count, total layers) for one candidate batch size."""
    from repro.core.autotune import lookup_plan

    dtype = jnp.int8 if precision == "int8" else jnp.float32
    tiers: Counter = Counter()
    probs = runner.tconv_problems()
    for prob in probs.values():
        hit = lookup_plan(prob, dtype=dtype, batch=batch)
        tiers[hit[1] if hit is not None else TIER_HEURISTIC] += 1
    return tiers, len(probs)


def nearest_tuned_key(prob, *, dtype, batch: int) -> Optional[str]:
    """The tuned key (user cache or shipped table) closest to a problem.

    Distance is the sum of |log| ratios over the continuous dims plus
    flat penalties for kernel/stride/dtype mismatch — crude, but the
    point is operational: when a shape misses every tuned bucket, the
    admission log should say which tuned key it *almost* was, so the
    operator knows whether to extend the sweep
    (``tools/tune_sweep.py``, e.g. the large-image slice) or fix the
    model config.  Returns None when nothing tuned exists at all.
    """
    from repro.core import autotune, model_fit
    from repro.core.plan_table import shipped_table

    keys = set(autotune.shared_cache().keys())
    table = shipped_table()
    if table is not None:
        keys.update(table.keys())
    want_dt = jnp.dtype(dtype).name
    best = None
    for key in keys:
        try:
            p, dt, _hw, b = model_fit.parse_cache_key(key)
        except ValueError:
            continue
        dist = sum(abs(math.log(a / b_)) for a, b_ in
                   ((p.ih, prob.ih), (p.iw, prob.iw), (p.ic, prob.ic),
                    (p.oc, prob.oc), (b, batch))) \
            + abs(p.ks - prob.ks) + 2 * abs(p.stride - prob.stride) \
            + (0.0 if jnp.dtype(dt).name == want_dt else 1.0) \
            + (0.0 if p.padding == prob.padding else 1.0)
        if best is None or dist < best[0]:
            best = (dist, key)
    return best[1] if best else None


def snap(runner, shape, precision: str, *,
         candidate_batches: Tuple[int, ...] = (8, 4, 2, 1),
         default_batch: int = 1, name: Optional[str] = None) -> BucketSpec:
    """Admit one request: validate, then pick the best-tuned batch bucket.

    Raises :class:`AdmissionError` for a shape or precision the server
    will not run.  Candidates are scored ``(fully_tuned, tuned_layers,
    batch)`` — prefer complete plan coverage, then coverage breadth, then
    the largest batch (fold_batch makes big batches the fast path).  If no
    candidate has any tuned layer, the bucket is ``default_batch`` on the
    heuristic tier.  ``name`` overrides the bucket's model field (the
    server's serving name may differ from ``runner.name`` when one family
    is served at several geometries).
    """
    from repro.models.runner import PRECISIONS

    if precision not in PRECISIONS:
        raise AdmissionError(
            f"precision must be one of {PRECISIONS}, got {precision!r}")
    expect = runner.input_shape()
    if tuple(shape) != expect:
        raise AdmissionError(
            f"model {runner.name!r} serves inputs of shape {expect}, "
            f"got {tuple(shape)}")

    best = None  # (score, batch, tiers, total)
    for b in sorted(set(int(b) for b in candidate_batches), reverse=True):
        tiers, total = plan_tiers(runner, batch=b, precision=precision)
        tuned = total - tiers.get(TIER_HEURISTIC, 0)
        score = (tuned == total and total > 0, tuned, b)
        if best is None or score > best[0]:
            best = (score, b, tiers, total)

    _, batch, tiers, total = best
    tuned = total - tiers.get(TIER_HEURISTIC, 0)
    if tuned == 0 and batch != default_batch:
        # Nothing tuned anywhere: no reason to pad requests up to a large
        # batch — serve at the default on the heuristic tier.  Log the
        # miss with the nearest tuned key: large-image shapes landing
        # here usually mean the sweep lacks the model's decoder slice.
        batch = int(default_batch)
        probs = runner.tconv_problems()
        if probs:
            probe = max(probs.values(),
                        key=lambda p: (p.ih * p.iw, p.ic * p.oc))
            near = nearest_tuned_key(
                probe, dtype=jnp.int8 if precision == "int8"
                else jnp.float32, batch=batch)
            log.warning(
                "bucket %s:%s:%s has no tuned plan at any candidate "
                "batch; falling back to heuristic default_batch=%d "
                "(largest layer %s; nearest tuned key: %s)",
                name or runner.name,
                "x".join(str(d) for d in expect), precision, batch,
                probe, near or "<none — empty cache and no shipped table>")
        tiers, total = plan_tiers(runner, batch=batch, precision=precision)
        tuned = total - tiers.get(TIER_HEURISTIC, 0)
    return BucketSpec(
        key=BucketKey(name or runner.name, expect, precision, batch),
        tuned_layers=tuned, total_layers=total,
        tiers=tuple(sorted(tiers.items())))
