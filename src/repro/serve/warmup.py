"""Plan-table-warmed jit pre-compilation — bound first-request latency.

At server start every runner's bucket forward is compiled and executed
once on synthetic inputs, so the first real request pays a jit-cache hit
instead of a trace+compile.  Because the runner's jitted path leaves
``plan=None`` per layer, compilation consults the four plan tiers at
trace time and records each hit in ``ops.consumed_plans()`` — the
:class:`WarmupRecord` captures that delta, which is how tests (and
operators) verify the server really compiled against the shipped tables
rather than silently falling back to the heuristic.
"""

from __future__ import annotations

import dataclasses
import time
from typing import List, Optional, Tuple

import jax

from repro.serve import bucketing


@dataclasses.dataclass(frozen=True)
class WarmupRecord:
    """What one (model, batch, precision) warmup compile did."""

    model: str
    batch: int
    precision: str
    seconds: float
    tuned_layers: int
    total_layers: int
    tiers: Tuple[Tuple[str, int], ...]      # lookup_plan attribution
    consumed: Tuple[Tuple[str, str], ...]   # (cache_key, tier) at trace time


def warm_runner(runner, *, batch: int,
                precision: str = "f32") -> WarmupRecord:
    """Compile + execute one bucket forward; attribute its plan tiers."""
    from repro.kernels import ops

    before = len(ops.consumed_plans())
    t0 = time.perf_counter()
    fn = runner.jitted(batch=batch, precision=precision)
    jax.block_until_ready(fn(runner.example_inputs(batch=batch)))
    seconds = time.perf_counter() - t0
    consumed = tuple((key, tier) for key, _plan, tier
                     in ops.consumed_plans()[before:])
    tiers, total = bucketing.plan_tiers(runner, batch=batch,
                                        precision=precision)
    tuned = total - tiers.get(bucketing.TIER_HEURISTIC, 0)
    return WarmupRecord(model=runner.name, batch=batch, precision=precision,
                        seconds=seconds, tuned_layers=tuned,
                        total_layers=total,
                        tiers=tuple(sorted(tiers.items())),
                        consumed=consumed)


def warm_server(server, *, precisions: Tuple[str, ...] = ("f32",),
                batches: Optional[Tuple[int, ...]] = None
                ) -> List[WarmupRecord]:
    """Warm every (model, precision) bucket the server would admit to.

    ``batches=None`` warms each model at its admission-snapped target
    batch (what real traffic will hit); an explicit tuple warms all of
    those sizes for every model instead.
    """
    records: List[WarmupRecord] = []
    for name, runner in server.runners.items():
        for precision in precisions:
            if batches is None:
                spec = server.bucket_for(name, runner.input_shape(),
                                         precision)
                sizes: Tuple[int, ...] = (spec.target_batch,)
            else:
                sizes = tuple(batches)
            for b in sizes:
                records.append(warm_runner(runner, batch=b,
                                           precision=precision))
    return records
