"""Wait-or-flush request batching — fill fold_batch-tuned batch sizes.

One FIFO queue per bucket.  A bucket flushes when either:

* **full** — it holds at least ``target_batch`` requests: the batch the
  plans were tuned for is ready, dispatch immediately; or
* **deadline** — its oldest request has waited ``max_wait_s``: dispatch
  the partial batch (padded up to the bucket shape by the server) so p99
  queue wait is bounded by the configured deadline rather than by traffic.

Resilience hooks (ISSUE 10, ``serve/resilience.py``):

* queues are **bounded** — ``max_queue_depth`` caps each bucket's FIFO
  and :meth:`Batcher.put` sheds the overflow with
  :class:`~repro.serve.bucketing.QueueFullError` instead of letting an
  overloaded bucket grow without bound;
* requests carry an optional absolute **deadline**;
  :meth:`Batcher.pop_expired` removes the expired ones *before* batches
  form, so a dead-on-arrival request fails fast with
  ``DeadlineExceeded`` rather than occupying a batch slot;
* :meth:`Batcher.pop_all` empties every queue at shutdown so ``stop()``
  can fail whatever could not be drained — a request must never be left
  unfulfilled.

Time is injected (``ready(now=...)``) so flush decisions are
deterministic under test; the server passes ``time.monotonic()``.
All methods are thread-safe (``submit`` runs on caller threads, the drain
loop on the server thread).
"""

from __future__ import annotations

import threading
from collections import deque
from typing import Dict, List, Optional, Tuple

from repro.serve.bucketing import BucketKey, BucketSpec, QueueFullError

FLUSH_FULL = "full"
FLUSH_DEADLINE = "deadline"

# Result-slot sentinel: distinguishes "not fulfilled yet" from a
# legitimately-None payload, so Request.result can never hand an
# unfulfilled wait back as a real result (it raises TimeoutError instead).
_UNSET = object()


class Request:
    """One in-flight request: payload + a thread-safe result slot."""

    __slots__ = ("rid", "model", "inputs", "precision", "t_enqueue",
                 "deadline", "t_done", "_event", "_value", "_error")

    def __init__(self, rid: int, model: str, inputs, precision: str,
                 t_enqueue: float, deadline: Optional[float] = None):
        self.rid = rid
        self.model = model
        self.inputs = inputs
        self.precision = precision
        self.t_enqueue = t_enqueue
        # Absolute monotonic-clock deadline (None = no deadline): past it
        # the request fails fast with DeadlineExceeded instead of being
        # executed (serve/resilience.py).
        self.deadline = deadline
        self.t_done: Optional[float] = None
        self._event = threading.Event()
        self._value = _UNSET
        self._error: Optional[BaseException] = None

    def set_result(self, value, t_done: float) -> None:
        self._value = value
        self.t_done = t_done
        self._event.set()

    def set_error(self, err: BaseException, t_done: float) -> None:
        self._error = err
        self.t_done = t_done
        self._event.set()

    def done(self) -> bool:
        return self._event.is_set()

    def expired(self, now: float) -> bool:
        return self.deadline is not None and now >= self.deadline

    def result(self, timeout: Optional[float] = None):
        """The fulfilled payload; raises rather than guessing.

        An unfulfilled wait raises ``TimeoutError`` — it must never
        return ``None``, which would be indistinguishable from a real
        ``None`` payload (the ``_UNSET`` sentinel keeps the two apart
        even if a caller races the fulfilling thread).  A request failed
        by the server re-raises its typed error (``DeadlineExceeded``,
        the ladder-exhausted fault, ...).
        """
        if not self._event.wait(timeout):
            raise TimeoutError(f"request {self.rid} not served "
                               f"within {timeout}s")
        if self._error is not None:
            raise self._error
        if self._value is _UNSET:  # fulfilled event without a payload:
            raise RuntimeError(     # an invariant violation, not a result
                f"request {self.rid} signalled done with no result/error")
        return self._value

    @property
    def latency_s(self) -> Optional[float]:
        """Enqueue-to-result wall time (None while in flight)."""
        return None if self.t_done is None else self.t_done - self.t_enqueue


class Batcher:
    """Per-bucket FIFO queues with the wait-or-flush policy."""

    def __init__(self, *, max_wait_s: float = 0.05,
                 max_queue_depth: Optional[int] = None):
        self.max_wait_s = float(max_wait_s)
        self.max_queue_depth = (None if max_queue_depth is None
                                else int(max_queue_depth))
        self._lock = threading.Lock()
        self._queues: Dict[BucketKey, deque] = {}
        self._specs: Dict[BucketKey, BucketSpec] = {}

    def put(self, spec: BucketSpec, request: Request) -> None:
        """Enqueue one request; sheds with :class:`QueueFullError` when the
        bucket's queue is at ``max_queue_depth`` (the request is NOT
        enqueued — the caller owns failing/raising it)."""
        with self._lock:
            self._specs[spec.key] = spec
            q = self._queues.setdefault(spec.key, deque())
            if (self.max_queue_depth is not None
                    and len(q) >= self.max_queue_depth):
                raise QueueFullError(
                    f"bucket {spec.key} queue is full "
                    f"({len(q)}/{self.max_queue_depth}); shedding")
            q.append(request)

    def pending(self) -> int:
        with self._lock:
            return sum(len(q) for q in self._queues.values())

    def next_deadline(self) -> Optional[float]:
        """Earliest time any queued request's wait deadline expires (the
        server's sleep bound), or None if nothing is queued."""
        with self._lock:
            heads = [q[0].t_enqueue for q in self._queues.values() if q]
        return min(heads) + self.max_wait_s if heads else None

    def pop_expired(self, now: float) -> List[Tuple[BucketSpec, list]]:
        """Remove every request whose deadline passed; FIFO order kept.

        Called by the server at the top of each ``serve_once`` tick with
        the same ``now`` it hands to :meth:`ready`, so an expired request
        fails fast with ``DeadlineExceeded`` instead of occupying a slot
        in the batch that forms right after.
        """
        out: List[Tuple[BucketSpec, list]] = []
        with self._lock:
            for key, q in self._queues.items():
                dead = [r for r in q if r.expired(now)]
                if dead:
                    live = [r for r in q if not r.expired(now)]
                    q.clear()
                    q.extend(live)
                    out.append((self._specs[key], dead))
        return out

    def pop_all(self) -> List[Tuple[BucketSpec, list]]:
        """Empty every queue (shutdown): the caller fulfils or fails each
        popped request so none is left waiting forever."""
        out: List[Tuple[BucketSpec, list]] = []
        with self._lock:
            for key, q in self._queues.items():
                if q:
                    out.append((self._specs[key], list(q)))
                    q.clear()
        return out

    def ready(self, now: float, *,
              force: bool = False) -> List[Tuple[BucketSpec, list, str]]:
        """Pop every batch due at ``now`` as (spec, requests, reason).

        Full batches flush regardless of age; a remaining partial flushes
        once its oldest member has waited ``max_wait_s`` (or immediately
        with ``force=True`` — shutdown/drain).
        """
        out: List[Tuple[BucketSpec, list, str]] = []
        with self._lock:
            for key, q in self._queues.items():
                spec = self._specs[key]
                target = max(spec.target_batch, 1)
                while len(q) >= target:
                    out.append((spec, [q.popleft() for _ in range(target)],
                                FLUSH_FULL))
                if q and (force or
                          now - q[0].t_enqueue >= self.max_wait_s):
                    out.append((spec, list(q), FLUSH_DEADLINE))
                    q.clear()
        return out
