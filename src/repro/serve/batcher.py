"""Wait-or-flush request batching — fill fold_batch-tuned batch sizes.

One FIFO queue per bucket.  A bucket flushes when either:

* **full** — it holds at least ``target_batch`` requests: the batch the
  plans were tuned for is ready, dispatch immediately; or
* **deadline** — its oldest request has waited ``max_wait_s``: dispatch
  the partial batch (padded up to the bucket shape by the server) so p99
  queue wait is bounded by the configured deadline rather than by traffic.

Time is injected (``ready(now=...)``) so flush decisions are
deterministic under test; the server passes ``time.monotonic()``.
All methods are thread-safe (``submit`` runs on caller threads, the drain
loop on the server thread).
"""

from __future__ import annotations

import threading
from collections import deque
from typing import Dict, List, Optional, Tuple

from repro.serve.bucketing import BucketKey, BucketSpec

FLUSH_FULL = "full"
FLUSH_DEADLINE = "deadline"


class Request:
    """One in-flight request: payload + a thread-safe result slot."""

    __slots__ = ("rid", "model", "inputs", "precision", "t_enqueue",
                 "t_done", "_event", "_value", "_error")

    def __init__(self, rid: int, model: str, inputs, precision: str,
                 t_enqueue: float):
        self.rid = rid
        self.model = model
        self.inputs = inputs
        self.precision = precision
        self.t_enqueue = t_enqueue
        self.t_done: Optional[float] = None
        self._event = threading.Event()
        self._value = None
        self._error: Optional[BaseException] = None

    def set_result(self, value, t_done: float) -> None:
        self._value = value
        self.t_done = t_done
        self._event.set()

    def set_error(self, err: BaseException, t_done: float) -> None:
        self._error = err
        self.t_done = t_done
        self._event.set()

    def done(self) -> bool:
        return self._event.is_set()

    def result(self, timeout: Optional[float] = None):
        if not self._event.wait(timeout):
            raise TimeoutError(f"request {self.rid} not served "
                               f"within {timeout}s")
        if self._error is not None:
            raise self._error
        return self._value

    @property
    def latency_s(self) -> Optional[float]:
        """Enqueue-to-result wall time (None while in flight)."""
        return None if self.t_done is None else self.t_done - self.t_enqueue


class Batcher:
    """Per-bucket FIFO queues with the wait-or-flush policy."""

    def __init__(self, *, max_wait_s: float = 0.05):
        self.max_wait_s = float(max_wait_s)
        self._lock = threading.Lock()
        self._queues: Dict[BucketKey, deque] = {}
        self._specs: Dict[BucketKey, BucketSpec] = {}

    def put(self, spec: BucketSpec, request: Request) -> None:
        with self._lock:
            self._specs[spec.key] = spec
            self._queues.setdefault(spec.key, deque()).append(request)

    def pending(self) -> int:
        with self._lock:
            return sum(len(q) for q in self._queues.values())

    def next_deadline(self) -> Optional[float]:
        """Earliest time any queued request's wait deadline expires (the
        server's sleep bound), or None if nothing is queued."""
        with self._lock:
            heads = [q[0].t_enqueue for q in self._queues.values() if q]
        return min(heads) + self.max_wait_s if heads else None

    def ready(self, now: float, *,
              force: bool = False) -> List[Tuple[BucketSpec, list, str]]:
        """Pop every batch due at ``now`` as (spec, requests, reason).

        Full batches flush regardless of age; a remaining partial flushes
        once its oldest member has waited ``max_wait_s`` (or immediately
        with ``force=True`` — shutdown/drain).
        """
        out: List[Tuple[BucketSpec, list, str]] = []
        with self._lock:
            for key, q in self._queues.items():
                spec = self._specs[key]
                target = max(spec.target_batch, 1)
                while len(q) >= target:
                    out.append((spec, [q.popleft() for _ in range(target)],
                                FLUSH_FULL))
                if q and (force or
                          now - q[0].t_enqueue >= self.max_wait_s):
                    out.append((spec, list(q), FLUSH_DEADLINE))
                    q.clear()
        return out
