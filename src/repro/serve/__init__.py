"""Production serving for the paper's TCONV models (DESIGN.md §9).

Shape-bucketed continuous batching over the :class:`GeneratorRunner`
contract: requests snap to the ``(model, shape, precision, batch)``
bucket with tuned-plan coverage (``bucketing``), a wait-or-flush batcher
fills fold_batch-tuned batch sizes with a bounded deadline (``batcher``),
and server start pre-compiles every bucket against the shipped plan
tables (``warmup``).  Entry point: :class:`TconvServer` (``server``).

The rainy-day half lives in ``resilience`` (DESIGN.md §9.4): per-request
deadlines, bounded queues with load shedding, a degradation ladder
(tuned -> heuristic -> [f32] -> lax), per-bucket circuit breakers,
drain-loop supervision, and the deterministic :class:`FaultInjector`
chaos hook.
"""

from repro.serve.batcher import Batcher, Request
from repro.serve.bucketing import (AdmissionError, BucketKey, BucketSpec,
                                   CircuitOpenError, QueueFullError,
                                   ShedError, snap)
from repro.serve.resilience import (CircuitBreaker, DeadlineExceeded,
                                    DegradationLadder, FaultInjector,
                                    InjectedFault, LadderExhausted,
                                    ResilienceConfig, TransientFault)
from repro.serve.server import ServerClosed, TconvServer
from repro.serve.warmup import WarmupRecord, warm_runner, warm_server

__all__ = [
    "AdmissionError", "Batcher", "BucketKey", "BucketSpec",
    "CircuitBreaker", "CircuitOpenError", "DeadlineExceeded",
    "DegradationLadder", "FaultInjector", "InjectedFault",
    "LadderExhausted", "QueueFullError", "Request", "ResilienceConfig",
    "ServerClosed", "ShedError", "TconvServer", "TransientFault",
    "WarmupRecord", "snap", "warm_runner", "warm_server",
]
