"""Production serving for the paper's TCONV models (DESIGN.md §9).

Shape-bucketed continuous batching over the :class:`GeneratorRunner`
contract: requests snap to the ``(model, shape, precision, batch)``
bucket with tuned-plan coverage (``bucketing``), a wait-or-flush batcher
fills fold_batch-tuned batch sizes with a bounded deadline (``batcher``),
and server start pre-compiles every bucket against the shipped plan
tables (``warmup``).  Entry point: :class:`TconvServer` (``server``).
"""

from repro.serve.batcher import Batcher, Request
from repro.serve.bucketing import (AdmissionError, BucketKey, BucketSpec,
                                   snap)
from repro.serve.server import TconvServer
from repro.serve.warmup import WarmupRecord, warm_runner, warm_server

__all__ = [
    "AdmissionError", "Batcher", "BucketKey", "BucketSpec", "Request",
    "TconvServer", "WarmupRecord", "snap", "warm_runner", "warm_server",
]
