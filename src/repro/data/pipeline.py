"""Sharded synthetic data pipelines with deterministic skip-ahead.

Production posture (DESIGN.md §6):

* **Determinism**: every batch is a pure function of ``(seed, step)`` — no
  iterator state.  Restart/elastic-rescale resumes at any step without
  replaying the stream (the classic skip-ahead used for preemption
  recovery), and straggler re-dispatch can recompute any shard's batch
  independently.
* **Sharding**: ``global_batch`` samples are laid out along the DP axes;
  each host materializes only its addressable shard
  (``jax.make_array_from_callback``), so no host ever holds the global
  batch.
* **Prefetch**: a small background-thread prefetch queue overlaps host
  batch synthesis with device compute.
"""

from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Any, Callable, Dict, Iterator, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P


@dataclasses.dataclass(frozen=True)
class DataConfig:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    kind: str = "lm"          # lm | image | latent
    image_size: int = 64
    channels: int = 3
    z_dim: int = 100


def _batch_rng(cfg: DataConfig, step: int) -> np.random.Generator:
    return np.random.default_rng(np.random.SeedSequence([cfg.seed, step]))


def synth_lm_batch(cfg: DataConfig, step: int) -> Dict[str, np.ndarray]:
    """Markov-ish synthetic token stream (pure fn of (seed, step))."""
    rng = _batch_rng(cfg, step)
    b, l = cfg.global_batch, cfg.seq_len
    base = rng.integers(0, cfg.vocab, (b, 1), dtype=np.int32)
    drift = rng.integers(-64, 65, (b, l), dtype=np.int32)
    toks = np.abs(base + np.cumsum(drift, axis=1)) % cfg.vocab
    tokens = toks.astype(np.int32)
    targets = np.roll(tokens, -1, axis=1)
    return {"tokens": tokens, "targets": targets}


def synth_image_batch(cfg: DataConfig, step: int) -> Dict[str, np.ndarray]:
    rng = _batch_rng(cfg, step)
    img = rng.standard_normal(
        (cfg.global_batch, cfg.image_size, cfg.image_size, cfg.channels),
        dtype=np.float32)
    return {"images": np.tanh(img)}


def synth_latent_batch(cfg: DataConfig, step: int) -> Dict[str, np.ndarray]:
    rng = _batch_rng(cfg, step)
    return {"z": rng.standard_normal((cfg.global_batch, cfg.z_dim),
                                     dtype=np.float32)}


_KINDS: Dict[str, Callable] = {"lm": synth_lm_batch, "image": synth_image_batch,
                               "latent": synth_latent_batch}


def make_batch(cfg: DataConfig, step: int, mesh=None,
               spec: Optional[P] = None) -> Dict[str, Any]:
    """Materialize the batch for ``step``; device-put sharded when a mesh
    is given (each device gets exactly its shard)."""
    host = _KINDS[cfg.kind](cfg, step)
    if mesh is None:
        return {k: jnp.asarray(v) for k, v in host.items()}
    out = {}
    for k, v in host.items():
        s = spec
        if s is None:
            axes = tuple(a for a in mesh.axis_names if a in ("pod", "data"))
            s = P(axes) if v.shape[0] % int(np.prod([mesh.shape[a] for a in axes])) == 0 else P()
        sh = NamedSharding(mesh, s)
        out[k] = jax.make_array_from_callback(
            v.shape, sh, lambda idx, vv=v: vv[idx])
    return out


class Prefetcher:
    """Background-thread prefetch: overlap host synthesis with device work."""

    def __init__(self, cfg: DataConfig, mesh=None, start_step: int = 0,
                 depth: int = 2):
        self.cfg, self.mesh = cfg, mesh
        self._q: "queue.Queue" = queue.Queue(maxsize=depth)
        self._stop = threading.Event()
        self._step = start_step
        self._thread = threading.Thread(target=self._work, daemon=True)
        self._thread.start()

    def _work(self):
        step = self._step
        while not self._stop.is_set():
            try:
                self._q.put((step, make_batch(self.cfg, step, self.mesh)),
                            timeout=0.5)
                step += 1
            except queue.Full:
                continue

    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        return self._q.get()

    def close(self):
        self._stop.set()
        self._thread.join(timeout=2)
