"""Training launcher: ``python -m repro.launch.train --arch qwen2.5-3b``.

On CPU this runs the *smoke* config by default (use ``--full`` on real
hardware).  Demonstrates the full substrate: sharded synthetic data,
AdamW, activation sharding, async checkpointing, preemption-safe resume,
elastic re-meshing.
"""

from __future__ import annotations

import argparse

import jax

from repro.configs import registry
from repro.data.pipeline import DataConfig
from repro.checkpoint.manager import CheckpointManager
from repro.optim import adamw
from repro.models import lm
from repro.runtime import steps as steps_mod
from repro.launch.mesh import use_mesh
from repro.runtime.fault_tolerance import (LoopConfig, PreemptionSimulator,
                                           TrainLoop, elastic_mesh)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--full", action="store_true",
                    help="use the full published config (needs real HW)")
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--model-parallel", type=int, default=1)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--resume", action="store_true")
    ap.add_argument("--preempt-at", type=int, default=None)
    ap.add_argument("--lr", type=float, default=3e-4)
    args = ap.parse_args()

    spec = registry.get(args.arch)
    cfg = spec.model if args.full else spec.smoke
    mesh = elastic_mesh(args.model_parallel)
    print(f"[mesh] {dict(zip(mesh.axis_names, mesh.devices.shape))} "
          f"arch={cfg.name}")

    opt_cfg = adamw.AdamWConfig(lr=args.lr, total_steps=args.steps,
                                warmup_steps=max(args.steps // 10, 1),
                                state_dtype=cfg.opt_state_dtype)
    with use_mesh(mesh):
        bundle = steps_mod.make_train_step(cfg, mesh, opt_cfg,
                                           batch=args.batch, seq=args.seq)
        params, specs = lm.init(cfg, jax.random.PRNGKey(0))
        state = {"params": params, "opt": adamw.init(params, opt_cfg)}

        data_cfg = DataConfig(vocab=cfg.vocab, seq_len=args.seq,
                              global_batch=args.batch)
        ckpt = CheckpointManager(args.ckpt_dir)
        loop = TrainLoop(
            bundle.fn, state, data_cfg,
            LoopConfig(total_steps=args.steps,
                       ckpt_every=max(args.steps // 3, 1), log_every=5),
            ckpt, mesh=mesh,
            specs={"params": specs, "opt": adamw.state_specs(specs)},
            preempt=PreemptionSimulator(args.preempt_at))
        if args.resume:
            loop.resume()
        state, metrics = loop.run()
        print(f"[done] final loss "
              f"{float(jax.device_get(metrics['loss'])):.4f}")


if __name__ == "__main__":
    main()
