"""Serving launcher: batched prefill + decode with a KV cache.

``python -m repro.launch.serve --arch qwen2.5-3b --tokens 32 --batch 4``

Runs the smoke config on CPU (``--full`` for real hardware).  Exercises
the serve path the decode_* dry-run cells lower: prefill the prompt, then
step the sequence-shardable cache one token at a time.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import registry
from repro.models import lm
from repro.launch.mesh import use_mesh
from repro.runtime.fault_tolerance import elastic_mesh


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--model-parallel", type=int, default=1)
    args = ap.parse_args()

    spec = registry.get(args.arch)
    cfg = spec.model if args.full else spec.smoke
    mesh = elastic_mesh(args.model_parallel)
    max_seq = args.prompt_len + args.tokens

    with use_mesh(mesh):
        params, _ = lm.init(cfg, jax.random.PRNGKey(0))
        rng = jax.random.PRNGKey(1)
        prompt = jax.random.randint(rng, (args.batch, args.prompt_len), 0,
                                    cfg.vocab)
        cache = lm.init_cache(cfg, args.batch, max_seq, dtype=jnp.float32)

        decode = jax.jit(lambda p, t, c: lm.decode(cfg, p, t, c))
        # Prefill via repeated decode (teacher forcing the prompt).
        tok = prompt[:, :1]
        t0 = time.time()
        for i in range(args.prompt_len):
            logits, cache = decode(params, prompt[:, i:i + 1], cache)
        out = []
        for _ in range(args.tokens):
            tok = jnp.argmax(logits[:, -1], axis=-1)[:, None]
            out.append(tok)
            logits, cache = decode(params, tok, cache)
        jax.block_until_ready(logits)
        dt = time.time() - t0
        total = args.batch * (args.prompt_len + args.tokens)
        print(f"[serve] {cfg.name}: {total} tokens in {dt:.2f}s "
              f"({total / dt:.1f} tok/s, batch={args.batch})")
        print("[serve] sample continuation:",
              jnp.concatenate(out, 1)[0, :16].tolist())


if __name__ == "__main__":
    main()
