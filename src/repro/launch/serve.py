"""Serving launcher: batched prefill + decode with a KV cache.

``python -m repro.launch.serve --arch qwen2.5-3b --tokens 32 --batch 4``

Runs the smoke config on CPU (``--full`` for real hardware).  Exercises
the serve path the decode_* dry-run cells lower: prefill the prompt, then
step the sequence-shardable cache one token at a time.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import registry
from repro.models import lm
from repro.launch.mesh import use_mesh
from repro.runtime.fault_tolerance import elastic_mesh


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--tokens", type=int, default=32)
    ap.add_argument("--model-parallel", type=int, default=1)
    args = ap.parse_args()

    spec = registry.get(args.arch)
    cfg = spec.model if args.full else spec.smoke
    mesh = elastic_mesh(args.model_parallel)
    max_seq = args.prompt_len + args.tokens

    with use_mesh(mesh):
        params, _ = lm.init(cfg, jax.random.PRNGKey(0))
        rng = jax.random.PRNGKey(1)
        prompt = jax.random.randint(rng, (args.batch, args.prompt_len), 0,
                                    cfg.vocab)
        cache = lm.init_cache(cfg, args.batch, max_seq, dtype=jnp.float32)

        decode = jax.jit(lambda p, t, c: lm.decode(cfg, p, t, c))

        @jax.jit
        def prefill(p, prompt_toks, c):
            # The whole prompt in ONE dispatch: scan the single-token
            # decode over prompt positions inside a single jit, instead of
            # O(prompt_len) separate dispatches (each one a full host
            # round-trip).  The cache carry is scan-stable because its
            # fill level is a traced int32 scalar.
            def step(c, tok):
                logits, c = lm.decode(cfg, p, tok[:, None], c)
                return c, logits

            c, all_logits = jax.lax.scan(
                step, c, jnp.moveaxis(prompt_toks, 1, 0))
            return all_logits[-1], c

        t0 = time.time()
        logits, cache = prefill(params, prompt, cache)
        jax.block_until_ready(logits)
        t_prefill = time.time() - t0

        # Timed loop is decode-only: one token per dispatch, by design.
        out = []
        t0 = time.time()
        for _ in range(args.tokens):
            tok = jnp.argmax(logits[:, -1], axis=-1)[:, None]
            out.append(tok)
            logits, cache = decode(params, tok, cache)
        jax.block_until_ready(logits)
        dt = time.time() - t0
        n_prefill = args.batch * args.prompt_len
        n_decode = args.batch * args.tokens
        print(f"[serve] {cfg.name}: prefill {n_prefill} tokens in "
              f"{t_prefill:.2f}s (one dispatch), decode {n_decode} tokens "
              f"in {dt:.2f}s ({n_decode / dt:.1f} tok/s, "
              f"batch={args.batch})")
        print("[serve] sample continuation:",
              jnp.concatenate(out, 1)[0, :16].tolist())


if __name__ == "__main__":
    main()
