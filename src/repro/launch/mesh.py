"""Production mesh construction (prompt-fixed topology).

Single pod:  (16, 16)    axes ('data', 'model')      — 256 chips
Multi-pod:   (2, 16, 16) axes ('pod', 'data', 'model') — 512 chips

Defined as functions so importing this module never touches jax device
state (device count is locked at first backend init — dryrun.py must set
XLA_FLAGS before importing anything).
"""

from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_mesh(shape, axes):
    """Arbitrary mesh (tests use small ones, e.g. (2, 2))."""
    return jax.make_mesh(tuple(shape), tuple(axes))


def make_host_mesh(model_parallel: int = 1):
    """Mesh over whatever devices exist (CPU smoke: 1 device)."""
    n = len(jax.devices())
    return jax.make_mesh((n // model_parallel, model_parallel),
                         ("data", "model"))


def use_mesh(mesh):
    """Context manager activating ``mesh`` across jax versions.

    Newer jax exposes ``jax.set_mesh`` (a context manager); on older
    releases (e.g. 0.4.x) ``Mesh`` itself is the context manager that sets
    the physical mesh for bare-``PartitionSpec`` sharding constraints.
    """
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    return mesh


def data_axes(mesh) -> tuple:
    """Axes that carry the global batch (pod included when present)."""
    return tuple(a for a in mesh.axis_names if a in ("pod", "data"))
