import os
os.environ["XLA_FLAGS"] = (os.environ.get("PRE_XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count="
                           + os.environ.get("DRYRUN_DEVICES", "512")).strip()
"""Multi-pod dry-run: lower + compile every (arch x shape x mesh) cell.

MUST be run as a script/module (the XLA flag above executes before any jax
import — jax locks the device count at first backend init):

    PYTHONPATH=src python -m repro.launch.dryrun --arch qwen2-7b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all --multi-pod
    PYTHONPATH=src python -m repro.launch.dryrun --all --both --out results/dryrun

Per cell it records: compile ok, per-device memory_analysis, cost_analysis
FLOPs/bytes, and collective-traffic bytes parsed from the post-SPMD HLO —
everything EXPERIMENTS.md §Dry-run/§Roofline consumes.
"""

import argparse  # noqa: E402
import json  # noqa: E402
import pathlib  # noqa: E402
import sys  # noqa: E402
import time  # noqa: E402
import traceback  # noqa: E402

import jax  # noqa: E402

from repro.configs import registry  # noqa: E402
from repro.distributed import hlo  # noqa: E402
from repro.launch.mesh import make_production_mesh, use_mesh  # noqa: E402
from repro.runtime import steps as steps_mod  # noqa: E402


def input_specs(arch: str, shape: str, mesh):
    """ShapeDtypeStruct stand-ins (sharding-annotated) for every model input."""
    return steps_mod.make_step_for_cell(arch, shape, mesh).abstract_args


def _mesh(multi_pod: bool):
    """Production mesh, or a scaled trial mesh via DRYRUN_MESH=4x4 etc."""
    override = os.environ.get("DRYRUN_MESH")
    if override:
        dims = tuple(int(x) for x in override.split("x"))
        axes = ("pod", "data", "model")[-len(dims):]
        return jax.make_mesh(dims, axes)
    return make_production_mesh(multi_pod=multi_pod)


def run_cell(arch: str, shape: str, *, multi_pod: bool, verbose: bool = True):
    mesh = _mesh(multi_pod)
    rec = {"arch": arch, "shape": shape,
           "mesh": "x".join(str(s) for s in mesh.devices.shape),
           "devices": mesh.devices.size}
    t0 = time.time()
    try:
        with use_mesh(mesh):
            bundle = steps_mod.make_step_for_cell(arch, shape, mesh)
            lowered = bundle.fn.lower(*bundle.abstract_args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
            mem = hlo.memory_analysis_dict(compiled)
            cost = hlo.flops_and_bytes(compiled)
            text = compiled.as_text()
            coll = hlo.collective_bytes(text)
            counts = hlo.collective_count(text)
            cost.update(hlo.weighted_cost(text))
            cost["attn_core_bytes"] = hlo.scoped_bytes(text, "attn_core")
            cost["score_like_bytes"] = hlo.score_like_bytes(text)
            cost["nested_scan_bytes"] = hlo.nested_scan_bytes(text)
        rec.update(ok=True, kind=bundle.kind, lower_s=round(t_lower, 1),
                   compile_s=round(t_compile, 1), memory=mem, cost=cost,
                   collective_bytes=coll, collective_counts=counts)
        if verbose:
            hbm_gb = mem["total_hbm_bytes"] / 2**30
            print(f"[OK] {arch:24s} {shape:12s} {rec['mesh']:8s} "
                  f"kind={bundle.kind:7s} hbm/dev={hbm_gb:7.2f}GiB "
                  f"flops/dev={cost['flops']:.3e} coll={coll.get('total',0):.3e}B "
                  f"(lower {t_lower:.0f}s compile {t_compile:.0f}s)",
                  flush=True)
    except Exception as e:  # noqa: BLE001 — record and continue
        rec.update(ok=False, error=f"{type(e).__name__}: {e}",
                   trace=traceback.format_exc()[-2000:])
        if verbose:
            print(f"[FAIL] {arch} {shape} {rec['mesh']}: {rec['error']}",
                  flush=True)
    return rec


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both", action="store_true",
                    help="run each cell on single-pod AND multi-pod meshes")
    ap.add_argument("--out", default=None, help="JSON output path")
    args = ap.parse_args()

    cells = registry.all_cells() if args.all else [(args.arch, args.shape)]
    meshes = [False, True] if args.both else [args.multi_pod]

    results = []
    for arch, shape in cells:
        for mp in meshes:
            results.append(run_cell(arch, shape, multi_pod=mp))

    if args.out:
        path = pathlib.Path(args.out)
        path.parent.mkdir(parents=True, exist_ok=True)
        path.write_text(json.dumps(results, indent=1))
        print(f"wrote {path}")
    n_fail = sum(not r["ok"] for r in results)
    print(f"{len(results) - n_fail}/{len(results)} cells compiled")
    return 1 if n_fail else 0


if __name__ == "__main__":
    sys.exit(main())
