"""Scale table: the 32-cell (arch x shape) roofline from the dry-run.

Reads ``results/dryrun_final.json`` (falling back to dryrun_all.json),
derives the three roofline terms per cell on the single-pod mesh
(197 TFLOP/s bf16, 819 GB/s HBM, 50 GB/s/link ICI), the dominant
bottleneck, MODEL_FLOPS = 6*N*D (train) / 2*N*D (inference) with
N = active params, and the useful-compute ratio.

Two memory terms are reported for attention-bearing cells:
  * t_mem      — the raw HLO-derived byte proxy (pure-XLA execution);
  * t_mem_fl   — flash-corrected: attention-interior traffic (tagged via
    named_scope + nested-scan structural attribution) stays in VMEM when
    the validated Pallas flash kernel (kernels/flash_attention.py) runs
    the layer on real TPUs.  Both are recorded in EXPERIMENTS §Roofline.

This module is EXPERIMENTS.md §Roofline's generator.
"""

from __future__ import annotations

import json
import pathlib

from benchmarks.common import emit
from repro.configs import registry

PEAK = 197e12
HBM = 819e9
ICI = 50e9


def active_params(arch: str) -> float:
    spec = registry.get(arch)
    cfg = spec.model
    n_total = cfg.param_count()
    if cfg.n_experts:
        ff = cfg.moe_d_ff or cfg.d_ff
        per_expert = 3 * cfg.d_model * ff
        inactive = (cfg.n_experts - cfg.top_k) * per_expert * cfg.n_layers
        return n_total - inactive
    return n_total


def tokens_per_step(shape: str) -> int:
    seq, bs, kind = registry.SHAPES[shape]
    return seq * bs if kind in ("train", "prefill") else bs


def main(path: str = None) -> None:
    f = None
    for cand in ([path] if path else []) + ["results/dryrun_final.json",
                                            "results/dryrun_all.json"]:
        if cand and pathlib.Path(cand).exists():
            f = pathlib.Path(cand)
            break
    if f is None:
        emit("roofline_skipped", None,
             "no dry-run JSON; run launch.dryrun --all --both")
        return
    rows = json.load(f.open())
    for r in rows:
        if not r["ok"] or r["mesh"] != "16x16":
            continue
        c = r["cost"]
        flops = c.get("weighted_dot_flops", 0.0)
        byts = c.get("weighted_bytes_proxy", 0.0)
        attn = max(c.get("attn_core_bytes", 0) + c.get("score_like_bytes", 0),
                   c.get("nested_scan_bytes", 0))
        coll = r["collective_bytes"].get("total", 0)
        t_c = flops / PEAK
        t_m = byts / HBM
        t_mf = max(byts - attn, 0) / HBM
        t_x = coll / ICI
        terms = {"compute": t_c, "memory": t_m, "collective": t_x}
        bottleneck = max(terms, key=terms.get)
        t_bound = max(terms.values())
        t_bound_fl = max(t_c, t_mf, t_x)
        kind = r.get("kind", "train")
        mult = 6 if kind.startswith("train") else 2
        model_flops = mult * active_params(r["arch"]) \
            * tokens_per_step(r["shape"]) / r["devices"]
        ratio = model_flops / max(flops, 1.0)
        frac = (model_flops / PEAK) / max(t_bound, 1e-12)
        frac_fl = (model_flops / PEAK) / max(t_bound_fl, 1e-12)
        emit(f"roofline_{r['arch']}_{r['shape']}", t_bound * 1e6,
             f"t_comp={t_c:.4f}s;t_mem={t_m:.4f}s;t_mem_fl={t_mf:.4f}s;"
             f"t_coll={t_x:.4f}s;bottleneck={bottleneck};"
             f"useful_ratio={min(ratio, 99):.2f};"
             f"frac={min(frac,1.0):.3f};frac_flash={min(frac_fl,1.0):.3f};"
             f"hbm_gib={r['memory']['total_hbm_bytes']/2**30:.1f}")


if __name__ == "__main__":
    main()
