"""Paper §V-F: performance-model validation.

The paper validates its analytical model within 10% of measured hardware.
Without a TPU we validate against the *compiler*: the model's FLOP and
byte counts for the pure-XLA methods must match ``cost_analysis()`` of
the actually-compiled programs, and the MM2IM kernel's issued-MAC formula
must match the grid geometry exactly.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.core import perf_model
from repro.core.maps import TConvProblem
from repro.kernels import ref
from repro.kernels.baselines import tdc_macs, zero_insertion_macs
from repro.kernels.mm2im_pallas import plan_blocks

PROBLEMS = [
    TConvProblem(8, 8, 64, 5, 32, 2),
    TConvProblem(16, 16, 32, 3, 16, 1),
    TConvProblem(4, 4, 128, 5, 64, 2),
    TConvProblem(9, 9, 96, 7, 48, 2),
]


def xla_flops(fn, *args) -> float:
    comp = jax.jit(fn).lower(*args).compile()
    ca = comp.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    return float(ca.get("flops", 0.0))


def main() -> None:
    for p in PROBLEMS:
        x = jnp.zeros((1, p.ih, p.iw, p.ic), jnp.float32)
        w = jnp.zeros((p.ks, p.ks, p.oc, p.ic), jnp.float32)

        # Unfused IOM: model says 2*M*N*K (+ scatter adds).
        got = xla_flops(lambda a, b: ref.iom_reference(a, b, stride=p.stride), x, w)
        want = 2.0 * p.macs
        emit(f"V-F_iom_unfused_{p.ih}x{p.ic}x{p.ks}s{p.stride}", 0.0,
             f"model={want:.3e};xla={got:.3e};ratio={got/want:.3f}")

        # Zero-insertion: model MACs == conv over dilated input.
        got = xla_flops(lambda a, b: ref.tconv_direct(a, b, stride=p.stride), x, w)
        want = 2.0 * zero_insertion_macs(p.ih, p.iw, p.ic, p.ks, p.oc, p.stride)
        emit(f"V-F_zero_insertion_{p.ih}x{p.ic}x{p.ks}s{p.stride}", 0.0,
             f"model={want:.3e};xla={got:.3e};ratio={got/want:.3f}")

        # MM2IM issued tile-MACs: formula vs explicit grid-geometry count
        # (ceil-quantized to whole 128^3 MXU tiles per launch — the same
        # quantization batch folding exploits).
        est = perf_model.mm2im_estimate(p, batch=1, bits=8)
        block_oh, block_oc = plan_blocks(p.ih, p.iw, p.ic, p.ks, p.oc,
                                         p.stride, p.padding, in_bytes=1)
        s = p.stride
        ct, _ = ref.crop_offsets(p.ks, s, p.padding)
        bi = block_oh // s
        delta = -(-max(p.ks - 1 - ct, 0) // s)
        eps = (ct - 1) // s
        n_slab = bi + delta + eps + 1
        n_j = -(-p.oh // block_oh)
        n_c = -(-p.oc // block_oc)
        mxu = perf_model.V5E.mxu_dim
        manual = n_c * n_j * perf_model.mxu_tiles(
            n_slab * p.iw, p.ks ** 2 * block_oc, p.ic, mxu) * mxu ** 3
        emit(f"V-F_mm2im_issued_{p.ih}x{p.ic}x{p.ks}s{p.stride}", 0.0,
             f"model={est.issued_macs};manual={manual};"
             f"match={est.issued_macs == manual}")


if __name__ == "__main__":
    main()
