"""Paper §V-F: performance-model validation — and it can actually fail.

The paper validates its analytical model within 10% of measured hardware.
Without a TPU we validate against the *compiler* and against *recorded
measurements*:

* the model's FLOP counts for the pure-XLA methods must match
  ``cost_analysis()`` of the actually-compiled programs (within 10% plus
  the explicit border-tap allowance);
* the model's byte counts must be the same order as the compiler's
  ``bytes accessed`` (loose band — XLA counts scatter temporaries we
  deliberately exclude — but tight enough to catch a bits-vs-bytes unit
  slip, which is 4-8x);
* the MM2IM issued-MAC formula must match an explicit manual
  grid-geometry count, for the unfolded grid **and** the folded batch-8
  geometry (the fold collapses the per-element launch axis:
  ``n_launches = n_c * n_j`` and the MatMul M-dimension grows to
  ``batch * n_slab * iw``);
* the rank-agreement score (``core/model_fit.rank_agreement``) over the
  committed ``BENCH_mm2im.json`` head-to-heads, scored by both the raw
  roofline and the shipped calibration — the calibrated model must not
  misrank more decisive pairs than the roofline it replaces.

Every check is a hard ``assert``: a mismatch makes this module (and the
``benchmarks.run`` harness, which counts module failures into its exit
code) exit nonzero instead of burying ``match=False`` inside a derived
string.
"""

from __future__ import annotations

import json
from pathlib import Path

import jax
import jax.numpy as jnp

from benchmarks.common import emit
from repro.core import model_fit, perf_model
from repro.core.maps import TConvProblem
from repro.kernels import ref
from repro.kernels.baselines import zero_insertion_macs
from repro.kernels.mm2im_pallas import plan_blocks

PROBLEMS = [
    TConvProblem(8, 8, 64, 5, 32, 2),
    TConvProblem(16, 16, 32, 3, 16, 1),
    TConvProblem(4, 4, 128, 5, 64, 2),
    TConvProblem(9, 9, 96, 7, 48, 2),
]

#: Model-vs-XLA byte ratio band.  XLA's ``bytes accessed`` includes
#: scatter/pad temporaries the HBM model deliberately excludes, so this
#: is a unit-error net (a bits-for-bytes slip is 4-8x), not a 10% gate.
BYTES_BAND = (1 / 3.0, 3.0)


def xla_costs(fn, *args) -> tuple:
    comp = jax.jit(fn).lower(*args).compile()
    ca = comp.cost_analysis()
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    return float(ca.get("flops", 0.0)), float(ca.get("bytes accessed", 0.0))


def _manual_issued_macs(p: TConvProblem, block_oh: int, block_oc: int,
                        *, batch: int = 1, fold_batch: bool = False) -> int:
    """Issued MXU MACs recomputed from the explicit grid geometry."""
    s = p.stride
    ct, _ = ref.crop_offsets(p.ks, s, p.padding)
    bi = block_oh // s
    delta = -(-max(p.ks - 1 - ct, 0) // s)
    eps = (ct - 1) // s
    n_slab = bi + delta + eps + 1
    n_j = -(-p.oh // block_oh)
    n_c = -(-p.oc // block_oc)
    mxu = perf_model.V5E.mxu_dim
    # Folding removes the per-batch-element launch axis and stacks the
    # batch into the MatMul M-dimension instead.
    n_launches = n_c * n_j * (1 if fold_batch else batch)
    m_rows = (batch if fold_batch else 1) * n_slab * p.iw
    return n_launches * perf_model.mxu_tiles(
        m_rows, p.ks ** 2 * block_oc, p.ic, mxu) * mxu ** 3


def check_rank_agreement() -> None:
    """Score the committed head-to-heads; calibration must not regress."""
    bench = Path(__file__).resolve().parent.parent / "BENCH_mm2im.json"
    if not bench.exists():
        emit("V-F_rank_agreement", None, "skipped=no BENCH_mm2im.json")
        return
    pairs = model_fit.pairs_from_bench(json.loads(bench.read_text()))
    if not pairs:
        emit("V-F_rank_agreement", None, "skipped=no head-to-head rows")
        return
    roofline = model_fit.rank_agreement(pairs, None)
    fitted = model_fit.rank_agreement(pairs, model_fit.shipped_fit())
    for label, score in (("roofline", roofline), ("fitted", fitted)):
        emit(f"V-F_rank_agreement_{label}", None,
             f"pairs={score['n_pairs']};agree={score['n_agree']};"
             f"decisive={score['n_decisive']};"
             f"misranks={score['n_misranks']};"
             f"mean_abs_log2_err={score['mean_abs_log2_err']};"
             f"calibrated={int(score['calibrated'])}")
    if fitted["calibrated"]:
        assert fitted["n_misranks"] <= roofline["n_misranks"], (
            f"shipped calibration misranks more decisive head-to-heads "
            f"({fitted['n_misranks']}) than the raw roofline "
            f"({roofline['n_misranks']}) — refit "
            f"(tools/tune_sweep.py --fit) or investigate the regression")
        assert (fitted["mean_abs_log2_err"]
                <= roofline["mean_abs_log2_err"]), (
            "shipped calibration predicts worse magnitudes than the raw "
            "roofline")


def main() -> None:
    for p in PROBLEMS:
        x = jnp.zeros((1, p.ih, p.iw, p.ic), jnp.float32)
        w = jnp.zeros((p.ks, p.ks, p.oc, p.ic), jnp.float32)

        # Unfused IOM: model says 2*M*N*K (+ scatter adds).
        got, got_bytes = xla_costs(
            lambda a, b: ref.iom_reference(a, b, stride=p.stride), x, w)
        want = 2.0 * p.macs
        model_bytes = perf_model.iom_unfused_estimate(p, 1, bits=32).hbm_bytes
        byte_ratio = got_bytes / max(model_bytes, 1)
        emit(f"V-F_iom_unfused_{p.ih}x{p.ic}x{p.ks}s{p.stride}", None,
             f"model={want:.3e};xla={got:.3e};ratio={got/want:.3f};"
             f"byte_ratio={byte_ratio:.3f}")
        assert abs(got - want) / want < 0.10, (
            f"IOM FLOP model off vs XLA on {p}: model {want:.3e}, "
            f"compiled {got:.3e}")
        assert BYTES_BAND[0] < byte_ratio < BYTES_BAND[1], (
            f"IOM byte model off vs XLA on {p}: model {model_bytes}, "
            f"compiled {got_bytes:.0f} (ratio {byte_ratio:.2f} outside "
            f"{BYTES_BAND})")

        # Zero-insertion: model MACs == conv over dilated input.  XLA's
        # conv cost excludes border padding taps; allow for them (same
        # bound as tests/test_perf_model.py).
        got, _ = xla_costs(
            lambda a, b: ref.tconv_direct(a, b, stride=p.stride), x, w)
        want = 2.0 * zero_insertion_macs(p.ih, p.iw, p.ic, p.ks, p.oc,
                                         p.stride)
        border = 2.0 * (p.ks - 1) / (p.stride * p.ih)
        emit(f"V-F_zero_insertion_{p.ih}x{p.ic}x{p.ks}s{p.stride}", None,
             f"model={want:.3e};xla={got:.3e};ratio={got/want:.3f}")
        assert abs(got - want) / want < 0.10 + border, (
            f"zero-insertion FLOP model off vs XLA on {p}: model "
            f"{want:.3e}, compiled {got:.3e}")

        # MM2IM issued tile-MACs: formula vs explicit grid-geometry count
        # (ceil-quantized to whole 128^3 MXU tiles per launch — the same
        # quantization batch folding exploits).
        est = perf_model.mm2im_estimate(p, batch=1, bits=8)
        block_oh, block_oc = plan_blocks(p.ih, p.iw, p.ic, p.ks, p.oc,
                                         p.stride, p.padding, in_bytes=1)
        manual = _manual_issued_macs(p, block_oh, block_oc)
        emit(f"V-F_mm2im_issued_{p.ih}x{p.ic}x{p.ks}s{p.stride}", None,
             f"model={est.issued_macs};manual={manual};"
             f"match={est.issued_macs == manual}")
        assert est.issued_macs == manual, (
            f"MM2IM issued-MAC formula disagrees with the manual grid "
            f"count on {p}: model {est.issued_macs}, manual {manual}")

        # Folded batch-8 geometry: the batch collapses into the MatMul
        # M-dimension (one launch per (c, j) cell, M = B*n_slab*Iw).
        batch = 8
        f_oh, f_oc = plan_blocks(p.ih, p.iw, p.ic, p.ks, p.oc, p.stride,
                                 p.padding, in_bytes=1, batch=batch,
                                 fold_batch=True)
        est_f = perf_model.mm2im_estimate(p, batch, bits=8, fold_batch=True,
                                          block_oh=f_oh, block_oc=f_oc)
        manual_f = _manual_issued_macs(p, f_oh, f_oc, batch=batch,
                                       fold_batch=True)
        emit(f"V-F_mm2im_issued_fold_b{batch}_"
             f"{p.ih}x{p.ic}x{p.ks}s{p.stride}", None,
             f"model={est_f.issued_macs};manual={manual_f};"
             f"match={est_f.issued_macs == manual_f}")
        assert est_f.issued_macs == manual_f, (
            f"folded MM2IM issued-MAC formula disagrees with the manual "
            f"grid count on {p} b{batch}: model {est_f.issued_macs}, "
            f"manual {manual_f}")

    check_rank_agreement()


if __name__ == "__main__":
    main()
