"""MM2IM kernel ablations — each design feature toggled, Table-II workloads.

Features ablated (modeled on v5e terms; correctness of every variant is
separately asserted by tests/test_mm2im_kernel.py):

  * fusion        — fused kernel vs unfused IOM (matmul -> HBM -> scatter)
  * grid order    — auto (traffic-chosen) vs forced bcj / cbj
  * block_oh      — planner choice vs minimal blocks (halo recompute cost)
  * crop skip     — tile-level cmap skip vs computing the full IOM output
                    (VALID-sized) and cropping afterwards
"""

from __future__ import annotations

from benchmarks.common import emit
from repro.configs.paper_models import TABLE_II
from repro.core import perf_model
from repro.core.maps import TConvProblem, drop_stats
from repro.kernels.mm2im_pallas import plan_blocks
from repro.kernels.ref import crop_offsets


def _estimate(p, block_oh, block_oc, grid_order="auto", bits=8):
    return perf_model.mm2im_estimate(p, batch=1, block_oh=block_oh,
                                     block_oc=block_oc, bits=bits,
                                     grid_order=grid_order)


def main() -> None:
    for row in TABLE_II:
        p = row.problem
        boh, boc = plan_blocks(p.ih, p.iw, p.ic, p.ks, p.oc, p.stride,
                               p.padding, in_bytes=1)
        base = _estimate(p, boh, boc)
        # grid order ablation
        t_bcj = _estimate(p, boh, boc, "bcj").t_overlapped
        t_cbj = _estimate(p, boh, boc, "cbj").t_overlapped
        # minimal row block (halo recompute worst case)
        t_tiny = _estimate(p, p.stride, min(boc, 8)).t_overlapped
        # no-crop-skip: model the full (VALID) output being computed
        p_full = TConvProblem(p.ih, p.iw, p.ic, p.ks, p.oc, p.stride, "VALID")
        t_nocrop = _estimate(p_full, *plan_blocks(
            p.ih, p.iw, p.ic, p.ks, p.oc, p.stride, "VALID", in_bytes=1)
        ).t_overlapped
        t_unfused = perf_model.iom_unfused_estimate(p, bits=8).t_overlapped
        t = base.t_overlapped
        emit(f"ablation_{row.name}", t * 1e6,
             f"vs_unfused={t_unfused/t:.2f}x;"
             f"grid_auto_vs_worst={max(t_bcj, t_cbj)/t:.2f}x;"
             f"tiny_blocks={t_tiny/t:.2f}x_slower;"
             f"no_crop_skip={t_nocrop/t:.2f}x_slower;"
             f"D_r={drop_stats(p)['D_r']:.3f}")


if __name__ == "__main__":
    main()
