"""Shared benchmark helpers: timing + CSV emission (+ JSON row capture)."""

from __future__ import annotations

import time

import jax
import numpy as np

# Every emit() is also recorded here so benchmarks.run can serialize the
# whole run as a JSON artifact (the CI perf-trajectory file) — same rows,
# machine-readable.
_ROWS: list[dict] = []


def time_fn(fn, *args, repeats: int = 5, warmup: int = 1, **kw) -> float:
    """Median wall-time (us) of a jitted callable."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args, **kw))
    ts = []
    for _ in range(repeats):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args, **kw))
        ts.append(time.perf_counter() - t0)
    return float(np.median(ts) * 1e6)


def emit(name: str, us_per_call, derived: str = "") -> None:
    """Record one benchmark row (CSV line + JSON capture).

    ``us_per_call=None`` marks a derived-only row (comparisons, modeled
    numbers) where no wall-clock call was measured: the JSON artifact
    stores ``null`` — a literal ``0.0`` would read as a measured
    zero-microsecond call — while the CSV line keeps printing ``0.0``
    so downstream column parsing is unchanged.
    """
    _ROWS.append({"name": name,
                  "us_per_call": None if us_per_call is None
                  else float(us_per_call),
                  "derived": derived})
    print(f"{name},{0.0 if us_per_call is None else us_per_call:.1f},"
          f"{derived}")


def rows() -> list[dict]:
    """All rows emitted so far in this process (insertion order)."""
    return list(_ROWS)


def clear_rows() -> None:
    _ROWS.clear()
