"""Serving trajectory: open-loop traffic through the TCONV server.

The measurement layer of ROADMAP direction 2: synthetic Poisson traffic
(arrival rate x image size x precision) is pushed through
``repro.serve.TconvServer`` and each sweep point reports throughput,
request-latency p50/p99, queue-wait p99 vs the configured max-wait
deadline, and the achieved batch-fill ratio.  A sequential per-request
baseline (the same jitted forward at batch 1, one dispatch per request)
anchors the headline claim: continuous batching into the fold_batch-tuned
batch-8 bucket beats request-at-a-time serving on throughput.

Batch-8 plans are seeded into the user plan cache with the fold_batch
heuristic geometry (the ``bench_gan_e2e`` pattern — admission needs the
*tier hit*, not a full tune); run under ``REPRO_AUTOTUNE_CACHE`` pointing
at a scratch file (CI does) to keep the seeding out of your real cache.

Interpret-mode caveat: absolute latencies are CPU-simulated, but the
batched-vs-sequential ratio, fill ratios, flush reasons, and wait-bound
behavior are real and diffable — same contract as the autotune slice.
"""

from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.models.runner import make_runner
from repro.serve.resilience import FaultInjector, ResilienceConfig
from repro.serve.server import TconvServer

TARGET_BATCH = 8
MAX_WAIT_S = 0.25       # deadline bounding p99 queue wait (generous: CPU)
N_REQUESTS = 16
SEQ_REQUESTS = 16


def seed_fold_plans(runner, *, batches=(TARGET_BATCH,),
                    dtypes=(jnp.float32, jnp.int8)) -> int:
    """Seed fold_batch plans for every runner layer into the user cache.

    Admission scores buckets by plan-tier *hits*; the heuristic fold
    geometry from ``tiling.plan`` is enough to make the batch-8 bucket
    the tuned fast path without paying a sweep in CI.
    """
    from repro.core import autotune, tiling
    from repro.kernels.registry import Plan

    cache = autotune.shared_cache()
    seeded = 0
    for prob in runner.tconv_problems().values():
        for b in batches:
            try:
                tp = tiling.plan(prob, batch=b, fold_batch=True)
            except Exception:
                continue  # layer/batch where folding cannot tile
            plan = Plan(tp.block_oh, tp.block_oc, tp.grid_order,
                        fold_batch=True)
            for dt in dtypes:
                cache.put(autotune.cache_key(prob, dtype=dt, batch=b), plan)
                seeded += 1
    return seeded


def run_traffic(runners: dict, model: str, *, rate_rps: float,
                precision: str, n: int = N_REQUESTS, seed: int = 0) -> dict:
    """One sweep point: n Poisson arrivals at rate_rps into a fresh server."""
    server = TconvServer(runners, max_wait_s=MAX_WAIT_S)
    server.warmup(precisions=(precision,))
    rng = np.random.default_rng(seed)
    if np.isfinite(rate_rps):
        arrivals = np.cumsum(rng.exponential(1.0 / rate_rps, n))
    else:
        # Closed burst: everything arrives at once, so throughput measures
        # service capacity rather than the (open-loop) arrival rate.
        arrivals = np.zeros(n)
    xs = np.asarray(runners[model].example_inputs(n, seed=seed))
    reqs = []
    with server:
        t0 = time.perf_counter()
        for i in range(n):
            dt = t0 + arrivals[i] - time.perf_counter()
            if dt > 0:
                time.sleep(dt)
            reqs.append(server.submit(model, xs[i], precision=precision))
        for r in reqs:
            r.result(timeout=600)
        wall = time.perf_counter() - t0
    lats_ms = np.asarray([r.latency_s for r in reqs]) * 1e3
    stats = server.stats()
    bucket = next(b for k, b in stats["buckets"].items()
                  if k.startswith(f"{model}:") and f":{precision}:" in k
                  and b["requests"])
    return {
        "throughput_rps": n / wall,
        "p50_ms": float(np.percentile(lats_ms, 50)),
        "p99_ms": float(np.percentile(lats_ms, 99)),
        "wait_p99_ms": bucket["queue_wait_max_s"] * 1e3,
        "fill": bucket["batch_fill_ratio"],
        "target_batch": bucket["target_batch"],
        "tuned_layers": bucket["tuned_layers"],
        "total_layers": bucket["total_layers"],
        "flush_full": bucket["flush_full"],
        "flush_deadline": bucket["flush_deadline"],
    }


def sequential_throughput(runner, *, precision: str,
                          n: int = SEQ_REQUESTS) -> float:
    """Request-at-a-time baseline: batch-1 jitted forward, one dispatch
    per request, no queueing."""
    fn = runner.jitted(batch=1, precision=precision)
    xs = np.asarray(runner.example_inputs(n, seed=1))
    jax.block_until_ready(fn(jnp.asarray(xs[:1])))  # compile outside timing
    t0 = time.perf_counter()
    for i in range(n):
        jax.block_until_ready(fn(jnp.asarray(xs[i:i + 1])))
    return n / (time.perf_counter() - t0)


def run_chaos(runners: dict, model: str, *, precision: str = "f32",
              n: int = N_REQUESTS, seed: int = 0) -> None:
    """Degraded-mode regime: the same traffic shape as :func:`run_traffic`
    but with deterministic faults injected, reporting what the resilience
    layer did about them (``serve_chaos_*`` rows — their own BENCH
    section; ``tools/bench_gate.py`` excludes them from latency banding
    because degraded-mode latency measures the injected fault, not the
    kernels).

    Three rows:

    * ``serve_chaos_ladder_*`` — every 2nd batch's tuned rung fails
      transiently: each batch retries once then descends; every request
      still completes, and the rung split + retry count land in the row.
    * ``serve_chaos_shed_*`` — a closed burst into a depth-bounded queue
      with no drain thread running: the overflow sheds at admission
      (``QueueFullError``), then the drain serves exactly what was
      admitted.
    * ``serve_chaos_breaker_*`` — the bucket is poisoned (every rung
      fails): K consecutive batch failures trip the breaker and later
      submits shed at admission instead of queueing doomed work.
    """
    from repro.serve.bucketing import ShedError

    # -- ladder: transient tuned-rung faults, everything still completes.
    inj = FaultInjector(fail_nth_batch=2, seed=seed)
    server = TconvServer(runners, max_wait_s=MAX_WAIT_S,
                         fault_injector=inj)
    server.warmup(precisions=(precision,))
    xs = np.asarray(runners[model].example_inputs(n, seed=seed))
    t0 = time.perf_counter()
    with server:
        reqs = [server.submit(model, xs[i], precision=precision)
                for i in range(n)]
        done = sum(1 for r in reqs if r.result(timeout=600) is not None)
    wall = time.perf_counter() - t0
    b = next(b for k, b in server.stats()["buckets"].items()
             if k.startswith(f"{model}:") and b["requests"])
    emit(f"serve_chaos_ladder_{model}_{precision}", None,
         f"completed={b['completed']};failed={b['failed']};"
         f"retries={b['retries']};degraded={b['degraded']};"
         f"rungs={'/'.join(f'{k}:{v}' for k, v in sorted(b['rungs'].items()))};"
         f"injected_faults={inj.injected.get('fail', 0)};"
         f"thr_rps={done / wall:.2f};all_served={int(done == n)}")

    # -- shed: bounded queue, burst admitted with the drain loop stopped.
    depth = 4
    server = TconvServer(runners, max_wait_s=MAX_WAIT_S,
                         resilience_config=ResilienceConfig(
                             max_queue_depth=depth))
    server.warmup(precisions=(precision,))
    reqs, shed = [], 0
    for i in range(n):
        try:
            reqs.append(server.submit(model, xs[i], precision=precision))
        except ShedError:
            shed += 1
    server.drain()
    done = sum(1 for r in reqs if r.result(timeout=600) is not None)
    b = next(b for k, b in server.stats()["buckets"].items()
             if k.startswith(f"{model}:") and (b["requests"] or b["shed"]))
    emit(f"serve_chaos_shed_{model}_{precision}", None,
         f"offered={n};admitted={len(reqs)};shed={b['shed']};"
         f"max_queue_depth={depth};completed={b['completed']};"
         f"admitted_all_served={int(done == len(reqs))}")

    # -- breaker: poisoned bucket, K consecutive failures trip it open.
    inj = FaultInjector(poison_bucket=f"{model}:", seed=seed)
    server = TconvServer(runners, max_wait_s=MAX_WAIT_S,
                         fault_injector=inj,
                         resilience_config=ResilienceConfig(
                             breaker_threshold=2, breaker_cooldown_s=60.0))
    reqs, shed = [], 0
    for i in range(n):
        try:
            reqs.append(server.submit(model, xs[i], precision=precision))
        except ShedError:
            shed += 1
        server.serve_once(force=True)   # one batch per submit: serial fails
    failed = sum(1 for r in reqs if r.done())
    b = next(b for k, b in server.stats()["buckets"].items()
             if k.startswith(f"{model}:") and (b["requests"] or b["shed"]))
    emit(f"serve_chaos_breaker_{model}_{precision}", None,
         f"offered={n};failed_typed={failed};shed_after_trip={b['shed']};"
         f"breaker_state={b['breaker']['state']};"
         f"breaker_trips={b['breaker']['trips']};"
         f"no_hangs={int(all(r.done() for r in reqs))}")


def main() -> None:
    runners = {
        # scale_down=8 (the bench_gan_e2e size): big enough that the
        # folded batch-8 forward beats 8 batch-1 dispatches at BOTH
        # precisions — at scale_down=16 the int8 quantize/dequant ops
        # (linear in batch) dilute the dispatch-amortization win.
        "dcgan": make_runner("dcgan", key=jax.random.PRNGKey(0),
                             init_kw={"scale_down": 8}),
        # The image-size axis: one upscaler family at two resolutions.
        "fsrcnn_h8": make_runner("fsrcnn", key=jax.random.PRNGKey(1),
                                 init_kw={"d": 8, "s": 4, "m": 1},
                                 input_hw=8),
        "fsrcnn_h16": make_runner("fsrcnn", key=jax.random.PRNGKey(2),
                                  init_kw={"d": 8, "s": 4, "m": 1},
                                  input_hw=16),
    }
    seeded = sum(seed_fold_plans(r) for r in runners.values())
    emit("serve_seeded_plans", None, f"entries={seeded}")

    # Arrival-rate x precision on the DCGAN bucket: a burst rate that
    # keeps the batcher full (flush-on-full) and a trickle that exercises
    # the deadline path (flush-on-deadline, p99 wait <= max_wait).
    for precision in ("f32", "int8"):
        for tag, rate in (("burst", 1000.0), ("trickle", 8.0)):
            m = run_traffic(runners, "dcgan", rate_rps=rate,
                            precision=precision)
            emit(f"serve_dcgan_{precision}_{tag}", m["p50_ms"] * 1e3,
                 f"thr_rps={m['throughput_rps']:.2f};"
                 f"p99_ms={m['p99_ms']:.1f};"
                 f"wait_p99_ms={m['wait_p99_ms']:.1f};"
                 f"max_wait_ms={MAX_WAIT_S * 1e3:.0f};"
                 f"wait_bounded={int(m['wait_p99_ms'] <= MAX_WAIT_S * 1e3 + 50)};"
                 f"fill={m['fill']:.2f};"
                 f"target_batch={m['target_batch']};"
                 f"tuned={m['tuned_layers']}/{m['total_layers']};"
                 f"flush_full={m['flush_full']};"
                 f"flush_deadline={m['flush_deadline']}")

    # Image-size axis (f32, burst).
    for model in ("fsrcnn_h8", "fsrcnn_h16"):
        m = run_traffic(runners, model, rate_rps=1000.0, precision="f32")
        emit(f"serve_{model}_f32_burst", m["p50_ms"] * 1e3,
             f"thr_rps={m['throughput_rps']:.2f};"
             f"p99_ms={m['p99_ms']:.1f};fill={m['fill']:.2f};"
             f"target_batch={m['target_batch']}")

    # Batched-vs-sequential: the acceptance head-to-head at the
    # batch-8-tuned bucket.  Both sides are offered work as fast as they
    # can take it (closed burst), so the ratio compares service capacity:
    # one padded batch-8 dispatch per 8 requests vs 8 batch-1 dispatches.
    for precision in ("f32", "int8"):
        seq = sequential_throughput(runners["dcgan"], precision=precision)
        m = run_traffic(runners, "dcgan", rate_rps=float("inf"),
                        precision=precision, n=32, seed=7)
        emit(f"serve_seq_vs_batched_dcgan_{precision}", None,
             f"seq_rps={seq:.2f};batched_rps={m['throughput_rps']:.2f};"
             f"speedup={m['throughput_rps'] / seq:.2f}x;"
             f"fill={m['fill']:.2f};target_batch={m['target_batch']}")

    # Degraded-mode chaos regime — lands in the BENCH doc's own
    # ``serve_chaos`` section (excluded from perf-gate latency banding).
    run_chaos(runners, "dcgan", precision="f32")


if __name__ == "__main__":
    main()
