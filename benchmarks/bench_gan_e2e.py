"""Paper Table IV: end-to-end DCGAN and pix2pix generator inference.

Two parts:

1. **Measured (CPU, reduced width)** — run the real models end-to-end
   with every TCONV method and verify identical outputs; wall-times are
   reported for the *jitted XLA baselines* (interpret-mode Pallas wall
   time is not meaningful — its correctness is asserted instead).
2. **Modeled (v5e, full width)** — per-layer roofline model summed over
   each model's TCONV stack: MM2IM vs unfused IOM / zero-insertion, the
   Table-IV speedup analogue.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn
from repro.configs.paper_models import TABLE_II
from repro.core import perf_model
from repro.core.maps import TConvProblem
from repro.models import gan

PIX2PIX_TCONVS = [  # U-Net up path (256x256 input): (oc, ks, ih, ic, s)
    (512, 4, 1, 512, 2), (512, 4, 2, 1024, 2), (512, 4, 4, 1024, 2),
    (512, 4, 8, 1024, 2), (256, 4, 16, 1024, 2), (128, 4, 32, 512, 2),
    (64, 4, 64, 256, 2), (3, 4, 128, 128, 2),
]


def modeled_e2e(layers, name: str) -> None:
    tot = {m: 0.0 for m in ("mm2im", "iom_unfused", "zero_insertion")}
    for (oc, ks, ih, ic, s) in layers:
        p = TConvProblem(ih, ih, ic, ks, oc, s)
        for m in tot:
            tot[m] += perf_model.ESTIMATORS[m](p, batch=1, bits=8).t_overlapped
    emit(f"tableIV_modeled_{name}", tot["mm2im"] * 1e6,
         f"speedup_vs_unfused={tot['iom_unfused']/tot['mm2im']:.2f}x;"
         f"vs_zero_insertion={tot['zero_insertion']/tot['mm2im']:.2f}x;"
         f"paper_tconv_speedup=2.4-3.0x")


def modeled_folded_e2e(layers, name: str, batch: int = 8) -> None:
    """Batch-8 generator TCONV stack: grid-batch vs batch-folded MM2IM.

    Per-layer tile-quantized roofline summed over the stack — the serve
    path's modeled payoff of the plan-v2 fold (the small-spatial head
    layers dominate the win; the late large-spatial layers already fill
    the MXU M-dimension and fold to ~1x)."""
    t_grid = t_fold = 0.0
    for (oc, ks, ih, ic, s) in layers:
        p = TConvProblem(ih, ih, ic, ks, oc, s)
        t_grid += perf_model.mm2im_estimate(p, batch, bits=8).t_overlapped
        t_fold += perf_model.mm2im_estimate(p, batch, bits=8,
                                            fold_batch=True).t_overlapped
    emit(f"tableIV_modeled_{name}_b{batch}_folded", t_fold * 1e6,
         f"grid_us={t_grid * 1e6:.0f};"
         f"fold_speedup={t_grid / t_fold:.2f}x")


def measured_cpu() -> None:
    key = jax.random.PRNGKey(0)
    # DCGAN (1/8 width) — all methods must agree.
    p, _ = gan.init_dcgan_g(key, scale_down=8)
    z = jax.random.normal(jax.random.PRNGKey(1), (2, 100))
    outs = {}
    for m in ("mm2im", "mm2im_db", "mm2im_ks", "iom_unfused",
              "zero_insertion", "tdc", "lax"):
        fn = jax.jit(lambda zz, m=m: gan.dcgan_generator(p, zz, method=m))
        outs[m] = np.asarray(fn(z))
        if m == "mm2im_db":
            # Pipelined variant: interpret-mode wall time is meaningless,
            # but the e2e output must be bit-identical to 'mm2im'.
            emit("tableIV_dcgan_cpu_mm2im_db", None,
                 f"bitident_vs_mm2im={int((outs[m] == outs['mm2im']).all())}")
        elif m != "mm2im":
            us = time_fn(fn, z, repeats=3)
            emit(f"tableIV_dcgan_cpu_{m}", us,
                 f"max_dev_vs_mm2im={np.abs(outs[m]-outs['mm2im']).max():.2e}")
    # Batch-folded DCGAN at batch 8: every TCONV runs under a fold_batch
    # plan — the e2e output must be bit-identical to the grid-batch run
    # (plan consumption must never change results), and the wall-time
    # ratio is the measured serve-path payoff of the fold.
    from repro.core import tiling
    from repro.kernels.registry import Plan

    z8 = jax.random.normal(jax.random.PRNGKey(4), (8, 100))
    fold_plans = {}
    for lname, prob in gan.dcgan_tconv_problems(p).items():
        tp = tiling.plan(prob, batch=8, fold_batch=True)
        fold_plans[lname] = Plan(tp.block_oh, tp.block_oc, tp.grid_order,
                                 fold_batch=True)
    fn_grid = jax.jit(lambda zz: gan.dcgan_generator(p, zz))
    fn_fold = jax.jit(lambda zz: gan.dcgan_generator(p, zz, plans=fold_plans))
    out_grid = np.asarray(fn_grid(z8))
    out_fold = np.asarray(fn_fold(z8))
    us_grid = time_fn(fn_grid, z8, repeats=3)
    us_fold = time_fn(fn_fold, z8, repeats=3)
    emit("tableIV_dcgan_cpu_b8_folded", us_fold,
         f"bitident_vs_grid={int((out_fold == out_grid).all())};"
         f"grid_us={us_grid:.1f};"
         f"fold_speedup={us_grid / max(us_fold, 1e-9):.2f}x")

    # pix2pix (depth 5, 1/8 width).
    pp, _ = gan.init_pix2pix_g(jax.random.PRNGKey(2), depth=5, scale_down=8)
    x = jax.random.normal(jax.random.PRNGKey(3), (1, 32, 32, 3))
    ref = None
    for m in ("mm2im", "lax"):
        fn = jax.jit(lambda xx, m=m: gan.pix2pix_generator(pp, xx, depth=5, method=m))
        y = np.asarray(fn(x))
        if ref is None:
            ref = y
        else:
            emit("tableIV_pix2pix_cpu_check", time_fn(fn, x, repeats=3),
                 f"max_dev={np.abs(y-ref).max():.2e}")


def main() -> None:
    dc = [(r.oc, r.ks, r.ihw, r.ic, r.stride) for r in TABLE_II
          if r.name.startswith("DCGAN")]
    modeled_e2e(dc, "dcgan")
    modeled_e2e(PIX2PIX_TCONVS, "pix2pix")
    modeled_folded_e2e(dc, "dcgan")
    modeled_folded_e2e(PIX2PIX_TCONVS, "pix2pix")
    measured_cpu()


if __name__ == "__main__":
    main()
