"""Paper Table IV: end-to-end DCGAN and pix2pix generator inference.

Two parts:

1. **Measured (CPU, reduced width)** — run the real models end-to-end
   with every TCONV method and verify identical outputs; wall-times are
   reported for the *jitted XLA baselines* (interpret-mode Pallas wall
   time is not meaningful — its correctness is asserted instead).
2. **Modeled (v5e, full width)** — per-layer roofline model summed over
   each model's TCONV stack: MM2IM vs unfused IOM / zero-insertion, the
   Table-IV speedup analogue.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn
from repro.configs.paper_models import TABLE_II
from repro.core import perf_model
from repro.core.maps import TConvProblem
from repro.models import gan

PIX2PIX_TCONVS = [  # U-Net up path (256x256 input): (oc, ks, ih, ic, s)
    (512, 4, 1, 512, 2), (512, 4, 2, 1024, 2), (512, 4, 4, 1024, 2),
    (512, 4, 8, 1024, 2), (256, 4, 16, 1024, 2), (128, 4, 32, 512, 2),
    (64, 4, 64, 256, 2), (3, 4, 128, 128, 2),
]


def modeled_e2e(layers, name: str) -> None:
    tot = {m: 0.0 for m in ("mm2im", "iom_unfused", "zero_insertion")}
    for (oc, ks, ih, ic, s) in layers:
        p = TConvProblem(ih, ih, ic, ks, oc, s)
        for m in tot:
            tot[m] += perf_model.ESTIMATORS[m](p, batch=1, bits=8).t_overlapped
    emit(f"tableIV_modeled_{name}", tot["mm2im"] * 1e6,
         f"speedup_vs_unfused={tot['iom_unfused']/tot['mm2im']:.2f}x;"
         f"vs_zero_insertion={tot['zero_insertion']/tot['mm2im']:.2f}x;"
         f"paper_tconv_speedup=2.4-3.0x")


def measured_cpu() -> None:
    key = jax.random.PRNGKey(0)
    # DCGAN (1/8 width) — all methods must agree.
    p, _ = gan.init_dcgan_g(key, scale_down=8)
    z = jax.random.normal(jax.random.PRNGKey(1), (2, 100))
    outs = {}
    for m in ("mm2im", "mm2im_db", "iom_unfused", "zero_insertion", "tdc",
              "lax"):
        fn = jax.jit(lambda zz, m=m: gan.dcgan_generator(p, zz, method=m))
        outs[m] = np.asarray(fn(z))
        if m == "mm2im_db":
            # Pipelined variant: interpret-mode wall time is meaningless,
            # but the e2e output must be bit-identical to 'mm2im'.
            emit("tableIV_dcgan_cpu_mm2im_db", 0.0,
                 f"bitident_vs_mm2im={int((outs[m] == outs['mm2im']).all())}")
        elif m != "mm2im":
            us = time_fn(fn, z, repeats=3)
            emit(f"tableIV_dcgan_cpu_{m}", us,
                 f"max_dev_vs_mm2im={np.abs(outs[m]-outs['mm2im']).max():.2e}")
    # pix2pix (depth 5, 1/8 width).
    pp, _ = gan.init_pix2pix_g(jax.random.PRNGKey(2), depth=5, scale_down=8)
    x = jax.random.normal(jax.random.PRNGKey(3), (1, 32, 32, 3))
    ref = None
    for m in ("mm2im", "lax"):
        fn = jax.jit(lambda xx, m=m: gan.pix2pix_generator(pp, xx, depth=5, method=m))
        y = np.asarray(fn(x))
        if ref is None:
            ref = y
        else:
            emit("tableIV_pix2pix_cpu_check", time_fn(fn, x, repeats=3),
                 f"max_dev={np.abs(y-ref).max():.2e}")


def main() -> None:
    dc = [(r.oc, r.ks, r.ihw, r.ic, r.stride) for r in TABLE_II
          if r.name.startswith("DCGAN")]
    modeled_e2e(dc, "dcgan")
    modeled_e2e(PIX2PIX_TCONVS, "pix2pix")
    measured_cpu()


if __name__ == "__main__":
    main()
