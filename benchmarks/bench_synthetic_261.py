"""Paper Fig. 6: the 261-configuration synthetic TCONV benchmark.

On the paper's FPGA this is measured speedup vs a dual-thread NEON CPU.
On TPU (this repo's target) we report, per problem:

  * the modeled roofline speedup of fused MM2IM over the unfused-IOM
    XLA baseline (matmul -> HBM -> scatter col2im) — apples-to-apples
    with the paper's "optimized vs baseline on the same device" framing;
  * the modeled speedup over Zero-Insertion (the paper's method (i));
  * a *measured* CPU subset (interpret-mode kernel vs jitted baseline is
    not meaningful for wall time, so the measured subset times the
    baselines themselves to validate the model's *ordering*).

Summary lines mirror the paper's takeaways (speedup vs Ic / Ks / S).
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import emit
from repro.configs.paper_models import synthetic_sweep
from repro.core import perf_model
from repro.core.maps import drop_stats


def main() -> None:
    sweep = synthetic_sweep()
    rows = []
    for p in sweep:
        su_iom = perf_model.modeled_speedup(p, batch=1, bits=8)
        su_zi = perf_model.modeled_speedup(p, batch=1, bits=8,
                                           baseline="zero_insertion")
        su_tdc = perf_model.modeled_speedup(p, batch=1, bits=8, baseline="tdc")
        rows.append((p, su_iom, su_zi, su_tdc))

    su = np.array([r[1] for r in rows])
    emit("fig6_mean_speedup_vs_unfused_iom", None,
         f"geomean={np.exp(np.log(su).mean()):.2f}x;paper_vs_cpu=1.9x;n={len(rows)}")
    emit("fig6_mean_speedup_vs_zero_insertion", None,
         f"geomean={np.exp(np.log([r[2] for r in rows]).mean()):.2f}x")
    emit("fig6_mean_speedup_vs_tdc", None,
         f"geomean={np.exp(np.log([r[3] for r in rows]).mean()):.2f}x")

    # Paper takeaway (ii): larger Ic -> larger speedup.
    for ic in (32, 64, 128, 256):
        sel = [r[1] for r in rows if r[0].ic == ic]
        if sel:
            emit(f"fig6_speedup_ic{ic}", None, f"geomean={np.exp(np.log(sel).mean()):.2f}x")
    # Takeaway (iii)/(v): Ks up -> speedup up; S up -> speedup down.
    for ks in (3, 5, 7):
        sel = [r[1] for r in rows if r[0].ks == ks]
        emit(f"fig6_speedup_ks{ks}", None, f"geomean={np.exp(np.log(sel).mean()):.2f}x")
    for s in (1, 2):
        sel = [r[1] for r in rows if r[0].stride == s]
        emit(f"fig6_speedup_s{s}", None, f"geomean={np.exp(np.log(sel).mean()):.2f}x")

    # Correlation with drop rate (paper: higher drop rate -> higher win).
    dr = np.array([drop_stats(r[0])["D_r"] for r in rows])
    c = np.corrcoef(dr, su)[0, 1]
    emit("fig6_corr_droprate_speedup", None, f"pearson={c:.3f}")


if __name__ == "__main__":
    main()
