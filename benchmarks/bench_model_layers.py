"""Paper Table II: TCONV layers from popular generative models.

Per layer: OPs (validated against the paper's OPs column), drop rate,
modeled v5e latency (8-bit) for MM2IM and all baselines, modeled GOPs
(effectual), and a measured CPU correctness run (reduced batch) proving
the fused kernel computes the layer.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import emit, time_fn
from repro.configs.paper_models import TABLE_II
from repro.core import perf_model
from repro.core.maps import drop_stats


def _ops_str(n: float) -> str:
    return f"{n/1e6:.0f}M" if n >= 1e6 else f"{n/1e3:.0f}K"


def main() -> None:
    for row in TABLE_II:
        p = row.problem
        st = drop_stats(p)
        est = perf_model.mm2im_estimate(p, batch=1, bits=8)
        base = perf_model.iom_unfused_estimate(p, batch=1, bits=8)
        t = est.t_overlapped
        gops = 2 * st["effectual_macs"] / t / 1e9
        emit(f"tableII_{row.name}", t * 1e6,
             f"OPs={_ops_str(p.ops)};paper_OPs={row.paper_ops};"
             f"D_r={st['D_r']:.3f};modeled_GOPs={gops:.1f};"
             f"speedup_vs_unfused={base.t_overlapped / t:.2f}x;"
             f"paper_speedup_vs_cpu={row.paper_speedup}x;"
             f"bottleneck={est.bottleneck};mxu_util={est.mxu_utilization:.2f}")


if __name__ == "__main__":
    main()
