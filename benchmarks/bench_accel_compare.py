"""Paper Table III analogue: method-vs-method efficiency on fixed hardware.

The paper compares accelerators by GOPs/DSP (throughput per unit of
compute resource).  The TPU analogue of "per DSP" is *per MXU cycle*:
effectual-FLOP fraction of issued MXU work (how much of the dense compute
the method wastes), plus modeled end-to-end latency per method on v5e.

Methods: fused MM2IM (ours, single- and double-buffered — the latter's
row includes the overlapped-copy term, so the delta between the two is the
modeled data-in stall), unfused IOM (matmul+scatter), Zero-Insertion,
TDC — all implemented and numerically validated in this repo.

A second, *measured* section runs the paper's int8 inference mode end to
end on every method: the MM2IM kernels requantize natively in the fused
PPU epilogue, and the §II-A baselines run through the dispatcher's
dequant -> compute -> requant fallback (``kernels/ops.py``) — an int8
baseline comparison that was impossible before the Epilogue-typed
dispatch unification (only the MM2IM kernels could take ``out_scale``).

A third section models the plan-v2 **batch-folded** dataflow on the
batch-8 Table II rows: issued-tile MXU utilization and predicted speedup
of folding the batch into the MatMul M-dimension vs the grid-batch
dataflow (``tableIII_fold_*`` rows), plus a measured int8 bit-identity
check of the folded kernel on the batched path.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import emit, time_fn
from repro.configs.paper_models import TABLE_II
from repro.core import perf_model
from repro.core.maps import TConvProblem

# Every registered method in the paper's precision.  The baselines run via
# the dispatcher fallback — interpret-mode wall time is meaningless for
# the Pallas kernels off-TPU, so the jitted XLA baselines are timed and
# the kernels' correctness vs the native requant path is asserted instead.
INT8_METHODS = ("mm2im", "mm2im_db", "mm2im_ks", "iom_unfused",
                "zero_insertion", "tdc", "lax")


def measured_int8() -> None:
    """Int8 end-to-end per method (DCGAN_4-shaped, reduced channels)."""
    p = TConvProblem(8, 8, 16, 5, 8, 2)
    rng = np.random.default_rng(0)
    xq = rng.integers(-128, 128, (1, p.ih, p.iw, p.ic)).astype(np.int8)
    wq = rng.integers(-128, 128, (p.ks, p.ks, p.oc, p.ic)).astype(np.int8)
    bq = rng.integers(-500, 500, (p.oc,)).astype(np.int32)
    scale = 0.003

    from repro.kernels.ops import tconv_int8
    from repro.kernels.registry import Plan

    outs = {}
    for m in INT8_METHODS:
        fn = lambda xx, m=m: tconv_int8(xx, wq, bq, scale, stride=p.stride,
                                        method=m)
        outs[m] = np.asarray(fn(xq))
        assert outs[m].dtype == np.int8, (m, outs[m].dtype)
        dev = int(np.abs(outs[m].astype(np.int32)
                         - outs["mm2im"].astype(np.int32)).max())
        if m in ("mm2im", "mm2im_db", "mm2im_ks"):
            emit(f"tableIII_int8_{m}", None,
                 f"native_requant=1;max_dev_vs_mm2im={dev}")
        else:
            us = time_fn(fn, xq, repeats=3)
            emit(f"tableIII_int8_{m}", us,
                 f"fallback=dequant-requant;max_dev_vs_mm2im={dev}")

    # Plan v2: the batch-folded int8 dataflow must be bit-identical to the
    # grid-batch kernel on the batched serve path.
    xq8 = rng.integers(-128, 128, (8, p.ih, p.iw, p.ic)).astype(np.int8)
    fold = np.asarray(tconv_int8(xq8, wq, bq, scale, stride=p.stride,
                                 plan=Plan(4, 8, "bcj", fold_batch=True)))
    grid = np.asarray(tconv_int8(xq8, wq, bq, scale, stride=p.stride,
                                 plan=Plan(4, 8, "bcj")))
    emit("tableIII_int8_folded_b8", None,
         f"bitident_vs_grid={int((fold == grid).all())};"
         f"native_requant=1;fold_batch=1")


def modeled_folded_b8() -> None:
    """Folded vs grid-batch MXU occupancy on the batch-8 Table II rows.

    The GOPs/DSP analogue under tile quantization: issued-tile utilization
    of the MM2IM MatMul with the batch folded into M vs one starved
    product per batch element (the Table II small-spatial GAN layers are
    exactly where the 128-lane M-dimension runs mostly empty)."""
    batch = 8
    for row in TABLE_II:
        p = row.problem
        e_grid = perf_model.mm2im_estimate(p, batch, bits=8)
        e_fold = perf_model.mm2im_estimate(p, batch, bits=8, fold_batch=True)
        emit(f"tableIII_fold_{row.name}", e_fold.t_overlapped * 1e6,
             f"batch={batch};grid_util={e_grid.mxu_utilization:.3f};"
             f"fold_util={e_fold.mxu_utilization:.3f};"
             f"fold_speedup={e_grid.t_overlapped / e_fold.t_overlapped:.2f}x;"
             f"grid_bottleneck={e_grid.bottleneck};"
             f"fold_bottleneck={e_fold.bottleneck}")


def main() -> None:
    agg = {m: [] for m in perf_model.ESTIMATORS}
    for row in TABLE_II:
        p = row.problem
        line = []
        for method, fn in perf_model.ESTIMATORS.items():
            e = fn(p, batch=1, bits=8)
            agg[method].append((e.t_overlapped, e.mxu_utilization,
                                e.hbm_bytes))
            line.append(f"{method}:t={e.t_overlapped*1e6:.0f}us"
                        f",util={e.mxu_utilization:.2f}")
        emit(f"tableIII_{row.name}", None, ";".join(line))

    for method, vals in agg.items():
        t = np.array([v[0] for v in vals])
        u = np.array([v[1] for v in vals])
        emit(f"tableIII_summary_{method}", float(t.mean() * 1e6),
             f"mean_mxu_util={u.mean():.3f};"
             f"rel_time_vs_mm2im={t.mean() / np.array([v[0] for v in agg['mm2im']]).mean():.2f}x")

    modeled_folded_b8()
    measured_int8()


if __name__ == "__main__":
    main()
