"""Paper Table III analogue: method-vs-method efficiency on fixed hardware.

The paper compares accelerators by GOPs/DSP (throughput per unit of
compute resource).  The TPU analogue of "per DSP" is *per MXU cycle*:
effectual-FLOP fraction of issued MXU work (how much of the dense compute
the method wastes), plus modeled end-to-end latency per method on v5e.

Methods: fused MM2IM (ours, single- and double-buffered — the latter's
row includes the overlapped-copy term, so the delta between the two is the
modeled data-in stall), unfused IOM (matmul+scatter), Zero-Insertion,
TDC — all implemented and numerically validated in this repo.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import emit
from repro.configs.paper_models import TABLE_II
from repro.core import perf_model


def main() -> None:
    agg = {m: [] for m in perf_model.ESTIMATORS}
    for row in TABLE_II:
        p = row.problem
        line = []
        for method, fn in perf_model.ESTIMATORS.items():
            e = fn(p, batch=1, bits=8)
            agg[method].append((e.t_overlapped, e.mxu_utilization,
                                e.hbm_bytes))
            line.append(f"{method}:t={e.t_overlapped*1e6:.0f}us"
                        f",util={e.mxu_utilization:.2f}")
        emit(f"tableIII_{row.name}", 0.0, ";".join(line))

    for method, vals in agg.items():
        t = np.array([v[0] for v in vals])
        u = np.array([v[1] for v in vals])
        emit(f"tableIII_summary_{method}", float(t.mean() * 1e6),
             f"mean_mxu_util={u.mean():.3f};"
             f"rel_time_vs_mm2im={t.mean() / np.array([v[0] for v in agg['mm2im']]).mean():.2f}x")


if __name__ == "__main__":
    main()
