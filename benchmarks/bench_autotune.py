"""Tuned-vs-default block plans over a slice of the 261-config sweep.

For each problem in the slice the autotuner enumerates legal
``(method, block_oh, block_oc, grid_order)`` tile plans — ``method``
choosing between the single-buffered MM2IM kernel and the double-buffered
DMA pipeline — prunes with the roofline model (overlapped-copy term
included), times the survivors through the real kernels, and persists the
winner.  We report, per problem:

  * measured us of the tuned plan vs the seed ``plan_blocks`` heuristic;
  * the winning plan geometry *and kernel variant*;
  * a single- vs double-buffered head-to-head at the default geometry
    (measured ratio next to the perf model's predicted ratio, so predicted
    and measured rankings can be compared);
  * a numerical check of the tuned plan against the unfused-IOM oracle
    (the acceptance gate: tuning must never change results).

A second pass re-opens the cache from a *fresh* ``PlanCache`` (simulating
a new process) and asserts every tuned key round-trips.  A third pass
times the plan-v2 **batch folding** knob head-to-head (folded vs
grid-batch at identical geometry on the batch-8 DCGAN layer-1 shape, both
kernel variants, measured ratio vs the tile-quantized roofline
prediction) and reports the batch-8 tuned winner.  A fourth pass
exercises the int8 and batch>1 key space (``autotune_sweep``) — the
paper's precision and the serving batch dimension — so the GAN
training/serve paths hit tuned plans out of the box.

The slice keeps problems small because off-TPU the kernel runs in Pallas
interpret mode; on a real TPU the same harness times the compiled kernel.
Set ``REPRO_AUTOTUNE_CACHE`` to control the cache file (defaults to a
temp file here so benchmark runs do not pollute the user cache).
"""

from __future__ import annotations

import os
import tempfile

import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit
from repro.configs.paper_models import (TABLE_II, is_small_problem,
                                        synthetic_sweep)
from repro.core import model_fit
from repro.core.autotune import (PlanCache, autotune_result, autotune_sweep,
                                 measure_plan)
from repro.core.maps import TConvProblem
from repro.core.perf_model import (mm2im_db_estimate, mm2im_estimate,
                                   mm2im_ks_estimate, mm2im_og_estimate)
from repro.kernels import ref
from repro.kernels.ops import tconv
from repro.kernels.registry import Plan


def _fit_pred_us(p: TConvProblem, plan: Plan, batch: int = 1):
    """Calibrated microsecond prediction, None without a shipped fit.

    Emitted next to the raw roofline prediction so the recorded rows show
    both models' rankings — the trajectory that motivated the calibration
    (pred_db_vs_sb=1.05x vs measured 0.75x; pred_fold_speedup=7.09x vs
    measured 1.09x) was invisible while only the roofline was recorded.
    """
    fit = model_fit.shipped_fit()
    if fit is None:
        return None
    return fit.predict_us(p, plan, batch=batch, bits=32)


def sweep_slice(limit: int = 4) -> list[TConvProblem]:
    """Small members of the 261-config sweep (interpret-mode friendly)."""
    small = [p for p in synthetic_sweep() if is_small_problem(p)]
    # Spread across the filtered list so Ks/S/Ic all vary.
    step = max(len(small) // limit, 1)
    return small[::step][:limit]


def fold_head_to_head() -> None:
    """Folded vs grid-batch MM2IM on a batch-8 small-image GAN layer.

    DCGAN layer 1 (4x4 input upscale) at 1/4 width — the Table II shape
    whose ``n_slab*iw`` M-dimension starves the 128-lane MXU hardest.  We
    time the *same* tile geometry with ``fold_batch`` off and on (both
    kernel variants), and run the full tuner at batch 8 so the reported
    winner reflects the plan dispatch would consume.  Folding is
    bit-identical by construction, so the speedup is free accuracy-wise;
    the perf model's tile-quantized prediction is printed next to the
    measured ratio (ranking-agreement check, as for sb-vs-db).
    """
    p = TConvProblem(4, 4, 256, 5, 128, 2)  # DCGAN_1 @ 1/4 width
    batch = 8
    # Geometry per variant: the sb kernel runs the whole output as one row
    # block; the db leg uses block_oh=4 so n_j=2 and the two-slot pipeline
    # actually has a block to overlap (candidate_plans excludes n_j<2 db
    # candidates for the same reason).
    geoms = {
        "mm2im": dict(block_oh=8, block_oc=128, grid_order="bcj"),
        "mm2im_db": dict(block_oh=4, block_oc=128, grid_order="bcj"),
        "mm2im_ks": dict(block_oh=8, block_oc=128, grid_order="bcj"),
        "mm2im_og": dict(block_oh=8, block_oc=128, grid_order="bcj"),
    }
    for method in ("mm2im", "mm2im_db", "mm2im_ks", "mm2im_og"):
        geom = geoms[method]
        # Alternating min-of-rounds: interpret-mode wall time on a shared
        # CPU drifts with background load, so interleave the two variants
        # and keep each one's best round — min is the noise-robust
        # statistic for "how fast can this program run".
        grid_us = fold_us = float("inf")
        for _ in range(3):
            grid_us = min(grid_us, measure_plan(
                p, Plan(method=method, **geom), batch=batch, repeats=3))
            fold_us = min(fold_us, measure_plan(
                p, Plan(method=method, fold_batch=True, **geom),
                batch=batch, repeats=3))
        est = {"mm2im_db": mm2im_db_estimate,
               "mm2im_ks": mm2im_ks_estimate,
               "mm2im_og": mm2im_og_estimate}.get(method, mm2im_estimate)
        pred_grid = est(p, batch, bits=32, **geom).t_overlapped
        pred_fold = est(p, batch, bits=32, fold_batch=True,
                        **geom).t_overlapped
        # Calibrated predictions beside the roofline; rank_agree scores the
        # model the autotuner actually prunes with (the fit when shipped).
        fit_grid = _fit_pred_us(p, Plan(method=method, **geom), batch)
        fit_fold = _fit_pred_us(p, Plan(method=method, fold_batch=True,
                                        **geom), batch)
        if fit_grid is not None:
            agree = (fold_us <= grid_us) == (fit_fold <= fit_grid)
            fit_part = (f"pred_fold_speedup_fit="
                        f"{fit_grid / max(fit_fold, 1e-9):.2f}x;")
        else:
            agree = (fold_us <= grid_us) == (pred_fold <= pred_grid)
            fit_part = ""
        emit(f"autotune_fold_dcgan1_{method}", fold_us,
             f"batch={batch};geom=oh{geom['block_oh']}/oc{geom['block_oc']}"
             f"/{geom['grid_order']};"
             f"grid_us={grid_us:.1f};fold_us={fold_us:.1f};"
             f"fold_speedup={grid_us / max(fold_us, 1e-9):.2f}x;"
             f"pred_fold_speedup={pred_grid / max(pred_fold, 1e-12):.2f}x;"
             f"{fit_part}"
             f"rank_agree={int(agree)}")

    # The tuner itself at batch 8: the winner the batched serve path gets.
    # repeats=5: the candidates differ by ~1.3x here, so the tuner's
    # median needs more samples than the default against CI timer noise.
    res = autotune_result(p, batch=batch, cache=PlanCache(
        os.path.join(tempfile.gettempdir(), "repro_bench_fold.json")),
        max_measure=4, repeats=5, force=True)
    w = res.plan
    emit("autotune_fold_dcgan1_tuned", res.us,
         f"plan=oh{w.block_oh}/oc{w.block_oc}/{w.grid_order}"
         f"/{w.method or 'mm2im'};fold_batch={int(w.fold_batch)};"
         f"default_us={res.default_us:.1f};"
         f"speedup={res.speedup_vs_default:.2f}x")


def _db_head_to_head(p: TConvProblem, res) -> str:
    """Single- vs double-buffered at the default geometry: measured ratio
    next to the roofline *and* calibrated predictions; ``rank_agree``
    scores the model the autotuner actually prunes with (the shipped fit
    when one exists, else the roofline)."""
    d = res.default_plan
    geom = dict(block_oh=d.block_oh, block_oc=d.block_oc,
                grid_order=d.grid_order)
    plan_sb = Plan(d.block_oh, d.block_oc, d.grid_order, "mm2im")
    plan_db = Plan(d.block_oh, d.block_oc, d.grid_order, "mm2im_db")
    sb_us = measure_plan(p, plan_sb, repeats=2)
    db_us = measure_plan(p, plan_db, repeats=2)
    pred_sb = mm2im_estimate(p, 1, bits=32, **geom).t_overlapped
    pred_db = mm2im_db_estimate(p, 1, bits=32, **geom).t_overlapped
    fit_sb, fit_db = _fit_pred_us(p, plan_sb), _fit_pred_us(p, plan_db)
    if fit_sb is not None:
        agree = (sb_us <= db_us) == (fit_sb <= fit_db)
        fit_part = f"pred_db_vs_sb_fit={fit_sb / max(fit_db, 1e-9):.2f}x;"
    else:
        agree = (sb_us <= db_us) == (pred_sb <= pred_db)
        fit_part = ""
    # geom= records the timed geometry so core/model_fit can replay this
    # head-to-head exactly (no heuristic reconstruction needed).
    return (f"geom=oh{d.block_oh}/oc{d.block_oc}/{d.grid_order};"
            f"sb_us={sb_us:.1f};db_us={db_us:.1f};"
            f"db_vs_sb={sb_us / max(db_us, 1e-9):.2f}x;"
            f"pred_db_vs_sb={pred_sb / max(pred_db, 1e-12):.2f}x;"
            f"{fit_part}"
            f"rank_agree={int(agree)}")


#: The large-image / stride-4 problems the og-vs-mm2im-vs-ks head-to-head
#: times (>= 32x32, the FSRCNN/pix2pix decoder regime of
#: ``paper_models.large_image_sweep``).  Channels kept small: interpret
#: mode executes these for real.
LARGE_IMAGE_PROBLEMS = (
    TConvProblem(32, 32, 16, 5, 16, 4),
    TConvProblem(32, 32, 32, 7, 16, 4),
    TConvProblem(64, 64, 16, 7, 16, 4),
    TConvProblem(64, 64, 32, 7, 16, 4),
)


def large_image_head_to_head() -> None:
    """og vs mm2im vs mm2im_ks on the large-image sweep regime.

    One row per problem (``autotune_large_*_ogcmp``), all three methods
    timed at the *same* heuristic-default tile geometry so the comparison
    isolates the dataflow, not the block shape.  ``core/model_fit``
    replays these rows as og-vs-mm2im and og-vs-ks rank pairs, and the
    distilled ``BENCH_mm2im.json`` carries them in its ``large_image``
    section for the CI perf gate.
    """
    from repro.core import tiling

    for p in LARGE_IMAGE_PROBLEMS:
        tp = tiling.plan(p, batch=1, bits=32)
        # ks/og segregate output rows into stride-phase classes, so their
        # row block must hold whole phase groups: snap oh to the stride.
        oh = max(p.stride, tp.block_oh - tp.block_oh % p.stride)
        geom = dict(block_oh=oh, block_oc=tp.block_oc,
                    grid_order=tp.grid_order)
        us = {}
        for method in ("mm2im_og", "mm2im", "mm2im_ks"):
            best = float("inf")
            for _ in range(2):  # alternating min-of-rounds (noise)
                best = min(best, measure_plan(
                    p, Plan(method=method, **geom), repeats=2))
            us[method] = best
        pred_og = mm2im_og_estimate(p, 1, bits=32, **geom).t_overlapped
        pred_mm = mm2im_estimate(p, 1, bits=32, **geom).t_overlapped
        fit_og = _fit_pred_us(p, Plan(method="mm2im_og", **geom))
        fit_mm = _fit_pred_us(p, Plan(method="mm2im", **geom))
        fit_part = ("" if fit_og is None else
                    f"pred_og_vs_mm2im_fit={fit_og / max(fit_mm, 1e-9):.2f}x;")
        emit(f"autotune_large_ih{p.ih}_ic{p.ic}_ks{p.ks}_oc{p.oc}"
             f"_s{p.stride}_ogcmp", None,
             f"geom=oh{geom['block_oh']}/oc{geom['block_oc']}"
             f"/{geom['grid_order']};"
             f"og_us={us['mm2im_og']:.1f};mm2im_us={us['mm2im']:.1f};"
             f"ks_us={us['mm2im_ks']:.1f};"
             f"og_vs_mm2im={us['mm2im'] / max(us['mm2im_og'], 1e-9):.2f}x;"
             f"og_vs_ks={us['mm2im_ks'] / max(us['mm2im_og'], 1e-9):.2f}x;"
             f"pred_og_vs_mm2im={pred_mm / max(pred_og, 1e-12):.2f}x;"
             f"{fit_part}"
             f"best={min(us, key=us.get)}")


def main() -> None:
    cache_path = os.environ.get(
        "REPRO_AUTOTUNE_CACHE",
        os.path.join(tempfile.gettempdir(), "repro_bench_autotune.json"))
    cache = PlanCache(cache_path)

    rng = np.random.default_rng(0)
    results = []
    for p in sweep_slice():
        # force=True: measure, don't replay — without wiping the cache file
        # (it may be the user's persistent tuned-plan store).
        res = autotune_result(p, cache=cache, max_measure=4, repeats=2,
                              force=True)
        # Tuned plan must be numerically indistinguishable from the oracle.
        x = rng.standard_normal((1, p.ih, p.iw, p.ic)).astype(np.float32)
        w = (rng.standard_normal((p.ks, p.ks, p.oc, p.ic)) * 0.1
             ).astype(np.float32)
        got = np.asarray(tconv(x, w, stride=p.stride, padding=p.padding,
                               plan=res.plan))
        want = np.asarray(ref.iom_reference(x, w, stride=p.stride,
                                            padding=p.padding))
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)
        results.append(res)
        name = f"autotune_ih{p.ih}_ic{p.ic}_ks{p.ks}_oc{p.oc}_s{p.stride}"
        pl = res.plan
        emit(name, res.us,
             f"default_us={res.default_us:.1f};"
             f"speedup={res.speedup_vs_default:.2f}x;"
             f"plan=oh{pl.block_oh}/oc{pl.block_oc}/{pl.grid_order}"
             f"/{pl.method or 'mm2im'};"
             f"cands={res.n_candidates};timed={res.n_measured}")
        # Derived-only row (the head-to-head times live in the derived
        # string): us_per_call=None, not a fake measured 0.0us.
        emit(name + "_dbcmp", None, _db_head_to_head(p, res))

    # Cross-process round-trip: a brand-new cache object must see every key.
    fresh = PlanCache(cache_path)
    missing = [r.key for r in results if fresh.get(r.key) != r.plan]
    assert not missing, f"cache round-trip lost keys: {missing}"
    su = np.array([r.speedup_vs_default for r in results])
    n_db = sum(1 for r in results if r.plan.method == "mm2im_db")
    emit("autotune_summary", None,
         f"n={len(results)};geomean_speedup={np.exp(np.log(su).mean()):.2f}x;"
         f"db_winners={n_db};cache_entries={len(fresh)};cache={cache_path}")

    # Folded vs grid-batch on the batch-8 DCGAN layer-1 shape (plan v2).
    fold_head_to_head()

    # og vs mm2im vs ks on the large-image / stride-4 sweep regime.
    large_image_head_to_head()

    # int8 (the paper's precision) + batch>1 key coverage: the instances
    # the GAN int8 serve path and batched training hit.  Replays from the
    # cache when already tuned (force is deliberately off here).
    q = sweep_slice(limit=2)
    sw = autotune_sweep(q, dtypes=(jnp.int8,), batches=(1,), cache=cache,
                        max_measure=2, repeats=1)
    sw += autotune_sweep(q[:1], dtypes=(jnp.float32,), batches=(2,),
                         cache=cache, max_measure=2, repeats=1)
    for i, r in enumerate(sw):
        emit(f"autotune_sweep_{i}", r.us,
             f"key={r.key};plan=oh{r.plan.block_oh}/oc{r.plan.block_oc}"
             f"/{r.plan.grid_order}/{r.plan.method or 'mm2im'};"
             f"from_cache={int(r.from_cache)}")

    # Tier hit-rate: re-run the slice through *automatic* consumption (no
    # plan= anywhere) and attribute each hit to the precedence tier that
    # served it — user cache (tuned above), shipped per-backend table
    # (committed under src/repro/data/plans), or heuristic fallback.
    from repro.core import autotune, plan_table
    from repro.kernels import ops

    old_env = os.environ.get(autotune.CACHE_ENV)
    os.environ[autotune.CACHE_ENV] = cache_path
    autotune.reset_shared_caches()
    ops.clear_consumed_plans()
    try:
        shipped = plan_table.shipped_table()
        probe = list(sweep_slice())
        if shipped is not None and len(shipped):
            # A committed-table problem the loop above did NOT tune, so the
            # shipped tier (below the user cache) actually shows up — the
            # Table II FCN row, which the tune_sweep --small slice ships.
            probe.append(next(r for r in TABLE_II
                              if r.name == "FCN").problem)
        for p in probe:
            x = rng.standard_normal((1, p.ih, p.iw, p.ic)).astype(np.float32)
            w = (rng.standard_normal((p.ks, p.ks, p.oc, p.ic)) * 0.1
                 ).astype(np.float32)
            np.asarray(tconv(x, w, stride=p.stride, padding=p.padding))
        tiers = [t for _, _, t in ops.consumed_plans()]
        emit("autotune_tier_hits", None,
             f"probed={len(probe)};"
             f"user_cache={tiers.count(autotune.TIER_USER_CACHE)};"
             f"shipped_table={tiers.count(autotune.TIER_SHIPPED)};"
             f"heuristic={len(probe) - len(tiers)};"
             f"shipped_backend="
             f"{shipped.provenance.get('backend') if shipped else None};"
             f"shipped_entries={len(shipped) if shipped else 0}")
    finally:
        if old_env is None:
            os.environ.pop(autotune.CACHE_ENV, None)
        else:
            os.environ[autotune.CACHE_ENV] = old_env
        autotune.reset_shared_caches()


if __name__ == "__main__":
    main()
