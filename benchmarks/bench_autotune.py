"""Tuned-vs-default block plans over a slice of the 261-config sweep.

For each problem in the slice the autotuner enumerates legal
``(block_oh, block_oc, grid_order)`` tile plans, prunes with the roofline
model, times the survivors through the real kernel, and persists the
winner.  We report, per problem:

  * measured us of the tuned plan vs the seed ``plan_blocks`` heuristic;
  * the winning plan geometry;
  * a numerical check of the tuned plan against the unfused-IOM oracle
    (the acceptance gate: tuning must never change results).

A second pass re-opens the cache from a *fresh* ``PlanCache`` (simulating
a new process) and asserts every tuned key round-trips.

The slice keeps problems small because off-TPU the kernel runs in Pallas
interpret mode; on a real TPU the same harness times the compiled kernel.
Set ``REPRO_AUTOTUNE_CACHE`` to control the cache file (defaults to a
temp file here so benchmark runs do not pollute the user cache).
"""

from __future__ import annotations

import os
import tempfile

import numpy as np

from benchmarks.common import emit
from repro.configs.paper_models import synthetic_sweep
from repro.core.autotune import PlanCache, autotune_result, measure_plan
from repro.core.maps import TConvProblem
from repro.kernels import ref
from repro.kernels.ops import tconv


def sweep_slice(limit: int = 4) -> list[TConvProblem]:
    """Small members of the 261-config sweep (interpret-mode friendly)."""
    small = [p for p in synthetic_sweep()
             if p.ih <= 7 and p.ic <= 64 and p.oc <= 32 and p.ks <= 5]
    # Spread across the filtered list so Ks/S/Ic all vary.
    step = max(len(small) // limit, 1)
    return small[::step][:limit]


def main() -> None:
    cache_path = os.environ.get(
        "REPRO_AUTOTUNE_CACHE",
        os.path.join(tempfile.gettempdir(), "repro_bench_autotune.json"))
    cache = PlanCache(cache_path)

    rng = np.random.default_rng(0)
    results = []
    for p in sweep_slice():
        # force=True: measure, don't replay — without wiping the cache file
        # (it may be the user's persistent tuned-plan store).
        res = autotune_result(p, cache=cache, max_measure=4, repeats=2,
                              force=True)
        # Tuned plan must be numerically indistinguishable from the oracle.
        x = rng.standard_normal((1, p.ih, p.iw, p.ic)).astype(np.float32)
        w = (rng.standard_normal((p.ks, p.ks, p.oc, p.ic)) * 0.1
             ).astype(np.float32)
        got = np.asarray(tconv(x, w, stride=p.stride, padding=p.padding,
                               plan=res.plan))
        want = np.asarray(ref.iom_reference(x, w, stride=p.stride,
                                            padding=p.padding))
        np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)
        results.append(res)
        name = f"autotune_ih{p.ih}_ic{p.ic}_ks{p.ks}_oc{p.oc}_s{p.stride}"
        pl = res.plan
        emit(name, res.us,
             f"default_us={res.default_us:.1f};"
             f"speedup={res.speedup_vs_default:.2f}x;"
             f"plan=oh{pl.block_oh}/oc{pl.block_oc}/{pl.grid_order};"
             f"cands={res.n_candidates};timed={res.n_measured}")

    # Cross-process round-trip: a brand-new cache object must see every key.
    fresh = PlanCache(cache_path)
    missing = [r.key for r in results if fresh.get(r.key) != r.plan]
    assert not missing, f"cache round-trip lost keys: {missing}"
    su = np.array([r.speedup_vs_default for r in results])
    emit("autotune_summary", 0.0,
         f"n={len(results)};geomean_speedup={np.exp(np.log(su).mean()):.2f}x;"
         f"cache_entries={len(fresh)};cache={cache_path}")


if __name__ == "__main__":
    main()
