"""Benchmark harness — one module per paper table/figure.

``PYTHONPATH=src python -m benchmarks.run [--only NAME] [--json OUT.json]``

Emits ``name,us_per_call,derived`` CSV lines (stdout).  ``--json`` also
writes every emitted row (plus run metadata: backend, jax version,
timestamp) to a JSON file — the machine-readable perf-trajectory artifact
CI records per commit (``BENCH_autotune.json`` for the autotune slice).
"""

from __future__ import annotations

import argparse
import json
import sys
import time
import traceback

MODULES = [
    ("drop_rates", "benchmarks.bench_drop_rates"),            # Fig. 1 / 7
    ("synthetic_261", "benchmarks.bench_synthetic_261"),      # Fig. 6
    ("model_layers", "benchmarks.bench_model_layers"),        # Table II
    ("accel_compare", "benchmarks.bench_accel_compare"),      # Table III
    ("gan_e2e", "benchmarks.bench_gan_e2e"),                  # Table IV
    ("perf_model_validation", "benchmarks.bench_perf_model_validation"),  # §V-F
    ("ablations", "benchmarks.bench_ablations"),              # kernel ablations
    ("autotune", "benchmarks.bench_autotune"),                # tuned vs default plans
    ("scale_roofline", "benchmarks.bench_scale_roofline"),    # §Roofline
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    ap.add_argument("--json", default=None, metavar="OUT",
                    help="also write the emitted rows + run metadata as JSON "
                         "(the CI perf-trajectory artifact)")
    args = ap.parse_args()
    print("name,us_per_call,derived")
    failures = 0
    ran = []
    for name, mod in MODULES:
        if args.only and args.only != name:
            continue
        t0 = time.time()
        try:
            __import__(mod, fromlist=["main"]).main()
            ran.append(name)
            print(f"# {name} done in {time.time()-t0:.1f}s", file=sys.stderr)
        except Exception:
            failures += 1
            print(f"# {name} FAILED", file=sys.stderr)
            traceback.print_exc()
    if args.json:
        import jax

        from benchmarks import common

        doc = {
            "schema": 1,
            "created": time.time(),
            "backend": jax.default_backend(),
            "jax": jax.__version__,
            "modules": ran,
            "failures": failures,
            "rows": common.rows(),
        }
        with open(args.json, "w") as f:
            json.dump(doc, f, indent=1, sort_keys=True)
            f.write("\n")
        print(f"# wrote {len(doc['rows'])} rows to {args.json}",
              file=sys.stderr)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
