"""Benchmark harness — one module per paper table/figure.

``PYTHONPATH=src python -m benchmarks.run [--only NAME] [--json OUT.json]``

Emits ``name,us_per_call,derived`` CSV lines (stdout).  ``--json`` also
writes every emitted row (plus run metadata: backend, jax version,
timestamp) to a JSON file — the machine-readable perf-trajectory artifact
CI records per commit (``BENCH_autotune.json`` for the autotune slice) —
and additionally distills a compact repo-root ``BENCH_mm2im.json``
(per-method timings, modeled MXU utilization incl. folded-vs-grid, and
the autotune tier hit-rates) so the MM2IM perf trajectory has a single
small file to diff across commits.
"""

from __future__ import annotations

import argparse
import json
import sys
import time
import traceback
from pathlib import Path

REPO_ROOT = Path(__file__).resolve().parent.parent

MODULES = [
    ("drop_rates", "benchmarks.bench_drop_rates"),            # Fig. 1 / 7
    ("synthetic_261", "benchmarks.bench_synthetic_261"),      # Fig. 6
    ("model_layers", "benchmarks.bench_model_layers"),        # Table II
    ("accel_compare", "benchmarks.bench_accel_compare"),      # Table III
    ("gan_e2e", "benchmarks.bench_gan_e2e"),                  # Table IV
    ("perf_model_validation", "benchmarks.bench_perf_model_validation"),  # §V-F
    ("ablations", "benchmarks.bench_ablations"),              # kernel ablations
    ("autotune", "benchmarks.bench_autotune"),                # tuned vs default plans
    ("scale_roofline", "benchmarks.bench_scale_roofline"),    # §Roofline
    ("serve_tconv", "benchmarks.bench_serve_tconv"),          # serving trajectory
]


def _parse_value(v: str):
    """Best-effort typed parse: int, then float, then the raw string.

    The distilled JSON previously shipped every derived value as a string
    (``tier_hits`` counts as ``"0"``/``"5"``), which made downstream
    consumers re-parse — and silently compare strings.
    """
    for cast in (int, float):
        try:
            return cast(v)
        except ValueError:
            pass
    return v


def _parse_derived(derived: str) -> dict:
    """'k=v;k=v' derived strings -> dict with numeric values typed."""
    out = {}
    for part in derived.split(";"):
        if "=" in part:
            k, _, v = part.partition("=")
            out[k] = _parse_value(v)
    return out


def mm2im_summary(rows: list) -> dict:
    """Distill the emitted rows into the compact MM2IM trajectory doc.

    Three sections, each present when its source ran (plus an
    always-available modeled section, so even an ``--only autotune`` run
    seeds a non-empty trajectory):

    * ``methods`` — per-method mean timing + modeled MXU utilization from
      the ``tableIII_summary_*`` rows;
    * ``autotune`` — every ``autotune*`` row verbatim (tuned-vs-default,
      sb-vs-db and folded-vs-grid head-to-heads);
    * ``tier_hits`` — the parsed ``autotune_tier_hits`` attribution;
    * ``modeled_fold`` — tile-quantized folded-vs-grid utilization on the
      batch-8 Table II rows straight from ``core/perf_model`` (no
      benchmarking required, so the field never goes empty);
    * ``rank_agreement`` — predicted-vs-measured ordering over this run's
      recorded head-to-heads (``core/model_fit.rank_agreement``), scored
      with the shipped per-backend calibration when one exists.  This is
      the section ``tools/bench_gate.py`` hard-gates on;
    * ``large_image`` — the og-vs-mm2im-vs-ks cross-method head-to-heads
      on the >=32x32 stride-4 regime (``autotune_large_*_ogcmp``), parsed
      so the gather-family trajectory diffs at a glance (the raw rows
      also stay in ``autotune`` for the rank-agreement gate);
    * ``serve`` — every ``serve*`` row from ``bench_serve_tconv`` with its
      derived fields parsed (batched-vs-sequential speedup, batch-fill
      ratio, wait-bound flag), so the serving trajectory diffs alongside
      the kernel one;
    * ``serve_chaos`` — the fault-injected degraded-mode rows
      (``serve_chaos_*``: ladder rung counts, shed/expired/breaker
      counters) kept in their *own* section: ``tools/bench_gate.py``
      ignores it for latency banding — degraded-mode latency is the
      injected fault's artifact, not a kernel regression signal.
    """
    methods = {}
    autotune_rows = []
    serve = {}
    serve_chaos = {}
    large_image = {}
    tier_hits = None
    for r in rows:
        name = r["name"]
        if name.startswith("tableIII_summary_"):
            d = _parse_derived(r["derived"])
            entry = {"us": r["us_per_call"]}
            if "mean_mxu_util" in d:
                entry["mean_mxu_util"] = float(d["mean_mxu_util"])
            methods[name[len("tableIII_summary_"):]] = entry
        elif name == "autotune_tier_hits":
            tier_hits = _parse_derived(r["derived"])
        elif name.startswith("autotune"):
            autotune_rows.append(r)
            if name.startswith("autotune_large_"):
                large_image[name] = _parse_derived(r["derived"])
        elif name.startswith("serve_chaos"):
            serve_chaos[name] = _parse_derived(r["derived"])
        elif name.startswith("serve"):
            serve[name] = _parse_derived(r["derived"])

    from repro.configs.paper_models import TABLE_II
    from repro.core.perf_model import mm2im_estimate

    modeled = {}
    for row in TABLE_II:
        g = mm2im_estimate(row.problem, 8, bits=8)
        f = mm2im_estimate(row.problem, 8, bits=8, fold_batch=True)
        modeled[row.name] = {
            "grid_mxu_util": round(g.mxu_utilization, 4),
            "fold_mxu_util": round(f.mxu_utilization, 4),
            "fold_speedup": round(g.t_overlapped / f.t_overlapped, 3),
        }
    rank = None
    if autotune_rows:
        from repro.core import model_fit

        pairs = model_fit.pairs_from_bench({"autotune": autotune_rows})
        if pairs:
            rank = model_fit.rank_agreement(pairs, model_fit.shipped_fit())

    return {"methods": methods, "autotune": autotune_rows,
            "tier_hits": tier_hits, "modeled_fold_b8": modeled,
            "rank_agreement": rank, "large_image": large_image,
            "serve": serve, "serve_chaos": serve_chaos}


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma-separated module names to run "
                         "(e.g. --only autotune,serve_tconv)")
    ap.add_argument("--json", default=None, metavar="OUT",
                    help="also write the emitted rows + run metadata as JSON "
                         "(the CI perf-trajectory artifact)")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None
    print("name,us_per_call,derived")
    failures = 0
    ran = []
    for name, mod in MODULES:
        if only is not None and name not in only:
            continue
        t0 = time.time()
        try:
            __import__(mod, fromlist=["main"]).main()
            ran.append(name)
            print(f"# {name} done in {time.time()-t0:.1f}s", file=sys.stderr)
        except Exception:
            failures += 1
            print(f"# {name} FAILED", file=sys.stderr)
            traceback.print_exc()
    if args.json:
        import jax

        from benchmarks import common

        doc = {
            "schema": 1,
            "created": time.time(),
            "backend": jax.default_backend(),
            "jax": jax.__version__,
            "modules": ran,
            "failures": failures,
            "rows": common.rows(),
        }
        with open(args.json, "w") as f:
            json.dump(doc, f, indent=1, sort_keys=True)
            f.write("\n")
        print(f"# wrote {len(doc['rows'])} rows to {args.json}",
              file=sys.stderr)

        # Compact MM2IM trajectory file at the repo root — the per-commit
        # artifact CI uploads next to BENCH_autotune.json.
        compact = {
            "schema": 1,
            "created": doc["created"],
            "backend": doc["backend"],
            "jax": doc["jax"],
            "modules": ran,
        }
        compact.update(mm2im_summary(doc["rows"]))
        mm2im_path = REPO_ROOT / "BENCH_mm2im.json"
        with open(mm2im_path, "w") as f:
            json.dump(compact, f, indent=1, sort_keys=True)
            f.write("\n")
        print(f"# wrote MM2IM trajectory to {mm2im_path}", file=sys.stderr)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
