"""Benchmark harness — one module per paper table/figure.

``PYTHONPATH=src python -m benchmarks.run [--only NAME]``

Emits ``name,us_per_call,derived`` CSV lines (stdout).
"""

from __future__ import annotations

import argparse
import sys
import time
import traceback

MODULES = [
    ("drop_rates", "benchmarks.bench_drop_rates"),            # Fig. 1 / 7
    ("synthetic_261", "benchmarks.bench_synthetic_261"),      # Fig. 6
    ("model_layers", "benchmarks.bench_model_layers"),        # Table II
    ("accel_compare", "benchmarks.bench_accel_compare"),      # Table III
    ("gan_e2e", "benchmarks.bench_gan_e2e"),                  # Table IV
    ("perf_model_validation", "benchmarks.bench_perf_model_validation"),  # §V-F
    ("ablations", "benchmarks.bench_ablations"),              # kernel ablations
    ("autotune", "benchmarks.bench_autotune"),                # tuned vs default plans
    ("scale_roofline", "benchmarks.bench_scale_roofline"),    # §Roofline
]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None)
    args = ap.parse_args()
    print("name,us_per_call,derived")
    failures = 0
    for name, mod in MODULES:
        if args.only and args.only != name:
            continue
        t0 = time.time()
        try:
            __import__(mod, fromlist=["main"]).main()
            print(f"# {name} done in {time.time()-t0:.1f}s", file=sys.stderr)
        except Exception:
            failures += 1
            print(f"# {name} FAILED", file=sys.stderr)
            traceback.print_exc()
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
