"""Paper Fig. 1 / Fig. 7: percentage of cropped (dropped) outputs.

Analytic drop rates D_r over (a) the generative-model layers of Fig. 1 /
Table II and (b) the 261-problem synthetic sweep, grouped the way Fig. 7
groups them (by Ks / Ih / S).  Cross-checks the paper's headline numbers:
Fig. 2 example D_r = 0.55; DCGAN <= 28% ineffectual work.
"""

from __future__ import annotations

import numpy as np

from benchmarks.common import emit
from repro.configs.paper_models import TABLE_II, synthetic_sweep
from repro.core.maps import TConvProblem, drop_stats


def main() -> None:
    # Fig. 2 worked example.
    ex = drop_stats(TConvProblem(2, 2, 2, 3, 2, 1))
    emit("fig2_example_drop_rate", None,
         f"D_r={ex['D_r']:.3f};paper=0.55;P/F={ex['buffer_saving_no_skip']:.2f}"
         f";skip={ex['buffer_saving_with_skip']:.2f}")

    # Fig. 1: model layers.
    for row in TABLE_II:
        st = drop_stats(row.problem)
        emit(f"fig1_drop_{row.name}", None,
             f"D_r={st['D_r']:.3f};eff_frac={st['effectual_fraction']:.3f}")

    # Fig. 7: synthetic sweep grouped by (Ks, S).
    groups: dict = {}
    for p in synthetic_sweep():
        groups.setdefault((p.ks, p.stride), []).append(drop_stats(p)["D_r"])
    for (ks, s), v in sorted(groups.items()):
        emit(f"fig7_drop_ks{ks}_s{s}", None,
             f"mean_D_r={np.mean(v):.3f};n={len(v)}")


if __name__ == "__main__":
    main()
