"""End-to-end driver: train a DCGAN whose generator runs on MM2IM.

    PYTHONPATH=src python examples/train_dcgan.py --steps 200 --scale-down 16

Full GAN training (generator + discriminator, alternating updates) on
synthetic image data; every generator TCONV layer executes the fused
MM2IM kernel *forward and backward* (custom_vjp).  At --scale-down 1 and
--image-size 64 this is the paper's DCGAN at full width (train on real
hardware); the CPU default trains a few hundred steps of the reduced
model in minutes, checkpointing along the way.

The step comes from ``runtime.steps.make_gan_train_step``, which resolves
tuned tile plans from the autotuner cache automatically — run
``python -m benchmarks.run --only autotune`` (or ``autotune_sweep``) once
and this trainer picks the tuned plans/kernel variant up on its own.
"""

from __future__ import annotations

import argparse
import time

import jax

from repro.checkpoint.manager import CheckpointManager
from repro.data.pipeline import DataConfig, make_batch
from repro.models import gan
from repro.optim import adamw
from repro.runtime import steps as runtime_steps


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--scale-down", type=int, default=16)
    ap.add_argument("--method", default="mm2im",
                    choices=["mm2im", "mm2im_db", "iom_unfused",
                             "zero_insertion", "tdc", "lax"])
    ap.add_argument("--lr", type=float, default=2e-4)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_dcgan")
    ap.add_argument("--log-every", type=int, default=20)
    args = ap.parse_args()

    kg, kd = jax.random.split(jax.random.PRNGKey(0))
    g_params, _ = gan.init_dcgan_g(kg, scale_down=args.scale_down)
    d_params, _ = gan.init_dcgan_d(kd, base=max(64 // args.scale_down, 8))

    opt_cfg = adamw.AdamWConfig(lr=args.lr, b1=0.5, b2=0.999,
                                weight_decay=0.0, clip_norm=None,
                                warmup_steps=0, total_steps=args.steps,
                                schedule="constant")
    bundle = runtime_steps.make_gan_train_step(
        g_params, d_params, opt_cfg, batch=args.batch, method=args.method)
    train_step = bundle.fn
    tuned = bundle.meta["plans"]  # what the step actually closed over
    if tuned:
        print(f"[dcgan] tuned plans from autotuner cache: "
              f"{ {k: (p.block_oh, p.block_oc, p.method) for k, p in tuned.items()} }")

    g_opt = adamw.init(g_params, opt_cfg)
    d_opt = adamw.init(d_params, opt_cfg)

    data_cfg = DataConfig(vocab=0, seq_len=0, global_batch=args.batch,
                          kind="image", image_size=64)
    z_cfg = DataConfig(vocab=0, seq_len=0, global_batch=args.batch,
                       kind="latent", seed=7)
    ckpt = CheckpointManager(args.ckpt_dir)
    state = (g_params, g_opt, d_params, d_opt)

    t0 = time.time()
    for step in range(args.steps):
        z = make_batch(z_cfg, step)["z"]
        real = make_batch(data_cfg, step)["images"]
        state, (dl, gl) = train_step(state, z, real)
        if (step + 1) % args.log_every == 0:
            print(f"[dcgan] step {step+1} d_loss={float(dl):.3f} "
                  f"g_loss={float(gl):.3f} ({(step+1)/(time.time()-t0):.1f} it/s)")
        if (step + 1) % max(args.steps // 2, 1) == 0:
            ckpt.save(step + 1, state, block=True)

    sample = runtime_steps.make_gan_sample_step(
        state[0], batch=4, method=args.method).fn
    imgs = sample(state[0], make_batch(z_cfg, 999)["z"][:4])
    print(f"[dcgan] done: generated {imgs.shape}, "
          f"range [{float(imgs.min()):.2f}, {float(imgs.max()):.2f}], "
          f"method={args.method}")


if __name__ == "__main__":
    main()
