"""Serve a small LM with batched requests through the decode path.

    PYTHONPATH=src python examples/serve_lm.py --arch mamba2-370m --requests 6

Demonstrates the serving runtime the decode_32k / long_500k dry-run cells
lower: batched request admission, KV/recurrent-state cache, greedy decode.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import registry
from repro.models import lm


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="mamba2-370m")
    ap.add_argument("--requests", type=int, default=6)
    ap.add_argument("--prompt-len", type=int, default=12)
    ap.add_argument("--gen", type=int, default=24)
    args = ap.parse_args()

    cfg = registry.get(args.arch).smoke
    params, _ = lm.init(cfg, jax.random.PRNGKey(0))
    max_seq = args.prompt_len + args.gen

    # Batched request queue (all admitted at once here; a real server
    # would do continuous batching — the cache supports it).
    prompts = jax.random.randint(jax.random.PRNGKey(1),
                                 (args.requests, args.prompt_len), 0, cfg.vocab)
    cache = lm.init_cache(cfg, args.requests, max_seq, dtype=jnp.float32)
    step = jax.jit(lambda p, t, c: lm.decode(cfg, p, t, c))

    t0 = time.time()
    logits = None
    for i in range(args.prompt_len):
        logits, cache = step(params, prompts[:, i:i + 1], cache)
    generated = []
    for _ in range(args.gen):
        nxt = jnp.argmax(logits[:, -1], -1)[:, None]
        generated.append(nxt)
        logits, cache = step(params, nxt, cache)
    jax.block_until_ready(logits)
    dt = time.time() - t0

    gen = jnp.concatenate(generated, 1)
    tput = args.requests * (args.prompt_len + args.gen) / dt
    print(f"[serve_lm] {cfg.name}: {args.requests} requests x "
          f"{args.gen} tokens, {tput:.1f} tok/s")
    for r in range(min(3, args.requests)):
        print(f"  req{r}: {gen[r, :10].tolist()} ...")


if __name__ == "__main__":
    main()
