"""TCONV method showcase: maps, tiling, and all four implementations.

    PYTHONPATH=src python examples/tconv_showcase.py

Renders the paper's Fig. 2 maps as ASCII, runs every method on the same
problem, and prints the per-method roofline — a compact tour of what the
paper contributes and what this repo reproduces.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import mm2im
from repro.core.maps import spatial_maps

p = mm2im.problem(2, 2, 2, 3, 2, 1)  # the paper's Fig. 2 example
omap, cmap = spatial_maps(p)

print("=== Fig. 2: output map (rows = input pixels m, cols = (kh,kw)) ===")
print("    (value = flat output index; '.' = cropped / ineffectual)")
for m in range(p.m):
    cells = []
    for kh in range(p.ks):
        for kw in range(p.ks):
            v = omap[m, kh, kw]
            cells.append(" ." if v < 0 else f"{v:2d}")
    print(f"  m={m}: " + " ".join(cells))

st = mm2im.analyze(p)
print(f"\nD_o={st['D_o']} dropped of {st['P_outs']} partial outputs "
      f"(D_r={st['D_r']:.2f}; paper: 0.55)")

print("\n=== All four methods, one problem ===")
key = jax.random.PRNGKey(0)
x = jax.random.normal(key, (1, 9, 9, 64))
w = jax.random.normal(key, (5, 5, 32, 64)) * 0.05
gold = mm2im.transposed_conv2d(x, w, stride=2, method="lax")
for m in ("mm2im", "iom_unfused", "zero_insertion", "tdc"):
    y = mm2im.transposed_conv2d(x, w, stride=2, method=m)
    print(f"  {m:15s} max|dev| = {jnp.abs(y - gold).max():.2e}")

print("\n=== Tiled-MM2IM plan (Alg. 1) + v5e roofline per method ===")
prob = mm2im.problem(9, 9, 64, 5, 32, 2)
print(" ", mm2im.tile_plan(prob).describe())
for m, est in mm2im.ESTIMATORS.items():
    e = est(prob, batch=1, bits=8)
    print(f"  {m:15s} t={e.t_overlapped*1e6:7.2f}us "
          f"compute={e.t_compute*1e6:6.2f}us memory={e.t_memory*1e6:6.2f}us "
          f"bottleneck={e.bottleneck}")
