"""Quickstart: the MM2IM public API in 60 lines.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp

from repro.core import mm2im

# --- 1. A TCONV problem (the paper's Fig. 2 example: tconv(2,2,2,3,2,1)).
p = mm2im.problem(2, 2, 2, 3, 2, 1)
stats = mm2im.analyze(p)
print(f"Fig.2 example: drop rate D_r={stats['D_r']:.2f} "
      f"(paper: 0.55), buffer saving with skip: "
      f"{stats['buffer_saving_with_skip']:.2f}x (paper: 9x)")

# --- 2. Run a transposed convolution through the fused Pallas kernel.
key = jax.random.PRNGKey(0)
x = jax.random.normal(key, (2, 8, 8, 32))          # NHWC
w = jax.random.normal(key, (5, 5, 16, 32)) * 0.05  # HWOI (Ks,Ks,Oc,Ic)
b = jnp.zeros((16,))

y = mm2im.transposed_conv2d(x, w, b, stride=2)                  # fused MM2IM
y_ref = mm2im.transposed_conv2d(x, w, b, stride=2, method="lax")  # XLA gold
print(f"output {y.shape}, max dev vs lax: {jnp.abs(y - y_ref).max():.2e}")

# --- 3. It's differentiable (trains through the kernel).
loss = lambda w_: jnp.sum(mm2im.transposed_conv2d(x, w_, b, stride=2) ** 2)
g = jax.grad(loss)(w)
print(f"grad through kernel: |dw| = {jnp.abs(g).mean():.4f}")

# --- 4. 8-bit mode (the paper's precision): int8 x int8 -> int32 -> requant.
xq = jax.random.randint(key, (1, 8, 8, 32), -128, 127, dtype=jnp.int8)
wq = jax.random.randint(key, (5, 5, 16, 32), -128, 127, dtype=jnp.int8)
bq = jnp.zeros((16,), jnp.int32)
yq = mm2im.tconv_int8(xq, wq, bq, 3e-4, stride=2)
print(f"int8 path: {yq.shape} {yq.dtype}")

# --- 5. Inspect the Tiled-MM2IM plan (Alg. 1) the kernel will execute.
plan = mm2im.tile_plan(mm2im.problem(8, 8, 32, 5, 16, 2))
print("tile plan:", plan.describe())

# --- 6. Roofline the methods (TPU v5e model).
for m, est in mm2im.ESTIMATORS.items():
    e = est(mm2im.problem(8, 8, 32, 5, 16, 2), batch=2, bits=8)
    print(f"  {m:15s} t={e.t_overlapped*1e6:7.1f}us bottleneck={e.bottleneck}")
