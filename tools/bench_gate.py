#!/usr/bin/env python
"""CI perf gate: fresh ``BENCH_mm2im.json`` vs the committed baseline.

The benchmark harness used to *upload* its distilled perf artifact and
hope someone diffed it; this tool turns the artifact into a gate with two
legs:

**Rank leg (hard).**  Both docs' recorded head-to-heads (sb-vs-db and
folded-vs-grid — ``core/model_fit.pairs_from_bench``) are re-scored at
gate time with the *same* model (the shipped per-backend calibration when
one exists, else the raw roofline), and the candidate fails outright when
it misranks more decisive pairs than the baseline does.  Decisive means
the measured ratio is beyond the ``--decisive-band`` (ordering pairs
inside the noise band is chance, not signal).  Re-scoring both sides at
gate time, rather than trusting scores embedded in the docs, keeps a
model change from shifting the goalposts for only one side.

**Latency leg (soft, banded).**  Absolute microseconds are meaningless
across CI machines, so the latency comparison is dimensionless: each
``autotune_*`` tuned row records its tuned-vs-default speedup on *its
own* machine, and the gate compares the geomean of candidate/baseline
speedup ratios over the problems both docs measured.  A geomean below
``--noise-band`` fails; anything inside the band is reported but passes
(interpret-mode wall time on shared CI runners drifts with neighbors).

Exit codes: 0 pass, 1 gate failure, 2 unusable input.

Typical CI invocation (after ``benchmarks.run --json`` regenerated the
repo-root ``BENCH_mm2im.json``)::

    git show HEAD:BENCH_mm2im.json > /tmp/bench_baseline.json
    PYTHONPATH=src python tools/bench_gate.py \
        --candidate BENCH_mm2im.json --baseline /tmp/bench_baseline.json
"""

from __future__ import annotations

import argparse
import json
import math
import sys
from pathlib import Path

REPO_ROOT = Path(__file__).resolve().parent.parent
if str(REPO_ROOT / "src") not in sys.path:
    sys.path.insert(0, str(REPO_ROOT / "src"))

from repro.core import model_fit

#: Candidate/baseline speedup-ratio geomean below this fails the latency
#: leg.  Generous by design: tuned-vs-default ratios from 2-3 repeat
#: interpret-mode timings swing hard on shared runners, and the geomean
#: over a handful of problems only partly damps that.
DEFAULT_NOISE_BAND = 0.5

#: Sections stripped from both docs before either leg runs.  The
#: ``serve_chaos`` rows measure fault-*injected* degraded-mode serving
#: (retries, ladder descents, shed bursts — ``benchmarks/bench_serve_tconv
#: .run_chaos``): their latencies are artifacts of the injected faults,
#: so banding on them would gate kernel PRs on chaos-harness noise.
IGNORED_SECTIONS = ("serve_chaos",)


def load_doc(path: str) -> dict:
    try:
        doc = json.loads(Path(path).read_text())
    except (OSError, ValueError) as e:
        raise SystemExit(f"bench_gate: cannot read {path}: {e}")
    for section in IGNORED_SECTIONS:
        doc.pop(section, None)
    return doc


def tuned_speedups(doc: dict) -> dict:
    """name -> tuned-vs-default speedup from the doc's autotune rows.

    Rows carry ``speedup=<x.xx>x`` in their derived strings
    (``benchmarks/bench_autotune.py``); comparison-only rows (dbcmp,
    fold head-to-heads) have none and are skipped.
    """
    out = {}
    for r in doc.get("autotune", []):
        for part in str(r.get("derived", "")).split(";"):
            k, _, v = part.partition("=")
            if k == "speedup" and v.endswith("x"):
                try:
                    s = float(v[:-1])
                except ValueError:
                    continue
                if s > 0 and math.isfinite(s):
                    out[r.get("name", "")] = s
    return out


def rank_leg(cand: dict, base: dict, fit, decisive_band: float) -> tuple:
    """(ok, report_lines) for the hard rank-agreement comparison."""
    lines = []
    scores = {}
    for label, doc in (("baseline", base), ("candidate", cand)):
        pairs = model_fit.pairs_from_bench(doc)
        if not pairs:
            lines.append(f"  {label}: no head-to-head rows")
            scores[label] = None
            continue
        s = model_fit.rank_agreement(pairs, fit, decisive_band=decisive_band)
        scores[label] = s
        lines.append(
            f"  {label}: {s['n_agree']}/{s['n_pairs']} agree "
            f"({s['n_decisive']} decisive, {s['n_misranks']} misranks, "
            f"mean |log2 err| {s['mean_abs_log2_err']})")
        for r in s["pairs"]:
            flag = "ok" if r["agree"] else \
                ("MISRANK" if r["decisive"] else "miss(noise)")
            lines.append(f"    {flag:11s} {r['name']}: measured "
                         f"{r['measured_ratio']}x, predicted "
                         f"{r['predicted_ratio']}x")
    if scores.get("baseline") is None:
        lines.append("  pass: no baseline head-to-heads to compare against")
        return True, lines
    if scores.get("candidate") is None:
        lines.append("  FAIL: baseline records head-to-heads but the "
                     "candidate has none (benchmark emission regression?)")
        return False, lines
    cand_m = scores["candidate"]["n_misranks"]
    base_m = scores["baseline"]["n_misranks"]
    if cand_m > base_m:
        lines.append(f"  FAIL: candidate misranks {cand_m} decisive "
                     f"head-to-heads, baseline misranked {base_m}")
        return False, lines
    lines.append(f"  pass: misranks {cand_m} (baseline {base_m})")
    return True, lines


def latency_leg(cand: dict, base: dict, noise_band: float) -> tuple:
    """(ok, report_lines) for the banded tuned-speedup comparison."""
    lines = []
    cs, bs = tuned_speedups(cand), tuned_speedups(base)
    shared = sorted(set(cs) & set(bs))
    if not shared:
        lines.append("  pass: no commonly-measured tuned rows to compare")
        return True, lines
    logs = []
    for name in shared:
        ratio = cs[name] / bs[name]
        logs.append(math.log(ratio))
        lines.append(f"  {name}: speedup {bs[name]:.2f}x -> {cs[name]:.2f}x "
                     f"(ratio {ratio:.2f})")
    geomean = math.exp(sum(logs) / len(logs))
    if geomean < noise_band:
        lines.append(f"  FAIL: tuned-speedup geomean ratio {geomean:.2f} "
                     f"below the noise band {noise_band} over "
                     f"{len(shared)} problems")
        return False, lines
    lines.append(f"  pass: geomean ratio {geomean:.2f} over {len(shared)} "
                 f"problems (band {noise_band})")
    return True, lines


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description=__doc__.splitlines()[0],
        formatter_class=argparse.ArgumentDefaultsHelpFormatter)
    ap.add_argument("--candidate", required=True,
                    help="freshly distilled BENCH_mm2im.json")
    ap.add_argument("--baseline", required=True,
                    help="committed BENCH_mm2im.json to gate against")
    ap.add_argument("--noise-band", type=float, default=DEFAULT_NOISE_BAND,
                    help="latency leg fails when the candidate/baseline "
                         "tuned-speedup geomean ratio drops below this")
    ap.add_argument("--decisive-band", type=float,
                    default=model_fit.DECISIVE_BAND,
                    help="head-to-heads measured closer to 1.0x than this "
                         "are noise, not rank signal")
    ap.add_argument("--uncalibrated", action="store_true",
                    help="score ranks with the raw roofline even when a "
                         "shipped calibration exists")
    args = ap.parse_args(argv)

    cand = load_doc(args.candidate)
    base = load_doc(args.baseline)
    fit = None if args.uncalibrated else model_fit.shipped_fit()
    print(f"bench_gate: {args.candidate} vs {args.baseline} "
          f"({'calibrated' if fit is not None else 'roofline'} model)")

    rank_ok, lines = rank_leg(cand, base, fit, args.decisive_band)
    print("rank leg (hard):")
    print("\n".join(lines))
    lat_ok, lines = latency_leg(cand, base, args.noise_band)
    print("latency leg (soft, banded):")
    print("\n".join(lines))

    if rank_ok and lat_ok:
        print("bench_gate: PASS")
        return 0
    print("bench_gate: FAIL")
    return 1


if __name__ == "__main__":
    raise SystemExit(main())
