#!/usr/bin/env python
"""Resumable full-sweep autotuner harness + shipped-table export.

This is the CLI that turns "tune once per machine" into "tuned out of the
box": it runs the autotuner (``core/autotune.py``) over the paper's full
evaluation space — all 261 synthetic sweep configurations
(``configs/paper_models.synthetic_sweep``) plus the Table II model rows —
across dtypes and batch sizes, persisting every result to the user plan
cache *immediately*, and can then promote that cache into a committed
per-backend plan table (``core/plan_table.py``, files under
``src/repro/data/plans/``).

Resumability is structural, not checkpoint-file magic: every
``autotune_result`` call writes its winner to the cache before the next
key starts, and a cache hit performs **zero** re-measurements — so an
interrupted run (Ctrl-C, ``--max-seconds``, preemption) simply re-runs
the same command and skips straight past completed keys.

Typical workflows::

    # Full sweep on the target machine (hours on interpret mode, use TPU):
    python tools/tune_sweep.py --dtypes f32,int8 --batches 1,8

    # Budgeted slice, resumed across invocations:
    python tools/tune_sweep.py --max-seconds 600        # ... interrupted
    python tools/tune_sweep.py --max-seconds 600        # skips done keys

    # Small interpret-friendly slice (what CI smokes and what generated
    # the committed cpu.json table):
    python tools/tune_sweep.py --small --repeats 2

    # Promote the tuned cache into a committed table, then commit it:
    python tools/tune_sweep.py --export src/repro/data/plans/cpu.json
    python tools/tune_sweep.py --validate-tables

    # Fit calibrated cost coefficients from the persisted measurements
    # (zero re-measurements — replays cache/table/bench numbers only):
    python tools/tune_sweep.py --fit src/repro/data/plans/cpu.fit.json \
        --fit-bench BENCH_mm2im.json

Run with ``PYTHONPATH=src`` from the repo root (see docs/EXPERIMENTS.md
§Autotune; table format in docs/AUTOTUNER.md).
"""

from __future__ import annotations

import argparse
import json
import sys
import time
from pathlib import Path

REPO_ROOT = Path(__file__).resolve().parent.parent
if str(REPO_ROOT / "src") not in sys.path:
    sys.path.insert(0, str(REPO_ROOT / "src"))

import jax
import jax.numpy as jnp

from repro.configs.paper_models import (TABLE_II, is_small_problem,
                                        large_image_sweep, synthetic_sweep)
from repro.core import plan_table
from repro.core.autotune import (PlanCache, autotune_result, cache_key,
                                 default_cache_path)
from repro.core.maps import TConvProblem

_DTYPES = {
    "f32": jnp.float32,
    "float32": jnp.float32,
    "bf16": jnp.bfloat16,
    "bfloat16": jnp.bfloat16,
    "int8": jnp.int8,
}


def sweep_problems() -> list[TConvProblem]:
    """261 synthetic configs + Table II rows + the large-image slice,
    deduplicated.  The large-image / stride-4 members
    (``paper_models.large_image_sweep``) extend the tuned keyspace into
    the FSRCNN/pix2pix decoder regime the paper's sweep never reaches —
    they are excluded from ``--small`` automatically (none satisfies
    ``is_small_problem``)."""
    probs = list(synthetic_sweep())
    seen = set(probs)
    for p in [row.problem for row in TABLE_II] + list(large_image_sweep()):
        if p not in seen:
            seen.add(p)
            probs.append(p)
    return probs


def work_items(args) -> list[tuple[TConvProblem, object, int, str]]:
    """Ordered (problem, dtype, batch, key) list after filter/small/limit."""
    dtypes = [_DTYPES[d.strip()] for d in args.dtypes.split(",") if d.strip()]
    batches = [int(b) for b in args.batches.split(",") if b.strip()]
    items = []
    for p in sweep_problems():
        if args.small and not is_small_problem(p):
            continue
        for dtype in dtypes:
            for batch in batches:
                key = cache_key(p, dtype=dtype, batch=batch)
                if args.filter and args.filter not in key:
                    continue
                items.append((p, dtype, batch, key))
    if args.limit is not None:
        items = items[:args.limit]
    return items


def run_sweep(args) -> int:
    cache = PlanCache(args.cache)
    items = work_items(args)
    if args.list:
        for _, _, _, key in items:
            print(key)
        print(f"# {len(items)} work items")
        return 0

    t0 = time.monotonic()
    measured = skipped = folded = 0
    interrupted = False
    for i, (p, dtype, batch, key) in enumerate(items):
        if args.max_seconds and time.monotonic() - t0 > args.max_seconds:
            interrupted = True
            remaining = len(items) - i
            print(f"-- budget of {args.max_seconds}s exhausted with "
                  f"{remaining} keys remaining; re-run the same command to "
                  f"resume (completed keys replay from the cache).")
            break
        res = autotune_result(p, dtype=dtype, batch=batch, cache=cache,
                              max_measure=args.max_measure,
                              repeats=args.repeats)
        if res.from_cache:
            skipped += 1
            status = "cached"
        else:
            measured += 1
            status = f"measured {res.n_measured}/{res.n_candidates}"
        pl = res.plan
        folded += int(pl.fold_batch)
        print(f"[{i + 1}/{len(items)}] {key} -> "
              f"oh{pl.block_oh}/oc{pl.block_oc}/{pl.grid_order}"
              f"/{pl.method or 'mm2im'}{'/fold' if pl.fold_batch else ''} "
              f"us={res.us:.1f} ({status})")

    print(f"-- sweep: measured={measured} skipped={skipped} "
          f"folded_winners={folded} "
          f"elapsed={time.monotonic() - t0:.1f}s "
          f"cache={cache.path} entries={len(cache)}"
          + (" (interrupted)" if interrupted else ""))
    if args.expect_measured is not None and measured != args.expect_measured:
        print(f"-- FAIL: expected exactly {args.expect_measured} measured "
              f"keys, got {measured} (resumability regression?)")
        return 2
    return 0


def _majority(values, fallback):
    """Most common non-None value, or the fallback when none recorded."""
    counts = {}
    for v in values:
        if v is not None:
            counts[v] = counts.get(v, 0) + 1
    return max(counts, key=counts.get) if counts else fallback


def run_export(args) -> int:
    """Promote the user cache into a shipped-table file (merge per key).

    Provenance is derived from the *entries'* recorded measurement
    conditions (autotune_result stamps backend/repeats/jax per entry),
    not from this invocation's flags — an export run on a different day,
    jax version or default-repeats must not misdocument how the plans
    were actually measured.  Exporting entries tuned on a different
    backend than the table is labeled for is refused outright.
    """
    cache = PlanCache(args.cache)
    keys = [k for k in cache.keys() if not args.filter or args.filter in k]
    if not keys:
        print(f"-- nothing to export: no matching entries in {cache.path}")
        return 1
    picked = {k: cache.get_entry(k) for k in keys}
    backend = args.backend or jax.default_backend()
    alien = sorted({e.get("backend") for e in picked.values()
                    if e.get("backend") not in (None, backend)})
    if alien:
        print(f"-- FAIL: cache holds entries tuned on backend(s) "
              f"{alien}, refusing to export them into a {backend!r} table; "
              f"export each backend to its own table (e.g. --backend "
              f"{alien[0]} --export .../{alien[0]}.json), using --filter "
              f"if the cache mixes backends per key")
        return 2
    out = Path(args.export)
    entries = {}
    if out.exists():  # incremental promotion: new tuning updates old table
        try:
            prior = json.loads(out.read_text())
            # Lenient v1 load: merging new tuning into a pre-fold v1 table
            # keeps its entries and re-stamps the file at the current
            # schema version (the fold_batch field is valid from v2 on).
            if prior.get("version") in plan_table.SUPPORTED_TABLE_VERSIONS:
                entries = dict(prior.get("entries", {}))
        except ValueError:
            print(f"-- warning: existing {out} unreadable, overwriting")
    entries.update(picked)
    table = {
        "version": plan_table.TABLE_VERSION,
        "provenance": {
            "backend": backend,
            "jax": _majority((e.get("jax") for e in entries.values()),
                             jax.__version__),
            "repeats": _majority((e.get("repeats")
                                  for e in entries.values()), args.repeats),
            "created": time.time(),
            "note": args.note,
        },
        "entries": entries,
    }
    errs = plan_table.validate_table_json(table, source=str(out))
    if errs:
        print("-- FAIL: refusing to export an invalid table:")
        for e in errs:
            print(f"   {e}")
        return 2
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(table, indent=1, sort_keys=True) + "\n")
    print(f"-- exported {len(keys)} entries ({len(entries)} total) from "
          f"{cache.path} to {out} (backend={backend})")
    return 0


def run_validate(args) -> int:
    """Schema-validate every committed table + calibration (CI gate)."""
    from repro.core import model_fit

    d = Path(args.table_dir) if args.table_dir else plan_table.table_dir()
    files = sorted(d.glob("*.json")) if d.is_dir() else []
    if not files:
        print(f"-- no tables under {d} (nothing to validate)")
        return 0
    bad = 0
    for f in files:
        if f.name.endswith(".fit.json"):
            # Calibration records share the directory but not the table
            # schema — validate them as fits.
            try:
                fit = model_fit.load_fit(f, strict=True)
            except ValueError as e:
                print(f"-- FAIL {f}: {e}")
                bad += 1
                continue
            print(f"-- ok {f}: fit backend={fit.backend} "
                  f"regimes={len(fit.regimes)} "
                  f"n_samples={fit.provenance.get('n_samples')}")
            continue
        try:
            t = plan_table.load_table(f.stem, directory=d, strict=True)
        except ValueError as e:
            print(f"-- FAIL {f}: {e}")
            bad += 1
            continue
        print(f"-- ok {f}: backend={t.provenance['backend']} "
              f"jax={t.provenance['jax']} entries={len(t)}")
    return 1 if bad else 0


def run_fit(args) -> int:
    """Fit calibrated cost coefficients from persisted measurements.

    Replays the microseconds already recorded in the tuned cache, any
    shipped table, and (optionally) distilled ``BENCH_mm2im.json`` docs
    through ``core/model_fit.fit_coefficients`` — **zero measurements**:
    this never runs a kernel, so it is safe (and instant) on a resumed
    cache, and CI asserts exactly that.  Prints the per-regime
    coefficients and the rank-agreement score over any bench head-to-heads
    so a regression is visible at fit time, then writes the
    ``<backend>.fit.json`` consumed by ``core/autotune.py``.
    """
    from repro.core import model_fit

    backend = args.backend or jax.default_backend()
    samples, sources, pairs = [], [], []
    cache_path = Path(args.cache).expanduser() if args.cache \
        else default_cache_path()
    for store in [cache_path, plan_table.table_dir() / f"{backend}.json"]:
        if Path(store).exists():
            got = model_fit.samples_from_store(store, backend=backend)
            # The shipped table is usually a promoted copy of the cache;
            # dedup identical (key, us) samples so one measurement does
            # not vote twice.
            fresh = [s for s in got if s not in set(samples)]
            if fresh:
                samples.extend(fresh)
                sources.append(f"{store} ({len(fresh)} samples)")
                print(f"-- {store}: {len(fresh)} samples")
    for bench in args.fit_bench or []:
        try:
            doc = json.loads(Path(bench).read_text())
        except (OSError, ValueError) as e:
            print(f"-- warning: skipping bench doc {bench}: {e}")
            continue
        got = model_fit.samples_from_bench(doc)
        pairs.extend(model_fit.pairs_from_bench(doc))
        samples.extend(got)
        sources.append(f"{bench} ({len(got)} samples)")
        print(f"-- {bench}: {len(got)} samples")
    if not samples:
        print("-- FAIL: no measured samples found (empty cache and no "
              "bench docs?)")
        return 2
    fit = model_fit.fit_coefficients(samples, backend=backend,
                                     sources=sources, note=args.note)
    for key, c in sorted(fit.regimes.items()):
        print(f"-- regime {key:14s} n={c.n_samples:3d} "
              f"us/tile={c.us_per_tile:.4g} us/launch={c.us_per_launch:.4g} "
              f"eff_bw={c.effective_hbm_gbps:.3g}GB/s "
              f"logerr={c.mean_abs_log_err:.3f}")
    if pairs:
        score = model_fit.rank_agreement(pairs, fit)
        print(f"-- rank agreement over {score['n_pairs']} recorded "
              f"head-to-heads: {score['n_agree']}/{score['n_pairs']} "
              f"(decisive {score['decisive_agree']}/{score['n_decisive']}, "
              f"misranks={score['n_misranks']}, "
              f"mean_abs_log2_err={score['mean_abs_log2_err']})")
    out = model_fit.save_fit(fit, args.fit)
    print(f"-- fitted {len(samples)} samples (0 measured) -> {out}")
    return 0


def build_parser() -> argparse.ArgumentParser:
    ap = argparse.ArgumentParser(
        description=__doc__.splitlines()[0],
        formatter_class=argparse.ArgumentDefaultsHelpFormatter)
    ap.add_argument("--cache", default=None,
                    help="plan cache file (default: $REPRO_AUTOTUNE_CACHE "
                         f"or {default_cache_path()})")
    ap.add_argument("--dtypes", default="f32,int8",
                    help="comma list from f32,bf16,int8")
    ap.add_argument("--batches", default="1,8", help="comma list of batches")
    ap.add_argument("--limit", type=int, default=None,
                    help="tune at most N work items")
    ap.add_argument("--filter", default=None,
                    help="only keys containing this substring")
    ap.add_argument("--small", action="store_true",
                    help="interpret-friendly small-problem slice only")
    ap.add_argument("--max-seconds", type=float, default=None,
                    help="stop (resumably) after this wall-time budget")
    ap.add_argument("--repeats", type=int, default=3,
                    help="timing repeats per candidate")
    ap.add_argument("--max-measure", type=int, default=None,
                    help="survivors timed per problem (default: 4 when a "
                         "shipped calibration exists for this backend, "
                         "else 6)")
    ap.add_argument("--list", action="store_true",
                    help="print the work-item keys and exit (no tuning)")
    ap.add_argument("--expect-measured", type=int, default=None,
                    help="exit 2 unless exactly N keys were measured "
                         "(CI resumability assertion)")
    ap.add_argument("--export", metavar="TABLE_JSON", default=None,
                    help="no tuning: promote the cache into a shipped-table "
                         "file (merging into an existing one)")
    ap.add_argument("--backend", default=None,
                    help="provenance backend label for --export "
                         "(default: jax.default_backend())")
    ap.add_argument("--note", default="tools/tune_sweep.py export",
                    help="provenance note for --export")
    ap.add_argument("--fit", metavar="FIT_JSON", default=None,
                    help="no tuning: fit calibrated cost coefficients from "
                         "the cache/table/bench measurements already on "
                         "disk (zero re-measurements) and write them here "
                         "(e.g. src/repro/data/plans/cpu.fit.json)")
    ap.add_argument("--fit-bench", metavar="BENCH_JSON", action="append",
                    default=None,
                    help="distilled benchmark doc(s) whose head-to-head "
                         "rows join the --fit samples (repeatable)")
    ap.add_argument("--validate-tables", action="store_true",
                    help="no tuning: schema-validate committed plan tables")
    ap.add_argument("--table-dir", default=None,
                    help="table directory for --validate-tables "
                         "(default: the packaged src/repro/data/plans)")
    return ap


def main(argv=None) -> int:
    args = build_parser().parse_args(argv)
    if args.validate_tables:
        return run_validate(args)
    if args.fit:
        return run_fit(args)
    if args.export:
        return run_export(args)
    return run_sweep(args)


if __name__ == "__main__":
    raise SystemExit(main())
