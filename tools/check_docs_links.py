#!/usr/bin/env python3
"""Docs link-check: fail on dangling intra-repo ``*.md`` references.

Source docstrings and docs cite each other as ``DESIGN.md §2`` /
``docs/EXPERIMENTS.md §Perf`` / markdown links; PR 1 shipped with
citations to files that did not exist.  This checker walks the repo's own
text (``src/``, ``tests/``, ``benchmarks/``, ``examples/``, ``tools/``,
``docs/`` and the root ``README.md``/``ROADMAP.md``/``CHANGES.md``) and
verifies that

1. every referenced ``*.md`` file exists — bare names resolve against the
   referencing file's directory, the repo root, and ``docs/`` (so the
   conventional ``DESIGN.md §N`` shorthand in docstrings stays legal);
2. every ``§<section>`` attached to such a reference matches a heading in
   the resolved file (numeric sections match ``## N.``-style headings,
   word sections match by name).

Exit code 0 = clean; 1 = dangling references (listed on stderr).  Run
directly or via CI:

    python tools/check_docs_links.py
"""

from __future__ import annotations

import re
import sys
from pathlib import Path

REPO = Path(__file__).resolve().parent.parent

# Files whose md references we own.  PAPER.md / PAPERS.md / SNIPPETS.md /
# ISSUE.md quote external material (paper text, other repos' code) and are
# excluded as sources — but stay valid as *targets*.
SOURCE_GLOBS = [
    "README.md", "ROADMAP.md", "CHANGES.md",
    "docs/**/*.md",
    "src/**/*.py", "tests/**/*.py", "benchmarks/**/*.py",
    "examples/**/*.py", "tools/**/*.py",
]

# A *.md path-ish token, optionally followed by section refs:  §2, §2.4,
# §Perf, §Dry-run/§Roofline ...  The tail is a lookahead so that a second
# md reference within it is still matched on its own.
MD_REF = re.compile(r"(?P<path>[\w./-]*\w\.md)(?=(?P<tail>[^\n]{0,60}))")
SECTION = re.compile(r"§\s*(?P<sec>[\w][\w.-]*)")
HEADING = re.compile(r"^#{1,6}\s+(?P<text>.+)$", re.MULTILINE)


def resolve(path_str: str, src: Path):
    """Find the referenced md file; None if it does not exist anywhere."""
    candidates = [
        src.parent / path_str,
        REPO / path_str,
        REPO / "docs" / Path(path_str).name,
    ]
    for c in candidates:
        try:
            if c.is_file():
                return c.resolve()
        except OSError:
            pass
    return None


def headings(md: Path) -> list:
    return [m.group("text").strip() for m in HEADING.finditer(md.read_text())]


def section_ok(md: Path, sec: str) -> bool:
    sec = sec.rstrip(".")
    for h in headings(md):
        if re.match(r"^\d", sec):
            # numeric: '2' / '2.4' match '2. Title' / '2.4 Title' headings.
            if re.match(rf"^§?{re.escape(sec)}(?:[.\s:]|$)", h):
                return True
        else:
            # word: 'Perf' matches a heading containing the word.
            if re.search(rf"(?:^|\W){re.escape(sec)}(?:\W|$)", h,
                         re.IGNORECASE):
                return True
    return False


def main() -> int:
    sources = []
    for g in SOURCE_GLOBS:
        sources.extend(sorted(REPO.glob(g)))
    errors = []
    n_refs = 0
    for src in sources:
        if "__pycache__" in src.parts:
            continue
        text = src.read_text(errors="replace")
        for m in MD_REF.finditer(text):
            raw = m.group("path")
            path_str = raw.lstrip("./")
            # External URLs: MD_REF can't match ':', so a scheme's '//'
            # starts the match itself (pre ends with 'scheme:'), or a bare
            # 'www.' host leads the path.
            pre = text[max(0, m.start() - 12):m.start()]
            if (raw.startswith("//") and pre.endswith(":")) \
                    or "://" in pre or path_str.startswith("www."):
                continue
            n_refs += 1
            rel = src.relative_to(REPO)
            line = text.count("\n", 0, m.start()) + 1
            target = resolve(path_str, src)
            if target is None:
                errors.append(f"{rel}:{line}: dangling reference to "
                              f"'{path_str}' (no such file)")
                continue
            # Only the text immediately after the name can carry § refs —
            # and only up to the next md reference, whose § refs are its own.
            tail = m.group("tail")
            nxt = MD_REF.search(tail)
            if nxt:
                tail = tail[:nxt.start()]
            for sm in SECTION.finditer(tail):
                sec = sm.group("sec")
                if sec in ("N", "Name"):
                    continue  # schema placeholders, not real sections
                if not section_ok(target, sec):
                    errors.append(
                        f"{rel}:{line}: '{path_str} §{sec}' — no matching "
                        f"heading in {target.relative_to(REPO)}")
    if errors:
        print(f"docs link-check: {len(errors)} dangling reference(s) "
              f"(of {n_refs} checked):", file=sys.stderr)
        for e in errors:
            print(f"  {e}", file=sys.stderr)
        return 1
    print(f"docs link-check: OK ({n_refs} references across "
          f"{len(sources)} files)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
